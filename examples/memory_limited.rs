//! Breaking the memory wall: run a circuit whose *standard* state-vector
//! footprint exceeds the configured primary budget, forcing both the
//! compression path and the two-level (disk-spill) memory manager — the
//! paper's §4.4 + Table 2 story at laptop scale.
//!
//!     cargo run --release --example memory_limited

use bmqsim::circuit::generators;
use bmqsim::sim::{BmqSim, SimConfig};
use bmqsim::types::{fmt_bytes, standard_memory_bytes, Precision};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 22; // standard footprint: 2^26 B = 64 MiB
    let budget = 4 << 20; // primary tier: 4 MiB — 16x too small for dense
    let spill = std::env::temp_dir().join("bmqsim-example-spill");

    println!("circuit      : ising, n={n}");
    println!(
        "standard mem : {} (dense simulation would need this)",
        fmt_bytes(standard_memory_bytes(n, Precision::F64))
    );
    println!("primary tier : {} budget", fmt_bytes(budget as u128));
    println!("secondary    : {} (disk spill, GDS/SSD analogue)\n", spill.display());

    let circuit = generators::ising(n, 42);
    let config = SimConfig {
        memory_budget: Some(budget),
        spill_dir: Some(spill),
        ..SimConfig::default()
    };
    let result = BmqSim::new(config).run(&circuit, false)?;

    println!("{}", result.metrics);
    println!("stages            : {}", result.stages);
    println!("peak compressed   : {}", fmt_bytes(result.peak_bytes as u128));
    println!(
        "primary peak      : {}",
        fmt_bytes(result.mem.peak_primary_bytes as u128)
    );
    println!(
        "secondary peak    : {}",
        fmt_bytes(result.mem.peak_secondary_bytes as u128)
    );
    println!("spill events      : {}", result.mem.spill_events);
    println!(
        "blocks on ssd     : {:.0}% at end of run",
        100.0 * result.mem.secondary_fraction()
    );
    assert!(
        result.mem.peak_primary_bytes <= budget,
        "two-level manager must respect the primary budget"
    );
    println!("\nOK — simulated a {} state inside a {} primary budget.",
        fmt_bytes(standard_memory_bytes(n, Precision::F64)),
        fmt_bytes(budget as u128));
    Ok(())
}
