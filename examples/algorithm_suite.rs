//! End-to-end driver: the full NWQBench-style suite (all 8 algorithms the
//! paper evaluates) through every layer of the system — Algorithm-1
//! partitioning, the pipelined compressed engine, the two-level memory
//! manager — reporting the paper's headline metrics per circuit: fidelity
//! (>0.99), memory reduction vs the 2^(n+4) standard, and time vs dense.
//!
//!     cargo run --release --example algorithm_suite [n_qubits]
//!
//! Results for the recorded run live in EXPERIMENTS.md.

use bmqsim::circuit::generators;
use bmqsim::metrics::Table;
use bmqsim::sim::{BmqSim, DenseSim, SimConfig};
use bmqsim::types::{fmt_bytes, standard_memory_bytes, Precision};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(18);
    println!("BMQSIM end-to-end suite at n={n} (paper runs 23-33; scaled testbed)\n");

    let mut table = Table::new(&[
        "algorithm", "gates", "stages", "dense (s)", "bmqsim (s)", "fidelity", "standard",
        "peak", "reduction",
    ]);
    let mut worst_fidelity: f64 = 1.0;
    for name in generators::ALL {
        let circuit = generators::build(name, n, 42)?;
        let dense = DenseSim::new(SimConfig::default()).run(&circuit)?;
        let ideal = dense.state.as_ref().unwrap();
        let result = BmqSim::new(SimConfig::default()).run(&circuit, true)?;
        let fidelity = result.state.as_ref().unwrap().fidelity(ideal);
        worst_fidelity = worst_fidelity.min(fidelity);
        let std_bytes = standard_memory_bytes(n, Precision::F64);
        table.row(&[
            name.to_string(),
            circuit.len().to_string(),
            result.stages.to_string(),
            format!("{:.3}", dense.wall_secs),
            format!("{:.3}", result.wall_secs),
            format!("{fidelity:.6}"),
            fmt_bytes(std_bytes),
            fmt_bytes(result.peak_bytes as u128),
            format!("{:.1}x", std_bytes as f64 / result.peak_bytes as f64),
        ]);
    }
    println!("{table}");
    println!("worst-case fidelity: {worst_fidelity:.6} (paper headline: > 0.99)");
    assert!(worst_fidelity > 0.99);
    println!("suite PASSED — all layers compose end to end.");
    Ok(())
}
