//! Quickstart: build a circuit, run it through BMQSIM, check fidelity.
//!
//!     cargo run --release --example quickstart

use bmqsim::circuit::Circuit;
use bmqsim::sim::{BmqSim, DenseSim, SimConfig};
use bmqsim::types::{fmt_bytes, standard_memory_bytes, Precision};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A 16-qubit circuit: GHZ prep + a phase-rotation layer + QFT tail.
    let n = 16;
    let mut circuit = Circuit::new(n, "quickstart");
    circuit.h(0);
    for q in 1..n {
        circuit.cx(q - 1, q);
    }
    for q in 0..n {
        circuit.rz(0.1 * q as f64, q);
    }
    for q in 0..4 {
        circuit.h(q);
        for j in (q + 1)..4 {
            circuit.cp(std::f64::consts::PI / (1 << (j - q)) as f64, j, q);
        }
    }
    println!(
        "circuit: {} qubits, {} gates ({} two-qubit)",
        circuit.n_qubits,
        circuit.len(),
        circuit.two_qubit_count()
    );

    // The compressed engine with the paper's defaults (pointwise 1e-3).
    let config = SimConfig { block_qubits: 12, ..SimConfig::default() };
    let result = BmqSim::new(config).run(&circuit, true)?;

    // Reference run for fidelity.
    let ideal = DenseSim::new(SimConfig::default()).run(&circuit)?.state.unwrap();
    let fidelity = result.state.as_ref().unwrap().fidelity(&ideal);

    println!("\n{}", result.metrics);
    println!("stages            : {}", result.stages);
    println!(
        "standard memory   : {}",
        fmt_bytes(standard_memory_bytes(n, Precision::F64))
    );
    println!("peak compressed   : {}", fmt_bytes(result.peak_bytes as u128));
    println!("fidelity vs ideal : {fidelity:.6}");
    assert!(fidelity > 0.99, "paper's headline: fidelity stays above 0.99");
    println!("\nOK — compressed simulation matched the dense reference.");
    Ok(())
}
