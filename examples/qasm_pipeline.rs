//! OpenQASM ingestion pipeline: parse a .qasm program, inspect its
//! Algorithm-1 partition, simulate it compressed, and sample measurements —
//! the workflow a downstream user runs on their own circuits.
//!
//!     cargo run --release --example qasm_pipeline [file.qasm]
//!
//! Without an argument, a bundled 12-qubit program is used.

use bmqsim::circuit::{partition_circuit, qasm};
use bmqsim::gates::measure;
use bmqsim::sim::{BmqSim, SimConfig};
use bmqsim::types::SplitMix64;

const BUNDLED: &str = r#"
OPENQASM 2.0;
include "qelib1.inc";
// 12-qubit W-like cascade with phases
qreg q[12];
creg c[12];
h q[0];
cx q[0], q[1];
cx q[1], q[2];
rz(pi/8) q[2];
cx q[2], q[3];
h q[4];
cp(pi/4) q[4], q[5];
cx q[5], q[6];
rzz(0.35) q[6], q[7];
u3(0.4, pi/2, -pi/4) q[8];
cx q[8], q[9];
swap q[9], q[10];
cry(1.2) q[10], q[11];
barrier q;
measure q[0] -> c[0];
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let circuit = match std::env::args().nth(1) {
        Some(path) => qasm::parse_file(std::path::Path::new(&path))?,
        None => qasm::parse(BUNDLED, "bundled")?,
    };
    println!(
        "parsed {}: {} qubits, {} gates",
        circuit.name,
        circuit.n_qubits,
        circuit.len()
    );
    for (kind, count) in circuit.kind_histogram() {
        println!("  {kind:<6} x{count}");
    }

    let b = 8.min(circuit.n_qubits);
    let plan = partition_circuit(&circuit, b, 2)?;
    println!(
        "\npartition: {} stages (block_qubits={b}); compression rounds {} vs {} per-gate",
        plan.stages.len(),
        plan.compression_rounds(),
        circuit.len()
    );

    let config = SimConfig { block_qubits: b, ..SimConfig::default() };
    let result = BmqSim::new(config).run(&circuit, true)?;
    println!("\nsimulated in {:.3}s; compression ratio {:.1}x",
        result.wall_secs, result.metrics.compression_ratio());

    let state = result.state.as_ref().unwrap();
    let mut rng = SplitMix64::new(7);
    let counts = measure::sample_counts(state, 4096, &mut rng);
    let mut rows: Vec<(usize, usize)> = counts.into_iter().collect();
    rows.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    println!("\ntop measurement outcomes (4096 shots):");
    for (idx, count) in rows.into_iter().take(8) {
        println!(
            "  |{idx:0w$b}> {:>6}  ({:.2}%)",
            count,
            100.0 * count as f64 / 4096.0,
            w = circuit.n_qubits
        );
    }
    Ok(())
}
