"""L2: JAX compute graphs wrapping the L1 Pallas kernels.

Each entry point here is a pure jax function of fixed-shape operands that
``aot.py`` lowers ONCE to HLO text. The rust runtime (L3) loads the HLO via
PJRT and calls it on the request path — python never runs at simulation time.

Shape strategy: artifacts are compiled for fixed power-of-two *chunk* sizes
(``M_CHUNK_1Q`` pair rows, etc.). The rust side processes arbitrarily large
SV-group buffers by looping whole chunks through the executable; buffers are
always power-of-two sized, so a buffer either fills N whole chunks or is
smaller than one chunk (then the dedicated small-shape variant from the
manifest is used). This keeps the artifact set tiny (a dozen modules) while
supporting every block/inner-size configuration.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import gate_kernel, quant_kernel

# Chunk geometry shared with rust via artifacts/manifest.json.
M_CHUNK_1Q = 1 << 14  # pair rows per executable call (k=2)
M_CHUNK_2Q = 1 << 13  # quad rows per executable call (k=4)
N_CHUNK = 1 << 15  # elements per quantizer call


def gate1q(xr, xi, ur, ui):
    """Apply a single-qubit (2x2) complex gate to pair-major planes."""
    return gate_kernel.apply_gate(xr, xi, ur, ui, k=2)


def gate2q(xr, xi, ur, ui):
    """Apply a double-qubit (4x4) complex gate to quad-major planes."""
    return gate_kernel.apply_gate(xr, xi, ur, ui, k=4)


def diag1q(xr, xi, dr, di):
    """Apply a diagonal single-qubit gate (Z/S/T/RZ/P family)."""
    return gate_kernel.apply_diag_gate(xr, xi, dr, di, k=2)


def diag2q(xr, xi, dr, di):
    """Apply a diagonal double-qubit gate (CZ/CP/RZZ family)."""
    return gate_kernel.apply_diag_gate(xr, xi, dr, di, k=4)


def make_quantize(error_bound: float):
    """Quantizer graph for a fixed point-wise relative bound."""

    def quantize(x):
        return quant_kernel.quantize(x, error_bound=error_bound)

    return quantize


def make_dequantize(error_bound: float, dtype):
    """Dequantizer graph for a fixed bound and output dtype."""

    def dequantize(codes, signs):
        return quant_kernel.dequantize(
            codes, signs, error_bound=error_bound, dtype=dtype
        )

    return dequantize


def norm_sq(xr, xi):
    """Total probability of a plane pair — used for normalization checks."""
    return (jnp.sum(xr * xr) + jnp.sum(xi * xi),)
