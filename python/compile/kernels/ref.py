"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel is checked
against its oracle by pytest (with hypothesis shape/dtype sweeps) at build
time, before AOT artifacts ship to the rust runtime.
"""

from __future__ import annotations

import jax.numpy as jnp

CODE_MID = 1 << 19
ZERO_CODE = 0


def apply_gate_ref(xr, xi, ur, ui):
    """out[m, :] = u @ x[m, :] over complex planes; reference einsum path."""
    x = xr + 1j * xi
    u = ur + 1j * ui
    out = jnp.einsum("ij,mj->mi", u, x)
    return jnp.real(out).astype(xr.dtype), jnp.imag(out).astype(xi.dtype)


def apply_diag_gate_ref(xr, xi, dr, di):
    """out[m, :] = diag(d) x[m, :] over complex planes."""
    x = xr + 1j * xi
    d = (dr + 1j * di).reshape(1, -1)
    out = x * d
    return jnp.real(out).astype(xr.dtype), jnp.imag(out).astype(xi.dtype)


def quantize_ref(x, *, error_bound: float):
    """Reference log2-domain point-wise relative quantizer."""
    b_a = jnp.log2(1.0 + error_bound)
    signs = (x < 0.0).astype(jnp.int32)
    ax = jnp.abs(x)
    is_zero = ax == 0.0
    safe = jnp.where(is_zero, 1.0, ax)
    code = jnp.round(jnp.log2(safe) / (2.0 * b_a)).astype(jnp.int32) + CODE_MID
    codes = jnp.where(is_zero, ZERO_CODE, code)
    return codes, signs


def dequantize_ref(codes, signs, *, error_bound: float, dtype=jnp.float64):
    """Reference reconstruction; |x_hat - x| / |x| <= error_bound."""
    b_a = jnp.log2(1.0 + error_bound)
    is_zero = codes == ZERO_CODE
    mag = jnp.exp2((codes - CODE_MID).astype(dtype) * (2.0 * b_a))
    mag = jnp.where(is_zero, jnp.zeros_like(mag), mag)
    return jnp.where(signs != 0, -mag, mag)
