"""L1 Pallas kernels: batched small complex mat-vec — the gate-application
hot-spot of state-vector simulation.

The state block is gathered (on the rust side) into pair-major layout
``[M, K]`` where ``K = 2`` for single-qubit gates and ``K = 4`` for
double-qubit gates: row ``m`` holds the ``K`` amplitudes whose indices differ
only in the target qubit bit(s). Applying the gate is then one batched
``K x K`` complex mat-vec::

    out[m, :] = u @ in[m, :]        for every m

Hardware adaptation (paper's CUDA threadblocks -> Pallas/TPU):
  * the GPU kernel tiled amplitude pairs across threadblocks in shared
    memory; here ``BlockSpec`` tiles the M axis into VMEM-sized chunks
    (TILE_M rows x K x 2 operands x 8 B = ~0.5 MiB at TILE_M=4096, K=4,
    far under the ~16 MiB VMEM budget) and the grid expresses the
    HBM->VMEM schedule,
  * 2x2/4x4 matmuls cannot feed the 128x128 MXU; the work is VPU-bound
    element-wise FMA, matching the paper's memory-bound characterization.
    We therefore phrase the complex product as broadcasted multiply-adds
    rather than ``jnp.dot`` so the VPU lowering is direct.

Complex numbers travel as split re/im planes (SoA): PJRT literal plumbing
on the rust side stays dtype-trivial and the compressor sees plain floats.

Kernels MUST run ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM tile along the batch (pair) axis. 4096 rows x 4 cols x 2 planes x 8 B
# = 256 KiB resident per operand tile — comfortable double-buffering headroom.
TILE_M = 4096


def _gate_kernel(xr_ref, xi_ref, ur_ref, ui_ref, or_ref, oi_ref, *, k: int):
    """One VMEM tile: out[m, i] = sum_j u[i, j] * x[m, j] (complex)."""
    xr = xr_ref[...]  # [tile_m, k]
    xi = xi_ref[...]
    ur = ur_ref[...]  # [k, k]
    ui = ui_ref[...]
    # Broadcasted complex mat-vec: accumulate over j with VPU FMAs.
    # (re + i*im) ' = (ur + i*ui) @ (xr + i*xi)
    acc_r = jnp.zeros_like(xr)
    acc_i = jnp.zeros_like(xi)
    for i in range(k):
        row_r = jnp.zeros_like(xr[:, 0])
        row_i = jnp.zeros_like(xi[:, 0])
        for j in range(k):
            row_r = row_r + ur[i, j] * xr[:, j] - ui[i, j] * xi[:, j]
            row_i = row_i + ur[i, j] * xi[:, j] + ui[i, j] * xr[:, j]
        acc_r = acc_r.at[:, i].set(row_r)
        acc_i = acc_i.at[:, i].set(row_i)
    or_ref[...] = acc_r
    oi_ref[...] = acc_i


@functools.partial(jax.jit, static_argnames=("k",))
def apply_gate(xr, xi, ur, ui, *, k: int):
    """Batched K x K complex mat-vec over pair-major planes.

    Args:
      xr, xi: ``[M, k]`` real/imag amplitude planes (M % TILE_M may be != 0).
      ur, ui: ``[k, k]`` real/imag unitary planes.
      k: 2 for single-qubit gates, 4 for double-qubit gates.

    Returns:
      (out_re, out_im), each ``[M, k]``.
    """
    m = xr.shape[0]
    tile = min(TILE_M, m)
    grid = (pl.cdiv(m, tile),)
    kern = functools.partial(_gate_kernel, k=k)
    out_shape = (
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        jax.ShapeDtypeStruct(xi.shape, xi.dtype),
    )
    data_spec = pl.BlockSpec((tile, k), lambda i: (i, 0))
    mat_spec = pl.BlockSpec((k, k), lambda i: (0, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[data_spec, data_spec, mat_spec, mat_spec],
        out_specs=(data_spec, data_spec),
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, ur, ui)


def _diag_kernel(xr_ref, xi_ref, dr_ref, di_ref, or_ref, oi_ref):
    """Diagonal-gate tile: out[m, j] = d[j] * x[m, j] (complex).

    Diagonal gates (Z, S, T, RZ, CP, RZZ, ...) never mix amplitudes, so the
    full K x K product is wasteful; this kernel is the paper-faithful
    fast path (pure element-wise VPU work, no gather restructure needed).
    """
    xr = xr_ref[...]
    xi = xi_ref[...]
    dr = dr_ref[...]  # [1, k]
    di = di_ref[...]
    or_ref[...] = xr * dr - xi * di
    oi_ref[...] = xi * dr + xr * di


@functools.partial(jax.jit, static_argnames=("k",))
def apply_diag_gate(xr, xi, dr, di, *, k: int):
    """Batched diagonal complex scale: out[m, :] = diag(d) x[m, :]."""
    m = xr.shape[0]
    tile = min(TILE_M, m)
    grid = (pl.cdiv(m, tile),)
    out_shape = (
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        jax.ShapeDtypeStruct(xi.shape, xi.dtype),
    )
    data_spec = pl.BlockSpec((tile, k), lambda i: (i, 0))
    diag_spec = pl.BlockSpec((1, k), lambda i: (0, 0))
    return pl.pallas_call(
        _diag_kernel,
        grid=grid,
        in_specs=[data_spec, data_spec, diag_spec, diag_spec],
        out_specs=(data_spec, data_spec),
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, dr, di)
