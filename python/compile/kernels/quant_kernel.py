"""L1 Pallas kernels: the point-wise hot loop of the Algorithm-2 compressor.

BMQSIM's point-wise relative-error control (paper §4.3) transforms amplitude
magnitudes into log2 space where an *absolute* bound ``b_a = log2(1 + b_r)``
realizes a point-wise *relative* bound ``b_r``. The per-element transform +
linear-scaling quantization is the compressor's compute hot-spot; everything
after it (prediction residual coding, Huffman) is bit-twiddling done in rust.

``quantize``  : x -> (sign_bit, code) with
                code = round(log2(|x|) / (2 * b_a)) - offset, 0 for x == 0
``dequantize``: inverse reconstruction honoring the bound.

Exact zeros are ubiquitous in state vectors (cat/ghz/bv compress 400-700x in
the paper precisely because of them), so zero survives round-trip exactly:
we reserve ``code == zero_code`` for it.

Element-wise -> pure VPU work; BlockSpec tiles a flat [N] plane in 64 KiB
chunks. interpret=True as required on CPU PJRT.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 8192

# Quantized codes are biased into uint-friendly range around this midpoint;
# log2|amplitude| for normalized states lies in ~[-1075, 0] for f64 so a
# 2^20 code space with midpoint 2^19 is ample at b_r >= 1e-6.
CODE_MID = 1 << 19
ZERO_CODE = 0


def _quantize_kernel(x_ref, codes_ref, signs_ref, *, inv_twoeb: float):
    x = x_ref[...]
    signs_ref[...] = (x < 0.0).astype(jnp.int32)
    ax = jnp.abs(x)
    is_zero = ax == 0.0
    # log2 of zero is -inf; mask before the transform to keep FP flags clean.
    safe = jnp.where(is_zero, 1.0, ax)
    logx = jnp.log2(safe)
    code = jnp.round(logx * inv_twoeb).astype(jnp.int32) + CODE_MID
    codes_ref[...] = jnp.where(is_zero, ZERO_CODE, code)


@functools.partial(jax.jit, static_argnames=("error_bound",))
def quantize(x, *, error_bound: float):
    """Point-wise relative-error quantization of one plane.

    Args:
      x: flat ``[N]`` float plane (re or im amplitudes).
      error_bound: point-wise relative bound ``b_r`` (e.g. 1e-3).

    Returns:
      (codes int32 ``[N]``, signs int32 ``[N]``).
    """
    b_a = math.log2(1.0 + error_bound)
    inv_twoeb = 1.0 / (2.0 * b_a)
    n = x.shape[0]
    tile = min(TILE_N, n)
    grid = (pl.cdiv(n, tile),)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_quantize_kernel, inv_twoeb=inv_twoeb),
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(x.shape, jnp.int32),
            jax.ShapeDtypeStruct(x.shape, jnp.int32),
        ),
        interpret=True,
    )(x)


def _dequantize_kernel(codes_ref, signs_ref, x_ref, *, twoeb: float, dtype):
    codes = codes_ref[...]
    signs = signs_ref[...]
    is_zero = codes == ZERO_CODE
    logx = (codes - CODE_MID).astype(dtype) * twoeb
    mag = jnp.exp2(logx)
    mag = jnp.where(is_zero, jnp.zeros_like(mag), mag)
    x_ref[...] = jnp.where(signs != 0, -mag, mag)


@functools.partial(jax.jit, static_argnames=("error_bound", "dtype"))
def dequantize(codes, signs, *, error_bound: float, dtype=jnp.float64):
    """Inverse of :func:`quantize`: reconstruct the plane within ``b_r``."""
    b_a = math.log2(1.0 + error_bound)
    twoeb = 2.0 * b_a
    n = codes.shape[0]
    tile = min(TILE_N, n)
    grid = (pl.cdiv(n, tile),)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, twoeb=twoeb, dtype=dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(codes.shape, dtype),
        interpret=True,
    )(codes, signs)
