"""AOT lowering: jax/pallas graphs -> HLO *text* artifacts + manifest.json.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``: jax
>= 0.5 emits HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/load_hlo).

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts

Every module is lowered with ``return_tuple=True``; the rust side unwraps
with ``to_tupleN()``. ``manifest.json`` records, per artifact: the kernel
name, operand dtypes/shapes, chunk geometry, and the error bound baked into
quantizer modules — rust reads only the manifest, never this file.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# Point-wise relative error bounds baked into quantizer artifacts. 1e-3 is
# the paper's default (§5.1); the others support the ablation sweeps.
ERROR_BOUNDS = [1e-2, 1e-3, 1e-4]

DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """Yield (name, fn, arg_specs, meta) for every artifact to emit."""
    for dname, dt in DTYPES.items():
        for kind, fn, m, k in (
            ("gate1q", model.gate1q, model.M_CHUNK_1Q, 2),
            ("gate2q", model.gate2q, model.M_CHUNK_2Q, 4),
        ):
            yield (
                f"{kind}_{dname}",
                fn,
                (
                    spec((m, k), dt),
                    spec((m, k), dt),
                    spec((k, k), dt),
                    spec((k, k), dt),
                ),
                {"kernel": kind, "dtype": dname, "m": m, "k": k},
            )
        for kind, fn, m, k in (
            ("diag1q", model.diag1q, model.M_CHUNK_1Q, 2),
            ("diag2q", model.diag2q, model.M_CHUNK_2Q, 4),
        ):
            yield (
                f"{kind}_{dname}",
                fn,
                (
                    spec((m, k), dt),
                    spec((m, k), dt),
                    spec((1, k), dt),
                    spec((1, k), dt),
                ),
                {"kernel": kind, "dtype": dname, "m": m, "k": k},
            )
        n = model.N_CHUNK
        for eb in ERROR_BOUNDS:
            tag = f"{eb:.0e}".replace("-0", "-")
            yield (
                f"quantize_{dname}_{tag}",
                model.make_quantize(eb),
                (spec((n,), dt),),
                {
                    "kernel": "quantize",
                    "dtype": dname,
                    "n": n,
                    "error_bound": eb,
                },
            )
            yield (
                f"dequantize_{dname}_{tag}",
                model.make_dequantize(eb, dt),
                (spec((n,), jnp.int32), spec((n,), jnp.int32)),
                {
                    "kernel": "dequantize",
                    "dtype": dname,
                    "n": n,
                    "error_bound": eb,
                },
            )
        yield (
            f"normsq_{dname}",
            model.norm_sq,
            (spec((n,), dt), spec((n,), dt)),
            {"kernel": "normsq", "dtype": dname, "n": n},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "chunks": {
            "m_1q": model.M_CHUNK_1Q,
            "m_2q": model.M_CHUNK_2Q,
            "n_quant": model.N_CHUNK,
        },
        "error_bounds": ERROR_BOUNDS,
        "modules": {},
    }
    for name, fn, arg_specs, meta in build_entries():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        meta["file"] = fname
        meta["outputs"] = len(lowered.out_info)
        manifest["modules"][name] = meta
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['modules'])} modules -> {args.out}")


if __name__ == "__main__":
    main()
