"""Pallas gate kernels vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps batch sizes, dtypes, and gate matrices; fixed cases pin
the physically meaningful gates (H, X, CX, RZ...) with exact expectations.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gate_kernel, ref

ATOL = {jnp.float32: 1e-5, jnp.float64: 1e-12}


def rand_planes(rng, m, k, dtype):
    xr = rng.standard_normal((m, k)).astype(dtype)
    xi = rng.standard_normal((m, k)).astype(dtype)
    return jnp.asarray(xr), jnp.asarray(xi)


def unitary_1q(theta, phi, lam, dtype):
    """U3 gate planes."""
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    u = np.array(
        [
            [c, -s * np.exp(1j * lam)],
            [s * np.exp(1j * phi), c * np.exp(1j * (phi + lam))],
        ]
    )
    return (
        jnp.asarray(u.real.astype(dtype)),
        jnp.asarray(u.imag.astype(dtype)),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("k", [2, 4])
@pytest.mark.parametrize("m", [1, 7, 256, 8192])
def test_gate_matches_ref(dtype, k, m):
    rng = np.random.default_rng(42 + m + k)
    xr, xi = rand_planes(rng, m, k, dtype)
    ur = jnp.asarray(rng.standard_normal((k, k)).astype(dtype))
    ui = jnp.asarray(rng.standard_normal((k, k)).astype(dtype))
    got_r, got_i = gate_kernel.apply_gate(xr, xi, ur, ui, k=k)
    want_r, want_i = ref.apply_gate_ref(xr, xi, ur, ui)
    np.testing.assert_allclose(got_r, want_r, atol=ATOL[dtype], rtol=1e-5)
    np.testing.assert_allclose(got_i, want_i, atol=ATOL[dtype], rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("k", [2, 4])
@pytest.mark.parametrize("m", [1, 5, 1024])
def test_diag_matches_ref(dtype, k, m):
    rng = np.random.default_rng(7 + m + k)
    xr, xi = rand_planes(rng, m, k, dtype)
    dr = jnp.asarray(rng.standard_normal((1, k)).astype(dtype))
    di = jnp.asarray(rng.standard_normal((1, k)).astype(dtype))
    got_r, got_i = gate_kernel.apply_diag_gate(xr, xi, dr, di, k=k)
    want_r, want_i = ref.apply_diag_gate_ref(xr, xi, dr, di)
    np.testing.assert_allclose(got_r, want_r, atol=ATOL[dtype], rtol=1e-5)
    np.testing.assert_allclose(got_i, want_i, atol=ATOL[dtype], rtol=1e-5)


def test_hadamard_on_zero_state():
    """H|0> = (|0> + |1>)/sqrt(2) for every pair row."""
    m = 64
    xr = jnp.zeros((m, 2), jnp.float64).at[:, 0].set(1.0)
    xi = jnp.zeros((m, 2), jnp.float64)
    h = 1.0 / math.sqrt(2.0)
    ur = jnp.asarray([[h, h], [h, -h]], jnp.float64)
    ui = jnp.zeros((2, 2), jnp.float64)
    got_r, got_i = gate_kernel.apply_gate(xr, xi, ur, ui, k=2)
    np.testing.assert_allclose(got_r, jnp.full((m, 2), h), atol=1e-15)
    np.testing.assert_allclose(got_i, 0.0, atol=1e-15)


def test_pauli_x_swaps_pair():
    m = 16
    rng = np.random.default_rng(3)
    xr, xi = rand_planes(rng, m, 2, np.float64)
    ur = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float64)
    ui = jnp.zeros((2, 2), jnp.float64)
    got_r, got_i = gate_kernel.apply_gate(xr, xi, ur, ui, k=2)
    np.testing.assert_allclose(got_r, xr[:, ::-1], atol=1e-15)
    np.testing.assert_allclose(got_i, xi[:, ::-1], atol=1e-15)


def test_cnot_permutes_quad():
    """CX in quad layout (q=control, k=target) permutes cols 2<->3."""
    m = 8
    rng = np.random.default_rng(5)
    xr, xi = rand_planes(rng, m, 4, np.float64)
    u = np.eye(4)[[0, 1, 3, 2]]
    ur, ui = jnp.asarray(u), jnp.zeros((4, 4), jnp.float64)
    got_r, _ = gate_kernel.apply_gate(xr, xi, ur, ui, k=4)
    np.testing.assert_allclose(got_r, xr[:, [0, 1, 3, 2]], atol=1e-15)


def test_unitarity_preserves_norm():
    """A unitary gate must preserve sum |a|^2 to fp accuracy."""
    rng = np.random.default_rng(11)
    m = 512
    xr, xi = rand_planes(rng, m, 2, np.float64)
    ur, ui = unitary_1q(0.7, 0.3, 1.1, np.float64)
    got_r, got_i = gate_kernel.apply_gate(xr, xi, ur, ui, k=2)
    before = float(jnp.sum(xr**2 + xi**2))
    after = float(jnp.sum(got_r**2 + got_i**2))
    assert abs(before - after) < 1e-9 * max(1.0, before)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=3000),
    k=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    use_f32=st.booleans(),
)
def test_gate_property_sweep(m, k, seed, use_f32):
    """Hypothesis: arbitrary shapes/dtypes/matrices match the oracle."""
    dtype = np.float32 if use_f32 else np.float64
    rng = np.random.default_rng(seed)
    xr, xi = rand_planes(rng, m, k, dtype)
    ur = jnp.asarray(rng.standard_normal((k, k)).astype(dtype))
    ui = jnp.asarray(rng.standard_normal((k, k)).astype(dtype))
    got_r, got_i = gate_kernel.apply_gate(xr, xi, ur, ui, k=k)
    want_r, want_i = ref.apply_gate_ref(xr, xi, ur, ui)
    tol = 1e-4 if use_f32 else 1e-11
    np.testing.assert_allclose(got_r, want_r, atol=tol, rtol=tol)
    np.testing.assert_allclose(got_i, want_i, atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=2000),
    k=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_diag_property_sweep(m, k, seed):
    rng = np.random.default_rng(seed)
    xr, xi = rand_planes(rng, m, k, np.float64)
    dr = jnp.asarray(rng.standard_normal((1, k)))
    di = jnp.asarray(rng.standard_normal((1, k)))
    got_r, got_i = gate_kernel.apply_diag_gate(xr, xi, dr, di, k=k)
    want_r, want_i = ref.apply_diag_gate_ref(xr, xi, dr, di)
    np.testing.assert_allclose(got_r, want_r, atol=1e-11, rtol=1e-11)
    np.testing.assert_allclose(got_i, want_i, atol=1e-11, rtol=1e-11)


def test_gate_composition_associativity():
    """(u2 u1) x == u2 (u1 x): kernel respects matrix composition."""
    rng = np.random.default_rng(23)
    m = 128
    xr, xi = rand_planes(rng, m, 2, np.float64)
    u1r, u1i = unitary_1q(0.4, 0.2, 0.9, np.float64)
    u2r, u2i = unitary_1q(1.3, -0.5, 0.1, np.float64)
    s1r, s1i = gate_kernel.apply_gate(xr, xi, u1r, u1i, k=2)
    s2r, s2i = gate_kernel.apply_gate(s1r, s1i, u2r, u2i, k=2)
    u1 = np.asarray(u1r) + 1j * np.asarray(u1i)
    u2 = np.asarray(u2r) + 1j * np.asarray(u2i)
    u21 = u2 @ u1
    cr, ci = gate_kernel.apply_gate(
        xr, xi, jnp.asarray(u21.real), jnp.asarray(u21.imag), k=2
    )
    np.testing.assert_allclose(s2r, cr, atol=1e-12)
    np.testing.assert_allclose(s2i, ci, atol=1e-12)
