"""Quantizer Pallas kernel vs oracle + the point-wise error-bound property.

The bound is THE contract of Algorithm 2: for every nonzero x,
|dequantize(quantize(x)) - x| / |x| <= b_r, and exact zeros survive exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant_kernel, ref


def roundtrip(x, eb, dtype=jnp.float64):
    codes, signs = quant_kernel.quantize(jnp.asarray(x, dtype), error_bound=eb)
    return np.asarray(
        quant_kernel.dequantize(codes, signs, error_bound=eb, dtype=dtype)
    )


@pytest.mark.parametrize("eb", [1e-2, 1e-3, 1e-4])
@pytest.mark.parametrize("n", [1, 64, 8192, 20000])
def test_quantize_matches_ref(eb, n):
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n)
    x[:: max(1, n // 7)] = 0.0  # salt exact zeros in
    xj = jnp.asarray(x)
    got_c, got_s = quant_kernel.quantize(xj, error_bound=eb)
    want_c, want_s = ref.quantize_ref(xj, error_bound=eb)
    np.testing.assert_array_equal(got_c, want_c)
    np.testing.assert_array_equal(got_s, want_s)
    got_x = quant_kernel.dequantize(got_c, got_s, error_bound=eb)
    want_x = ref.dequantize_ref(want_c, want_s, error_bound=eb)
    np.testing.assert_allclose(got_x, want_x, rtol=1e-14)


@pytest.mark.parametrize("eb", [1e-2, 1e-3, 1e-4])
def test_pointwise_relative_error_bound(eb):
    """The headline invariant: point-wise relative error <= b_r."""
    rng = np.random.default_rng(17)
    # span many magnitudes incl. denormal-ish and large values
    x = rng.standard_normal(4096) * np.logspace(-30, 3, 4096)
    rec = roundtrip(x, eb)
    nz = x != 0
    rel = np.abs(rec[nz] - x[nz]) / np.abs(x[nz])
    assert rel.max() <= eb * (1 + 1e-9), f"max rel err {rel.max()} > {eb}"


def test_exact_zero_roundtrip():
    x = np.zeros(1000)
    rec = roundtrip(x, 1e-3)
    assert (rec == 0.0).all()


def test_signs_preserved():
    x = np.array([-1.5, 2.0, -1e-20, 3e10, 0.0, -0.25])
    rec = roundtrip(x, 1e-3)
    np.testing.assert_array_equal(np.sign(rec), np.sign(x))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=1, max_value=30000),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
    scale_pow=st.integers(min_value=-200, max_value=100),
)
def test_roundtrip_bound_property(seed, n, eb, scale_pow):
    """Hypothesis: bound holds for arbitrary sizes and magnitude regimes."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n) * (2.0**scale_pow)
    zeros = rng.random(n) < 0.3
    x[zeros] = 0.0
    rec = roundtrip(x, eb)
    nz = x != 0
    if nz.any():
        rel = np.abs(rec[nz] - x[nz]) / np.abs(x[nz])
        assert rel.max() <= eb * (1 + 1e-9)
    assert (rec[~nz] == 0.0).all()


def test_f32_pipeline():
    rng = np.random.default_rng(4)
    x = rng.standard_normal(5000).astype(np.float32)
    codes, signs = quant_kernel.quantize(jnp.asarray(x), error_bound=1e-3)
    rec = np.asarray(
        quant_kernel.dequantize(codes, signs, error_bound=1e-3, dtype=jnp.float32)
    )
    nz = x != 0
    rel = np.abs(rec[nz] - x[nz]) / np.abs(x[nz])
    # f32 adds its own epsilon on top of the quantization bound
    assert rel.max() <= 1e-3 + 1e-5


def test_codes_are_stable():
    """Quantizing a reconstructed value must yield the same code (idempotent
    after one round-trip) — prevents drift across repeated stage cycles."""
    rng = np.random.default_rng(9)
    x = rng.standard_normal(2048)
    eb = 1e-3
    c1, s1 = quant_kernel.quantize(jnp.asarray(x), error_bound=eb)
    r1 = quant_kernel.dequantize(c1, s1, error_bound=eb)
    c2, s2 = quant_kernel.quantize(r1, error_bound=eb)
    r2 = quant_kernel.dequantize(c2, s2, error_bound=eb)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-12)
