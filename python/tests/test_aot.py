"""AOT lowering smoke tests: every module lowers to parseable HLO text."""

import json

import jax
import pytest

from compile import aot, model


def test_entries_cover_all_kernels():
    names = [name for name, *_ in aot.build_entries()]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for kind in ("gate1q", "gate2q", "diag1q", "diag2q", "quantize", "dequantize"):
        assert any(kind in n for n in names), f"missing {kind} artifacts"
    # both dtypes present
    assert any("_f32" in n for n in names)
    assert any("_f64" in n for n in names)


@pytest.mark.parametrize(
    "pick", ["gate1q_f64", "diag2q_f32", "quantize_f64_1e-3", "dequantize_f32_1e-3"]
)
def test_module_lowers_to_hlo_text(pick):
    for name, fn, arg_specs, meta in aot.build_entries():
        if name == pick:
            lowered = jax.jit(fn).lower(*arg_specs)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), text[:80]
            assert "ENTRY" in text
            return
    pytest.fail(f"{pick} not found in build_entries")


def test_artifact_generation_end_to_end(tmp_path):
    """Full aot run into a temp dir; manifest is consistent with files."""
    import sys
    from unittest import mock

    out = tmp_path / "artifacts"
    with mock.patch.object(sys, "argv", ["aot", "--out", str(out)]):
        aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["chunks"]["m_1q"] == model.M_CHUNK_1Q
    for name, meta in manifest["modules"].items():
        p = out / meta["file"]
        assert p.exists(), f"{name}: missing {meta['file']}"
        head = p.read_text()[:200]
        assert head.startswith("HloModule"), f"{name}: bad HLO header"
