//! §Perf microbenchmarks: gate-kernel and codec throughput on the hot path.
//! Self-timed (no criterion in the vendor set); prints GB/s and Mamps/s.
use bmqsim::circuit::{Gate, GateKind};
use bmqsim::compress::Codec;
use bmqsim::gates::apply_gate;
use bmqsim::types::SplitMix64;
use std::time::Instant;

fn time_it(reps: usize, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let n = 22; // 4M amplitudes, 64 MiB state
    let len = 1usize << n;
    let mut rng = SplitMix64::new(7);
    let mut re: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let mut im: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let bytes = (len * 16) as f64;

    println!("== gate kernels (n={n}, {} amps, state {:.0} MiB) ==", len, bytes / (1 << 20) as f64);
    for (label, gate) in [
        ("h (dense 1q)", Gate::q1(GateKind::H, 10).unwrap()),
        ("x (perm 1q)", Gate::q1(GateKind::X, 10).unwrap()),
        ("rz (diag 1q)", Gate::q1(GateKind::Rz(0.3), 10).unwrap()),
        ("t  (diag 1q)", Gate::q1(GateKind::T, 10).unwrap()),
        ("cx (perm 2q)", Gate::q2(GateKind::Cx, 12, 3).unwrap()),
        ("cp (diag 2q)", Gate::q2(GateKind::Cp(0.7), 12, 3).unwrap()),
        ("rxx (dense 2q)", Gate::q2(GateKind::Rxx(0.4), 12, 3).unwrap()),
    ] {
        let secs = time_it(5, || apply_gate(&mut re, &mut im, &gate));
        println!(
            "  {label:<15} {:>8.2} ms   {:>7.2} GB/s   {:>8.1} Mamp/s",
            secs * 1e3,
            bytes / secs / 1e9,
            len as f64 / secs / 1e6
        );
    }

    // memcpy roofline reference
    let mut dst = vec![0.0f64; len];
    let secs = time_it(5, || {
        dst.copy_from_slice(&re);
        std::hint::black_box(&mut dst);
    });
    println!("  {:<15} {:>8.2} ms   {:>7.2} GB/s   (read+write of one plane)", "memcpy ref", secs * 1e3, (len * 16) as f64 / secs / 1e9);

    println!("\n== codecs (plane = 2^20 doubles, 8 MiB) ==");
    let plen = 1 << 20;
    let dense: Vec<f64> = (0..plen).map(|_| rng.next_gaussian() * 1e-2).collect();
    let mut sparse = vec![0.0f64; plen];
    for i in 0..64 {
        sparse[i * (plen / 64)] = 0.1;
    }
    let pbytes = (plen * 8) as f64;
    for (label, data) in [("dense gaussian", &dense), ("sparse (64 nz)", &sparse)] {
        for codec in [Codec::pointwise(1e-3), Codec::absolute(1e-3), Codec::raw()] {
            let enc = codec.compress(data).unwrap();
            let csecs = time_it(3, || {
                let _ = codec.compress(data).unwrap();
            });
            let dsecs = time_it(3, || {
                let _ = codec.decompress(&enc).unwrap();
            });
            println!(
                "  {label:<15} {:<14} ratio {:>8.1}x   comp {:>7.2} GB/s   decomp {:>7.2} GB/s",
                codec.name(),
                pbytes / enc.len() as f64,
                pbytes / csecs / 1e9,
                pbytes / dsecs / 1e9
            );
        }
    }
}
