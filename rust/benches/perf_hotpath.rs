//! §Perf microbenchmarks: gate-kernel, codec, and group-chain throughput
//! on the hot path. Self-timed (no criterion in the vendor set); prints
//! GB/s / Mamps/s tables and writes a machine-readable
//! `BENCH_hotpath.json` next to the CWD, seeding the repo's perf
//! trajectory.
//!
//! The codec section measures both the allocating path (`decompress` into
//! a fresh Vec + copy into the destination — the pre-refactor engine hot
//! path) and the zero-copy path (`decompress_into_with` + scratch arena),
//! so the win of the `*_into` APIs is visible where it matters. The
//! group-chain section runs the full fetch → decompress → apply →
//! compress → store cycle the way the engine group chain does.

use bmqsim::bench_harness::bench_json::{num as jnum, obj as json_obj, write_bench_file};
use bmqsim::bench_harness::{bench_smoke, time_it};
use bmqsim::circuit::{Gate, GateKind};
use bmqsim::compress::{Codec, CodecScratch};
use bmqsim::gates::{apply_gate, apply_gate_remapped};
use bmqsim::memory::{BlockPayload, BlockStore};
use bmqsim::pipeline::Scratch;
use bmqsim::state::BlockLayout;
use bmqsim::types::SplitMix64;

fn main() {
    let mut json_kernels: Vec<(String, String)> = Vec::new();
    let mut json_codecs: Vec<(String, String)> = Vec::new();

    // BENCH_SMOKE=1 (CI): shrink planes/reps so the full bench still runs
    // end-to-end and emits BENCH_hotpath.json in seconds.
    let smoke = bench_smoke();
    let n = if smoke { 16 } else { 22 }; // full: 4M amplitudes, 64 MiB state
    let kernel_reps = if smoke { 2 } else { 5 };
    let codec_reps = if smoke { 1 } else { 3 };
    let len = 1usize << n;
    let mut rng = SplitMix64::new(7);
    let mut re: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let mut im: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let bytes = (len * 16) as f64;

    println!("== gate kernels (n={n}, {} amps, state {:.0} MiB) ==", len, bytes / (1 << 20) as f64);
    for (label, key, gate) in [
        ("h (dense 1q)", "h", Gate::q1(GateKind::H, 10).unwrap()),
        ("x (perm 1q)", "x", Gate::q1(GateKind::X, 10).unwrap()),
        ("rz (diag 1q)", "rz", Gate::q1(GateKind::Rz(0.3), 10).unwrap()),
        ("t  (diag 1q)", "t", Gate::q1(GateKind::T, 10).unwrap()),
        ("cx (perm 2q)", "cx", Gate::q2(GateKind::Cx, 12, 3).unwrap()),
        ("cp (diag 2q)", "cp", Gate::q2(GateKind::Cp(0.7), 12, 3).unwrap()),
        ("rxx (dense 2q)", "rxx", Gate::q2(GateKind::Rxx(0.4), 12, 3).unwrap()),
    ] {
        let secs = time_it(kernel_reps, || apply_gate(&mut re, &mut im, &gate));
        println!(
            "  {label:<15} {:>8.2} ms   {:>7.2} GB/s   {:>8.1} Mamp/s",
            secs * 1e3,
            bytes / secs / 1e9,
            len as f64 / secs / 1e6
        );
        json_kernels.push((
            key.to_string(),
            json_obj(&[
                ("gbps".into(), jnum(bytes / secs / 1e9)),
                ("mamps".into(), jnum(len as f64 / secs / 1e6)),
            ]),
        ));
    }

    // memcpy roofline reference
    let mut dst = vec![0.0f64; len];
    let secs = time_it(kernel_reps, || {
        dst.copy_from_slice(&re);
        std::hint::black_box(&mut dst);
    });
    println!(
        "  {:<15} {:>8.2} ms   {:>7.2} GB/s   (read+write of one plane)",
        "memcpy ref",
        secs * 1e3,
        (len * 16) as f64 / secs / 1e9
    );
    json_kernels.push((
        "memcpy_ref".into(),
        json_obj(&[("gbps".into(), jnum((len * 16) as f64 / secs / 1e9))]),
    ));

    let plen = if smoke { 1 << 16 } else { 1 << 20 };
    println!(
        "\n== codecs (plane = {plen} doubles, {:.1} MiB) ==",
        (plen * 8) as f64 / (1 << 20) as f64
    );
    let dense: Vec<f64> = (0..plen).map(|_| rng.next_gaussian() * 1e-2).collect();
    let mut sparse = vec![0.0f64; plen];
    for i in 0..64 {
        sparse[i * (plen / 64)] = 0.1;
    }
    let pbytes = (plen * 8) as f64;
    let mut scratch = CodecScratch::new();
    for (label, key, data) in
        [("dense gaussian", "dense_gaussian", &dense), ("sparse (64 nz)", "sparse_64nz", &sparse)]
    {
        let mut per_codec: Vec<(String, String)> = Vec::new();
        for codec in [Codec::pointwise(1e-3), Codec::absolute(1e-3), Codec::raw()] {
            let enc = codec.compress(data).unwrap();
            let mut target = vec![0.0f64; plen];
            let mut outbuf: Vec<u8> = Vec::new();
            // Pre-refactor paths: fresh allocations each call, plus the
            // plane copy decompress forced on the engine.
            let csecs = time_it(codec_reps, || {
                let _ = std::hint::black_box(codec.compress(data).unwrap());
            });
            let dsecs = time_it(codec_reps, || {
                let v = codec.decompress(&enc).unwrap();
                target.copy_from_slice(&v);
                std::hint::black_box(&mut target);
            });
            // Zero-copy paths: reused output + scratch arena.
            let cisecs = time_it(codec_reps, || {
                codec.compress_into_with(data, &mut outbuf, &mut scratch).unwrap();
                std::hint::black_box(&mut outbuf);
            });
            let disecs = time_it(codec_reps, || {
                codec.decompress_into_with(&enc, &mut target, &mut scratch).unwrap();
                std::hint::black_box(&mut target);
            });
            println!(
                "  {label:<15} {:<14} ratio {:>7.1}x   comp {:>6.2} GB/s (into {:>6.2})   decomp {:>6.2} GB/s (into {:>6.2}, {:.2}x)",
                codec.name(),
                pbytes / enc.len() as f64,
                pbytes / csecs / 1e9,
                pbytes / cisecs / 1e9,
                pbytes / dsecs / 1e9,
                pbytes / disecs / 1e9,
                dsecs / disecs
            );
            per_codec.push((
                codec.name().to_string(),
                json_obj(&[
                    ("ratio".into(), jnum(pbytes / enc.len() as f64)),
                    ("comp_gbps".into(), jnum(pbytes / csecs / 1e9)),
                    ("comp_into_gbps".into(), jnum(pbytes / cisecs / 1e9)),
                    ("decomp_gbps".into(), jnum(pbytes / dsecs / 1e9)),
                    ("decomp_into_gbps".into(), jnum(pbytes / disecs / 1e9)),
                    ("decomp_into_speedup".into(), jnum(dsecs / disecs)),
                ]),
            ));
        }
        json_codecs.push((key.to_string(), json_obj(&per_codec)));
    }

    // ---- Full group-chain benchmark: fetch → decompress → apply →
    // compress → store, the shape of the engine group chain. ----
    let (cn, cb) = if smoke { (16, 12) } else { (20, 16) };
    println!("\n== group chain (n={cn}, b={cb}: 16 blocks, groups of 4, glen=2^{}) ==", cb + 2);
    let layout = BlockLayout::new(cn, cb).unwrap();
    let schedule = layout.group_schedule(&[cb, cb + 2]).unwrap();
    let block_len = layout.block_len();
    let glen = schedule.group_len();
    let codec = Codec::pointwise(1e-3);
    // Targets must be block-local or INNER globals (cb, cb+2): an outer
    // global would panic in `buffer_bit`.
    let gates = [
        Gate::q1(GateKind::H, 3).unwrap(),
        Gate::q2(GateKind::Cx, cb + 2, 2).unwrap(),
        Gate::q1(GateKind::Rz(0.41), cb).unwrap(),
    ];
    let remapped: Vec<(Gate, Vec<usize>)> = gates
        .iter()
        .map(|g| {
            let bits: Vec<usize> = g.targets().iter().map(|&q| schedule.buffer_bit(q)).collect();
            (*g, bits)
        })
        .collect();

    let init_store = |rng: &mut SplitMix64| -> BlockStore {
        let store = BlockStore::unbounded();
        for id in 0..layout.num_blocks() {
            let r: Vec<f64> = (0..block_len).map(|_| rng.next_gaussian() * 1e-2).collect();
            let i: Vec<f64> = (0..block_len).map(|_| rng.next_gaussian() * 1e-2).collect();
            store
                .put(
                    id,
                    BlockPayload {
                        re: codec.compress(&r).unwrap(),
                        im: codec.compress(&i).unwrap(),
                    },
                )
                .unwrap();
        }
        store
    };

    let total_amps = (layout.num_blocks() * block_len) as f64;
    let reps = if smoke { 1usize } else { 3 };

    // Zero-copy chain: scratch arena + *_into APIs + recycled payloads.
    let store = init_store(&mut rng);
    let mut s = Scratch::new();
    let zc_secs = time_it(reps, || {
        for gidx in 0..schedule.num_groups() {
            s.ensure_planes(glen);
            schedule.group_blocks_into(gidx, &mut s.block_ids);
            s.payloads.clear();
            for &id in s.block_ids.iter() {
                s.payloads.push(store.take(id).unwrap());
            }
            for (slot, p) in s.payloads.iter().enumerate() {
                let dst = slot * block_len..(slot + 1) * block_len;
                codec.decompress_into_with(&p.re, &mut s.re[dst.clone()], &mut s.codec).unwrap();
                codec.decompress_into_with(&p.im, &mut s.im[dst], &mut s.codec).unwrap();
            }
            for (gate, bits) in &remapped {
                apply_gate_remapped(&mut s.re, &mut s.im, gate, bits);
            }
            for (slot, p) in s.payloads.iter_mut().enumerate() {
                let src = slot * block_len..(slot + 1) * block_len;
                codec.compress_into_with(&s.re[src.clone()], &mut p.re, &mut s.codec).unwrap();
                codec.compress_into_with(&s.im[src], &mut p.im, &mut s.codec).unwrap();
            }
            for (p, &id) in s.payloads.drain(..).zip(s.block_ids.iter()) {
                store.put(id, p).unwrap();
            }
        }
    });

    // Allocating chain: the pre-refactor shape (fresh planes per group,
    // temp Vec + copy on decompress, fresh Vec per compress).
    let store = init_store(&mut rng);
    let alloc_secs = time_it(reps, || {
        for gidx in 0..schedule.num_groups() {
            let block_ids = schedule.group_blocks(gidx);
            let payloads: Vec<BlockPayload> =
                block_ids.iter().map(|&id| store.take(id).unwrap()).collect();
            let mut re = vec![0.0f64; glen];
            let mut im = vec![0.0f64; glen];
            for (slot, p) in payloads.iter().enumerate() {
                let r = codec.decompress(&p.re).unwrap();
                let i = codec.decompress(&p.im).unwrap();
                re[slot * block_len..(slot + 1) * block_len].copy_from_slice(&r);
                im[slot * block_len..(slot + 1) * block_len].copy_from_slice(&i);
            }
            for (gate, bits) in &remapped {
                apply_gate_remapped(&mut re, &mut im, gate, bits);
            }
            for (slot, &id) in block_ids.iter().enumerate() {
                let r = codec.compress(&re[slot * block_len..(slot + 1) * block_len]).unwrap();
                let i = codec.compress(&im[slot * block_len..(slot + 1) * block_len]).unwrap();
                store.put(id, BlockPayload { re: r, im: i }).unwrap();
            }
        }
    });

    let zc_amps = total_amps / zc_secs;
    let alloc_amps = total_amps / alloc_secs;
    println!(
        "  zero-copy chain  {:>8.2} ms/pass   {:>8.2} Mamp/s",
        zc_secs * 1e3,
        zc_amps / 1e6
    );
    println!(
        "  allocating chain {:>8.2} ms/pass   {:>8.2} Mamp/s",
        alloc_secs * 1e3,
        alloc_amps / 1e6
    );
    println!("  chain speedup    {:>8.2}x", alloc_secs / zc_secs);
    let json_chain = json_obj(&[
        ("amps_per_s".into(), jnum(zc_amps)),
        ("alloc_amps_per_s".into(), jnum(alloc_amps)),
        ("speedup".into(), jnum(alloc_secs / zc_secs)),
        ("glen".into(), format!("{glen}")),
        ("groups".into(), format!("{}", schedule.num_groups())),
    ]);

    // ---- Machine-readable output (schema-stamped) ----
    println!();
    write_bench_file(
        "BENCH_hotpath.json",
        &[
            ("bench".into(), "\"perf_hotpath\"".into()),
            ("smoke".into(), format!("{smoke}")),
            ("gate_kernels".into(), json_obj(&json_kernels)),
            ("codecs".into(), json_obj(&json_codecs)),
            ("group_chain".into(), json_chain),
        ],
    );
}
