//! Fig. 12 — impact of pipeline stream count (1/2/4/8), with and without
//! the overlapped (decode/apply/encode) chain pipeline layered on top.
//!
//! `BENCH_SMOKE=1` shrinks the sweep so CI exercises it in seconds.
use bmqsim::bench_harness as bench;

fn main() {
    let smoke = bench::bench_smoke();
    let (algos, n): (Vec<&str>, usize) = if smoke {
        (vec!["qft", "qaoa"], 12)
    } else {
        (vec!["qft", "qaoa", "ising", "qsvm"], 18)
    };
    bench::print_experiment("Fig 12: stream count sweep", || {
        Ok(vec![
            bench::fig12_streams(&algos, n, false)?,
            bench::fig12_streams(&algos, n, true)?,
        ])
    });
    println!("paper shape: best around 2 streams; 8 streams loses to context overhead.\noverlapped rows conceal codec time inside each stream's chain.");
}
