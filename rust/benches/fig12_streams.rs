//! Fig. 12 — impact of pipeline stream count (1/2/4/8), with and without
//! the overlapped (decode/apply/encode) chain pipeline layered on top.
//! Emits machine-readable `BENCH_streams.json` (every wall time plus the
//! per-stream-count overlapped-vs-sequential speedup geomeans) for the
//! per-PR perf trajectory.
//!
//! `BENCH_SMOKE=1` shrinks the sweep so CI exercises it in seconds.
use bmqsim::bench_harness as bench;
use bmqsim::bench_harness::bench_json;

fn main() {
    let smoke = bench::bench_smoke();
    let (algos, n): (Vec<&str>, usize) = if smoke {
        (vec!["qft", "qaoa"], 12)
    } else {
        (vec!["qft", "qaoa", "ising", "qsvm"], 18)
    };
    let mut fields: Vec<(String, String)> = Vec::new();
    bench::print_experiment("Fig 12: stream count sweep", || {
        let (tables, f) = bench::fig12_streams_study(&algos, n)?;
        fields = f;
        Ok(tables)
    });
    bench_json::require_fields("BENCH_streams.json", &fields);
    fields.push(("smoke".to_string(), format!("{smoke}")));
    bench_json::write_bench_file("BENCH_streams.json", &fields);
    println!("paper shape: best around 2 streams; 8 streams loses to context overhead.\noverlapped rows conceal codec time inside each stream's chain.");
}
