//! Fig. 12 — impact of pipeline stream count (1/2/4/8).
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Fig 12: stream count sweep", || {
        Ok(vec![bench::fig12_streams(&["qft", "qaoa", "ising", "qsvm"], 18)?])
    });
    println!("paper shape: best around 2 streams; 8 streams loses to context overhead.");
}
