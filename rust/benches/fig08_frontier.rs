//! Fig. 8 frontier — adaptive error control: the compression-ratio /
//! fidelity frontier of the budget controller (global and amplitude
//! policies) vs the *equivalent fixed global bound* on the deep-random
//! workload, all at the same whole-run fidelity target. Writes the
//! machine-readable `BENCH_frontier.json` gated by `bench_check`
//! (`compression_ratio_at_target`, `fidelity_margin`).
use bmqsim::bench_harness as bench;
use bmqsim::bench_harness::bench_json;

fn main() {
    // BENCH_SMOKE=1 (CI): a smaller deep-random instance; the frontier
    // shape (amplitude >= target at a better ratio than fixed) holds at
    // both scales, only the ratios shrink.
    let (n, b) = if bench::bench_smoke() { (10, 5) } else { (13, 7) };
    let target = 0.999;
    let mut fields: Vec<(String, String)> = Vec::new();
    bench::print_experiment("Fig 8 frontier: adaptive error control at target 0.999", || {
        let (t, f) = bench::fig08_frontier(n, b, target)?;
        fields = f;
        Ok(vec![t])
    });
    bench_json::require_fields("BENCH_frontier.json", &fields);
    bench_json::write_bench_file("BENCH_frontier.json", &fields);
    println!(
        "paper shape: both budget policies land at fidelity >= {target}; the amplitude \
         policy does so at a better compression ratio than the equivalent fixed bound."
    );
}
