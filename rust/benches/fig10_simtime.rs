//! Fig. 10 — simulation time across circuits/sizes vs the dense baseline.
use bmqsim::bench_harness as bench;
use bmqsim::circuit::generators;

fn main() {
    bench::print_experiment("Fig 10: simulation time vs dense baseline", || {
        Ok(vec![bench::fig10_simtime(&generators::ALL, &[16, 18, 20])?])
    });
    println!("paper shape: BMQSIM within small factors of well-optimized dense simulators\n(paper: ~1x of Qiskit-Aer; cuQuantum/HyQuas 9-12x faster at much higher memory).");
}
