//! Table 2 — maximum supported qubits per simulator under a fixed memory
//! budget (scaled: 64 MiB here vs the paper's 128 GB Machine 1).
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Table 2: max qubits under 16 MiB budget", || {
        Ok(vec![bench::table2_max_qubits(16 << 20, 24)?])
    });
    println!("paper shape: BMQSIM reaches ~10 more qubits than dense simulators;\n+SSD adds a few more (paper: 42 / 47 vs ~26-33).");
}
