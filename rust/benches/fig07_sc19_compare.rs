//! Fig. 7 — simulation time: SC19-Sim (CPU/GPU analogue) vs BMQSIM.
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Fig 7: SC19-Sim vs BMQSIM simulation time", || {
        Ok(vec![bench::fig07_sc19_compare(
            &["qft", "qaoa", "ising", "ghz_state"],
            &[14, 16],
        )?])
    });
    println!("paper shape: BMQSIM orders of magnitude faster (paper: 1385x/539x avg).");
}
