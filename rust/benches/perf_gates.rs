//! Gate-throughput microbenchmark: fused-batched stage application
//! (`gates::fused::apply_stage`) vs the per-gate scalar reference, on a
//! deep 20+ qubit stage-shaped workload plus the QFT gate list.
//!
//! Reports amplitudes/sec (len x gates / wall — the per-gate path touches
//! exactly that many amplitudes, so the ratio is the wall-clock speedup),
//! plane-sweep counts, and the fidelity of the fused output against the
//! per-gate output (expected >= 1 - 1e-10: both are the same product in
//! f64, differing only in rounding association). Writes
//! `BENCH_gates.json` next to the CWD for the per-PR perf trajectory.
//!
//! `BENCH_SMOKE=1` shrinks the plane so CI finishes in seconds.

use bmqsim::bench_harness::{bench_json, bench_smoke, time_it};
use bmqsim::circuit::fusion::fuse_gates;
use bmqsim::circuit::{generators, Circuit};
use bmqsim::gates::fused::{stage_sweeps, DEFAULT_TILE_BITS};
use bmqsim::gates::{apply_gate, apply_stage};
use bmqsim::state::StateVector;
use bmqsim::types::SplitMix64;

/// Stage-shaped deep circuit on an `n`-qubit group plane: a dense body of
/// block-local gates (low qubits) plus per-layer inner-global traffic on
/// the top 4 bits — the workload the engine group chain actually sees.
fn deep_stage_circuit(n: usize, layers: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "deep_stage");
    let hi_start = n.saturating_sub(4).max(2);
    for _ in 0..layers {
        for q in 0..hi_start - 1 {
            let th = rng.next_f64() * 2.0 - 1.0;
            c.u3(th, 0.3, -0.1, q);
            if q % 2 == 0 {
                c.cx(q, q + 1);
            } else {
                c.cp(th, q, q + 1);
            }
        }
        for g in hi_start..n {
            c.h(g);
            c.cp(rng.next_f64(), g, g - hi_start);
        }
    }
    c
}

/// `StateVector::fidelity_normalized` over raw plane pairs — the same
/// metric the engine tests report, so trajectory numbers stay comparable.
fn fidelity(n: usize, a_re: &[f64], a_im: &[f64], b_re: &[f64], b_im: &[f64]) -> f64 {
    let a = StateVector::from_planes(n, a_re.to_vec(), a_im.to_vec()).unwrap();
    let b = StateVector::from_planes(n, b_re.to_vec(), b_im.to_vec()).unwrap();
    a.fidelity_normalized(&b)
}

struct CaseResult {
    json: String,
    headline_speedup: f64,
    fidelity: f64,
}

fn run_case(
    label: &str,
    c: &Circuit,
    tile_bits: usize,
    par_workers: usize,
    reps: usize,
) -> CaseResult {
    let n = c.n_qubits;
    let len = 1usize << n;
    let mut rng = SplitMix64::new(0x6A7E5);
    let re0: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let im0: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let gates = c.gates.len();
    let amps = (len as f64) * (gates as f64);

    // Per-gate scalar reference.
    let mut re = re0.clone();
    let mut im = im0.clone();
    let unfused_secs = time_it(reps, || {
        re.copy_from_slice(&re0);
        im.copy_from_slice(&im0);
        for g in &c.gates {
            apply_gate(&mut re, &mut im, g);
        }
    });
    let unfused_state = (re.clone(), im.clone());

    // Fused-batched, single worker.
    let ops = fuse_gates(&c.gates, 3);
    let sweeps = stage_sweeps(&ops, n, tile_bits);
    let fused_secs = time_it(reps, || {
        re.copy_from_slice(&re0);
        im.copy_from_slice(&im0);
        apply_stage(&mut re, &mut im, &ops, tile_bits, 1);
    });
    let fid = fidelity(n, &re, &im, &unfused_state.0, &unfused_state.1);

    // Fused-batched, worker-parallel sweeps.
    let fused_par_secs = time_it(reps, || {
        re.copy_from_slice(&re0);
        im.copy_from_slice(&im0);
        apply_stage(&mut re, &mut im, &ops, tile_bits, par_workers);
    });
    let fid_par = fidelity(n, &re, &im, &unfused_state.0, &unfused_state.1);

    let speedup = unfused_secs / fused_secs;
    let speedup_par = unfused_secs / fused_par_secs;
    println!(
        "== {label}: n={n}, {gates} gates -> {} fused ops, {sweeps} sweeps ==",
        ops.len()
    );
    println!(
        "  per-gate scalar   {:>9.2} ms   {:>9.1} Mamp/s",
        unfused_secs * 1e3,
        amps / unfused_secs / 1e6
    );
    println!(
        "  fused batched x1  {:>9.2} ms   {:>9.1} Mamp/s   {speedup:>6.2}x   fidelity {fid:.12}",
        fused_secs * 1e3,
        amps / fused_secs / 1e6
    );
    println!(
        "  fused batched x{par_workers}  {:>9.2} ms   {:>9.1} Mamp/s   {speedup_par:>6.2}x   fidelity {fid_par:.12}",
        fused_par_secs * 1e3,
        amps / fused_par_secs / 1e6
    );

    let json = bench_json::obj(&[
        ("n".into(), format!("{n}")),
        ("gates".into(), format!("{gates}")),
        ("fused_ops".into(), format!("{}", ops.len())),
        ("sweeps".into(), format!("{sweeps}")),
        ("tile_bits".into(), format!("{tile_bits}")),
        ("par_workers".into(), format!("{par_workers}")),
        ("unfused_amps_per_s".into(), bench_json::num(amps / unfused_secs)),
        ("fused_amps_per_s".into(), bench_json::num(amps / fused_secs)),
        ("fused_par_amps_per_s".into(), bench_json::num(amps / fused_par_secs)),
        ("speedup_fused".into(), bench_json::num(speedup)),
        ("speedup_fused_parallel".into(), bench_json::num(speedup_par)),
        ("fidelity_fused_vs_unfused".into(), format!("{:.14}", fid.min(fid_par))),
    ]);
    // Headline = SINGLE-worker fused vs per-gate scalar: parallelism must
    // not mask a regression in the fusion/tiling win itself.
    CaseResult { json, headline_speedup: speedup, fidelity: fid.min(fid_par) }
}

fn main() {
    let smoke = bench_smoke();
    // Acceptance target: 20+ qubit deep circuit in full mode.
    let (n, layers, reps) = if smoke { (14, 2, 1) } else { (20, 6, 2) };
    let par_workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8);

    let deep = deep_stage_circuit(n, layers, 0xD4E9);
    let deep_res = run_case("deep_stage", &deep, DEFAULT_TILE_BITS, par_workers, reps);

    let qft = generators::qft(n);
    let qft_res = run_case("qft", &qft, DEFAULT_TILE_BITS, par_workers, reps);

    println!();
    bench_json::write_bench_file(
        "BENCH_gates.json",
        &[
            ("bench".into(), "\"perf_gates\"".into()),
            ("smoke".into(), format!("{smoke}")),
            ("deep_stage".into(), deep_res.json.clone()),
            ("qft".into(), qft_res.json.clone()),
            (
                "speedup".into(),
                bench_json::num(deep_res.headline_speedup),
            ),
            ("fidelity".into(), format!("{:.14}", deep_res.fidelity.min(qft_res.fidelity))),
        ],
    );
    if deep_res.headline_speedup < 2.0 {
        eprintln!(
            "WARNING: fused-batched speedup {:.2}x below the 2x target",
            deep_res.headline_speedup
        );
    }
}
