//! Fig. 14 — circuit partition time as % of end-to-end simulation.
use bmqsim::bench_harness as bench;
use bmqsim::circuit::generators;

fn main() {
    bench::print_experiment("Fig 14: partition overhead", || {
        Ok(vec![bench::fig14_partition_overhead(&generators::ALL, 18)?])
    });
    println!("paper shape: negligible (well under 1%).");
}
