//! Fig. 9 — memory consumption vs standard 2^(n+4) bytes, plus §5.4 spill
//! fractions under a restricted budget.
use bmqsim::bench_harness as bench;
use bmqsim::circuit::generators;

fn main() {
    bench::print_experiment("Fig 9: memory consumption + §5.4 spill", || {
        let (a, b) = bench::fig09_memory(&generators::ALL, &[16, 18, 20], 1 << 20)?;
        Ok(vec![a, b])
    });
    println!("paper shape: cat/bv/ghz reduce 400-700x; cc ~15x; qft ~10x.");
}
