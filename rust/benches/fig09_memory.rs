//! Fig. 9 — memory consumption vs standard 2^(n+4) bytes, §5.4 spill
//! fractions under a restricted budget, and the two-level-store
//! concurrency study (single-lock synchronous spill vs sharded + async
//! writer + prefetch), which also emits machine-readable
//! `BENCH_memory.json` for the per-PR perf trajectory.
//!
//! `BENCH_SMOKE=1` shrinks problem sizes so CI exercises the full path
//! (same JSON shape) in seconds.
use bmqsim::bench_harness as bench;
use bmqsim::bench_harness::bench_json;
use bmqsim::circuit::generators;

fn main() {
    let smoke = bench::bench_smoke();
    let (algos, ns, budget): (Vec<&str>, Vec<usize>, usize) = if smoke {
        (vec!["qft", "qaoa", "ghz_state"], vec![12], 1 << 16)
    } else {
        (generators::ALL.to_vec(), vec![16, 18, 20], 1 << 20)
    };
    bench::print_experiment("Fig 9: memory consumption + §5.4 spill", || {
        let (a, b) = bench::fig09_memory(&algos, &ns, budget)?;
        Ok(vec![a, b])
    });

    // The concurrency study: >=30% spill fraction, workers > 1, sharded +
    // async + prefetch vs the 1-shard synchronous baseline.
    let (n, b, streams) = if smoke { (12, 8, 4) } else { (16, 12, 4) };
    let mut fields: Vec<(String, String)> = Vec::new();
    bench::print_experiment("Fig 9 addendum: sync vs sharded+async spill", || {
        let (t, f) = bench::fig09_async_spill("qaoa", n, b, streams)?;
        fields = f;
        Ok(vec![t])
    });
    bench_json::require_fields("BENCH_memory.json", &fields);
    bench_json::write_bench_file("BENCH_memory.json", &fields);
    println!("paper shape: cat/bv/ghz reduce 400-700x; cc ~15x; qft ~10x.");
}
