//! Fig. 8 — fidelity of SC19-Sim vs BMQSIM against the dense ideal state.
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Fig 8: fidelity (|<ideal|sim>|)", || {
        Ok(vec![bench::fig08_fidelity(
            &["qft", "qaoa", "ising", "ghz_state", "qsvm"],
            &[14, 16],
        )?])
    });
    println!("paper shape: BMQSIM > 0.99 everywhere and >= SC19, especially on deep circuits (qft).");
}
