//! Fig. 15 — inner size x SV block size: compression ratio + sim time.
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Fig 15: parameter tuning (qaoa)", || {
        let (ratio, time) = bench::fig15_params("qaoa", 18, &[2, 3, 4, 5], &[8, 10, 12, 14])?;
        Ok(vec![ratio, time])
    });
    println!("paper shape: ratio roughly flat across settings; time improves with\nlarger inner/block sizes (fewer stages, fewer kernel launches).");
}
