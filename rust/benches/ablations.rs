//! Ablations A1-A3: bitmap pre-scan, error-control mode, and the staging
//! mechanism itself (per-gate vs per-stage, via fig07's SC19 comparison).
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Ablation A1: bitmap pre-scan on/off", || {
        Ok(vec![bench::ablation_prescan(1 << 16)?])
    });
    bench::print_experiment("Ablation A2: pointwise-relative vs absolute bound", || {
        Ok(vec![bench::ablation_error_mode("ising", 16)?])
    });
    bench::print_experiment("Ablation A3: staging (1 stage-decompress) vs per-gate", || {
        Ok(vec![bench::fig07_sc19_compare(&["qft"], &[14])?])
    });
}
