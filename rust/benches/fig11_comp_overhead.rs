//! Fig. 11 — compression overhead: BMQSIM vs BMQSIM without compression.
use bmqsim::bench_harness as bench;
use bmqsim::circuit::generators;

fn main() {
    bench::print_experiment("Fig 11: compression overhead", || {
        Ok(vec![bench::fig11_comp_overhead(&generators::ALL, &[16, 18])?])
    });
    println!("paper shape: overhead minimal; on high-ratio circuits (cat/bv/ghz)\ncompression WINS (smaller transfers) — paper reports 9% average speedup.");
}
