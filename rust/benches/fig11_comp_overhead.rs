//! Fig. 11 — compression overhead: BMQSIM vs BMQSIM without compression,
//! plus the overhead-concealment study (sequential vs software-pipelined
//! decode/apply/encode group chains under a squeezed budget), which emits
//! machine-readable `BENCH_overlap.json` for the per-PR perf trajectory.
//!
//! `BENCH_SMOKE=1` shrinks problem sizes so CI exercises the full path
//! (same JSON shape) in seconds.
use bmqsim::bench_harness as bench;
use bmqsim::bench_harness::bench_json;
use bmqsim::circuit::generators;

fn main() {
    let smoke = bench::bench_smoke();
    let (algos, ns): (Vec<&str>, Vec<usize>) = if smoke {
        (vec!["qft", "qaoa", "ghz_state"], vec![12])
    } else {
        (generators::ALL.to_vec(), vec![16, 18])
    };
    bench::print_experiment("Fig 11: compression overhead", || {
        Ok(vec![bench::fig11_comp_overhead(&algos, &ns)?])
    });

    // Overhead concealment: sequential vs pipelined chains at budget =
    // peak/4 with >= 4 concurrent workers (ISSUE 4 acceptance geometry),
    // now driven through the persistent phase pool.
    let (n, b, workers, depth) = if smoke { (12, 8, 4, 2) } else { (16, 12, 4, 2) };
    let mut fields: Vec<(String, String)> = Vec::new();
    bench::print_experiment("Fig 11 addendum: sequential vs pipelined chains", || {
        let (t, f) = bench::overlap_study("qaoa", n, b, workers, depth)?;
        fields = f;
        Ok(vec![t])
    });
    bench_json::require_fields("BENCH_overlap.json", &fields);

    // Auto-enable crossover: where pipelining breaks even over the group
    // sizes the block-size knob produces, and which side OverlapMode::Auto
    // picked at each geometry.
    let (auto_n, auto_blocks): (usize, Vec<usize>) =
        if smoke { (12, vec![4, 6, 8]) } else { (16, vec![6, 9, 12, 14]) };
    let mut auto_fields: Vec<(String, String)> = Vec::new();
    bench::print_experiment("Fig 11 addendum: overlap auto-enable crossover", || {
        let (t, f) = bench::fig11_auto_enable("qaoa", auto_n, &auto_blocks)?;
        auto_fields = f;
        Ok(vec![t])
    });
    bench_json::require_fields("BENCH_overlap.json", &auto_fields);
    fields.push(("auto_enable".to_string(), bench_json::obj(&auto_fields)));

    bench_json::write_bench_file("BENCH_overlap.json", &fields);
    println!("paper shape: overhead minimal; on high-ratio circuits (cat/bv/ghz)\ncompression WINS (smaller transfers) — paper reports 9% average speedup.\npipelined chains must be byte-identical while concealing codec time.");
}
