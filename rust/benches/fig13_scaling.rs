//! Fig. 13 — multi-device scaling (1/2/4 logical devices).
use bmqsim::bench_harness as bench;

fn main() {
    bench::print_experiment("Fig 13: device scaling", || {
        Ok(vec![bench::fig13_scaling(&["qft", "qaoa", "ising", "ghz_state"], 18)?])
    });
    println!("paper shape: sub-linear (1.7x @2, 2.3x @4 for qft) — transfer-link bound.");
}
