//! Property tests for the zero-copy codec APIs: `compress_into` /
//! `decompress_into` (and their `_with`-scratch forms) must be
//! byte-for-byte and bit-for-bit identical to the allocating paths across
//! all three wire modes, including error behaviour on truncated payloads
//! and undersized or dirty destination buffers.

use bmqsim::compress::{
    decoded_len, decompress_any, decompress_any_into, decompress_any_into_with, Codec,
    CodecScratch,
};
use bmqsim::types::SplitMix64;

fn all_codecs() -> [Codec; 4] {
    [Codec::pointwise(1e-3), Codec::pointwise(1e-5), Codec::absolute(1e-4), Codec::raw()]
}

/// Adversarial plane shapes: dense, sparse, constant, zero, tiny, huge,
/// non-finite, negative zero, empty.
fn planes() -> Vec<Vec<f64>> {
    let mut rng = SplitMix64::new(0xBEEF);
    let n = 3000;
    vec![
        (0..n).map(|_| rng.next_gaussian() * 1e-2).collect(),
        (0..n).map(|i| if i % 97 == 0 { rng.next_gaussian() } else { 0.0 }).collect(),
        vec![std::f64::consts::FRAC_1_SQRT_2; n],
        vec![0.0; n],
        vec![-0.0; 130],
        (0..n).map(|i| 10f64.powi((i % 120) as i32 - 60) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
        {
            let mut v: Vec<f64> = (0..200).map(|_| rng.next_gaussian()).collect();
            v[7] = f64::INFINITY;
            v[100] = f64::NEG_INFINITY;
            v[150] = f64::NAN;
            v
        },
        vec![f64::MIN_POSITIVE / 4.0, 1e300, -1e-300, 0.0, -5.0],
        Vec::new(),
    ]
}

#[test]
fn compress_into_is_byte_identical_to_compress() {
    let mut scratch = CodecScratch::new();
    let mut out = Vec::new();
    for codec in all_codecs() {
        for (pi, plane) in planes().iter().enumerate() {
            let reference = codec.compress(plane).unwrap();
            // Dirty, reused output buffer: must be fully replaced.
            out.clear();
            out.extend_from_slice(&[0xAB; 37]);
            codec.compress_into(plane, &mut out).unwrap();
            assert_eq!(out, reference, "{} plane {pi} (compress_into)", codec.name());
            out.clear();
            out.extend_from_slice(&[0xCD; 11]);
            codec.compress_into_with(plane, &mut out, &mut scratch).unwrap();
            assert_eq!(out, reference, "{} plane {pi} (compress_into_with)", codec.name());
        }
    }
}

#[test]
fn decompress_into_is_bit_identical_to_decompress() {
    let mut scratch = CodecScratch::new();
    for codec in all_codecs() {
        for (pi, plane) in planes().iter().enumerate() {
            let enc = codec.compress(plane).unwrap();
            let reference = codec.decompress(&enc).unwrap();
            assert_eq!(decoded_len(&enc).unwrap(), plane.len());

            // Dirty destination: NaN canaries everywhere.
            let mut dst = vec![f64::NAN; plane.len()];
            codec.decompress_into(&enc, &mut dst).unwrap();
            for (i, (&a, &b)) in reference.iter().zip(&dst).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} plane {pi} idx {i}", codec.name());
            }

            let mut dst2 = vec![7.77f64; plane.len()];
            codec.decompress_into_with(&enc, &mut dst2, &mut scratch).unwrap();
            for (i, (&a, &b)) in reference.iter().zip(&dst2).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} plane {pi} idx {i} (with)", codec.name());
            }
        }
    }
}

#[test]
fn undersized_and_oversized_buffers_are_rejected() {
    let data: Vec<f64> = (0..500).map(|i| (i as f64 * 0.37).sin()).collect();
    for codec in all_codecs() {
        let enc = codec.compress(&data).unwrap();
        let mut small = vec![0.0f64; data.len() - 1];
        assert!(
            codec.decompress_into(&enc, &mut small).is_err(),
            "{}: undersized buffer accepted",
            codec.name()
        );
        let mut big = vec![0.0f64; data.len() + 1];
        assert!(
            codec.decompress_into(&enc, &mut big).is_err(),
            "{}: oversized buffer accepted",
            codec.name()
        );
        // The data itself is untouched semantically: a correct-size pass
        // still succeeds afterwards with the same scratch-free entry point.
        let mut exact = vec![0.0f64; data.len()];
        codec.decompress_into(&enc, &mut exact).unwrap();
    }
}

#[test]
fn truncation_errors_match_between_paths() {
    let mut rng = SplitMix64::new(42);
    let data: Vec<f64> = (0..2000)
        .map(|i| if i % 13 == 0 { 0.0 } else { rng.next_gaussian() })
        .collect();
    let mut scratch = CodecScratch::new();
    for codec in all_codecs() {
        let enc = codec.compress(&data).unwrap();
        for cut in [1usize, 2, 5, 9, 33, enc.len() / 2, enc.len() - 1] {
            if cut == 0 || cut >= enc.len() {
                continue;
            }
            let trunc = &enc[..enc.len() - cut];
            let alloc = decompress_any(trunc);
            let mut dst = vec![0.0f64; data.len()];
            let into = decompress_any_into_with(trunc, &mut dst, &mut scratch);
            assert_eq!(
                alloc.is_err(),
                into.is_err(),
                "{} cut {cut}: alloc {:?} vs into {:?}",
                codec.name(),
                alloc.as_ref().map(|v| v.len()),
                into.as_ref().map(|_| ())
            );
            // When both succeed (cut landed in dead padding), values agree.
            if let (Ok(a), Ok(())) = (&alloc, &into) {
                assert_eq!(a.len(), dst.len());
                for (x, y) in a.iter().zip(&dst) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
    }
}

#[test]
fn steady_state_scratch_reuse_many_planes() {
    // The same scratch + output buffers across many differently-shaped
    // planes: results must match the one-shot paths every time (no state
    // leaks between calls).
    let mut rng = SplitMix64::new(7);
    let mut scratch = CodecScratch::new();
    let mut out = Vec::new();
    let codec = Codec::pointwise(1e-3);
    for round in 0..40 {
        let n = 128 + (rng.next_u64() % 4096) as usize;
        let zero_frac = (round % 5) as f64 / 5.0;
        let data: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < zero_frac { 0.0 } else { rng.next_gaussian() })
            .collect();
        codec.compress_into_with(&data, &mut out, &mut scratch).unwrap();
        assert_eq!(out, codec.compress(&data).unwrap(), "round {round}: bytes diverged");
        let mut dst = vec![f64::NAN; n];
        decompress_any_into_with(&out, &mut dst, &mut scratch).unwrap();
        let reference = decompress_any(&out).unwrap();
        for (i, (&a, &b)) in reference.iter().zip(&dst).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "round {round} idx {i}");
        }
    }
}

#[test]
fn decompress_any_into_matches_wrapper() {
    let data: Vec<f64> = (0..1024).map(|i| ((i * i) as f64).cos()).collect();
    for codec in all_codecs() {
        let enc = codec.compress(&data).unwrap();
        let mut a = vec![0.0f64; data.len()];
        decompress_any_into(&enc, &mut a).unwrap();
        let b = decompress_any(&enc).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}", codec.name());
        }
    }
}
