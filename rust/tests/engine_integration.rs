//! Cross-engine integration + property tests.
//!
//! The vendor set ships no proptest, so properties run on a hand-rolled
//! harness: seeded random cases via SplitMix64, many iterations, failing
//! seeds printed for reproduction.

use bmqsim::circuit::{generators, Circuit, Gate, GateKind};
use bmqsim::compress::{decompress_any, Codec};
use bmqsim::pipeline::PipelineConfig;
use bmqsim::sim::{BmqSim, DenseSim, Sc19Sim, SimConfig};
use bmqsim::state::BlockLayout;
use bmqsim::types::SplitMix64;

/// Random circuit over the full gate vocabulary.
fn random_circuit(n: usize, gates: usize, rng: &mut SplitMix64) -> Circuit {
    let mut c = Circuit::new(n, "random");
    for _ in 0..gates {
        let q = rng.next_below(n as u64) as usize;
        let theta = rng.next_f64() * 6.0 - 3.0;
        let gate = match rng.next_below(14) {
            0 => Gate::q1(GateKind::H, q),
            1 => Gate::q1(GateKind::X, q),
            2 => Gate::q1(GateKind::T, q),
            3 => Gate::q1(GateKind::Rx(theta), q),
            4 => Gate::q1(GateKind::Ry(theta), q),
            5 => Gate::q1(GateKind::Rz(theta), q),
            6 => Gate::q1(GateKind::U3(theta, theta * 0.3, -theta), q),
            7 => Gate::q1(GateKind::Sx, q),
            _ => {
                let mut p = rng.next_below(n as u64) as usize;
                if p == q {
                    p = (p + 1) % n;
                }
                match rng.next_below(6) {
                    0 => Gate::q2(GateKind::Cx, q, p),
                    1 => Gate::q2(GateKind::Cz, q, p),
                    2 => Gate::q2(GateKind::Swap, q, p),
                    3 => Gate::q2(GateKind::Cp(theta), q, p),
                    4 => Gate::q2(GateKind::Rzz(theta), q, p),
                    _ => Gate::q2(GateKind::Rxx(theta), q, p),
                }
            }
        };
        c.push(gate.unwrap()).unwrap();
    }
    c
}

/// PROPERTY: with a lossless (raw) codec, BMQSIM is bit-for-bit faithful to
/// the dense engine for arbitrary circuits and block geometries.
#[test]
fn property_staged_engine_equals_dense_on_random_circuits() {
    let mut seed_rng = SplitMix64::new(0xFEED);
    for case in 0..25 {
        let seed = seed_rng.next_u64();
        let mut rng = SplitMix64::new(seed);
        let n = 4 + (rng.next_below(6) as usize); // 4..9 qubits
        let gates = 5 + (rng.next_below(60) as usize);
        let b = 2 + (rng.next_below(n as u64 - 1) as usize); // 2..n
        let inner = 2 + (rng.next_below(3) as usize);
        let c = random_circuit(n, gates, &mut rng);

        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let mut config = SimConfig { block_qubits: b, inner_size: inner, ..SimConfig::default() };
        config.codec = Codec::raw();
        config.pipeline = PipelineConfig::new(1 + (case % 2), 1 + (case % 3));
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let got = r.state.as_ref().unwrap();
        for i in 0..ideal.len() {
            assert!(
                (ideal.re[i] - got.re[i]).abs() < 1e-12
                    && (ideal.im[i] - got.im[i]).abs() < 1e-12,
                "case {case} seed {seed:#x} n={n} b={b} inner={inner}: amp {i} differs"
            );
        }
    }
}

/// PROPERTY: with the paper's lossy codec, fidelity stays above 0.99
/// (the paper's headline) on random circuits.
#[test]
fn property_lossy_fidelity_above_paper_threshold() {
    let mut seed_rng = SplitMix64::new(0xBEEF);
    for case in 0..10 {
        let seed = seed_rng.next_u64();
        let mut rng = SplitMix64::new(seed);
        let n = 6 + (rng.next_below(4) as usize);
        let gates = 20 + (rng.next_below(80) as usize);
        let c = random_circuit(n, gates, &mut rng);
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let config = SimConfig { block_qubits: n - 3, ..SimConfig::default() };
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity_normalized(&ideal);
        assert!(f > 0.99, "case {case} seed {seed:#x}: fidelity {f}");
    }
}

/// PROPERTY: sc19 and bmqsim agree with each other under a raw codec (the
/// staging rewrite preserves semantics exactly).
#[test]
fn property_sc19_equals_bmqsim_raw() {
    let mut seed_rng = SplitMix64::new(0xABCD);
    for case in 0..8 {
        let seed = seed_rng.next_u64();
        let mut rng = SplitMix64::new(seed);
        let n = 5 + (rng.next_below(3) as usize);
        let c = random_circuit(n, 30, &mut rng);
        let mut config = SimConfig { block_qubits: 3, ..SimConfig::default() };
        config.codec = Codec::raw();
        let a = Sc19Sim::new(config.clone(), 2).run(&c, true).unwrap();
        let b = BmqSim::new(config).run(&c, true).unwrap();
        let (sa, sb) = (a.state.as_ref().unwrap(), b.state.as_ref().unwrap());
        for i in 0..sa.len() {
            assert!(
                (sa.re[i] - sb.re[i]).abs() < 1e-12 && (sa.im[i] - sb.im[i]).abs() < 1e-12,
                "case {case} seed {seed:#x}: amp {i}"
            );
        }
    }
}

/// PROPERTY: the two-level memory manager never exceeds its primary budget
/// and never changes results, across random tight budgets.
#[test]
fn property_spill_respects_budget_and_preserves_results() {
    let dir = std::env::temp_dir().join("bmqsim-int-spill");
    let mut seed_rng = SplitMix64::new(0x5111);
    for case in 0..6 {
        let seed = seed_rng.next_u64();
        let mut rng = SplitMix64::new(seed);
        let n = 8;
        let c = random_circuit(n, 40, &mut rng);
        let base = {
            let config = SimConfig { block_qubits: 4, ..SimConfig::default() };
            BmqSim::new(config).run(&c, true).unwrap().state.unwrap()
        };
        let budget = 512 + (rng.next_below(4096) as usize);
        let mut config = SimConfig { block_qubits: 4, ..SimConfig::default() };
        config.memory_budget = Some(budget);
        config.spill_dir = Some(dir.clone());
        let r = BmqSim::new(config).run(&c, true).unwrap();
        assert!(
            r.mem.peak_primary_bytes <= budget,
            "case {case} seed {seed:#x}: primary {} > budget {budget}",
            r.mem.peak_primary_bytes
        );
        let f = r.state.as_ref().unwrap().fidelity_normalized(&base);
        assert!(f > 1.0 - 1e-12, "case {case} seed {seed:#x}: spill changed state ({f})");
    }
}

/// PROPERTY: codec round-trips respect the pointwise bound on adversarial
/// plane shapes (constant, ramp, alternating, random, denormal).
#[test]
fn property_codec_bound_on_adversarial_planes() {
    let mut rng = SplitMix64::new(0xC0DE);
    let n = 4096;
    let planes: Vec<Vec<f64>> = vec![
        vec![0.0; n],
        vec![1.0; n],
        (0..n).map(|i| i as f64 * 1e-6).collect(),
        (0..n).map(|i| if i % 2 == 0 { 1e-10 } else { -1e10 }).collect(),
        (0..n).map(|_| rng.next_gaussian()).collect(),
        (0..n).map(|_| f64::MIN_POSITIVE * (1.0 + rng.next_f64())).collect(),
        (0..n)
            .map(|i| if i % 37 == 0 { 0.0 } else { rng.next_gaussian() * 1e-150 })
            .collect(),
    ];
    for (pi, plane) in planes.iter().enumerate() {
        for eb in [1e-2, 1e-3, 1e-5] {
            let codec = Codec::pointwise(eb);
            let enc = codec.compress(plane).unwrap();
            let dec = decompress_any(&enc).unwrap();
            for (i, (&x, &y)) in plane.iter().zip(&dec).enumerate() {
                if x == 0.0 {
                    assert_eq!(y, 0.0, "plane {pi} eb {eb} idx {i}");
                } else {
                    let rel = (y - x).abs() / x.abs();
                    assert!(rel <= eb * (1.0 + 1e-9), "plane {pi} eb {eb} idx {i}: {rel}");
                }
            }
        }
    }
}

/// PROPERTY: group schedules tile the block set exactly once for random
/// geometries (the routing invariant of the coordinator).
#[test]
fn property_group_schedules_tile_exactly() {
    let mut rng = SplitMix64::new(0x9999);
    for case in 0..200 {
        let n = 4 + (rng.next_below(12) as usize);
        let b = 1 + (rng.next_below(n as u64) as usize);
        let layout = BlockLayout::new(n, b).unwrap();
        let c = n - b;
        // random inner subset of global bits
        let mut inner: Vec<usize> =
            (0..c).filter(|_| rng.next_f64() < 0.4).map(|g| b + g).collect();
        inner.truncate(10);
        let gs = layout.group_schedule(&inner).unwrap();
        let mut seen = vec![false; layout.num_blocks()];
        for g in 0..gs.num_groups() {
            for id in gs.group_blocks(g) {
                assert!(!seen[id], "case {case}: block {id} twice (n={n} b={b} inner={inner:?})");
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: blocks missed");
    }
}

/// All 8 paper benchmarks, end to end, against the dense ideal.
#[test]
fn all_paper_benchmarks_meet_fidelity_headline() {
    for name in generators::ALL {
        let c = generators::build(name, 12, 42).unwrap();
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let config = SimConfig { block_qubits: 8, ..SimConfig::default() };
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(&ideal);
        assert!(f > 0.99, "{name}: fidelity {f} (paper headline >0.99)");
    }
}

/// Deterministic results across repeated runs (same config, same seed).
#[test]
fn runs_are_deterministic() {
    let c = generators::build("qaoa", 10, 7).unwrap();
    let config = SimConfig { block_qubits: 6, ..SimConfig::default() };
    let a = BmqSim::new(config.clone()).run(&c, true).unwrap().state.unwrap();
    let b = BmqSim::new(config).run(&c, true).unwrap().state.unwrap();
    assert_eq!(a.re, b.re);
    assert_eq!(a.im, b.im);
}
