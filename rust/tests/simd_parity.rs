//! Differential parity suite for the vectorized kernels (`src/simd`).
//!
//! Every vector kernel is compared pointwise against the always-compiled
//! scalar oracle (`simd::scalar_ops()`) across lane widths, unaligned
//! slice offsets, tail lengths, and special values — the contract is
//! *byte identity*, not approximate agreement, so every comparison here
//! is on `f64::to_bits` / exact integers.
//!
//! Note on the global kill switch: `simd::dispatch()` honors the
//! process-wide `disable_scope` guard, and tests in this binary run
//! concurrently. If a `no_simd` engine run overlaps a kernel test, that
//! test transiently compares scalar against scalar — still valid, never
//! flaky. Counter assertions are gated on the fetched table actually
//! being a vector tier, and no test asserts the process-wide counter is
//! zero (other threads may bump it at any time).

use bmqsim::circuit::generators;
use bmqsim::compress::{Codec, CodecScratch};
use bmqsim::gates::fused::subspace_bases;
use bmqsim::sim::{BmqSim, DenseSim, SimConfig};
use bmqsim::simd;
use bmqsim::types::SplitMix64;

/// Lengths spanning sub-lane, exact-lane, and ragged-tail cases for
/// every lane width in play (2, 4, and the 64-wide bitmap word).
const LENS: &[usize] =
    &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129, 1023];

fn special(sel: u64) -> f64 {
    match sel % 8 {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => 0.0,
        4 => -0.0,
        5 => 4.9e-324, // smallest subnormal
        6 => 1e300,
        _ => -1e300,
    }
}

/// Random plane; with `specials`, ~1 in 7 slots is a special value.
fn plane(len: usize, seed: u64, specials: bool) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    (0..len)
        .map(|_| {
            if specials && rng.next_below(7) == 0 {
                special(rng.next_u64())
            } else {
                rng.next_gaussian()
            }
        })
        .collect()
}

fn mat8(rng: &mut SplitMix64) -> [[f64; 8]; 8] {
    std::array::from_fn(|_| std::array::from_fn(|_| rng.next_gaussian()))
}

fn assert_f64_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: index {i}: {x:?} vs {y:?}");
    }
}

#[test]
fn quant_dequant_parity() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    let twoeb = 2.0e-3;
    let big = plane(1200, 0xA1, true);
    for &len in LENS {
        // Slice offsets 0..4 de-align the data from whatever the
        // allocator gave us, so vector loads hit every alignment class.
        for off in 0..4 {
            let data = &big[off..off + len];
            let (mut cv, mut ov) = (Vec::new(), Vec::new());
            let (mut cs, mut os) = (Vec::new(), Vec::new());
            v.quant_abs(data, twoeb, &mut cv, &mut ov);
            s.quant_abs(data, twoeb, &mut cs, &mut os);
            assert_eq!(cv, cs, "codes: len={len} off={off}");
            assert_eq!(ov.len(), os.len(), "outlier count: len={len} off={off}");
            for ((ia, xa), (ib, xb)) in ov.iter().zip(os.iter()) {
                assert_eq!(ia, ib, "outlier index: len={len} off={off}");
                assert_eq!(xa.to_bits(), xb.to_bits(), "outlier value: len={len} off={off}");
            }
            let mut dv = vec![0.0; len];
            let mut ds = vec![0.0; len];
            v.dequant_abs(&cv, twoeb, &mut dv);
            s.dequant_abs(&cs, twoeb, &mut ds);
            assert_f64_bits_eq(&dv, &ds, &format!("dequant len={len} off={off}"));
        }
    }
}

/// The MAX_CODE clamp edge (|x/twoeb| just below, at, and above 4.0e15)
/// must pick the outlier escape vs. the rounded code identically, and
/// round-half-away ties must round the same way.
#[test]
fn quant_parity_at_the_outlier_boundary() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    let twoeb = 2.0e-3;
    let mc = 4.0e15;
    let mut edge = Vec::new();
    for &q in &[0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 0.0] {
        edge.push(q * twoeb);
    }
    for &q in &[mc * (1.0 - 1e-10), mc, mc * (1.0 + 1e-10), mc * 2.0] {
        edge.push(q * twoeb);
        edge.push(-q * twoeb);
    }
    let (mut cv, mut ov) = (Vec::new(), Vec::new());
    let (mut cs, mut os) = (Vec::new(), Vec::new());
    v.quant_abs(&edge, twoeb, &mut cv, &mut ov);
    s.quant_abs(&edge, twoeb, &mut cs, &mut os);
    assert_eq!(cv, cs, "boundary codes");
    assert_eq!(ov, os, "boundary outliers");
}

/// Dequantization across the full contract range of codes (|code| <=
/// 4.0e15, which is all the quantizer can ever emit).
#[test]
fn dequant_parity_across_code_range() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    let mut rng = SplitMix64::new(0xDE11);
    let span = 8_000_000_000_000_001u64; // 2 * 4e15 + 1
    let codes: Vec<i64> = (0..1037)
        .map(|i| match i % 7 {
            0 => 4_000_000_000_000_000,
            1 => -4_000_000_000_000_000,
            2 => 0,
            _ => (rng.next_u64() % span) as i64 - 4_000_000_000_000_000,
        })
        .collect();
    for &len in LENS {
        for off in 0..4 {
            let c = &codes[off..off + len];
            let mut dv = vec![0.0; len];
            let mut ds = vec![0.0; len];
            v.dequant_abs(c, 2.0e-3, &mut dv);
            s.dequant_abs(c, 2.0e-3, &mut ds);
            assert_f64_bits_eq(&dv, &ds, &format!("dequant range len={len} off={off}"));
        }
    }
}

#[test]
fn bitmap_and_popcount_parity() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    // Heavy on zeros and sign flips so both bitmaps get dense bit traffic.
    let mut rng = SplitMix64::new(0xB17);
    let big: Vec<f64> = (0..1200)
        .map(|_| match rng.next_below(6) {
            0 => 0.0,
            1 => -0.0,
            2 => f64::NAN,
            3 => -f64::NAN,
            _ => rng.next_gaussian(),
        })
        .collect();
    for &len in LENS {
        for off in 0..4 {
            let data = &big[off..off + len];
            let (mut wv, mut ws) = (Vec::new(), Vec::new());
            let nv = v.pack_sign_bits(data, &mut wv);
            let ns = s.pack_sign_bits(data, &mut ws);
            assert_eq!(nv, ns, "sign count: len={len} off={off}");
            assert_eq!(wv, ws, "sign words: len={len} off={off}");
            let zv = v.pack_zero_bits(data, &mut wv);
            let zs = s.pack_zero_bits(data, &mut ws);
            assert_eq!(zv, zs, "zero count: len={len} off={off}");
            assert_eq!(wv, ws, "zero words: len={len} off={off}");
            let pv = v.popcount_words(&wv);
            let ps = s.popcount_words(&ws);
            assert_eq!(pv, ps, "popcount: len={len} off={off}");
        }
    }
    // Popcount over raw random words (all bit densities).
    let words: Vec<u64> = (0..257).map(|_| rng.next_u64()).collect();
    for &wlen in &[0usize, 1, 2, 3, 7, 8, 9, 31, 32, 33, 255] {
        let pv = v.popcount_words(&words[..wlen]);
        let ps = s.popcount_words(&words[..wlen]);
        assert_eq!(pv, ps, "popcount words len={wlen}");
    }
}

#[test]
fn zigzag_deltas_parity() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    let mut rng = SplitMix64::new(0x2162);
    let big: Vec<i64> = (0..1200)
        .map(|_| match rng.next_below(10) {
            0 => i64::MAX,
            1 => i64::MIN,
            2 => 0,
            3 => -1,
            _ => rng.next_u64() as i64,
        })
        .collect();
    for &len in LENS {
        for off in 0..4 {
            let codes = &big[off..off + len];
            let (mut zv, mut zs) = (Vec::new(), Vec::new());
            v.zigzag_deltas(codes, &mut zv);
            s.zigzag_deltas(codes, &mut zs);
            assert_eq!(zv, zs, "zigzag: len={len} off={off}");
        }
    }
}

#[test]
fn dense_1q_parity() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    let mut rng = SplitMix64::new(0xD15E);
    let m: [f64; 8] = std::array::from_fn(|_| rng.next_gaussian());
    for &n in &[2usize, 4, 6, 7] {
        let len = 1usize << n;
        for bitpow in 0..n {
            let bit = 1usize << bitpow;
            let re0 = plane(len, 0xE0 + n as u64, false);
            let im0 = plane(len, 0xF0 + n as u64, false);
            let (mut rv, mut iv) = (re0.clone(), im0.clone());
            let (mut rs, mut is_) = (re0, im0);
            v.dense_1q(&m, &mut rv, &mut iv, bit);
            s.dense_1q(&m, &mut rs, &mut is_, bit);
            assert_f64_bits_eq(&rv, &rs, &format!("dense_1q re n={n} bit={bit}"));
            assert_f64_bits_eq(&iv, &is_, &format!("dense_1q im n={n} bit={bit}"));
        }
    }
}

#[test]
fn fused_kq_quad_parity() {
    let v = simd::dispatch();
    let s = simd::scalar_ops();
    // Supports with bits[0] >= 2 — the quad-contiguity precondition the
    // fused apply path checks before dispatching the vector kernel.
    let cases: &[&[usize]] = &[&[2], &[5], &[2, 4], &[3, 5], &[2, 3, 6], &[3, 4, 5]];
    let len = 1usize << 8;
    let mut rng = SplitMix64::new(0xF0ED);
    for (case, &bits) in cases.iter().enumerate() {
        let dim = 1usize << bits.len();
        let mut offs = [0usize; 8];
        for (site, off) in offs.iter_mut().enumerate().take(dim) {
            for (j, &b) in bits.iter().enumerate() {
                if site & (1 << j) != 0 {
                    *off |= 1 << b;
                }
            }
        }
        let mr = mat8(&mut rng);
        let mi = mat8(&mut rng);
        let re0 = plane(len, 0x100 + case as u64, false);
        let im0 = plane(len, 0x200 + case as u64, false);
        let (mut rv, mut iv) = (re0.clone(), im0.clone());
        let (mut rs, mut is_) = (re0, im0);
        let qv = v.fused_kq_quad_fn();
        let qs = s.fused_kq_quad_fn();
        for base in subspace_bases(len, bits).step_by(4) {
            qv(&mut rv, &mut iv, base, &offs, &mr, &mi, dim);
        }
        for base in subspace_bases(len, bits).step_by(4) {
            qs(&mut rs, &mut is_, base, &offs, &mr, &mi, dim);
        }
        assert_f64_bits_eq(&rv, &rs, &format!("fused quad re bits={bits:?}"));
        assert_f64_bits_eq(&iv, &is_, &format!("fused quad im bits={bits:?}"));
    }
}

/// End-to-end codec parity: compressing with the dispatched table and
/// with a scalar-pinned `CodecScratch` must produce byte-identical
/// payloads, and both decode paths must reproduce identical planes.
/// The 4096-length case exceeds the multi-symbol Huffman threshold, so
/// the table-driven multi decode is exercised against the same bytes.
#[test]
fn codec_byte_identity_vector_vs_scalar() {
    let mut pw_no_prescan = Codec::pointwise(1e-3);
    pw_no_prescan.prescan = false;
    let codecs = [Codec::absolute(1e-3), Codec::pointwise(1e-3), pw_no_prescan, Codec::raw()];
    for (ci, codec) in codecs.iter().enumerate() {
        for &len in &[0usize, 1, 5, 63, 64, 100, 1024, 4096] {
            // Mix smooth amplitudes with exact zeros so the pointwise
            // zero bitmap and the residual run-length branch both fire.
            let mut rng = SplitMix64::new(0xC0DEC ^ ((ci as u64) << 20) ^ len as u64);
            let data: Vec<f64> = (0..len)
                .map(|i| {
                    if rng.next_below(5) == 0 {
                        0.0
                    } else {
                        1e-2 * ((i as f64) * 0.01).sin() + 1e-4 * rng.next_gaussian()
                    }
                })
                .collect();
            let mut sv = CodecScratch::new();
            let mut ss = CodecScratch::with_ops(simd::scalar_ops());
            let (mut bv, mut bs) = (Vec::new(), Vec::new());
            codec.compress_into_with(&data, &mut bv, &mut sv).unwrap();
            codec.compress_into_with(&data, &mut bs, &mut ss).unwrap();
            assert_eq!(bv, bs, "payload: codec={} len={len}", codec.name());
            let mut ov = vec![0.0; len];
            let mut os_ = vec![0.0; len];
            codec.decompress_into_with(&bv, &mut ov, &mut sv).unwrap();
            codec.decompress_into_with(&bs, &mut os_, &mut ss).unwrap();
            assert_f64_bits_eq(&ov, &os_, &format!("decode: codec={} len={len}", codec.name()));
        }
    }
}

/// `--no-simd` (SimConfig::no_simd) must be a pure diagnostic knob: the
/// final state of a full engine run is bit-for-bit identical with the
/// vector kernels pinned off. (`simd_kernels_used` is not asserted to
/// be zero here: the counter is process-wide and concurrent tests in
/// this binary bump it.)
#[test]
fn no_simd_engine_run_is_byte_identical() {
    let c = generators::qft(10);
    let cfg = |no_simd: bool| SimConfig { block_qubits: 8, no_simd, ..SimConfig::default() };

    let a = BmqSim::new(cfg(false)).run(&c, true).unwrap();
    let b = BmqSim::new(cfg(true)).run(&c, true).unwrap();
    let (sa, sb) = (a.state.unwrap(), b.state.unwrap());
    assert_f64_bits_eq(&sa.re, &sb.re, "bmqsim re");
    assert_f64_bits_eq(&sa.im, &sb.im, "bmqsim im");

    let d1 = DenseSim::new(cfg(false)).run(&c).unwrap();
    let d2 = DenseSim::new(cfg(true)).run(&c).unwrap();
    let (sd1, sd2) = (d1.state.unwrap(), d2.state.unwrap());
    assert_f64_bits_eq(&sd1.re, &sd2.re, "dense re");
    assert_f64_bits_eq(&sd1.im, &sd2.im, "dense im");
}

/// On vector-capable hosts, kernel invocations through a vector table
/// are counted. Gated on the *fetched* table being a vector tier so the
/// test is meaningful-or-skipped, never flaky (a concurrent `no_simd`
/// engine run can transiently pin dispatch to scalar).
#[test]
fn vector_tables_count_invocations() {
    let t = simd::dispatch();
    if !t.vectorized() {
        return;
    }
    let before = simd::kernels_used();
    let data = plane(256, 0xC0, false);
    let (mut codes, mut outliers) = (Vec::new(), Vec::new());
    t.quant_abs(&data, 2.0e-3, &mut codes, &mut outliers);
    assert!(simd::kernels_used() > before, "vector quant_abs must bump the kernel counter");
}
