//! Concurrency hammer for the sharded two-level `BlockStore`: many
//! threads churn put/take/get against a tight budget + spill dir, in both
//! spill modes, asserting
//!   * byte-identical payload round-trips under interception, spilling,
//!     promotion, and prefetching,
//!   * the primary budget is never exceeded (sampled mid-run and via the
//!     peak counter),
//!   * `MemStats` accounting (bytes + block counts per tier) is exactly
//!     consistent at every quiescent point.
//!
//! The fault-matrix tests re-run the same churn under a seeded
//! [`FaultPlan`] ({transient EIO, torn read, bit flip, ENOSPC, writer
//! death} × {sync, async}), asserting the recovery contract: every store
//! op either succeeds with byte-identical data or returns a *typed*
//! `Error::Spill`/`Error::Corruption` — never a panic, a hang, or silent
//! corruption.

use bmqsim::memory::{BlockPayload, BlockStore, FaultPlan, StoreOptions, SECONDARY_FRAME_BYTES};
use bmqsim::types::Error;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const IDS_PER_THREAD: usize = 12;

fn payload_for(id: usize, version: usize) -> BlockPayload {
    let len = 24 + (id * 7 + version * 13) % 90;
    let tag = ((id * 31 + version * 17) % 251) as u8;
    BlockPayload { re: vec![tag; len], im: vec![tag.wrapping_add(1); len] }
}

fn check(p: &BlockPayload, id: usize, version: usize) {
    let want = payload_for(id, version);
    assert_eq!(p.re, want.re, "block {id} v{version}: re corrupted");
    assert_eq!(p.im, want.im, "block {id} v{version}: im corrupted");
}

fn spill_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bmqsim-hammer-{tag}-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn hammer(tag: &str, opts: StoreOptions, budget: usize, threads: usize, rounds: usize) {
    let store =
        Arc::new(BlockStore::with_options(Some(budget), Some(spill_dir(tag)), opts).unwrap());
    let over_budget = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        for t in 0..threads {
            let store = store.clone();
            let over = over_budget.clone();
            scope.spawn(move || {
                // Each thread owns a disjoint id range (engines never race
                // on one block id; threads do race on shards, the policy
                // index, the write-back queue, and the spill file).
                let ids: Vec<usize> = (0..IDS_PER_THREAD).map(|k| t * 64 + k).collect();
                for round in 0..rounds {
                    for &id in &ids {
                        store.put(id, payload_for(id, round)).unwrap();
                    }
                    if store.stats().primary_bytes > budget {
                        over.store(true, Ordering::Relaxed);
                    }
                    for &id in &ids {
                        check(&store.get(id).unwrap(), id, round);
                    }
                    for &id in &ids {
                        let p = store.take(id).unwrap();
                        check(&p, id, round);
                        store.put(id, p).unwrap(); // recycle, engine-style
                    }
                }
            });
        }
    });
    store.flush().unwrap();
    assert!(!over_budget.load(Ordering::Relaxed), "primary budget exceeded mid-run");

    let st = store.stats();
    assert_eq!(st.blocks_write_back, 0, "write-back queue not drained");
    assert_eq!(st.write_back_bytes, 0);
    assert_eq!(st.blocks_primary + st.blocks_secondary, threads * IDS_PER_THREAD);
    assert!(st.primary_bytes <= budget);
    assert!(st.peak_primary_bytes <= budget, "peak {} > budget {budget}", st.peak_primary_bytes);

    // Every block readable with the final version's bytes.
    let mut total_payload = 0usize;
    for t in 0..threads {
        for k in 0..IDS_PER_THREAD {
            let id = t * 64 + k;
            let p = store.get(id).unwrap();
            check(&p, id, rounds - 1);
            total_payload += p.len();
        }
    }
    // get() may have promoted blocks; the re-snapshot must still balance:
    // primary bytes count raw payloads, secondary extents add the payload
    // framing plus the checksummed on-disk frame header.
    let st = store.stats();
    assert_eq!(st.blocks_primary + st.blocks_secondary, threads * IDS_PER_THREAD);
    assert_eq!(
        st.primary_bytes + st.secondary_bytes,
        total_payload + SECONDARY_FRAME_BYTES * st.blocks_secondary,
        "byte accounting drifted (primary {} secondary {} over {} blocks)",
        st.primary_bytes,
        st.secondary_bytes,
        st.blocks_secondary,
    );
    assert!(st.spill_events > 0, "budget never forced a spill — hammer too gentle");
}

#[test]
fn hammer_sharded_async_store() {
    let opts = StoreOptions {
        shards: 8,
        prefetch_depth: 0,
        async_spill: true,
        write_back_cap: 16,
        ..Default::default()
    };
    hammer("async", opts, 4096, 8, 60);
}

#[test]
fn hammer_single_shard_sync_store() {
    let opts = StoreOptions {
        shards: 1,
        prefetch_depth: 0,
        async_spill: false,
        write_back_cap: 16,
        ..Default::default()
    };
    hammer("sync", opts, 4096, 8, 60);
}

/// The recovery contract: a store op under fault injection may fail, but
/// only with the typed spill/corruption taxonomy — anything else (panic,
/// OOM misclassification, codec garbage) is a bug.
fn assert_typed(e: &Error) {
    assert!(
        matches!(e, Error::Spill { .. } | Error::Corruption(_)),
        "untyped failure under fault injection: {e:?}"
    );
}

/// Re-run the hammer churn under a fault plan. Every op must either
/// succeed with byte-identical data (`check`) or return a typed error,
/// after which the thread stops cleanly. With `expect_complete` the plan
/// is fully recoverable (transient faults, graceful ENOSPC): no op may
/// fail at all and the final contents must be exact.
///
/// No budget/peak assertions here: the ENOSPC ladder renegotiates the
/// primary budget by design.
fn fault_hammer(tag: &str, spec: &str, async_spill: bool, fallback: bool, expect_complete: bool) {
    let opts = StoreOptions {
        shards: 4,
        prefetch_depth: 0,
        async_spill,
        write_back_cap: 16,
        fault_plan: Some(FaultPlan::parse(spec).unwrap()),
        fallback_dir: fallback.then(|| spill_dir(&format!("{tag}-fb"))),
        ..Default::default()
    };
    let store =
        Arc::new(BlockStore::with_options(Some(4096), Some(spill_dir(tag)), opts).unwrap());
    let threads = 4usize;
    let rounds = 30usize;
    let failed = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        for t in 0..threads {
            let store = store.clone();
            let failed = failed.clone();
            scope.spawn(move || {
                let ids: Vec<usize> = (0..IDS_PER_THREAD).map(|k| t * 64 + k).collect();
                let fail = |e: &Error| {
                    assert_typed(e);
                    failed.store(true, Ordering::Relaxed);
                };
                for round in 0..rounds {
                    for &id in &ids {
                        if let Err(e) = store.put(id, payload_for(id, round)) {
                            return fail(&e);
                        }
                    }
                    for &id in &ids {
                        match store.take(id) {
                            Ok(p) => {
                                check(&p, id, round);
                                if let Err(e) = store.put(id, p) {
                                    return fail(&e);
                                }
                            }
                            Err(e) => return fail(&e),
                        }
                    }
                }
            });
        }
    });
    let flush = store.flush();
    if let Err(e) = &flush {
        assert_typed(e);
    }
    let st = store.stats();
    let injected =
        st.io_retries + st.checksum_failures + st.frames_recovered + st.enospc_fallbacks;
    assert!(injected > 0, "{tag}: the fault plan {spec:?} never engaged the recovery machinery");
    if expect_complete {
        assert!(
            !failed.load(Ordering::Relaxed),
            "{tag}: plan {spec:?} must be fully recoverable, but an op failed"
        );
        flush.expect("flush after recoverable faults");
        for t in 0..threads {
            for k in 0..IDS_PER_THREAD {
                let id = t * 64 + k;
                check(&store.get(id).unwrap(), id, rounds - 1);
            }
        }
    }
}

#[test]
fn fault_hammer_transient_eio_sync() {
    fault_hammer("feio-s", "seed=3,eio=0.03", false, false, true);
}

#[test]
fn fault_hammer_transient_eio_async() {
    fault_hammer("feio-a", "seed=4,eio=0.03", true, false, true);
}

#[test]
fn fault_hammer_torn_read_sync() {
    fault_hammer("fsr-s", "seed=5,short_read=0.03", false, false, true);
}

#[test]
fn fault_hammer_torn_read_async() {
    fault_hammer("fsr-a", "seed=6,short_read=0.03", true, false, true);
}

#[test]
fn fault_hammer_bitflip_sync() {
    fault_hammer("fbf-s", "seed=7,bitflip=0.03", false, false, true);
}

#[test]
fn fault_hammer_bitflip_async() {
    fault_hammer("fbf-a", "seed=8,bitflip=0.03", true, false, true);
}

#[test]
fn fault_hammer_enospc_sync_with_fallback_stripe() {
    // Primary stripe fills after 2 KiB; evictions retarget the fallback.
    fault_hammer("fen-s", "enospc_after=2048", false, true, true);
}

#[test]
fn fault_hammer_enospc_async_renegotiates_budget() {
    // No fallback stripe: the ladder's bottom rung halts eviction and
    // grows the primary budget — the churn still completes exactly.
    fault_hammer("fen-a", "enospc_after=2048", true, false, true);
}

#[test]
fn fault_hammer_writer_death_self_heals() {
    // The writer dies after 5 claimed jobs; the store spills inline from
    // then on. The low EIO rate keeps exercising retry on the inline path
    // (writer death itself bumps no recovery counter).
    fault_hammer("fwd-a", "seed=9,writer_death_after=5,eio=0.02", true, false, true);
}

#[test]
fn hammer_prefetcher_races_with_churn() {
    // A published schedule keeps the prefetcher promoting blocks 0..35
    // while 4 threads continuously take/rewrite them: exercises the
    // generation checks (stale reads must be discarded, never installed).
    let opts = StoreOptions {
        shards: 4,
        prefetch_depth: 8,
        async_spill: true,
        write_back_cap: 8,
        ..Default::default()
    };
    let store =
        Arc::new(BlockStore::with_options(Some(2048), Some(spill_dir("pf")), opts).unwrap());
    let threads = 4usize;
    let rounds = 40usize;
    let all_ids: Vec<usize> = (0..threads * IDS_PER_THREAD).collect();
    for &id in &all_ids {
        store.put(id, payload_for(id, 0)).unwrap();
    }
    store.publish_schedule(&all_ids, 4);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let store = store.clone();
            scope.spawn(move || {
                for round in 1..=rounds {
                    for k in 0..IDS_PER_THREAD {
                        let id = t * IDS_PER_THREAD + k;
                        let p = store.take(id).unwrap();
                        check(&p, id, round - 1);
                        store.put(id, payload_for(id, round)).unwrap();
                    }
                }
            });
        }
    });
    store.flush().unwrap();
    for &id in &all_ids {
        check(&store.get(id).unwrap(), id, rounds);
    }
    let st = store.stats();
    assert_eq!(st.blocks_primary + st.blocks_secondary, all_ids.len());
    assert!(st.peak_primary_bytes <= 2048);
}
