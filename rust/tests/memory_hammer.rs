//! Concurrency hammer for the sharded two-level `BlockStore`: many
//! threads churn put/take/get against a tight budget + spill dir, in both
//! spill modes, asserting
//!   * byte-identical payload round-trips under interception, spilling,
//!     promotion, and prefetching,
//!   * the primary budget is never exceeded (sampled mid-run and via the
//!     peak counter),
//!   * `MemStats` accounting (bytes + block counts per tier) is exactly
//!     consistent at every quiescent point.

use bmqsim::memory::{BlockPayload, BlockStore, StoreOptions};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const IDS_PER_THREAD: usize = 12;

fn payload_for(id: usize, version: usize) -> BlockPayload {
    let len = 24 + (id * 7 + version * 13) % 90;
    let tag = ((id * 31 + version * 17) % 251) as u8;
    BlockPayload { re: vec![tag; len], im: vec![tag.wrapping_add(1); len] }
}

fn check(p: &BlockPayload, id: usize, version: usize) {
    let want = payload_for(id, version);
    assert_eq!(p.re, want.re, "block {id} v{version}: re corrupted");
    assert_eq!(p.im, want.im, "block {id} v{version}: im corrupted");
}

fn spill_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bmqsim-hammer-{tag}-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn hammer(tag: &str, opts: StoreOptions, budget: usize, threads: usize, rounds: usize) {
    let store =
        Arc::new(BlockStore::with_options(Some(budget), Some(spill_dir(tag)), opts).unwrap());
    let over_budget = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        for t in 0..threads {
            let store = store.clone();
            let over = over_budget.clone();
            scope.spawn(move || {
                // Each thread owns a disjoint id range (engines never race
                // on one block id; threads do race on shards, the policy
                // index, the write-back queue, and the spill file).
                let ids: Vec<usize> = (0..IDS_PER_THREAD).map(|k| t * 64 + k).collect();
                for round in 0..rounds {
                    for &id in &ids {
                        store.put(id, payload_for(id, round)).unwrap();
                    }
                    if store.stats().primary_bytes > budget {
                        over.store(true, Ordering::Relaxed);
                    }
                    for &id in &ids {
                        check(&store.get(id).unwrap(), id, round);
                    }
                    for &id in &ids {
                        let p = store.take(id).unwrap();
                        check(&p, id, round);
                        store.put(id, p).unwrap(); // recycle, engine-style
                    }
                }
            });
        }
    });
    store.flush().unwrap();
    assert!(!over_budget.load(Ordering::Relaxed), "primary budget exceeded mid-run");

    let st = store.stats();
    assert_eq!(st.blocks_write_back, 0, "write-back queue not drained");
    assert_eq!(st.write_back_bytes, 0);
    assert_eq!(st.blocks_primary + st.blocks_secondary, threads * IDS_PER_THREAD);
    assert!(st.primary_bytes <= budget);
    assert!(st.peak_primary_bytes <= budget, "peak {} > budget {budget}", st.peak_primary_bytes);

    // Every block readable with the final version's bytes.
    let mut total_payload = 0usize;
    for t in 0..threads {
        for k in 0..IDS_PER_THREAD {
            let id = t * 64 + k;
            let p = store.get(id).unwrap();
            check(&p, id, rounds - 1);
            total_payload += p.len();
        }
    }
    // get() may have promoted blocks; the re-snapshot must still balance:
    // primary bytes count raw payloads, secondary extents add 16 B framing.
    let st = store.stats();
    assert_eq!(st.blocks_primary + st.blocks_secondary, threads * IDS_PER_THREAD);
    assert_eq!(
        st.primary_bytes + st.secondary_bytes,
        total_payload + 16 * st.blocks_secondary,
        "byte accounting drifted (primary {} secondary {} over {} blocks)",
        st.primary_bytes,
        st.secondary_bytes,
        st.blocks_secondary,
    );
    assert!(st.spill_events > 0, "budget never forced a spill — hammer too gentle");
}

#[test]
fn hammer_sharded_async_store() {
    let opts = StoreOptions {
        shards: 8,
        prefetch_depth: 0,
        async_spill: true,
        write_back_cap: 16,
        ..Default::default()
    };
    hammer("async", opts, 4096, 8, 60);
}

#[test]
fn hammer_single_shard_sync_store() {
    let opts = StoreOptions {
        shards: 1,
        prefetch_depth: 0,
        async_spill: false,
        write_back_cap: 16,
        ..Default::default()
    };
    hammer("sync", opts, 4096, 8, 60);
}

#[test]
fn hammer_prefetcher_races_with_churn() {
    // A published schedule keeps the prefetcher promoting blocks 0..35
    // while 4 threads continuously take/rewrite them: exercises the
    // generation checks (stale reads must be discarded, never installed).
    let opts = StoreOptions {
        shards: 4,
        prefetch_depth: 8,
        async_spill: true,
        write_back_cap: 8,
        ..Default::default()
    };
    let store =
        Arc::new(BlockStore::with_options(Some(2048), Some(spill_dir("pf")), opts).unwrap());
    let threads = 4usize;
    let rounds = 40usize;
    let all_ids: Vec<usize> = (0..threads * IDS_PER_THREAD).collect();
    for &id in &all_ids {
        store.put(id, payload_for(id, 0)).unwrap();
    }
    store.publish_schedule(&all_ids, 4);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let store = store.clone();
            scope.spawn(move || {
                for round in 1..=rounds {
                    for k in 0..IDS_PER_THREAD {
                        let id = t * IDS_PER_THREAD + k;
                        let p = store.take(id).unwrap();
                        check(&p, id, round - 1);
                        store.put(id, payload_for(id, round)).unwrap();
                    }
                }
            });
        }
    });
    store.flush().unwrap();
    for &id in &all_ids {
        check(&store.get(id).unwrap(), id, rounds);
    }
    let st = store.stats();
    assert_eq!(st.blocks_primary + st.blocks_secondary, all_ids.len());
    assert!(st.peak_primary_bytes <= 2048);
}
