//! Integration: the AOT'd JAX/Pallas HLO path must agree with the native
//! rust kernels — the end-to-end proof that all three layers compose.
//!
//! Requires `make artifacts`; tests skip (with a notice) when absent so
//! `cargo test` stays runnable before the python build step.

use bmqsim::circuit::{generators, Gate, GateKind};
use bmqsim::runtime::XlaApplier;
use bmqsim::sim::{BmqSim, DenseSim, GateApplier, SimConfig};
use bmqsim::state::StateVector;
use bmqsim::types::SplitMix64;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn random_state(n: usize, seed: u64) -> StateVector {
    let mut rng = SplitMix64::new(seed);
    let len = 1usize << n;
    let re: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let im: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    StateVector::from_planes(n, re, im).unwrap()
}

#[test]
fn gate_application_parity_native_vs_xla() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaApplier::new(dir).unwrap();
    let native = bmqsim::sim::NativeApplier;
    use GateKind::*;
    let gates_1q = [H, X, Y, T, Sx, Rx(0.71), Ry(-0.4), Rz(1.3), P(0.9), U3(0.3, 1.1, -0.6)];
    let gates_2q = [Cx, Cz, Swap, Cp(0.8), Rzz(-0.5), Rxx(0.6), Crz(1.7)];

    let n = 8;
    for (gi, kind) in gates_1q.iter().enumerate() {
        for t in [0usize, 3, 7] {
            let s = random_state(n, 100 + gi as u64 * 10 + t as u64);
            let gate = Gate::q1(*kind, t).unwrap();
            let mut a = s.clone();
            native.apply(&mut a.re, &mut a.im, &gate, &[t]).unwrap();
            let mut b = s.clone();
            xla.apply(&mut b.re, &mut b.im, &gate, &[t]).unwrap();
            for i in 0..a.len() {
                assert!(
                    (a.re[i] - b.re[i]).abs() < 1e-10 && (a.im[i] - b.im[i]).abs() < 1e-10,
                    "{kind:?} t={t} amp {i}: native ({},{}) xla ({},{})",
                    a.re[i],
                    a.im[i],
                    b.re[i],
                    b.im[i]
                );
            }
        }
    }
    for (gi, kind) in gates_2q.iter().enumerate() {
        for (qa, qb) in [(0usize, 1usize), (5, 2), (7, 0)] {
            let s = random_state(n, 500 + gi as u64 * 10 + qa as u64);
            let gate = Gate::q2(*kind, qa, qb).unwrap();
            let mut a = s.clone();
            native.apply(&mut a.re, &mut a.im, &gate, &[qa, qb]).unwrap();
            let mut b = s.clone();
            xla.apply(&mut b.re, &mut b.im, &gate, &[qa, qb]).unwrap();
            for i in 0..a.len() {
                assert!(
                    (a.re[i] - b.re[i]).abs() < 1e-10 && (a.im[i] - b.im[i]).abs() < 1e-10,
                    "{kind:?} ({qa},{qb}) amp {i}"
                );
            }
        }
    }
}

#[test]
fn dense_engine_full_circuit_through_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaApplier::new(dir).unwrap();
    for name in ["ghz_state", "qft", "qaoa"] {
        let c = generators::build(name, 6, 11).unwrap();
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let cfg = SimConfig::default();
        let r = DenseSim::with_applier(cfg, &xla).run(&c).unwrap();
        let f = r.state.unwrap().fidelity_normalized(&ideal);
        assert!(f > 1.0 - 1e-9, "{name}: xla-backend fidelity {f}");
    }
}

#[test]
fn bmqsim_engine_through_xla_backend() {
    // The headline composition: staged compressed engine with the Pallas
    // kernels doing every state update.
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaApplier::new(dir).unwrap();
    let c = generators::build("ising", 8, 5).unwrap();
    let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
    let cfg = SimConfig { block_qubits: 5, ..SimConfig::default() };
    let r = BmqSim::with_applier(cfg, &xla).run(&c, true).unwrap();
    let f = r.state.as_ref().unwrap().fidelity_normalized(&ideal);
    assert!(f > 0.999, "bmqsim+xla fidelity {f}");
    assert!(r.metrics.gates_applied as usize >= c.len());
}

#[test]
fn quantizer_artifact_matches_rust_codec_semantics() {
    // The Pallas quantizer (L1) and the rust pointwise codec implement the
    // same log2-domain transform; dequantize(quantize(x)) must satisfy the
    // same point-wise relative bound.
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaApplier::new(dir).unwrap();
    let mut rng = SplitMix64::new(3);
    let x: Vec<f64> = (0..40_000)
        .map(|i| if i % 9 == 0 { 0.0 } else { rng.next_gaussian() * 10f64.powi((i % 17) as i32 - 8) })
        .collect();
    let eb = 1e-3;
    let (codes, signs) = xla.quantize(&x, eb).unwrap();
    let rec = xla.dequantize(&codes, &signs, eb).unwrap();
    for (i, (&a, &b)) in x.iter().zip(&rec).enumerate() {
        if a == 0.0 {
            assert_eq!(b, 0.0, "zero at {i}");
        } else {
            let rel = (b - a).abs() / a.abs();
            assert!(rel <= eb * (1.0 + 1e-9), "idx {i}: rel {rel}");
            assert_eq!(a < 0.0, b < 0.0, "sign at {i}");
        }
    }
}

#[test]
fn xla_applier_is_safe_under_concurrent_use() {
    // GateApplier: Sync — multiple pipeline workers submit concurrently;
    // the service thread serializes launches (single device queue).
    let Some(dir) = artifacts_dir() else { return };
    let xla = std::sync::Arc::new(XlaApplier::new(dir).unwrap());
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let xla = xla.clone();
            s.spawn(move || {
                let st = random_state(6, t);
                let gate = Gate::q1(GateKind::H, (t % 6) as usize).unwrap();
                let mut a = st.clone();
                xla.apply(&mut a.re, &mut a.im, &gate, &[(t % 6) as usize]).unwrap();
                // Norm preserved => executed correctly.
                assert!((a.norm_sq() - st.norm_sq()).abs() < 1e-9);
            });
        }
    });
}
