//! Adaptive error-control integration tests (ISSUE 10): the whole-run
//! fidelity guarantee must hold across `{global, amplitude}` policies ×
//! `{no spill, sync spill, async spill}` × `{cross-stage on, off}`, the
//! budget ledger must never spend past its allocation, the checkpoint
//! fingerprint must pin the error policy and fidelity target (resuming
//! under a different error contract is rejected typed), and the CLI
//! flags must round-trip end to end.

use bmqsim::circuit::generators;
use bmqsim::compress::budget::ErrorPolicy;
use bmqsim::memory::checkpoint;
use bmqsim::sim::{BmqSim, DenseSim, OverlapMode, SimConfig};
use bmqsim::state::StateVector;
use bmqsim::types::Error;
use std::path::PathBuf;
use std::process::Command;

const TARGET: f64 = 0.999;

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bmq-ec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn base_cfg(policy: ErrorPolicy) -> SimConfig {
    SimConfig {
        block_qubits: 5,
        inner_size: 2,
        fidelity_target: Some(TARGET),
        error_policy: policy,
        ..SimConfig::default()
    }
}

/// The deep random circuit is the workload the controller exists for:
/// nonuniform per-block amplitude mass, every stage lossy.
fn workload() -> (bmqsim::circuit::Circuit, StateVector) {
    let c = generators::build("random", 10, 7).unwrap();
    let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
    (c, ideal)
}

// ---------------------------------------------------------------------
// The acceptance matrix: terminal fidelity >= target everywhere.
// ---------------------------------------------------------------------

#[test]
fn fidelity_meets_target_across_policy_spill_and_overlap_matrix() {
    let (c, ideal) = workload();
    let eps_total = (1.0 - TARGET) / 2.0;

    for policy in [ErrorPolicy::Global, ErrorPolicy::Amplitude] {
        // (spill tier active, synchronous spill, cross-stage overlap)
        for (spill, sync, cross) in [
            (false, false, false),
            (false, false, true),
            (true, true, false),
            (true, true, true),
            (true, false, false),
            (true, false, true),
        ] {
            let tag = format!("{policy}-sp{}-sy{}-x{}", spill as u8, sync as u8, cross as u8);
            let mut cfg = base_cfg(policy);
            cfg.cross_stage = if cross { OverlapMode::On } else { OverlapMode::Off };
            if spill {
                cfg.memory_budget = Some(1024);
                cfg.spill_dir = Some(tdir(&tag).join("spill"));
                cfg.sync_spill = sync;
            }
            let r = BmqSim::new(cfg).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(&ideal);
            assert!(f >= TARGET, "{tag}: fidelity {f} < target {TARGET}");

            // The ledger is conservative: spent L2 error never exceeds
            // the whole-run allocation, and every handed-out bound was
            // recorded.
            assert!(
                r.metrics.error_budget_spent <= eps_total + 1e-15,
                "{tag}: spent {} > budget {eps_total}",
                r.metrics.error_budget_spent
            );
            assert!(r.metrics.per_block_bound_max > 0.0, "{tag}: no bounds recorded");
            assert!(
                r.metrics.per_block_bound_min <= r.metrics.per_block_bound_max,
                "{tag}: bound span inverted"
            );
            if spill {
                // Under a 1 KiB budget the tier machinery must have
                // engaged: blocks either spilled or were recompressed
                // in place (the compressed-primary third tier).
                assert!(
                    r.mem.spill_events > 0 || r.mem.recompressions > 0,
                    "{tag}: tight budget but no spills and no recompressions"
                );
            }
        }
    }
}

#[test]
fn amplitude_policy_spreads_bounds_wider_than_global() {
    let (c, ideal) = workload();

    let rg = BmqSim::new(base_cfg(ErrorPolicy::Global)).run(&c, true).unwrap();
    let ra = BmqSim::new(base_cfg(ErrorPolicy::Amplitude)).run(&c, true).unwrap();
    assert!(rg.state.as_ref().unwrap().fidelity(&ideal) >= TARGET);
    assert!(ra.state.as_ref().unwrap().fidelity(&ideal) >= TARGET);

    // Amplitude-aware splitting is the point: heavy blocks get tighter
    // bounds than near-zero blocks, so the per-block span is strictly
    // wider than the global policy's (which hands every block in a
    // round the same bound, min == max only differing across rounds).
    let ga = rg.metrics.per_block_bound_max / rg.metrics.per_block_bound_min;
    let aa = ra.metrics.per_block_bound_max / ra.metrics.per_block_bound_min;
    assert!(
        aa > ga,
        "amplitude span ratio {aa} not wider than global {ga}"
    );
}

// ---------------------------------------------------------------------
// Checkpoint fingerprint pins the error contract.
// ---------------------------------------------------------------------

#[test]
fn fingerprint_covers_error_policy() {
    let (c, ideal) = workload();
    let root = tdir("fp");

    let mut cfg = base_cfg(ErrorPolicy::Amplitude);
    cfg.checkpoint_dir = Some(root.clone());
    cfg.checkpoint_every = 1;
    cfg.checkpoint_keep = 64;
    let r = BmqSim::new(cfg).run(&c, true).unwrap();
    assert!(r.metrics.checkpoints >= 2);
    assert!(r.state.as_ref().unwrap().fidelity(&ideal) >= TARGET);

    let resume = |mutate: &dyn Fn(&mut SimConfig)| {
        let mut rc = base_cfg(ErrorPolicy::Amplitude);
        rc.resume_from = Some(root.clone());
        mutate(&mut rc);
        BmqSim::new(rc).run(&c, true)
    };

    // A checkpoint written under one error contract must not resume
    // under another: the budget already spent cannot be re-audited.
    for mutate in [
        (&|rc: &mut SimConfig| rc.error_policy = ErrorPolicy::Global) as &dyn Fn(&mut SimConfig),
        &|rc: &mut SimConfig| rc.fidelity_target = Some(0.99),
        &|rc: &mut SimConfig| rc.fidelity_target = None,
    ] {
        match resume(mutate) {
            Err(Error::Checkpoint(m)) => {
                assert!(m.contains("fingerprint"), "unexpected message: {m}")
            }
            other => panic!("expected Error::Checkpoint, got {other:?}"),
        }
    }

    // Keep only the OLDEST retained checkpoint so the resume restarts
    // from a genuinely intermediate cursor: the rescaled budget (the
    // resumed process only owns the remaining stages' share) must still
    // land the whole-run guarantee.
    let mut ckpts = checkpoint::list_checkpoints(&root); // newest-first
    assert!(ckpts.len() >= 2);
    let (oldest_cursor, _) = *ckpts.last().unwrap();
    assert!(oldest_cursor < r.stages, "oldest checkpoint is terminal");
    ckpts.truncate(ckpts.len() - 1);
    for (_, dir) in ckpts {
        std::fs::remove_dir_all(dir).unwrap();
    }
    let rr = resume(&|_| {}).unwrap();
    assert_eq!(rr.metrics.resumes, 1);
    let f = rr.state.as_ref().unwrap().fidelity(&ideal);
    assert!(f >= TARGET, "resumed run broke the guarantee: {f}");
}

// ---------------------------------------------------------------------
// CLI round-trip: flags parse, the report shows the controller, bad
// values exit with the config code.
// ---------------------------------------------------------------------

#[test]
fn cli_flags_round_trip_and_reject_bad_values() {
    let run = |args: &[&str]| {
        Command::new(env!("CARGO_BIN_EXE_bmqsim")).args(args).output().expect("spawn bmqsim")
    };
    let base: &[&str] = &["run", "--algo", "random", "--qubits", "8", "--block-qubits", "4"];

    let mut ok: Vec<&str> = base.to_vec();
    ok.extend_from_slice(&["--fidelity-target", "0.999", "--error-policy", "amplitude"]);
    let out = run(&ok);
    assert_eq!(out.status.code(), Some(0), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("error control"), "no error-control report line:\n{stdout}");

    // Bad values are usage/config errors (exit 2), not crashes.
    let mut bad: Vec<&str> = base.to_vec();
    bad.extend_from_slice(&["--error-policy", "frobnicate"]);
    assert_eq!(run(&bad).status.code(), Some(2));
    let mut bad: Vec<&str> = base.to_vec();
    bad.extend_from_slice(&["--fidelity-target", "1.5"]);
    assert_eq!(run(&bad).status.code(), Some(2));
    let mut bad: Vec<&str> = base.to_vec();
    bad.extend_from_slice(&["--fidelity-target", "nope"]);
    assert_eq!(run(&bad).status.code(), Some(2));
}
