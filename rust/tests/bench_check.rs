//! End-to-end tests for the CI bench-regression gate: the `bench_check`
//! binary must exit non-zero when fed a synthetically regressed
//! `BENCH_*.json` (ISSUE 5 acceptance) and zero on healthy artifacts.

use std::path::{Path, PathBuf};
use std::process::Command;

fn bench_check_exe() -> &'static str {
    env!("CARGO_BIN_EXE_bench_check")
}

fn setup(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bmq-check-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("bench_baselines")).unwrap();
    dir
}

fn write(dir: &Path, rel: &str, body: &str) {
    std::fs::write(dir.join(rel), body).unwrap();
}

fn run_in(dir: &Path, args: &[&str]) -> std::process::Output {
    Command::new(bench_check_exe())
        .current_dir(dir)
        .env_remove("BENCH_BASELINE_REFRESH")
        .args(args)
        .output()
        .expect("spawn bench_check")
}

#[test]
fn regressed_artifact_exits_nonzero() {
    let dir = setup("regress");
    write(&dir, "bench_baselines/BENCH_gates.json", r#"{"speedup": 3.0}"#);
    // 2.0 vs 3.0 = −33%, beyond the 25% gate.
    write(&dir, "BENCH_gates.json", r#"{"speedup": 2.0}"#);
    let out = run_in(&dir, &["BENCH_gates.json"]);
    assert!(
        !out.status.success(),
        "gate did not fire: stdout={} stderr={}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("REGRESSED"), "no finding printed: {stdout}");
}

#[test]
fn healthy_artifact_exits_zero() {
    let dir = setup("healthy");
    write(&dir, "bench_baselines/BENCH_gates.json", r#"{"speedup": 3.0}"#);
    write(&dir, "BENCH_gates.json", r#"{"speedup": 2.9}"#);
    let out = run_in(&dir, &["BENCH_gates.json"]);
    assert!(
        out.status.success(),
        "gate fired spuriously: stdout={} stderr={}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn missing_required_artifact_exits_nonzero() {
    let dir = setup("missing");
    let out = run_in(&dir, &["BENCH_gates.json"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("BENCH_gates.json"), "unhelpful error: {stderr}");
}

#[test]
fn refresh_env_repins_then_gate_passes() {
    let dir = setup("refresh");
    write(&dir, "bench_baselines/BENCH_gates.json", r#"{"speedup": 9.0}"#);
    write(&dir, "BENCH_gates.json", r#"{"speedup": 2.0}"#);
    // Gate fires against the stale pin…
    assert!(!run_in(&dir, &["BENCH_gates.json"]).status.success());
    // …refresh re-pins…
    let out = Command::new(bench_check_exe())
        .current_dir(&dir)
        .env("BENCH_BASELINE_REFRESH", "1")
        .output()
        .unwrap();
    assert!(out.status.success());
    // …and the same artifact now passes.
    assert!(run_in(&dir, &["BENCH_gates.json"]).status.success());
}

#[test]
fn append_history_flag_records_passing_runs_only() {
    let dir = setup("history");
    write(&dir, "bench_baselines/BENCH_gates.json", r#"{"speedup": 3.0}"#);
    write(&dir, "BENCH_gates.json", r#"{"speedup": 2.9, "git_sha": "e2e1234"}"#);
    let out = run_in(&dir, &["--append-history", "BENCH_gates.json"]);
    assert!(out.status.success());
    let body = std::fs::read_to_string(dir.join("bench_history.jsonl")).unwrap();
    assert_eq!(body.lines().count(), 1);
    assert!(body.contains("e2e1234"), "history line lacks git sha: {body}");
    assert!(body.contains("\"speedup\""), "history line lacks gated metric: {body}");
    // A regressed run fails the gate BEFORE appending — the trajectory
    // only records accepted states.
    write(&dir, "BENCH_gates.json", r#"{"speedup": 1.0, "git_sha": "bad"}"#);
    let out = run_in(&dir, &["--append-history", "BENCH_gates.json"]);
    assert!(!out.status.success());
    let body = std::fs::read_to_string(dir.join("bench_history.jsonl")).unwrap();
    assert_eq!(body.lines().count(), 1, "regressed run must not be recorded");
}

#[test]
fn committed_baselines_cover_every_gated_artifact() {
    // The real bench_baselines/ directory ships a pin for each gated file,
    // so CI never hits the missing-baseline error on a fresh clone.
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_baselines");
    for rule in bmqsim::bench_harness::check::RULES {
        let pin = manifest.join(rule.file);
        assert!(pin.is_file(), "missing committed baseline {}", pin.display());
        // And the gated metric is actually present in the pin.
        let text = std::fs::read_to_string(&pin).unwrap();
        let doc = bmqsim::runtime::Json::parse(&text).unwrap();
        let mut cur = &doc;
        for key in rule.path {
            cur = cur.get(key).unwrap_or_else(|| {
                panic!("baseline {} lacks gated path {:?}", rule.file, rule.path)
            });
        }
        assert!(cur.as_f64().is_some(), "{}: gated metric not numeric", rule.file);
    }
}
