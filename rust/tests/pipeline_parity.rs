//! Parity property tests for the overlapped group-chain pipeline (ISSUE 4,
//! persistent pool since ISSUE 5): across {pipeline_depth auto/1/2/3} ×
//! {workers 1/4} × {sync/async spill}, the three-phase decode → apply →
//! encode pipeline — now running on the persistent `PhasePool` — must
//! produce terminal compressed blocks that are **byte-identical** to the
//! sequential chain, with identical fidelity — overlap may only move
//! *when* work happens, never *what* it computes. Also exercises
//! spill-aware scheduling and the prefetch auto-depth controller
//! end-to-end through the engine.
//!
//! CI runs this file with `--test-threads` pinned so the race-sensitive
//! configurations (overlap + async spill + prefetcher churn) actually get
//! cores to interleave on instead of being serialized by test-runner
//! oversubscription.

use bmqsim::circuit::{generators, Circuit};
use bmqsim::memory::{BlockPayload, FaultPlan};
use bmqsim::pipeline::PipelineConfig;
use bmqsim::sim::{BmqSim, OverlapMode, SimConfig};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bmqsim-parity-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn base_cfg(block_qubits: usize) -> SimConfig {
    SimConfig { block_qubits, inner_size: 2, ..SimConfig::default() }
}

/// Run to completion and read back every terminal compressed block.
fn terminal_blocks(config: SimConfig, c: &Circuit) -> Vec<BlockPayload> {
    let (store, layout) = BmqSim::new(config).run_keeping_store(c).unwrap();
    (0..layout.num_blocks()).map(|id| store.get(id).unwrap()).collect()
}

#[test]
fn pipelined_chain_is_byte_identical_across_depths_workers_and_spill_modes() {
    // Lossy default codec on purpose: parity must hold bit-for-bit even
    // when the codec itself is lossy (determinism, not accuracy).
    for (name, n, bq, seed) in [("qaoa", 10usize, 5usize, 3u64), ("qft", 9, 4, 0)] {
        let c = generators::build(name, n, seed).unwrap();
        let mut seq = base_cfg(bq);
        seq.pipeline = PipelineConfig::sequential();
        seq.overlap = OverlapMode::Off;
        let reference = terminal_blocks(seq, &c);

        // Squeeze the budget to a quarter of the compressed peak so the
        // spilled configurations genuinely exercise both spill modes.
        let probe = BmqSim::new(base_cfg(bq)).run(&c, false).unwrap();
        let budget = (probe.peak_bytes / 4).max(512);

        // `None` = adaptive ring depth (the AIMD controller drives it).
        for depth in [None, Some(1usize), Some(2), Some(3)] {
            for workers in [1usize, 4] {
                for sync_spill in [false, true] {
                    let mut config = base_cfg(bq);
                    config.pipeline = PipelineConfig::new(1, workers);
                    config.overlap = OverlapMode::On;
                    match depth {
                        Some(d) => {
                            config.pipeline_depth = d;
                            config.pipeline_depth_auto = false;
                        }
                        None => config.pipeline_depth_auto = true,
                    }
                    config.sync_spill = sync_spill;
                    config.memory_budget = Some(budget);
                    config.spill_dir = Some(tmpdir(name));
                    let got = terminal_blocks(config, &c);
                    assert_eq!(got.len(), reference.len());
                    for (id, (a, b)) in reference.iter().zip(&got).enumerate() {
                        assert!(
                            a.re == b.re && a.im == b.im,
                            "{name}: block {id} bytes differ \
                             (depth={depth:?} workers={workers} sync_spill={sync_spill})"
                        );
                    }
                }
            }
        }

        // The squeezed budget actually spilled (otherwise the sync/async
        // axis above tested nothing).
        let mut spilled = base_cfg(bq);
        spilled.overlap = OverlapMode::On;
        spilled.memory_budget = Some(budget);
        spilled.spill_dir = Some(tmpdir(name));
        let r = BmqSim::new(spilled).run(&c, false).unwrap();
        assert!(r.mem.spill_events > 0, "{name}: budget {budget} never spilled");
        // …and the overlapped configurations really ran on the persistent
        // pool: threads spawned once, one handoff per stage.
        assert_eq!(r.metrics.phase_threads_spawned, 3 * 2);
        assert_eq!(r.metrics.pool_stage_handoffs, r.stages as u64);
    }
}

#[test]
fn cross_stage_overlap_is_byte_identical_across_the_full_axis() {
    // ISSUE 8: the cross-stage drain protocol replaces the per-stage
    // barrier with shared-block boundary gates. Whatever the epoch window
    // reorders, terminal compressed blocks must stay byte-identical across
    // {cross on/off} × {depth auto/2/3} × {workers 1/4} × {sync/async
    // spill} — the gate is a correctness mechanism, never a semantic one.
    let c = generators::build("qaoa", 10, 3).unwrap();
    let mut seq = base_cfg(5);
    seq.pipeline = PipelineConfig::sequential();
    seq.overlap = OverlapMode::Off;
    seq.cross_stage = OverlapMode::Off;
    let reference = terminal_blocks(seq, &c);

    let probe = BmqSim::new(base_cfg(5)).run(&c, false).unwrap();
    let budget = (probe.peak_bytes / 4).max(512);

    for cross in [OverlapMode::Off, OverlapMode::On] {
        for depth in [None, Some(2usize), Some(3)] {
            for workers in [1usize, 4] {
                for sync_spill in [false, true] {
                    let mut config = base_cfg(5);
                    config.pipeline = PipelineConfig::new(1, workers);
                    config.overlap = OverlapMode::On;
                    config.cross_stage = cross;
                    match depth {
                        Some(d) => {
                            config.pipeline_depth = d;
                            config.pipeline_depth_auto = false;
                        }
                        None => config.pipeline_depth_auto = true,
                    }
                    config.sync_spill = sync_spill;
                    config.memory_budget = Some(budget);
                    config.spill_dir = Some(tmpdir("cross"));
                    let got = terminal_blocks(config, &c);
                    assert_eq!(got.len(), reference.len());
                    for (id, (a, b)) in reference.iter().zip(&got).enumerate() {
                        assert!(
                            a.re == b.re && a.im == b.im,
                            "block {id} bytes differ (cross={cross:?} depth={depth:?} \
                             workers={workers} sync_spill={sync_spill})"
                        );
                    }
                }
            }
        }
    }

    // The axis above is vacuous if cross-stage never actually engaged:
    // a multi-stage run with the window open must either decode across
    // the boundary or time an epoch drain.
    let mut engaged = base_cfg(5);
    engaged.pipeline = PipelineConfig::new(1, 4);
    engaged.overlap = OverlapMode::On;
    engaged.cross_stage = OverlapMode::On;
    engaged.pipeline_depth = 2;
    engaged.pipeline_depth_auto = false;
    engaged.memory_budget = Some(budget);
    engaged.spill_dir = Some(tmpdir("cross"));
    let r = BmqSim::new(engaged).run(&c, false).unwrap();
    assert!(r.stages > 1, "need a multi-stage plan to cross a boundary");
    assert!(
        r.metrics.cross_stage_decodes > 0 || r.metrics.epoch_drain_ns > 0,
        "cross-stage pinned On but neither early decodes nor epoch drains recorded"
    );
}

#[test]
fn cross_stage_with_transient_faults_stays_byte_identical() {
    // Mid-drain fault tolerance: recoverable spill EIOs fire while two
    // epochs are in flight. Retries must absorb every fault without
    // wedging a boundary-gate waiter or perturbing terminal bytes.
    let c = generators::build("qaoa", 10, 3).unwrap();
    let mut seq = base_cfg(5);
    seq.pipeline = PipelineConfig::sequential();
    seq.overlap = OverlapMode::Off;
    seq.cross_stage = OverlapMode::Off;
    let reference = terminal_blocks(seq, &c);

    let probe = BmqSim::new(base_cfg(5)).run(&c, false).unwrap();
    let budget = (probe.peak_bytes / 4).max(512);
    let mut config = base_cfg(5);
    config.pipeline = PipelineConfig::new(1, 4);
    config.overlap = OverlapMode::On;
    config.cross_stage = OverlapMode::On;
    config.pipeline_depth = 2;
    config.pipeline_depth_auto = false;
    config.memory_budget = Some(budget);
    config.spill_dir = Some(tmpdir("cross-fault"));
    config.fault_plan = Some(FaultPlan::parse("seed=9,eio=0.05").unwrap());
    let got = terminal_blocks(config.clone(), &c);
    for (id, (a, b)) in reference.iter().zip(&got).enumerate() {
        assert!(a.re == b.re && a.im == b.im, "block {id} differs under transient faults");
    }
    let r = BmqSim::new(config).run(&c, false).unwrap();
    assert!(r.mem.io_retries > 0, "fault plan never engaged; test is vacuous");
}

#[test]
fn pipelined_fidelity_matches_sequential_exactly() {
    let c = generators::build("ising", 10, 11).unwrap();
    let mut seq = base_cfg(5);
    seq.pipeline = PipelineConfig::sequential();
    seq.overlap = OverlapMode::Off;
    let base = BmqSim::new(seq).run(&c, true).unwrap();
    let mut ovl = base_cfg(5);
    ovl.pipeline = PipelineConfig::new(1, 4);
    ovl.overlap = OverlapMode::On;
    ovl.pipeline_depth = 2;
    ovl.pipeline_depth_auto = false;
    let r = BmqSim::new(ovl).run(&c, true).unwrap();
    let (sa, oa) = (base.state.as_ref().unwrap(), r.state.as_ref().unwrap());
    assert_eq!(sa.re, oa.re, "real planes differ");
    assert_eq!(sa.im, oa.im, "imaginary planes differ");
    let f = oa.fidelity_normalized(sa);
    assert!(f > 1.0 - 1e-15, "fidelity {f}");
}

#[test]
fn spill_aware_ordering_keeps_state_identical_and_reorders_under_budget() {
    // Belady-rank consistency, end to end: with spill-aware scheduling ON
    // the engine publishes the REORDERED block order, so eviction ranks
    // and the prefetch window follow the true processing order — any
    // inconsistency shows up as corrupted terminal bytes or a store error.
    let c = generators::build("qaoa", 12, 5).unwrap();
    let mut seq = base_cfg(6);
    seq.pipeline = PipelineConfig::sequential();
    seq.overlap = OverlapMode::Off;
    seq.spill_aware = false;
    let reference = terminal_blocks(seq, &c);

    let probe = BmqSim::new(base_cfg(6)).run(&c, false).unwrap();
    let budget = (probe.peak_bytes / 4).max(512);
    for spill_aware in [false, true] {
        let mut config = base_cfg(6);
        config.pipeline = PipelineConfig::new(1, 2);
        config.overlap = OverlapMode::On;
        config.memory_budget = Some(budget);
        config.spill_dir = Some(tmpdir("order"));
        config.spill_aware = spill_aware;
        let got = terminal_blocks(config.clone(), &c);
        for (id, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert!(
                a.re == b.re && a.im == b.im,
                "block {id} differs (spill_aware={spill_aware})"
            );
        }
        let r = BmqSim::new(config).run(&c, false).unwrap();
        if spill_aware {
            assert!(
                r.metrics.groups_reordered > 0,
                "spill-aware scheduling never promoted a resident group"
            );
        } else {
            assert_eq!(r.metrics.groups_reordered, 0);
        }
    }
}

#[test]
fn prefetch_auto_depth_adapts_through_the_engine() {
    // No --prefetch-depth analogue: prefetch_auto starts at the default
    // depth and must land somewhere in the controller's [1, 32] band
    // while leaving results untouched.
    let c = generators::build("qft", 11, 1).unwrap();
    let mut seq = base_cfg(5);
    seq.pipeline = PipelineConfig::sequential();
    seq.overlap = OverlapMode::Off;
    let reference = terminal_blocks(seq, &c);

    let probe = BmqSim::new(base_cfg(5)).run(&c, false).unwrap();
    let mut config = base_cfg(5);
    config.overlap = OverlapMode::On;
    config.prefetch_auto = true;
    config.memory_budget = Some((probe.peak_bytes / 4).max(512));
    config.spill_dir = Some(tmpdir("auto"));
    let r = BmqSim::new(config.clone()).run(&c, false).unwrap();
    assert!(
        (1usize..=32).contains(&r.mem.prefetch_depth),
        "auto depth {} outside AIMD band",
        r.mem.prefetch_depth
    );
    let got = terminal_blocks(config, &c);
    for (id, (a, b)) in reference.iter().zip(&got).enumerate() {
        assert!(a.re == b.re && a.im == b.im, "block {id} differs under auto-depth");
    }
}
