//! Property tests pinning fused-batched gate application against the
//! per-gate reference kernels: every gate kind, every target
//! permutation, random circuits, all tile sizes and worker counts
//! (including workers = 1). Tolerance 1e-12 absolute per amplitude.
//!
//! No proptest in the vendor set: seeded SplitMix64 cases, failing seeds
//! printed for reproduction (same harness as `engine_integration.rs`).

use bmqsim::circuit::fusion::{fuse_gates, FusedGate};
use bmqsim::circuit::{Circuit, Gate, GateKind};
use bmqsim::gates::{apply_gate, apply_stage};
use bmqsim::types::SplitMix64;

fn random_planes(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let len = 1usize << n;
    (
        (0..len).map(|_| rng.next_gaussian()).collect(),
        (0..len).map(|_| rng.next_gaussian()).collect(),
    )
}

fn assert_close(got_re: &[f64], got_im: &[f64], want_re: &[f64], want_im: &[f64], tag: &str) {
    for i in 0..got_re.len() {
        assert!(
            (got_re[i] - want_re[i]).abs() < 1e-12 && (got_im[i] - want_im[i]).abs() < 1e-12,
            "{tag}: amp {i}: got ({}, {}) want ({}, {})",
            got_re[i],
            got_im[i],
            want_re[i],
            want_im[i]
        );
    }
}

fn all_1q_kinds() -> Vec<GateKind> {
    use GateKind::*;
    vec![
        X,
        Y,
        Z,
        H,
        S,
        Sdg,
        T,
        Tdg,
        Sx,
        Rx(0.7),
        Ry(-0.4),
        Rz(1.9),
        P(0.33),
        U3(0.3, 1.2, -0.8),
    ]
}

fn all_2q_kinds() -> Vec<GateKind> {
    use GateKind::*;
    vec![Cx, Cy, Cz, Swap, Cp(0.9), Crx(0.5), Cry(-1.1), Crz(2.0), Rxx(0.6), Rzz(-0.3)]
}

/// Fused singleton ops must match the per-gate kernels for EVERY kind on
/// EVERY target (1q) / ordered target pair (2q), at every worker count.
#[test]
fn every_kind_and_permutation_matches_per_gate_reference() {
    let n = 5;
    for (ki, kind) in all_1q_kinds().into_iter().enumerate() {
        for t in 0..n {
            let gate = Gate::q1(kind, t).unwrap();
            check_gate_list(&[gate], n, (ki * 100 + t) as u64, &format!("{kind:?} t={t}"));
        }
    }
    for (ki, kind) in all_2q_kinds().into_iter().enumerate() {
        for qa in 0..n {
            for qb in 0..n {
                if qa == qb {
                    continue;
                }
                let gate = Gate::q2(kind, qa, qb).unwrap();
                check_gate_list(
                    &[gate],
                    n,
                    (ki * 1000 + qa * 10 + qb) as u64,
                    &format!("{kind:?} ({qa},{qb})"),
                );
            }
        }
    }
}

/// Apply `gates` per-gate and fused-batched (all tile/worker shapes) and
/// compare amplitudes.
fn check_gate_list(gates: &[Gate], n: usize, seed: u64, tag: &str) {
    let (re0, im0) = random_planes(n, seed);
    let mut want = (re0.clone(), im0.clone());
    for g in gates {
        apply_gate(&mut want.0, &mut want.1, g);
    }
    for max_k in [2usize, 3] {
        let ops: Vec<FusedGate> = fuse_gates(gates, max_k);
        for tile_bits in [1usize, 3, n, 24] {
            for workers in [1usize, 2, 4] {
                let mut got = (re0.clone(), im0.clone());
                apply_stage(&mut got.0, &mut got.1, &ops, tile_bits, workers);
                assert_close(
                    &got.0,
                    &got.1,
                    &want.0,
                    &want.1,
                    &format!("{tag} k={max_k} tile={tile_bits} workers={workers}"),
                );
            }
        }
    }
}

/// Random circuits over the full vocabulary: fused-batched == per-gate.
#[test]
fn property_random_circuits_fused_equals_per_gate() {
    let mut seed_rng = SplitMix64::new(0xF05E);
    let kinds_1q = all_1q_kinds();
    let kinds_2q = all_2q_kinds();
    for case in 0..20 {
        let seed = seed_rng.next_u64();
        let mut rng = SplitMix64::new(seed);
        let n = 4 + (rng.next_below(5) as usize); // 4..8 qubits
        let gates = 10 + (rng.next_below(70) as usize);
        let mut c = Circuit::new(n, "rand");
        for _ in 0..gates {
            let q = rng.next_below(n as u64) as usize;
            if rng.next_below(2) == 0 {
                let kind = kinds_1q[rng.next_below(kinds_1q.len() as u64) as usize];
                c.push(Gate::q1(kind, q).unwrap()).unwrap();
            } else {
                let mut p = rng.next_below(n as u64) as usize;
                if p == q {
                    p = (p + 1) % n;
                }
                let kind = kinds_2q[rng.next_below(kinds_2q.len() as u64) as usize];
                c.push(Gate::q2(kind, q, p).unwrap()).unwrap();
            }
        }
        check_gate_list(&c.gates, n, seed ^ 0xA5A5, &format!("case {case} seed {seed:#x}"));
    }
}

/// Fusion bookkeeping on random circuits: sources conserved, sweep count
/// never exceeds op count, and a deep same-qubit run beats its gate count.
#[test]
fn sweep_counts_shrink_on_deep_runs() {
    use bmqsim::gates::fused::stage_sweeps;
    let mut c = Circuit::new(10, "deep");
    for i in 0..120 {
        match i % 3 {
            0 => c.h(4),
            1 => c.t(4),
            _ => c.cx(4, 5),
        };
    }
    let ops = fuse_gates(&c.gates, 3);
    assert_eq!(ops.len(), 1, "same-support run must fuse to one op");
    let sweeps = stage_sweeps(&ops, 10, 15);
    assert_eq!(sweeps, 1);
    assert!((sweeps as usize) < c.gates.len(), "sweeps {} >= gates {}", sweeps, c.gates.len());
}
