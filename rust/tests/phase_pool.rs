//! Persistent phase-worker runtime tests (ISSUE 5 acceptance):
//!
//! * pool reuse across stages — the thread-spawn counter is `3 × workers`
//!   after a multi-stage run, NOT `3 × workers × stages`;
//! * the AIMD ring-depth trajectory under synthetic stall imbalance;
//! * panic-in-phase teardown through the persistent pool;
//! * auto-enable heuristic boundary cases (tiny groups → off,
//!   codec-heavy groups → on).

use bmqsim::circuit::generators;
use bmqsim::pipeline::{
    PhasePool, PipelineConfig, RingDepthController, RING_AIMD_STALL_STEP_NS, RING_DEPTH_MAX,
};
use bmqsim::sim::{auto_overlap, BmqSim, OverlapMode, SimConfig, OVERLAP_AUTO_MIN_CONCEAL_NS};
use bmqsim::types::Error;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

// ---------------------------------------------------------------------------
// Pool reuse across stages
// ---------------------------------------------------------------------------

#[test]
fn engine_spawns_phase_threads_once_per_run_not_per_stage() {
    // Multi-stage circuit, overlap pinned on: the persistent pool must
    // report one thread set for the whole run and one handoff per stage.
    let c = generators::qft(10);
    let mut config = SimConfig { block_qubits: 5, inner_size: 2, ..SimConfig::default() };
    config.pipeline = PipelineConfig::new(1, 2);
    config.overlap = OverlapMode::On;
    config.pipeline_depth = 2;
    config.pipeline_depth_auto = false;
    let r = BmqSim::new(config).run(&c, false).unwrap();
    assert!(r.stages > 1, "need a multi-stage circuit to prove reuse");
    assert_eq!(
        r.metrics.phase_threads_spawned, 6,
        "3 threads x 2 workers, spawned once for the run"
    );
    assert_eq!(
        r.metrics.pool_stage_handoffs, r.stages as u64,
        "each stage is a descriptor handoff, not a spawn/join cycle"
    );
    // The old scoped driver's cost model for comparison: it would have
    // spawned 3 * workers * stages threads.
    assert!(r.metrics.phase_threads_spawned < 3 * 2 * r.stages as u64);
}

#[test]
fn pool_processes_every_item_across_many_stages_on_the_same_threads() {
    let mut pool = PhasePool::new(PipelineConfig::new(1, 4), 3);
    let stages = 5usize;
    for stage in 0..stages {
        let n = 64;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let order: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        pool.run_stage(
            n,
            2,
            &|ctx, i| {
                ctx.scratch.ensure_planes(8);
                ctx.scratch.re[0] = (stage * 1000 + i) as f64;
                Ok(())
            },
            &|ctx, i| {
                assert_eq!(ctx.scratch.re[0], (stage * 1000 + i) as f64);
                hits[i].fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
            &|_ctx, i| {
                order.lock().unwrap().push(i);
                Ok(())
            },
        )
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "stage {stage}");
        assert_eq!(order.into_inner().unwrap().len(), n);
    }
    assert_eq!(pool.threads_spawned(), 12, "4 workers x 3 phases, once");
    assert_eq!(pool.stats().stage_handoffs.load(Ordering::Relaxed), stages as u64);
    // Ring arenas persisted: each warmed slot grew at most once, ever.
    assert!(pool.total_plane_grows() <= (4 * 2) as u64);
    assert!(pool.total_plane_grows() >= 1);
}

// ---------------------------------------------------------------------------
// AIMD ring-depth trajectory
// ---------------------------------------------------------------------------

#[test]
fn ring_depth_grows_under_stall_imbalance_and_shrinks_when_idle() {
    let mut ctl = RingDepthController::new(2, true, RING_DEPTH_MAX);
    let mut stall = 0u64;
    // Stage 1 primes the snapshot — depth must not move on no-history.
    assert_eq!(ctl.stage_depth(stall), 2);
    // Sustained phase imbalance: additive increase, one slot per stage,
    // capped at RING_DEPTH_MAX.
    let mut seen = vec![];
    for _ in 0..10 {
        stall += 2 * RING_AIMD_STALL_STEP_NS;
        seen.push(ctl.stage_depth(stall));
    }
    assert_eq!(seen[..6], [3, 4, 5, 6, 7, 8]);
    assert!(seen.iter().all(|&d| d <= RING_DEPTH_MAX));
    assert_eq!(ctl.current(), RING_DEPTH_MAX);
    assert_eq!(ctl.peak(), RING_DEPTH_MAX);
    // Imbalance gone: multiplicative decrease back toward the floor.
    assert_eq!(ctl.stage_depth(stall), 4);
    assert_eq!(ctl.stage_depth(stall), 2);
    assert_eq!(ctl.stage_depth(stall), 2, "floor holds at depth 2");
    assert!(ctl.adjustments() >= 8);
}

#[test]
fn pinned_depth_ignores_stall_history() {
    let mut ctl = RingDepthController::new(3, false, RING_DEPTH_MAX);
    for stall in [0u64, 10_000_000, 10_000_000, 500_000_000] {
        assert_eq!(ctl.stage_depth(stall), 3);
    }
    assert_eq!(ctl.adjustments(), 0);
    assert_eq!(ctl.peak(), 3);
}

#[test]
fn adaptive_depth_lands_in_band_through_the_engine() {
    let c = generators::qft(11);
    let mut config = SimConfig { block_qubits: 5, inner_size: 2, ..SimConfig::default() };
    config.overlap = OverlapMode::On;
    config.pipeline_depth_auto = true; // CLI default: --pipeline-depth omitted
    let r = BmqSim::new(config).run(&c, false).unwrap();
    let d = r.metrics.ring_depth_final;
    assert!(
        (1..=RING_DEPTH_MAX as u64).contains(&d),
        "adaptive ring depth {d} outside its band"
    );
    assert!(r.metrics.ring_depth_peak >= d.min(2));
}

// ---------------------------------------------------------------------------
// Panic-in-phase teardown through the persistent pool
// ---------------------------------------------------------------------------

#[test]
fn phase_panic_tears_down_through_the_persistent_pool() {
    for phase in 0..3usize {
        let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = pool.run_stage(
                32,
                2,
                &move |_c, i| {
                    assert!(!(phase == 0 && i == 7), "kaboom-decode");
                    Ok(())
                },
                &move |_c, i| {
                    assert!(!(phase == 1 && i == 7), "kaboom-apply");
                    Ok(())
                },
                &move |_c, i| {
                    assert!(!(phase == 2 && i == 7), "kaboom-encode");
                    Ok(())
                },
            );
        }));
        assert!(
            caught.is_err(),
            "phase {phase} panic was swallowed instead of re-raised by run_stage"
        );
        // Teardown joins the still-alive phase threads without hanging.
        drop(pool);
    }
}

#[test]
fn phase_error_aborts_stage_but_pool_remains_usable() {
    let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 2);
    let r = pool.run_stage(
        200,
        2,
        &|_c, i| {
            if i == 11 {
                Err(Error::Codec("synthetic decode failure".into()))
            } else {
                Ok(())
            }
        },
        &|_c, _i| Ok(()),
        &|_c, _i| Ok(()),
    );
    assert!(matches!(r, Err(Error::Codec(_))));
    // Same pool, next stage: clean run, same thread set.
    let done = AtomicUsize::new(0);
    pool.run_stage(
        50,
        2,
        &|_c, _i| Ok(()),
        &|_c, _i| Ok(()),
        &|_c, _i| {
            done.fetch_add(1, Ordering::Relaxed);
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(done.load(Ordering::Relaxed), 50);
    assert_eq!(pool.threads_spawned(), 6);
}

// ---------------------------------------------------------------------------
// Overlap auto-enable boundary cases
// ---------------------------------------------------------------------------

#[test]
fn auto_enable_declines_tiny_groups_and_engages_codec_heavy_ones() {
    // Tiny groups: even an expensive codec cannot amortize the handshake.
    assert!(!auto_overlap(64, 32, 20.0));
    assert!(!auto_overlap(256, 32, 10.0));
    // Codec-heavy big groups: engage.
    assert!(auto_overlap(1 << 14, 8, 10.0));
    assert!(auto_overlap(1 << 16, 4, 3.0));
    // One group = nothing to pipeline, regardless of cost.
    assert!(!auto_overlap(1 << 20, 1, 1_000.0));
    // Free codec (raw passthrough on a fast machine): decline.
    assert!(!auto_overlap(1 << 16, 32, 0.0));
    // Exact threshold boundary: >= engages.
    let glen = 1usize << 12;
    let exactly = OVERLAP_AUTO_MIN_CONCEAL_NS / (4.0 * glen as f64);
    assert!(auto_overlap(glen, 2, exactly));
    assert!(!auto_overlap(glen, 2, exactly * 0.99));
}

#[test]
fn auto_mode_decides_each_stage_and_pinned_modes_do_not() {
    let c = generators::build("qaoa", 10, 5).unwrap();
    let mk = |mode: OverlapMode| {
        let mut config =
            SimConfig { block_qubits: 5, inner_size: 2, ..SimConfig::default() };
        config.overlap = mode;
        config
    };
    let auto_r = BmqSim::new(mk(OverlapMode::Auto)).run(&c, false).unwrap();
    assert_eq!(
        auto_r.metrics.auto_overlap_on + auto_r.metrics.auto_overlap_off,
        auto_r.stages as u64
    );
    for mode in [OverlapMode::On, OverlapMode::Off] {
        let r = BmqSim::new(mk(mode)).run(&c, false).unwrap();
        assert_eq!(r.metrics.auto_overlap_on + r.metrics.auto_overlap_off, 0);
    }
}
