//! Crash-consistency integration tests for the checkpoint/restore
//! subsystem (ISSUE 9): resume parity (a killed-and-resumed run must be
//! byte-identical to an uninterrupted one), corruption property tests
//! (any damaged byte fails typed, never silently-wrong), retention
//! fallback, typed process exit codes, and subprocess kill-resume chaos
//! driven both by scripted `kill@manifest`/`kill@checkpoint` fault plans
//! (deterministic placement) and a real mid-run SIGKILL.

use bmqsim::circuit::generators;
use bmqsim::compress::Codec;
use bmqsim::memory::checkpoint::{self, CheckpointMeta, BLOCKS_NAME, MANIFEST_NAME};
use bmqsim::memory::{xxh64, BlockPayload};
use bmqsim::sim::{BmqSim, OverlapMode, Sc19Sim, SimConfig};
use bmqsim::state::BlockLayout;
use bmqsim::types::Error;
use std::path::{Path, PathBuf};
use std::process::Command;

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bmq-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// xxh64 chain over every terminal compressed payload in block order —
/// the same digest `bmqsim run` prints, computed in-process.
fn store_digest(store: &bmqsim::memory::BlockStore, layout: &BlockLayout) -> u64 {
    let mut d = 0u64;
    for id in 0..layout.num_blocks() {
        let p = store.get(id).unwrap();
        d = xxh64(&p.re, d);
        d = xxh64(&p.im, d);
    }
    d
}

fn base_cfg() -> SimConfig {
    SimConfig { block_qubits: 5, inner_size: 2, ..SimConfig::default() }
}

// ---------------------------------------------------------------------
// In-process resume parity across {sync, async spill} x {cross-stage
// on, off} — the acceptance matrix.
// ---------------------------------------------------------------------

#[test]
fn resume_from_intermediate_checkpoint_is_byte_identical() {
    let c = generators::build("qaoa", 10, 7).unwrap();
    let (want_r, want_store, want_layout) =
        BmqSim::new(base_cfg()).run_with_store(&c, false).unwrap();
    let want = store_digest(&want_store, &want_layout);
    assert!(want_r.stages >= 3, "need intermediate stages, got {}", want_r.stages);
    drop((want_store, want_layout));

    for (sync_spill, cross) in
        [(false, false), (false, true), (true, false), (true, true)]
    {
        let tag = format!("parity-s{}-x{}", sync_spill as u8, cross as u8);
        let root = tdir(&tag);
        let mut cfg = base_cfg();
        cfg.checkpoint_dir = Some(root.clone());
        cfg.checkpoint_every = 1;
        cfg.checkpoint_keep = 64; // retain everything: we resume from the oldest
        cfg.cross_stage = if cross { OverlapMode::On } else { OverlapMode::Off };
        cfg.memory_budget = Some(10 * 1024);
        cfg.spill_dir = Some(root.join("spill"));
        cfg.sync_spill = sync_spill;
        let (r, store, layout) = BmqSim::new(cfg).run_with_store(&c, false).unwrap();
        assert!(r.metrics.checkpoints >= 2, "{tag}: only {} checkpoints", r.metrics.checkpoints);
        assert!(r.metrics.checkpoint_bytes > 0);
        assert_eq!(
            store_digest(&store, &layout),
            want,
            "{tag}: checkpointing perturbed the terminal state"
        );
        drop((store, layout));

        // Keep only the OLDEST retained checkpoint (a genuinely
        // intermediate cursor), as if the run was killed right after it.
        let mut ckpts = checkpoint::list_checkpoints(&root); // newest-first
        assert!(ckpts.len() >= 2, "{tag}: {} checkpoints on disk", ckpts.len());
        let (oldest_cursor, _) = *ckpts.last().unwrap();
        assert!(oldest_cursor < want_r.stages, "{tag}: oldest checkpoint is terminal");
        ckpts.truncate(ckpts.len() - 1);
        for (_, dir) in ckpts {
            std::fs::remove_dir_all(dir).unwrap();
        }

        // Resume under a *different* execution shape (no spill budget,
        // default cross-stage): the fingerprint only pins semantic knobs.
        let mut rcfg = base_cfg();
        rcfg.resume_from = Some(root.clone());
        let (rr, rstore, rlayout) = BmqSim::new(rcfg).run_with_store(&c, false).unwrap();
        assert_eq!(
            store_digest(&rstore, &rlayout),
            want,
            "{tag}: resumed terminal state diverged"
        );
        assert_eq!(rr.metrics.resumes, 1, "{tag}");
        // Carried counters: the resumed run reports the WHOLE logical
        // run's work, not just the post-resume tail.
        assert_eq!(rr.metrics.gates_applied, want_r.metrics.gates_applied, "{tag}");
        assert_eq!(rr.metrics.groups_processed, want_r.metrics.groups_processed, "{tag}");
    }
}

#[test]
fn sc19_resume_matches_uninterrupted_run() {
    let c = generators::build("qft", 8, 42).unwrap();
    let mut cfg = SimConfig { block_qubits: 4, ..SimConfig::default() };
    cfg.codec = Codec::raw();
    let want = Sc19Sim::new(cfg.clone(), 1).run(&c, true).unwrap();

    let root = tdir("sc19");
    let mut ckpt = cfg.clone();
    ckpt.checkpoint_dir = Some(root.clone());
    ckpt.checkpoint_every = 3; // gate-granularity cursor
    ckpt.checkpoint_keep = 64;
    let r = Sc19Sim::new(ckpt, 1).run(&c, false).unwrap();
    assert!(r.metrics.checkpoints >= 2);

    let mut ckpts = checkpoint::list_checkpoints(&root);
    let (oldest_cursor, _) = *ckpts.last().unwrap();
    assert!(oldest_cursor < c.len());
    ckpts.truncate(ckpts.len() - 1);
    for (_, dir) in ckpts {
        std::fs::remove_dir_all(dir).unwrap();
    }

    let mut rcfg = cfg;
    rcfg.resume_from = Some(root);
    let rr = Sc19Sim::new(rcfg, 1).run(&c, true).unwrap();
    let f = rr.state.as_ref().unwrap().fidelity(want.state.as_ref().unwrap());
    assert!(f > 1.0 - 1e-12, "sc19 resume diverged: {f}");
    assert_eq!(rr.metrics.resumes, 1);
    assert_eq!(rr.metrics.gates_applied, c.len() as u64);
}

// ---------------------------------------------------------------------
// Typed rejection: wrong config, wrong engine, wrong circuit.
// ---------------------------------------------------------------------

#[test]
fn resume_rejects_mismatched_config_engine_and_circuit() {
    let c = generators::build("qft", 8, 42).unwrap();
    let root = tdir("mismatch");
    let mut cfg = SimConfig { block_qubits: 4, ..SimConfig::default() };
    cfg.checkpoint_dir = Some(root.clone());
    BmqSim::new(cfg.clone()).run(&c, false).unwrap();

    let resume = |mutate: &dyn Fn(&mut SimConfig)| {
        let mut r = SimConfig { block_qubits: 4, ..SimConfig::default() };
        r.resume_from = Some(root.clone());
        mutate(&mut r);
        BmqSim::new(r).run(&c, false)
    };

    // Semantic config drift -> fingerprint mismatch, typed.
    for mutate in [
        (&|r: &mut SimConfig| r.codec = Codec::pointwise(1e-5)) as &dyn Fn(&mut SimConfig),
        &|r: &mut SimConfig| r.block_qubits = 3,
        &|r: &mut SimConfig| r.fusion = false,
    ] {
        match resume(mutate) {
            Err(Error::Checkpoint(m)) => {
                assert!(m.contains("fingerprint"), "unexpected message: {m}")
            }
            other => panic!("expected Error::Checkpoint, got {other:?}"),
        }
    }

    // Different circuit -> fingerprint mismatch too.
    let c2 = generators::build("qft", 8, 43).unwrap();
    let mut r2 = SimConfig { block_qubits: 4, ..SimConfig::default() };
    r2.resume_from = Some(root.clone());
    assert!(matches!(BmqSim::new(r2).run(&c2, false), Err(Error::Checkpoint(_))));

    // Wrong engine -> typed engine mismatch (before the fingerprint).
    let mut sc = SimConfig { block_qubits: 4, ..SimConfig::default() };
    sc.resume_from = Some(root.clone());
    match Sc19Sim::new(sc, 1).run(&c, false) {
        Err(Error::Checkpoint(m)) => assert!(m.contains("engine"), "unexpected message: {m}"),
        other => panic!("expected Error::Checkpoint, got {other:?}"),
    }

    // Empty/absent root -> typed, not a panic.
    let mut none = SimConfig { block_qubits: 4, ..SimConfig::default() };
    none.resume_from = Some(root.join("does-not-exist"));
    assert!(matches!(BmqSim::new(none).run(&c, false), Err(Error::Checkpoint(_))));
}

// ---------------------------------------------------------------------
// Corruption property tests: every damaged byte is load-bearing.
// ---------------------------------------------------------------------

fn demo_blocks() -> Vec<(usize, BlockPayload)> {
    (0..4)
        .map(|i| {
            (i, BlockPayload {
                re: (0..50).map(|b| (b * 7 + i * 13) as u8).collect(),
                im: vec![0x5A ^ i as u8; 37],
            })
        })
        .collect()
}

fn demo_meta(cursor: usize) -> CheckpointMeta<'static> {
    CheckpointMeta {
        engine: "bmqsim",
        stage_cursor: cursor,
        total_stages: 8,
        fingerprint: 0xFEED_FACE_CAFE_F00D,
        counters: &[("gates_applied", 9), ("compressions", 4)],
    }
}

#[test]
fn every_manifest_truncation_and_frame_flip_fails_typed() {
    let root = tdir("damage");
    checkpoint::write_checkpoint(&root, &demo_meta(4), &demo_blocks(), 4).unwrap();
    let dir = root.join("ckpt-000004");

    // The intact checkpoint loads and round-trips the payloads.
    let loaded = checkpoint::load_checkpoint(&dir).unwrap();
    assert_eq!(loaded.blocks, demo_blocks());
    assert_eq!(loaded.manifest.stage_cursor, 4);

    // Every proper prefix of the manifest (a torn write at any offset)
    // must fail with a typed error — never panic, never load.
    let manifest = std::fs::read(dir.join(MANIFEST_NAME)).unwrap();
    for len in 0..manifest.len() {
        std::fs::write(dir.join(MANIFEST_NAME), &manifest[..len]).unwrap();
        match checkpoint::load_checkpoint(&dir) {
            Err(Error::Checkpoint(_)) | Err(Error::Corruption(_)) => {}
            other => panic!("truncation at {len}: expected typed error, got {other:?}"),
        }
    }
    std::fs::write(dir.join(MANIFEST_NAME), &manifest).unwrap();

    // Every flipped bit position (sampled bytewise) of the blocks file
    // must fail typed: the manifest's per-frame checksum or the frame's
    // own payload checksum catches it.
    let blocks = std::fs::read(dir.join(BLOCKS_NAME)).unwrap();
    for i in 0..blocks.len() {
        let mut bad = blocks.clone();
        bad[i] ^= 0x01;
        std::fs::write(dir.join(BLOCKS_NAME), &bad).unwrap();
        match checkpoint::load_checkpoint(&dir) {
            Err(Error::Checkpoint(_)) | Err(Error::Corruption(_)) => {}
            other => panic!("bit flip at byte {i}: expected typed error, got {other:?}"),
        }
    }
    std::fs::write(dir.join(BLOCKS_NAME), &blocks).unwrap();

    // Truncating the blocks file fails typed as well.
    for len in [0usize, 1, blocks.len() / 2, blocks.len() - 1] {
        std::fs::write(dir.join(BLOCKS_NAME), &blocks[..len]).unwrap();
        match checkpoint::load_checkpoint(&dir) {
            Err(Error::Checkpoint(_)) | Err(Error::Corruption(_)) => {}
            other => panic!("blocks truncated to {len}: expected typed error, got {other:?}"),
        }
    }
    std::fs::write(dir.join(BLOCKS_NAME), &blocks).unwrap();
    checkpoint::load_checkpoint(&dir).unwrap();
}

#[test]
fn corrupt_newest_checkpoint_falls_back_to_previous_retained() {
    let root = tdir("fallback");
    let meta4 = demo_meta(4);
    let meta6 = demo_meta(6);
    checkpoint::write_checkpoint(&root, &meta4, &demo_blocks(), 4).unwrap();
    checkpoint::write_checkpoint(&root, &meta6, &demo_blocks(), 4).unwrap();

    // Newest wins while intact.
    let l = checkpoint::load_latest(&root, "bmqsim", meta6.fingerprint).unwrap();
    assert_eq!(l.manifest.stage_cursor, 6);

    // Tear the newest manifest: the previous retained checkpoint still
    // resumes (the `keep >= 2` default exists exactly for this).
    let newest = root.join("ckpt-000006").join(MANIFEST_NAME);
    let bytes = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
    let l = checkpoint::load_latest(&root, "bmqsim", meta4.fingerprint).unwrap();
    assert_eq!(l.manifest.stage_cursor, 4, "did not fall back");
    assert_eq!(l.blocks, demo_blocks());

    // Both torn -> typed error, never a panic.
    let older = root.join("ckpt-000004").join(MANIFEST_NAME);
    let b2 = std::fs::read(&older).unwrap();
    std::fs::write(&older, &b2[..b2.len() / 3]).unwrap();
    assert!(matches!(
        checkpoint::load_latest(&root, "bmqsim", meta4.fingerprint),
        Err(Error::Checkpoint(_)) | Err(Error::Corruption(_))
    ));
}

// ---------------------------------------------------------------------
// Subprocess chaos: scripted kills at exact I/O boundaries, a real
// SIGKILL, and the typed process exit codes.
// ---------------------------------------------------------------------

fn bmqsim_exe() -> &'static str {
    env!("CARGO_BIN_EXE_bmqsim")
}

fn run_cli(args: &[&str]) -> std::process::Output {
    Command::new(bmqsim_exe()).args(args).output().expect("spawn bmqsim")
}

fn state_digest(out: &std::process::Output) -> String {
    let stdout = String::from_utf8_lossy(&out.stdout);
    stdout
        .lines()
        .find(|l| l.starts_with("state digest"))
        .and_then(|l| l.split_whitespace().last())
        .unwrap_or_else(|| panic!("no state digest line in:\n{stdout}"))
        .to_string()
}

fn committed_checkpoints(root: &Path) -> usize {
    checkpoint::list_checkpoints(root)
        .into_iter()
        .filter(|(_, d)| d.join(MANIFEST_NAME).is_file())
        .count()
}

#[test]
fn scripted_kill_then_resume_matches_uninterrupted_digest() {
    let circuit: &[&str] =
        &["run", "--algo", "qaoa", "--qubits", "12", "--block-qubits", "5", "--seed", "7"];
    // The acceptance matrix rides on CLI flags: {sync, async spill} x
    // {cross-stage on, off}. (The tight-budget spill interaction itself
    // is pinned in-process above; here the flags prove the full CLI
    // paths stay crash-consistent.)
    let rows: &[&[&str]] = &[
        &["--no-cross-stage"],
        &["--cross-stage"],
        &["--sync-spill", "--memory-budget", "1", "--no-cross-stage"],
        &["--memory-budget", "1", "--cross-stage"],
    ];
    for (i, row) in rows.iter().enumerate() {
        let root = tdir(&format!("scripted-{i}"));
        let roots = root.to_str().unwrap().to_string();
        let spill = root.join("spill");
        let spills = spill.to_str().unwrap().to_string();
        let mut base: Vec<&str> = circuit.to_vec();
        base.extend_from_slice(row);
        if row.contains(&"--memory-budget") {
            base.extend_from_slice(&["--spill-dir", &spills]);
        }

        let clean = run_cli(&base);
        assert!(clean.status.success(), "row {i}: clean run failed: {:?}", clean);
        let want = state_digest(&clean);

        // `kill@manifest:3` = the 2nd checkpoint's temp-manifest write
        // (2 manifest ops per checkpoint): the process aborts with
        // checkpoint 1 fully committed and checkpoint 2 absent.
        let mut killed: Vec<&str> = base.clone();
        killed.extend_from_slice(&[
            "--checkpoint-dir", &roots,
            "--checkpoint-every", "1",
            "--fault-plan", "kill@manifest:3",
        ]);
        let out = run_cli(&killed);
        assert!(!out.status.success(), "row {i}: scripted kill did not fire");
        assert_eq!(committed_checkpoints(&root), 1, "row {i}");

        // Resume (keep checkpointing on: the resumed run re-checkpoints
        // and must still land on the same terminal bytes).
        let mut resumed: Vec<&str> = base.clone();
        resumed.extend_from_slice(&[
            "--resume", &roots,
            "--checkpoint-dir", &roots,
            "--checkpoint-every", "1",
        ]);
        let out = run_cli(&resumed);
        assert!(
            out.status.success(),
            "row {i}: resume failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert_eq!(state_digest(&out), want, "row {i}: digest diverged after kill+resume");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("resumes"), "row {i}: no checkpoint metrics line:\n{stdout}");
    }
}

#[test]
fn kill_mid_rename_resumes_from_previous_intact_checkpoint() {
    let root = tdir("mid-rename");
    let roots = root.to_str().unwrap().to_string();
    let base: &[&str] =
        &["run", "--algo", "qft", "--qubits", "10", "--block-qubits", "5", "--seed", "3"];
    let clean = run_cli(base);
    assert!(clean.status.success());
    let want = state_digest(&clean);

    // `kill@manifest:4` = the 2nd checkpoint's atomic rename: its temp
    // manifest exists but was never committed. The resume must treat the
    // directory as torn and fall back to checkpoint 1.
    let mut killed: Vec<&str> = base.to_vec();
    killed.extend_from_slice(&[
        "--checkpoint-dir", &roots,
        "--checkpoint-every", "1",
        "--fault-plan", "kill@manifest:4",
    ]);
    let out = run_cli(&killed);
    assert!(!out.status.success(), "scripted rename kill did not fire");
    assert_eq!(committed_checkpoints(&root), 1);

    let mut resumed: Vec<&str> = base.to_vec();
    resumed.extend_from_slice(&["--resume", &roots]);
    let out = run_cli(&resumed);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(state_digest(&out), want);
}

#[test]
fn kill_mid_frame_write_leaves_no_commit_and_exits_4_on_resume() {
    let root = tdir("mid-frame");
    let roots = root.to_str().unwrap().to_string();
    let base: &[&str] = &["run", "--algo", "ghz_state", "--qubits", "8", "--block-qubits", "4"];

    // `kill@checkpoint:1` aborts during the very first block frame of
    // the very first checkpoint: nothing was ever committed.
    let mut killed: Vec<&str> = base.to_vec();
    killed.extend_from_slice(&[
        "--checkpoint-dir", &roots,
        "--checkpoint-every", "1",
        "--fault-plan", "kill@checkpoint:1",
    ]);
    let out = run_cli(&killed);
    assert!(!out.status.success());
    assert_eq!(committed_checkpoints(&root), 0);

    // Resuming from a root with no committed checkpoint is the
    // checkpoint exit class (4), not a crash or a silent fresh start.
    let mut resumed: Vec<&str> = base.to_vec();
    resumed.extend_from_slice(&["--resume", &roots]);
    let out = run_cli(&resumed);
    assert_eq!(out.status.code(), Some(4), "{}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn real_sigkill_mid_run_then_resume_matches() {
    let root = tdir("sigkill");
    let roots = root.to_str().unwrap().to_string();
    let base: &[&str] =
        &["run", "--algo", "qaoa", "--qubits", "13", "--block-qubits", "6", "--seed", "11"];
    let clean = run_cli(base);
    assert!(clean.status.success());
    let want = state_digest(&clean);

    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--checkpoint-dir", &roots, "--checkpoint-every", "1"]);
    let mut child = Command::new(bmqsim_exe())
        .args(&args)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn bmqsim");
    // Kill as soon as the first checkpoint commits. If the run outpaces
    // the poll and finishes first, the resume below degenerates to
    // "resume from the terminal snapshot" — still digest-identical, so
    // the test is chaos when it can be and never flaky.
    let t0 = std::time::Instant::now();
    loop {
        if committed_checkpoints(&root) >= 1 {
            let _ = child.kill();
            break;
        }
        if child.try_wait().expect("try_wait").is_some() {
            break;
        }
        assert!(t0.elapsed().as_secs() < 60, "no checkpoint appeared within 60s");
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    let _ = child.wait();
    assert!(committed_checkpoints(&root) >= 1);

    let mut resumed: Vec<&str> = base.to_vec();
    resumed.extend_from_slice(&["--resume", &roots]);
    let out = run_cli(&resumed);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(state_digest(&out), want, "SIGKILL + resume diverged");
}

#[test]
fn exit_codes_reflect_the_error_taxonomy() {
    // Usage / config problems -> 2.
    let out = run_cli(&["run", "--algo", "no-such-algo", "--qubits", "8"]);
    assert_eq!(out.status.code(), Some(2), "{}", String::from_utf8_lossy(&out.stderr));
    let out = run_cli(&["run", "--qubits", "8"]);
    assert_eq!(out.status.code(), Some(2));
    let out = run_cli(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));

    // Checkpoint problems -> 4.
    let missing = std::env::temp_dir().join("bmq-ckpt-no-such-root");
    let _ = std::fs::remove_dir_all(&missing);
    let out = run_cli(&[
        "run", "--algo", "qft", "--qubits", "6", "--block-qubits", "3",
        "--resume", missing.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(4), "{}", String::from_utf8_lossy(&out.stderr));

    // Healthy run -> 0.
    let out = run_cli(&["run", "--algo", "qft", "--qubits", "6", "--block-qubits", "3"]);
    assert_eq!(out.status.code(), Some(0), "{}", String::from_utf8_lossy(&out.stderr));
}
