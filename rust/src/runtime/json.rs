//! Minimal JSON parser — just enough to read `artifacts/manifest.json`.
//! (The build environment vendors no serde; see DESIGN.md substitutions.)

use crate::types::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(Error::Artifact(format!("json: trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Artifact(format!("json: {msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && (self.b[self.pos] as char).is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
            "format": "hlo-text",
            "chunks": {"m_1q": 16384, "m_2q": 8192},
            "error_bounds": [0.01, 0.001, 1e-4],
            "modules": {
                "gate1q_f64": {"kernel": "gate1q", "dtype": "f64", "m": 16384, "k": 2, "file": "gate1q_f64.hlo.txt", "outputs": 2}
            }
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        assert_eq!(j.get("chunks").unwrap().get("m_1q").unwrap().as_usize().unwrap(), 16384);
        match j.get("error_bounds").unwrap() {
            Json::Arr(a) => {
                assert_eq!(a.len(), 3);
                assert!((a[2].as_f64().unwrap() - 1e-4).abs() < 1e-18);
            }
            _ => panic!(),
        }
        let m = j.get("modules").unwrap().get("gate1q_f64").unwrap();
        assert_eq!(m.get("k").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3],[]]").unwrap();
        match j {
            Json::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }
}
