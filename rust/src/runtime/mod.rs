//! PJRT runtime: load the AOT'd JAX/Pallas HLO artifacts and execute them
//! from the rust hot path — the L3↔L1/L2 bridge of the three-layer
//! architecture. Python never runs here; only its compiled output does.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (neither `Send`
//! nor `Sync`), so all PJRT state lives on one dedicated **service thread**
//! (created per [`XlaApplier`]); callers talk to it over channels. This
//! serializes executable launches — semantically the single GPU queue of
//! the paper's per-device stream — while the engines' pipeline still
//! overlaps (de)compression on other workers.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): see
//! DESIGN.md and /opt/xla-example/README.md for why serialized protos from
//! jax >= 0.5 are rejected by xla_extension 0.5.1.
//!
//! **Build gating:** the `xla` crate is a vendored native dependency that
//! the default environment does not ship, so the PJRT path compiles only
//! under the `xla` cargo feature. Without it, [`XlaApplier`] is a stub
//! whose constructor fails with a clear message — the manifest/JSON layer
//! stays available either way.

mod json;
mod manifest;

pub use json::Json;
pub use manifest::{Manifest, ModuleInfo};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::XlaApplier;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::circuit::Gate;
    use crate::sim::GateApplier;
    use crate::types::{Error, Result};
    use std::path::PathBuf;

    const MSG: &str =
        "built without the `xla` feature; rebuild with `--features xla` and a vendored xla crate";

    /// Stub [`GateApplier`] compiled when the `xla` feature is off. The
    /// constructor always fails, so the methods are unreachable.
    pub struct XlaApplier {
        _private: (),
    }

    impl XlaApplier {
        pub fn new(_artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            Err(Error::Xla(MSG.into()))
        }

        pub fn quantize(&self, _x: &[f64], _error_bound: f64) -> Result<(Vec<i32>, Vec<i32>)> {
            Err(Error::Xla(MSG.into()))
        }

        pub fn dequantize(
            &self,
            _codes: &[i32],
            _signs: &[i32],
            _error_bound: f64,
        ) -> Result<Vec<f64>> {
            Err(Error::Xla(MSG.into()))
        }
    }

    impl GateApplier for XlaApplier {
        fn apply(
            &self,
            _re: &mut [f64],
            _im: &mut [f64],
            _gate: &Gate,
            _bits: &[usize],
        ) -> Result<()> {
            Err(Error::Xla(MSG.into()))
        }

        fn name(&self) -> &'static str {
            "xla-stub"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaApplier;
