//! PJRT service thread + [`XlaApplier`] — the `xla`-crate-backed implementation.
//! Compiled only with the `xla` feature; see `runtime::stub` for the default.

use super::Manifest;
use crate::circuit::Gate;
use crate::sim::GateApplier;
use crate::types::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

// ---------------------------------------------------------------------
// Service-thread jobs
// ---------------------------------------------------------------------

enum Job {
    /// Batched K x K complex mat-vec over pair-major planes.
    Gate {
        arity: usize,
        diagonal: bool,
        xr: Vec<f64>,
        xi: Vec<f64>,
        ur: Vec<f64>,
        ui: Vec<f64>,
        rows: usize,
        k: usize,
        reply: mpsc::Sender<Result<(Vec<f64>, Vec<f64>)>>,
    },
    /// Point-wise quantize via the Pallas quantizer artifact.
    Quantize {
        x: Vec<f64>,
        error_bound: f64,
        reply: mpsc::Sender<Result<(Vec<i32>, Vec<i32>)>>,
    },
    /// Inverse of `Quantize`.
    Dequantize {
        codes: Vec<i32>,
        signs: Vec<i32>,
        error_bound: f64,
        reply: mpsc::Sender<Result<Vec<f64>>>,
    },
    Shutdown,
}

// ---------------------------------------------------------------------
// Service thread internals (all PJRT state lives here)
// ---------------------------------------------------------------------

struct Service {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Service {
    fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Service { client, manifest, execs: HashMap::new() })
    }

    fn exec(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(name) {
            let info = self
                .manifest
                .modules
                .get(name)
                .ok_or_else(|| Error::Artifact(format!("no module {name} in manifest")))?;
            let proto = xla::HloModuleProto::from_text_file(
                info.file
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.execs.insert(name.to_string(), exe);
        }
        Ok(&self.execs[name])
    }

    /// Chunked gate execution: the artifact has a fixed row count
    /// (`m_1q`/`m_2q`); larger inputs loop whole chunks, smaller ones are
    /// zero-padded (zero rows are invariant under the mat-vec).
    fn run_gate(
        &mut self,
        arity: usize,
        diagonal: bool,
        xr: &[f64],
        xi: &[f64],
        ur: &[f64],
        ui: &[f64],
        rows: usize,
        k: usize,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let chunk = if arity == 1 { self.manifest.m_1q } else { self.manifest.m_2q };
        let name = self.manifest.gate_module(arity, diagonal, "f64")?.name.clone();
        let (mat_rows, mat_cols) = if diagonal { (1usize, k) } else { (k, k) };

        let mut out_r = vec![0.0f64; rows * k];
        let mut out_i = vec![0.0f64; rows * k];
        let mut row = 0usize;
        while row < rows {
            let take = chunk.min(rows - row);
            let (cr, ci) = {
                // Pad the final partial chunk to the artifact shape.
                let mut bufr = vec![0.0f64; chunk * k];
                let mut bufi = vec![0.0f64; chunk * k];
                bufr[..take * k].copy_from_slice(&xr[row * k..(row + take) * k]);
                bufi[..take * k].copy_from_slice(&xi[row * k..(row + take) * k]);
                let exe = self.exec(&name)?;
                let lxr = xla::Literal::vec1(&bufr).reshape(&[chunk as i64, k as i64])?;
                let lxi = xla::Literal::vec1(&bufi).reshape(&[chunk as i64, k as i64])?;
                let lur =
                    xla::Literal::vec1(ur).reshape(&[mat_rows as i64, mat_cols as i64])?;
                let lui =
                    xla::Literal::vec1(ui).reshape(&[mat_rows as i64, mat_cols as i64])?;
                let result = exe.execute::<xla::Literal>(&[lxr, lxi, lur, lui])?[0][0]
                    .to_literal_sync()?;
                let (or_, oi_) = result.to_tuple2()?;
                (or_.to_vec::<f64>()?, oi_.to_vec::<f64>()?)
            };
            out_r[row * k..(row + take) * k].copy_from_slice(&cr[..take * k]);
            out_i[row * k..(row + take) * k].copy_from_slice(&ci[..take * k]);
            row += take;
        }
        Ok((out_r, out_i))
    }

    fn quant_module(&self, kernel: &str, error_bound: f64) -> Result<String> {
        self.manifest
            .modules
            .values()
            .find(|m| {
                m.kernel == kernel
                    && m.dtype == "f64"
                    && m.error_bound
                        .map(|e| (e - error_bound).abs() < e * 1e-9)
                        .unwrap_or(false)
            })
            .map(|m| m.name.clone())
            .ok_or_else(|| {
                Error::Artifact(format!("no {kernel} artifact for error bound {error_bound}"))
            })
    }

    fn run_quantize(&mut self, x: &[f64], error_bound: f64) -> Result<(Vec<i32>, Vec<i32>)> {
        let chunk = self.manifest.n_quant;
        let name = self.quant_module("quantize", error_bound)?;
        let n = x.len();
        let mut codes = vec![0i32; n];
        let mut signs = vec![0i32; n];
        let mut at = 0usize;
        while at < n {
            let take = chunk.min(n - at);
            let mut buf = vec![0.0f64; chunk];
            buf[..take].copy_from_slice(&x[at..at + take]);
            let exe = self.exec(&name)?;
            let lx = xla::Literal::vec1(&buf);
            let result = exe.execute::<xla::Literal>(&[lx])?[0][0].to_literal_sync()?;
            let (lc, ls) = result.to_tuple2()?;
            let (cv, sv) = (lc.to_vec::<i32>()?, ls.to_vec::<i32>()?);
            codes[at..at + take].copy_from_slice(&cv[..take]);
            signs[at..at + take].copy_from_slice(&sv[..take]);
            at += take;
        }
        Ok((codes, signs))
    }

    fn run_dequantize(
        &mut self,
        codes: &[i32],
        signs: &[i32],
        error_bound: f64,
    ) -> Result<Vec<f64>> {
        let chunk = self.manifest.n_quant;
        let name = self.quant_module("dequantize", error_bound)?;
        let n = codes.len();
        let mut out = vec![0.0f64; n];
        let mut at = 0usize;
        while at < n {
            let take = chunk.min(n - at);
            let mut bc = vec![0i32; chunk];
            let mut bs = vec![0i32; chunk];
            bc[..take].copy_from_slice(&codes[at..at + take]);
            bs[..take].copy_from_slice(&signs[at..at + take]);
            let exe = self.exec(&name)?;
            let lc = xla::Literal::vec1(&bc);
            let ls = xla::Literal::vec1(&bs);
            let result = exe.execute::<xla::Literal>(&[lc, ls])?[0][0].to_literal_sync()?;
            let lx = result.to_tuple1()?;
            let xv = lx.to_vec::<f64>()?;
            out[at..at + take].copy_from_slice(&xv[..take]);
            at += take;
        }
        Ok(out)
    }

    fn serve(mut self, rx: mpsc::Receiver<Job>) {
        while let Ok(job) = rx.recv() {
            match job {
                Job::Gate { arity, diagonal, xr, xi, ur, ui, rows, k, reply } => {
                    let r = self.run_gate(arity, diagonal, &xr, &xi, &ur, &ui, rows, k);
                    let _ = reply.send(r);
                }
                Job::Quantize { x, error_bound, reply } => {
                    let _ = reply.send(self.run_quantize(&x, error_bound));
                }
                Job::Dequantize { codes, signs, error_bound, reply } => {
                    let _ = reply.send(self.run_dequantize(&codes, &signs, error_bound));
                }
                Job::Shutdown => return,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Public handle
// ---------------------------------------------------------------------

/// Thread-safe handle to the PJRT service; implements [`GateApplier`] so
/// the engines can run their hot path through the AOT'd Pallas kernels.
pub struct XlaApplier {
    tx: Mutex<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl XlaApplier {
    /// Start the service thread against an artifacts directory. Fails fast
    /// if the manifest or PJRT client cannot be initialized.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || match Service::new(&dir) {
                Ok(svc) => {
                    let _ = init_tx.send(Ok(()));
                    svc.serve(rx);
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                }
            })
            .map_err(|e| Error::Xla(format!("cannot spawn xla service: {e}")))?;
        init_rx
            .recv()
            .map_err(|_| Error::Xla("xla service died during init".into()))??;
        Ok(XlaApplier { tx: Mutex::new(tx), handle: Some(handle) })
    }

    fn submit<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<Result<T>>) -> Job,
    ) -> Result<T> {
        let (rtx, rrx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(make(rtx)).map_err(|_| Error::Xla("xla service gone".into()))?;
        }
        rrx.recv().map_err(|_| Error::Xla("xla service dropped reply".into()))?
    }

    /// Quantize a plane through the Pallas quantizer artifact (parity path
    /// for the rust codec; see python/compile/kernels/quant_kernel.py).
    pub fn quantize(&self, x: &[f64], error_bound: f64) -> Result<(Vec<i32>, Vec<i32>)> {
        self.submit(|reply| Job::Quantize { x: x.to_vec(), error_bound, reply })
    }

    /// Dequantize codes produced by [`XlaApplier::quantize`].
    pub fn dequantize(&self, codes: &[i32], signs: &[i32], error_bound: f64) -> Result<Vec<f64>> {
        self.submit(|reply| Job::Dequantize {
            codes: codes.to_vec(),
            signs: signs.to_vec(),
            error_bound,
            reply,
        })
    }
}

impl Drop for XlaApplier {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Job::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl GateApplier for XlaApplier {
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()> {
        let len = re.len();
        let diagonal = gate.kind.is_diagonal();
        match gate.arity() {
            1 => {
                let t = bits[0];
                let bit = 1usize << t;
                let rows = len / 2;
                // Gather pairs into [rows, 2] planes (paper Fig. 2 pairing).
                let mut xr = vec![0.0f64; rows * 2];
                let mut xi = vec![0.0f64; rows * 2];
                for (m, i0) in crate::gates::pair_indices(len, t).enumerate() {
                    let i1 = i0 | bit;
                    xr[m * 2] = re[i0];
                    xr[m * 2 + 1] = re[i1];
                    xi[m * 2] = im[i0];
                    xi[m * 2 + 1] = im[i1];
                }
                let (ur, ui) = if diagonal {
                    let d = gate.diagonal();
                    (vec![d[0].re, d[1].re], vec![d[0].im, d[1].im])
                } else {
                    let m = gate.matrix1q();
                    (m.iter().map(|c| c.re).collect(), m.iter().map(|c| c.im).collect())
                };
                let (or_, oi_) = self.submit(|reply| Job::Gate {
                    arity: 1,
                    diagonal,
                    xr,
                    xi,
                    ur,
                    ui,
                    rows,
                    k: 2,
                    reply,
                })?;
                for (m, i0) in crate::gates::pair_indices(len, t).enumerate() {
                    let i1 = i0 | bit;
                    re[i0] = or_[m * 2];
                    re[i1] = or_[m * 2 + 1];
                    im[i0] = oi_[m * 2];
                    im[i1] = oi_[m * 2 + 1];
                }
                Ok(())
            }
            _ => {
                let (qa, qb) = (bits[0], bits[1]);
                let (ba, bb) = (1usize << qa, 1usize << qb);
                let rows = len / 4;
                let mut xr = vec![0.0f64; rows * 4];
                let mut xi = vec![0.0f64; rows * 4];
                // Basis order |q_a q_b> = 00,01,10,11 (q_a the high bit),
                // matching Gate::matrix2q.
                for (m, i) in crate::gates::quad_indices(len, qa.max(qb), qa.min(qb)).enumerate() {
                    let idx = [i, i | bb, i | ba, i | ba | bb];
                    for (s, &ix) in idx.iter().enumerate() {
                        xr[m * 4 + s] = re[ix];
                        xi[m * 4 + s] = im[ix];
                    }
                }
                let (ur, ui) = if diagonal {
                    let d = gate.diagonal();
                    (
                        d.iter().map(|c| c.re).collect::<Vec<_>>(),
                        d.iter().map(|c| c.im).collect::<Vec<_>>(),
                    )
                } else {
                    let m = gate.matrix2q();
                    (
                        m.iter().map(|c| c.re).collect::<Vec<_>>(),
                        m.iter().map(|c| c.im).collect::<Vec<_>>(),
                    )
                };
                let (or_, oi_) = self.submit(|reply| Job::Gate {
                    arity: 2,
                    diagonal,
                    xr,
                    xi,
                    ur,
                    ui,
                    rows,
                    k: 4,
                    reply,
                })?;
                for (m, i) in crate::gates::quad_indices(len, qa.max(qb), qa.min(qb)).enumerate() {
                    let idx = [i, i | bb, i | ba, i | ba | bb];
                    for (s, &ix) in idx.iter().enumerate() {
                        re[ix] = or_[m * 4 + s];
                        im[ix] = oi_[m * 4 + s];
                    }
                }
                Ok(())
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
