//! `artifacts/manifest.json` loading: which AOT modules exist, their chunk
//! geometry, dtypes, and baked error bounds.

use super::json::Json;
use crate::types::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT module entry.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub name: String,
    pub kernel: String,
    pub dtype: String,
    pub file: PathBuf,
    /// Pair/quad rows per call (gate kernels).
    pub m: Option<usize>,
    /// Gate dimension (2 or 4) for gate kernels.
    pub k: Option<usize>,
    /// Elements per call (quantizer kernels).
    pub n: Option<usize>,
    /// Baked-in point-wise relative bound (quantizer kernels).
    pub error_bound: Option<f64>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub modules: BTreeMap<String, ModuleInfo>,
    pub m_1q: usize,
    pub m_2q: usize,
    pub n_quant: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&src)?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Artifact("manifest: unexpected format".into()));
        }
        let chunks = j
            .get("chunks")
            .ok_or_else(|| Error::Artifact("manifest: missing chunks".into()))?;
        let need = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Artifact(format!("manifest: missing chunks.{k}")))
        };
        let m_1q = need(chunks, "m_1q")?;
        let m_2q = need(chunks, "m_2q")?;
        let n_quant = need(chunks, "n_quant")?;

        let mut modules = BTreeMap::new();
        let mods = j
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Artifact("manifest: missing modules".into()))?;
        for (name, meta) in mods {
            let kernel = meta
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Artifact(format!("manifest: {name} missing kernel")))?
                .to_string();
            let dtype = meta
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f64")
                .to_string();
            let file = dir.join(
                meta.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Artifact(format!("manifest: {name} missing file")))?,
            );
            modules.insert(
                name.clone(),
                ModuleInfo {
                    name: name.clone(),
                    kernel,
                    dtype,
                    file,
                    m: meta.get("m").and_then(Json::as_usize),
                    k: meta.get("k").and_then(Json::as_usize),
                    n: meta.get("n").and_then(Json::as_usize),
                    error_bound: meta.get("error_bound").and_then(Json::as_f64),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), modules, m_1q, m_2q, n_quant })
    }

    /// Gate module name for arity/diagonality/dtype.
    pub fn gate_module(&self, arity: usize, diagonal: bool, dtype: &str) -> Result<&ModuleInfo> {
        let kind = match (arity, diagonal) {
            (1, false) => "gate1q",
            (1, true) => "diag1q",
            (2, false) => "gate2q",
            (2, true) => "diag2q",
            _ => return Err(Error::Artifact(format!("no gate module for arity {arity}"))),
        };
        let name = format!("{kind}_{dtype}");
        self.modules
            .get(&name)
            .ok_or_else(|| Error::Artifact(format!("manifest: missing module {name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_generated_manifest_when_present() {
        let dir = repo_artifacts();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.m_1q.is_power_of_two());
        assert!(m.modules.len() >= 10);
        let g = m.gate_module(1, false, "f64").unwrap();
        assert_eq!(g.kernel, "gate1q");
        assert!(g.file.exists());
        assert_eq!(g.k, Some(2));
        let d = m.gate_module(2, true, "f32").unwrap();
        assert_eq!(d.kernel, "diag2q");
    }

    #[test]
    fn missing_dir_gives_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
