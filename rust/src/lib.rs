//! # BMQSIM-RS
//!
//! A full-state (state-vector) quantum circuit simulation framework that
//! breaks the memory wall with error-bounded lossy compression — a rust +
//! JAX/Pallas (AOT via PJRT) reproduction of *"Overcoming Memory
//! Constraints in Quantum Circuit Simulation with a High-Fidelity
//! Compression Framework"* (BMQSIM, 2024).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured reproduction log.

pub mod bench_harness;
pub mod circuit;
// The user-facing core — compress, memory, sim — keeps rustdoc complete:
// every public item in these subtrees must carry a doc comment, and the
// CI `docs` job runs `cargo doc` with `-D warnings` to enforce it.
#[warn(missing_docs)]
pub mod compress;
pub mod gates;
// The store's locking/recovery layer bans bare `unwrap()` (a panicking
// worker must never wedge siblings): CI runs clippy with this lint as an
// error for the whole `memory` subtree. Tests opt back in locally.
#[deny(clippy::unwrap_used)]
#[warn(missing_docs)]
pub mod memory;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
#[warn(missing_docs)]
pub mod sim;
pub mod simd;
pub mod state;
pub mod types;
