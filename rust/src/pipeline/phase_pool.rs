//! Persistent phase-worker runtime (ROADMAP "persistent phase workers").
//!
//! [`super::run_items_overlapped`] spawns and joins `3 × workers` phase
//! threads *per stage*. At paper scale a run has hundreds of stages, so the
//! engines pay thread churn plus a full pipeline fill/drain on every stage
//! boundary. [`PhasePool`] removes that: it is created **once per
//! simulation run**, keeps the decode/apply/encode threads (and their
//! scratch [`RingPool`]) alive for the whole run, and feeds each stage to
//! them as a *work descriptor* — three phase closures plus an item count —
//! over an epoch-stamped control channel. A stage handoff is one condvar
//! broadcast instead of `3 × workers` spawns and joins.
//!
//! The phase threads execute the exact same loop bodies as the scoped
//! driver (`decode_phase_loop` / `apply_phase_loop` / `encode_phase_loop`),
//! so the slot handshake protocol — and the byte-identical-output property
//! it guarantees — is shared, not duplicated.
//!
//! ## Lifetime erasure
//!
//! Stage closures borrow stage-local state (the group schedule, the fused
//! plan, the store, metrics). Persistent threads are `'static`, so the
//! pool erases the closure lifetimes behind raw trait object pointers —
//! the same trick scoped-thread libraries use. [`PhasePool::run_stage`]
//! makes that sound by **blocking until every phase thread has finished
//! the stage** before returning; the epoch-drained form
//! ([`PhasePool::submit_stage`] / [`PhasePool::drain_oldest`]) extends the
//! argument to **two in-flight epochs**: the caller contractually keeps
//! both epochs' closures alive until the drain call that retires them
//! returns (`submit_stage` is `unsafe` for exactly this reason; the
//! engine-side `PoolDriver` owns the boxed closures and drains before
//! dropping them, including on unwind). The pointers are never
//! dereferenced after the borrows they came from end. The `unsafe` is
//! confined to small, documented sites (`erase`, `submit_stage`, and the
//! dereference in `run_phase`).
//!
//! ## Epoch drain (cross-stage overlap)
//!
//! The classic `run_stage` barrier drains all three phase rings at every
//! stage boundary, so decode threads idle while the previous stage's tail
//! groups encode. With `submit_stage`, up to [`MAX_EPOCHS_IN_FLIGHT`]
//! stages coexist: each epoch gets its own ring **bank** (control block +
//! scratch ring + work queue), so epoch `s+1`'s decode handshake shares
//! nothing with epoch `s`'s encode handshake, and each thread simply
//! processes epochs in order. Whether a given `s+1` group may *semantically*
//! begin (its input blocks re-encoded by stage `s`) is the engine's
//! business — see `sim::BoundaryGate`.
//!
//! ## Unwind safety
//!
//! A panic inside a phase closure is caught on the phase thread
//! (`catch_unwind`), recorded, and re-raised on the *caller* by
//! `run_stage` — preserving the scoped driver's behaviour where
//! `thread::scope` re-raises. The in-ring `PhaseExit` guards still run
//! during the unwind, raising the abort flag and marking the phase's done
//! flag so sibling phases drain instead of wedging; the pool's threads
//! survive (they caught the unwind) and are joined by `Drop`.

use super::{
    apply_phase_loop, decode_phase_loop, encode_phase_loop, OverlapStats, PhaseEnv,
    PipelineConfig, RingCtrl, RingPool, Semaphore,
};
use crate::types::Error;
use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Ring-depth bounds for the AIMD controller (CLI `--pipeline-depth`
/// omitted). Depth 2 is classic double buffering — the floor below which
/// the ring cannot absorb any phase-time variance; 8 slots per worker is
/// the allocation cap (unused slots are empty `Scratch` arenas, so the cap
/// costs nothing until a slot is actually warmed).
pub const RING_DEPTH_MIN: usize = 2;
pub const RING_DEPTH_MAX: usize = 8;

/// AIMD thresholds, per stage: handshake stall growing by more than this
/// since the last stage means a phase ran dry (additive increase); growth
/// below the idle floor means the current depth already conceals the
/// imbalance (multiplicative decrease — cheap to re-grow). Same shape as
/// the prefetch auto-depth controller in `memory`.
pub const RING_AIMD_STALL_STEP_NS: u64 = 500_000;
pub const RING_AIMD_IDLE_NS: u64 = 50_000;

/// Per-stage AIMD controller for the scratch-ring depth, driven by the
/// cumulative [`OverlapStats`] stall counter (ROADMAP "adaptive ring
/// depth"). With `auto` off it pins the configured depth. The first stage
/// primes the stall snapshot — no history must not read as "idle" and
/// shrink the ring during exactly the fill the depth exists to cover.
pub struct RingDepthController {
    auto: bool,
    cur: usize,
    cap: usize,
    last_stall_ns: u64,
    primed: bool,
    adjustments: u64,
    peak: usize,
}

impl RingDepthController {
    pub fn new(start: usize, auto: bool, cap: usize) -> Self {
        let cap = cap.max(1);
        let cur = start.clamp(1, cap);
        RingDepthController {
            auto,
            cur,
            cap,
            last_stall_ns: 0,
            primed: false,
            adjustments: 0,
            peak: cur,
        }
    }

    /// Depth the controller currently recommends.
    pub fn current(&self) -> usize {
        self.cur
    }

    /// Deepest ring the controller has recommended so far.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// How many times the depth actually changed (the trajectory length).
    pub fn adjustments(&self) -> u64 {
        self.adjustments
    }

    /// One step, called before each stage with the run's *cumulative*
    /// handshake stall time; returns the depth to use for this stage.
    pub fn stage_depth(&mut self, total_stall_ns: u64) -> usize {
        if !self.auto {
            return self.cur;
        }
        let delta = total_stall_ns.saturating_sub(self.last_stall_ns);
        self.last_stall_ns = total_stall_ns;
        if !self.primed {
            self.primed = true;
            return self.cur;
        }
        let floor = RING_DEPTH_MIN.min(self.cap);
        let next = if delta > RING_AIMD_STALL_STEP_NS {
            (self.cur + 1).min(self.cap)
        } else if delta < RING_AIMD_IDLE_NS {
            (self.cur / 2).max(floor)
        } else {
            self.cur
        };
        if next != self.cur {
            self.adjustments += 1;
            self.cur = next;
            self.peak = self.peak.max(next);
        }
        self.cur
    }
}

/// The phase-closure trait object the pool executes. Fixed to the crate
/// error type: the pool exists for the engines' hot path, and a concrete
/// `E` is what makes the type-erased stage descriptor possible.
type Phase<'a> = dyn Fn(&mut super::WorkerCtx<'_>, usize) -> Result<(), Error> + Sync + 'a;

/// Lifetime-erased pointer to a phase closure.
///
/// SAFETY invariant: the pointee outlives the epoch it was submitted for —
/// `run_stage` does not return until every phase thread has reported the
/// stage done, and `submit_stage`'s contract makes the caller keep the
/// closures alive until the drain call that retires the epoch returns.
/// Threads never touch a spec after its epoch is retired.
#[derive(Clone, Copy)]
struct RawPhase(*const Phase<'static>);

// SAFETY: the pointee is `Sync` (required by `Phase`) and the RawPhase is
// only dereferenced while the originating borrow is provably live (the
// stage barrier in `run_stage`, or the submit/drain contract).
unsafe impl Send for RawPhase {}
unsafe impl Sync for RawPhase {}

fn erase(f: &Phase<'_>) -> RawPhase {
    // SAFETY: pure lifetime extension of a fat reference; see RawPhase.
    RawPhase(unsafe { std::mem::transmute::<*const Phase<'_>, *const Phase<'static>>(f) })
}

/// One stage's work descriptor, published to all phase threads at once.
#[derive(Clone, Copy)]
struct StageSpec {
    depth: usize,
    decode: RawPhase,
    apply: RawPhase,
    encode: RawPhase,
}

/// Most epochs (stages) that may be in flight at once. Two is the whole
/// point of the drain protocol — stage `s`'s encode tail and stage
/// `s+1`'s decode head — and it bounds the ring-bank allocation.
pub const MAX_EPOCHS_IN_FLIGHT: usize = 2;

/// One in-flight epoch: the stage's work descriptor, the ring bank it
/// runs on, and how many of the `3 × workers` threads finished it.
struct EpochSlot {
    id: u64,
    bank: usize,
    spec: StageSpec,
    done: usize,
}

/// Epoch-stamped control state. `next_epoch` increments once per
/// submitted stage; each thread runs the oldest epoch it has not yet
/// completed (in id order), then bumps that epoch's `done`. Drain calls
/// wait for the front slot's `done == 3 × workers` and pop it.
struct PoolCtl {
    next_epoch: u64,
    shutdown: bool,
    epochs: VecDeque<EpochSlot>,
}

struct PoolInner {
    ctl: Mutex<PoolCtl>,
    cv: Condvar,
    /// Per-bank work queues: epoch item indices for the epoch currently
    /// occupying that bank.
    queues: [Mutex<VecDeque<usize>>; MAX_EPOCHS_IN_FLIGHT],
    /// Bank-major ring controls: `ctrls[bank * workers + w]`.
    ctrls: Vec<RingCtrl>,
    /// Bank-major scratch rings, same indexing as `ctrls`.
    rings: RingPool,
    transfer: Semaphore,
    abort: AtomicBool,
    failed: Mutex<Option<Error>>,
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
    stats: OverlapStats,
    devices: usize,
    workers: usize,
}

#[derive(Clone, Copy)]
enum Role {
    Decode,
    Apply,
    Encode,
}

impl Role {
    fn name(self) -> &'static str {
        match self {
            Role::Decode => "decode",
            Role::Apply => "apply",
            Role::Encode => "encode",
        }
    }
}

/// The persistent phase-worker runtime: `3 × workers` long-lived
/// decode/apply/encode threads over a persistent scratch [`RingPool`],
/// fed one [`StageSpec`] per [`PhasePool::run_stage`] call.
pub struct PhasePool {
    inner: Arc<PoolInner>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    depth_cap: usize,
    /// Epoch-drain watchdog (CLI `--stall-timeout-ms`; `None` = off): a
    /// drain that observes no phase-thread progress for this long gives
    /// up with a typed error and a progress dump instead of waiting
    /// forever on a wedged phase (e.g. a spill writer pinned by a
    /// `stall@write` fault plan).
    stall_timeout: Option<std::time::Duration>,
}

impl PhasePool {
    /// Spawn the pool's phase threads — the only thread creation the pool
    /// ever performs. `depth_cap` bounds the per-stage ring depth (the
    /// rings are allocated at the cap; unwarmed slots cost nothing).
    pub fn new(cfg: PipelineConfig, depth_cap: usize) -> Self {
        let workers = cfg.workers().max(1);
        let depth_cap = depth_cap.max(1);
        // One ring bank per in-flight epoch: bank 1's slots stay empty
        // `Scratch` arenas until a cross-stage submission actually warms
        // them, so the second bank costs nothing on the barrier path.
        let banked = MAX_EPOCHS_IN_FLIGHT * workers;
        let inner = Arc::new(PoolInner {
            ctl: Mutex::new(PoolCtl {
                next_epoch: 0,
                shutdown: false,
                epochs: VecDeque::with_capacity(MAX_EPOCHS_IN_FLIGHT),
            }),
            cv: Condvar::new(),
            queues: [Mutex::new(VecDeque::new()), Mutex::new(VecDeque::new())],
            ctrls: (0..banked).map(|_| RingCtrl::new(depth_cap)).collect(),
            rings: RingPool::new(banked, depth_cap),
            transfer: Semaphore::new(cfg.transfer_slots),
            abort: AtomicBool::new(false),
            failed: Mutex::new(None),
            panic_payload: Mutex::new(None),
            stats: OverlapStats::default(),
            devices: cfg.devices.max(1),
            workers,
        });
        let mut handles = Vec::with_capacity(3 * workers);
        for w in 0..workers {
            for role in [Role::Decode, Role::Apply, Role::Encode] {
                let inner = Arc::clone(&inner);
                let handle = std::thread::Builder::new()
                    .name(format!("bmq-{}-{w}", role.name()))
                    .spawn(move || phase_main(inner, w, role))
                    .expect("spawn phase-pool worker");
                handles.push(handle);
            }
        }
        PhasePool { inner, handles, workers, depth_cap, stall_timeout: None }
    }

    /// Arm (or disarm with `None`) the epoch-drain watchdog. Engines set
    /// this from `SimConfig::stall_timeout_ms` right after construction.
    pub fn set_stall_timeout(&mut self, timeout: Option<std::time::Duration>) {
        self.stall_timeout = timeout;
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn depth_cap(&self) -> usize {
        self.depth_cap
    }

    /// Total phase threads this pool has EVER spawned — `3 × workers`,
    /// fixed at construction. Engines surface it as
    /// `Metrics::phase_threads_spawned`; a multi-stage run keeping it at
    /// `3 × workers` is the proof that stages reuse threads instead of
    /// re-spawning them (`tests/phase_pool.rs`).
    pub fn threads_spawned(&self) -> u64 {
        (3 * self.workers) as u64
    }

    /// Run-cumulative overlap instrumentation (stalls, decode-ahead hits,
    /// stage handoffs).
    pub fn stats(&self) -> &OverlapStats {
        &self.inner.stats
    }

    /// Plane-growth events across the pool's scratch rings (the
    /// arena-reuse counter surfaced as `Metrics::scratch_grows`).
    pub fn total_plane_grows(&self) -> u64 {
        self.inner.rings.total_plane_grows()
    }

    /// Number of submitted epochs not yet retired by a drain call.
    pub fn in_flight(&self) -> usize {
        self.inner.ctl.lock().unwrap().epochs.len()
    }

    /// Raise the pool-wide abort flag so in-flight epochs skim their
    /// remaining items instead of doing work. Used by owners tearing a
    /// window down early (e.g. on an unwind between submit and drain).
    pub fn abort(&self) {
        self.inner.abort.store(true, Ordering::Release);
    }

    /// Submit items `0..n` as one epoch on the persistent threads at ring
    /// depth `depth` (clamped to `1..=depth_cap`), without waiting for it
    /// to finish. If [`MAX_EPOCHS_IN_FLIGHT`] epochs are already in
    /// flight, the oldest is drained first (returning its error, if any).
    ///
    /// Takes `&mut self` deliberately: exclusivity guarantees no second
    /// caller can re-arm a bank (queue, rings) while this window's
    /// lifetime-erased closures are still running.
    ///
    /// # Safety
    ///
    /// The three closures (and everything they borrow) must remain live
    /// until the drain call ([`Self::drain_oldest`] / [`Self::drain_all`])
    /// that retires this epoch returns — including on the unwind path.
    /// The pool stores lifetime-erased pointers to them and dereferences
    /// those from its phase threads until the epoch is drained.
    pub unsafe fn submit_stage(
        &mut self,
        n: usize,
        depth: usize,
        decode: &Phase<'_>,
        apply: &Phase<'_>,
        encode: &Phase<'_>,
    ) -> Result<(), Error> {
        if self.in_flight() >= MAX_EPOCHS_IN_FLIGHT {
            self.drain_oldest()?;
        }
        let inner = &*self.inner;
        let depth = depth.clamp(1, self.depth_cap);
        let workers = self.workers;
        let mut ctl = inner.ctl.lock().unwrap();
        // Reuse bank 0 whenever the window is empty (the serialized /
        // barrier path then warms exactly one bank, like the pre-epoch
        // pool); alternate banks only for a genuinely overlapped submit.
        let bank = match ctl.epochs.back() {
            Some(e) => 1 - e.bank,
            None => 0,
        };
        if ctl.epochs.is_empty() {
            // Re-arm pool-global failure state. No phase thread is inside
            // any epoch (window empty), so plain stores are race-free.
            inner.abort.store(false, Ordering::Release);
            *inner.failed.lock().unwrap() = None;
        }
        {
            let mut q = inner.queues[bank].lock().unwrap();
            q.clear();
            q.extend(0..n);
        }
        // The bank's previous epoch (if any) was at least two submits ago,
        // hence fully drained: no phase thread touches this ring.
        for ctrl in &inner.ctrls[bank * workers..(bank + 1) * workers] {
            ctrl.reset(depth);
        }
        inner.stats.stage_handoffs.fetch_add(1, Ordering::Relaxed);
        ctl.next_epoch += 1;
        let id = ctl.next_epoch;
        ctl.epochs.push_back(EpochSlot {
            id,
            bank,
            spec: StageSpec {
                depth,
                decode: erase(decode),
                apply: erase(apply),
                encode: erase(encode),
            },
            done: 0,
        });
        drop(ctl);
        inner.cv.notify_all();
        Ok(())
    }

    /// Wait for the oldest in-flight epoch to finish and retire it,
    /// returning `true` if one was retired. With a stall timeout armed,
    /// the wait is bounded: the watchdog timer re-arms every time any
    /// phase thread reports an epoch done (progress), and fires a typed
    /// error with a progress dump once the window sits idle past the
    /// deadline. The wedged epoch is NOT retired on the error path — its
    /// erased closure pointers may still be dereferenced by phase
    /// threads, so the owner must leak the closures rather than free
    /// them (`sim::PoolDriver::drop` does).
    fn wait_front_drained(&self) -> Result<bool, Error> {
        const WATCHDOG_POLL: std::time::Duration = std::time::Duration::from_millis(5);
        let inner = &*self.inner;
        let threads = 3 * self.workers;
        let mut ctl = inner.ctl.lock().unwrap();
        if ctl.epochs.is_empty() {
            return Ok(false);
        }
        let mut last_done = ctl.epochs.front().map_or(0, |e| e.done);
        let mut idle_since = std::time::Instant::now();
        while ctl.epochs.front().is_some_and(|e| e.done < threads) {
            match self.stall_timeout {
                None => ctl = inner.cv.wait(ctl).unwrap(),
                Some(limit) => {
                    ctl = inner.cv.wait_timeout(ctl, WATCHDOG_POLL).unwrap().0;
                    let done = ctl.epochs.front().map_or(threads, |e| e.done);
                    if done != last_done {
                        last_done = done;
                        idle_since = std::time::Instant::now();
                    } else if idle_since.elapsed() >= limit {
                        return Err(Error::spill(format!(
                            "epoch-drain watchdog: no phase-thread progress for \
                             {} ms ({} epochs in flight, front epoch {done}/{threads} \
                             phase threads done)",
                            limit.as_millis(),
                            ctl.epochs.len(),
                        )));
                    }
                }
            }
        }
        // Drop the epoch's raw pointers before the caller releases the
        // borrows they came from.
        ctl.epochs.pop_front();
        Ok(true)
    }

    /// Surface a recorded panic or first phase error once the window is
    /// empty.
    fn resolve(&self) -> Result<(), Error> {
        if let Some(payload) = self.inner.panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
        match self.inner.failed.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Drain the oldest in-flight epoch. On failure anywhere in the
    /// window the *whole* window is drained (the abort flag makes the
    /// remaining epochs skim) before the panic is re-raised / the first
    /// error is returned — no erased borrow survives the error path.
    /// Errors and panics only surface once the window is empty, so a
    /// clean `drain_oldest` with a second epoch still in flight returns
    /// `Ok(())` immediately after the front epoch retires.
    pub fn drain_oldest(&mut self) -> Result<(), Error> {
        self.wait_front_drained()?;
        if self.inner.abort.load(Ordering::Acquire) {
            while self.wait_front_drained()? {}
        }
        if self.in_flight() == 0 {
            self.resolve()
        } else {
            Ok(())
        }
    }

    /// Drain every in-flight epoch, then surface a recorded panic or the
    /// first phase error.
    pub fn drain_all(&mut self) -> Result<(), Error> {
        while self.wait_front_drained()? {}
        self.resolve()
    }

    /// Run items `0..n` through the three-phase pipeline on the persistent
    /// threads at ring depth `depth` (clamped to `1..=depth_cap`). Blocks
    /// until the stage fully completes. The first phase error aborts the
    /// stage and is returned; a phase panic is re-raised here. The pool
    /// remains reusable after an `Err` (per-stage state is re-armed on the
    /// next call); after a re-raised panic the scratch slot the panic
    /// poisoned makes further stages unusable — drop the pool.
    ///
    /// This is the full-barrier composition of `submit_stage` +
    /// `drain_all`: the drain before return is what makes the lifetime
    /// erasure sound without any caller-side contract.
    pub fn run_stage(
        &mut self,
        n: usize,
        depth: usize,
        decode: &Phase<'_>,
        apply: &Phase<'_>,
        encode: &Phase<'_>,
    ) -> Result<(), Error> {
        // SAFETY: the closure borrows are live across the immediate
        // `drain_all` below; no erased pointer survives this call.
        unsafe { self.submit_stage(n, depth, decode, apply, encode)? };
        self.drain_all()
    }
}

impl Drop for PhasePool {
    fn drop(&mut self) {
        // Owners (PoolDriver, run_stage) drain before dropping; if epochs
        // are somehow still pending, abort so the threads skim them
        // instead of doing work on the way out.
        self.inner.abort.store(true, Ordering::Release);
        {
            let mut ctl = self.inner.ctl.lock().unwrap();
            ctl.shutdown = true;
        }
        self.inner.cv.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Long-lived phase-thread main: park on the control condvar until an
/// epoch this thread has not run yet exists (or shutdown), run this
/// thread's phase loop for the *oldest* such epoch, report it done,
/// repeat. Pending epochs are processed before a shutdown is honoured.
fn phase_main(inner: Arc<PoolInner>, w: usize, role: Role) {
    let mut seen = 0u64;
    loop {
        let (id, bank, spec) = {
            let mut ctl = inner.ctl.lock().unwrap();
            loop {
                if let Some(e) = ctl.epochs.iter().find(|e| e.id > seen) {
                    break (e.id, e.bank, e.spec);
                }
                if ctl.shutdown {
                    return;
                }
                ctl = inner.cv.wait(ctl).unwrap();
            }
        };
        seen = id;
        // Catch a phase-closure panic so the thread survives for the next
        // stage teardown path; the in-loop PhaseExit guard already ran
        // during the unwind (abort + done flags), so siblings drain.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_phase(&inner, w, bank, role, &spec);
        }));
        if let Err(payload) = outcome {
            let mut slot = inner.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut ctl = inner.ctl.lock().unwrap();
        if let Some(e) = ctl.epochs.iter_mut().find(|e| e.id == id) {
            e.done += 1;
        }
        drop(ctl);
        inner.cv.notify_all();
    }
}

fn run_phase(inner: &PoolInner, w: usize, bank: usize, role: Role, spec: &StageSpec) {
    let lane = bank * inner.workers + w;
    let env = PhaseEnv {
        ctrl: &inner.ctrls[lane],
        slots: &inner.rings.rings[lane][..spec.depth],
        stats: &inner.stats,
        abort: &inner.abort,
        transfer: &inner.transfer,
        worker: w,
        device: w % inner.devices,
    };
    // SAFETY: the epoch stays in the control window until this thread
    // reports done (and the caller's drain retires it), so the erased
    // closure borrows are live here.
    match role {
        Role::Decode => {
            let f = unsafe { &*spec.decode.0 };
            decode_phase_loop(&env, &inner.queues[bank], &inner.failed, f);
        }
        Role::Apply => {
            let f = unsafe { &*spec.apply.0 };
            apply_phase_loop(&env, &inner.failed, f);
        }
        Role::Encode => {
            let f = unsafe { &*spec.encode.0 };
            encode_phase_loop(&env, &inner.failed, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn ok_phase() -> impl Fn(&mut super::super::WorkerCtx<'_>, usize) -> Result<(), Error> + Sync
    {
        |_ctx, _i| Ok(())
    }

    #[test]
    fn pool_runs_items_through_all_phases_in_order() {
        let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 3);
        for _stage in 0..3 {
            let n = 40;
            let out: Mutex<Vec<(usize, f64)>> = Mutex::new(Vec::new());
            pool.run_stage(
                n,
                2,
                &|ctx, i| {
                    ctx.scratch.ensure_planes(4);
                    ctx.scratch.re[0] = i as f64;
                    Ok(())
                },
                &|ctx, i| {
                    assert_eq!(ctx.scratch.re[0], i as f64, "apply saw wrong slot");
                    ctx.scratch.re[0] *= 10.0;
                    Ok(())
                },
                &|ctx, i| {
                    out.lock().unwrap().push((i, ctx.scratch.re[0]));
                    Ok(())
                },
            )
            .unwrap();
            let mut got = out.into_inner().unwrap();
            assert_eq!(got.len(), n);
            got.sort_unstable_by_key(|&(i, _)| i);
            for (i, (item, v)) in got.iter().enumerate() {
                assert_eq!(*item, i);
                assert_eq!(*v, 10.0 * i as f64);
            }
        }
        assert_eq!(pool.threads_spawned(), 6);
        assert_eq!(pool.stats().stage_handoffs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn pool_error_aborts_stage_and_pool_stays_usable() {
        let mut pool = PhasePool::new(PipelineConfig::new(1, 1), 2);
        let r = pool.run_stage(
            100,
            2,
            &|_c, i| {
                if i == 5 {
                    Err(Error::Codec("boom".into()))
                } else {
                    Ok(())
                }
            },
            &ok_phase(),
            &ok_phase(),
        );
        assert!(matches!(r, Err(Error::Codec(_))));
        // The next stage runs clean on the same threads.
        let done = AtomicUsize::new(0);
        pool.run_stage(
            16,
            2,
            &ok_phase(),
            &ok_phase(),
            &|_c, _i| {
                done.fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 16);
        assert_eq!(pool.threads_spawned(), 3);
    }

    #[test]
    fn pool_spill_error_mid_stage_drains_and_pool_stays_usable() {
        // The failure-domain contract for the memory tier: a worker hitting
        // an unrecoverable spill fault mid-stage (e.g. retry exhaustion in
        // `BlockStore::take`) must surface the typed `Error::Spill` to the
        // caller, with every sibling phase draining instead of wedging —
        // and the pool must run further stages afterwards. Injected in the
        // *apply* phase so both an upstream (decode) and downstream
        // (encode) sibling have in-flight slots to drain.
        let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 3);
        let r = pool.run_stage(
            64,
            3,
            &ok_phase(),
            &|_c, i| {
                if i == 9 {
                    Err(Error::spill_io(
                        "take(9): read_frame retries exhausted",
                        std::io::Error::from_raw_os_error(5),
                    ))
                } else {
                    Ok(())
                }
            },
            &ok_phase(),
        );
        match r {
            Err(Error::Spill { source: Some(io), .. }) => {
                assert_eq!(io.raw_os_error(), Some(5), "io source lost in transit");
            }
            other => panic!("expected typed Error::Spill with io source, got {other:?}"),
        }
        // Same threads, clean stage: the pool recovered from the fault.
        let done = AtomicUsize::new(0);
        pool.run_stage(
            32,
            3,
            &ok_phase(),
            &ok_phase(),
            &|_c, _i| {
                done.fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 32);
        assert_eq!(pool.threads_spawned(), 6, "recovery must not respawn threads");
    }

    #[test]
    fn pool_zero_items_and_depth_clamp() {
        let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 2);
        // depth 99 clamps to the cap; zero items completes immediately.
        pool.run_stage(0, 99, &ok_phase(), &ok_phase(), &ok_phase()).unwrap();
        pool.run_stage(4, 0, &ok_phase(), &ok_phase(), &ok_phase()).unwrap();
    }

    #[test]
    fn pool_panic_propagates_to_caller_and_teardown_joins() {
        for phase in 0..3usize {
            let mut pool = PhasePool::new(PipelineConfig::new(1, 1), 2);
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = pool.run_stage(
                    16,
                    2,
                    &move |_c, i| {
                        assert!(!(phase == 0 && i == 3), "kaboom-decode");
                        Ok(())
                    },
                    &move |_c, i| {
                        assert!(!(phase == 1 && i == 3), "kaboom-apply");
                        Ok(())
                    },
                    &move |_c, i| {
                        assert!(!(phase == 2 && i == 3), "kaboom-encode");
                        Ok(())
                    },
                );
            }));
            assert!(caught.is_err(), "phase {phase} panic was swallowed or hung");
            drop(pool); // must join, not hang, after a panicked stage
        }
    }

    #[test]
    fn pool_two_epochs_overlap_across_the_boundary() {
        // Epoch 0's encode of its LAST item blocks until epoch 1's decode
        // has run: only possible if the second epoch starts while the
        // first is still draining. (The last item, so epoch 0's decode can
        // retire its whole queue and move on to epoch 1.) A full-barrier
        // pool would wedge here and surface the bounded-wait error below
        // instead of hanging.
        let mut pool = PhasePool::new(PipelineConfig::new(1, 1), 2);
        let crossed = AtomicBool::new(false);
        let d0 = ok_phase();
        let a0 = ok_phase();
        let e0 = |_c: &mut super::super::WorkerCtx<'_>, i: usize| {
            if i == 3 {
                let t0 = std::time::Instant::now();
                while !crossed.load(Ordering::Acquire) {
                    if t0.elapsed() > std::time::Duration::from_secs(10) {
                        return Err(Error::Codec("epoch 1 never overlapped epoch 0".into()));
                    }
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            }
            Ok(())
        };
        let d1 = |_c: &mut super::super::WorkerCtx<'_>, _i: usize| {
            crossed.store(true, Ordering::Release);
            Ok(())
        };
        let a1 = ok_phase();
        let e1 = ok_phase();
        // SAFETY: all six closures outlive the drain_all below.
        unsafe {
            pool.submit_stage(4, 2, &d0, &a0, &e0).unwrap();
            pool.submit_stage(4, 2, &d1, &a1, &e1).unwrap();
        }
        assert_eq!(pool.in_flight(), 2);
        pool.drain_all().unwrap();
        assert_eq!(pool.in_flight(), 0);
        assert!(crossed.load(Ordering::Acquire));
        assert_eq!(pool.stats().stage_handoffs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn pool_mid_drain_error_drains_both_epochs_and_stays_usable() {
        // An `Err` in the OLD epoch's encode while the new epoch is
        // already in flight: the whole window must drain (no wedge, no
        // dangling spec) and the typed error surface from the drain.
        let mut pool = PhasePool::new(PipelineConfig::new(1, 2), 3);
        let d0 = ok_phase();
        let a0 = ok_phase();
        let e0 = |_c: &mut super::super::WorkerCtx<'_>, i: usize| {
            if i == 3 {
                Err(Error::spill_io(
                    "put(3): mid-drain fault",
                    std::io::Error::from_raw_os_error(5),
                ))
            } else {
                Ok(())
            }
        };
        let d1 = ok_phase();
        let a1 = ok_phase();
        let e1 = ok_phase();
        // SAFETY: all six closures outlive the drain calls below.
        let r = unsafe {
            pool.submit_stage(32, 3, &d0, &a0, &e0).unwrap();
            pool.submit_stage(32, 3, &d1, &a1, &e1).unwrap();
            pool.drain_oldest().and_then(|()| pool.drain_all())
        };
        assert!(matches!(r, Err(Error::Spill { .. })), "typed error lost: {r:?}");
        assert_eq!(pool.in_flight(), 0, "error path left epochs in flight");
        // Same threads, clean barrier stage: the pool recovered.
        let done = AtomicUsize::new(0);
        pool.run_stage(
            16,
            2,
            &ok_phase(),
            &ok_phase(),
            &|_c, _i| {
                done.fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 16);
        assert_eq!(pool.threads_spawned(), 6, "recovery must not respawn threads");
    }

    #[test]
    fn pool_mid_drain_panic_in_second_epoch_tears_down_and_joins() {
        // A panic in the NEW epoch while the old one drains: the drain
        // must re-raise on the caller and `drop` must join, not hang.
        let mut pool = PhasePool::new(PipelineConfig::new(1, 1), 2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let d0 = ok_phase();
            let a0 = ok_phase();
            let e0 = ok_phase();
            let d1 = |_c: &mut super::super::WorkerCtx<'_>, i: usize| {
                assert!(i != 2, "kaboom-cross-stage-decode");
                Ok(())
            };
            let a1 = ok_phase();
            let e1 = ok_phase();
            // SAFETY: the closures outlive drain_all, which either
            // returns or re-raises after the window is empty.
            unsafe {
                pool.submit_stage(8, 2, &d0, &a0, &e0).unwrap();
                pool.submit_stage(8, 2, &d1, &a1, &e1).unwrap();
            }
            let _ = pool.drain_all();
        }));
        assert!(caught.is_err(), "mid-drain panic was swallowed or hung");
        drop(pool); // must join, not hang, after a panicked window
    }

    #[test]
    fn ring_depth_controller_aimd_trajectory() {
        let mut ctl = RingDepthController::new(2, true, 8);
        assert_eq!(ctl.stage_depth(0), 2, "first stage primes, never moves");
        // Growing stall → additive increase.
        assert_eq!(ctl.stage_depth(10_000_000), 3);
        assert_eq!(ctl.stage_depth(25_000_000), 4);
        // Stall flat (delta 0) → multiplicative decrease to the floor.
        assert_eq!(ctl.stage_depth(25_000_000), 2);
        assert_eq!(ctl.stage_depth(25_000_000), 2, "floor holds");
        // Moderate growth between thresholds → hold.
        assert_eq!(ctl.stage_depth(25_000_000 + RING_AIMD_IDLE_NS + 1), 2);
        assert_eq!(ctl.peak(), 4);
        assert_eq!(ctl.adjustments(), 3);
    }

    #[test]
    fn ring_depth_controller_caps_and_pins() {
        let mut ctl = RingDepthController::new(2, true, 4);
        let mut stall = 0u64;
        ctl.stage_depth(stall); // prime
        for _ in 0..10 {
            stall += 2 * RING_AIMD_STALL_STEP_NS;
            ctl.stage_depth(stall);
        }
        assert_eq!(ctl.current(), 4, "depth exceeded its cap");
        // Pinned controller never moves regardless of stall history.
        let mut pinned = RingDepthController::new(3, false, 8);
        for s in [0u64, 1_000_000_000, 1_000_000_000] {
            assert_eq!(pinned.stage_depth(s), 3);
        }
        assert_eq!(pinned.adjustments(), 0);
    }
}
