//! Transfer-concealed workflow pipeline (paper §4.2), CPU incarnation.
//!
//! The paper schedules each SV group's H2D → decompress → update →
//! compress → D2H chain onto a CUDA stream and overlaps chains across
//! streams; multiple GPUs process disjoint groups, all contending on one
//! PCIe link. Here (hardware substitution; see DESIGN.md):
//!
//! * a *device* is a set of worker threads,
//! * a device runs `streams` chains concurrently (`workers = devices *
//!   streams`) — stream count is the Fig. 12 knob,
//! * the shared PCIe link is a global [`Semaphore`] that fetch/store
//!   (memory-movement) sections must hold, so transfer contention behaves
//!   like the paper's multi-GPU starvation effect (§5.8) while
//!   (de)compression and gate application overlap freely.
//!
//! The environment vendors no tokio/rayon, so this is a dependency-free
//! scoped thread pool + work queue + condvar semaphore.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Counting semaphore (Mutex + Condvar; no external deps).
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Semaphore { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    pub fn acquire(&self) -> SemaphoreGuard<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        SemaphoreGuard { sem: self }
    }

    fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        self.cv.notify_one();
    }
}

/// RAII permit.
pub struct SemaphoreGuard<'a> {
    sem: &'a Semaphore,
}

impl Drop for SemaphoreGuard<'_> {
    fn drop(&mut self) {
        self.sem.release();
    }
}

/// Pipeline concurrency shape.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Logical devices (paper: GPUs). Fig. 13 knob.
    pub devices: usize,
    /// Concurrent group chains per device (paper: CUDA streams). Fig. 12 knob.
    pub streams: usize,
    /// Permits on the shared transfer link (paper: PCIe). One permit per
    /// device models independent DMA engines contending on the link
    /// arbiter; the default is `devices`.
    pub transfer_slots: usize,
}

impl PipelineConfig {
    pub fn new(devices: usize, streams: usize) -> Self {
        PipelineConfig { devices: devices.max(1), streams: streams.max(1), transfer_slots: devices.max(1) }
    }

    /// Fully sequential (streams = devices = 1).
    pub fn sequential() -> Self {
        Self::new(1, 1)
    }

    pub fn workers(&self) -> usize {
        self.devices * self.streams
    }
}

/// Run `task` over items `0..n` on the pipeline's worker pool. Tasks pull
/// from a shared queue (dynamic load balance, like the paper's round-robin
/// stream assignment). The first error aborts remaining work and is
/// returned; panics propagate.
pub fn run_items<E, F>(cfg: PipelineConfig, n: usize, task: F) -> Result<(), E>
where
    E: Send,
    F: Fn(WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
    E: std::fmt::Debug,
{
    let transfer = Semaphore::new(cfg.transfer_slots);
    let queue: Mutex<VecDeque<usize>> = Mutex::new((0..n).collect());
    let failed: Mutex<Option<E>> = Mutex::new(None);
    let workers = cfg.workers().min(n.max(1));

    std::thread::scope(|scope| {
        for w in 0..workers {
            let queue = &queue;
            let failed = &failed;
            let transfer = &transfer;
            let task = &task;
            scope.spawn(move || loop {
                if failed.lock().unwrap().is_some() {
                    return;
                }
                let item = { queue.lock().unwrap().pop_front() };
                let Some(item) = item else { return };
                let ctx = WorkerCtx { worker: w, device: w % cfg.devices.max(1), transfer };
                if let Err(e) = task(ctx, item) {
                    let mut f = failed.lock().unwrap();
                    if f.is_none() {
                        *f = Some(e);
                    }
                    return;
                }
            });
        }
    });

    match failed.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-task context: which worker/device is running, and the shared
/// transfer link for fetch/store sections.
pub struct WorkerCtx<'a> {
    pub worker: usize,
    pub device: usize,
    transfer: &'a Semaphore,
}

impl WorkerCtx<'_> {
    /// Execute `f` while holding a transfer permit (the PCIe section).
    pub fn transfer<T>(&self, f: impl FnOnce() -> T) -> T {
        let _g = self.transfer.acquire();
        f()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_every_item_exactly_once() {
        let hits = Vec::from_iter((0..500).map(|_| AtomicUsize::new(0)));
        run_items::<(), _>(PipelineConfig::new(2, 4), 500, |_ctx, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            Ok(())
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_config_uses_one_worker() {
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(PipelineConfig::sequential(), 50, |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallelism_is_bounded_by_workers() {
        let cfg = PipelineConfig::new(2, 2);
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 64, |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(300));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert!(max_live.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn transfer_section_respects_slots() {
        let cfg = PipelineConfig { devices: 1, streams: 8, transfer_slots: 1 };
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 32, |ctx, _i| {
            ctx.transfer(|| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                max_live.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(100));
                live.fetch_sub(1, Ordering::SeqCst);
            });
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn first_error_aborts_and_propagates() {
        let done = AtomicUsize::new(0);
        let r = run_items::<String, _>(PipelineConfig::new(1, 2), 1000, |_ctx, i| {
            if i == 3 {
                return Err("boom".to_string());
            }
            done.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(50));
            Ok(())
        });
        assert_eq!(r.unwrap_err(), "boom");
        assert!(done.load(Ordering::Relaxed) < 1000);
    }

    #[test]
    fn devices_assign_round_robin() {
        let cfg = PipelineConfig::new(4, 1);
        let seen = Mutex::new(std::collections::BTreeSet::new());
        run_items::<(), _>(cfg, 64, |ctx, _i| {
            seen.lock().unwrap().insert(ctx.device);
            std::thread::sleep(std::time::Duration::from_micros(100));
            Ok(())
        })
        .unwrap();
        assert!(seen.into_inner().unwrap().len() > 1);
    }

    #[test]
    fn zero_items_is_fine() {
        run_items::<(), _>(PipelineConfig::new(2, 2), 0, |_ctx, _i| Ok(())).unwrap();
    }
}
