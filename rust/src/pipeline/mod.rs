//! Transfer-concealed workflow pipeline (paper §4.2), CPU incarnation.
//!
//! The paper schedules each SV group's H2D → decompress → update →
//! compress → D2H chain onto a CUDA stream and overlaps chains across
//! streams; multiple GPUs process disjoint groups, all contending on one
//! PCIe link. Here (hardware substitution; see DESIGN.md):
//!
//! * a *device* is a set of worker threads,
//! * a device runs `streams` chains concurrently (`workers = devices *
//!   streams`) — stream count is the Fig. 12 knob,
//! * the shared PCIe link is a global [`Semaphore`] that fetch/store
//!   (memory-movement) sections must hold, so transfer contention behaves
//!   like the paper's multi-GPU starvation effect (§5.8) while
//!   (de)compression and gate application overlap freely.
//!
//! The environment vendors no tokio/rayon, so this is a dependency-free
//! scoped thread pool + work queue + condvar semaphore.
//!
//! Each worker owns a [`Scratch`] arena (group planes, codec buffers,
//! recycled block payloads) drawn from the caller's [`ScratchPool`], so a
//! group chain's steady state performs no heap allocation: the pool
//! outlives individual [`run_items`] calls and buffers carry over from
//! stage to stage (§Perf, DESIGN.md).

use crate::compress::CodecScratch;
use crate::memory::BlockPayload;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

mod phase_pool;
pub use phase_pool::{
    PhasePool, RingDepthController, MAX_EPOCHS_IN_FLIGHT, RING_AIMD_IDLE_NS,
    RING_AIMD_STALL_STEP_NS, RING_DEPTH_MAX, RING_DEPTH_MIN,
};

/// Counting semaphore (Mutex + Condvar; no external deps).
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Semaphore { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    pub fn acquire(&self) -> SemaphoreGuard<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        SemaphoreGuard { sem: self }
    }

    fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        self.cv.notify_one();
    }
}

/// RAII permit.
pub struct SemaphoreGuard<'a> {
    sem: &'a Semaphore,
}

impl Drop for SemaphoreGuard<'_> {
    fn drop(&mut self) {
        self.sem.release();
    }
}

/// Pipeline concurrency shape.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Logical devices (paper: GPUs). Fig. 13 knob.
    pub devices: usize,
    /// Concurrent group chains per device (paper: CUDA streams). Fig. 12 knob.
    pub streams: usize,
    /// Permits on the shared transfer link (paper: PCIe). One permit per
    /// device models independent DMA engines contending on the link
    /// arbiter; the default is `devices`.
    pub transfer_slots: usize,
}

impl PipelineConfig {
    pub fn new(devices: usize, streams: usize) -> Self {
        PipelineConfig { devices: devices.max(1), streams: streams.max(1), transfer_slots: devices.max(1) }
    }

    /// Fully sequential (streams = devices = 1).
    pub fn sequential() -> Self {
        Self::new(1, 1)
    }

    pub fn workers(&self) -> usize {
        self.devices * self.streams
    }
}

/// Per-worker reusable buffers for the group-chain hot path. Owned by a
/// [`ScratchPool`] so capacity survives across [`run_items`] calls (i.e.
/// across pipeline stages): after the first stage warms the arena, a
/// steady-state group chain performs zero group-plane heap allocations.
///
/// Ownership rules (see DESIGN.md §Perf): the worker that holds the
/// `Scratch` has exclusive access for the duration of one item; `re`/`im`
/// are resized (never reallocated while capacity suffices) to the current
/// group length; `payloads` recycles compressed-block byte buffers between
/// `BlockStore::take` and `compress_into` so the bytes flow
/// store → worker → store without fresh allocations.
#[derive(Default)]
pub struct Scratch {
    /// Gathered group plane, real part (cache-line-aligned backing so
    /// vector loads over the plane start aligned; derefs to `[f64]`).
    pub re: crate::simd::AlignedF64,
    /// Gathered group plane, imaginary part.
    pub im: crate::simd::AlignedF64,
    /// Block ids of the current group (gather order).
    pub block_ids: Vec<usize>,
    /// Fetched payloads; their byte buffers are reused as compression
    /// outputs and handed back to the store.
    pub payloads: Vec<BlockPayload>,
    /// Codec intermediate buffers (codes, bitmap words, entropy bytes).
    pub codec: CodecScratch,
    /// How many times `ensure_planes` had to grow the plane backing
    /// storage — the arena-reuse counter surfaced in `Metrics`.
    pub plane_grows: u64,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize the group planes to exactly `len` amplitudes, reporting
    /// whether backing storage had to grow (steady state: never).
    pub fn ensure_planes(&mut self, len: usize) -> bool {
        let grew = len > self.re.capacity() || len > self.im.capacity();
        if grew {
            self.plane_grows += 1;
        }
        self.re.resize(len, 0.0);
        self.im.resize(len, 0.0);
        debug_assert!(
            crate::simd::is_aligned_64(self.re.as_slice().as_ptr())
                && crate::simd::is_aligned_64(self.im.as_slice().as_ptr()),
            "scratch planes must stay cache-line aligned"
        );
        grew
    }
}

/// A set of per-worker [`Scratch`] arenas. Create one per engine run with
/// `workers` slots and pass it to every [`run_items`] call so buffers are
/// reused across stages. Worker `w` always gets slot `w`.
pub struct ScratchPool {
    slots: Vec<Mutex<Scratch>>,
}

impl ScratchPool {
    pub fn new(workers: usize) -> Self {
        ScratchPool { slots: (0..workers.max(1)).map(|_| Mutex::new(Scratch::new())).collect() }
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Total plane-growth events across all slots (for the arena-reuse
    /// assertions and `Metrics::scratch_grows`).
    pub fn total_plane_grows(&self) -> u64 {
        self.slots.iter().map(|s| s.lock().unwrap().plane_grows).sum()
    }
}

/// Run `task` over items `0..n` on the pipeline's worker pool. Tasks pull
/// from a shared queue (dynamic load balance, like the paper's round-robin
/// stream assignment). Each worker thread checks out its [`Scratch`] slot
/// from `pool` for the whole call. The first error aborts remaining work
/// and is returned; panics propagate.
pub fn run_items<E, F>(cfg: PipelineConfig, n: usize, pool: &ScratchPool, task: F) -> Result<(), E>
where
    E: Send,
    F: Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
    E: std::fmt::Debug,
{
    let transfer = Semaphore::new(cfg.transfer_slots);
    let queue: Mutex<VecDeque<usize>> = Mutex::new((0..n).collect());
    let failed: Mutex<Option<E>> = Mutex::new(None);
    let workers = cfg.workers().min(n.max(1)).min(pool.workers());

    std::thread::scope(|scope| {
        for w in 0..workers {
            let queue = &queue;
            let failed = &failed;
            let transfer = &transfer;
            let task = &task;
            let pool = &pool;
            scope.spawn(move || {
                let mut scratch = pool.slots[w].lock().unwrap();
                loop {
                    if failed.lock().unwrap().is_some() {
                        return;
                    }
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some(item) = item else { return };
                    let mut ctx = WorkerCtx {
                        worker: w,
                        device: w % cfg.devices.max(1),
                        link: TransferLink { sem: transfer },
                        scratch: &mut *scratch,
                    };
                    if let Err(e) = task(&mut ctx, item) {
                        let mut f = failed.lock().unwrap();
                        if f.is_none() {
                            *f = Some(e);
                        }
                        return;
                    }
                }
            });
        }
    });

    match failed.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// Overlapped group chains: the decode → apply → encode software pipeline.
// ---------------------------------------------------------------------------

/// How long a phase thread dozes between handshake re-checks. Also bounds
/// how stale an abort flag can go unnoticed.
const HANDSHAKE_POLL: Duration = Duration::from_micros(500);

/// Slot lifecycle in a worker's scratch ring. Transitions only move
/// forward (`Free → Decoded → Applied → Free`), each performed by exactly
/// one of the worker's three phase threads, so the slot's [`Scratch`] is
/// never touched by two threads at once.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum SlotPhase {
    Free,
    Decoded,
    Applied,
}

/// Handshake state for one worker's slot ring (next-slot protocol: every
/// phase walks the ring in order, so FIFO item order is structural).
struct RingState {
    status: Vec<SlotPhase>,
    /// Item id occupying each slot (valid while status != Free).
    items: Vec<usize>,
    decode_done: bool,
    apply_done: bool,
}

struct RingCtrl {
    state: Mutex<RingState>,
    cv: Condvar,
}

impl RingCtrl {
    fn new(depth: usize) -> Self {
        RingCtrl {
            state: Mutex::new(RingState {
                status: vec![SlotPhase::Free; depth],
                items: vec![0; depth],
                decode_done: false,
                apply_done: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Re-arm the ring for a fresh stage at (possibly different) `depth`:
    /// all slots `Free`, done flags cleared. Only called between stages,
    /// when no phase thread is touching the ring.
    fn reset(&self, depth: usize) {
        let mut st = self.state.lock().unwrap();
        st.status.clear();
        st.status.resize(depth, SlotPhase::Free);
        st.items.clear();
        st.items.resize(depth, 0);
        st.decode_done = false;
        st.apply_done = false;
    }
}

/// Unwind-safe phase teardown: marks the phase's done flag — and, when
/// the thread is panicking, the global abort — on EVERY exit path, so a
/// panic inside a phase closure (gate kernel assert, codec bug) tears the
/// pipeline down and propagates through `thread::scope` instead of
/// leaving sibling phase threads waiting forever on a flag that
/// straight-line code would never set.
struct PhaseExit<'a> {
    ctrl: &'a RingCtrl,
    abort: &'a AtomicBool,
    mark: fn(&mut RingState),
}

impl Drop for PhaseExit<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.abort.store(true, Ordering::Release);
        }
        // Phase closures never panic while holding the state lock, so it
        // cannot be poisoned here.
        let mut st = self.ctrl.state.lock().unwrap();
        (self.mark)(&mut st);
        drop(st);
        self.ctrl.cv.notify_all();
    }
}

/// Per-worker rings of [`Scratch`] slots for the overlapped pipeline.
/// Like [`ScratchPool`], it outlives individual driver calls so plane /
/// payload / codec buffers carry over from stage to stage; `depth` slots
/// per worker bound how many group chains can be in flight per worker
/// (`depth >= 2` enables decode/apply/encode overlap, 1 degenerates to a
/// hand-off-serialized chain).
pub struct RingPool {
    rings: Vec<Vec<Mutex<Scratch>>>,
    depth: usize,
}

impl RingPool {
    pub fn new(workers: usize, depth: usize) -> Self {
        let depth = depth.max(1);
        RingPool {
            rings: (0..workers.max(1))
                .map(|_| (0..depth).map(|_| Mutex::new(Scratch::new())).collect())
                .collect(),
            depth,
        }
    }

    pub fn workers(&self) -> usize {
        self.rings.len()
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total plane-growth events across every slot of every ring (the
    /// arena-reuse counter surfaced as `Metrics::scratch_grows`).
    pub fn total_plane_grows(&self) -> u64 {
        self.rings
            .iter()
            .flatten()
            .map(|s| s.lock().unwrap().plane_grows)
            .sum()
    }
}

/// Overlap instrumentation filled by [`run_items_overlapped`]: handshake
/// stall time per phase plus how often the apply phase found its next
/// group already decoded (the "overhead concealed" signal).
#[derive(Default)]
pub struct OverlapStats {
    /// Apply found the next slot already `Decoded` — zero wait.
    pub decode_ahead_hits: AtomicU64,
    /// Decode waited for a `Free` slot (encode back-pressure).
    pub stall_decode_ns: AtomicU64,
    /// Apply waited for a `Decoded` slot (fetch/decompress behind).
    pub stall_apply_ns: AtomicU64,
    /// Encode waited for an `Applied` slot (apply behind).
    pub stall_encode_ns: AtomicU64,
    /// Stages dispatched through a persistent [`PhasePool`] (each one a
    /// work-descriptor handoff to already-running phase threads, where the
    /// scoped driver would have spawned and joined 3×workers threads).
    pub stage_handoffs: AtomicU64,
}

impl OverlapStats {
    pub fn total_stall_ns(&self) -> u64 {
        self.stall_decode_ns.load(Ordering::Relaxed)
            + self.stall_apply_ns.load(Ordering::Relaxed)
            + self.stall_encode_ns.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Shared phase-loop bodies. One function per phase, used by BOTH drivers:
// `run_items_overlapped` runs them on scoped threads spawned per call, the
// persistent `PhasePool` runs them on long-lived threads fed per-stage work
// descriptors — so the handshake protocol (and its model-checked behaviour)
// is a single implementation.
// ---------------------------------------------------------------------------

/// Everything a phase loop needs that is stable for one stage. `slots` is
/// already truncated to the stage's effective ring depth.
pub(crate) struct PhaseEnv<'a> {
    pub(crate) ctrl: &'a RingCtrl,
    pub(crate) slots: &'a [Mutex<Scratch>],
    pub(crate) stats: &'a OverlapStats,
    pub(crate) abort: &'a AtomicBool,
    pub(crate) transfer: &'a Semaphore,
    pub(crate) worker: usize,
    pub(crate) device: usize,
}

/// Record the first error and raise the global abort flag.
pub(crate) fn record_fail<E>(failed: &Mutex<Option<E>>, abort: &AtomicBool, e: E) {
    let mut f = failed.lock().unwrap();
    if f.is_none() {
        *f = Some(e);
    }
    drop(f);
    abort.store(true, Ordering::Release);
}

/// Decode phase: shared queue → `Free` slot → `Decoded`.
pub(crate) fn decode_phase_loop<E: Send>(
    env: &PhaseEnv<'_>,
    queue: &Mutex<VecDeque<usize>>,
    failed: &Mutex<Option<E>>,
    decode: &(dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync),
) {
    let depth = env.slots.len();
    let _exit =
        PhaseExit { ctrl: env.ctrl, abort: env.abort, mark: |st: &mut RingState| st.decode_done = true };
    let mut slot = 0usize;
    loop {
        if env.abort.load(Ordering::Acquire) {
            break;
        }
        let item = { queue.lock().unwrap().pop_front() };
        let Some(item) = item else { break };
        {
            let mut st = env.ctrl.state.lock().unwrap();
            if st.status[slot] != SlotPhase::Free {
                let t0 = Instant::now();
                while st.status[slot] != SlotPhase::Free && !env.abort.load(Ordering::Acquire) {
                    st = env.ctrl.cv.wait_timeout(st, HANDSHAKE_POLL).unwrap().0;
                }
                env.stats
                    .stall_decode_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            if st.status[slot] != SlotPhase::Free {
                break; // aborted while waiting
            }
        }
        let r = {
            let mut scratch = env.slots[slot].lock().unwrap();
            let mut ctx = WorkerCtx {
                worker: env.worker,
                device: env.device,
                link: TransferLink { sem: env.transfer },
                scratch: &mut *scratch,
            };
            decode(&mut ctx, item)
        };
        match r {
            Ok(()) => {
                let mut st = env.ctrl.state.lock().unwrap();
                st.status[slot] = SlotPhase::Decoded;
                st.items[slot] = item;
                drop(st);
                env.ctrl.cv.notify_all();
                slot = (slot + 1) % depth;
            }
            Err(e) => {
                record_fail(failed, env.abort, e);
                break;
            }
        }
    }
}

/// Apply phase: `Decoded` slot → `Applied`.
pub(crate) fn apply_phase_loop<E: Send>(
    env: &PhaseEnv<'_>,
    failed: &Mutex<Option<E>>,
    apply: &(dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync),
) {
    let depth = env.slots.len();
    let _exit =
        PhaseExit { ctrl: env.ctrl, abort: env.abort, mark: |st: &mut RingState| st.apply_done = true };
    let mut slot = 0usize;
    loop {
        let item;
        {
            let mut st = env.ctrl.state.lock().unwrap();
            if st.status[slot] == SlotPhase::Decoded {
                env.stats.decode_ahead_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                let t0 = Instant::now();
                while st.status[slot] != SlotPhase::Decoded
                    && !st.decode_done
                    && !env.abort.load(Ordering::Acquire)
                {
                    st = env.ctrl.cv.wait_timeout(st, HANDSHAKE_POLL).unwrap().0;
                }
                env.stats
                    .stall_apply_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            if st.status[slot] != SlotPhase::Decoded {
                break; // decode finished (or abort): ring drained
            }
            item = st.items[slot];
        }
        if env.abort.load(Ordering::Acquire) {
            break;
        }
        let r = {
            let mut scratch = env.slots[slot].lock().unwrap();
            let mut ctx = WorkerCtx {
                worker: env.worker,
                device: env.device,
                link: TransferLink { sem: env.transfer },
                scratch: &mut *scratch,
            };
            apply(&mut ctx, item)
        };
        match r {
            Ok(()) => {
                let mut st = env.ctrl.state.lock().unwrap();
                st.status[slot] = SlotPhase::Applied;
                drop(st);
                env.ctrl.cv.notify_all();
                slot = (slot + 1) % depth;
            }
            Err(e) => {
                record_fail(failed, env.abort, e);
                break;
            }
        }
    }
}

/// Encode phase: `Applied` slot → `Free`.
pub(crate) fn encode_phase_loop<E: Send>(
    env: &PhaseEnv<'_>,
    failed: &Mutex<Option<E>>,
    encode: &(dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync),
) {
    let depth = env.slots.len();
    let _exit = PhaseExit { ctrl: env.ctrl, abort: env.abort, mark: |_st: &mut RingState| {} };
    let mut slot = 0usize;
    loop {
        let item;
        {
            let mut st = env.ctrl.state.lock().unwrap();
            if st.status[slot] != SlotPhase::Applied {
                let t0 = Instant::now();
                while st.status[slot] != SlotPhase::Applied
                    && !st.apply_done
                    && !env.abort.load(Ordering::Acquire)
                {
                    st = env.ctrl.cv.wait_timeout(st, HANDSHAKE_POLL).unwrap().0;
                }
                env.stats
                    .stall_encode_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            if st.status[slot] != SlotPhase::Applied {
                break; // apply finished (or abort): nothing left
            }
            item = st.items[slot];
        }
        if env.abort.load(Ordering::Acquire) {
            break;
        }
        let r = {
            let mut scratch = env.slots[slot].lock().unwrap();
            let mut ctx = WorkerCtx {
                worker: env.worker,
                device: env.device,
                link: TransferLink { sem: env.transfer },
                scratch: &mut *scratch,
            };
            encode(&mut ctx, item)
        };
        match r {
            Ok(()) => {
                let mut st = env.ctrl.state.lock().unwrap();
                st.status[slot] = SlotPhase::Free;
                drop(st);
                env.ctrl.cv.notify_all();
                slot = (slot + 1) % depth;
            }
            Err(e) => {
                record_fail(failed, env.abort, e);
                break;
            }
        }
    }
}

/// Run `0..n` items through a three-phase software pipeline on the
/// configured workers: per worker, a *decode* thread pulls items from the
/// shared queue and fills ring slots, an *apply* thread consumes decoded
/// slots, and an *encode* thread drains applied slots back to `Free` —
/// so while group *g* is being applied, *g+1* is already being fetched /
/// decompressed and *g−1* compressed / stored.
///
/// Identical results to [`run_items`] running `decode; apply; encode` per
/// item are structural: each item passes through all three phases in
/// order on the same `Scratch`, items are disjoint, and slot handoffs are
/// full memory barriers (mutex). The first phase error aborts all workers
/// and is returned.
pub fn run_items_overlapped<E, D, A, S>(
    cfg: PipelineConfig,
    n: usize,
    pool: &RingPool,
    stats: &OverlapStats,
    decode: D,
    apply: A,
    encode: S,
) -> Result<(), E>
where
    E: Send + std::fmt::Debug,
    D: Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
    A: Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
    S: Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
{
    let transfer = Semaphore::new(cfg.transfer_slots);
    let queue: Mutex<VecDeque<usize>> = Mutex::new((0..n).collect());
    let failed: Mutex<Option<E>> = Mutex::new(None);
    let abort = AtomicBool::new(false);
    let workers = cfg.workers().min(n.max(1)).min(pool.workers());
    let depth = pool.depth();
    let ctrls: Vec<RingCtrl> = (0..workers).map(|_| RingCtrl::new(depth)).collect();

    std::thread::scope(|scope| {
        for w in 0..workers {
            let ctrl = &ctrls[w];
            let slots = &pool.rings[w];
            let queue = &queue;
            let failed = &failed;
            let abort = &abort;
            let transfer = &transfer;
            let device = w % cfg.devices.max(1);
            let (decode, apply, encode) = (&decode, &apply, &encode);

            // ---- Decode thread: queue → Free slot → Decoded ----
            scope.spawn(move || {
                let env = PhaseEnv {
                    ctrl,
                    slots: &slots[..depth],
                    stats,
                    abort,
                    transfer,
                    worker: w,
                    device,
                };
                decode_phase_loop(&env, queue, failed, decode);
            });

            // ---- Apply thread: Decoded slot → Applied ----
            scope.spawn(move || {
                let env = PhaseEnv {
                    ctrl,
                    slots: &slots[..depth],
                    stats,
                    abort,
                    transfer,
                    worker: w,
                    device,
                };
                apply_phase_loop(&env, failed, apply);
            });

            // ---- Encode thread: Applied slot → Free ----
            scope.spawn(move || {
                let env = PhaseEnv {
                    ctrl,
                    slots: &slots[..depth],
                    stats,
                    abort,
                    transfer,
                    worker: w,
                    device,
                };
                encode_phase_loop(&env, failed, encode);
            });
        }
    });

    match failed.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Run `f` over both amplitude planes in disjoint, aligned chunks of
/// `chunk_len` amplitudes, split across up to `workers` scoped worker
/// threads — the worker-parallel *plane sweep* primitive the batched gate
/// kernels use (`gates::fused`).
///
/// Each worker owns a contiguous span of whole chunks (disjoint index
/// ranges, no locking); `f` receives the chunk's base amplitude index and
/// mutable sub-slices of both planes. `chunk_len` must divide `re.len()`
/// (both are powers of two on every call site). With `workers <= 1` — or
/// a single chunk — the sweep runs inline on the calling thread, so the
/// sequential path has zero thread overhead.
pub fn run_plane_chunks<F>(workers: usize, chunk_len: usize, re: &mut [f64], im: &mut [f64], f: F)
where
    F: Fn(usize, &mut [f64], &mut [f64]) + Sync,
{
    let len = re.len();
    debug_assert_eq!(len, im.len());
    debug_assert!(chunk_len > 0 && len % chunk_len == 0);
    let n_chunks = len / chunk_len;
    let workers = workers.max(1).min(n_chunks.max(1));
    if workers <= 1 {
        for (ci, (rc, ic)) in
            re.chunks_mut(chunk_len).zip(im.chunks_mut(chunk_len)).enumerate()
        {
            f(ci * chunk_len, rc, ic);
        }
        return;
    }
    let per = n_chunks / workers;
    let extra = n_chunks % workers;
    std::thread::scope(|scope| {
        let mut re_rest = re;
        let mut im_rest = im;
        let mut base = 0usize;
        // Spawn workers - 1 threads; the calling thread takes the last
        // span itself instead of idling at the scope join.
        for w in 0..workers - 1 {
            let span = (per + usize::from(w < extra)) * chunk_len;
            let (r_span, r_next) = re_rest.split_at_mut(span);
            let (i_span, i_next) = im_rest.split_at_mut(span);
            re_rest = r_next;
            im_rest = i_next;
            let f = &f;
            let start = base;
            scope.spawn(move || {
                for (ci, (rc, ic)) in
                    r_span.chunks_mut(chunk_len).zip(i_span.chunks_mut(chunk_len)).enumerate()
                {
                    f(start + ci * chunk_len, rc, ic);
                }
            });
            base += span;
        }
        for (ci, (rc, ic)) in
            re_rest.chunks_mut(chunk_len).zip(im_rest.chunks_mut(chunk_len)).enumerate()
        {
            f(base + ci * chunk_len, rc, ic);
        }
    });
}

/// Copyable handle to the shared transfer link; lets tasks enter transfer
/// sections while holding disjoint borrows of the scratch arena.
#[derive(Clone, Copy)]
pub struct TransferLink<'a> {
    sem: &'a Semaphore,
}

impl TransferLink<'_> {
    /// Execute `f` while holding a transfer permit (the PCIe section).
    pub fn section<T>(&self, f: impl FnOnce() -> T) -> T {
        let _g = self.sem.acquire();
        f()
    }
}

/// Per-task context: which worker/device is running, the shared transfer
/// link for fetch/store sections, and the worker's scratch arena.
pub struct WorkerCtx<'a> {
    pub worker: usize,
    pub device: usize,
    pub link: TransferLink<'a>,
    pub scratch: &'a mut Scratch,
}

impl WorkerCtx<'_> {
    /// Execute `f` while holding a transfer permit (the PCIe section).
    pub fn transfer<T>(&self, f: impl FnOnce() -> T) -> T {
        self.link.section(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_every_item_exactly_once() {
        let hits = Vec::from_iter((0..500).map(|_| AtomicUsize::new(0)));
        run_items::<(), _>(PipelineConfig::new(2, 4), 500, &ScratchPool::new(8), |_ctx, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            Ok(())
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_config_uses_one_worker() {
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(PipelineConfig::sequential(), 50, &ScratchPool::new(1), |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallelism_is_bounded_by_workers() {
        let cfg = PipelineConfig::new(2, 2);
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 64, &ScratchPool::new(cfg.workers()), |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(300));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert!(max_live.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn transfer_section_respects_slots() {
        let cfg = PipelineConfig { devices: 1, streams: 8, transfer_slots: 1 };
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 32, &ScratchPool::new(cfg.workers()), |ctx, _i| {
            ctx.transfer(|| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                max_live.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(100));
                live.fetch_sub(1, Ordering::SeqCst);
            });
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn first_error_aborts_and_propagates() {
        let done = AtomicUsize::new(0);
        let r = run_items::<String, _>(PipelineConfig::new(1, 2), 1000, &ScratchPool::new(2), |_ctx, i| {
            if i == 3 {
                return Err("boom".to_string());
            }
            done.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(50));
            Ok(())
        });
        assert_eq!(r.unwrap_err(), "boom");
        assert!(done.load(Ordering::Relaxed) < 1000);
    }

    #[test]
    fn devices_assign_round_robin() {
        let cfg = PipelineConfig::new(4, 1);
        let seen = Mutex::new(std::collections::BTreeSet::new());
        run_items::<(), _>(cfg, 64, &ScratchPool::new(cfg.workers()), |ctx, _i| {
            seen.lock().unwrap().insert(ctx.device);
            std::thread::sleep(std::time::Duration::from_micros(100));
            Ok(())
        })
        .unwrap();
        assert!(seen.into_inner().unwrap().len() > 1);
    }

    #[test]
    fn zero_items_is_fine() {
        run_items::<(), _>(PipelineConfig::new(2, 2), 0, &ScratchPool::new(4), |_ctx, _i| Ok(())).unwrap();
    }

    #[test]
    fn plane_chunks_cover_plane_exactly_once() {
        for workers in [1usize, 2, 3, 8, 64] {
            let len = 1usize << 10;
            let mut re = vec![0.0f64; len];
            let mut im = vec![0.0f64; len];
            run_plane_chunks(workers, 1 << 4, &mut re, &mut im, |base, rc, ic| {
                assert_eq!(rc.len(), 1 << 4);
                assert_eq!(ic.len(), rc.len());
                assert_eq!(base % rc.len(), 0);
                for (i, v) in rc.iter_mut().enumerate() {
                    *v += (base + i) as f64;
                }
                for v in ic.iter_mut() {
                    *v += 1.0;
                }
            });
            for (i, (&r, &v)) in re.iter().zip(im.iter()).enumerate() {
                assert_eq!(r, i as f64, "workers={workers}");
                assert_eq!(v, 1.0, "workers={workers}");
            }
        }
    }

    #[test]
    fn plane_chunks_single_chunk_runs_inline() {
        let len = 64usize;
        let mut re = vec![0.0f64; len];
        let mut im = vec![0.0f64; len];
        let tid = std::thread::current().id();
        run_plane_chunks(8, len, &mut re, &mut im, |base, rc, _ic| {
            assert_eq!(base, 0);
            assert_eq!(rc.len(), len);
            assert_eq!(std::thread::current().id(), tid);
        });
    }

    #[test]
    fn ensure_planes_grows_only_on_capacity_increase() {
        let mut s = Scratch::new();
        assert!(s.ensure_planes(1024)); // cold arena grows
        assert_eq!(s.plane_grows, 1);
        assert_eq!(s.re.len(), 1024);
        assert!(!s.ensure_planes(512)); // shrink: no growth
        assert_eq!(s.re.len(), 512);
        assert!(!s.ensure_planes(1024)); // back within capacity: no growth
        assert_eq!(s.plane_grows, 1);
        assert!(s.ensure_planes(4096)); // genuinely larger: grows once more
        assert_eq!(s.plane_grows, 2);
    }

    #[test]
    fn overlapped_runs_every_item_through_all_three_phases_in_order() {
        // Each item must see decode -> apply -> encode exactly once, and
        // the scratch slot must carry state between the phases.
        for (workers, depth, n) in
            [(1usize, 1usize, 7usize), (1, 2, 33), (2, 3, 64), (4, 2, 100)]
        {
            let cfg = PipelineConfig::new(1, workers);
            let pool = RingPool::new(cfg.workers(), depth);
            let stats = OverlapStats::default();
            let out: Mutex<Vec<(usize, f64)>> = Mutex::new(Vec::new());
            run_items_overlapped::<(), _, _, _>(
                cfg,
                n,
                &pool,
                &stats,
                |ctx, i| {
                    ctx.scratch.ensure_planes(4);
                    ctx.scratch.re[0] = i as f64;
                    Ok(())
                },
                |ctx, i| {
                    assert_eq!(ctx.scratch.re[0], i as f64, "apply saw wrong slot");
                    ctx.scratch.re[0] *= 10.0;
                    Ok(())
                },
                |ctx, i| {
                    assert_eq!(ctx.scratch.re[0], 10.0 * i as f64, "encode saw wrong slot");
                    out.lock().unwrap().push((i, ctx.scratch.re[0]));
                    Ok(())
                },
            )
            .unwrap();
            let mut got = out.into_inner().unwrap();
            assert_eq!(got.len(), n, "workers={workers} depth={depth}");
            got.sort_unstable_by_key(|&(i, _)| i);
            for (i, (item, v)) in got.iter().enumerate() {
                assert_eq!(*item, i);
                assert_eq!(*v, 10.0 * i as f64);
            }
        }
    }

    #[test]
    fn overlapped_phases_actually_overlap() {
        // With depth 2 and a single worker, decode of item i+1 must be
        // able to run while apply of item i is still in progress.
        let cfg = PipelineConfig::sequential();
        let pool = RingPool::new(1, 2);
        let stats = OverlapStats::default();
        let live = AtomicUsize::new(0);
        let max_live = AtomicUsize::new(0);
        // Fast decode/encode around a slow apply: decode runs ahead of
        // apply (so decode-ahead hits accrue) and overlaps it in time.
        let enter = |micros: u64| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(micros));
            live.fetch_sub(1, Ordering::SeqCst);
        };
        run_items_overlapped::<(), _, _, _>(
            cfg,
            24,
            &pool,
            &stats,
            |_ctx, _i| {
                enter(300);
                Ok(())
            },
            |_ctx, _i| {
                enter(2000);
                Ok(())
            },
            |_ctx, _i| {
                enter(300);
                Ok(())
            },
        )
        .unwrap();
        assert!(
            max_live.load(Ordering::SeqCst) > 1,
            "phases never overlapped on a depth-2 ring"
        );
        assert!(stats.decode_ahead_hits.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn overlapped_error_in_each_phase_aborts_and_propagates() {
        for phase in 0..3usize {
            let cfg = PipelineConfig::new(1, 2);
            let pool = RingPool::new(cfg.workers(), 2);
            let stats = OverlapStats::default();
            let boom = move |p: usize, i: usize| -> Result<(), String> {
                if p == phase && i == 5 {
                    Err(format!("boom-{p}"))
                } else {
                    Ok(())
                }
            };
            let r = run_items_overlapped::<String, _, _, _>(
                cfg,
                200,
                &pool,
                &stats,
                |_ctx, i| boom(0, i),
                |_ctx, i| boom(1, i),
                |_ctx, i| boom(2, i),
            );
            assert_eq!(r.unwrap_err(), format!("boom-{phase}"));
        }
    }

    #[test]
    fn overlapped_panic_in_a_phase_propagates_instead_of_hanging() {
        // A panicking phase closure must tear the pipeline down (abort +
        // done flags via PhaseExit) so thread::scope re-raises the panic;
        // before the exit guards, sibling phases waited forever.
        for phase in 0..3usize {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let pool = RingPool::new(1, 2);
                let stats = OverlapStats::default();
                let _ = run_items_overlapped::<(), _, _, _>(
                    PipelineConfig::sequential(),
                    16,
                    &pool,
                    &stats,
                    |_c, i| {
                        assert!(!(phase == 0 && i == 3), "kaboom-decode");
                        Ok(())
                    },
                    |_c, i| {
                        assert!(!(phase == 1 && i == 3), "kaboom-apply");
                        Ok(())
                    },
                    |_c, i| {
                        assert!(!(phase == 2 && i == 3), "kaboom-encode");
                        Ok(())
                    },
                );
            }));
            assert!(caught.is_err(), "phase {phase} panic was swallowed or hung");
        }
    }

    #[test]
    fn overlapped_zero_items_is_fine() {
        let pool = RingPool::new(2, 2);
        let stats = OverlapStats::default();
        run_items_overlapped::<(), _, _, _>(
            PipelineConfig::new(1, 2),
            0,
            &pool,
            &stats,
            |_c, _i| Ok(()),
            |_c, _i| Ok(()),
            |_c, _i| Ok(()),
        )
        .unwrap();
    }

    #[test]
    fn ring_pool_persists_scratch_across_calls() {
        let pool = RingPool::new(1, 2);
        let stats = OverlapStats::default();
        for _round in 0..3 {
            run_items_overlapped::<(), _, _, _>(
                PipelineConfig::sequential(),
                8,
                &pool,
                &stats,
                |ctx, _i| {
                    ctx.scratch.ensure_planes(1024);
                    Ok(())
                },
                |_c, _i| Ok(()),
                |_c, _i| Ok(()),
            )
            .unwrap();
        }
        // Each ring slot grows at most once, ever — not once per round.
        assert!(pool.total_plane_grows() <= 2);
        assert!(pool.total_plane_grows() >= 1);
    }

    #[test]
    fn overlapped_transfer_sections_respect_slots() {
        let cfg = PipelineConfig { devices: 1, streams: 4, transfer_slots: 1 };
        let pool = RingPool::new(cfg.workers(), 2);
        let stats = OverlapStats::default();
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items_overlapped::<(), _, _, _>(
            cfg,
            32,
            &pool,
            &stats,
            |ctx, _i| {
                ctx.transfer(|| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    max_live.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    live.fetch_sub(1, Ordering::SeqCst);
                });
                Ok(())
            },
            |_c, _i| Ok(()),
            |ctx, _i| {
                ctx.transfer(|| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    max_live.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    live.fetch_sub(1, Ordering::SeqCst);
                });
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scratch_pool_persists_across_run_items_calls() {
        // The arena must survive stage boundaries: the second call sees the
        // capacity warmed by the first, so no plane growth happens.
        let cfg = PipelineConfig::new(1, 2);
        let pool = ScratchPool::new(cfg.workers());
        for _round in 0..3 {
            run_items::<(), _>(cfg, 16, &pool, |ctx, _i| {
                ctx.scratch.ensure_planes(2048);
                Ok(())
            })
            .unwrap();
        }
        // At most one growth per worker, ever — not one per round or item.
        assert!(pool.total_plane_grows() <= cfg.workers() as u64);
        assert!(pool.total_plane_grows() >= 1);
    }
}
