//! Transfer-concealed workflow pipeline (paper §4.2), CPU incarnation.
//!
//! The paper schedules each SV group's H2D → decompress → update →
//! compress → D2H chain onto a CUDA stream and overlaps chains across
//! streams; multiple GPUs process disjoint groups, all contending on one
//! PCIe link. Here (hardware substitution; see DESIGN.md):
//!
//! * a *device* is a set of worker threads,
//! * a device runs `streams` chains concurrently (`workers = devices *
//!   streams`) — stream count is the Fig. 12 knob,
//! * the shared PCIe link is a global [`Semaphore`] that fetch/store
//!   (memory-movement) sections must hold, so transfer contention behaves
//!   like the paper's multi-GPU starvation effect (§5.8) while
//!   (de)compression and gate application overlap freely.
//!
//! The environment vendors no tokio/rayon, so this is a dependency-free
//! scoped thread pool + work queue + condvar semaphore.
//!
//! Each worker owns a [`Scratch`] arena (group planes, codec buffers,
//! recycled block payloads) drawn from the caller's [`ScratchPool`], so a
//! group chain's steady state performs no heap allocation: the pool
//! outlives individual [`run_items`] calls and buffers carry over from
//! stage to stage (§Perf, DESIGN.md).

use crate::compress::CodecScratch;
use crate::memory::BlockPayload;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Counting semaphore (Mutex + Condvar; no external deps).
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Semaphore { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    pub fn acquire(&self) -> SemaphoreGuard<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        SemaphoreGuard { sem: self }
    }

    fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        self.cv.notify_one();
    }
}

/// RAII permit.
pub struct SemaphoreGuard<'a> {
    sem: &'a Semaphore,
}

impl Drop for SemaphoreGuard<'_> {
    fn drop(&mut self) {
        self.sem.release();
    }
}

/// Pipeline concurrency shape.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Logical devices (paper: GPUs). Fig. 13 knob.
    pub devices: usize,
    /// Concurrent group chains per device (paper: CUDA streams). Fig. 12 knob.
    pub streams: usize,
    /// Permits on the shared transfer link (paper: PCIe). One permit per
    /// device models independent DMA engines contending on the link
    /// arbiter; the default is `devices`.
    pub transfer_slots: usize,
}

impl PipelineConfig {
    pub fn new(devices: usize, streams: usize) -> Self {
        PipelineConfig { devices: devices.max(1), streams: streams.max(1), transfer_slots: devices.max(1) }
    }

    /// Fully sequential (streams = devices = 1).
    pub fn sequential() -> Self {
        Self::new(1, 1)
    }

    pub fn workers(&self) -> usize {
        self.devices * self.streams
    }
}

/// Per-worker reusable buffers for the group-chain hot path. Owned by a
/// [`ScratchPool`] so capacity survives across [`run_items`] calls (i.e.
/// across pipeline stages): after the first stage warms the arena, a
/// steady-state group chain performs zero group-plane heap allocations.
///
/// Ownership rules (see DESIGN.md §Perf): the worker that holds the
/// `Scratch` has exclusive access for the duration of one item; `re`/`im`
/// are resized (never reallocated while capacity suffices) to the current
/// group length; `payloads` recycles compressed-block byte buffers between
/// `BlockStore::take` and `compress_into` so the bytes flow
/// store → worker → store without fresh allocations.
#[derive(Default)]
pub struct Scratch {
    /// Gathered group plane, real part.
    pub re: Vec<f64>,
    /// Gathered group plane, imaginary part.
    pub im: Vec<f64>,
    /// Block ids of the current group (gather order).
    pub block_ids: Vec<usize>,
    /// Fetched payloads; their byte buffers are reused as compression
    /// outputs and handed back to the store.
    pub payloads: Vec<BlockPayload>,
    /// Codec intermediate buffers (codes, bitmap words, entropy bytes).
    pub codec: CodecScratch,
    /// How many times `ensure_planes` had to grow the plane backing
    /// storage — the arena-reuse counter surfaced in `Metrics`.
    pub plane_grows: u64,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize the group planes to exactly `len` amplitudes, reporting
    /// whether backing storage had to grow (steady state: never).
    pub fn ensure_planes(&mut self, len: usize) -> bool {
        let grew = len > self.re.capacity() || len > self.im.capacity();
        if grew {
            self.plane_grows += 1;
        }
        self.re.resize(len, 0.0);
        self.im.resize(len, 0.0);
        grew
    }
}

/// A set of per-worker [`Scratch`] arenas. Create one per engine run with
/// `workers` slots and pass it to every [`run_items`] call so buffers are
/// reused across stages. Worker `w` always gets slot `w`.
pub struct ScratchPool {
    slots: Vec<Mutex<Scratch>>,
}

impl ScratchPool {
    pub fn new(workers: usize) -> Self {
        ScratchPool { slots: (0..workers.max(1)).map(|_| Mutex::new(Scratch::new())).collect() }
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Total plane-growth events across all slots (for the arena-reuse
    /// assertions and `Metrics::scratch_grows`).
    pub fn total_plane_grows(&self) -> u64 {
        self.slots.iter().map(|s| s.lock().unwrap().plane_grows).sum()
    }
}

/// Run `task` over items `0..n` on the pipeline's worker pool. Tasks pull
/// from a shared queue (dynamic load balance, like the paper's round-robin
/// stream assignment). Each worker thread checks out its [`Scratch`] slot
/// from `pool` for the whole call. The first error aborts remaining work
/// and is returned; panics propagate.
pub fn run_items<E, F>(cfg: PipelineConfig, n: usize, pool: &ScratchPool, task: F) -> Result<(), E>
where
    E: Send,
    F: Fn(&mut WorkerCtx<'_>, usize) -> Result<(), E> + Sync,
    E: std::fmt::Debug,
{
    let transfer = Semaphore::new(cfg.transfer_slots);
    let queue: Mutex<VecDeque<usize>> = Mutex::new((0..n).collect());
    let failed: Mutex<Option<E>> = Mutex::new(None);
    let workers = cfg.workers().min(n.max(1)).min(pool.workers());

    std::thread::scope(|scope| {
        for w in 0..workers {
            let queue = &queue;
            let failed = &failed;
            let transfer = &transfer;
            let task = &task;
            let pool = &pool;
            scope.spawn(move || {
                let mut scratch = pool.slots[w].lock().unwrap();
                loop {
                    if failed.lock().unwrap().is_some() {
                        return;
                    }
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some(item) = item else { return };
                    let mut ctx = WorkerCtx {
                        worker: w,
                        device: w % cfg.devices.max(1),
                        link: TransferLink { sem: transfer },
                        scratch: &mut *scratch,
                    };
                    if let Err(e) = task(&mut ctx, item) {
                        let mut f = failed.lock().unwrap();
                        if f.is_none() {
                            *f = Some(e);
                        }
                        return;
                    }
                }
            });
        }
    });

    match failed.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Run `f` over both amplitude planes in disjoint, aligned chunks of
/// `chunk_len` amplitudes, split across up to `workers` scoped worker
/// threads — the worker-parallel *plane sweep* primitive the batched gate
/// kernels use (`gates::fused`).
///
/// Each worker owns a contiguous span of whole chunks (disjoint index
/// ranges, no locking); `f` receives the chunk's base amplitude index and
/// mutable sub-slices of both planes. `chunk_len` must divide `re.len()`
/// (both are powers of two on every call site). With `workers <= 1` — or
/// a single chunk — the sweep runs inline on the calling thread, so the
/// sequential path has zero thread overhead.
pub fn run_plane_chunks<F>(workers: usize, chunk_len: usize, re: &mut [f64], im: &mut [f64], f: F)
where
    F: Fn(usize, &mut [f64], &mut [f64]) + Sync,
{
    let len = re.len();
    debug_assert_eq!(len, im.len());
    debug_assert!(chunk_len > 0 && len % chunk_len == 0);
    let n_chunks = len / chunk_len;
    let workers = workers.max(1).min(n_chunks.max(1));
    if workers <= 1 {
        for (ci, (rc, ic)) in
            re.chunks_mut(chunk_len).zip(im.chunks_mut(chunk_len)).enumerate()
        {
            f(ci * chunk_len, rc, ic);
        }
        return;
    }
    let per = n_chunks / workers;
    let extra = n_chunks % workers;
    std::thread::scope(|scope| {
        let mut re_rest = re;
        let mut im_rest = im;
        let mut base = 0usize;
        // Spawn workers - 1 threads; the calling thread takes the last
        // span itself instead of idling at the scope join.
        for w in 0..workers - 1 {
            let span = (per + usize::from(w < extra)) * chunk_len;
            let (r_span, r_next) = re_rest.split_at_mut(span);
            let (i_span, i_next) = im_rest.split_at_mut(span);
            re_rest = r_next;
            im_rest = i_next;
            let f = &f;
            let start = base;
            scope.spawn(move || {
                for (ci, (rc, ic)) in
                    r_span.chunks_mut(chunk_len).zip(i_span.chunks_mut(chunk_len)).enumerate()
                {
                    f(start + ci * chunk_len, rc, ic);
                }
            });
            base += span;
        }
        for (ci, (rc, ic)) in
            re_rest.chunks_mut(chunk_len).zip(im_rest.chunks_mut(chunk_len)).enumerate()
        {
            f(base + ci * chunk_len, rc, ic);
        }
    });
}

/// Copyable handle to the shared transfer link; lets tasks enter transfer
/// sections while holding disjoint borrows of the scratch arena.
#[derive(Clone, Copy)]
pub struct TransferLink<'a> {
    sem: &'a Semaphore,
}

impl TransferLink<'_> {
    /// Execute `f` while holding a transfer permit (the PCIe section).
    pub fn section<T>(&self, f: impl FnOnce() -> T) -> T {
        let _g = self.sem.acquire();
        f()
    }
}

/// Per-task context: which worker/device is running, the shared transfer
/// link for fetch/store sections, and the worker's scratch arena.
pub struct WorkerCtx<'a> {
    pub worker: usize,
    pub device: usize,
    pub link: TransferLink<'a>,
    pub scratch: &'a mut Scratch,
}

impl WorkerCtx<'_> {
    /// Execute `f` while holding a transfer permit (the PCIe section).
    pub fn transfer<T>(&self, f: impl FnOnce() -> T) -> T {
        self.link.section(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_every_item_exactly_once() {
        let hits = Vec::from_iter((0..500).map(|_| AtomicUsize::new(0)));
        run_items::<(), _>(PipelineConfig::new(2, 4), 500, &ScratchPool::new(8), |_ctx, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            Ok(())
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_config_uses_one_worker() {
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(PipelineConfig::sequential(), 50, &ScratchPool::new(1), |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallelism_is_bounded_by_workers() {
        let cfg = PipelineConfig::new(2, 2);
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 64, &ScratchPool::new(cfg.workers()), |_ctx, _i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            max_live.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(300));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert!(max_live.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn transfer_section_respects_slots() {
        let cfg = PipelineConfig { devices: 1, streams: 8, transfer_slots: 1 };
        let max_live = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_items::<(), _>(cfg, 32, &ScratchPool::new(cfg.workers()), |ctx, _i| {
            ctx.transfer(|| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                max_live.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(100));
                live.fetch_sub(1, Ordering::SeqCst);
            });
            Ok(())
        })
        .unwrap();
        assert_eq!(max_live.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn first_error_aborts_and_propagates() {
        let done = AtomicUsize::new(0);
        let r = run_items::<String, _>(PipelineConfig::new(1, 2), 1000, &ScratchPool::new(2), |_ctx, i| {
            if i == 3 {
                return Err("boom".to_string());
            }
            done.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(50));
            Ok(())
        });
        assert_eq!(r.unwrap_err(), "boom");
        assert!(done.load(Ordering::Relaxed) < 1000);
    }

    #[test]
    fn devices_assign_round_robin() {
        let cfg = PipelineConfig::new(4, 1);
        let seen = Mutex::new(std::collections::BTreeSet::new());
        run_items::<(), _>(cfg, 64, &ScratchPool::new(cfg.workers()), |ctx, _i| {
            seen.lock().unwrap().insert(ctx.device);
            std::thread::sleep(std::time::Duration::from_micros(100));
            Ok(())
        })
        .unwrap();
        assert!(seen.into_inner().unwrap().len() > 1);
    }

    #[test]
    fn zero_items_is_fine() {
        run_items::<(), _>(PipelineConfig::new(2, 2), 0, &ScratchPool::new(4), |_ctx, _i| Ok(())).unwrap();
    }

    #[test]
    fn plane_chunks_cover_plane_exactly_once() {
        for workers in [1usize, 2, 3, 8, 64] {
            let len = 1usize << 10;
            let mut re = vec![0.0f64; len];
            let mut im = vec![0.0f64; len];
            run_plane_chunks(workers, 1 << 4, &mut re, &mut im, |base, rc, ic| {
                assert_eq!(rc.len(), 1 << 4);
                assert_eq!(ic.len(), rc.len());
                assert_eq!(base % rc.len(), 0);
                for (i, v) in rc.iter_mut().enumerate() {
                    *v += (base + i) as f64;
                }
                for v in ic.iter_mut() {
                    *v += 1.0;
                }
            });
            for (i, (&r, &v)) in re.iter().zip(im.iter()).enumerate() {
                assert_eq!(r, i as f64, "workers={workers}");
                assert_eq!(v, 1.0, "workers={workers}");
            }
        }
    }

    #[test]
    fn plane_chunks_single_chunk_runs_inline() {
        let len = 64usize;
        let mut re = vec![0.0f64; len];
        let mut im = vec![0.0f64; len];
        let tid = std::thread::current().id();
        run_plane_chunks(8, len, &mut re, &mut im, |base, rc, _ic| {
            assert_eq!(base, 0);
            assert_eq!(rc.len(), len);
            assert_eq!(std::thread::current().id(), tid);
        });
    }

    #[test]
    fn ensure_planes_grows_only_on_capacity_increase() {
        let mut s = Scratch::new();
        assert!(s.ensure_planes(1024)); // cold arena grows
        assert_eq!(s.plane_grows, 1);
        assert_eq!(s.re.len(), 1024);
        assert!(!s.ensure_planes(512)); // shrink: no growth
        assert_eq!(s.re.len(), 512);
        assert!(!s.ensure_planes(1024)); // back within capacity: no growth
        assert_eq!(s.plane_grows, 1);
        assert!(s.ensure_planes(4096)); // genuinely larger: grows once more
        assert_eq!(s.plane_grows, 2);
    }

    #[test]
    fn scratch_pool_persists_across_run_items_calls() {
        // The arena must survive stage boundaries: the second call sees the
        // capacity warmed by the first, so no plane growth happens.
        let cfg = PipelineConfig::new(1, 2);
        let pool = ScratchPool::new(cfg.workers());
        for _round in 0..3 {
            run_items::<(), _>(cfg, 16, &pool, |ctx, _i| {
                ctx.scratch.ensure_planes(2048);
                Ok(())
            })
            .unwrap();
        }
        // At most one growth per worker, ever — not one per round or item.
        assert!(pool.total_plane_grows() <= cfg.workers() as u64);
        assert!(pool.total_plane_grows() >= 1);
    }
}
