//! Metrics: thread-safe per-phase timers and counters, and the report
//! tables the CLI / bench harness print.
//!
//! The phases mirror the paper's pipeline stages (Fig. 6): H2D transfer,
//! decompression, state-vector update, compression, D2H transfer — plus
//! partitioning (Fig. 14) and end-to-end wall time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Pipeline phases instrumented across all engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Circuit partitioning (offline, Algorithm 1).
    Partition,
    /// Fetch compressed block bytes from the store (H2D analogue).
    Fetch,
    Decompress,
    /// Gate application / state-vector update.
    Apply,
    Compress,
    /// Store compressed bytes back (D2H analogue).
    Store,
}

impl Phase {
    pub const ALL: [Phase; 6] =
        [Phase::Partition, Phase::Fetch, Phase::Decompress, Phase::Apply, Phase::Compress, Phase::Store];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Partition => "partition",
            Phase::Fetch => "fetch",
            Phase::Decompress => "decompress",
            Phase::Apply => "apply",
            Phase::Compress => "compress",
            Phase::Store => "store",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Partition => 0,
            Phase::Fetch => 1,
            Phase::Decompress => 2,
            Phase::Apply => 3,
            Phase::Compress => 4,
            Phase::Store => 5,
        }
    }
}

/// Accumulating, thread-safe metrics sink. Phase times are *CPU-side busy
/// times summed across workers*; wall time is tracked separately.
#[derive(Debug, Default)]
pub struct Metrics {
    phase_nanos: [AtomicU64; 6],
    pub compressions: AtomicU64,
    pub decompressions: AtomicU64,
    pub bytes_compressed_in: AtomicU64,
    pub bytes_compressed_out: AtomicU64,
    pub gates_applied: AtomicU64,
    pub groups_processed: AtomicU64,
    /// Arena-reuse counter: how often a pipeline worker's scratch planes
    /// had to grow (steady state after warmup: zero; see pipeline::Scratch).
    pub scratch_grows: AtomicU64,
    /// Gate merges performed by the fusion pass: original gates minus
    /// fused ops, summed over stages (each merge removes one plane sweep).
    pub gates_fused: AtomicU64,
    /// Full passes over the state per gate-application phase: counted once
    /// per stage (a stage's SV groups tile the state, so walking every
    /// group once is ONE state sweep). Per-gate engines count one per
    /// gate; the fused-batched path counts one per sweep segment — the
    /// headline "sweeps << gates" metric.
    pub plane_sweeps: AtomicU64,
    /// Fused-op kernel invocations across all group chains (scales with
    /// group count, unlike `plane_sweeps`).
    pub fused_ops_applied: AtomicU64,
    /// Budget-driven Belady evictions in the two-level store (engines copy
    /// these four from `MemStats` at end of run so the report is
    /// self-contained).
    pub evictions: AtomicU64,
    /// Group fetches served from primary by a prefetcher-staged block.
    pub prefetch_hits: AtomicU64,
    /// Group fetches that paid a synchronous secondary-tier read.
    pub prefetch_misses: AtomicU64,
    /// Worker time stalled on spill machinery (in-flight write waits,
    /// write-back back-pressure, synchronous disk reads).
    pub spill_stall_ns: AtomicU64,
    /// Overlapped pipeline: how often the apply phase found its next
    /// group already decoded (zero wait) — the "overhead concealed"
    /// counter. 0 when `overlap` is off.
    pub decode_ahead_hits: AtomicU64,
    /// Overlapped pipeline: total time phase threads spent waiting on the
    /// ring handshake (decode waiting for a free slot, apply for a
    /// decoded one, encode for an applied one). 0 when `overlap` is off.
    pub overlap_stall_ns: AtomicU64,
    /// Spill-aware scheduling: groups moved ahead of their natural stage
    /// position because their blocks were already primary-resident.
    pub groups_reordered: AtomicU64,
    /// Persistent phase pool: total phase threads spawned for the run —
    /// `3 × workers` exactly once, NOT per stage (the pool-reuse proof;
    /// 0 when no stage engaged overlap).
    pub phase_threads_spawned: AtomicU64,
    /// Persistent phase pool: stages dispatched as work descriptors to
    /// the already-running phase threads.
    pub pool_stage_handoffs: AtomicU64,
    /// Adaptive ring depth: depth in effect after the last stage.
    pub ring_depth_final: AtomicU64,
    /// Adaptive ring depth: deepest ring the AIMD controller reached.
    pub ring_depth_peak: AtomicU64,
    /// Adaptive ring depth: number of depth changes (trajectory length).
    pub ring_depth_adjustments: AtomicU64,
    /// Overlap auto-enable: stages where the heuristic engaged the
    /// pipeline (0 when the mode is pinned on/off).
    pub auto_overlap_on: AtomicU64,
    /// Overlap auto-enable: stages where the heuristic declined.
    pub auto_overlap_off: AtomicU64,
    /// Spill recovery: transient I/O errors retried transparently
    /// (bounded exponential backoff; copied from `MemStats`).
    pub io_retries: AtomicU64,
    /// Spill recovery: frame reads whose xxh64 verification failed
    /// (corrupt or short data caught before it reached a worker).
    pub checksum_failures: AtomicU64,
    /// Spill recovery: frames re-served from the retention ring or the
    /// write-back queue after persistent on-disk corruption.
    pub frames_recovered: AtomicU64,
    /// Spill recovery: ENOSPC degradations — evictions re-targeted at the
    /// fallback stripe, or budget renegotiations when no stripe exists.
    pub enospc_fallbacks: AtomicU64,
    /// Vector (SIMD) kernel invocations attributed to this run: the delta
    /// of the process-wide `simd::kernels_used` counter across the run.
    /// 0 under `--no-simd` / `BMQSIM_NO_SIMD` or on scalar-only hosts.
    /// Best-effort: concurrent runs in one process share the counter.
    pub simd_kernels_used: AtomicU64,
    /// Cross-stage overlap: decode items accepted into epoch s+1 while
    /// epoch s was still encoding (0 under the per-stage barrier).
    pub cross_stage_decodes: AtomicU64,
    /// Cross-stage overlap: time decode threads waited at a boundary gate
    /// for shared blocks still owned by the previous stage's encoders.
    pub boundary_stall_ns: AtomicU64,
    /// Cross-stage overlap: time the engine thread spent draining the
    /// epoch window (the residual, partial stand-in for the old barrier).
    pub epoch_drain_ns: AtomicU64,
    /// Checkpointing: stage-boundary snapshots committed this run.
    pub checkpoints: AtomicU64,
    /// Checkpointing: total bytes persisted (frames + manifests).
    pub checkpoint_bytes: AtomicU64,
    /// Checkpointing: engine-thread time spent quiescing + writing
    /// snapshots (the checkpoint overhead the cadence knob trades off).
    pub checkpoint_ns: AtomicU64,
    /// Times this run's state was rehydrated from a checkpoint (1 for a
    /// `--resume` run; carried across resumes via the manifest, so a
    /// twice-interrupted run reports 2).
    pub resumes: AtomicU64,
    /// Adaptive error control: eviction victims the memory tier kept
    /// resident by recompressing at a controller-approved looser bound
    /// (the compressed-primary third tier). Copied from `MemStats`.
    pub recompressions: AtomicU64,
    /// Adaptive error control: committed L2 error in linear ε units, as
    /// f64 bits ([`f64::to_bits`]) — 0 without a fidelity target.
    pub error_budget_spent: AtomicU64,
    /// Adaptive error control: tightest per-encode bound issued, f64 bits.
    pub per_block_bound_min: AtomicU64,
    /// Adaptive error control: loosest per-encode bound issued, f64 bits.
    pub per_block_bound_max: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, attributing its duration to `phase`.
    pub fn time<T>(&self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_nanos(phase, t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn add_nanos(&self, phase: Phase, nanos: u64) {
        self.phase_nanos[phase.index()].fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn phase_secs(&self, phase: Phase) -> f64 {
        self.phase_nanos[phase.index()].load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn count(&self, c: &AtomicU64) -> u64 {
        c.load(Ordering::Relaxed)
    }

    /// Snapshot into a plain, printable report.
    pub fn snapshot(&self, wall_secs: f64) -> MetricsReport {
        MetricsReport {
            wall_secs,
            aggregate_phase_secs: Phase::ALL
                .iter()
                .map(|&p| self.phase_secs(p))
                .sum(),
            phase_secs: Phase::ALL.map(|p| (p.name(), self.phase_secs(p))),
            compressions: self.compressions.load(Ordering::Relaxed),
            decompressions: self.decompressions.load(Ordering::Relaxed),
            bytes_in: self.bytes_compressed_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_compressed_out.load(Ordering::Relaxed),
            gates_applied: self.gates_applied.load(Ordering::Relaxed),
            groups_processed: self.groups_processed.load(Ordering::Relaxed),
            scratch_grows: self.scratch_grows.load(Ordering::Relaxed),
            gates_fused: self.gates_fused.load(Ordering::Relaxed),
            plane_sweeps: self.plane_sweeps.load(Ordering::Relaxed),
            fused_ops_applied: self.fused_ops_applied.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_misses: self.prefetch_misses.load(Ordering::Relaxed),
            spill_stall_ns: self.spill_stall_ns.load(Ordering::Relaxed),
            decode_ahead_hits: self.decode_ahead_hits.load(Ordering::Relaxed),
            overlap_stall_ns: self.overlap_stall_ns.load(Ordering::Relaxed),
            groups_reordered: self.groups_reordered.load(Ordering::Relaxed),
            phase_threads_spawned: self.phase_threads_spawned.load(Ordering::Relaxed),
            pool_stage_handoffs: self.pool_stage_handoffs.load(Ordering::Relaxed),
            ring_depth_final: self.ring_depth_final.load(Ordering::Relaxed),
            ring_depth_peak: self.ring_depth_peak.load(Ordering::Relaxed),
            ring_depth_adjustments: self.ring_depth_adjustments.load(Ordering::Relaxed),
            auto_overlap_on: self.auto_overlap_on.load(Ordering::Relaxed),
            auto_overlap_off: self.auto_overlap_off.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            checksum_failures: self.checksum_failures.load(Ordering::Relaxed),
            frames_recovered: self.frames_recovered.load(Ordering::Relaxed),
            enospc_fallbacks: self.enospc_fallbacks.load(Ordering::Relaxed),
            simd_kernels_used: self.simd_kernels_used.load(Ordering::Relaxed),
            cross_stage_decodes: self.cross_stage_decodes.load(Ordering::Relaxed),
            boundary_stall_ns: self.boundary_stall_ns.load(Ordering::Relaxed),
            epoch_drain_ns: self.epoch_drain_ns.load(Ordering::Relaxed),
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
            checkpoint_bytes: self.checkpoint_bytes.load(Ordering::Relaxed),
            checkpoint_ns: self.checkpoint_ns.load(Ordering::Relaxed),
            resumes: self.resumes.load(Ordering::Relaxed),
            recompressions: self.recompressions.load(Ordering::Relaxed),
            error_budget_spent: f64::from_bits(
                self.error_budget_spent.load(Ordering::Relaxed),
            ),
            per_block_bound_min: f64::from_bits(
                self.per_block_bound_min.load(Ordering::Relaxed),
            ),
            per_block_bound_max: f64::from_bits(
                self.per_block_bound_max.load(Ordering::Relaxed),
            ),
        }
    }

    /// The cumulative counters a checkpoint manifest carries across a
    /// resume (`memory::checkpoint`): the work-done counters that must
    /// stay monotonic over kills so a resumed run's report covers the
    /// whole logical run, not just the post-resume tail.
    pub fn checkpoint_counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("compressions", self.compressions.load(Ordering::Relaxed)),
            ("decompressions", self.decompressions.load(Ordering::Relaxed)),
            ("gates_applied", self.gates_applied.load(Ordering::Relaxed)),
            ("gates_fused", self.gates_fused.load(Ordering::Relaxed)),
            ("plane_sweeps", self.plane_sweeps.load(Ordering::Relaxed)),
            ("fused_ops_applied", self.fused_ops_applied.load(Ordering::Relaxed)),
            ("groups_processed", self.groups_processed.load(Ordering::Relaxed)),
            ("resumes", self.resumes.load(Ordering::Relaxed)),
        ]
    }

    /// Restore one manifest counter by name (the inverse of
    /// [`Self::checkpoint_counters`]). Unknown names are ignored so newer
    /// manifests resume under older binaries within the same schema.
    pub fn restore_counter(&self, name: &str, value: u64) {
        let field = match name {
            "compressions" => &self.compressions,
            "decompressions" => &self.decompressions,
            "gates_applied" => &self.gates_applied,
            "gates_fused" => &self.gates_fused,
            "plane_sweeps" => &self.plane_sweeps,
            "fused_ops_applied" => &self.fused_ops_applied,
            "groups_processed" => &self.groups_processed,
            "resumes" => &self.resumes,
            _ => return,
        };
        field.store(value, Ordering::Relaxed);
    }

    /// Copy the memory-subsystem counters out of a [`crate::memory::MemStats`]
    /// snapshot (engines call this once, after flushing the store).
    pub fn absorb_mem(&self, mem: &crate::memory::MemStats) {
        self.evictions.store(mem.evictions, Ordering::Relaxed);
        self.prefetch_hits.store(mem.prefetch_hits, Ordering::Relaxed);
        self.prefetch_misses.store(mem.prefetch_misses, Ordering::Relaxed);
        self.spill_stall_ns.store(mem.spill_stall_ns, Ordering::Relaxed);
        self.io_retries.store(mem.io_retries, Ordering::Relaxed);
        self.checksum_failures.store(mem.checksum_failures, Ordering::Relaxed);
        self.frames_recovered.store(mem.frames_recovered, Ordering::Relaxed);
        self.enospc_fallbacks.store(mem.enospc_fallbacks, Ordering::Relaxed);
        self.recompressions.store(mem.recompressions, Ordering::Relaxed);
    }

    /// Copy the error-budget ledger out of the run's
    /// [`crate::compress::budget::BudgetController`] (engines call this
    /// once, at end of run, when a fidelity target was set).
    pub fn absorb_budget(&self, b: &crate::compress::budget::BudgetStats) {
        self.error_budget_spent.store(b.spent.to_bits(), Ordering::Relaxed);
        self.per_block_bound_min.store(b.bound_min.to_bits(), Ordering::Relaxed);
        self.per_block_bound_max.store(b.bound_max.to_bits(), Ordering::Relaxed);
    }

    /// Copy the overlapped-pipeline counters out of a run's accumulated
    /// [`crate::pipeline::OverlapStats`] (engines call this once, after
    /// the last stage).
    pub fn absorb_overlap(&self, o: &crate::pipeline::OverlapStats) {
        self.decode_ahead_hits.store(
            o.decode_ahead_hits.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        self.overlap_stall_ns.store(o.total_stall_ns(), Ordering::Relaxed);
        self.pool_stage_handoffs.store(
            o.stage_handoffs.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
    }
}

/// Immutable metrics snapshot attached to every `SimResult`.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub wall_secs: f64,
    /// Sum of all per-phase busy times across workers. Phase timers are
    /// *monotonic per-phase accumulators summed per worker*, NOT
    /// wall-clock attribution: once phases overlap (pipelined chains,
    /// `workers > 1`) this aggregate legitimately exceeds `wall_secs` —
    /// compare phases to this total, not to wall time.
    pub aggregate_phase_secs: f64,
    pub phase_secs: [(&'static str, f64); 6],
    pub compressions: u64,
    pub decompressions: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub gates_applied: u64,
    pub groups_processed: u64,
    /// Plane-growth events in the pipeline scratch arenas.
    pub scratch_grows: u64,
    /// Gate merges performed by the fusion pass (sweeps removed).
    pub gates_fused: u64,
    /// Full state sweeps spent applying gates (one per stage sweep
    /// segment; per-gate paths count one per gate).
    pub plane_sweeps: u64,
    /// Fused-op kernel invocations summed over group chains.
    pub fused_ops_applied: u64,
    /// Budget-driven Belady evictions in the two-level store.
    pub evictions: u64,
    /// Group fetches served from primary by a prefetcher-staged block.
    pub prefetch_hits: u64,
    /// Group fetches that paid a synchronous secondary-tier read.
    pub prefetch_misses: u64,
    /// Worker time stalled on spill machinery, in nanoseconds.
    pub spill_stall_ns: u64,
    /// Overlapped pipeline: apply found its next group already decoded.
    pub decode_ahead_hits: u64,
    /// Overlapped pipeline: total ring-handshake wait time (ns).
    pub overlap_stall_ns: u64,
    /// Groups promoted ahead of their natural order by spill-aware
    /// scheduling (their blocks were already primary-resident).
    pub groups_reordered: u64,
    /// Persistent phase pool: phase threads spawned for the whole run
    /// (`3 × workers` once, not per stage; 0 = pool never engaged).
    pub phase_threads_spawned: u64,
    /// Persistent phase pool: stage work-descriptor handoffs.
    pub pool_stage_handoffs: u64,
    /// Adaptive ring depth in effect after the last stage.
    pub ring_depth_final: u64,
    /// Deepest adaptive ring depth reached during the run.
    pub ring_depth_peak: u64,
    /// Number of adaptive ring-depth changes (trajectory length).
    pub ring_depth_adjustments: u64,
    /// Stages where the overlap auto-enable heuristic engaged.
    pub auto_overlap_on: u64,
    /// Stages where the overlap auto-enable heuristic declined.
    pub auto_overlap_off: u64,
    /// Transient spill I/O errors retried transparently.
    pub io_retries: u64,
    /// Spill-frame reads that failed xxh64 verification.
    pub checksum_failures: u64,
    /// Frames re-served from the retention ring / write-back queue after
    /// persistent corruption.
    pub frames_recovered: u64,
    /// ENOSPC degradations (fallback-stripe writes + budget renegotiations).
    pub enospc_fallbacks: u64,
    /// Vector (SIMD) kernel invocations attributed to this run (0 when
    /// the scalar oracle was pinned or the host has no vector tier).
    pub simd_kernels_used: u64,
    /// Decode items accepted into the next epoch while the previous stage
    /// was still encoding (0 under the per-stage barrier).
    pub cross_stage_decodes: u64,
    /// Decode-thread wait at cross-stage boundary gates, in nanoseconds.
    pub boundary_stall_ns: u64,
    /// Engine-thread time spent draining the epoch window, in nanoseconds.
    pub epoch_drain_ns: u64,
    /// Stage-boundary snapshots committed this run.
    pub checkpoints: u64,
    /// Total checkpoint bytes persisted (frames + manifests).
    pub checkpoint_bytes: u64,
    /// Engine-thread time spent quiescing + writing snapshots, in ns.
    pub checkpoint_ns: u64,
    /// Checkpoint rehydrations in this run's lineage (carried across
    /// resumes via the manifest counters).
    pub resumes: u64,
    /// Adaptive error control: victims kept primary-resident by a
    /// controller-approved harder recompression instead of being spilled.
    pub recompressions: u64,
    /// Adaptive error control: committed L2 error in linear ε units
    /// (0.0 without a fidelity target).
    pub error_budget_spent: f64,
    /// Tightest per-encode bound the controller issued (0.0 = no
    /// controller ran).
    pub per_block_bound_min: f64,
    /// Loosest per-encode bound the controller issued.
    pub per_block_bound_max: f64,
}

impl MetricsReport {
    pub fn phase(&self, name: &str) -> f64 {
        self.phase_secs
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// Achieved compression ratio over everything that passed through the
    /// compressor (1.0 when compression was off).
    pub fn compression_ratio(&self) -> f64 {
        if self.bytes_out == 0 {
            1.0
        } else {
            self.bytes_in as f64 / self.bytes_out as f64
        }
    }

    /// Overlapped-pipeline occupancy: fraction of phase-thread time spent
    /// doing chain work rather than waiting on a ring handshake,
    /// `busy / (busy + overlap_stall)`. `None` when no phase time was
    /// recorded at all (an idle run has no occupancy to report — callers
    /// must not read a perfect 1.0 out of a run that did nothing).
    pub fn pipeline_occupancy(&self) -> Option<f64> {
        let busy: f64 = self
            .phase_secs
            .iter()
            .filter(|(n, _)| *n != "partition")
            .map(|(_, s)| *s)
            .sum();
        let stall = self.overlap_stall_ns as f64 * 1e-9;
        if busy + stall <= 0.0 {
            None
        } else {
            Some(busy / (busy + stall))
        }
    }
}

impl std::fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "wall time        : {:>10.3} s", self.wall_secs)?;
        writeln!(
            f,
            "phase time total : {:>10.3} s (busy, summed over workers/phases)",
            self.aggregate_phase_secs
        )?;
        for (name, secs) in &self.phase_secs {
            writeln!(f, "{name:<17}: {secs:>10.3} s (busy, summed over workers)")?;
        }
        if self.decode_ahead_hits + self.overlap_stall_ns > 0 {
            if let Some(occ) = self.pipeline_occupancy() {
                writeln!(
                    f,
                    "pipeline overlap : {:>10.1}% occupancy ({} decode-ahead hits, {:.1} ms stalled)",
                    100.0 * occ,
                    self.decode_ahead_hits,
                    self.overlap_stall_ns as f64 * 1e-6
                )?;
            }
        }
        // Gated on the two counters only the gated protocol bumps:
        // `epoch_drain_ns` alone also accrues under the per-stage barrier
        // (drain_all times the barrier wait), so it must not make a
        // barrier run print a cross-stage line.
        if self.cross_stage_decodes + self.boundary_stall_ns > 0 {
            writeln!(
                f,
                "cross-stage      : {:>10} early decodes, {:.1} ms gate wait, {:.1} ms epoch drain",
                self.cross_stage_decodes,
                self.boundary_stall_ns as f64 * 1e-6,
                self.epoch_drain_ns as f64 * 1e-6
            )?;
        }
        if self.pool_stage_handoffs > 0 {
            writeln!(
                f,
                "phase pool       : {:>10} threads spawned once, {} stage handoffs, ring depth {} (peak {}, {} adjusts)",
                self.phase_threads_spawned,
                self.pool_stage_handoffs,
                self.ring_depth_final,
                self.ring_depth_peak,
                self.ring_depth_adjustments
            )?;
        }
        if self.auto_overlap_on + self.auto_overlap_off > 0 {
            writeln!(
                f,
                "overlap auto     : {:>10} stages pipelined / {} sequential",
                self.auto_overlap_on, self.auto_overlap_off
            )?;
        }
        if self.groups_reordered > 0 {
            writeln!(f, "groups reordered : {:>10} (spill-aware scheduling)", self.groups_reordered)?;
        }
        writeln!(f, "gates applied    : {:>10}", self.gates_applied)?;
        writeln!(
            f,
            "gates fused      : {:>10} ({} sweeps over {} fused ops)",
            self.gates_fused, self.plane_sweeps, self.fused_ops_applied
        )?;
        writeln!(f, "groups processed : {:>10}", self.groups_processed)?;
        if self.evictions + self.prefetch_hits + self.prefetch_misses > 0 {
            writeln!(
                f,
                "evictions        : {:>10} (prefetch {} hit / {} miss, {:.1} ms stalled)",
                self.evictions,
                self.prefetch_hits,
                self.prefetch_misses,
                self.spill_stall_ns as f64 * 1e-6
            )?;
        }
        if self.io_retries + self.checksum_failures + self.frames_recovered + self.enospc_fallbacks
            > 0
        {
            writeln!(
                f,
                "spill recovery   : {:>10} retries, {} checksum failures, {} frames recovered, {} ENOSPC fallbacks",
                self.io_retries,
                self.checksum_failures,
                self.frames_recovered,
                self.enospc_fallbacks
            )?;
        }
        if self.checkpoints + self.resumes > 0 {
            writeln!(
                f,
                "checkpoints      : {:>10} written ({:.1} MiB, {:.1} ms), {} resumes",
                self.checkpoints,
                self.checkpoint_bytes as f64 / (1 << 20) as f64,
                self.checkpoint_ns as f64 * 1e-6,
                self.resumes
            )?;
        }
        if self.per_block_bound_max > 0.0 || self.recompressions > 0 {
            writeln!(
                f,
                "error control    : {:>10.2e} budget spent, bounds [{:.2e}, {:.2e}], {} recompressions",
                self.error_budget_spent,
                self.per_block_bound_min,
                self.per_block_bound_max,
                self.recompressions
            )?;
        }
        if self.simd_kernels_used > 0 {
            writeln!(
                f,
                "simd kernels     : {:>10} vector invocations ({})",
                self.simd_kernels_used,
                crate::simd::active_level().name()
            )?;
        }
        writeln!(
            f,
            "(de)compressions : {:>10} / {}",
            self.compressions, self.decompressions
        )?;
        writeln!(f, "compression ratio: {:>10.2}x", self.compression_ratio())
    }
}

/// Fixed-width ASCII table builder for the report/bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |f: &mut std::fmt::Formatter<'_>| -> std::fmt::Result {
            for (i, w) in widths.iter().enumerate() {
                write!(f, "+{}", "-".repeat(w + 2))?;
                if i + 1 == ncol {
                    writeln!(f, "+")?;
                }
            }
            Ok(())
        };
        line(f)?;
        for (i, h) in self.headers.iter().enumerate() {
            write!(f, "| {:<w$} ", h, w = widths[i])?;
        }
        writeln!(f, "|")?;
        line(f)?;
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                write!(f, "| {:>w$} ", c, w = widths[i])?;
            }
            writeln!(f, "|")?;
        }
        line(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let m = Metrics::new();
        m.time(Phase::Apply, || std::thread::sleep(std::time::Duration::from_millis(5)));
        m.time(Phase::Apply, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(m.phase_secs(Phase::Apply) >= 0.009);
        assert_eq!(m.phase_secs(Phase::Compress), 0.0);
    }

    #[test]
    fn snapshot_and_ratio() {
        let m = Metrics::new();
        m.bytes_compressed_in.fetch_add(1000, Ordering::Relaxed);
        m.bytes_compressed_out.fetch_add(100, Ordering::Relaxed);
        let r = m.snapshot(1.5);
        assert_eq!(r.wall_secs, 1.5);
        assert!((r.compression_ratio() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn ratio_without_compression_is_one() {
        let m = Metrics::new();
        assert_eq!(m.snapshot(0.0).compression_ratio(), 1.0);
    }

    #[test]
    fn aggregate_phase_time_sums_phases() {
        let m = Metrics::new();
        m.add_nanos(Phase::Apply, 2_000_000_000);
        m.add_nanos(Phase::Compress, 1_000_000_000);
        let r = m.snapshot(1.0);
        assert!((r.aggregate_phase_secs - 3.0).abs() < 1e-9);
        // Overlapped runs legitimately exceed wall time.
        assert!(r.aggregate_phase_secs > r.wall_secs);
    }

    #[test]
    fn occupancy_is_busy_over_busy_plus_stall() {
        let m = Metrics::new();
        // An idle run has no phase time: no occupancy, not a perfect 1.0.
        assert_eq!(m.snapshot(0.0).pipeline_occupancy(), None);
        m.add_nanos(Phase::Apply, 3_000_000_000);
        m.overlap_stall_ns.store(1_000_000_000, Ordering::Relaxed);
        let r = m.snapshot(1.0);
        assert!((r.pipeline_occupancy().unwrap() - 0.75).abs() < 1e-9);
        // Partition time is offline planning, not a pipeline phase.
        m.add_nanos(Phase::Partition, 9_000_000_000);
        let r = m.snapshot(1.0);
        assert!((r.pipeline_occupancy().unwrap() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["algo", "qubits", "time"]);
        t.row(&["qft".into(), "20".into(), "1.23".into()]);
        let s = t.to_string();
        assert!(s.contains("| algo"));
        assert!(s.contains("qft"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn concurrent_timing_is_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.time(Phase::Compress, || {});
                        m.compressions.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(m.compressions.load(Ordering::Relaxed), 800);
    }
}
