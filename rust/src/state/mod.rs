//! State-vector storage and the SV block / SV group index algebra.
//!
//! Amplitudes live in split re/im planes (SoA). [`StateVector`] is the
//! dense, whole-state container used by the `dense` reference engine and by
//! fidelity checks; the compressed engines never materialize it — they work
//! on per-group gather buffers managed by `sim::bmqsim` + `memory`.

mod layout;

pub use layout::{BlockLayout, GroupSchedule};

use crate::types::{Complex, Error, Result};

/// A dense `n`-qubit state vector as split re/im planes of length `2^n`.
#[derive(Debug, Clone)]
pub struct StateVector {
    pub n_qubits: usize,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl StateVector {
    /// `|0...0>` — the standard initial state (paper §4.2 "common practice").
    pub fn zero_state(n_qubits: usize) -> Result<Self> {
        if n_qubits == 0 || n_qubits > 40 {
            return Err(Error::Config(format!("unsupported qubit count {n_qubits}")));
        }
        let len = 1usize << n_qubits;
        let mut re = vec![0.0; len];
        let im = vec![0.0; len];
        re[0] = 1.0;
        Ok(StateVector { n_qubits, re, im })
    }

    /// Construct from existing planes (must both be length `2^n`).
    pub fn from_planes(n_qubits: usize, re: Vec<f64>, im: Vec<f64>) -> Result<Self> {
        let len = 1usize << n_qubits;
        if re.len() != len || im.len() != len {
            return Err(Error::Config(format!(
                "plane length {} / {} != 2^{n_qubits}",
                re.len(),
                im.len()
            )));
        }
        Ok(StateVector { n_qubits, re, im })
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn amplitude(&self, i: usize) -> Complex {
        Complex::new(self.re[i], self.im[i])
    }

    /// Total probability `sum |a_i|^2` (1.0 for a valid state).
    pub fn norm_sq(&self) -> f64 {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(r, i)| r * r + i * i)
            .sum()
    }

    /// Fidelity `|<self|other>|` — the paper's §5.3 metric.
    pub fn fidelity(&self, other: &StateVector) -> f64 {
        assert_eq!(self.n_qubits, other.n_qubits, "qubit count mismatch");
        let (mut re, mut im) = (0.0f64, 0.0f64);
        for k in 0..self.len() {
            // <self|other> = sum conj(a_k) b_k
            re += self.re[k] * other.re[k] + self.im[k] * other.im[k];
            im += self.re[k] * other.im[k] - self.im[k] * other.re[k];
        }
        (re * re + im * im).sqrt()
    }

    /// Normalized fidelity `|<self|other>| / (|self| |other|)` — bounded by
    /// 1 (Cauchy-Schwarz) even when lossy compression perturbed the norms;
    /// used when *comparing* engines (the raw paper metric can exceed 1 on
    /// unnormalized states, making order comparisons meaningless).
    pub fn fidelity_normalized(&self, other: &StateVector) -> f64 {
        let denom = (self.norm_sq() * other.norm_sq()).sqrt();
        if denom == 0.0 {
            0.0
        } else {
            self.fidelity(other) / denom
        }
    }

    /// Probability of measuring basis state `i`.
    pub fn probability(&self, i: usize) -> f64 {
        self.re[i] * self.re[i] + self.im[i] * self.im[i]
    }

    /// Marginal probability that qubit `q` reads 1.
    pub fn prob_qubit_one(&self, q: usize) -> f64 {
        let bit = 1usize << q;
        let mut p = 0.0;
        for i in 0..self.len() {
            if i & bit != 0 {
                p += self.probability(i);
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_state_is_normalized_basis_state() {
        let s = StateVector::zero_state(5).unwrap();
        assert_eq!(s.len(), 32);
        assert_eq!(s.amplitude(0), Complex::ONE);
        assert!((s.norm_sq() - 1.0).abs() < 1e-15);
        assert_eq!(s.probability(3), 0.0);
    }

    #[test]
    fn fidelity_self_is_one() {
        let s = StateVector::zero_state(4).unwrap();
        assert!((s.fidelity(&s) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn fidelity_orthogonal_is_zero() {
        let a = StateVector::zero_state(3).unwrap();
        let mut re = vec![0.0; 8];
        re[5] = 1.0;
        let b = StateVector::from_planes(3, re, vec![0.0; 8]).unwrap();
        assert!(a.fidelity(&b).abs() < 1e-15);
    }

    #[test]
    fn fidelity_is_phase_invariant() {
        // |<a|b>| must ignore a global phase on b.
        let a = StateVector::zero_state(2).unwrap();
        let phase = Complex::cis(1.234);
        let re = vec![phase.re, 0.0, 0.0, 0.0];
        let im = vec![phase.im, 0.0, 0.0, 0.0];
        let b = StateVector::from_planes(2, re, im).unwrap();
        assert!((a.fidelity(&b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_planes_validates_length() {
        assert!(StateVector::from_planes(3, vec![0.0; 7], vec![0.0; 8]).is_err());
    }

    #[test]
    fn prob_qubit_one() {
        // |10> (qubit1=1, qubit0=0) at index 2
        let mut re = vec![0.0; 4];
        re[2] = 1.0;
        let s = StateVector::from_planes(2, re, vec![0.0; 4]).unwrap();
        assert_eq!(s.prob_qubit_one(1), 1.0);
        assert_eq!(s.prob_qubit_one(0), 0.0);
    }
}
