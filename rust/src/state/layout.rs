//! SV block / SV group index algebra (paper Figures 1, 2, 4, 5).
//!
//! The state vector of `n` qubits is split into `2^c` SV blocks of `2^b`
//! amplitudes (`n = b + c`). The low `b` bits of an amplitude index are its
//! *local index*; the high `c` bits are its *global index* == the block id.
//!
//! A stage with sorted inner global indices `inner = [g_0 < g_1 < ...]`
//! (absolute qubit numbers, all `>= b`) induces **SV groups**: fix the
//! remaining (outer) global bits, vary the inner bits → `2^|inner|` blocks
//! whose amplitudes close under every gate of the stage (Fig. 4). Gathering
//! those blocks in inner-pattern order produces a contiguous buffer that
//! behaves exactly like a dense state of `b + |inner|` qubits, where
//!   * local qubit `t < b`       → buffer bit `t`
//!   * inner global `g = inner[p]` → buffer bit `b + p`
//! so the stage executor is just a dense simulator plus this remap.

use crate::types::{Error, Result};

/// Geometry of the block decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockLayout {
    pub n_qubits: usize,
    /// `b`: qubits resolved inside one block.
    pub block_qubits: usize,
}

impl BlockLayout {
    pub fn new(n_qubits: usize, block_qubits: usize) -> Result<Self> {
        if block_qubits > n_qubits {
            return Err(Error::Config(format!(
                "block_qubits {block_qubits} > n_qubits {n_qubits}"
            )));
        }
        Ok(BlockLayout { n_qubits, block_qubits })
    }

    /// `c`: number of global bits.
    pub fn global_qubits(&self) -> usize {
        self.n_qubits - self.block_qubits
    }

    /// Amplitudes per block, `2^b`.
    pub fn block_len(&self) -> usize {
        1usize << self.block_qubits
    }

    /// Number of blocks, `2^c`.
    pub fn num_blocks(&self) -> usize {
        1usize << self.global_qubits()
    }

    /// Block id (global index) of amplitude `i`.
    pub fn block_of(&self, i: usize) -> usize {
        i >> self.block_qubits
    }

    /// Local index of amplitude `i` within its block.
    pub fn local_of(&self, i: usize) -> usize {
        i & (self.block_len() - 1)
    }

    /// Build the group schedule for a stage's inner set.
    pub fn group_schedule(&self, inner: &[usize]) -> Result<GroupSchedule> {
        GroupSchedule::new(*self, inner)
    }
}

/// Precomputed iteration data for the SV groups of one stage.
#[derive(Debug, Clone)]
pub struct GroupSchedule {
    pub layout: BlockLayout,
    /// Sorted absolute qubit numbers of the stage's inner globals.
    pub inner: Vec<usize>,
    /// Bit positions of the inner globals **within the global index**
    /// (i.e. `inner[p] - b`), sorted ascending.
    inner_bits: Vec<usize>,
    /// Bit positions of the outer globals within the global index.
    outer_bits: Vec<usize>,
}

impl GroupSchedule {
    fn new(layout: BlockLayout, inner: &[usize]) -> Result<Self> {
        let b = layout.block_qubits;
        let c = layout.global_qubits();
        let mut inner_bits = Vec::with_capacity(inner.len());
        for (i, &g) in inner.iter().enumerate() {
            if g < b || g >= layout.n_qubits {
                return Err(Error::Config(format!(
                    "inner qubit {g} outside global range [{b}, {})",
                    layout.n_qubits
                )));
            }
            if i > 0 && inner[i - 1] >= g {
                return Err(Error::Config("inner set must be sorted & distinct".into()));
            }
            inner_bits.push(g - b);
        }
        let outer_bits: Vec<usize> =
            (0..c).filter(|bit| !inner_bits.contains(bit)).collect();
        Ok(GroupSchedule { layout, inner: inner.to_vec(), inner_bits, outer_bits })
    }

    /// Blocks per group: `2^|inner|`.
    pub fn blocks_per_group(&self) -> usize {
        1usize << self.inner_bits.len()
    }

    /// Number of groups: `2^(c - |inner|)`. Groups tile the block set.
    pub fn num_groups(&self) -> usize {
        1usize << self.outer_bits.len()
    }

    /// Amplitudes per gathered group buffer.
    pub fn group_len(&self) -> usize {
        self.blocks_per_group() * self.layout.block_len()
    }

    /// The block ids of group `g` (rank over outer assignments), ordered by
    /// ascending inner-bit pattern — the gather order that makes the buffer
    /// a dense `(b + |inner|)`-qubit state.
    pub fn group_blocks(&self, g: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.group_blocks_into(g, &mut out);
        out
    }

    /// [`GroupSchedule::group_blocks`] into a reused buffer (`out` is
    /// cleared, capacity retained) — the allocation-free gather helper the
    /// pipeline workers use.
    pub fn group_blocks_into(&self, g: usize, out: &mut Vec<usize>) {
        debug_assert!(g < self.num_groups());
        // Scatter outer rank bits into outer_bits positions.
        let mut base = 0usize;
        for (i, &bit) in self.outer_bits.iter().enumerate() {
            if g & (1 << i) != 0 {
                base |= 1 << bit;
            }
        }
        out.clear();
        out.reserve(self.blocks_per_group());
        out.extend((0..self.blocks_per_group()).map(|pat| {
            let mut id = base;
            for (p, &bit) in self.inner_bits.iter().enumerate() {
                if pat & (1 << p) != 0 {
                    id |= 1 << bit;
                }
            }
            id
        }));
    }

    /// Remap an absolute circuit qubit to its bit position in the gathered
    /// group buffer. Panics if the qubit is an *outer* global (a correctly
    /// partitioned stage never targets one).
    pub fn buffer_bit(&self, qubit: usize) -> usize {
        let b = self.layout.block_qubits;
        if qubit < b {
            qubit
        } else {
            let p = self
                .inner_bits
                .iter()
                .position(|&g| g == qubit - b)
                .unwrap_or_else(|| panic!("qubit {qubit} is an outer global for this stage"));
            b + p
        }
    }

    /// Buffer qubit count: `b + |inner|`.
    pub fn buffer_qubits(&self) -> usize {
        self.layout.block_qubits + self.inner_bits.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_basics() {
        let l = BlockLayout::new(6, 2).unwrap();
        assert_eq!(l.global_qubits(), 4);
        assert_eq!(l.block_len(), 4);
        assert_eq!(l.num_blocks(), 16);
        assert_eq!(l.block_of(0b110101), 0b1101);
        assert_eq!(l.local_of(0b110101), 0b01);
    }

    #[test]
    fn paper_figure5_example() {
        // 6-qubit circuit, b=2, c=4; stage inner = {3, 5} (absolute).
        let l = BlockLayout::new(6, 2).unwrap();
        let gs = l.group_schedule(&[3, 5]).unwrap();
        assert_eq!(gs.blocks_per_group(), 4);
        assert_eq!(gs.num_groups(), 4); // paper: "a total of 4 groups"
        assert_eq!(gs.group_len(), 16);
        assert_eq!(gs.buffer_qubits(), 4);
        // Inner bits within global index: {1, 3}; outer: {0, 2}.
        // Group 0 (outer bits clear): patterns over inner bits.
        assert_eq!(gs.group_blocks(0), vec![0b0000, 0b0010, 0b1000, 0b1010]);
        // Group with outer rank 1 -> outer bit 0 set.
        assert_eq!(gs.group_blocks(1), vec![0b0001, 0b0011, 0b1001, 0b1011]);
        // Group with outer rank 2 -> outer bit 2 set.
        assert_eq!(gs.group_blocks(2), vec![0b0100, 0b0110, 0b1100, 0b1110]);
    }

    #[test]
    fn groups_tile_block_set_exactly_once() {
        for (n, b, inner) in [
            (8usize, 3usize, vec![4usize, 6]),
            (10, 4, vec![5, 7, 9]),
            (7, 7, vec![]),
            (9, 2, vec![2, 3, 4]),
        ] {
            let l = BlockLayout::new(n, b).unwrap();
            let gs = l.group_schedule(&inner).unwrap();
            let mut seen = vec![false; l.num_blocks()];
            for g in 0..gs.num_groups() {
                for id in gs.group_blocks(g) {
                    assert!(!seen[id], "block {id} visited twice");
                    seen[id] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "blocks missed");
        }
    }

    #[test]
    fn buffer_bit_remap() {
        let l = BlockLayout::new(8, 3).unwrap();
        let gs = l.group_schedule(&[4, 6]).unwrap();
        assert_eq!(gs.buffer_bit(0), 0);
        assert_eq!(gs.buffer_bit(2), 2);
        assert_eq!(gs.buffer_bit(4), 3); // first inner -> bit b+0
        assert_eq!(gs.buffer_bit(6), 4); // second inner -> bit b+1
    }

    #[test]
    #[should_panic(expected = "outer global")]
    fn buffer_bit_rejects_outer() {
        let l = BlockLayout::new(8, 3).unwrap();
        let gs = l.group_schedule(&[4]).unwrap();
        gs.buffer_bit(5);
    }

    #[test]
    fn gather_semantics_match_amplitude_indices() {
        // The k-th amplitude of the gathered buffer must be the amplitude
        // whose full index has: local bits = k % block_len, inner global
        // bits = the inner pattern of k's block slot, outer bits = group's.
        let l = BlockLayout::new(6, 2).unwrap();
        let gs = l.group_schedule(&[3, 5]).unwrap();
        for g in 0..gs.num_groups() {
            let blocks = gs.group_blocks(g);
            for (slot, &blk) in blocks.iter().enumerate() {
                for local in 0..l.block_len() {
                    let full_index = (blk << l.block_qubits) | local;
                    let buf_index = (slot << l.block_qubits) | local;
                    // Reconstruct the buffer index from the remapped bits:
                    let mut want = 0usize;
                    for q in 0..l.n_qubits {
                        let bit = (full_index >> q) & 1;
                        if bit == 1 {
                            let pos = if q < l.block_qubits {
                                q
                            } else if let Some(p) =
                                gs.inner.iter().position(|&x| x == q)
                            {
                                l.block_qubits + p
                            } else {
                                continue; // outer bit: constant within group
                            };
                            want |= 1 << pos;
                        }
                    }
                    assert_eq!(buf_index, want);
                }
            }
        }
    }

    #[test]
    fn empty_inner_means_block_per_group() {
        let l = BlockLayout::new(8, 3).unwrap();
        let gs = l.group_schedule(&[]).unwrap();
        assert_eq!(gs.blocks_per_group(), 1);
        assert_eq!(gs.num_groups(), 32);
        assert_eq!(gs.buffer_qubits(), 3);
    }

    #[test]
    fn invalid_inner_rejected() {
        let l = BlockLayout::new(8, 3).unwrap();
        assert!(l.group_schedule(&[2]).is_err()); // local, not global
        assert!(l.group_schedule(&[9]).is_err()); // out of range
        assert!(l.group_schedule(&[5, 4]).is_err()); // unsorted
        assert!(l.group_schedule(&[4, 4]).is_err()); // duplicate
    }
}
