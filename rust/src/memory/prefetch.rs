//! Schedule-driven prefetcher: walks the published group schedule ahead of
//! the pipeline workers and stages upcoming spilled blocks back into the
//! primary tier, so the workers' `take` calls hit RAM instead of paying a
//! mid-chain synchronous disk read.
//!
//! The prefetcher is a plain background thread with its own read buffer.
//! It never holds a shard lock across file I/O: it snapshots a spilled
//! slot's `(offset, len, gen)` under the lock, reads the extent outside
//! it, and installs the promoted payload only if the slot's generation is
//! unchanged (any concurrent `take`/`put` bumps or removes the slot, which
//! invalidates the read). To make room under a tight budget it evicts only
//! blocks whose next use lies *beyond* its prefetch window, preserving the
//! Belady ordering.

use super::{plock, pwait_timeout};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

pub(crate) fn prefetch_loop(shared: Arc<super::Shared>) {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Snapshot the current schedule (cheap: Arc clone of the order).
        // A stitched (cross-stage) schedule has two segments with their
        // own group geometries: `head_groups` groups of `head_bpg` blocks
        // (the draining previous stage), then the next stage at `bpg`.
        let (order, bpg, head_groups, head_bpg) = {
            let s = plock(&shared.sched);
            (s.order.clone(), s.blocks_per_group.max(1), s.head_groups, s.head_bpg.max(1))
        };
        let mut did_work = false;
        if !order.is_empty() {
            let head_blocks = (head_groups * head_bpg).min(order.len());
            let num_groups = head_groups + (order.len() - head_blocks) / bpg;
            // Window base: the farther of the completion cursor and the
            // decode-phase cursor (`group_fetched`). An overlapped
            // pipeline fetches ahead of completion, so windowing off the
            // fetch cursor keeps the prefetcher ahead of *decode* instead
            // of trailing the slower store phase. Depth is dynamic under
            // the AIMD auto-depth controller.
            let progress = shared
                .progress
                .load(Ordering::Acquire)
                .max(shared.fetch_cursor.load(Ordering::Acquire))
                .min(num_groups);
            let depth = shared.dyn_depth.load(Ordering::Relaxed);
            let end = (progress + 1 + depth).min(num_groups);
            // Blocks with rank < `end` are inside the window; eviction to
            // make room may only touch ranks >= `end` (strictly farther).
            for g in progress..end {
                let range = if g < head_groups {
                    g * head_bpg..(g + 1) * head_bpg
                } else {
                    let o = head_blocks + (g - head_groups) * bpg;
                    o..o + bpg
                };
                for &id in &order[range] {
                    if shared.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    if shared.try_promote(id, end as u64, true, &mut buf) {
                        did_work = true;
                    }
                }
            }
        }
        if !did_work {
            // Nothing promotable right now: doze until the engine publishes
            // a schedule / finishes a group (or the timeout re-polls).
            let guard = plock(&shared.sched);
            drop(pwait_timeout(&shared.sched_cv, guard, Duration::from_millis(2)));
        }
    }
}
