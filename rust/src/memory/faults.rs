//! Deterministic fault-injection harness for the two-level store.
//!
//! A [`FaultPlan`] describes *where the disk misbehaves*: scripted fault
//! points ("the 3rd write fails with EIO") and seeded-probabilistic rates
//! ("2% of reads are torn"). The store compiles the plan into a
//! [`FaultInjector`] that every `SpillFile` read/write and every
//! write-back-queue transition consults, so the recovery machinery
//! (checksummed frames, retry/backoff, the write-back retention ring, the
//! ENOSPC degradation ladder, writer self-healing) can be exercised
//! deterministically in tests and at low rates in CI.
//!
//! Plans reach the store through [`super::StoreOptions::fault_plan`] /
//! `SimConfig::fault_plan` (`--fault-plan` on the CLI) or, for CI runs
//! that cannot touch the config, the `BMQSIM_FAULT_PLAN` environment
//! variable (see [`FaultPlan::from_env`]).
//!
//! The module also carries the dependency-free xxhash64 implementation
//! used for spill-frame checksums (the build environment vendors no
//! `xxhash-rust`; see DESIGN.md substitutions).

use crate::types::{Error, Result, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

// ---------------------------------------------------------------------------
// xxhash64 (XXH64, Collet) — spill-frame checksum.
// ---------------------------------------------------------------------------

const PRIME64_1: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME64_2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME64_3: u64 = 0x1656_67B1_9E37_79F9;
const PRIME64_4: u64 = 0x85EB_CA77_C2B2_AE63;
const PRIME64_5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline]
fn xxh_round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME64_2))
        .rotate_left(31)
        .wrapping_mul(PRIME64_1)
}

#[inline]
fn xxh_merge(acc: u64, val: u64) -> u64 {
    (acc ^ xxh_round(0, val)).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4)
}

#[inline]
fn read_u64(b: &[u8]) -> u64 {
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[..8]);
    u64::from_le_bytes(w)
}

#[inline]
fn read_u32(b: &[u8]) -> u32 {
    let mut w = [0u8; 4];
    w.copy_from_slice(&b[..4]);
    u32::from_le_bytes(w)
}

/// XXH64 over `data` with `seed` — the spill-frame checksum.
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let len = data.len() as u64;
    let mut rest = data;
    let mut h: u64 = if rest.len() >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while rest.len() >= 32 {
            v1 = xxh_round(v1, read_u64(rest));
            v2 = xxh_round(v2, read_u64(&rest[8..]));
            v3 = xxh_round(v3, read_u64(&rest[16..]));
            v4 = xxh_round(v4, read_u64(&rest[24..]));
            rest = &rest[32..];
        }
        let mut h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = xxh_merge(h, v1);
        h = xxh_merge(h, v2);
        h = xxh_merge(h, v3);
        xxh_merge(h, v4)
    } else {
        seed.wrapping_add(PRIME64_5)
    };
    h = h.wrapping_add(len);
    while rest.len() >= 8 {
        h = (h ^ xxh_round(0, read_u64(rest))).rotate_left(27).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4);
        rest = &rest[8..];
    }
    if rest.len() >= 4 {
        h = (h ^ u64::from(read_u32(rest)).wrapping_mul(PRIME64_1))
            .rotate_left(23)
            .wrapping_mul(PRIME64_2)
            .wrapping_add(PRIME64_3);
        rest = &rest[4..];
    }
    for &b in rest {
        h = (h ^ u64::from(b).wrapping_mul(PRIME64_5)).rotate_left(11).wrapping_mul(PRIME64_1);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^ (h >> 32)
}

// ---------------------------------------------------------------------------
// Fault plan
// ---------------------------------------------------------------------------

/// What goes wrong at a fault point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Transient I/O error (EIO) — retryable with backoff.
    Eio,
    /// Torn write: only a prefix of the frame reaches the disk before the
    /// op errors (the retry rewrites the whole frame).
    ShortWrite,
    /// Torn read: the tail of the extent comes back as zeros (caught by
    /// the frame checksum, healed by a re-read).
    ShortRead,
    /// One bit of the read buffer flips (transient — a re-read is clean).
    BitFlip,
    /// The extent itself is corrupt: every read of the faulted offset
    /// flips a bit (re-reads don't help; only the write-back retention
    /// ring can recover the bytes).
    StickyFlip,
    /// Disk full (ENOSPC) on write — engages the degradation ladder.
    Enospc,
    /// The writer thread stalls for `FaultPlan::stall_ms` before a job.
    Stall,
    /// The writer thread exits ("dies") after requeueing its current job.
    WriterDeath,
    /// The process aborts on the spot (raw `abort()`, no unwinding, no
    /// destructors) — models SIGKILL / power loss at an exact checkpoint
    /// or manifest I/O boundary, so atomicity tests can prove a torn
    /// write is impossible to observe. Only valid at the `manifest` /
    /// `checkpoint` op sites.
    Kill,
}

/// Which I/O site a scripted fault intercepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    /// Secondary-tier (spill file) reads.
    Read,
    /// Secondary-tier (spill file) writes.
    Write,
    /// Checkpoint manifest writes (temp-file write + the atomic rename).
    Manifest,
    /// Checkpoint block-frame writes (`blocks.bin` payload frames).
    Checkpoint,
}

/// A scripted fault point: the `nth` (1-based) op of type `op` fails with
/// `kind`. Ops count *attempts*, so a retried write consumes fresh
/// indices — `eio@write:3` faults exactly one attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScriptedFault {
    /// Which store operation the fault targets.
    pub op: FaultOp,
    /// 1-based attempt index at which the fault fires.
    pub nth: u64,
    /// The failure injected at that point.
    pub kind: FaultKind,
}

/// Scripted + seeded-probabilistic fault schedule for one store.
///
/// Parseable from a compact spec (CLI `--fault-plan`, env
/// `BMQSIM_FAULT_PLAN`): comma-separated tokens, either rates/knobs
/// (`seed=42`, `eio=0.02`, `short_read=0.01`, `short_write=0.01`,
/// `bitflip=0.05`, `stall=0.1`, `stall_ms=20`, `enospc_after=4096`,
/// `writer_death_after=3`) or scripted points `KIND@OP:N`
/// (`eio@write:3`, `short@read:2`, `bitflip@read:1`,
/// `stickyflip@read:4`, `enospc@write:5`, `stall@write:2`).
///
/// Checkpoint sites: `OP` may also be `manifest` (manifest temp-write /
/// atomic rename) or `checkpoint` (block-frame writes), where `:N` is
/// optional and defaults to 1 — `kill@manifest` aborts the process at
/// the first manifest write, `kill@checkpoint:3` at the third frame,
/// `eio@manifest:1` / `short@checkpoint:2` inject recoverable I/O
/// failures at the same sites.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed for the probabilistic draws (fully deterministic per seed).
    pub seed: u64,
    /// Per-op probability of a transient EIO (reads and writes).
    pub p_eio: f64,
    /// Per-read probability of a torn read (zeroed tail).
    pub p_short_read: f64,
    /// Per-write probability of a torn write (prefix lands, op errors).
    pub p_short_write: f64,
    /// Per-read probability of a one-shot bit flip in the buffer.
    pub p_bitflip: f64,
    /// Per-writer-job probability of a stall of `stall_ms`.
    pub p_stall: f64,
    /// Stall duration for `Stall` faults (default 10 ms).
    pub stall_ms: u64,
    /// Primary spill file reports ENOSPC once this many bytes landed.
    pub enospc_after_bytes: Option<u64>,
    /// Writer thread dies after claiming this many jobs (1-based).
    pub writer_death_after: Option<u64>,
    /// Scripted fault points (see [`ScriptedFault`]).
    pub scripted: Vec<ScriptedFault>,
}

impl FaultPlan {
    /// Parse the compact spec format (see the type docs). Empty specs are
    /// rejected — an empty plan injects nothing and hides typos.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan { stall_ms: 10, ..FaultPlan::default() };
        let bad = |tok: &str, why: &str| {
            Err(Error::Config(format!("fault-plan token {tok:?}: {why}")))
        };
        let mut any = false;
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            any = true;
            if let Some((kind, rest)) = tok.split_once('@') {
                // Scripted: KIND@OP:N (:N optional at checkpoint sites).
                let (op_str, nth_str) = match rest.split_once(':') {
                    Some((o, n)) => (o, Some(n)),
                    None => (rest, None),
                };
                let op = match op_str {
                    "read" => FaultOp::Read,
                    "write" => FaultOp::Write,
                    "manifest" => FaultOp::Manifest,
                    "checkpoint" => FaultOp::Checkpoint,
                    _ => return bad(tok, "op must be read|write|manifest|checkpoint"),
                };
                let kind = match (kind, op) {
                    ("eio", _) => FaultKind::Eio,
                    ("short", FaultOp::Read) => FaultKind::ShortRead,
                    ("short", _) => FaultKind::ShortWrite,
                    ("bitflip", FaultOp::Read) => FaultKind::BitFlip,
                    ("stickyflip", FaultOp::Read) => FaultKind::StickyFlip,
                    ("enospc", FaultOp::Write) => FaultKind::Enospc,
                    ("stall", FaultOp::Write) => FaultKind::Stall,
                    ("kill", FaultOp::Manifest | FaultOp::Checkpoint) => FaultKind::Kill,
                    _ => return bad(tok, "unknown kind or kind/op mismatch"),
                };
                let nth = match nth_str {
                    Some(n) => match n.parse::<u64>() {
                        Ok(n) => n,
                        Err(_) => return bad(tok, "N must be a positive integer"),
                    },
                    // `kill@manifest` ≡ `kill@manifest:1`; read/write sites
                    // keep the explicit-N requirement (hides typos).
                    None if matches!(op, FaultOp::Manifest | FaultOp::Checkpoint) => 1,
                    None => return bad(tok, "expected KIND@OP:N"),
                };
                if nth == 0 {
                    return bad(tok, "N is 1-based");
                }
                plan.scripted.push(ScriptedFault { op, nth, kind });
                continue;
            }
            let Some((key, val)) = tok.split_once('=') else {
                return bad(tok, "expected key=value or KIND@OP:N");
            };
            let prob = |v: &str| -> Result<f64> {
                match v.parse::<f64>() {
                    Ok(p) if (0.0..=1.0).contains(&p) => Ok(p),
                    _ => Err(Error::Config(format!(
                        "fault-plan {key}={v}: probability must be in [0, 1]"
                    ))),
                }
            };
            match key {
                "seed" => match val.parse() {
                    Ok(s) => plan.seed = s,
                    Err(_) => return bad(tok, "seed must be a u64"),
                },
                "eio" => plan.p_eio = prob(val)?,
                "short_read" => plan.p_short_read = prob(val)?,
                "short_write" => plan.p_short_write = prob(val)?,
                "bitflip" => plan.p_bitflip = prob(val)?,
                "stall" => plan.p_stall = prob(val)?,
                "stall_ms" => match val.parse() {
                    Ok(ms) => plan.stall_ms = ms,
                    Err(_) => return bad(tok, "stall_ms must be a u64"),
                },
                "enospc_after" => match val.parse() {
                    Ok(b) => plan.enospc_after_bytes = Some(b),
                    Err(_) => return bad(tok, "enospc_after must be bytes (u64)"),
                },
                "writer_death_after" => match val.parse() {
                    Ok(n) => plan.writer_death_after = Some(n),
                    Err(_) => return bad(tok, "writer_death_after must be a u64"),
                },
                _ => return bad(tok, "unknown key"),
            }
        }
        if !any {
            return Err(Error::Config("empty fault-plan spec".into()));
        }
        Ok(plan)
    }

    /// CI hook: read a plan from `BMQSIM_FAULT_PLAN`. A malformed spec is
    /// reported on stderr and ignored (a CI smoke must not abort on a
    /// typo'd env var — the recovery-counter assertions catch the no-op).
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("BMQSIM_FAULT_PLAN").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("warning: ignoring BMQSIM_FAULT_PLAN: {e}");
                None
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Runtime injector
// ---------------------------------------------------------------------------

/// Which spill file an I/O op targets. The fallback stripe is exempt from
/// ENOSPC injection (it models a separate device), every other fault kind
/// applies to both tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SpillTier {
    Primary,
    Fallback,
}

/// Injected outcome for one write attempt.
pub(crate) enum WriteFault {
    /// Fail with a transient io::Error (retryable).
    Transient(std::io::Error),
    /// Write only the first `n` bytes, then fail transiently.
    Short(usize),
    /// Fail with ENOSPC (not retryable — degradation ladder).
    Enospc,
}

/// Injected outcome for one read attempt.
pub(crate) enum ReadFault {
    /// Fail with a transient io::Error before reading (retryable).
    Transient(std::io::Error),
    /// Zero the buffer past byte `n` (torn read — checksum catches it).
    Short(usize),
    /// Flip one bit of the returned buffer.
    BitFlip,
}

/// Injected outcome for one writer-thread job.
pub(crate) enum WriterFault {
    Stall(Duration),
    Die,
}

/// Injected outcome for one checkpoint-site I/O op (manifest or frame).
pub(crate) enum CkptFault {
    /// Fail with an io::Error (surfaced as `Error::Checkpoint` by the
    /// writer and carried out of the run — a snapshot the operator asked
    /// for but that cannot be persisted is a fatal, typed condition).
    Transient(std::io::Error),
    /// Write only the first `n` bytes, then fail (torn-file modeling).
    Short(usize),
    /// Abort the process on the spot (SIGKILL / power-loss model).
    Kill,
}

pub(crate) fn eio() -> std::io::Error {
    std::io::Error::from_raw_os_error(5) // EIO
}

pub(crate) fn enospc() -> std::io::Error {
    std::io::Error::from_raw_os_error(28) // ENOSPC
}

/// Compiled [`FaultPlan`]: thread-safe decision engine shared by the
/// primary/fallback spill files and the writer loop.
pub(crate) struct FaultInjector {
    plan: FaultPlan,
    rng: Mutex<SplitMix64>,
    reads: AtomicU64,
    writes: AtomicU64,
    jobs: AtomicU64,
    manifest_ops: AtomicU64,
    ckpt_ops: AtomicU64,
    /// Bytes successfully written to the primary tier (ENOSPC trigger).
    primary_written: AtomicU64,
    /// Offsets whose extents are persistently corrupt (StickyFlip).
    sticky: Mutex<Vec<u64>>,
    /// Total faults injected (test/CI visibility).
    pub(crate) injected: AtomicU64,
}

impl FaultInjector {
    pub(crate) fn new(plan: FaultPlan) -> Self {
        let seed = plan.seed;
        FaultInjector {
            plan,
            rng: Mutex::new(SplitMix64::new(seed ^ 0xFA17_0000)),
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            jobs: AtomicU64::new(0),
            manifest_ops: AtomicU64::new(0),
            ckpt_ops: AtomicU64::new(0),
            primary_written: AtomicU64::new(0),
            sticky: Mutex::new(Vec::new()),
            injected: AtomicU64::new(0),
        }
    }

    fn scripted(&self, op: FaultOp, nth: u64) -> Option<FaultKind> {
        self.plan.scripted.iter().find(|s| s.op == op && s.nth == nth).map(|s| s.kind)
    }

    fn draw(&self) -> f64 {
        self.rng.lock().unwrap_or_else(std::sync::PoisonError::into_inner).next_f64()
    }

    fn hit(&self) {
        self.injected.fetch_add(1, Ordering::Relaxed);
    }

    /// Decide the fate of one write attempt of `len` bytes to `tier`.
    pub(crate) fn on_write(&self, tier: SpillTier, len: usize) -> Option<WriteFault> {
        let nth = self.writes.fetch_add(1, Ordering::Relaxed) + 1;
        if tier == SpillTier::Primary {
            if let Some(cap) = self.plan.enospc_after_bytes {
                if self.primary_written.load(Ordering::Relaxed) + len as u64 > cap {
                    self.hit();
                    return Some(WriteFault::Enospc);
                }
            }
        }
        let fault = match self.scripted(FaultOp::Write, nth) {
            Some(FaultKind::Eio) => Some(WriteFault::Transient(eio())),
            Some(FaultKind::ShortWrite) => Some(WriteFault::Short(len / 2)),
            Some(FaultKind::Enospc) if tier == SpillTier::Primary => Some(WriteFault::Enospc),
            _ => {
                let r = self.draw();
                if r < self.plan.p_eio {
                    Some(WriteFault::Transient(eio()))
                } else if r < self.plan.p_eio + self.plan.p_short_write {
                    Some(WriteFault::Short(len / 2))
                } else {
                    None
                }
            }
        };
        match fault {
            Some(f) => {
                self.hit();
                Some(f)
            }
            None => {
                if tier == SpillTier::Primary {
                    self.primary_written.fetch_add(len as u64, Ordering::Relaxed);
                }
                None
            }
        }
    }

    /// Decide the fate of one read attempt at `offset`.
    pub(crate) fn on_read(&self, offset: u64, len: usize) -> Option<ReadFault> {
        let nth = self.reads.fetch_add(1, Ordering::Relaxed) + 1;
        {
            let sticky = self.sticky.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if sticky.contains(&offset) {
                self.hit();
                return Some(ReadFault::BitFlip);
            }
        }
        let fault = match self.scripted(FaultOp::Read, nth) {
            Some(FaultKind::Eio) => Some(ReadFault::Transient(eio())),
            Some(FaultKind::ShortRead) => Some(ReadFault::Short(len / 2)),
            Some(FaultKind::BitFlip) => Some(ReadFault::BitFlip),
            Some(FaultKind::StickyFlip) => {
                self.sticky
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .push(offset);
                Some(ReadFault::BitFlip)
            }
            _ => {
                let r = self.draw();
                if r < self.plan.p_eio {
                    Some(ReadFault::Transient(eio()))
                } else if r < self.plan.p_eio + self.plan.p_short_read {
                    Some(ReadFault::Short(len / 2))
                } else if r < self.plan.p_eio + self.plan.p_short_read + self.plan.p_bitflip {
                    Some(ReadFault::BitFlip)
                } else {
                    None
                }
            }
        };
        if fault.is_some() {
            self.hit();
        }
        fault
    }

    /// Decide the fate of one writer-thread job (stall / death).
    pub(crate) fn on_writer_job(&self) -> Option<WriterFault> {
        let nth = self.jobs.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(after) = self.plan.writer_death_after {
            if nth >= after {
                self.hit();
                return Some(WriterFault::Die);
            }
        }
        let stall = matches!(
            self.scripted(FaultOp::Write, nth),
            Some(FaultKind::Stall)
        ) || (self.plan.p_stall > 0.0 && self.draw() < self.plan.p_stall);
        if stall {
            self.hit();
            return Some(WriterFault::Stall(Duration::from_millis(self.plan.stall_ms.max(1))));
        }
        None
    }

    /// Decide the fate of one checkpoint-site I/O op of `len` bytes.
    /// `op` must be [`FaultOp::Manifest`] or [`FaultOp::Checkpoint`]
    /// (each site counts its own 1-based attempt sequence). Scripted
    /// points only — the probabilistic rates model a flaky *spill* disk,
    /// not the checkpoint destination, and checkpoint atomicity tests
    /// need exact fault placement.
    pub(crate) fn on_checkpoint_io(&self, op: FaultOp, len: usize) -> Option<CkptFault> {
        let ctr = match op {
            FaultOp::Manifest => &self.manifest_ops,
            FaultOp::Checkpoint => &self.ckpt_ops,
            FaultOp::Read | FaultOp::Write => return None,
        };
        let nth = ctr.fetch_add(1, Ordering::Relaxed) + 1;
        let fault = match self.scripted(op, nth) {
            Some(FaultKind::Kill) => Some(CkptFault::Kill),
            Some(FaultKind::Eio) => Some(CkptFault::Transient(eio())),
            Some(FaultKind::ShortWrite) => Some(CkptFault::Short(len / 2)),
            _ => None,
        };
        if fault.is_some() {
            self.hit();
        }
        fault
    }

    /// Apply a bit flip to `buf` (deterministic position: middle byte).
    pub(crate) fn flip_bit(buf: &mut [u8]) {
        if !buf.is_empty() {
            let i = buf.len() / 2;
            buf[i] ^= 0x01;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn xxh64_known_vector_and_properties() {
        // The canonical empty-input vector (xxHash reference, seed 0).
        assert_eq!(xxh64(b"", 0), 0xEF46_DB37_51D8_E999);
        // Determinism + sensitivity across the stripe/tail code paths.
        for len in [1usize, 3, 4, 7, 8, 15, 31, 32, 33, 64, 100] {
            let data: Vec<u8> = (0..len).map(|i| (i * 37 + 11) as u8).collect();
            let h = xxh64(&data, 7);
            assert_eq!(h, xxh64(&data, 7), "len {len}: not deterministic");
            assert_ne!(h, xxh64(&data, 8), "len {len}: seed-insensitive");
            let mut flipped = data.clone();
            flipped[len / 2] ^= 0x01;
            assert_ne!(h, xxh64(&flipped, 7), "len {len}: bit-flip-insensitive");
        }
    }

    #[test]
    fn plan_parses_rates_and_scripts() {
        let p = FaultPlan::parse(
            "seed=9,eio=0.25,bitflip=0.5,stall_ms=3,enospc_after=4096,\
             eio@write:3,short@read:2,stickyflip@read:4,writer_death_after=2",
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.p_eio, 0.25);
        assert_eq!(p.p_bitflip, 0.5);
        assert_eq!(p.stall_ms, 3);
        assert_eq!(p.enospc_after_bytes, Some(4096));
        assert_eq!(p.writer_death_after, Some(2));
        assert_eq!(p.scripted.len(), 3);
        assert!(p
            .scripted
            .contains(&ScriptedFault { op: FaultOp::Write, nth: 3, kind: FaultKind::Eio }));
        assert!(p
            .scripted
            .contains(&ScriptedFault { op: FaultOp::Read, nth: 2, kind: FaultKind::ShortRead }));
    }

    #[test]
    fn plan_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("eio=1.5").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("eio@write").is_err());
        assert!(FaultPlan::parse("eio@flush:1").is_err());
        assert!(FaultPlan::parse("bitflip@write:1").is_err());
        assert!(FaultPlan::parse("eio@write:0").is_err());
    }

    #[test]
    fn scripted_write_fault_fires_exactly_once() {
        let inj = FaultInjector::new(FaultPlan::parse("eio@write:2").unwrap());
        assert!(inj.on_write(SpillTier::Primary, 64).is_none());
        assert!(matches!(inj.on_write(SpillTier::Primary, 64), Some(WriteFault::Transient(_))));
        assert!(inj.on_write(SpillTier::Primary, 64).is_none());
        assert_eq!(inj.injected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn probabilistic_faults_are_seed_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            let inj = FaultInjector::new(FaultPlan {
                seed,
                p_eio: 0.3,
                ..FaultPlan::default()
            });
            (0..64).map(|_| inj.on_read(0, 64).is_some()).collect()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
        assert!(run(1).iter().any(|&f| f), "p=0.3 over 64 ops injected nothing");
    }

    #[test]
    fn enospc_after_bytes_spares_the_fallback_tier() {
        let inj =
            FaultInjector::new(FaultPlan { enospc_after_bytes: Some(100), ..Default::default() });
        assert!(inj.on_write(SpillTier::Primary, 80).is_none());
        assert!(matches!(inj.on_write(SpillTier::Primary, 80), Some(WriteFault::Enospc)));
        assert!(inj.on_write(SpillTier::Fallback, 80).is_none(), "fallback is a separate device");
    }

    #[test]
    fn sticky_flip_corrupts_every_reread() {
        let inj = FaultInjector::new(FaultPlan::parse("stickyflip@read:1").unwrap());
        assert!(matches!(inj.on_read(128, 64), Some(ReadFault::BitFlip)));
        // Same offset: corrupt forever. Different offset: clean.
        assert!(matches!(inj.on_read(128, 64), Some(ReadFault::BitFlip)));
        assert!(inj.on_read(256, 64).is_none());
    }

    #[test]
    fn checkpoint_sites_parse_and_fire() {
        let p = FaultPlan::parse("kill@manifest,kill@checkpoint:3,eio@manifest:2").unwrap();
        assert!(p.scripted.contains(&ScriptedFault {
            op: FaultOp::Manifest,
            nth: 1,
            kind: FaultKind::Kill
        }));
        assert!(p.scripted.contains(&ScriptedFault {
            op: FaultOp::Checkpoint,
            nth: 3,
            kind: FaultKind::Kill
        }));
        let inj = FaultInjector::new(p);
        // Manifest site: kill on attempt 1, eio on attempt 2.
        assert!(matches!(inj.on_checkpoint_io(FaultOp::Manifest, 64), Some(CkptFault::Kill)));
        assert!(matches!(
            inj.on_checkpoint_io(FaultOp::Manifest, 64),
            Some(CkptFault::Transient(_))
        ));
        // Checkpoint-frame site counts independently: clean, clean, kill.
        assert!(inj.on_checkpoint_io(FaultOp::Checkpoint, 64).is_none());
        assert!(inj.on_checkpoint_io(FaultOp::Checkpoint, 64).is_none());
        assert!(matches!(inj.on_checkpoint_io(FaultOp::Checkpoint, 64), Some(CkptFault::Kill)));
        assert_eq!(inj.injected.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn checkpoint_sites_do_not_leak_into_spill_ops() {
        // A manifest-site script must never fire at the spill read/write
        // sites, and the checkpoint hook injects nothing without a script.
        let inj = FaultInjector::new(FaultPlan::parse("kill@manifest:1").unwrap());
        assert!(inj.on_write(SpillTier::Primary, 64).is_none());
        assert!(inj.on_read(0, 64).is_none());
        let clean = FaultInjector::new(FaultPlan::parse("eio@write:1").unwrap());
        assert!(clean.on_checkpoint_io(FaultOp::Manifest, 64).is_none());
        assert!(clean.on_checkpoint_io(FaultOp::Checkpoint, 64).is_none());
    }

    #[test]
    fn kill_rejected_at_spill_sites_and_bare_n_still_required_there() {
        assert!(FaultPlan::parse("kill@write:1").is_err());
        assert!(FaultPlan::parse("kill@read:1").is_err());
        assert!(FaultPlan::parse("eio@write").is_err(), ":N stays mandatory at spill sites");
        assert!(FaultPlan::parse("kill@manifest:0").is_err());
    }

    #[test]
    fn writer_death_after_n_jobs() {
        let inj =
            FaultInjector::new(FaultPlan { writer_death_after: Some(3), ..Default::default() });
        assert!(inj.on_writer_job().is_none());
        assert!(inj.on_writer_job().is_none());
        assert!(matches!(inj.on_writer_job(), Some(WriterFault::Die)));
    }
}
