//! Two-level memory management (paper §4.4): a **sharded**, I/O-decoupled
//! block store.
//!
//! Compressed SV blocks have *unpredictable* sizes (Challenge ④): the
//! compression ratio depends on state content, so a fixed primary budget
//! can overflow mid-simulation. [`BlockStore`] keeps compressed blocks in a
//! budgeted primary tier (host RAM; the paper's CPU DRAM) and overflows to
//! a secondary-tier file (the GPUDirect-Storage/SSD analogue).
//!
//! Layering (see DESIGN.md "Two-level memory"):
//!
//! * **Shards** — block slots live in `N` independently locked maps keyed
//!   by block id, so pipeline workers on disjoint groups never contend on
//!   one global lock. **No file I/O ever happens under a shard lock.**
//! * **Belady eviction** — the engine publishes each stage's group
//!   schedule ([`BlockStore::publish_schedule`]); when the budget
//!   overflows, the store evicts the resident block whose next use is
//!   *farthest* in the schedule (the schedule is fully known per stage,
//!   so Belady's optimal policy is implementable), instead of exiling the
//!   hot block just written.
//! * **Async spill writer** (`spill.rs`) — eviction candidates enter a
//!   write-back queue; a background thread performs the file writes.
//!   `take`/`get`/`put` intercept queued blocks before they hit disk.
//! * **Prefetcher** (`prefetch.rs`) — walks the schedule ahead of the
//!   workers and stages upcoming spilled blocks back into primary, turning
//!   mid-chain synchronous disk reads into primary hits.
//!
//! The store also keeps the statistics behind Fig. 9 (peak footprint),
//! §5.4's spill fractions, and the new eviction/prefetch/stall counters.
//!
//! **Failure domains** (DESIGN.md "Failure domains & recovery"): spilled
//! frames carry checksummed headers verified on every read; transient I/O
//! is retried with backoff; corrupt frames are healed from a small
//! retention ring of recently written payloads; ENOSPC degrades
//! gracefully (fallback stripe → budget renegotiation) instead of
//! erroring; and a dead or panicked spill writer is survived by draining
//! the write-back queue inline. All locking is poison-recovering
//! ([`plock`]): one panicked worker can never wedge the store. A
//! [`FaultPlan`] makes every one of those paths deterministically
//! testable.

pub mod checkpoint;
mod faults;
mod prefetch;
mod spill;

pub use faults::{xxh64, FaultKind, FaultOp, FaultPlan, ScriptedFault};

use crate::types::{Error, Result};
use faults::{FaultInjector, SpillTier};
use spill::{RecoveryCounters, SpillFile};
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Poison-recovering lock: a thread that panicked while holding a store
/// mutex must not wedge every sibling. All store state is kept consistent
/// *across* lock sections (atomics + verify-after-reacquire protocols),
/// so the data under a poisoned guard is safe to keep using; failures the
/// panicking thread caused surface through `Shared::failure` instead.
pub(crate) fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-recovering `Condvar::wait_timeout` (timeout result discarded —
/// every caller re-checks its predicate in a loop).
pub(crate) fn pwait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _)) => g,
        Err(p) => p.into_inner().0,
    }
}

/// One compressed block's payload: both planes, length-framed.
///
/// Payloads are *recycled* on the pipeline hot path: the byte buffers a
/// worker receives from [`BlockStore::take`] are reused as
/// `compress_into` outputs for the updated planes and handed straight
/// back to [`BlockStore::put`], so in steady state block bytes cycle
/// store → worker → store without fresh allocations (§Perf, DESIGN.md).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlockPayload {
    /// Compressed real plane.
    pub re: Vec<u8>,
    /// Compressed imaginary plane.
    pub im: Vec<u8>,
}

impl BlockPayload {
    /// Total compressed bytes across both planes.
    pub fn len(&self) -> usize {
        self.re.len() + self.im.len()
    }

    /// True when both planes are empty.
    pub fn is_empty(&self) -> bool {
        self.re.is_empty() && self.im.is_empty()
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() + 16);
        out.extend_from_slice(&(self.re.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.im.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.re);
        out.extend_from_slice(&self.im);
        out
    }

    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 16 {
            return Err(Error::Codec("block payload truncated".into()));
        }
        let re_len = u64::from_le_bytes(bytes[0..8].try_into().expect("8-byte slice")) as usize;
        let im_len = u64::from_le_bytes(bytes[8..16].try_into().expect("8-byte slice")) as usize;
        if bytes.len() != 16 + re_len + im_len {
            return Err(Error::Codec("block payload length mismatch".into()));
        }
        Ok(BlockPayload {
            re: bytes[16..16 + re_len].to_vec(),
            im: bytes[16 + re_len..].to_vec(),
        })
    }
}

/// Framing overhead of [`BlockPayload::to_bytes`] (two u64 lengths).
const FRAME_BYTES: usize = 16;

/// Total per-block overhead on the secondary tier: the in-RAM payload
/// framing plus the on-disk frame header (magic + length + xxh64). Extent
/// lengths and `secondary_bytes` include both.
pub const SECONDARY_FRAME_BYTES: usize = FRAME_BYTES + spill::HEADER_BYTES;

/// Payloads retained after their spill write completes, so a frame that
/// later fails verification can be healed without touching the disk.
/// Bounded: corruption recovery is best-effort beyond the last
/// `RECOVERY_RING_CAP` spills (older corrupt frames surface as typed
/// [`Error::Corruption`], never as silent damage).
const RECOVERY_RING_CAP: usize = 8;

#[derive(Default)]
struct RecoveryRing {
    entries: VecDeque<(usize, u64, BlockPayload)>,
}

impl RecoveryRing {
    fn insert(&mut self, id: usize, gen: u64, payload: BlockPayload) {
        self.entries.retain(|(i, _, _)| *i != id);
        if self.entries.len() >= RECOVERY_RING_CAP {
            self.entries.pop_front();
        }
        self.entries.push_back((id, gen, payload));
    }

    fn remove(&mut self, id: usize, gen: u64) -> Option<BlockPayload> {
        let idx = self.entries.iter().position(|(i, g, _)| *i == id && *g == gen)?;
        self.entries.remove(idx).map(|(_, _, p)| p)
    }

    fn drop_entry(&mut self, id: usize, gen: u64) {
        self.entries.retain(|(i, g, _)| !(*i == id && *g == gen));
    }
}

/// Next-use rank for blocks already processed this stage (next use is the
/// following stage at the earliest — prime eviction candidates).
const DONE_BASE: u64 = 1 << 40;
/// Rank for blocks absent from the published schedule (never used this
/// stage — evicted first).
const NO_USE: u64 = u64::MAX;

#[derive(Debug)]
enum Slot {
    /// Resident in the primary tier. `prefetched` marks blocks staged by
    /// the prefetcher, so `take` can count prefetch hits.
    Primary { payload: BlockPayload, prefetched: bool },
    /// Eviction in progress: the payload sits in the write-back queue
    /// (interceptable) or is being written by the spill writer (waiters
    /// block until the slot flips to `Spilled`).
    Evicting { epoch: u64 },
    /// On disk (on `tier`). `gen` guards lock-free readers: any slot
    /// transition bumps it, invalidating reads that raced with an extent
    /// reuse.
    Spilled { tier: SpillTier, offset: u64, len: usize, gen: u64 },
}

/// Write-back entry state: queued payloads are interceptable; in-flight
/// writes force interceptors to wait for the `Spilled` transition.
enum WbState {
    Queued(BlockPayload),
    InFlight,
}

struct WbEntry {
    epoch: u64,
    state: WbState,
}

#[derive(Default)]
struct WriteBack {
    /// FIFO of (block id, eviction epoch); stale entries are skipped.
    queue: VecDeque<(usize, u64)>,
    map: HashMap<usize, WbEntry>,
}

/// Belady policy state: next-use rank per block id (group position in the
/// published schedule) and an ordered index of primary-resident blocks.
#[derive(Default)]
struct Policy {
    rank: HashMap<usize, u64>,
    /// (rank, id) — `last()` is the eviction victim.
    resident: BTreeSet<(u64, usize)>,
    /// id → rank key currently used in `resident`.
    resident_rank: HashMap<usize, u64>,
    done_seq: u64,
}

/// Prefetcher input: the flat block order of the current stage — or, in
/// the stitched (cross-stage) form, of the still-draining previous stage
/// followed by the next stage. The two segments may have different group
/// geometries: the first `head_groups * head_bpg` blocks belong to the
/// previous stage (`head_bpg` blocks per group), the rest to the next
/// stage at `blocks_per_group`. A plain publication has `head_groups = 0`.
#[derive(Default)]
struct ScheduleState {
    order: Arc<Vec<usize>>,
    blocks_per_group: usize,
    head_groups: usize,
    head_bpg: usize,
}

/// First background-spill failure, recorded where it happened and
/// re-surfaced as a typed [`Error::Spill`] (with the originating
/// `io::Error` reconstructed as `source()`) on every subsequent store op.
struct FailureRecord {
    msg: String,
    io: Option<(std::io::ErrorKind, Option<i32>, String)>,
}

/// Controller-approved recompression hook (the compressed-primary third
/// tier): given a block id and its current payload, re-encode it at a
/// looser bound and return the smaller payload, or `None` to decline (no
/// budget left, nothing to gain). Installed by the engines when a
/// fidelity target is set; the store calls it from [`Shared::evict_one`]
/// with no locks held.
pub type RecompressFn = dyn Fn(usize, &BlockPayload) -> Option<BlockPayload> + Send + Sync;

/// Shareable [`RecompressFn`] wrapper so [`StoreOptions`] keeps its
/// `Debug`/`Clone` derives.
#[derive(Clone)]
pub struct Recompressor(pub Arc<RecompressFn>);

impl std::fmt::Debug for Recompressor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Recompressor(..)")
    }
}

/// Store tuning knobs (see `SimConfig::{store_shards, prefetch_depth,
/// sync_spill}` and the corresponding CLI flags).
#[derive(Debug, Clone)]
pub struct StoreOptions {
    /// Lock shards (rounded up to a power of two).
    pub shards: usize,
    /// Groups the prefetcher stages ahead of the workers (0 = disabled).
    /// With `auto_depth` this is only the *starting* depth.
    pub prefetch_depth: usize,
    /// Background spill writer (false = spill inline on the caller, the
    /// single-lock-era behaviour minus the I/O-under-lock).
    pub async_spill: bool,
    /// Max blocks in the write-back queue before `put` back-pressures.
    pub write_back_cap: usize,
    /// Adapt the prefetch depth per stage (AIMD on the observed hit/miss
    /// ratio and spill stall time) instead of holding `prefetch_depth`
    /// fixed. See [`Shared::auto_depth_step`].
    pub auto_depth: bool,
    /// Deterministic fault schedule for the spill I/O paths (tests / CI
    /// chaos runs; `None` in production).
    pub fault_plan: Option<FaultPlan>,
    /// Second spill stripe used when the primary spill device reports
    /// ENOSPC (the degradation ladder's middle rung).
    pub fallback_dir: Option<PathBuf>,
    /// Compressed-primary third tier: under budget pressure, offer an
    /// eviction victim to this hook first — a controller-approved harder
    /// recompression keeps the block resident (smaller) instead of
    /// spilling it. `None` (default) = classic two-tier behaviour.
    pub recompressor: Option<Recompressor>,
}

impl Default for StoreOptions {
    fn default() -> Self {
        StoreOptions {
            shards: 8,
            prefetch_depth: 4,
            async_spill: true,
            write_back_cap: 64,
            auto_depth: false,
            fault_plan: None,
            fallback_dir: None,
            recompressor: None,
        }
    }
}

/// Auto-depth bounds and thresholds (see [`Shared::auto_depth_step`]).
const AUTO_DEPTH_MAX: usize = 32;
/// Stall growth per stage that counts as prefetch pressure even without
/// an outright miss (in-flight-write waits, back-pressure): 200 µs.
const AUTO_DEPTH_STALL_STEP_NS: u64 = 200_000;

/// Last-stage counter snapshot the AIMD step diffs against. `primed`
/// distinguishes "no stage observed yet" from "an idle stage ran": the
/// very first publish only records the baseline, it never steps.
#[derive(Default)]
struct AutoDepthState {
    primed: bool,
    hits: u64,
    misses: u64,
    stall_ns: u64,
}

/// Cumulative statistics, readable at any time.
#[derive(Debug, Default, Clone)]
pub struct MemStats {
    /// Compressed bytes currently resident in the primary (RAM) tier.
    pub primary_bytes: usize,
    /// High-water mark of `primary_bytes`.
    pub peak_primary_bytes: usize,
    /// Bytes currently spilled to the secondary (disk) tier.
    pub secondary_bytes: usize,
    /// High-water mark of `secondary_bytes`.
    pub peak_secondary_bytes: usize,
    /// Bytes currently staged in the write-back queue (RAM, leaving).
    pub write_back_bytes: usize,
    /// Blocks written to the secondary tier (spills).
    pub spill_events: u64,
    /// Blocks read back from the secondary tier.
    pub fetch_from_secondary: u64,
    /// Blocks currently resident in primary.
    pub blocks_primary: usize,
    /// Blocks currently in the secondary tier.
    pub blocks_secondary: usize,
    /// Blocks currently staged in the write-back queue.
    pub blocks_write_back: usize,
    /// Budget-driven evictions of a resident victim (policy decisions;
    /// `spill_events` additionally counts budget-bypass direct spills).
    pub evictions: u64,
    /// `take` served from primary by a prefetcher-staged block.
    pub prefetch_hits: u64,
    /// `take` that paid a synchronous disk read while a schedule was
    /// published (the reads prefetching exists to remove).
    pub prefetch_misses: u64,
    /// Worker time stalled on spill machinery: in-flight write waits,
    /// write-back back-pressure, and synchronous secondary-tier reads.
    pub spill_stall_ns: u64,
    /// Prefetch depth at snapshot time (tracks the AIMD controller when
    /// `StoreOptions::auto_depth` is set, else the configured constant).
    pub prefetch_depth: usize,
    /// Transient-I/O attempts that were retried with backoff.
    pub io_retries: u64,
    /// Spill-frame reads that failed header/checksum verification.
    pub checksum_failures: u64,
    /// Corrupt frames healed from the write-back retention ring.
    pub frames_recovered: u64,
    /// ENOSPC degradations: fallback-stripe writes + budget
    /// renegotiations (the store kept running instead of erroring).
    pub enospc_fallbacks: u64,
    /// Eviction victims kept resident by a controller-approved harder
    /// recompression (the compressed-primary third tier) instead of
    /// being spilled.
    pub recompressions: u64,
}

impl MemStats {
    /// Peak total compressed footprint (Fig. 9's "practical memory").
    pub fn peak_total(&self) -> usize {
        // peaks may not coincide, so this is an upper bound; tracked
        // precisely by peak_total_bytes in the store.
        self.peak_primary_bytes + self.peak_secondary_bytes
    }

    /// Fraction of resident blocks on (or bound for) the secondary tier
    /// (§5.4).
    pub fn secondary_fraction(&self) -> f64 {
        let off_primary = self.blocks_secondary + self.blocks_write_back;
        let total = self.blocks_primary + off_primary;
        if total == 0 {
            0.0
        } else {
            off_primary as f64 / total as f64
        }
    }

    /// Prefetch hit rate over all schedule-covered secondary fetches.
    pub fn prefetch_hit_rate(&self) -> f64 {
        let total = self.prefetch_hits + self.prefetch_misses;
        if total == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / total as f64
        }
    }
}

/// Copy of a slot's state, extracted so locks can be dropped before
/// acting (no borrows into the shard map survive the peek).
enum Peek {
    Missing,
    Prim,
    Evict(u64),
    Spill { tier: SpillTier, offset: u64, len: usize, gen: u64 },
}

fn peek(slots: &HashMap<usize, Slot>, id: usize) -> Peek {
    match slots.get(&id) {
        None => Peek::Missing,
        Some(Slot::Primary { .. }) => Peek::Prim,
        Some(Slot::Evicting { epoch }) => Peek::Evict(*epoch),
        Some(&Slot::Spilled { tier, offset, len, gen }) => Peek::Spill { tier, offset, len, gen },
    }
}

/// State shared between the store handle, the spill writer, and the
/// prefetcher. All methods uphold one invariant: **no file I/O while any
/// shard lock is held** — disk work happens between a peek (copy slot
/// state out) and a verify (re-lock, check the slot didn't move).
pub(crate) struct Shared {
    budget: Option<usize>,
    opts: StoreOptions,
    shards: Vec<Mutex<HashMap<usize, Slot>>>,
    shard_mask: usize,
    policy: Mutex<Policy>,
    spill: Option<SpillFile>,
    /// Second spill stripe for ENOSPC degradation (eagerly created when
    /// `StoreOptions::fallback_dir` is configured and spilling is on).
    fallback: Option<SpillFile>,
    /// Compiled fault schedule (tests / CI chaos runs).
    pub(crate) injector: Option<Arc<FaultInjector>>,
    /// Recovery telemetry shared with both spill files.
    counters: Arc<RecoveryCounters>,
    /// Recently spilled payloads retained for corruption recovery.
    recovery: Mutex<RecoveryRing>,
    /// Set when the primary spill device hit ENOSPC with no usable
    /// fallback: eviction stops (it can't go anywhere) and the primary
    /// budget is renegotiated instead.
    evict_halted: AtomicBool,
    /// Renegotiated budget headroom granted by the ENOSPC ladder.
    budget_bump: AtomicUsize,
    /// False once the background writer died (injected death or panic);
    /// the store then spills inline and drains the queue itself.
    pub(crate) writer_alive: AtomicBool,
    pub(crate) wb: Mutex<WriteBack>,
    pub(crate) wb_cv: Condvar,
    pub(crate) sched: Mutex<ScheduleState>,
    pub(crate) sched_cv: Condvar,
    pub(crate) progress: AtomicUsize,
    /// Schedule cursor advanced by the *decode* phase (group fetched, not
    /// yet stored back). The prefetcher windows off
    /// `max(progress, fetch_cursor)` so an overlapped pipeline's
    /// read-ahead pulls the window forward before groups complete.
    pub(crate) fetch_cursor: AtomicUsize,
    /// Current prefetch depth: `opts.prefetch_depth` when fixed, adapted
    /// per stage when `opts.auto_depth`.
    pub(crate) dyn_depth: AtomicUsize,
    auto_state: Mutex<AutoDepthState>,
    pub(crate) shutdown: AtomicBool,
    /// Source for eviction epochs and spill generations.
    epoch_counter: AtomicU64,
    /// First spill-writer failure, surfaced on the next store op.
    failure: Mutex<Option<FailureRecord>>,

    primary_bytes: AtomicUsize,
    peak_primary: AtomicUsize,
    secondary_bytes: AtomicUsize,
    peak_secondary: AtomicUsize,
    wb_bytes: AtomicUsize,
    peak_total: AtomicUsize,
    blocks_primary: AtomicUsize,
    blocks_secondary: AtomicUsize,
    wb_blocks: AtomicUsize,
    spill_events: AtomicU64,
    fetch_secondary: AtomicU64,
    sched_epoch: AtomicU64,
    evictions: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetch_misses: AtomicU64,
    spill_stall_ns: AtomicU64,
    /// Victims kept resident by the recompression hook instead of spilled.
    recompressions: AtomicU64,
}

impl Shared {
    fn shard(&self, id: usize) -> &Mutex<HashMap<usize, Slot>> {
        &self.shards[id & self.shard_mask]
    }

    fn next_epoch(&self) -> u64 {
        self.epoch_counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub(crate) fn record_failure(&self, e: &Error) {
        let mut f = plock(&self.failure);
        if f.is_none() {
            let io = match e {
                Error::Io(io) | Error::Spill { source: Some(io), .. } => {
                    Some((io.kind(), io.raw_os_error(), io.to_string()))
                }
                _ => None,
            };
            let msg = match e {
                // Avoid double-wrapping: check_failure re-prefixes.
                Error::Spill { msg, .. } => msg.clone(),
                other => other.to_string(),
            };
            *f = Some(FailureRecord { msg, io });
        }
    }

    fn check_failure(&self) -> Result<()> {
        match plock(&self.failure).as_ref() {
            Some(r) => {
                let source = r.io.as_ref().map(|(kind, raw, msg)| match raw {
                    Some(errno) => std::io::Error::from_raw_os_error(*errno),
                    None => std::io::Error::new(*kind, msg.clone()),
                });
                Err(Error::Spill {
                    msg: format!("spill writer failed: {}", r.msg),
                    source,
                })
            }
            None => Ok(()),
        }
    }

    fn stall(&self, t0: Instant) {
        self.spill_stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    fn bump_peaks(&self) {
        let p = self.primary_bytes.load(Ordering::Relaxed);
        let s = self.secondary_bytes.load(Ordering::Relaxed);
        let w = self.wb_bytes.load(Ordering::Relaxed);
        self.peak_primary.fetch_max(p, Ordering::Relaxed);
        self.peak_secondary.fetch_max(s, Ordering::Relaxed);
        self.peak_total.fetch_max(p + s + w, Ordering::Relaxed);
    }

    /// Effective primary budget: the configured ceiling plus any headroom
    /// the ENOSPC ladder renegotiated (payloads that had nowhere to go).
    fn effective_budget(&self) -> Option<usize> {
        self.budget.map(|b| b + self.budget_bump.load(Ordering::Relaxed))
    }

    /// Reserve `len` bytes of primary budget. With a budget this is a CAS
    /// loop that never lets `primary_bytes` exceed it; without one it
    /// always succeeds.
    fn try_reserve(&self, len: usize) -> bool {
        match self.effective_budget() {
            None => {
                self.primary_bytes.fetch_add(len, Ordering::Relaxed);
                true
            }
            Some(b) => {
                let mut cur = self.primary_bytes.load(Ordering::Relaxed);
                loop {
                    if cur + len > b {
                        return false;
                    }
                    match self.primary_bytes.compare_exchange_weak(
                        cur,
                        cur + len,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => return true,
                        Err(c) => cur = c,
                    }
                }
            }
        }
    }

    fn unreserve(&self, len: usize) {
        self.primary_bytes.fetch_sub(len, Ordering::Relaxed);
    }

    /// The spill file serving `tier`. Callers only hold a tier they read
    /// out of an installed `Spilled` slot, so the file must exist.
    fn file_for(&self, tier: SpillTier) -> &SpillFile {
        let f = match tier {
            SpillTier::Primary => self.spill.as_ref(),
            SpillTier::Fallback => self.fallback.as_ref(),
        };
        f.expect("spilled slot without a spill file for its tier")
    }

    /// Heal a corrupt frame from the retention ring (consuming the entry).
    fn recover_frame(&self, id: usize, gen: u64) -> Option<BlockPayload> {
        let p = plock(&self.recovery).remove(id, gen)?;
        self.counters.frames_recovered.fetch_add(1, Ordering::Relaxed);
        Some(p)
    }

    /// The extent for (id, gen) was freed after a clean read: its ring
    /// entry (if any) can never be needed again.
    fn recovery_drop(&self, id: usize, gen: u64) {
        plock(&self.recovery).drop_entry(id, gen);
    }

    // ---- Belady policy index (metadata only; no I/O under this lock) ----

    fn policy_insert(&self, id: usize) {
        let mut p = plock(&self.policy);
        let r = *p.rank.get(&id).unwrap_or(&NO_USE);
        if let Some(old) = p.resident_rank.insert(id, r) {
            p.resident.remove(&(old, id));
        }
        p.resident.insert((r, id));
    }

    fn policy_remove(&self, id: usize) {
        let mut p = plock(&self.policy);
        if let Some(old) = p.resident_rank.remove(&id) {
            p.resident.remove(&(old, id));
        }
    }

    /// The block was consumed this stage: its next use is next stage at
    /// the earliest, so a subsequent `put` files it as a prime victim.
    fn policy_mark_done(&self, id: usize) {
        let mut p = plock(&self.policy);
        p.done_seq += 1;
        let r = DONE_BASE + p.done_seq;
        p.rank.insert(id, r);
        if let Some(old) = p.resident_rank.remove(&id) {
            p.resident.remove(&(old, id));
        }
    }

    /// Pop the farthest-next-use resident candidate (rank >= `min_rank`).
    fn policy_pick_victim(&self, min_rank: u64) -> Option<usize> {
        let mut p = plock(&self.policy);
        let &(rank, id) = p.resident.iter().next_back()?;
        if rank < min_rank {
            return None;
        }
        p.resident.remove(&(rank, id));
        p.resident_rank.remove(&id);
        Some(id)
    }

    /// Fallback victim search when the index is empty or stale: scan the
    /// shards for primary blocks, rank them, pick the farthest.
    fn scan_for_victim(&self, min_rank: u64) -> Option<usize> {
        let mut candidates: Vec<usize> = Vec::new();
        for shard in &self.shards {
            let sg = plock(shard);
            candidates.extend(
                sg.iter()
                    .filter(|(_, s)| matches!(s, Slot::Primary { .. }))
                    .map(|(&id, _)| id),
            );
        }
        if candidates.is_empty() {
            return None;
        }
        let p = plock(&self.policy);
        let mut best: Option<(u64, usize)> = None;
        for id in candidates {
            let r = *p.rank.get(&id).unwrap_or(&NO_USE);
            let better = match best {
                None => true,
                Some((br, bid)) => (r, id) > (br, bid),
            };
            if better {
                best = Some((r, id));
            }
        }
        drop(p);
        let (r, id) = best?;
        if r < min_rank {
            None
        } else {
            Some(id)
        }
    }

    // ---- Eviction & spilling ----

    /// Evict one primary-resident block (next use farthest, rank >=
    /// `min_rank`) into the write-back pipeline. Returns false when no
    /// eligible victim exists.
    fn evict_one(&self, min_rank: u64) -> Result<bool> {
        if self.evict_halted.load(Ordering::Relaxed) {
            // ENOSPC ladder bottom rung: the spill devices are full, so
            // eviction has nowhere to go — callers renegotiate the budget
            // instead of churning the write-back pipeline.
            return Ok(false);
        }
        for _ in 0..64 {
            let victim = match self.policy_pick_victim(min_rank) {
                Some(v) => Some(v),
                None => self.scan_for_victim(min_rank),
            };
            let Some(victim) = victim else { return Ok(false) };
            let epoch = self.next_epoch();
            let payload = {
                let mut sg = plock(self.shard(victim));
                if matches!(sg.get(&victim), Some(Slot::Primary { .. })) {
                    let Some(Slot::Primary { payload, .. }) =
                        sg.insert(victim, Slot::Evicting { epoch })
                    else {
                        unreachable!()
                    };
                    Some(payload)
                } else {
                    None // raced with take/put: stale candidate, try next
                }
            };
            let Some(payload) = payload else { continue };
            let len = payload.len();
            // Compressed-primary third tier: before paying a spill, offer
            // the victim to the recompression hook. Runs with no locks
            // held; concurrent `take`s of the victim spin on the
            // `Evicting` slot and observe the reinstalled `Primary`.
            // Primary accounting stays charged until the decision, so the
            // budget reservation protocol (`peak <= budget`) is untouched:
            // on success the footprint only shrinks, on decline the
            // classic spill flow below takes over.
            if let Some(rc) = &self.opts.recompressor {
                if let Some(smaller) = (rc.0)(victim, &payload) {
                    if smaller.len() < len {
                        let slen = smaller.len();
                        plock(self.shard(victim))
                            .insert(victim, Slot::Primary { payload: smaller, prefetched: false });
                        self.primary_bytes.fetch_sub(len - slen, Ordering::Relaxed);
                        self.policy_insert(victim);
                        self.recompressions.fetch_add(1, Ordering::Relaxed);
                        return Ok(true);
                    }
                }
            }
            self.primary_bytes.fetch_sub(len, Ordering::Relaxed);
            self.blocks_primary.fetch_sub(1, Ordering::Relaxed);
            self.wb_bytes.fetch_add(len, Ordering::Relaxed);
            self.wb_blocks.fetch_add(1, Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            self.spill_events.fetch_add(1, Ordering::Relaxed);
            self.dispatch_spill(victim, epoch, payload);
            self.check_failure()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Route an `Evicting` payload to disk: enqueue for the background
    /// writer, or write inline on the calling thread (sync mode, or
    /// self-healing after the writer died).
    fn dispatch_spill(&self, id: usize, epoch: u64, payload: BlockPayload) {
        if self.opts.async_spill && self.writer_alive.load(Ordering::Acquire) {
            let mut wg = plock(&self.wb);
            wg.map.insert(id, WbEntry { epoch, state: WbState::Queued(payload) });
            wg.queue.push_back((id, epoch));
            drop(wg);
            self.wb_cv.notify_all();
        } else {
            self.spill_block_now(id, epoch, payload);
        }
    }

    /// Pop the oldest claimable write-back job (stale entries skipped),
    /// flipping it `Queued` → `InFlight`. Shared by the writer thread and
    /// the inline drain path.
    pub(crate) fn claim_next(wb: &mut WriteBack) -> Option<(usize, u64, BlockPayload)> {
        while let Some((id, epoch)) = wb.queue.pop_front() {
            let take = matches!(
                wb.map.get(&id),
                Some(e) if e.epoch == epoch && matches!(e.state, WbState::Queued(_))
            );
            if take {
                let entry = wb.map.get_mut(&id).expect("claimable entry vanished");
                let state = std::mem::replace(&mut entry.state, WbState::InFlight);
                let WbState::Queued(payload) = state else { unreachable!() };
                return Some((id, epoch, payload));
            }
        }
        None
    }

    /// Put a claimed-but-unwritten job back at the head of the queue
    /// (writer death mid-claim: nothing may be lost).
    pub(crate) fn requeue_job(&self, id: usize, epoch: u64, payload: BlockPayload) {
        let mut wg = plock(&self.wb);
        if matches!(wg.map.get(&id), Some(e) if e.epoch == epoch) {
            if let Some(e) = wg.map.get_mut(&id) {
                e.state = WbState::Queued(payload);
            }
            wg.queue.push_front((id, epoch));
        }
        drop(wg);
        self.wb_cv.notify_all();
    }

    /// Self-healing drain: with the background writer dead, foreground
    /// threads (flush, back-pressured put) claim and write the remaining
    /// queue entries themselves. Bounded — a job another thread holds
    /// in flight must finish or the wait surfaces as a typed error.
    fn drain_wb_inline(&self) -> Result<()> {
        let mut spins = 0u32;
        while self.wb_blocks.load(Ordering::Relaxed) > 0 {
            if self.shutdown.load(Ordering::Acquire) {
                return Ok(());
            }
            self.check_failure()?;
            let claimed = {
                let mut wg = plock(&self.wb);
                Self::claim_next(&mut wg)
            };
            match claimed {
                Some((id, epoch, payload)) => {
                    self.spill_block_now(id, epoch, payload);
                    spins = 0;
                }
                None => {
                    // Nothing claimable but blocks still counted: another
                    // drainer's write is in flight — wait it out.
                    let wg = plock(&self.wb);
                    if self.wb_blocks.load(Ordering::Relaxed) == 0 {
                        return Ok(());
                    }
                    drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
                    spins += 1;
                    if spins > 120_000 {
                        return Err(Error::spill(
                            "write-back queue never drained after spill-writer death",
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// A block that cannot fit the primary tier at all bypasses it
    /// (paper: "directly save this chunk to the storage via GDS").
    fn spill_incoming(&self, id: usize, payload: BlockPayload) -> Result<()> {
        let epoch = self.next_epoch();
        plock(self.shard(id)).insert(id, Slot::Evicting { epoch });
        self.wb_bytes.fetch_add(payload.len(), Ordering::Relaxed);
        self.wb_blocks.fetch_add(1, Ordering::Relaxed);
        self.spill_events.fetch_add(1, Ordering::Relaxed);
        self.bump_peaks();
        self.dispatch_spill(id, epoch, payload);
        self.check_failure()
    }

    /// Serialize → write → install `Spilled`, entirely outside shard
    /// locks. Called by the writer thread (async) or inline (sync /
    /// writer-dead drain). ENOSPC walks the degradation ladder: primary
    /// stripe → fallback stripe → reinstate in primary with a
    /// renegotiated budget. Only non-ENOSPC hard failures poison the
    /// store (`record_failure`).
    pub(crate) fn spill_block_now(&self, id: usize, epoch: u64, payload: BlockPayload) {
        let plen = payload.len();
        let bytes = payload.to_bytes();
        let mut tier = SpillTier::Primary;
        let mut written: Result<(u64, usize)> = match self.spill.as_ref() {
            Some(spill) => spill.write(&bytes),
            None => Err(Error::spill("spill file missing")),
        };
        if matches!(&written, Err(e) if spill::error_is_enospc(e)) {
            // Ladder rung 2: the fallback stripe (a different device).
            if let Some(fb) = self.fallback.as_ref() {
                self.counters.enospc_fallbacks.fetch_add(1, Ordering::Relaxed);
                tier = SpillTier::Fallback;
                written = fb.write(&bytes);
            }
        }
        match written {
            Ok((offset, stored)) => {
                let gen = self.next_epoch();
                let installed = {
                    let mut sg = plock(self.shard(id));
                    match sg.get(&id) {
                        Some(Slot::Evicting { epoch: e }) if *e == epoch => {
                            sg.insert(id, Slot::Spilled { tier, offset, len: stored, gen });
                            true
                        }
                        _ => false,
                    }
                };
                if installed {
                    self.secondary_bytes.fetch_add(stored, Ordering::Relaxed);
                    self.blocks_secondary.fetch_add(1, Ordering::Relaxed);
                    // Retain the payload for corruption recovery: a frame
                    // that fails its checksum on read heals from here.
                    plock(&self.recovery).insert(id, gen, payload);
                } else {
                    // Unreachable by protocol (interceptors wait on
                    // in-flight writes); defensively drop the disk copy.
                    self.file_for(tier).free_extent(offset, stored);
                }
                // Write-back accounting is released only now, AFTER the
                // Spilled slot is installed: flush()/stats() never observe
                // a block in no tier.
                self.wb_bytes.fetch_sub(plen, Ordering::Relaxed);
                self.wb_blocks.fetch_sub(1, Ordering::Relaxed);
                self.bump_peaks();
            }
            Err(e) => {
                // Never lose data: reinstate the payload in primary (even
                // over budget).
                {
                    let mut sg = plock(self.shard(id));
                    if matches!(sg.get(&id), Some(Slot::Evicting { epoch: ep }) if *ep == epoch) {
                        sg.insert(id, Slot::Primary { payload, prefetched: false });
                        self.primary_bytes.fetch_add(plen, Ordering::Relaxed);
                        self.blocks_primary.fetch_add(1, Ordering::Relaxed);
                    }
                }
                self.wb_bytes.fetch_sub(plen, Ordering::Relaxed);
                self.wb_blocks.fetch_sub(1, Ordering::Relaxed);
                if self.budget.is_some() {
                    self.policy_insert(id);
                }
                if spill::error_is_enospc(&e) {
                    // Ladder rung 3, graceful: every spill device is full,
                    // so stop evicting and renegotiate the primary budget
                    // by the stranded payload's size. The simulation keeps
                    // running with a larger RAM footprint instead of
                    // dying on a full disk.
                    self.evict_halted.store(true, Ordering::Relaxed);
                    self.budget_bump.fetch_add(plen, Ordering::Relaxed);
                    self.counters.enospc_fallbacks.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.record_failure(&e);
                }
            }
        }
        let mut wg = plock(&self.wb);
        if matches!(wg.map.get(&id), Some(en) if en.epoch == epoch) {
            wg.map.remove(&id);
        }
        drop(wg);
        self.wb_cv.notify_all();
    }

    /// Remove any existing version of `id` (any tier), waiting out
    /// in-flight spill writes. No-op when absent.
    fn clear_slot(&self, id: usize) -> Result<()> {
        let mut spins = 0u32;
        loop {
            let mut sg = plock(self.shard(id));
            match peek(&sg, id) {
                Peek::Missing => return Ok(()),
                Peek::Prim => {
                    let Some(Slot::Primary { payload, .. }) = sg.remove(&id) else {
                        unreachable!()
                    };
                    drop(sg);
                    self.primary_bytes.fetch_sub(payload.len(), Ordering::Relaxed);
                    self.blocks_primary.fetch_sub(1, Ordering::Relaxed);
                    if self.budget.is_some() {
                        self.policy_remove(id);
                    }
                    return Ok(());
                }
                Peek::Evict(epoch) => {
                    let mut wg = plock(&self.wb);
                    let queued = matches!(
                        wg.map.get(&id),
                        Some(e) if e.epoch == epoch && matches!(e.state, WbState::Queued(_))
                    );
                    if queued {
                        let entry = wg.map.remove(&id).expect("queued entry vanished");
                        let WbState::Queued(payload) = entry.state else { unreachable!() };
                        sg.remove(&id);
                        drop(wg);
                        drop(sg);
                        self.wb_bytes.fetch_sub(payload.len(), Ordering::Relaxed);
                        self.wb_blocks.fetch_sub(1, Ordering::Relaxed);
                        self.wb_cv.notify_all();
                        return Ok(());
                    }
                    drop(sg);
                    let t0 = Instant::now();
                    drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
                    self.stall(t0);
                    spins += 1;
                    if spins > 120_000 {
                        return Err(Error::spill(format!(
                            "block {id}: spill write never completed"
                        )));
                    }
                    self.check_failure()?;
                }
                Peek::Spill { tier, offset, len, gen } => {
                    sg.remove(&id);
                    drop(sg);
                    self.secondary_bytes.fetch_sub(len, Ordering::Relaxed);
                    self.blocks_secondary.fetch_sub(1, Ordering::Relaxed);
                    self.file_for(tier).free_extent(offset, len);
                    self.recovery_drop(id, gen);
                    return Ok(());
                }
            }
        }
    }

    // ---- Public-facing operations (via BlockStore) ----

    fn put(&self, id: usize, payload: BlockPayload) -> Result<()> {
        self.check_failure()?;
        let len = payload.len();
        self.clear_slot(id)?;
        let mut attempts = 0u32;
        let mut waits = 0u32;
        while !self.try_reserve(len) {
            attempts += 1;
            if self.spill.is_none() {
                return Err(Error::OutOfMemory(format!(
                    "block {id} ({len} B) exceeds primary budget {:?} and no spill dir configured",
                    self.budget
                )));
            }
            // Back-pressure: bound the write-back queue's RAM. Bounded
            // like every other wait path — a wedged writer must surface
            // as an error, not a silent hang.
            if self.opts.async_spill
                && self.wb_blocks.load(Ordering::Relaxed) >= self.opts.write_back_cap
            {
                if !self.writer_alive.load(Ordering::Acquire) {
                    // The writer died with a full queue: self-heal by
                    // draining it on this thread.
                    self.drain_wb_inline()?;
                    continue;
                }
                let t0 = Instant::now();
                let wg = plock(&self.wb);
                drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
                self.stall(t0);
                waits += 1;
                if waits > 120_000 {
                    return Err(Error::spill(format!(
                        "block {id}: write-back queue never drained"
                    )));
                }
                self.check_failure()?;
                continue;
            }
            if attempts > 10_000 || !self.evict_one(0)? {
                return self.spill_incoming(id, payload);
            }
        }
        plock(self.shard(id)).insert(id, Slot::Primary { payload, prefetched: false });
        self.blocks_primary.fetch_add(1, Ordering::Relaxed);
        if self.budget.is_some() {
            self.policy_insert(id);
        }
        self.bump_peaks();
        Ok(())
    }

    fn take(&self, id: usize) -> Result<BlockPayload> {
        self.check_failure()?;
        let mut spins = 0u32;
        loop {
            let mut sg = plock(self.shard(id));
            match peek(&sg, id) {
                Peek::Missing => {
                    return Err(Error::OutOfMemory(format!("block {id} not resident")))
                }
                Peek::Prim => {
                    let Some(Slot::Primary { payload, prefetched }) = sg.remove(&id) else {
                        unreachable!()
                    };
                    drop(sg);
                    self.primary_bytes.fetch_sub(payload.len(), Ordering::Relaxed);
                    self.blocks_primary.fetch_sub(1, Ordering::Relaxed);
                    if prefetched {
                        self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    if self.budget.is_some() {
                        self.policy_mark_done(id);
                    }
                    return Ok(payload);
                }
                Peek::Evict(epoch) => {
                    let mut wg = plock(&self.wb);
                    let queued = matches!(
                        wg.map.get(&id),
                        Some(e) if e.epoch == epoch && matches!(e.state, WbState::Queued(_))
                    );
                    if queued {
                        // Intercept the block before it hits disk.
                        let entry = wg.map.remove(&id).expect("queued entry vanished");
                        let WbState::Queued(payload) = entry.state else { unreachable!() };
                        sg.remove(&id);
                        drop(wg);
                        drop(sg);
                        self.wb_bytes.fetch_sub(payload.len(), Ordering::Relaxed);
                        self.wb_blocks.fetch_sub(1, Ordering::Relaxed);
                        if self.budget.is_some() {
                            self.policy_mark_done(id);
                        }
                        self.wb_cv.notify_all();
                        return Ok(payload);
                    }
                    // Write in flight: wait (outside the shard lock) for
                    // the Spilled transition, then retry.
                    drop(sg);
                    let t0 = Instant::now();
                    drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
                    self.stall(t0);
                    spins += 1;
                    if spins > 120_000 {
                        return Err(Error::spill(format!(
                            "block {id}: spill write never completed"
                        )));
                    }
                    self.check_failure()?;
                }
                Peek::Spill { tier, offset, len, gen } => {
                    sg.remove(&id);
                    drop(sg);
                    self.secondary_bytes.fetch_sub(len, Ordering::Relaxed);
                    self.blocks_secondary.fetch_sub(1, Ordering::Relaxed);
                    self.fetch_secondary.fetch_add(1, Ordering::Relaxed);
                    if self.sched_epoch.load(Ordering::Relaxed) > 0 {
                        self.prefetch_misses.fetch_add(1, Ordering::Relaxed);
                    }
                    if self.budget.is_some() {
                        self.policy_mark_done(id);
                    }
                    // The extent is unreachable (slot removed) until we
                    // free it below, so the read races with nothing.
                    let spill = self.file_for(tier);
                    let t0 = Instant::now();
                    let mut buf = Vec::new();
                    let read = spill.read_frame(offset, len, &mut buf);
                    // The slot is already gone either way: release the
                    // extent even on a read error (no one references it).
                    spill.free_extent(offset, len);
                    self.stall(t0);
                    return match read {
                        Ok(()) => {
                            self.recovery_drop(id, gen);
                            BlockPayload::from_bytes(&buf)
                        }
                        Err(e @ Error::Corruption(_)) => {
                            // The frame is damaged on disk — heal from the
                            // write-back retention ring if it still holds
                            // this exact spill's payload.
                            match self.recover_frame(id, gen) {
                                Some(p) => Ok(p),
                                None => Err(e),
                            }
                        }
                        Err(e) => Err(e),
                    };
                }
            }
        }
    }

    fn get(&self, id: usize) -> Result<BlockPayload> {
        self.check_failure()?;
        let mut spins = 0u32;
        loop {
            let sg = plock(self.shard(id));
            match peek(&sg, id) {
                Peek::Missing => {
                    return Err(Error::OutOfMemory(format!("block {id} not resident")))
                }
                Peek::Prim => {
                    let Some(Slot::Primary { payload, .. }) = sg.get(&id) else {
                        unreachable!()
                    };
                    return Ok(payload.clone());
                }
                Peek::Evict(epoch) => {
                    let wg = plock(&self.wb);
                    if let Some(e) = wg.map.get(&id) {
                        if e.epoch == epoch {
                            if let WbState::Queued(p) = &e.state {
                                // Still queued: read it from RAM and let the
                                // write-back proceed.
                                return Ok(p.clone());
                            }
                        }
                    }
                    drop(sg);
                    let t0 = Instant::now();
                    drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
                    self.stall(t0);
                    spins += 1;
                    if spins > 120_000 {
                        return Err(Error::spill(format!(
                            "block {id}: spill write never completed"
                        )));
                    }
                    self.check_failure()?;
                }
                Peek::Spill { tier, offset, len, gen } => {
                    drop(sg);
                    let spill = self.file_for(tier);
                    let t0 = Instant::now();
                    let mut buf = Vec::new();
                    let read = spill.read_frame(offset, len, &mut buf);
                    self.stall(t0);
                    let mut sg = plock(self.shard(id));
                    let unchanged =
                        matches!(sg.get(&id), Some(&Slot::Spilled { gen: g, .. }) if g == gen);
                    if !unchanged {
                        // The slot moved while we read (take/put/prefetch
                        // raced): discard and re-resolve.
                        drop(sg);
                        spins += 1;
                        if spins > 120_000 {
                            return Err(Error::spill(format!(
                                "block {id}: unstable under concurrent churn"
                            )));
                        }
                        continue;
                    }
                    // Generation verified: the extent was stable for the
                    // whole read, so a corrupt frame is real disk damage —
                    // heal it from the retention ring (clone: the extent
                    // stays installed) or surface the typed error.
                    let payload = match read {
                        Ok(()) => BlockPayload::from_bytes(&buf)?,
                        Err(e @ Error::Corruption(_)) => {
                            let ring = plock(&self.recovery);
                            let found = ring
                                .entries
                                .iter()
                                .find(|(i, g, _)| *i == id && *g == gen)
                                .map(|(_, _, p)| p.clone());
                            drop(ring);
                            match found {
                                Some(p) => {
                                    self.counters
                                        .frames_recovered
                                        .fetch_add(1, Ordering::Relaxed);
                                    p
                                }
                                None => return Err(e),
                            }
                        }
                        Err(e) => return Err(e),
                    };
                    self.fetch_secondary.fetch_add(1, Ordering::Relaxed);
                    // Promote to primary when the budget allows, so
                    // repeated terminal reads (materialize / observables)
                    // stop re-reading the file.
                    if self.try_reserve(payload.len()) {
                        sg.insert(
                            id,
                            Slot::Primary { payload: payload.clone(), prefetched: false },
                        );
                        drop(sg);
                        self.blocks_primary.fetch_add(1, Ordering::Relaxed);
                        self.secondary_bytes.fetch_sub(len, Ordering::Relaxed);
                        self.blocks_secondary.fetch_sub(1, Ordering::Relaxed);
                        spill.free_extent(offset, len);
                        self.recovery_drop(id, gen);
                        if self.budget.is_some() {
                            self.policy_insert(id);
                        }
                        self.bump_peaks();
                    }
                    return Ok(payload);
                }
            }
        }
    }

    /// Prefetcher work unit: promote a spilled block into primary if its
    /// read survives generation checks. Eviction to make room only touches
    /// blocks with rank >= `protect_below` (beyond the prefetch window).
    pub(crate) fn try_promote(
        &self,
        id: usize,
        protect_below: u64,
        mark_prefetched: bool,
        buf: &mut Vec<u8>,
    ) -> bool {
        let (tier, offset, len, gen) = {
            let sg = plock(self.shard(id));
            match sg.get(&id) {
                Some(&Slot::Spilled { tier, offset, len, gen }) => (tier, offset, len, gen),
                _ => return false,
            }
        };
        let plen = len.saturating_sub(SECONDARY_FRAME_BYTES);
        let mut guard = 0u32;
        while !self.try_reserve(plen) {
            guard += 1;
            if guard > 64 || !matches!(self.evict_one(protect_below), Ok(true)) {
                return false;
            }
        }
        let spill = self.file_for(tier);
        if spill.read_frame(offset, len, buf).is_err() {
            // Corrupt or unreadable: leave it for the foreground take(),
            // which can heal from the retention ring.
            self.unreserve(plen);
            return false;
        }
        let parsed = BlockPayload::from_bytes(buf);
        let mut sg = plock(self.shard(id));
        let unchanged = matches!(sg.get(&id), Some(&Slot::Spilled { gen: g, .. }) if g == gen);
        let payload = match (unchanged, parsed) {
            (true, Ok(p)) => p,
            _ => {
                drop(sg);
                self.unreserve(plen);
                return false;
            }
        };
        sg.insert(id, Slot::Primary { payload, prefetched: mark_prefetched });
        drop(sg);
        self.blocks_primary.fetch_add(1, Ordering::Relaxed);
        self.secondary_bytes.fetch_sub(len, Ordering::Relaxed);
        self.blocks_secondary.fetch_sub(1, Ordering::Relaxed);
        spill.free_extent(offset, len);
        self.recovery_drop(id, gen);
        if self.budget.is_some() {
            self.policy_insert(id);
        }
        self.bump_peaks();
        true
    }

    /// How many of `ids` would cost a synchronous disk read to fetch
    /// right now (slot on the secondary tier, or absent). Primary and
    /// write-back-queued blocks are free: `take` intercepts the queue in
    /// RAM. This is the spill-aware-scheduling query — engines rank a
    /// stage's groups by it and run the cheap (resident) groups first,
    /// shrinking the prefetcher's cold-start window.
    fn residency_rank(&self, ids: &[usize]) -> usize {
        ids.iter()
            .filter(|&&id| {
                let sg = plock(self.shard(id));
                matches!(peek(&sg, id), Peek::Spill { .. } | Peek::Missing)
            })
            .count()
    }

    /// Decode-phase cursor: a group's blocks were all fetched (taken).
    fn group_fetched(&self) {
        self.fetch_cursor.fetch_add(1, Ordering::AcqRel);
        self.sched_cv.notify_all();
    }

    /// One AIMD step of the prefetch-depth controller, run per published
    /// schedule (i.e. per stage): misses or stall growth since the last
    /// stage mean the window is too shallow → additive increase; a stage
    /// with no secondary-tier traffic at all means there is nothing to
    /// stage → multiplicative decrease back toward 1 (cheap to re-grow).
    fn auto_depth_step(&self) {
        let hits = self.prefetch_hits.load(Ordering::Relaxed);
        let misses = self.prefetch_misses.load(Ordering::Relaxed);
        let stall = self.spill_stall_ns.load(Ordering::Relaxed);
        let mut last = plock(&self.auto_state);
        let primed = last.primed;
        let hit_d = hits.saturating_sub(last.hits);
        let miss_d = misses.saturating_sub(last.misses);
        let stall_d = stall.saturating_sub(last.stall_ns);
        *last = AutoDepthState { primed: true, hits, misses, stall_ns: stall };
        drop(last);
        if !primed {
            // First stage of the run: no prior stage to diff against —
            // "no history" must not read as "idle stage" and shrink the
            // window during exactly the cold start prefetching covers.
            return;
        }
        let cur = self.dyn_depth.load(Ordering::Relaxed);
        let next = if miss_d > 0 || stall_d > AUTO_DEPTH_STALL_STEP_NS {
            (cur + 1).min(AUTO_DEPTH_MAX)
        } else if hit_d == 0 {
            (cur / 2).max(1)
        } else {
            cur
        };
        if next != cur {
            self.dyn_depth.store(next, Ordering::Relaxed);
        }
    }

    fn publish_schedule(&self, order: &[usize], blocks_per_group: usize) {
        let bpg = blocks_per_group.max(1);
        {
            let mut s = plock(&self.sched);
            s.order = Arc::new(order.to_vec());
            s.blocks_per_group = bpg;
            s.head_groups = 0;
            s.head_bpg = 1;
        }
        if self.opts.auto_depth {
            self.auto_depth_step();
        }
        self.sched_epoch.fetch_add(1, Ordering::Relaxed);
        self.progress.store(0, Ordering::Release);
        self.fetch_cursor.store(0, Ordering::Release);
        if self.budget.is_some() {
            {
                let mut p = plock(&self.policy);
                p.rank.clear();
                p.done_seq = 0;
                for (i, &id) in order.iter().enumerate() {
                    p.rank.insert(id, (i / bpg) as u64);
                }
            }
            self.rekey_residents();
        }
        self.sched_cv.notify_all();
    }

    /// Epoch-aware (stitched) schedule publication for cross-stage
    /// overlap: `head` is the still-draining previous stage's flat block
    /// order (grouped at `head_bpg`), `tail` the next stage's order at
    /// `tail_bpg`. Unlike [`Self::publish_schedule`], the group cursors
    /// are NOT reset — they are *rebased* by `retired_groups` (the group
    /// count of the stage that just left the window, which the caller
    /// guarantees is fully completed), so Belady eviction ranks and the
    /// prefetch window span the stage boundary instead of restarting from
    /// zero while the previous stage's tail is still encoding.
    fn publish_schedule_stitched(
        &self,
        head: &[usize],
        head_bpg: usize,
        tail: &[usize],
        tail_bpg: usize,
        retired_groups: usize,
    ) {
        let head_bpg = head_bpg.max(1);
        let tail_bpg = tail_bpg.max(1);
        let head_groups = head.len() / head_bpg;
        let mut order = Vec::with_capacity(head.len() + tail.len());
        order.extend_from_slice(head);
        order.extend_from_slice(tail);
        {
            let mut s = plock(&self.sched);
            s.order = Arc::new(order);
            s.blocks_per_group = tail_bpg;
            s.head_groups = head_groups;
            s.head_bpg = head_bpg;
        }
        if self.opts.auto_depth {
            self.auto_depth_step();
        }
        self.sched_epoch.fetch_add(1, Ordering::Relaxed);
        // Rebase, not reset: the previous stage is still running, so its
        // workers' concurrent `group_completed`/`group_fetched` increments
        // must survive the publication. `fetch_update` keeps the
        // subtraction atomic against them.
        let rebase = |c: &AtomicUsize| {
            let _ = c.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                Some(v.saturating_sub(retired_groups))
            });
        };
        rebase(&self.progress);
        rebase(&self.fetch_cursor);
        if self.budget.is_some() {
            // Groups of the head already completed keep no head rank —
            // their next use is their tail occurrence (racy snapshot;
            // ranks are a performance policy, not a correctness one).
            let start = self.progress.load(Ordering::Acquire);
            {
                let mut p = plock(&self.policy);
                p.rank.clear();
                p.done_seq = 0;
                // First-future-use wins: a block in both segments keeps
                // its earlier (head) rank — that IS its next use.
                for (i, &id) in head.iter().enumerate().skip(start * head_bpg) {
                    p.rank.entry(id).or_insert((i / head_bpg) as u64);
                }
                for (j, &id) in tail.iter().enumerate() {
                    p.rank.entry(id).or_insert((head_groups + j / tail_bpg) as u64);
                }
            }
            self.rekey_residents();
        }
        self.sched_cv.notify_all();
    }

    /// Re-key the resident index under freshly rebuilt ranks, shard by
    /// shard (entries for ids that move mid-rebuild self-heal via the
    /// victim verify-and-skip loop).
    fn rekey_residents(&self) {
        for shard in &self.shards {
            let sg = plock(shard);
            let ids: Vec<usize> = sg
                .iter()
                .filter(|(_, s)| matches!(s, Slot::Primary { .. }))
                .map(|(&id, _)| id)
                .collect();
            drop(sg);
            for id in ids {
                self.policy_insert(id);
            }
        }
    }

    fn group_completed(&self) {
        self.progress.fetch_add(1, Ordering::AcqRel);
        self.sched_cv.notify_all();
    }

    fn flush(&self) -> Result<()> {
        let mut spins = 0u32;
        while self.wb_blocks.load(Ordering::Relaxed) > 0 {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            self.check_failure()?;
            if self.opts.async_spill && !self.writer_alive.load(Ordering::Acquire) {
                // Writer died: drain its queue on this thread.
                self.drain_wb_inline()?;
                continue;
            }
            let wg = plock(&self.wb);
            if self.wb_blocks.load(Ordering::Relaxed) == 0 {
                break;
            }
            drop(pwait_timeout(&self.wb_cv, wg, Duration::from_millis(1)));
            spins += 1;
            if spins > 120_000 {
                return Err(Error::spill("write-back queue never drained in flush"));
            }
        }
        self.check_failure()
    }

    fn stats(&self) -> MemStats {
        MemStats {
            primary_bytes: self.primary_bytes.load(Ordering::Relaxed),
            peak_primary_bytes: self.peak_primary.load(Ordering::Relaxed),
            secondary_bytes: self.secondary_bytes.load(Ordering::Relaxed),
            peak_secondary_bytes: self.peak_secondary.load(Ordering::Relaxed),
            write_back_bytes: self.wb_bytes.load(Ordering::Relaxed),
            spill_events: self.spill_events.load(Ordering::Relaxed),
            fetch_from_secondary: self.fetch_secondary.load(Ordering::Relaxed),
            blocks_primary: self.blocks_primary.load(Ordering::Relaxed),
            blocks_secondary: self.blocks_secondary.load(Ordering::Relaxed),
            blocks_write_back: self.wb_blocks.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_misses: self.prefetch_misses.load(Ordering::Relaxed),
            spill_stall_ns: self.spill_stall_ns.load(Ordering::Relaxed),
            prefetch_depth: self.dyn_depth.load(Ordering::Relaxed),
            io_retries: self.counters.io_retries.load(Ordering::Relaxed),
            checksum_failures: self.counters.checksum_failures.load(Ordering::Relaxed),
            frames_recovered: self.counters.frames_recovered.load(Ordering::Relaxed),
            enospc_fallbacks: self.counters.enospc_fallbacks.load(Ordering::Relaxed),
            recompressions: self.recompressions.load(Ordering::Relaxed),
        }
    }
}

/// Thread-safe, sharded two-level block store.
pub struct BlockStore {
    shared: Arc<Shared>,
    writer: Option<std::thread::JoinHandle<()>>,
    prefetcher: Option<std::thread::JoinHandle<()>>,
}

impl BlockStore {
    /// `budget = None` disables the secondary tier entirely; putting beyond
    /// the budget then returns [`Error::OutOfMemory`]. Uses default
    /// [`StoreOptions`].
    pub fn new(budget: Option<usize>, spill_dir: Option<PathBuf>) -> Result<Self> {
        Self::with_options(budget, spill_dir, StoreOptions::default())
    }

    /// Full-control constructor: shard count, prefetch depth, sync/async
    /// spill. Background threads spawn only when spilling is configured.
    pub fn with_options(
        budget: Option<usize>,
        spill_dir: Option<PathBuf>,
        opts: StoreOptions,
    ) -> Result<Self> {
        // Hoisted before `opts` moves into Shared.
        let async_spill = opts.async_spill;
        let prefetch_depth = opts.prefetch_depth;
        let auto_depth = opts.auto_depth;
        let injector = opts.fault_plan.clone().map(|p| Arc::new(FaultInjector::new(p)));
        let counters = Arc::new(RecoveryCounters::default());
        let spill = match (&budget, &spill_dir) {
            (Some(_), Some(dir)) => Some(SpillFile::create(
                dir,
                SpillTier::Primary,
                injector.clone(),
                Arc::clone(&counters),
            )?),
            _ => None,
        };
        // The ENOSPC fallback stripe is created eagerly: a full disk is
        // the worst moment to discover the fallback dir isn't writable.
        let fallback = match (&spill, &opts.fallback_dir) {
            (Some(_), Some(dir)) => Some(SpillFile::create(
                dir,
                SpillTier::Fallback,
                injector.clone(),
                Arc::clone(&counters),
            )?),
            _ => None,
        };
        let nshards = opts.shards.max(1).next_power_of_two();
        let shared = Arc::new(Shared {
            budget,
            opts,
            shards: (0..nshards).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_mask: nshards - 1,
            policy: Mutex::new(Policy::default()),
            spill,
            fallback,
            injector,
            counters,
            recovery: Mutex::new(RecoveryRing::default()),
            evict_halted: AtomicBool::new(false),
            budget_bump: AtomicUsize::new(0),
            writer_alive: AtomicBool::new(true),
            wb: Mutex::new(WriteBack::default()),
            wb_cv: Condvar::new(),
            sched: Mutex::new(ScheduleState::default()),
            sched_cv: Condvar::new(),
            progress: AtomicUsize::new(0),
            fetch_cursor: AtomicUsize::new(0),
            dyn_depth: AtomicUsize::new(prefetch_depth.max(usize::from(auto_depth))),
            auto_state: Mutex::new(AutoDepthState::default()),
            shutdown: AtomicBool::new(false),
            epoch_counter: AtomicU64::new(0),
            failure: Mutex::new(None),
            primary_bytes: AtomicUsize::new(0),
            peak_primary: AtomicUsize::new(0),
            secondary_bytes: AtomicUsize::new(0),
            peak_secondary: AtomicUsize::new(0),
            wb_bytes: AtomicUsize::new(0),
            peak_total: AtomicUsize::new(0),
            blocks_primary: AtomicUsize::new(0),
            blocks_secondary: AtomicUsize::new(0),
            wb_blocks: AtomicUsize::new(0),
            spill_events: AtomicU64::new(0),
            fetch_secondary: AtomicU64::new(0),
            sched_epoch: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            prefetch_misses: AtomicU64::new(0),
            spill_stall_ns: AtomicU64::new(0),
            recompressions: AtomicU64::new(0),
        });
        let mut store = BlockStore { shared, prefetcher: None, writer: None };
        if store.shared.spill.is_some() {
            if async_spill {
                let s = Arc::clone(&store.shared);
                store.writer = Some(
                    std::thread::Builder::new()
                        .name("bmqsim-spill".into())
                        .spawn(move || spill::writer_loop(s))
                        .map_err(Error::Io)?,
                );
            }
            if prefetch_depth > 0 || auto_depth {
                let s = Arc::clone(&store.shared);
                store.prefetcher = Some(
                    std::thread::Builder::new()
                        .name("bmqsim-prefetch".into())
                        .spawn(move || prefetch::prefetch_loop(s))
                        .map_err(Error::Io)?,
                );
            }
        }
        Ok(store)
    }

    /// Unbounded in-RAM store (the common case when memory suffices).
    pub fn unbounded() -> Self {
        Self::new(None, None).expect("unbounded store cannot fail")
    }

    /// Insert/overwrite block `id`. When the primary budget would be
    /// exceeded, the *farthest-next-use* resident block is evicted to the
    /// write-back pipeline (Belady; falls back to spilling the incoming
    /// block only when nothing else is evictable).
    pub fn put(&self, id: usize, payload: BlockPayload) -> Result<()> {
        self.shared.put(id, payload)
    }

    /// Remove and return block `id` (the engines' fetch-for-update path —
    /// the block's budget is released while it's being worked on).
    /// Intercepts queued write-backs before they hit disk.
    pub fn take(&self, id: usize) -> Result<BlockPayload> {
        self.shared.take(id)
    }

    /// Read a block without removing it (terminal state materialization).
    /// Spilled blocks are promoted back to primary when the budget allows.
    pub fn get(&self, id: usize) -> Result<BlockPayload> {
        self.shared.get(id)
    }

    /// True if the store currently holds block `id` (any tier).
    pub fn contains(&self, id: usize) -> bool {
        plock(self.shared.shard(id)).contains_key(&id)
    }

    /// Publish a stitched two-stage schedule (cross-stage overlap): the
    /// still-draining previous stage's flat block order (`head`, grouped
    /// at `head_bpg`) followed by the next stage's (`tail` at `tail_bpg`).
    /// Group cursors are rebased by `retired_groups` — the caller's
    /// guarantee that the stage leaving the window has fully completed —
    /// instead of reset, so Belady ranks and the prefetch window span the
    /// boundary. See [`BlockStore::publish_schedule`] for the plain form.
    pub fn publish_schedule_stitched(
        &self,
        head: &[usize],
        head_bpg: usize,
        tail: &[usize],
        tail_bpg: usize,
        retired_groups: usize,
    ) {
        self.shared
            .publish_schedule_stitched(head, head_bpg, tail, tail_bpg, retired_groups);
    }

    /// Publish a stage's group schedule: `order` lists block ids in group
    /// processing order, `blocks_per_group` of them per group. Drives both
    /// Belady eviction ranks and the prefetch window.
    pub fn publish_schedule(&self, order: &[usize], blocks_per_group: usize) {
        self.shared.publish_schedule(order, blocks_per_group);
    }

    /// Advance the schedule cursor: one group's chain finished (store
    /// phase done). The prefetcher works `prefetch_depth` groups ahead of
    /// this point.
    pub fn group_completed(&self) {
        self.shared.group_completed();
    }

    /// Advance the *decode-phase* cursor: one group's blocks were all
    /// fetched (taken) for update. In an overlapped pipeline the decode
    /// phase runs ahead of group completion, and the prefetcher windows
    /// off the farther of the two cursors — so read-ahead starts pulling
    /// the next spilled blocks while earlier groups are still in flight.
    pub fn group_fetched(&self) {
        self.shared.group_fetched();
    }

    /// How many of `ids` would cost a synchronous disk read to fetch
    /// right now (spilled or absent). Primary-resident and
    /// write-back-queued blocks rank 0 — `take` serves them from RAM.
    /// Engines use this to run resident groups first within a stage
    /// (spill-aware scheduling).
    pub fn residency_rank(&self, ids: &[usize]) -> usize {
        self.shared.residency_rank(ids)
    }

    /// True when blocks can actually move between tiers (budget + spill
    /// file configured) — i.e. when residency ranks can differ at all.
    /// Lets engines skip the per-group residency query otherwise.
    pub fn may_spill(&self) -> bool {
        self.shared.budget.is_some() && self.shared.spill.is_some()
    }

    /// The prefetcher's current depth (adapts per stage under
    /// [`StoreOptions::auto_depth`], else the configured constant).
    pub fn current_prefetch_depth(&self) -> usize {
        self.shared.dyn_depth.load(Ordering::Relaxed)
    }

    /// Wait until the write-back queue drains; surfaces any background
    /// spill-writer failure.
    pub fn flush(&self) -> Result<()> {
        self.shared.flush()
    }

    /// Snapshot of the cumulative memory statistics.
    pub fn stats(&self) -> MemStats {
        self.shared.stats()
    }

    /// Precise peak of primary + write-back + secondary together (Fig. 9
    /// metric).
    pub fn peak_total_bytes(&self) -> usize {
        self.shared.peak_total.load(Ordering::Relaxed)
    }

    /// Spill-file tail in bytes (0 without a spill file) — diagnostics:
    /// bounds file growth under extent reuse.
    pub fn spill_tail_bytes(&self) -> u64 {
        self.shared.spill.as_ref().map_or(0, |s| s.tail())
    }

    /// Snapshot every live block for a checkpoint, in id order. Collects
    /// the full id set across shards, then reads each block through the
    /// hardened [`BlockStore::get`] path — which waits out in-flight
    /// evictions, checksum-verifies spilled frames (healing from the
    /// retention ring where possible), and never evicts other blocks.
    /// Callers must quiesce the engine first (drain the epoch window and
    /// [`BlockStore::flush`] the write-back queue) so the id set is
    /// stable and no payload is in flight.
    pub fn export_blocks(&self) -> Result<Vec<(usize, BlockPayload)>> {
        let mut ids = BTreeSet::new();
        for shard in &self.shared.shards {
            ids.extend(plock(shard).keys().copied());
        }
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            out.push((id, self.shared.get(id)?));
        }
        Ok(out)
    }

    /// Rebuild the store's contents from a checkpoint snapshot. Each
    /// payload goes through the normal [`BlockStore::put`] path so budget
    /// accounting, Belady eviction, and spilling behave exactly as they
    /// would have in the uninterrupted run.
    pub fn rehydrate(&self, blocks: Vec<(usize, BlockPayload)>) -> Result<()> {
        for (id, payload) in blocks {
            self.put(id, payload)?;
        }
        Ok(())
    }

    /// The active fault injector, if a [`FaultPlan`] was configured —
    /// checkpoint writers consult it at the manifest/frame op sites.
    pub(crate) fn injector(&self) -> Option<&FaultInjector> {
        self.shared.injector.as_deref()
    }
}

impl Drop for BlockStore {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wb_cv.notify_all();
        self.shared.sched_cv.notify_all();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prefetcher.take() {
            let _ = h.join();
        }
        // The spill file itself is removed by SpillFile::drop.
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn payload(n: usize, tag: u8) -> BlockPayload {
        BlockPayload { re: vec![tag; n], im: vec![tag.wrapping_add(1); n] }
    }

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("bmqsim-test-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sync_opts() -> StoreOptions {
        StoreOptions { async_spill: false, prefetch_depth: 0, ..StoreOptions::default() }
    }

    #[test]
    fn unbounded_put_take() {
        let s = BlockStore::unbounded();
        s.put(3, payload(100, 7)).unwrap();
        assert!(s.contains(3));
        let p = s.take(3).unwrap();
        assert_eq!(p.re, vec![7u8; 100]);
        assert!(!s.contains(3));
        assert!(s.take(3).is_err());
    }

    #[test]
    fn budget_accounting_and_peak() {
        let s = BlockStore::unbounded();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(50, 2)).unwrap();
        let st = s.stats();
        assert_eq!(st.primary_bytes, 300); // (100+100) + (50+50)
        s.take(0).unwrap();
        assert_eq!(s.stats().primary_bytes, 100);
        assert_eq!(s.stats().peak_primary_bytes, 300);
    }

    #[test]
    fn overwrite_releases_old_bytes() {
        let s = BlockStore::unbounded();
        s.put(0, payload(100, 1)).unwrap();
        s.put(0, payload(10, 2)).unwrap();
        assert_eq!(s.stats().primary_bytes, 20);
        assert_eq!(s.take(0).unwrap().re, vec![2u8; 10]);
    }

    #[test]
    fn evicts_resident_not_incoming_and_reads_back() {
        // Old behaviour spilled the hot incoming block; the eviction
        // policy instead keeps the incoming block resident and evicts a
        // prior one (no schedule -> all ranks equal, highest id wins ties
        // in the index but any resident victim is acceptable).
        let s = BlockStore::with_options(Some(250), Some(tmpdir()), sync_opts()).unwrap();
        s.put(0, payload(100, 1)).unwrap(); // 200 B primary
        s.put(1, payload(100, 2)).unwrap(); // would be 400 -> evict block 0
        let st = s.stats();
        assert_eq!(st.blocks_primary, 1);
        assert_eq!(st.blocks_secondary, 1);
        assert_eq!(st.spill_events, 1);
        assert_eq!(st.evictions, 1);
        assert!(st.secondary_fraction() > 0.49);
        assert!(st.primary_bytes <= 250);
        // The incoming block stayed in primary; the victim reads back
        // intact from the secondary tier.
        let p1 = s.take(1).unwrap();
        assert_eq!(p1.re, vec![2u8; 100]);
        assert_eq!(s.stats().fetch_from_secondary, 0, "block 1 must be a primary hit");
        let p0 = s.take(0).unwrap();
        assert_eq!(p0.re, vec![1u8; 100]);
        assert_eq!(p0.im, vec![2u8; 100]);
        assert_eq!(s.stats().fetch_from_secondary, 1);
    }

    #[test]
    fn recompressor_keeps_victim_resident() {
        // Budget fits one 200 B block; the second put must evict — but the
        // hook shrinks the victim 4x, so it stays primary and nothing
        // reaches the spill tier.
        let opts = StoreOptions {
            recompressor: Some(Recompressor(Arc::new(|_id, p: &BlockPayload| {
                Some(BlockPayload { re: p.re[..p.re.len() / 4].to_vec(), im: p.im[..p.im.len() / 4].to_vec() })
            }))),
            ..sync_opts()
        };
        let s = BlockStore::with_options(Some(300), Some(tmpdir()), opts).unwrap();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(50, 2)).unwrap();
        let st = s.stats();
        assert_eq!(st.recompressions, 1);
        assert_eq!(st.spill_events, 0, "recompression is not a spill");
        assert_eq!(st.evictions, 0);
        assert_eq!(st.blocks_primary, 2);
        assert!(st.primary_bytes <= 300, "budget holds: {}", st.primary_bytes);
        // The recompressed payload is what readers observe.
        assert_eq!(s.take(0).unwrap().re, vec![1u8; 25]);
        assert_eq!(s.take(1).unwrap().re, vec![2u8; 50]);
    }

    #[test]
    fn recompressor_decline_falls_back_to_spill() {
        // A hook that declines (None) or fails to shrink must leave the
        // classic spill path untouched.
        for grow in [false, true] {
            let opts = StoreOptions {
                recompressor: Some(Recompressor(Arc::new(move |_id, p: &BlockPayload| {
                    if grow {
                        Some(BlockPayload { re: p.re.clone(), im: p.im.clone() })
                    } else {
                        None
                    }
                }))),
                ..sync_opts()
            };
            let s = BlockStore::with_options(Some(250), Some(tmpdir()), opts).unwrap();
            s.put(0, payload(100, 1)).unwrap();
            s.put(1, payload(100, 2)).unwrap();
            let st = s.stats();
            assert_eq!(st.recompressions, 0, "grow={grow}");
            assert_eq!(st.spill_events, 1, "grow={grow}");
            assert_eq!(st.blocks_secondary, 1, "grow={grow}");
            // The spilled victim reads back byte-identical.
            assert_eq!(s.take(0).unwrap().re, vec![1u8; 100], "grow={grow}");
        }
    }

    #[test]
    fn belady_eviction_follows_published_schedule() {
        // Budget fits 3 of 4 equal blocks. Schedule order 0,1,2,3: the
        // farthest-next-use resident must be evicted at each overflow.
        let s = BlockStore::with_options(Some(620), Some(tmpdir()), sync_opts()).unwrap();
        s.publish_schedule(&[0, 1, 2, 3], 1);
        for id in 0..3 {
            s.put(id, payload(100, id as u8)).unwrap(); // 600 B primary
        }
        s.put(3, payload(100, 3)).unwrap(); // overflow: evict block 2 (farthest resident)
        let st = s.stats();
        assert_eq!(st.evictions, 1);
        // Blocks 0 and 1 (next uses) stayed resident: taking them must not
        // touch the disk.
        s.take(0).unwrap();
        s.take(1).unwrap();
        assert_eq!(s.stats().fetch_from_secondary, 0);
        // Block 2 was the victim.
        s.take(2).unwrap();
        assert_eq!(s.stats().fetch_from_secondary, 1);
    }

    #[test]
    fn done_blocks_are_preferred_victims() {
        // After take+put (a processed group), a block's next use is the
        // NEXT stage — it must be evicted before upcoming-schedule blocks.
        let s = BlockStore::with_options(Some(620), Some(tmpdir()), sync_opts()).unwrap();
        s.publish_schedule(&[0, 1, 2, 3], 1);
        for id in 0..3 {
            s.put(id, payload(100, id as u8)).unwrap();
        }
        // Process block 0: take marks it done; re-put keeps it resident.
        let p = s.take(0).unwrap();
        s.put(0, p).unwrap();
        // Overflow: block 0 (done) outranks blocks 1/2 (upcoming).
        s.put(3, payload(100, 3)).unwrap();
        s.take(1).unwrap();
        s.take(2).unwrap();
        assert_eq!(s.stats().fetch_from_secondary, 0, "upcoming blocks were evicted");
        s.take(0).unwrap();
        assert_eq!(s.stats().fetch_from_secondary, 1, "done block was not the victim");
    }

    #[test]
    fn no_spill_dir_means_oom() {
        let s = BlockStore::new(Some(100), None).unwrap();
        assert!(s.put(0, payload(100, 1)).is_err());
    }

    #[test]
    fn spill_extent_reuse_bounds_file_growth() {
        let s = BlockStore::with_options(Some(10), Some(tmpdir()), sync_opts()).unwrap();
        for round in 0..5 {
            for id in 0..4 {
                s.put(id, payload(64, (round * 4 + id) as u8)).unwrap();
            }
            for id in 0..4 {
                let p = s.take(id).unwrap();
                assert_eq!(p.re[0], (round * 4 + id) as u8);
            }
        }
        // All extents freed and reused: spill file shouldn't have grown 5x.
        let tail = s.spill_tail_bytes();
        assert!(tail <= 4 * (64 * 2 + SECONDARY_FRAME_BYTES) as u64 * 2, "tail {tail}");
    }

    #[test]
    fn get_does_not_remove() {
        let s = BlockStore::unbounded();
        s.put(5, payload(8, 9)).unwrap();
        let a = s.get(5).unwrap();
        let b = s.get(5).unwrap();
        assert_eq!(a.re, b.re);
        assert!(s.contains(5));
    }

    #[test]
    fn get_promotes_spilled_block_when_budget_allows() {
        let s = BlockStore::with_options(Some(450), Some(tmpdir()), sync_opts()).unwrap();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(100, 2)).unwrap();
        s.put(2, payload(100, 3)).unwrap(); // evicts one of 0/1 to disk
        assert_eq!(s.stats().blocks_secondary, 1);
        let spilled = if s.stats().fetch_from_secondary == 0 {
            // Find the spilled id without disturbing counters: whichever
            // take below reports a secondary fetch. Instead free room
            // first, then exercise get().
            let st = s.stats();
            assert_eq!(st.blocks_primary, 2);
            // Determine victim: with no schedule both candidates tie on
            // rank and the index picks the max id among {0, 1} -> 1.
            1usize
        } else {
            unreachable!()
        };
        // Make room, then get() must promote (disk read once, then RAM).
        s.take(2).unwrap();
        let a = s.get(spilled).unwrap();
        assert_eq!(a.re, vec![2u8; 100]);
        let st = s.stats();
        assert_eq!(st.fetch_from_secondary, 1);
        assert_eq!(st.blocks_secondary, 0, "get() did not promote");
        let b = s.get(spilled).unwrap();
        assert_eq!(b.re, a.re);
        assert_eq!(s.stats().fetch_from_secondary, 1, "second get() re-read the file");
    }

    #[test]
    fn async_interception_returns_correct_bytes() {
        // Queue evictions behind the background writer and immediately
        // take them back: whether intercepted in the queue or read from
        // disk, bytes must round-trip.
        let opts = StoreOptions { async_spill: true, prefetch_depth: 0, ..Default::default() };
        let s = BlockStore::with_options(Some(300), Some(tmpdir()), opts).unwrap();
        for round in 0..50usize {
            for id in 0..4usize {
                let tag = (round * 4 + id % 251) as u8;
                s.put(id, payload(60, tag)).unwrap();
            }
            for id in (0..4usize).rev() {
                let p = s.take(id).unwrap();
                assert_eq!(p.re[0], ((round * 4 + id % 251) as u8), "round {round} id {id}");
                assert_eq!(p.re.len(), 60);
            }
        }
        s.flush().unwrap();
        let st = s.stats();
        assert_eq!(st.blocks_primary + st.blocks_secondary + st.blocks_write_back, 0);
        assert_eq!(st.primary_bytes, 0);
        assert_eq!(st.secondary_bytes, 0);
        assert_eq!(st.write_back_bytes, 0);
    }

    #[test]
    fn sync_and_async_agree_on_contents() {
        let run = |async_spill: bool| -> Vec<BlockPayload> {
            let opts = StoreOptions { async_spill, prefetch_depth: 0, ..Default::default() };
            let s = BlockStore::with_options(Some(500), Some(tmpdir()), opts).unwrap();
            for id in 0..8 {
                s.put(id, payload(50 + id, (id * 3) as u8)).unwrap();
            }
            s.flush().unwrap();
            (0..8).map(|id| s.get(id).unwrap()).collect()
        };
        let sync = run(false);
        let async_ = run(true);
        for (a, b) in sync.iter().zip(&async_) {
            assert_eq!(a.re, b.re);
            assert_eq!(a.im, b.im);
        }
    }

    #[test]
    fn two_stores_in_one_process_use_distinct_spill_files() {
        // The old naming scheme derived uniqueness from a stack address,
        // which can collide across stores and clobber a live spill file.
        let dir = tmpdir();
        let a = BlockStore::with_options(Some(10), Some(dir.clone()), sync_opts()).unwrap();
        let b = BlockStore::with_options(Some(10), Some(dir), sync_opts()).unwrap();
        for id in 0..6 {
            a.put(id, payload(40, 0xA0 | id as u8)).unwrap();
            b.put(id, payload(40, 0xB0 | id as u8)).unwrap();
        }
        for id in 0..6 {
            assert_eq!(a.take(id).unwrap().re[0], 0xA0 | id as u8);
            assert_eq!(b.take(id).unwrap().re[0], 0xB0 | id as u8);
        }
    }

    #[test]
    fn prefetcher_stages_scheduled_blocks_and_counts_hits() {
        let opts = StoreOptions {
            async_spill: true,
            prefetch_depth: 4,
            shards: 4,
            ..Default::default()
        };
        let s = BlockStore::with_options(Some(450), Some(tmpdir()), opts).unwrap();
        for id in 0..6 {
            s.put(id, payload(100, id as u8)).unwrap();
        }
        s.flush().unwrap();
        assert!(s.stats().blocks_secondary >= 4);
        // Publish the schedule; the prefetcher should stage upcoming
        // blocks into primary as room allows.
        s.publish_schedule(&[0, 1, 2, 3, 4, 5], 1);
        for id in 0..6usize {
            // Give the prefetcher a window to win the race, then take.
            let deadline = Instant::now() + Duration::from_millis(200);
            while !matches!(
                s.shared.shard(id).lock().unwrap().get(&id),
                Some(Slot::Primary { .. })
            ) && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_micros(200));
            }
            let p = s.take(id).unwrap();
            assert_eq!(p.re, vec![id as u8; 100]);
            s.group_completed();
        }
        let st = s.stats();
        assert!(
            st.prefetch_hits > 0,
            "prefetcher staged nothing (hits {} misses {})",
            st.prefetch_hits,
            st.prefetch_misses
        );
    }

    #[test]
    fn residency_rank_counts_only_disk_fetches() {
        let s = BlockStore::with_options(Some(450), Some(tmpdir()), sync_opts()).unwrap();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(100, 2)).unwrap();
        s.put(2, payload(100, 3)).unwrap(); // overflow: evicts block 1 (id tie-break)
        assert!(s.may_spill());
        assert_eq!(s.stats().blocks_secondary, 1);
        assert_eq!(s.residency_rank(&[0, 2]), 0, "resident blocks rank 0");
        assert_eq!(s.residency_rank(&[1]), 1, "spilled block costs a read");
        assert_eq!(s.residency_rank(&[0, 1, 2]), 1);
        assert_eq!(s.residency_rank(&[99]), 1, "absent counts as a miss");
        assert_eq!(s.residency_rank(&[]), 0);
        let un = BlockStore::unbounded();
        un.put(0, payload(10, 1)).unwrap();
        assert!(!un.may_spill());
        assert_eq!(un.residency_rank(&[0]), 0);
    }

    #[test]
    fn fetch_cursor_advances_and_resets_with_schedule() {
        let s = BlockStore::unbounded();
        s.publish_schedule(&[0, 1, 2, 3], 1);
        assert_eq!(s.shared.fetch_cursor.load(Ordering::Relaxed), 0);
        s.group_fetched();
        s.group_fetched();
        assert_eq!(s.shared.fetch_cursor.load(Ordering::Relaxed), 2);
        // Completion lags decode; the prefetch window keys off the max.
        assert_eq!(s.shared.progress.load(Ordering::Relaxed), 0);
        s.publish_schedule(&[4, 5], 1);
        assert_eq!(s.shared.fetch_cursor.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stitched_publish_rebases_cursors_instead_of_resetting() {
        let s = BlockStore::unbounded();
        // Stage A: 4 single-block groups; 3 already completed when stage B
        // arrives (one tail group still encoding).
        s.publish_schedule(&[0, 1, 2, 3], 1);
        for _ in 0..3 {
            s.group_fetched();
            s.group_completed();
        }
        // First boundary: nothing retired yet (rebase 0). Stage B has
        // 2 groups of 2 blocks — a different geometry than the head.
        s.publish_schedule_stitched(&[0, 1, 2, 3], 1, &[4, 5, 6, 7], 2, 0);
        assert_eq!(s.shared.progress.load(Ordering::Relaxed), 3, "cursor was reset");
        assert_eq!(s.shared.fetch_cursor.load(Ordering::Relaxed), 3);
        // Stage A's tail completes, then stage B runs its 2 groups.
        s.group_fetched();
        s.group_completed();
        for _ in 0..2 {
            s.group_fetched();
            s.group_completed();
        }
        assert_eq!(s.shared.progress.load(Ordering::Relaxed), 6);
        // Second boundary: stage A (4 groups) has left the window.
        s.publish_schedule_stitched(&[4, 5, 6, 7], 2, &[0, 1, 2, 3], 1, 4);
        assert_eq!(s.shared.progress.load(Ordering::Relaxed), 2, "rebase must subtract");
        assert_eq!(s.shared.fetch_cursor.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stitched_belady_ranks_span_the_stage_boundary() {
        // Budget fits 3 of 4 equal blocks. Under a per-stage reset, block
        // 1 (unused by the rest of stage A) would be ranked NO_USE and
        // evicted first; the stitched schedule knows stage B reuses it
        // FIRST, so the true farthest-next-use block (3) must be the
        // victim instead.
        let s = BlockStore::with_options(Some(620), Some(tmpdir()), sync_opts()).unwrap();
        s.publish_schedule(&[0, 1, 2, 3], 1);
        // Stage A processed groups 0 and 1 already (cursor = 2), its tail
        // (groups 2, 3) still pending; stage B will run 1, 0, 2, 3.
        s.group_completed();
        s.group_completed();
        s.publish_schedule_stitched(&[0, 1, 2, 3], 1, &[1, 0, 2, 3], 1, 0);
        for id in 0..3 {
            s.put(id, payload(100, id as u8)).unwrap(); // 600 B primary
        }
        // Overflow. Next uses under the stitched ranks: 2 -> group 2
        // (stage A tail), 3 -> group 3, 0 -> group 5 (stage B), 1 ->
        // group 4. Block 0 is farthest -> the victim.
        s.put(3, payload(100, 3)).unwrap();
        assert_eq!(s.stats().evictions, 1);
        s.take(2).unwrap();
        s.take(3).unwrap();
        s.take(1).unwrap();
        assert_eq!(
            s.stats().fetch_from_secondary,
            0,
            "a block the stitched window still needs was evicted"
        );
        s.take(0).unwrap();
        assert_eq!(s.stats().fetch_from_secondary, 1, "block 0 was not the victim");
    }

    #[test]
    fn auto_depth_aimd_steps_per_stage() {
        // No budget/spill: no background threads, so counter injection is
        // race-free; the AIMD step still runs on every publish.
        let opts = StoreOptions { auto_depth: true, prefetch_depth: 4, ..Default::default() };
        let s = BlockStore::with_options(None, None, opts).unwrap();
        assert_eq!(s.current_prefetch_depth(), 4);
        // First publish only primes the baseline — no history, no step.
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 4);
        // Idle stage (no secondary traffic): multiplicative decrease.
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 2);
        // Misses since last stage: additive increase.
        s.shared.prefetch_misses.fetch_add(3, Ordering::Relaxed);
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 3);
        // Stall growth alone (in-flight waits / back-pressure) also counts
        // as pressure.
        s.shared.spill_stall_ns.fetch_add(1_000_000, Ordering::Relaxed);
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 4);
        // All hits, no misses, no stall: the depth is right — hold.
        s.shared.prefetch_hits.fetch_add(5, Ordering::Relaxed);
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 4);
        // Idle again: decay toward the floor of 1, never 0.
        s.publish_schedule(&[0], 1);
        s.publish_schedule(&[0], 1);
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 1);
        // Sustained misses cap at AUTO_DEPTH_MAX.
        for _ in 0..40 {
            s.shared.prefetch_misses.fetch_add(1, Ordering::Relaxed);
            s.publish_schedule(&[0], 1);
        }
        assert_eq!(s.current_prefetch_depth(), AUTO_DEPTH_MAX);
        // MemStats reports the live depth.
        assert_eq!(s.stats().prefetch_depth, AUTO_DEPTH_MAX);
    }

    #[test]
    fn fixed_depth_never_adapts() {
        let s = BlockStore::with_options(None, None, StoreOptions::default()).unwrap();
        assert_eq!(s.current_prefetch_depth(), 4);
        s.shared.prefetch_misses.fetch_add(10, Ordering::Relaxed);
        s.publish_schedule(&[0], 1);
        s.publish_schedule(&[0], 1);
        assert_eq!(s.current_prefetch_depth(), 4);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let s = std::sync::Arc::new(BlockStore::new(Some(3000), Some(tmpdir())).unwrap());
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let s = s.clone();
                scope.spawn(move || {
                    for i in 0..50usize {
                        let id = t * 100 + i;
                        s.put(id, payload(40, (id % 251) as u8)).unwrap();
                        let p = s.take(id).unwrap();
                        assert_eq!(p.re[0], (id % 251) as u8);
                        s.put(id, p).unwrap();
                    }
                });
            }
        });
        s.flush().unwrap();
        let st = s.stats();
        assert_eq!(st.blocks_primary + st.blocks_secondary + st.blocks_write_back, 400);
        assert!(st.peak_primary_bytes <= 3000);
    }

    #[test]
    fn payload_framing_roundtrip() {
        let p = payload(33, 5);
        let bytes = p.to_bytes();
        let q = BlockPayload::from_bytes(&bytes).unwrap();
        assert_eq!(p.re, q.re);
        assert_eq!(p.im, q.im);
        assert!(BlockPayload::from_bytes(&bytes[..10]).is_err());
    }

    // ---- Fault injection & recovery ----

    fn faulted_sync_opts(spec: &str) -> StoreOptions {
        StoreOptions { fault_plan: Some(FaultPlan::parse(spec).unwrap()), ..sync_opts() }
    }

    #[test]
    fn transient_eviction_write_eio_is_retried_transparently() {
        let s = BlockStore::with_options(
            Some(250),
            Some(tmpdir()),
            faulted_sync_opts("eio@write:1"),
        )
        .unwrap();
        s.put(0, payload(100, 3)).unwrap();
        // Eviction write attempt 1 fails with EIO; the retry lands and the
        // caller never notices.
        s.put(1, payload(100, 4)).unwrap();
        assert_eq!(s.take(0).unwrap().re, vec![3u8; 100]);
        assert_eq!(s.take(1).unwrap().re, vec![4u8; 100]);
        let st = s.stats();
        assert!(st.io_retries >= 1, "retry counter not bumped: {st:?}");
        assert_eq!(st.checksum_failures, 0);
    }

    #[test]
    fn sticky_corruption_heals_from_retention_ring() {
        // The extent is persistently corrupt: re-reads never verify, so
        // take() must fall back to the write-back retention ring and
        // return byte-identical data.
        let s = BlockStore::with_options(
            Some(250),
            Some(tmpdir()),
            faulted_sync_opts("stickyflip@read:1"),
        )
        .unwrap();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(100, 2)).unwrap(); // evicts block 0 to disk
        assert_eq!(s.stats().blocks_secondary, 1);
        let p = s.take(0).unwrap();
        assert_eq!(p.re, vec![1u8; 100]);
        assert_eq!(p.im, vec![2u8; 100]);
        let st = s.stats();
        assert_eq!(st.frames_recovered, 1, "ring recovery not used: {st:?}");
        assert!(st.checksum_failures >= 1);
    }

    #[test]
    fn enospc_retargets_the_fallback_stripe() {
        let fb = tmpdir().join("fallback-stripe");
        let opts = StoreOptions {
            fallback_dir: Some(fb),
            ..faulted_sync_opts("enospc_after=0")
        };
        let s = BlockStore::with_options(Some(250), Some(tmpdir()), opts).unwrap();
        s.put(0, payload(100, 5)).unwrap();
        s.put(1, payload(100, 6)).unwrap(); // primary stripe full -> fallback
        let st = s.stats();
        assert!(st.enospc_fallbacks >= 1, "ladder never engaged: {st:?}");
        assert_eq!(st.blocks_secondary, 1);
        assert_eq!(s.take(0).unwrap().re, vec![5u8; 100]);
        assert_eq!(s.take(1).unwrap().re, vec![6u8; 100]);
    }

    #[test]
    fn enospc_without_fallback_renegotiates_the_budget() {
        // Every spill device is full and there is no fallback: the ladder's
        // bottom rung halts eviction and grows the primary budget instead
        // of erroring — the run completes with a larger RAM footprint.
        let s = BlockStore::with_options(
            Some(250),
            Some(tmpdir()),
            faulted_sync_opts("enospc_after=0"),
        )
        .unwrap();
        s.put(0, payload(100, 7)).unwrap();
        s.put(1, payload(100, 8)).unwrap(); // eviction hits ENOSPC: reinstate + bump
        let st = s.stats();
        assert!(st.enospc_fallbacks >= 1);
        assert_eq!(st.blocks_secondary, 0, "nothing could reach a disk");
        assert_eq!(st.blocks_primary, 2, "stranded payload reinstated over budget");
        assert_eq!(s.take(0).unwrap().re, vec![7u8; 100]);
        assert_eq!(s.take(1).unwrap().re, vec![8u8; 100]);
        s.put(2, payload(100, 9)).unwrap();
        assert_eq!(s.take(2).unwrap().re, vec![9u8; 100]);
    }

    #[test]
    fn writer_death_drains_inline_and_loses_nothing() {
        let opts = StoreOptions {
            async_spill: true,
            prefetch_depth: 0,
            fault_plan: Some(FaultPlan::parse("writer_death_after=1").unwrap()),
            ..StoreOptions::default()
        };
        let s = BlockStore::with_options(Some(250), Some(tmpdir()), opts).unwrap();
        for id in 0..6usize {
            s.put(id, payload(100, id as u8)).unwrap();
        }
        // The writer died on its first claimed job (requeueing it); flush
        // must self-heal by draining the queue on this thread.
        s.flush().unwrap();
        assert!(!s.shared.writer_alive.load(Ordering::Relaxed));
        for id in 0..6usize {
            assert_eq!(s.take(id).unwrap().re, vec![id as u8; 100], "block {id}");
        }
    }

    #[test]
    fn poisoned_locks_never_wedge_the_store() {
        let s = BlockStore::with_options(Some(250), Some(tmpdir()), sync_opts()).unwrap();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(100, 2)).unwrap(); // spills block 0
        // Panic while holding the write-back and policy locks, the way a
        // crashing pipeline worker would.
        let _ = std::thread::scope(|sc| {
            sc.spawn(|| {
                let _wb = s.shared.wb.lock();
                let _po = s.shared.policy.lock();
                panic!("injected worker panic");
            })
            .join()
        });
        // Poison the spill file's extent allocator too.
        s.shared.spill.as_ref().unwrap().poison_alloc_for_test();
        // Every store op still works across all the poisoned mutexes:
        // put (evicts through the wb lock), flush, take (disk read +
        // extent free under the allocator lock).
        s.put(2, payload(50, 3)).unwrap();
        s.flush().unwrap();
        assert_eq!(s.take(0).unwrap().re, vec![1u8; 100]);
        assert_eq!(s.take(1).unwrap().re, vec![2u8; 100]);
        assert_eq!(s.take(2).unwrap().re, vec![3u8; 50]);
    }
}
