//! Two-level memory management (paper §4.4).
//!
//! Compressed SV blocks have *unpredictable* sizes (Challenge ④): the
//! compression ratio depends on state content, so a fixed primary budget
//! can overflow mid-simulation. [`BlockStore`] keeps compressed blocks in a
//! budgeted primary tier (host RAM here; the paper's CPU DRAM) and, when an
//! incoming block would exceed the budget, writes it straight to a
//! secondary tier file (the GPUDirect-Storage/SSD analogue: the block
//! bypasses the primary tier entirely, like GDS bypasses the CPU bounce
//! buffer). Blocks are re-promoted on fetch when the budget allows.
//!
//! The store also keeps the statistics behind Fig. 9 (peak footprint) and
//! §5.4's spill-fraction numbers.

use crate::types::{Error, Result};
use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One compressed block's payload: both planes, length-framed.
///
/// Payloads are *recycled* on the pipeline hot path: the byte buffers a
/// worker receives from [`BlockStore::take`] are reused as
/// `compress_into` outputs for the updated planes and handed straight
/// back to [`BlockStore::put`], so in steady state block bytes cycle
/// store → worker → store without fresh allocations (§Perf, DESIGN.md).
#[derive(Debug, Clone, Default)]
pub struct BlockPayload {
    pub re: Vec<u8>,
    pub im: Vec<u8>,
}

impl BlockPayload {
    pub fn len(&self) -> usize {
        self.re.len() + self.im.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty() && self.im.is_empty()
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() + 16);
        out.extend_from_slice(&(self.re.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.im.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.re);
        out.extend_from_slice(&self.im);
        out
    }

    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 16 {
            return Err(Error::Codec("block payload truncated".into()));
        }
        let re_len = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        let im_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        if bytes.len() != 16 + re_len + im_len {
            return Err(Error::Codec("block payload length mismatch".into()));
        }
        Ok(BlockPayload {
            re: bytes[16..16 + re_len].to_vec(),
            im: bytes[16 + re_len..].to_vec(),
        })
    }
}

#[derive(Debug)]
enum Slot {
    Primary(BlockPayload),
    /// Offset + length into the spill file.
    Spilled { offset: u64, len: usize },
}

/// Cumulative statistics, readable at any time.
#[derive(Debug, Default, Clone)]
pub struct MemStats {
    pub primary_bytes: usize,
    pub peak_primary_bytes: usize,
    pub secondary_bytes: usize,
    pub peak_secondary_bytes: usize,
    pub spill_events: u64,
    pub fetch_from_secondary: u64,
    pub blocks_primary: usize,
    pub blocks_secondary: usize,
}

impl MemStats {
    /// Peak total compressed footprint (Fig. 9's "practical memory").
    pub fn peak_total(&self) -> usize {
        // peaks may not coincide, so this is an upper bound; tracked
        // precisely by peak_total_bytes in the store.
        self.peak_primary_bytes + self.peak_secondary_bytes
    }

    /// Fraction of resident blocks currently in the secondary tier (§5.4).
    pub fn secondary_fraction(&self) -> f64 {
        let total = self.blocks_primary + self.blocks_secondary;
        if total == 0 {
            0.0
        } else {
            self.blocks_secondary as f64 / total as f64
        }
    }
}

struct Inner {
    slots: HashMap<usize, Slot>,
    primary_bytes: usize,
    peak_primary: usize,
    secondary_bytes: usize,
    peak_secondary: usize,
    peak_total: usize,
    blocks_secondary: usize,
    spill_file: Option<std::fs::File>,
    spill_tail: u64,
    /// Reusable holes in the spill file (freed block extents).
    spill_free: Vec<(u64, usize)>,
}

/// Thread-safe two-level block store.
pub struct BlockStore {
    /// Primary tier budget in bytes; `None` = unlimited (no spilling).
    budget: Option<usize>,
    spill_path: Option<PathBuf>,
    inner: Mutex<Inner>,
    spill_events: AtomicU64,
    fetch_secondary: AtomicU64,
}

impl BlockStore {
    /// `budget = None` disables the secondary tier entirely; putting beyond
    /// the budget then returns [`Error::OutOfMemory`].
    pub fn new(budget: Option<usize>, spill_dir: Option<PathBuf>) -> Result<Self> {
        let spill_path = match (&budget, spill_dir) {
            (Some(_), Some(dir)) => {
                std::fs::create_dir_all(&dir)?;
                let unique = format!(
                    "bmqsim-spill-{}-{:x}.bin",
                    std::process::id(),
                    &dir as *const _ as usize
                );
                Some(dir.join(unique))
            }
            _ => None,
        };
        Ok(BlockStore {
            budget,
            spill_path,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                primary_bytes: 0,
                peak_primary: 0,
                secondary_bytes: 0,
                peak_secondary: 0,
                peak_total: 0,
                blocks_secondary: 0,
                spill_file: None,
                spill_tail: 0,
                spill_free: Vec::new(),
            }),
            spill_events: AtomicU64::new(0),
            fetch_secondary: AtomicU64::new(0),
        })
    }

    /// Unbounded in-RAM store (the common case when memory suffices).
    pub fn unbounded() -> Self {
        Self::new(None, None).expect("unbounded store cannot fail")
    }

    /// Insert/overwrite block `id`. Spills to the secondary tier when the
    /// primary budget would be exceeded (paper: "directly save this chunk
    /// to the storage via GDS").
    pub fn put(&self, id: usize, payload: BlockPayload) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        // Drop any previous version of this block first.
        Self::remove_locked(&mut g, id);
        let len = payload.len();
        let fits = match self.budget {
            Some(b) => g.primary_bytes + len <= b,
            None => true,
        };
        if fits {
            g.primary_bytes += len;
            g.peak_primary = g.peak_primary.max(g.primary_bytes);
            g.slots.insert(id, Slot::Primary(payload));
        } else {
            if self.spill_path.is_none() {
                return Err(Error::OutOfMemory(format!(
                    "block {id} ({len} B) exceeds primary budget {:?} and no spill dir configured",
                    self.budget
                )));
            }
            let bytes = payload.to_bytes();
            let (offset, stored) = Self::spill_write_locked(&mut g, self.spill_path.as_ref().unwrap(), &bytes)?;
            g.secondary_bytes += stored;
            g.peak_secondary = g.peak_secondary.max(g.secondary_bytes);
            g.blocks_secondary += 1;
            g.slots.insert(id, Slot::Spilled { offset, len: stored });
            self.spill_events.fetch_add(1, Ordering::Relaxed);
        }
        g.peak_total = g.peak_total.max(g.primary_bytes + g.secondary_bytes);
        Ok(())
    }

    /// Remove and return block `id` (the engines' fetch-for-update path —
    /// the block's budget is released while it's being worked on).
    pub fn take(&self, id: usize) -> Result<BlockPayload> {
        let mut g = self.inner.lock().unwrap();
        let slot = g
            .slots
            .remove(&id)
            .ok_or_else(|| Error::OutOfMemory(format!("block {id} not resident")))?;
        match slot {
            Slot::Primary(p) => {
                g.primary_bytes -= p.len();
                Ok(p)
            }
            Slot::Spilled { offset, len } => {
                g.secondary_bytes -= len;
                g.blocks_secondary -= 1;
                g.spill_free.push((offset, len));
                self.fetch_secondary.fetch_add(1, Ordering::Relaxed);
                let bytes = Self::spill_read_locked(&mut g, offset, len)?;
                BlockPayload::from_bytes(&bytes)
            }
        }
    }

    /// Read a block without removing it (terminal state materialization).
    pub fn get(&self, id: usize) -> Result<BlockPayload> {
        let mut g = self.inner.lock().unwrap();
        match g.slots.get(&id) {
            Some(Slot::Primary(p)) => Ok(p.clone()),
            Some(&Slot::Spilled { offset, len }) => {
                self.fetch_secondary.fetch_add(1, Ordering::Relaxed);
                let bytes = Self::spill_read_locked(&mut g, offset, len)?;
                BlockPayload::from_bytes(&bytes)
            }
            None => Err(Error::OutOfMemory(format!("block {id} not resident"))),
        }
    }

    pub fn contains(&self, id: usize) -> bool {
        self.inner.lock().unwrap().slots.contains_key(&id)
    }

    pub fn stats(&self) -> MemStats {
        let g = self.inner.lock().unwrap();
        MemStats {
            primary_bytes: g.primary_bytes,
            peak_primary_bytes: g.peak_primary,
            secondary_bytes: g.secondary_bytes,
            peak_secondary_bytes: g.peak_secondary,
            spill_events: self.spill_events.load(Ordering::Relaxed),
            fetch_from_secondary: self.fetch_secondary.load(Ordering::Relaxed),
            blocks_primary: g.slots.len() - g.blocks_secondary,
            blocks_secondary: g.blocks_secondary,
        }
    }

    /// Precise peak of primary+secondary together (Fig. 9 metric).
    pub fn peak_total_bytes(&self) -> usize {
        self.inner.lock().unwrap().peak_total
    }

    fn remove_locked(g: &mut Inner, id: usize) {
        if let Some(old) = g.slots.remove(&id) {
            match old {
                Slot::Primary(p) => g.primary_bytes -= p.len(),
                Slot::Spilled { offset, len } => {
                    g.secondary_bytes -= len;
                    g.blocks_secondary -= 1;
                    g.spill_free.push((offset, len));
                }
            }
        }
    }

    fn spill_write_locked(g: &mut Inner, path: &PathBuf, bytes: &[u8]) -> Result<(u64, usize)> {
        if g.spill_file.is_none() {
            g.spill_file = Some(
                std::fs::OpenOptions::new()
                    .create(true)
                    .read(true)
                    .write(true)
                    .truncate(true)
                    .open(path)?,
            );
        }
        // First-fit reuse of freed extents to bound spill-file growth.
        let mut offset = None;
        for i in 0..g.spill_free.len() {
            if g.spill_free[i].1 >= bytes.len() {
                let (off, cap) = g.spill_free.swap_remove(i);
                if cap > bytes.len() {
                    g.spill_free.push((off + bytes.len() as u64, cap - bytes.len()));
                }
                offset = Some(off);
                break;
            }
        }
        let offset = offset.unwrap_or_else(|| {
            let o = g.spill_tail;
            g.spill_tail += bytes.len() as u64;
            o
        });
        let f = g.spill_file.as_mut().unwrap();
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(bytes)?;
        Ok((offset, bytes.len()))
    }

    fn spill_read_locked(g: &mut Inner, offset: u64, len: usize) -> Result<Vec<u8>> {
        let f = g
            .spill_file
            .as_mut()
            .ok_or_else(|| Error::OutOfMemory("spill file missing".into()))?;
        f.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }
}

impl Drop for BlockStore {
    fn drop(&mut self) {
        if let Some(p) = &self.spill_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize, tag: u8) -> BlockPayload {
        BlockPayload { re: vec![tag; n], im: vec![tag.wrapping_add(1); n] }
    }

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("bmqsim-test-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn unbounded_put_take() {
        let s = BlockStore::unbounded();
        s.put(3, payload(100, 7)).unwrap();
        assert!(s.contains(3));
        let p = s.take(3).unwrap();
        assert_eq!(p.re, vec![7u8; 100]);
        assert!(!s.contains(3));
        assert!(s.take(3).is_err());
    }

    #[test]
    fn budget_accounting_and_peak() {
        let s = BlockStore::unbounded();
        s.put(0, payload(100, 1)).unwrap();
        s.put(1, payload(50, 2)).unwrap();
        let st = s.stats();
        assert_eq!(st.primary_bytes, 300); // (100+100) + (50+50)
        s.take(0).unwrap();
        assert_eq!(s.stats().primary_bytes, 100);
        assert_eq!(s.stats().peak_primary_bytes, 300);
    }

    #[test]
    fn overwrite_releases_old_bytes() {
        let s = BlockStore::unbounded();
        s.put(0, payload(100, 1)).unwrap();
        s.put(0, payload(10, 2)).unwrap();
        assert_eq!(s.stats().primary_bytes, 20);
        assert_eq!(s.take(0).unwrap().re, vec![2u8; 10]);
    }

    #[test]
    fn spills_when_over_budget_and_reads_back() {
        let s = BlockStore::new(Some(250), Some(tmpdir())).unwrap();
        s.put(0, payload(100, 1)).unwrap(); // 200 B primary
        s.put(1, payload(100, 2)).unwrap(); // would be 400 -> spill
        let st = s.stats();
        assert_eq!(st.blocks_primary, 1);
        assert_eq!(st.blocks_secondary, 1);
        assert_eq!(st.spill_events, 1);
        assert!(st.secondary_fraction() > 0.49);
        // Read back from the secondary tier, content intact.
        let p = s.take(1).unwrap();
        assert_eq!(p.re, vec![2u8; 100]);
        assert_eq!(p.im, vec![3u8; 100]);
        assert_eq!(s.stats().fetch_from_secondary, 1);
    }

    #[test]
    fn no_spill_dir_means_oom() {
        let s = BlockStore::new(Some(100), None).unwrap();
        assert!(s.put(0, payload(100, 1)).is_err());
    }

    #[test]
    fn spill_extent_reuse() {
        let s = BlockStore::new(Some(10), Some(tmpdir())).unwrap();
        for round in 0..5 {
            for id in 0..4 {
                s.put(id, payload(64, (round * 4 + id) as u8)).unwrap();
            }
            for id in 0..4 {
                let p = s.take(id).unwrap();
                assert_eq!(p.re[0], (round * 4 + id) as u8);
            }
        }
        // All extents freed and reused: spill file shouldn't have grown 5x.
        let g = s.inner.lock().unwrap();
        assert!(g.spill_tail <= 4 * (64 * 2 + 16) as u64 * 2, "tail {}", g.spill_tail);
    }

    #[test]
    fn get_does_not_remove() {
        let s = BlockStore::unbounded();
        s.put(5, payload(8, 9)).unwrap();
        let a = s.get(5).unwrap();
        let b = s.get(5).unwrap();
        assert_eq!(a.re, b.re);
        assert!(s.contains(5));
    }

    #[test]
    fn concurrent_access_is_safe() {
        let s = std::sync::Arc::new(BlockStore::new(Some(3000), Some(tmpdir())).unwrap());
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let s = s.clone();
                scope.spawn(move || {
                    for i in 0..50usize {
                        let id = t * 100 + i;
                        s.put(id, payload(40, (id % 251) as u8)).unwrap();
                        let p = s.take(id).unwrap();
                        assert_eq!(p.re[0], (id % 251) as u8);
                        s.put(id, p).unwrap();
                    }
                });
            }
        });
        let st = s.stats();
        assert_eq!(st.blocks_primary + st.blocks_secondary, 400);
    }

    #[test]
    fn payload_framing_roundtrip() {
        let p = payload(33, 5);
        let bytes = p.to_bytes();
        let q = BlockPayload::from_bytes(&bytes).unwrap();
        assert_eq!(p.re, q.re);
        assert_eq!(p.im, q.im);
        assert!(BlockPayload::from_bytes(&bytes[..10]).is_err());
    }
}
