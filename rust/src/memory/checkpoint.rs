//! Crash-consistent checkpoint/restore for the block store
//! (DESIGN.md "Checkpoint & resume").
//!
//! A checkpoint is one directory `ckpt-<cursor>` under the configured
//! checkpoint root, holding exactly two files:
//!
//! * `blocks.bin` — every live block's serialized payload wrapped in the
//!   same checksummed `[BQSF]` frame format the spill tier uses
//!   ([`super::spill::frame_encode`]), concatenated in block-id order.
//! * `MANIFEST.bqm` — a one-line integrity header (`BMQCKPT <xxh64>`)
//!   followed by a schema-versioned JSON body: stage cursor, config
//!   fingerprint, carried metric counters, and a block table with one
//!   `[id, offset, len, xxh64]` row per frame.
//!
//! **Atomicity argument.** The manifest is the *commit record*: a
//! checkpoint exists iff `MANIFEST.bqm` is present and verifies. The
//! writer orders `blocks.bin` write → fsync → manifest written to a temp
//! name → fsync → `rename` → directory fsync, so a kill at any instant
//! leaves either no manifest (the directory is invisible to resume — the
//! previous checkpoint is still the newest valid one) or a fully
//! consistent manifest whose referenced frames were already durable
//! before the rename. POSIX `rename` within one directory is atomic;
//! there is no window in which a torn manifest can be observed under its
//! final name. Every corruption mode below the rename (truncated or
//! bit-flipped manifest body, damaged frame bytes, a resized blocks
//! file) is caught by the header checksum, the per-frame checksums, or
//! the manifest block table, and surfaces as a typed
//! [`Error::Checkpoint`] / [`Error::Corruption`] — resume then falls
//! back to the next-older retained checkpoint instead of panicking or
//! silently continuing from damaged state.
//!
//! Fault hooks: when the store carries a [`FaultInjector`], every frame
//! write consults the `checkpoint` op site and the manifest temp-write
//! and rename consult the `manifest` op site (attempts 1 and 2), so
//! scripted plans like `kill@manifest` / `kill@checkpoint:3` can abort
//! the process at exact boundaries to prove the argument above.

use super::faults::{xxh64, CkptFault, FaultInjector, FaultOp};
use super::spill::{frame_check, frame_encode, HEADER_BYTES};
use super::BlockPayload;
use crate::runtime::Json;
use crate::types::{Error, Result};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Manifest JSON schema version; bumped on any incompatible change.
pub const MANIFEST_SCHEMA: u32 = 1;
/// The commit record's file name (presence == checkpoint committed).
pub const MANIFEST_NAME: &str = "MANIFEST.bqm";
/// Concatenated checksummed block frames.
pub const BLOCKS_NAME: &str = "blocks.bin";
const MANIFEST_MAGIC: &str = "BMQCKPT";
const TMP_NAME: &str = "MANIFEST.tmp";

/// One row of the manifest block table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockEntry {
    /// Block id this frame belongs to.
    pub id: usize,
    /// Byte offset of the frame inside `blocks.bin`.
    pub offset: u64,
    /// Whole-frame length (header + payload).
    pub len: usize,
    /// xxh64 over the whole frame bytes (seed 0) — a manifest-side
    /// double-check on top of the frame's own embedded payload checksum.
    pub checksum: u64,
}

/// Everything the engine needs to persist besides the blocks themselves.
#[derive(Debug, Clone)]
pub struct CheckpointMeta<'a> {
    /// Engine identifier (`"bmqsim"`, `"sc19-cpu"`, ...): a checkpoint
    /// may only resume the engine that wrote it.
    pub engine: &'a str,
    /// Stages fully completed when the snapshot was taken — resume
    /// republishes the schedule starting at this stage index.
    pub stage_cursor: usize,
    /// Total stages of the run (sanity display; not load-bearing).
    pub total_stages: usize,
    /// xxh64 fingerprint of the semantic run configuration + circuit
    /// (see `sim::checkpoint_fingerprint`). Mismatch → typed error.
    pub fingerprint: u64,
    /// Cumulative metric counters carried across the resume so reports
    /// stay monotonic (compressions, gates applied, ...).
    pub counters: &'a [(&'a str, u64)],
}

/// A parsed, checksum-verified manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// On-disk schema version (see `SCHEMA`).
    pub schema: u32,
    /// Engine that wrote the checkpoint.
    pub engine: String,
    /// Stages fully completed at snapshot time.
    pub stage_cursor: usize,
    /// Total stages of the run that wrote the snapshot.
    pub total_stages: usize,
    /// Semantic run-configuration fingerprint (must match to resume).
    pub fingerprint: u64,
    /// Expected byte length of `blocks.bin`.
    pub blocks_len: u64,
    /// Carried-over cumulative metric counters.
    pub counters: Vec<(String, u64)>,
    /// Block table (one row per persisted frame).
    pub blocks: Vec<BlockEntry>,
}

/// A fully verified checkpoint: manifest plus every rehydrated payload.
#[derive(Debug)]
pub struct LoadedCheckpoint {
    /// Directory the checkpoint was loaded from.
    pub dir: PathBuf,
    /// The verified manifest.
    pub manifest: Manifest,
    /// `(block id, payload)` pairs, checksum-verified.
    pub blocks: Vec<(usize, BlockPayload)>,
}

fn ckio(what: &str, path: &Path, e: &std::io::Error) -> Error {
    Error::checkpoint(format!("{what} {}: {e}", path.display()))
}

/// fsync a directory so a completed rename survives power loss.
fn fsync_dir(dir: &Path) -> Result<()> {
    File::open(dir)
        .and_then(|f| f.sync_all())
        .map_err(|e| ckio("fsync of", dir, &e))
}

/// Minimal JSON string escaping (engine names are identifiers, but the
/// emitter must not be able to produce an unparseable manifest).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Emit the manifest JSON body. 64-bit checksums/fingerprints are hex
/// *strings* — `Json::Num` is an `f64`, lossy above 2^53.
fn emit_manifest(meta: &CheckpointMeta<'_>, entries: &[BlockEntry], blocks_len: u64) -> String {
    let mut s = String::with_capacity(128 + entries.len() * 48);
    s.push_str(&format!(
        "{{\"schema\":{},\"engine\":\"{}\",\"stage_cursor\":{},\"total_stages\":{},\
         \"fingerprint\":\"{:016x}\",\"blocks_file\":\"{}\",\"blocks_len\":{},",
        MANIFEST_SCHEMA,
        json_escape(meta.engine),
        meta.stage_cursor,
        meta.total_stages,
        meta.fingerprint,
        BLOCKS_NAME,
        blocks_len,
    ));
    s.push_str("\"counters\":{");
    for (i, (name, val)) in meta.counters.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\"{}\":{}", json_escape(name), val));
    }
    s.push_str("},\"blocks\":[");
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("[{},{},{},\"{:016x}\"]", e.id, e.offset, e.len, e.checksum));
    }
    s.push_str("]}");
    s
}

/// Consult the injector at a checkpoint fault site. `Kill` aborts the
/// process on the spot (the SIGKILL / power-loss model — no unwinding,
/// no destructors); recoverable faults surface as [`Error::Checkpoint`]
/// after optionally tearing the file under `partial`.
fn fault_gate(
    injector: Option<&FaultInjector>,
    op: FaultOp,
    len: usize,
    what: &str,
    mut partial: Option<(&mut File, &[u8])>,
) -> Result<()> {
    let Some(inj) = injector else { return Ok(()) };
    match inj.on_checkpoint_io(op, len) {
        None => Ok(()),
        Some(CkptFault::Kill) => std::process::abort(),
        Some(CkptFault::Transient(e)) => {
            Err(Error::checkpoint(format!("{what}: injected fault: {e}")))
        }
        Some(CkptFault::Short(n)) => {
            if let Some((f, bytes)) = partial.take() {
                let _ = f.write_all(&bytes[..n.min(bytes.len())]);
            }
            Err(Error::checkpoint(format!("{what}: injected torn write")))
        }
    }
}

/// Persist one checkpoint under `root` and prune retained checkpoints
/// down to the `keep` most recent. `blocks` must be the quiesced store's
/// complete live set (engines drain the epoch window and flush the
/// write-back queue first). Returns the bytes written (frames +
/// manifest) for the `checkpoint_bytes` metric.
pub fn write_checkpoint(
    root: &Path,
    meta: &CheckpointMeta<'_>,
    blocks: &[(usize, BlockPayload)],
    keep: usize,
) -> Result<u64> {
    write_checkpoint_with(root, meta, blocks, None, keep)
}

/// [`write_checkpoint`] with the store's fault injector threaded through
/// so scripted `kill@manifest` / `eio@checkpoint:N` plans fire at the
/// exact I/O boundaries (crate-internal: [`FaultInjector`] is not public
/// API).
pub(crate) fn write_checkpoint_with(
    root: &Path,
    meta: &CheckpointMeta<'_>,
    blocks: &[(usize, BlockPayload)],
    injector: Option<&FaultInjector>,
    keep: usize,
) -> Result<u64> {
    std::fs::create_dir_all(root).map_err(|e| ckio("create of checkpoint root", root, &e))?;
    let dir = root.join(format!("ckpt-{:06}", meta.stage_cursor));
    // A torn previous attempt at this cursor (kill before its manifest
    // landed) may linger; it is never the checkpoint a resume came from
    // (resume only runs stages past its source cursor), so clearing it
    // is safe.
    if dir.exists() {
        let _ = std::fs::remove_dir_all(&dir);
    }
    std::fs::create_dir_all(&dir).map_err(|e| ckio("create of checkpoint dir", &dir, &e))?;

    // Frames first: durable before the manifest can reference them.
    let blocks_path = dir.join(BLOCKS_NAME);
    let mut file = File::create(&blocks_path).map_err(|e| ckio("create of", &blocks_path, &e))?;
    let mut entries = Vec::with_capacity(blocks.len());
    let mut offset = 0u64;
    for (id, payload) in blocks {
        let frame = frame_encode(&payload.to_bytes());
        fault_gate(
            injector,
            FaultOp::Checkpoint,
            frame.len(),
            &format!("checkpoint frame for block {id}"),
            Some((&mut file, &frame)),
        )?;
        file.write_all(&frame)
            .map_err(|e| ckio(&format!("frame write for block {id} to"), &blocks_path, &e))?;
        entries
            .push(BlockEntry { id: *id, offset, len: frame.len(), checksum: xxh64(&frame, 0) });
        offset += frame.len() as u64;
    }
    file.sync_all().map_err(|e| ckio("fsync of", &blocks_path, &e))?;
    drop(file);

    // Manifest: temp write (manifest-site attempt 1) → fsync → atomic
    // rename (attempt 2) → directory fsyncs.
    let body = emit_manifest(meta, &entries, offset);
    let text = format!("{MANIFEST_MAGIC} {:016x}\n{body}", xxh64(body.as_bytes(), 0));
    let tmp = dir.join(TMP_NAME);
    {
        let mut tf = File::create(&tmp).map_err(|e| ckio("create of", &tmp, &e))?;
        fault_gate(
            injector,
            FaultOp::Manifest,
            text.len(),
            "manifest temp write",
            Some((&mut tf, text.as_bytes())),
        )?;
        tf.write_all(text.as_bytes()).map_err(|e| ckio("write of", &tmp, &e))?;
        tf.sync_all().map_err(|e| ckio("fsync of", &tmp, &e))?;
    }
    fault_gate(injector, FaultOp::Manifest, text.len(), "manifest rename", None)?;
    let final_path = dir.join(MANIFEST_NAME);
    std::fs::rename(&tmp, &final_path).map_err(|e| ckio("rename to", &final_path, &e))?;
    fsync_dir(&dir)?;
    fsync_dir(root)?;

    prune(root, keep);
    Ok(offset + text.len() as u64)
}

/// Remove all but the `keep` (min 1) most recent checkpoint directories.
/// Only called after a successful commit, so the newest retained entry
/// is always a valid checkpoint.
fn prune(root: &Path, keep: usize) {
    for (_, dir) in list_checkpoints(root).into_iter().skip(keep.max(1)) {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Checkpoint directories under `root`, newest (highest cursor) first.
/// Lists every `ckpt-<N>` directory, committed or torn — validation
/// happens at load time.
pub fn list_checkpoints(root: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(root) else { return out };
    for ent in rd.flatten() {
        if let Some(name) = ent.file_name().to_str() {
            if let Some(n) = name.strip_prefix("ckpt-") {
                if let Ok(cursor) = n.parse::<usize>() {
                    out.push((cursor, ent.path()));
                }
            }
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

/// Read and verify a checkpoint's manifest: integrity header first (any
/// torn or bit-flipped byte in the file fails the xxh64 before the JSON
/// is even parsed), then schema-checked field extraction. Every failure
/// is a typed [`Error::Checkpoint`].
pub fn load_manifest(dir: &Path) -> Result<Manifest> {
    let path = dir.join(MANIFEST_NAME);
    let raw =
        std::fs::read(&path).map_err(|e| ckio("read of", &path, &e))?;
    let text = std::str::from_utf8(&raw)
        .map_err(|_| Error::checkpoint(format!("{}: not valid utf-8", path.display())))?;
    let (header, body) = text
        .split_once('\n')
        .ok_or_else(|| Error::checkpoint(format!("{}: missing header line", path.display())))?;
    let sum = header
        .strip_prefix(MANIFEST_MAGIC)
        .map(str::trim)
        .ok_or_else(|| Error::checkpoint(format!("{}: bad magic", path.display())))?;
    let want = u64::from_str_radix(sum, 16)
        .map_err(|_| Error::checkpoint(format!("{}: bad header checksum field", path.display())))?;
    let got = xxh64(body.as_bytes(), 0);
    if want != got {
        return Err(Error::checkpoint(format!(
            "{}: checksum mismatch (stored {want:016x}, computed {got:016x}) — torn or corrupt",
            path.display()
        )));
    }
    let j = Json::parse(body)
        .map_err(|e| Error::checkpoint(format!("{}: {e}", path.display())))?;
    let field_u64 = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(Json::as_f64)
            .map(|n| n as u64)
            .ok_or_else(|| Error::checkpoint(format!("{}: missing field {k:?}", path.display())))
    };
    let schema = field_u64("schema")? as u32;
    if schema != MANIFEST_SCHEMA {
        return Err(Error::checkpoint(format!(
            "{}: manifest schema {schema} unsupported (this build reads {MANIFEST_SCHEMA})",
            path.display()
        )));
    }
    let engine = j
        .get("engine")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::checkpoint(format!("{}: missing field \"engine\"", path.display())))?
        .to_string();
    let fingerprint = j
        .get("fingerprint")
        .and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| {
            Error::checkpoint(format!("{}: missing/bad field \"fingerprint\"", path.display()))
        })?;
    let mut counters = Vec::new();
    if let Some(obj) = j.get("counters").and_then(Json::as_obj) {
        for (k, v) in obj {
            let val = v.as_f64().ok_or_else(|| {
                Error::checkpoint(format!("{}: non-numeric counter {k:?}", path.display()))
            })?;
            counters.push((k.clone(), val as u64));
        }
    }
    let mut blocks = Vec::new();
    match j.get("blocks") {
        Some(Json::Arr(rows)) => {
            for row in rows {
                let bad = || {
                    Error::checkpoint(format!("{}: malformed block-table row", path.display()))
                };
                let Json::Arr(cells) = row else { return Err(bad()) };
                if cells.len() != 4 {
                    return Err(bad());
                }
                let id = cells[0].as_usize().ok_or_else(bad)?;
                let offset = cells[1].as_f64().ok_or_else(bad)? as u64;
                let len = cells[2].as_usize().ok_or_else(bad)?;
                let checksum = cells[3]
                    .as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(bad)?;
                blocks.push(BlockEntry { id, offset, len, checksum });
            }
        }
        _ => {
            return Err(Error::checkpoint(format!(
                "{}: missing field \"blocks\"",
                path.display()
            )))
        }
    }
    Ok(Manifest {
        schema,
        engine,
        stage_cursor: field_u64("stage_cursor")? as usize,
        total_stages: field_u64("total_stages")? as usize,
        fingerprint,
        blocks_len: field_u64("blocks_len")?,
        counters,
        blocks,
    })
}

/// Load and fully verify one checkpoint directory: manifest, blocks-file
/// size, and every frame (manifest checksum + embedded frame checksum +
/// payload framing). Frame damage surfaces as [`Error::Corruption`];
/// manifest damage as [`Error::Checkpoint`].
pub fn load_checkpoint(dir: &Path) -> Result<LoadedCheckpoint> {
    let manifest = load_manifest(dir)?;
    let blocks_path = dir.join(BLOCKS_NAME);
    let bytes = std::fs::read(&blocks_path).map_err(|e| ckio("read of", &blocks_path, &e))?;
    if bytes.len() as u64 != manifest.blocks_len {
        return Err(Error::Corruption(format!(
            "{}: {} B on disk, manifest says {}",
            blocks_path.display(),
            bytes.len(),
            manifest.blocks_len
        )));
    }
    let mut blocks = Vec::with_capacity(manifest.blocks.len());
    for e in &manifest.blocks {
        let end = e.offset.checked_add(e.len as u64).filter(|&end| end <= bytes.len() as u64);
        let Some(end) = end else {
            return Err(Error::Corruption(format!(
                "{}: block {} frame [{}, +{}) exceeds the blocks file",
                blocks_path.display(),
                e.id,
                e.offset,
                e.len
            )));
        };
        let frame = &bytes[e.offset as usize..end as usize];
        let got = xxh64(frame, 0);
        if got != e.checksum {
            return Err(Error::Corruption(format!(
                "{}: block {} frame checksum mismatch (manifest {:016x}, computed {got:016x})",
                blocks_path.display(),
                e.id,
                e.checksum
            )));
        }
        let plen = frame_check(frame, e.offset)?;
        let payload =
            BlockPayload::from_bytes(&frame[HEADER_BYTES..HEADER_BYTES + plen]).map_err(|_| {
                Error::Corruption(format!(
                    "{}: block {} payload framing is corrupt",
                    blocks_path.display(),
                    e.id
                ))
            })?;
        blocks.push((e.id, payload));
    }
    Ok(LoadedCheckpoint { dir: dir.to_path_buf(), manifest, blocks })
}

/// Resume entry point: walk the retained checkpoints newest-first and
/// return the first that fully verifies. A torn or corrupt newer
/// checkpoint falls back to the previous retained one; an intact
/// checkpoint written by a different engine or run configuration is a
/// hard typed error (no fallback — every checkpoint in a directory
/// shares one config, so older ones cannot match either).
pub fn load_latest(root: &Path, engine: &str, fingerprint: u64) -> Result<LoadedCheckpoint> {
    let cands = list_checkpoints(root);
    if cands.is_empty() {
        return Err(Error::checkpoint(format!(
            "no checkpoints under {} (expected ckpt-* directories)",
            root.display()
        )));
    }
    let mut last_err: Option<Error> = None;
    for (_, dir) in cands {
        match load_checkpoint(&dir) {
            Ok(l) => {
                if l.manifest.engine != engine {
                    return Err(Error::checkpoint(format!(
                        "{} was written by engine {:?}; this run uses {engine:?}",
                        dir.display(),
                        l.manifest.engine
                    )));
                }
                if l.manifest.fingerprint != fingerprint {
                    return Err(Error::checkpoint(format!(
                        "config fingerprint mismatch: {} has {:016x}, this run computes \
                         {fingerprint:016x} (circuit or semantic config differs)",
                        dir.display(),
                        l.manifest.fingerprint
                    )));
                }
                return Ok(l);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        Error::checkpoint(format!("no loadable checkpoint under {}", root.display()))
    }))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

    fn tmproot() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "bmqsim-ckpt-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn payloads(n: usize) -> Vec<(usize, BlockPayload)> {
        (0..n)
            .map(|i| {
                (i, BlockPayload { re: vec![i as u8; 20 + i], im: vec![(i as u8) ^ 0xFF; 8 + i] })
            })
            .collect()
    }

    fn meta(cursor: usize, fp: u64) -> CheckpointMeta<'static> {
        CheckpointMeta {
            engine: "bmqsim",
            stage_cursor: cursor,
            total_stages: 9,
            fingerprint: fp,
            counters: &[("compressions", 42), ("gates_applied", 7)],
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let root = tmproot();
        let blocks = payloads(5);
        let bytes = write_checkpoint(&root, &meta(3, 0xABCD), &blocks, 2).unwrap();
        assert!(bytes > 0);
        let l = load_latest(&root, "bmqsim", 0xABCD).unwrap();
        assert_eq!(l.manifest.stage_cursor, 3);
        assert_eq!(l.manifest.total_stages, 9);
        assert_eq!(l.manifest.schema, MANIFEST_SCHEMA);
        assert_eq!(l.manifest.counters.len(), 2);
        assert!(l.manifest.counters.contains(&("compressions".to_string(), 42)));
        assert_eq!(l.blocks.len(), 5);
        for ((id, p), (eid, ep)) in l.blocks.iter().zip(blocks.iter()) {
            assert_eq!(id, eid);
            assert_eq!(p.re, ep.re);
            assert_eq!(p.im, ep.im);
        }
    }

    #[test]
    fn fingerprint_and_engine_mismatch_are_typed() {
        let root = tmproot();
        write_checkpoint(&root, &meta(1, 0x1111), &payloads(2), 2).unwrap();
        match load_latest(&root, "bmqsim", 0x2222) {
            Err(Error::Checkpoint(m)) => assert!(m.contains("fingerprint"), "{m}"),
            other => panic!("expected Checkpoint error, got {:?}", other.map(|_| ())),
        }
        match load_latest(&root, "sc19-cpu", 0x1111) {
            Err(Error::Checkpoint(m)) => assert!(m.contains("engine"), "{m}"),
            other => panic!("expected Checkpoint error, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn newest_wins_and_retention_prunes() {
        let root = tmproot();
        for cursor in [1usize, 2, 3, 4] {
            write_checkpoint(&root, &meta(cursor, 7), &payloads(cursor), 2).unwrap();
        }
        let listed = list_checkpoints(&root);
        assert_eq!(listed.len(), 2, "keep=2 must prune to the two newest");
        assert_eq!(listed[0].0, 4);
        assert_eq!(listed[1].0, 3);
        assert_eq!(load_latest(&root, "bmqsim", 7).unwrap().manifest.stage_cursor, 4);
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let root = tmproot();
        write_checkpoint(&root, &meta(2, 9), &payloads(3), 2).unwrap();
        write_checkpoint(&root, &meta(4, 9), &payloads(3), 2).unwrap();
        // Damage the newest manifest (single byte in the JSON body).
        let man = root.join("ckpt-000004").join(MANIFEST_NAME);
        let mut raw = std::fs::read(&man).unwrap();
        let n = raw.len();
        raw[n - 3] ^= 0x01;
        std::fs::write(&man, &raw).unwrap();
        let l = load_latest(&root, "bmqsim", 9).unwrap();
        assert_eq!(l.manifest.stage_cursor, 2, "must fall back to the intact checkpoint");
        // A manifest-less (torn) directory is skipped the same way.
        std::fs::remove_file(&man).unwrap();
        assert_eq!(load_latest(&root, "bmqsim", 9).unwrap().manifest.stage_cursor, 2);
    }

    #[test]
    fn no_checkpoints_is_typed() {
        let root = tmproot();
        assert!(matches!(load_latest(&root, "bmqsim", 0), Err(Error::Checkpoint(_))));
    }

    #[test]
    fn every_manifest_byte_is_load_bearing() {
        // The satellite property test at the unit level: flipping ANY
        // byte of the manifest yields a typed error, never a panic or a
        // silently wrong manifest.
        let root = tmproot();
        write_checkpoint(&root, &meta(5, 0xFEED), &payloads(2), 2).unwrap();
        let man = root.join("ckpt-000005").join(MANIFEST_NAME);
        let good = std::fs::read(&man).unwrap();
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x01;
            std::fs::write(&man, &bad).unwrap();
            match load_checkpoint(root.join("ckpt-000005").as_path()) {
                Err(Error::Checkpoint(_)) | Err(Error::Corruption(_)) => {}
                Ok(_) => panic!("flip at byte {i} loaded successfully"),
                Err(other) => panic!("flip at byte {i}: unexpected error {other:?}"),
            }
        }
        std::fs::write(&man, &good).unwrap();
        assert!(load_checkpoint(root.join("ckpt-000005").as_path()).is_ok());
    }

    #[test]
    fn frame_damage_is_corruption() {
        let root = tmproot();
        write_checkpoint(&root, &meta(1, 1), &payloads(3), 2).unwrap();
        let bp = root.join("ckpt-000001").join(BLOCKS_NAME);
        let good = std::fs::read(&bp).unwrap();
        // Bit-flip in the middle of the file (some frame's payload).
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        std::fs::write(&bp, &bad).unwrap();
        assert!(matches!(
            load_checkpoint(root.join("ckpt-000001").as_path()),
            Err(Error::Corruption(_))
        ));
        // Truncation.
        std::fs::write(&bp, &good[..good.len() - 1]).unwrap();
        assert!(matches!(
            load_checkpoint(root.join("ckpt-000001").as_path()),
            Err(Error::Corruption(_))
        ));
    }
}
