//! Secondary-tier spill file: extent allocation + positioned I/O, and the
//! background spill-writer thread.
//!
//! All file I/O in the memory subsystem goes through [`SpillFile`], which
//! uses positioned reads/writes (`pread`/`pwrite` via
//! [`std::os::unix::fs::FileExt`]) on a single shared handle — no seek
//! state, so the writer thread, pipeline workers (`take`/`get` on spilled
//! blocks), and the prefetcher all touch the file concurrently without a
//! file lock. Only the *extent allocator* (tail pointer + free list) is
//! mutex-protected, and its critical sections are pure bookkeeping.
//!
//! The writer thread ([`writer_loop`]) drains the store's write-back
//! queue: eviction candidates accumulate as `Queued` payloads that
//! `take`/`get`/`put` can still intercept; once the writer claims one it
//! becomes `InFlight` (interceptors wait), is written outside all shard
//! locks, and the slot flips to `Spilled`. See `memory::Shared` for the
//! state machine and DESIGN.md "Two-level memory" for the ownership rules.

use crate::types::{Error, Result};
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Process-wide spill-file sequence number: two stores created in the same
/// process (even with the same spill dir) always get distinct file names.
/// (The previous scheme derived uniqueness from a *stack address*, which
/// can be reused across stores and clobber a live spill file.)
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

struct ExtentAlloc {
    tail: u64,
    /// Reusable holes (offset, capacity) from freed block extents.
    free: Vec<(u64, usize)>,
}

/// The secondary-tier file: positioned I/O + first-fit extent reuse.
pub(crate) struct SpillFile {
    file: File,
    path: PathBuf,
    alloc: Mutex<ExtentAlloc>,
}

impl SpillFile {
    /// Create a fresh, uniquely named spill file inside `dir`.
    pub(crate) fn create(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let unique = format!(
            "bmqsim-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = dir.join(unique);
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(SpillFile { file, path, alloc: Mutex::new(ExtentAlloc { tail: 0, free: Vec::new() }) })
    }

    /// Reserve an extent of `len` bytes (first-fit over freed holes, else
    /// the tail). Pure bookkeeping — no I/O.
    fn alloc_extent(&self, len: usize) -> u64 {
        let mut a = self.alloc.lock().unwrap();
        for i in 0..a.free.len() {
            if a.free[i].1 >= len {
                let (off, cap) = a.free.swap_remove(i);
                if cap > len {
                    a.free.push((off + len as u64, cap - len));
                }
                return off;
            }
        }
        let off = a.tail;
        a.tail += len as u64;
        off
    }

    /// Return an extent to the free list. No I/O; safe under shard locks,
    /// though callers free after releasing them anyway.
    pub(crate) fn free_extent(&self, offset: u64, len: usize) {
        self.alloc.lock().unwrap().free.push((offset, len));
    }

    /// Allocate an extent and write `bytes` into it (pwrite; no allocator
    /// lock held during the write).
    pub(crate) fn write(&self, bytes: &[u8]) -> Result<(u64, usize)> {
        let offset = self.alloc_extent(bytes.len());
        if let Err(e) = self.file.write_all_at(bytes, offset) {
            self.free_extent(offset, bytes.len());
            return Err(Error::Io(e));
        }
        Ok((offset, bytes.len()))
    }

    /// Positioned read of a whole extent into `buf` (resized to `len`).
    pub(crate) fn read_into(&self, offset: u64, len: usize, buf: &mut Vec<u8>) -> Result<()> {
        buf.clear();
        buf.resize(len, 0);
        self.file.read_exact_at(buf, offset).map_err(Error::Io)
    }

    /// Current tail (diagnostics/tests: bounds file growth under reuse).
    pub(crate) fn tail(&self) -> u64 {
        self.alloc.lock().unwrap().tail
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Background spill writer: claims queued eviction candidates from the
/// write-back queue and performs the serialize→write→install sequence
/// outside every shard lock. Exits when the store shuts down.
pub(crate) fn writer_loop(shared: Arc<super::Shared>) {
    loop {
        let job = {
            let mut wb = shared.wb.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                // Pop the oldest queue entry whose epoch is still current;
                // stale entries (intercepted or re-evicted ids) are skipped.
                let mut claimed = None;
                while let Some((id, epoch)) = wb.queue.pop_front() {
                    let take = matches!(
                        wb.map.get(&id),
                        Some(e) if e.epoch == epoch && matches!(e.state, super::WbState::Queued(_))
                    );
                    if take {
                        let entry = wb.map.get_mut(&id).unwrap();
                        let state = std::mem::replace(&mut entry.state, super::WbState::InFlight);
                        let super::WbState::Queued(payload) = state else { unreachable!() };
                        claimed = Some((id, epoch, payload));
                        break;
                    }
                }
                if let Some(job) = claimed {
                    break job;
                }
                let (guard, _) = shared
                    .wb_cv
                    .wait_timeout(wb, Duration::from_millis(5))
                    .unwrap();
                wb = guard;
            }
        };
        let (id, epoch, payload) = job;
        shared.spill_block_now(id, epoch, payload);
    }
}
