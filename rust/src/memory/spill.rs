//! Secondary-tier spill file: extent allocation + positioned I/O with
//! checksummed frames, bounded retry, and the background spill-writer
//! thread.
//!
//! All file I/O in the memory subsystem goes through [`SpillFile`], which
//! uses positioned reads/writes (`pread`/`pwrite` via
//! [`std::os::unix::fs::FileExt`]) on a single shared handle — no seek
//! state, so the writer thread, pipeline workers (`take`/`get` on spilled
//! blocks), and the prefetcher all touch the file concurrently without a
//! file lock. Only the *extent allocator* (tail pointer + free list) is
//! mutex-protected, and its critical sections are pure bookkeeping.
//!
//! Failure domains (DESIGN.md "Failure domains & recovery"):
//!
//! * Every extent is a **frame**: a 16-byte header (magic, payload length,
//!   xxh64 over the payload) ahead of the serialized block. Every disk
//!   read re-verifies the header before bytes reach a decoder, so torn
//!   reads and bit flips surface as [`Error::Corruption`] at the I/O
//!   boundary instead of as garbage amplitudes downstream.
//! * Transient I/O errors (EIO, interrupted, torn writes) are retried up
//!   to [`MAX_IO_ATTEMPTS`] with exponential backoff; `pwrite` of a full
//!   frame is idempotent, so a short write is healed by simply rewriting.
//! * ENOSPC is **not** retried — it propagates to the store's degradation
//!   ladder (fallback stripe, then budget renegotiation).
//! * A [`FaultInjector`] (when configured) intercepts every read/write
//!   attempt and writer-queue transition, making all of the above
//!   deterministically testable.
//!
//! The writer thread ([`writer_loop`]) drains the store's write-back
//! queue: eviction candidates accumulate as `Queued` payloads that
//! `take`/`get`/`put` can still intercept; once the writer claims one it
//! becomes `InFlight` (interceptors wait), is written outside all shard
//! locks, and the slot flips to `Spilled`. A writer panic or injected
//! death marks the writer dead (`Shared::writer_alive`) and the store
//! self-heals by draining the queue inline. See `memory::Shared` for the
//! state machine and DESIGN.md "Two-level memory" for ownership rules.

use super::faults::{xxh64, FaultInjector, ReadFault, SpillTier, WriteFault, WriterFault};
use super::{plock, pwait_timeout};
use crate::types::{Error, Result};
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Process-wide spill-file sequence number: two stores created in the same
/// process (even with the same spill dir) always get distinct file names.
/// (The previous scheme derived uniqueness from a *stack address*, which
/// can be reused across stores and clobber a live spill file.)
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// On-disk frame header: `[magic "BQSF" (4)][payload_len u32 LE][xxh64
/// (payload, seed = payload_len) u64 LE]`, followed by the payload.
pub(crate) const HEADER_BYTES: usize = 16;
const FRAME_MAGIC: [u8; 4] = *b"BQSF";

/// Transient-I/O retry budget: 1 initial attempt + 4 retries.
pub(crate) const MAX_IO_ATTEMPTS: u32 = 5;

/// Exponential backoff before retry `attempt` (1-based): 200 µs, 400 µs,
/// 800 µs, 1.6 ms.
fn backoff(attempt: u32) -> Duration {
    Duration::from_micros((100u64 << attempt.min(6)).min(6_400))
}

/// Transient (retry-worthy) I/O errors: EINTR-style kinds plus raw EIO,
/// which on real disks is routinely a one-off (media retry, path flap).
fn is_transient(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::WriteZero
            | std::io::ErrorKind::TimedOut
    ) || e.raw_os_error() == Some(5)
}

fn is_enospc(e: &std::io::Error) -> bool {
    e.raw_os_error() == Some(28)
}

/// Does this crate error carry ENOSPC? (The store's degradation ladder
/// keys off this; `io::ErrorKind::StorageFull` is not stable on our
/// toolchain, hence the raw errno check.)
pub(crate) fn error_is_enospc(e: &Error) -> bool {
    match e {
        Error::Io(io) => is_enospc(io),
        Error::Spill { source: Some(io), .. } => is_enospc(io),
        _ => false,
    }
}

/// Per-store recovery telemetry, shared by both spill tiers and surfaced
/// through `MemStats` → `Metrics`.
#[derive(Default)]
pub(crate) struct RecoveryCounters {
    /// Transient-I/O attempts that were retried (reads and writes).
    pub(crate) io_retries: AtomicU64,
    /// Frame reads that failed header/checksum verification.
    pub(crate) checksum_failures: AtomicU64,
    /// Corrupt frames healed from the write-back retention ring.
    pub(crate) frames_recovered: AtomicU64,
    /// ENOSPC degradations (fallback-stripe writes + budget bumps).
    pub(crate) enospc_fallbacks: AtomicU64,
}

/// Wrap `payload` in a checksummed `[BQSF]` frame. Shared by the spill
/// tier and the checkpoint writer (`memory::checkpoint`), so checkpointed
/// blocks carry the exact same integrity envelope as spilled ones.
pub(crate) fn frame_encode(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&xxh64(payload, payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Verify a frame's header against its payload; returns the payload
/// length on success. Shared with `memory::checkpoint` (resume-side
/// verification of checkpoint frames).
pub(crate) fn frame_check(frame: &[u8], offset: u64) -> Result<usize> {
    if frame.len() < HEADER_BYTES {
        return Err(Error::Corruption(format!(
            "frame at {offset}: {} B is shorter than the {HEADER_BYTES} B header",
            frame.len()
        )));
    }
    if frame[0..4] != FRAME_MAGIC {
        return Err(Error::Corruption(format!("frame at {offset}: bad magic")));
    }
    let plen = u32::from_le_bytes(
        frame[4..8].try_into().expect("4-byte slice"),
    ) as usize;
    if plen != frame.len() - HEADER_BYTES {
        return Err(Error::Corruption(format!(
            "frame at {offset}: header says {plen} B payload, extent holds {}",
            frame.len() - HEADER_BYTES
        )));
    }
    let want = u64::from_le_bytes(frame[8..16].try_into().expect("8-byte slice"));
    let got = xxh64(&frame[HEADER_BYTES..], plen as u64);
    if want != got {
        return Err(Error::Corruption(format!(
            "frame at {offset}: xxh64 mismatch (stored {want:016x}, computed {got:016x})"
        )));
    }
    Ok(plen)
}

struct ExtentAlloc {
    tail: u64,
    /// Reusable holes (offset, capacity) from freed block extents.
    free: Vec<(u64, usize)>,
}

/// One secondary-tier file: positioned I/O + first-fit extent reuse,
/// frame checksums, retry with backoff, and fault interception.
pub(crate) struct SpillFile {
    file: File,
    path: PathBuf,
    tier: SpillTier,
    injector: Option<Arc<FaultInjector>>,
    counters: Arc<RecoveryCounters>,
    alloc: Mutex<ExtentAlloc>,
}

impl SpillFile {
    /// Create a fresh, uniquely named spill file inside `dir`.
    pub(crate) fn create(
        dir: &Path,
        tier: SpillTier,
        injector: Option<Arc<FaultInjector>>,
        counters: Arc<RecoveryCounters>,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let unique = format!(
            "bmqsim-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = dir.join(unique);
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(SpillFile {
            file,
            path,
            tier,
            injector,
            counters,
            alloc: Mutex::new(ExtentAlloc { tail: 0, free: Vec::new() }),
        })
    }

    /// Reserve an extent of `len` bytes (first-fit over freed holes, else
    /// the tail). Pure bookkeeping — no I/O.
    fn alloc_extent(&self, len: usize) -> u64 {
        let mut a = plock(&self.alloc);
        for i in 0..a.free.len() {
            if a.free[i].1 >= len {
                let (off, cap) = a.free.swap_remove(i);
                if cap > len {
                    a.free.push((off + len as u64, cap - len));
                }
                return off;
            }
        }
        let off = a.tail;
        a.tail += len as u64;
        off
    }

    /// Return an extent to the free list. No I/O; safe under shard locks,
    /// though callers free after releasing them anyway.
    pub(crate) fn free_extent(&self, offset: u64, len: usize) {
        plock(&self.alloc).free.push((offset, len));
    }

    /// Allocate an extent and write `payload` into it as a checksummed
    /// frame (pwrite; no allocator lock held during the write). Transient
    /// errors are retried with backoff; ENOSPC and exhausted retries free
    /// the extent and surface as [`Error::Spill`] with the `io::Error`
    /// preserved.
    pub(crate) fn write(&self, payload: &[u8]) -> Result<(u64, usize)> {
        let frame = frame_encode(payload);
        let offset = self.alloc_extent(frame.len());
        match self.write_with_retry(offset, &frame) {
            Ok(()) => Ok((offset, frame.len())),
            Err(e) => {
                self.free_extent(offset, frame.len());
                Err(e)
            }
        }
    }

    fn write_with_retry(&self, offset: u64, frame: &[u8]) -> Result<()> {
        let mut attempt = 0u32;
        loop {
            let injected =
                self.injector.as_ref().and_then(|i| i.on_write(self.tier, frame.len()));
            let res: std::io::Result<()> = match injected {
                Some(WriteFault::Enospc) => Err(super::faults::enospc()),
                Some(WriteFault::Transient(e)) => Err(e),
                Some(WriteFault::Short(n)) => {
                    // A torn write: a prefix lands, then the op errors.
                    // pwrite of the full frame is idempotent, so the retry
                    // below simply rewrites over the torn bytes.
                    let _ = self.file.write_all_at(&frame[..n.min(frame.len())], offset);
                    Err(super::faults::eio())
                }
                None => self.file.write_all_at(frame, offset),
            };
            match res {
                Ok(()) => return Ok(()),
                Err(e) if is_transient(&e) && attempt + 1 < MAX_IO_ATTEMPTS => {
                    attempt += 1;
                    self.counters.io_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(backoff(attempt));
                }
                Err(e) => {
                    return Err(Error::spill_io(
                        format!(
                            "write of {} B frame at offset {offset} failed after {} attempt(s)",
                            frame.len(),
                            attempt + 1
                        ),
                        e,
                    ))
                }
            }
        }
    }

    /// Positioned read of a whole frame extent; on success `buf` holds the
    /// *verified payload* (header stripped). Transient errors and failed
    /// verifications are retried (a re-read heals in-transit damage);
    /// persistent mismatches surface as [`Error::Corruption`] for the
    /// store's retention-ring recovery.
    pub(crate) fn read_frame(&self, offset: u64, len: usize, buf: &mut Vec<u8>) -> Result<()> {
        let mut attempt = 0u32;
        loop {
            buf.clear();
            buf.resize(len, 0);
            let injected = self.injector.as_ref().and_then(|i| i.on_read(offset, len));
            let res: std::io::Result<()> = match injected {
                Some(ReadFault::Transient(e)) => Err(e),
                Some(ReadFault::Short(n)) => {
                    let r = self.file.read_exact_at(buf, offset);
                    for b in &mut buf[n.min(len)..] {
                        *b = 0;
                    }
                    r
                }
                Some(ReadFault::BitFlip) => {
                    let r = self.file.read_exact_at(buf, offset);
                    FaultInjector::flip_bit(buf);
                    r
                }
                None => self.file.read_exact_at(buf, offset),
            };
            let err = match res {
                Ok(()) => match frame_check(buf, offset) {
                    Ok(plen) => {
                        buf.copy_within(HEADER_BYTES..HEADER_BYTES + plen, 0);
                        buf.truncate(plen);
                        return Ok(());
                    }
                    Err(e) => {
                        self.counters.checksum_failures.fetch_add(1, Ordering::Relaxed);
                        e
                    }
                },
                Err(e) if is_transient(&e) => Error::spill_io(
                    format!("read of {len} B frame at offset {offset} failed"),
                    e,
                ),
                Err(e) => {
                    return Err(Error::spill_io(
                        format!("read of {len} B frame at offset {offset} failed"),
                        e,
                    ))
                }
            };
            attempt += 1;
            if attempt >= MAX_IO_ATTEMPTS {
                return Err(err);
            }
            self.counters.io_retries.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(backoff(attempt));
        }
    }

    /// Current tail (diagnostics/tests: bounds file growth under reuse).
    pub(crate) fn tail(&self) -> u64 {
        plock(&self.alloc).tail
    }

    /// Test hook: poison the allocator mutex the way a panicking worker
    /// would, to prove `plock` recovery keeps the file usable.
    #[cfg(test)]
    pub(crate) fn poison_alloc_for_test(&self) {
        let _ = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = self.alloc.lock();
                panic!("injected allocator panic");
            })
            .join()
        });
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Background spill writer: claims queued eviction candidates from the
/// write-back queue and performs the serialize→write→install sequence
/// outside every shard lock. Exits when the store shuts down — or when a
/// fault (injected death, panic) kills it, in which case it flags
/// `Shared::writer_alive` so the store drains the queue inline instead of
/// hanging on a thread that no longer exists.
pub(crate) fn writer_loop(shared: Arc<super::Shared>) {
    loop {
        let job = {
            let mut wb = plock(&shared.wb);
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(job) = super::Shared::claim_next(&mut wb) {
                    break job;
                }
                wb = pwait_timeout(&shared.wb_cv, wb, Duration::from_millis(5));
            }
        };
        let (id, epoch, payload) = job;
        if let Some(inj) = shared.injector.as_ref() {
            match inj.on_writer_job() {
                Some(WriterFault::Stall(d)) => std::thread::sleep(d),
                Some(WriterFault::Die) => {
                    // Requeue the claimed job (nothing is lost), then die.
                    shared.requeue_job(id, epoch, payload);
                    shared.writer_alive.store(false, Ordering::Release);
                    shared.wb_cv.notify_all();
                    return;
                }
                None => {}
            }
        }
        // A panic anywhere in the spill path must not take down the queue:
        // record it, mark the writer dead, and let foreground threads
        // drain inline / surface the typed failure.
        let ok = catch_unwind(AssertUnwindSafe(|| shared.spill_block_now(id, epoch, payload)));
        if ok.is_err() {
            shared.record_failure(&Error::spill(format!(
                "spill writer panicked while writing block {id}"
            )));
            {
                let mut wg = plock(&shared.wb);
                if matches!(wg.map.get(&id), Some(en) if en.epoch == epoch) {
                    wg.map.remove(&id);
                }
            }
            shared.writer_alive.store(false, Ordering::Release);
            shared.wb_cv.notify_all();
            return;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("bmqsim-spillfile-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn plain(tier: SpillTier) -> SpillFile {
        SpillFile::create(&tmpdir(), tier, None, Arc::new(RecoveryCounters::default())).unwrap()
    }

    #[test]
    fn frame_roundtrip_and_overhead() {
        let f = plain(SpillTier::Primary);
        let payload: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
        let (off, len) = f.write(&payload).unwrap();
        assert_eq!(len, payload.len() + HEADER_BYTES);
        let mut buf = Vec::new();
        f.read_frame(off, len, &mut buf).unwrap();
        assert_eq!(buf, payload);
    }

    #[test]
    fn frame_check_catches_each_field() {
        let payload = vec![7u8; 64];
        let mut frame = frame_encode(&payload);
        assert!(frame_check(&frame, 0).is_ok());
        let good = frame.clone();
        frame[0] = b'X'; // magic
        assert!(matches!(frame_check(&frame, 0), Err(Error::Corruption(_))));
        frame = good.clone();
        frame[4] ^= 0x01; // length
        assert!(matches!(frame_check(&frame, 0), Err(Error::Corruption(_))));
        frame = good.clone();
        frame[HEADER_BYTES + 10] ^= 0x01; // payload bit
        assert!(matches!(frame_check(&frame, 0), Err(Error::Corruption(_))));
        assert!(matches!(frame_check(&good[..8], 0), Err(Error::Corruption(_))));
    }

    #[test]
    fn transient_write_faults_are_retried() {
        let plan = super::super::FaultPlan::parse("eio@write:1,short@write:2").unwrap();
        let counters = Arc::new(RecoveryCounters::default());
        let f = SpillFile::create(
            &tmpdir(),
            SpillTier::Primary,
            Some(Arc::new(FaultInjector::new(plan))),
            counters.clone(),
        )
        .unwrap();
        // Attempt 1 EIO, attempt 2 torn: the third rewrite lands clean.
        let payload = vec![42u8; 100];
        let (off, len) = f.write(&payload).unwrap();
        assert_eq!(counters.io_retries.load(Ordering::Relaxed), 2);
        let mut buf = Vec::new();
        f.read_frame(off, len, &mut buf).unwrap();
        assert_eq!(buf, payload);
    }

    #[test]
    fn transient_read_corruption_heals_on_reread() {
        let plan = super::super::FaultPlan::parse("bitflip@read:1,short@read:2").unwrap();
        let counters = Arc::new(RecoveryCounters::default());
        let f = SpillFile::create(
            &tmpdir(),
            SpillTier::Primary,
            Some(Arc::new(FaultInjector::new(plan))),
            counters.clone(),
        )
        .unwrap();
        let payload = vec![9u8; 80];
        let (off, len) = f.write(&payload).unwrap();
        let mut buf = Vec::new();
        f.read_frame(off, len, &mut buf).unwrap();
        assert_eq!(buf, payload, "re-reads must heal in-transit damage");
        assert_eq!(counters.checksum_failures.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn persistent_corruption_is_typed_not_silent() {
        // Sticky corruption: every re-read is damaged; after the retry
        // budget the caller gets Error::Corruption, never bad bytes.
        let plan = super::super::FaultPlan::parse("stickyflip@read:1").unwrap();
        let counters = Arc::new(RecoveryCounters::default());
        let f = SpillFile::create(
            &tmpdir(),
            SpillTier::Primary,
            Some(Arc::new(FaultInjector::new(plan))),
            counters.clone(),
        )
        .unwrap();
        let (off, len) = f.write(&vec![1u8; 64]).unwrap();
        let mut buf = Vec::new();
        match f.read_frame(off, len, &mut buf) {
            Err(Error::Corruption(m)) => assert!(m.contains("xxh64")),
            other => panic!("expected Corruption, got {other:?}"),
        }
        assert_eq!(
            counters.checksum_failures.load(Ordering::Relaxed),
            u64::from(MAX_IO_ATTEMPTS)
        );
    }

    #[test]
    fn exhausted_write_retries_preserve_the_io_source() {
        use std::error::Error as _;
        let plan = super::super::FaultPlan::parse("seed=1,eio=1.0").unwrap();
        let f = SpillFile::create(
            &tmpdir(),
            SpillTier::Primary,
            Some(Arc::new(FaultInjector::new(plan))),
            Arc::new(RecoveryCounters::default()),
        )
        .unwrap();
        let err = f.write(&[0u8; 32]).unwrap_err();
        assert!(matches!(err, Error::Spill { .. }));
        assert!(err.source().is_some(), "io source must be preserved");
        // The failed extent was freed: the next write reuses offset 0.
        assert_eq!(f.tail(), (32 + HEADER_BYTES) as u64);
    }

    #[test]
    fn enospc_is_not_retried() {
        let plan = super::super::FaultPlan::parse("enospc_after=0").unwrap();
        let counters = Arc::new(RecoveryCounters::default());
        let f = SpillFile::create(
            &tmpdir(),
            SpillTier::Primary,
            Some(Arc::new(FaultInjector::new(plan))),
            counters.clone(),
        )
        .unwrap();
        let err = f.write(&[0u8; 32]).unwrap_err();
        assert!(error_is_enospc(&err), "got {err:?}");
        assert_eq!(counters.io_retries.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn poisoned_allocator_recovers() {
        let f = plain(SpillTier::Primary);
        f.poison_alloc_for_test();
        let (off, len) = f.write(&[3u8; 16]).unwrap();
        let mut buf = Vec::new();
        f.read_frame(off, len, &mut buf).unwrap();
        assert_eq!(buf, vec![3u8; 16]);
    }
}
