//! CI bench-regression gate CLI (see `bench_harness::check`).
//!
//! USAGE:
//!   bench_check [--baselines <dir>] [--fresh <dir>] [--tolerance <t>]
//!               [--append-history [<file>]] [FILE...]
//!
//! Positional FILE arguments are fresh `BENCH_*.json` artifacts that MUST
//! exist (each CI matrix job passes the artifact its bench emits); gated
//! files that happen to be present are always checked. Exits non-zero on
//! any regression beyond the tolerance.
//!
//! `--append-history` appends one schema-stamped JSONL line per checked
//! artifact (git sha, date, gated ratio metrics) to `bench_history.jsonl`
//! (or the given file) after a PASSING gate run, building a committed
//! perf trajectory across CI runs.
//!
//! `BENCH_BASELINE_REFRESH=1 bench_check` re-pins the committed baselines
//! from the fresh artifacts instead of checking (run the smokes first).

use bmqsim::bench_harness::check::{append_history, refresh, run, CheckConfig, DEFAULT_TOLERANCE};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    match real_main() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("bench_check: {e}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> Result<ExitCode, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = CheckConfig::new(".", "bench_baselines");
    cfg.tolerance = DEFAULT_TOLERANCE;
    let mut history: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--append-history" => {
                // Optional value: a following non-flag .jsonl arg names the
                // history file; otherwise the committed default is used.
                match args.get(i + 1) {
                    Some(v) if v.ends_with(".jsonl") => {
                        history = Some(v.into());
                        i += 2;
                    }
                    _ => {
                        history = Some("bench_history.jsonl".into());
                        i += 1;
                    }
                }
            }
            "--baselines" => {
                cfg.baseline_dir =
                    args.get(i + 1).ok_or("missing value for --baselines")?.into();
                i += 2;
            }
            "--fresh" => {
                cfg.fresh_dir = args.get(i + 1).ok_or("missing value for --fresh")?.into();
                i += 2;
            }
            "--tolerance" => {
                let v = args.get(i + 1).ok_or("missing value for --tolerance")?;
                cfg.tolerance =
                    v.parse().map_err(|_| format!("bad value for --tolerance: {v:?}"))?;
                i += 2;
            }
            "--help" | "-h" => {
                println!(
                    "bench_check [--baselines <dir>] [--fresh <dir>] [--tolerance <t>] \
                     [--append-history [<file>]] [FILE...]"
                );
                return Ok(ExitCode::SUCCESS);
            }
            flag if flag.starts_with("--") => {
                return Err(format!("unknown flag {flag:?}"));
            }
            file => {
                cfg.required.push(file.to_string());
                i += 1;
            }
        }
    }

    if matches!(std::env::var("BENCH_BASELINE_REFRESH"), Ok(v) if !v.is_empty() && v != "0") {
        let n = refresh(&cfg)?;
        println!(
            "re-pinned {n} baseline(s) into {} — commit them to move the gate",
            cfg.baseline_dir.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let report = run(&cfg)?;
    for note in &report.notes {
        println!("note: {note}");
    }
    for finding in &report.findings {
        println!("{finding}");
    }
    let failures = report.failures();
    if failures > 0 {
        eprintln!(
            "bench_check: {failures} gated metric(s) regressed beyond {:.0}% \
             (intentional? re-pin with BENCH_BASELINE_REFRESH=1)",
            100.0 * cfg.tolerance
        );
        return Ok(ExitCode::FAILURE);
    }
    println!(
        "bench_check: {} artifact(s) checked, {} metric(s) within {:.0}% of baseline",
        report.checked_files,
        report.findings.len(),
        100.0 * cfg.tolerance
    );
    if let Some(path) = &history {
        let n = append_history(&cfg, path)?;
        println!("bench_check: appended {n} line(s) to {}", path.display());
    }
    Ok(ExitCode::SUCCESS)
}
