use bmqsim::compress::{decompress_any, Codec};
use bmqsim::types::SplitMix64;
fn main() {
    let mut rng = SplitMix64::new(7);
    let plen = 1 << 20;
    let dense: Vec<f64> = (0..plen).map(|_| rng.next_gaussian() * 1e-2).collect();
    let codec = Codec::pointwise(1e-3);
    let enc = codec.compress(&dense).unwrap();
    for _ in 0..12 {
        let _ = std::hint::black_box(codec.compress(&dense).unwrap());
        let _ = std::hint::black_box(decompress_any(&enc).unwrap());
    }
}
