//! Quick local triage for stage application: profile fused-batched vs
//! per-gate scalar gate application at configurable qubit counts.
//!
//! ```text
//! profile_gates [--qubits N] [--depth D] [--mode fused|unfused|both]
//!               [--tile-bits T] [--workers W] [--max-k K]
//!               [--circuit qft|layers] [--reps R] [--seed S]
//! ```
//!
//! Defaults: 20 qubits, qft circuit, both modes, tile 15, 1 worker,
//! k = 3, 2 reps. Prints ms/pass and Mamp/s per mode so a perf
//! regression bisects in one command (`perf_gates` is the recorded
//! benchmark; this is the knob-turning tool).

use bmqsim::bench_harness::time_it;
use bmqsim::circuit::fusion::fuse_gates;
use bmqsim::circuit::{generators, Circuit};
use bmqsim::gates::fused::stage_sweeps;
use bmqsim::gates::{apply_gate, apply_stage};
use bmqsim::types::SplitMix64;

fn parse_flag<T: std::str::FromStr>(args: &[String], key: &str, default: T) -> T {
    let Some(i) = args.iter().position(|a| a == key) else {
        return default;
    };
    let Some(v) = args.get(i + 1) else {
        eprintln!("missing value for {key}");
        std::process::exit(2);
    };
    v.parse().unwrap_or_else(|_| {
        eprintln!("bad value for {key}: {v:?}");
        std::process::exit(2);
    })
}

fn layered_circuit(n: usize, depth: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "layers");
    for _ in 0..depth {
        for q in 0..n {
            c.u3(rng.next_f64(), 0.2, -0.4, q);
        }
        for q in 0..n - 1 {
            if q % 2 == 0 {
                c.cx(q, q + 1);
            } else {
                c.cp(rng.next_f64(), q, q + 1);
            }
        }
    }
    c
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = parse_flag(&args, "--qubits", 20);
    let depth: usize = parse_flag(&args, "--depth", 4);
    let tile_bits: usize = parse_flag(&args, "--tile-bits", 15);
    let workers: usize = parse_flag(&args, "--workers", 1);
    let max_k: usize = parse_flag(&args, "--max-k", 3);
    let reps: usize = parse_flag(&args, "--reps", 2);
    let seed: u64 = parse_flag(&args, "--seed", 7u64);
    let mode: String = parse_flag(&args, "--mode", "both".to_string());
    let circuit: String = parse_flag(&args, "--circuit", "qft".to_string());

    if !matches!(mode.as_str(), "both" | "fused" | "unfused") {
        eprintln!("unknown --mode {mode:?} (fused|unfused|both)");
        std::process::exit(2);
    }
    let c = match circuit.as_str() {
        "qft" => generators::qft(n),
        "layers" => layered_circuit(n, depth, seed),
        other => {
            eprintln!("unknown --circuit {other:?} (qft|layers)");
            std::process::exit(2);
        }
    };
    let len = 1usize << n;
    let mut rng = SplitMix64::new(seed);
    let re0: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let im0: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
    let amps = (len as f64) * (c.gates.len() as f64);
    println!(
        "circuit {} — n={n}, {} gates, plane 2^{n} amps, tile 2^{tile_bits}, {workers} worker(s)",
        c.name,
        c.gates.len()
    );

    let mut re = re0.clone();
    let mut im = im0.clone();
    let mut timed = |label: &str, f: &mut dyn FnMut(&mut [f64], &mut [f64])| {
        let secs = time_it(reps, || {
            re.copy_from_slice(&re0);
            im.copy_from_slice(&im0);
            f(re.as_mut_slice(), im.as_mut_slice());
        });
        println!(
            "  {label:<18} {:>9.2} ms/pass   {:>9.1} Mamp/s",
            secs * 1e3,
            amps / secs / 1e6
        );
        secs
    };

    let mut unfused_secs = None;
    if mode == "both" || mode == "unfused" {
        unfused_secs = Some(timed("per-gate scalar", &mut |re, im| {
            for g in &c.gates {
                apply_gate(re, im, g);
            }
        }));
    }
    if mode == "both" || mode == "fused" {
        let ops = fuse_gates(&c.gates, max_k);
        println!(
            "  fusion: {} gates -> {} ops, {} sweeps (k<={max_k})",
            c.gates.len(),
            ops.len(),
            stage_sweeps(&ops, n, tile_bits)
        );
        let fused_secs = timed("fused batched", &mut |re, im| {
            apply_stage(re, im, &ops, tile_bits, workers);
        });
        if let Some(u) = unfused_secs {
            println!("  speedup            {:>9.2}x", u / fused_secs);
        }
    }
}
