//! Gate fusion: merge runs of consecutive gates whose combined support
//! fits `k <= 3` qubits into one dense `2^k x 2^k` unitary.
//!
//! Rationale (§Perf): after the zero-allocation refactor the group-chain
//! hot path is dominated by gate application, and `gates/apply.rs` walks
//! the whole plane once *per gate*. A fused run costs ONE walk for its
//! whole gate sequence, so the sweep count per stage drops by the fusion
//! factor — the same amortization Algorithm 1 buys for (de)compression,
//! applied one level down. SC19 ("Full-State Quantum Circuit Simulation
//! by Using Data Compression") reports the update step is
//! memory-bandwidth-bound, so fewer sweeps translate directly to time.
//!
//! Fusion rules:
//! * gates merge **in circuit order** — a gate joins the current run iff
//!   the union of supports stays within the `k` limit; no commuting-based
//!   reordering is attempted, so runs are always contiguous subsequences
//!   and the fused product is exactly the sequential product;
//! * `k` is capped at [`MAX_FUSED_QUBITS`] (= 3): an 8x8 matvec per octet
//!   still fits registers, while `k = 4` would already touch 16 amplitudes
//!   per site and stop vectorizing well;
//! * a single gate always forms a (trivial) `FusedGate`, even when the
//!   `max_k` knob is below its arity — fusion never splits a gate.
//!
//! Matrix basis convention: support bits are sorted ascending and basis
//! bit `j` of a matrix index corresponds to support bit `bits[j]`, i.e.
//! `bits[0]` is the matrix LSB. (Note this differs from
//! [`Gate::matrix2q`], whose basis puts `qubits[0]` in the HIGH bit; the
//! constructors permute accordingly.)

use super::Gate;
use crate::types::Complex;

/// Hard cap on the fused-unitary width `k`.
pub const MAX_FUSED_QUBITS: usize = 3;

/// A dense `2^k x 2^k` unitary over `k <= 3` support bits — the unit of
/// batched gate application ([`crate::gates::fused`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FusedGate {
    /// Sorted, distinct buffer bit positions of the support (ascending).
    bits: Vec<usize>,
    /// Row-major `2^k x 2^k` unitary; basis bit `j` <-> `bits[j]`.
    mat: Vec<Complex>,
    /// How many original circuit gates were merged into this op.
    source_gates: usize,
}

impl FusedGate {
    /// Wrap a single gate, with its targets already remapped to buffer
    /// bit positions (`bits[i]` is the buffer bit of `gate.targets()[i]`).
    pub fn from_gate(gate: &Gate, bits: &[usize]) -> FusedGate {
        debug_assert_eq!(bits.len(), gate.arity());
        match gate.arity() {
            1 => FusedGate {
                bits: vec![bits[0]],
                mat: gate.matrix1q().to_vec(),
                source_gates: 1,
            },
            _ => {
                let (pa, pb) = (bits[0], bits[1]);
                debug_assert_ne!(pa, pb);
                let support = if pa < pb { vec![pa, pb] } else { vec![pb, pa] };
                // matrix2q basis: bit 1 <-> qubits[0] (buffer bit pa),
                // bit 0 <-> qubits[1] (buffer bit pb).
                let pos = [
                    support.iter().position(|&b| b == pb).unwrap(),
                    support.iter().position(|&b| b == pa).unwrap(),
                ];
                let mat = embed(&gate.matrix2q(), &pos, support.len());
                FusedGate { bits: support, mat, source_gates: 1 }
            }
        }
    }

    /// Support width `k`.
    pub fn k(&self) -> usize {
        self.bits.len()
    }

    /// Matrix dimension `2^k`.
    pub fn dim(&self) -> usize {
        1usize << self.bits.len()
    }

    /// Sorted support bit positions.
    pub fn bits(&self) -> &[usize] {
        &self.bits
    }

    /// Row-major `2^k x 2^k` unitary (basis bit `j` <-> `bits[j]`).
    pub fn matrix(&self) -> &[Complex] {
        &self.mat
    }

    /// Highest support bit — decides tile locality in the batched kernel.
    pub fn max_bit(&self) -> usize {
        *self.bits.last().expect("fused gate has non-empty support")
    }

    /// Number of original gates folded into this op.
    pub fn source_gates(&self) -> usize {
        self.source_gates
    }

    /// Try to fold `gate` (applied AFTER this op) into the product. Fails
    /// (without modifying `self`) when the union support would exceed
    /// `max_k` bits.
    pub fn try_absorb(&mut self, gate: &Gate, bits: &[usize], max_k: usize) -> bool {
        let mut union = self.bits.clone();
        for &b in bits {
            if let Err(pos) = union.binary_search(&b) {
                union.insert(pos, b);
            }
        }
        if union.len() > max_k {
            return false;
        }
        let dim = 1usize << union.len();
        let cur = if union == self.bits {
            std::mem::take(&mut self.mat)
        } else {
            let pos: Vec<usize> =
                self.bits.iter().map(|b| union.binary_search(b).unwrap()).collect();
            embed(&self.mat, &pos, union.len())
        };
        let g = FusedGate::from_gate(gate, bits);
        let gpos: Vec<usize> =
            g.bits.iter().map(|b| union.binary_search(b).unwrap()).collect();
        let gm = embed(&g.mat, &gpos, union.len());
        // `gate` acts after the accumulated run: v' = G (M v) = (G M) v.
        self.mat = matmul(&gm, &cur, dim);
        self.bits = union;
        self.source_gates += 1;
        true
    }
}

/// Expand `m` (a matrix over `pos.len()` basis bits) onto a `2^k` space:
/// matrix-basis bit `i` sits at target-basis bit `pos[i]`; bits outside
/// `pos` are untouched (identity).
fn embed(m: &[Complex], pos: &[usize], k: usize) -> Vec<Complex> {
    let sm = pos.len();
    let dm = 1usize << sm;
    debug_assert_eq!(m.len(), dm * dm);
    let dim = 1usize << k;
    let mut mask = 0usize;
    for &p in pos {
        mask |= 1 << p;
    }
    let gather = |idx: usize| -> usize {
        let mut s = 0usize;
        for (i, &p) in pos.iter().enumerate() {
            if (idx >> p) & 1 == 1 {
                s |= 1 << i;
            }
        }
        s
    };
    let mut out = vec![Complex::ZERO; dim * dim];
    for r in 0..dim {
        for c in 0..dim {
            if (r & !mask) == (c & !mask) {
                out[r * dim + c] = m[gather(r) * dm + gather(c)];
            }
        }
    }
    out
}

/// Row-major `dim x dim` complex matrix product `a * b`.
fn matmul(a: &[Complex], b: &[Complex], dim: usize) -> Vec<Complex> {
    let mut out = vec![Complex::ZERO; dim * dim];
    for r in 0..dim {
        for c in 0..dim {
            let mut acc = Complex::ZERO;
            for t in 0..dim {
                acc += a[r * dim + t] * b[t * dim + c];
            }
            out[r * dim + c] = acc;
        }
    }
    out
}

fn fuse_inner<'a, I>(items: I, max_k: usize) -> Vec<FusedGate>
where
    I: Iterator<Item = (&'a Gate, &'a [usize])>,
{
    let max_k = max_k.clamp(1, MAX_FUSED_QUBITS);
    let mut out: Vec<FusedGate> = Vec::new();
    for (gate, bits) in items {
        let absorbed = match out.last_mut() {
            Some(cur) => cur.try_absorb(gate, bits, max_k),
            None => false,
        };
        if !absorbed {
            out.push(FusedGate::from_gate(gate, bits));
        }
    }
    out
}

/// Fuse a gate list whose targets are already buffer bit positions (the
/// SV-group path: `bits` come from `GroupSchedule::buffer_bit`).
pub fn fuse_remapped(gates: &[(Gate, Vec<usize>)], max_k: usize) -> Vec<FusedGate> {
    fuse_inner(gates.iter().map(|(g, b)| (g, b.as_slice())), max_k)
}

/// Fuse a gate list in absolute-qubit space (dense-plane semantics).
pub fn fuse_gates(gates: &[Gate], max_k: usize) -> Vec<FusedGate> {
    fuse_inner(gates.iter().map(|g| (g, g.targets())), max_k)
}

/// Fusion tally for a gate list: `(fused_ops, gate_merges)` where
/// `gate_merges = gates - fused_ops` is the number of plane sweeps the
/// fusion pass removes.
pub fn fusion_summary(gates: &[Gate], max_k: usize) -> (usize, usize) {
    let ops = fuse_gates(gates, max_k).len();
    (ops, gates.len() - ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{Circuit, GateKind};
    use crate::types::SplitMix64;

    /// Reference: apply `op` to a dense state by brute-force expansion.
    fn apply_fused_ref(re: &mut [f64], im: &mut [f64], op: &FusedGate) {
        let len = re.len();
        let dim = op.dim();
        let m = op.matrix();
        let mask: usize = op.bits().iter().map(|&b| 1usize << b).sum();
        let mut out_re = vec![0.0; len];
        let mut out_im = vec![0.0; len];
        for out in 0..len {
            let mut r = 0usize;
            for (j, &b) in op.bits().iter().enumerate() {
                if (out >> b) & 1 == 1 {
                    r |= 1 << j;
                }
            }
            for s in 0..dim {
                let mut input = out & !mask;
                for (j, &b) in op.bits().iter().enumerate() {
                    if (s >> j) & 1 == 1 {
                        input |= 1 << b;
                    }
                }
                let c = m[r * dim + s];
                out_re[out] += c.re * re[input] - c.im * im[input];
                out_im[out] += c.re * im[input] + c.im * re[input];
            }
        }
        re.copy_from_slice(&out_re);
        im.copy_from_slice(&out_im);
    }

    fn random_planes(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let len = 1usize << n;
        (
            (0..len).map(|_| rng.next_gaussian()).collect(),
            (0..len).map(|_| rng.next_gaussian()).collect(),
        )
    }

    fn random_circuit(n: usize, depth: usize, seed: u64) -> Circuit {
        use GateKind::*;
        let mut rng = SplitMix64::new(seed);
        let mut c = Circuit::new(n, "rand");
        for _ in 0..depth {
            let q = (rng.next_u64() as usize) % n;
            let p = {
                let mut p = (rng.next_u64() as usize) % n;
                while p == q {
                    p = (rng.next_u64() as usize) % n;
                }
                p
            };
            let theta = rng.next_f64() * 2.0 - 1.0;
            let gate = match rng.next_u64() % 8 {
                0 => Gate::q1(H, q).unwrap(),
                1 => Gate::q1(X, q).unwrap(),
                2 => Gate::q1(Rz(theta), q).unwrap(),
                3 => Gate::q1(U3(theta, 0.4, -0.2), q).unwrap(),
                4 => Gate::q2(Cx, q, p).unwrap(),
                5 => Gate::q2(Cp(theta), q, p).unwrap(),
                6 => Gate::q2(Rxx(theta), q, p).unwrap(),
                _ => Gate::q2(Swap, q, p).unwrap(),
            };
            c.push(gate).unwrap();
        }
        c
    }

    #[test]
    fn single_gate_wrapping_matches_per_gate_kernels() {
        use GateKind::*;
        let n = 4;
        let kinds_1q =
            [X, Y, Z, H, S, T, Sx, Rx(0.7), Ry(-0.4), Rz(1.9), P(0.33), U3(0.3, 1.2, -0.8)];
        for t in 0..n {
            for (ki, kind) in kinds_1q.iter().enumerate() {
                let gate = Gate::q1(*kind, t).unwrap();
                let op = FusedGate::from_gate(&gate, gate.targets());
                assert_eq!(op.k(), 1);
                assert_eq!(op.bits(), &[t]);
                let (mut re, mut im) = random_planes(n, (t * 100 + ki) as u64);
                let (mut re2, mut im2) = (re.clone(), im.clone());
                crate::gates::apply_gate(&mut re, &mut im, &gate);
                apply_fused_ref(&mut re2, &mut im2, &op);
                for i in 0..re.len() {
                    assert!((re[i] - re2[i]).abs() < 1e-12 && (im[i] - im2[i]).abs() < 1e-12);
                }
            }
        }
        let kinds_2q = [Cx, Cy, Cz, Swap, Cp(0.9), Crx(0.5), Cry(-1.1), Rxx(0.6), Rzz(-0.3)];
        for qa in 0..n {
            for qb in 0..n {
                if qa == qb {
                    continue;
                }
                for (ki, kind) in kinds_2q.iter().enumerate() {
                    let gate = Gate::q2(*kind, qa, qb).unwrap();
                    let op = FusedGate::from_gate(&gate, gate.targets());
                    assert_eq!(op.k(), 2);
                    assert_eq!(op.bits(), &[qa.min(qb), qa.max(qb)]);
                    let (mut re, mut im) =
                        random_planes(n, (qa * 1000 + qb * 100 + ki) as u64);
                    let (mut re2, mut im2) = (re.clone(), im.clone());
                    crate::gates::apply_gate(&mut re, &mut im, &gate);
                    apply_fused_ref(&mut re2, &mut im2, &op);
                    for i in 0..re.len() {
                        assert!(
                            (re[i] - re2[i]).abs() < 1e-12 && (im[i] - im2[i]).abs() < 1e-12,
                            "{kind:?} ({qa},{qb}) amp {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_product_equals_sequential_application() {
        for seed in 0..6u64 {
            let n = 5;
            let c = random_circuit(n, 40, seed);
            for max_k in 1..=3usize {
                let ops = fuse_gates(&c.gates, max_k);
                // Reference: per-gate application.
                let (mut re_ref, mut im_ref) = random_planes(n, seed + 77);
                let (mut re, mut im) = (re_ref.clone(), im_ref.clone());
                for g in &c.gates {
                    crate::gates::apply_gate(&mut re_ref, &mut im_ref, g);
                }
                for op in &ops {
                    apply_fused_ref(&mut re, &mut im, op);
                }
                for i in 0..re.len() {
                    assert!(
                        (re[i] - re_ref[i]).abs() < 1e-12 && (im[i] - im_ref[i]).abs() < 1e-12,
                        "seed {seed} max_k {max_k} amp {i}"
                    );
                }
                // Bookkeeping: every source gate accounted for exactly once.
                let total: usize = ops.iter().map(|o| o.source_gates()).sum();
                assert_eq!(total, c.gates.len());
                for op in &ops {
                    assert!(op.k() <= max_k.max(2), "op wider than limit");
                    assert!(op.bits().windows(2).all(|w| w[0] < w[1]));
                }
            }
        }
    }

    #[test]
    fn same_qubit_run_fuses_to_one_op() {
        let mut c = Circuit::new(4, "deep");
        for _ in 0..50 {
            c.t(2).h(2);
        }
        let ops = fuse_gates(&c.gates, 3);
        assert_eq!(ops.len(), 1);
        assert_eq!(ops[0].source_gates(), 100);
        assert_eq!(ops[0].bits(), &[2]);
    }

    #[test]
    fn k_limit_bounds_runs() {
        // Gates on disjoint qubit pairs: k=2 keeps them separate, k=3
        // cannot hold two disjoint 2q gates either (4 qubits), so only
        // overlapping pairs merge.
        let mut c = Circuit::new(6, "pairs");
        c.cx(0, 1).cx(2, 3).cx(4, 5);
        assert_eq!(fuse_gates(&c.gates, 2).len(), 3);
        assert_eq!(fuse_gates(&c.gates, 3).len(), 3);
        // Overlapping chain fits in 3 qubits pairwise.
        let mut c = Circuit::new(6, "chain");
        c.cx(0, 1).cx(1, 2).cx(0, 2);
        assert_eq!(fuse_gates(&c.gates, 3).len(), 1);
        assert_eq!(fuse_gates(&c.gates, 2).len(), 3);
    }

    #[test]
    fn max_k_one_still_admits_two_qubit_gates() {
        let mut c = Circuit::new(4, "mk1");
        c.h(0).h(0).cx(0, 1).rz(0.5, 1);
        let ops = fuse_gates(&c.gates, 1);
        // h+h fuse (k=1); cx stands alone (k=2 allowed as a single gate);
        // rz cannot join the cx (union still 2 > 1).
        assert_eq!(ops.len(), 3);
        assert_eq!(ops[0].source_gates(), 2);
        assert_eq!(ops[1].k(), 2);
    }

    #[test]
    fn fused_matrices_stay_unitary() {
        let c = random_circuit(5, 60, 9);
        for op in fuse_gates(&c.gates, 3) {
            let dim = op.dim();
            let m = op.matrix();
            for r1 in 0..dim {
                for r2 in 0..dim {
                    let mut acc = Complex::ZERO;
                    for t in 0..dim {
                        acc += m[r1 * dim + t] * m[r2 * dim + t].conj();
                    }
                    let want = if r1 == r2 { Complex::ONE } else { Complex::ZERO };
                    assert!(acc.approx_eq(want, 1e-10), "row pair ({r1},{r2})");
                }
            }
        }
    }

    #[test]
    fn summary_counts_merges() {
        let mut c = Circuit::new(4, "sum");
        c.h(0).t(0).h(1).cx(0, 1);
        let (ops, merges) = fusion_summary(&c.gates, 3);
        // h0+t0 fuse; h1 joins {0,1}? h1 -> union {0} u {1} = 2 <= 3: all
        // four gates collapse into one op.
        assert_eq!(ops, 1);
        assert_eq!(merges, 3);
    }
}
