//! The paper's Algorithm 1: optimal-compression circuit partitioning (§4.1).
//!
//! Given a block size of `2^b` amplitudes, qubit indices `< b` are *local*
//! (pairs live inside one SV block) and indices `>= b` are *global* (pairs
//! span blocks, Fig. 2). The partitioner walks the gate list greedily,
//! accumulating gates into the current *stage* until the set of distinct
//! global indices targeted by the stage would exceed `inner_size`; it then
//! seals the stage and starts a new one.
//!
//! Within a stage, the targeted global indices are its **inner** indices.
//! The SV blocks whose global-index bits agree on all *outer* (non-inner)
//! positions form an **SV group** of `2^|inner|` blocks (Fig. 4/5): every
//! amplitude pair any stage gate needs lies inside one group, so the whole
//! stage costs ONE decompression + ONE compression per group — the
//! mechanism behind the paper's 2673-gates -> 28-stages reduction on
//! 33-qubit QFT.

use super::fusion::{self, FusedGate};
use super::{Circuit, Gate};
use crate::types::{Error, Result};

/// One stage of the partitioned circuit.
#[derive(Debug, Clone)]
pub struct Stage {
    /// Gates of this stage, in original circuit order.
    pub gates: Vec<Gate>,
    /// Sorted distinct global qubit indices targeted by `gates`
    /// (absolute qubit numbers, each `>= block_qubits`).
    pub inner: Vec<usize>,
}

impl Stage {
    /// Number of SV blocks per SV group for this stage: `2^|inner|`.
    pub fn group_blocks(&self) -> usize {
        1usize << self.inner.len()
    }

    /// The stage's gate list fused into `k <= max_k` dense unitaries, in
    /// absolute-qubit space (see [`fusion`]). Engines that gather SV
    /// groups fuse the *remapped* gate list instead
    /// ([`fusion::fuse_remapped`]); this view serves dense execution and
    /// sweep-count planning.
    pub fn fused_ops(&self, max_k: usize) -> Vec<FusedGate> {
        fusion::fuse_gates(&self.gates, max_k)
    }
}

/// The output of Algorithm 1 plus the geometry it was computed for.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub stages: Vec<Stage>,
    /// `b`: qubits resolved inside one SV block (block = `2^b` amplitudes).
    pub block_qubits: usize,
    /// Configured cap on distinct global (inner) indices per stage.
    pub inner_size: usize,
    pub n_qubits: usize,
}

impl PartitionPlan {
    /// `c = n - b`: number of global index bits.
    pub fn global_qubits(&self) -> usize {
        self.n_qubits.saturating_sub(self.block_qubits)
    }

    /// Total number of SV blocks: `2^c`.
    pub fn total_blocks(&self) -> usize {
        1usize << self.global_qubits()
    }

    /// (De)compression operations implied by the plan: one compress + one
    /// decompress per stage (per group, but groups tile the state exactly
    /// once). Compare against `gates.len()` for the per-gate baseline.
    pub fn compression_rounds(&self) -> usize {
        self.stages.len()
    }

    /// Number of SV groups in `stage` (groups partition the block set).
    pub fn groups_in_stage(&self, stage: &Stage) -> usize {
        1usize << (self.global_qubits() - stage.inner.len())
    }

    /// Plan-wide fusion tally at width `max_k`: `(fused_ops, gate_merges)`
    /// summed over stages. `gate_merges` is the number of plane sweeps the
    /// fusion pass removes relative to per-gate application — compare
    /// against `total gates` the way [`Self::compression_rounds`] compares
    /// against the gate-wise (de)compression count.
    pub fn fusion_summary(&self, max_k: usize) -> (usize, usize) {
        let mut ops = 0usize;
        let mut merges = 0usize;
        for stage in &self.stages {
            let (o, m) = fusion::fusion_summary(&stage.gates, max_k);
            ops += o;
            merges += m;
        }
        (ops, merges)
    }
}

/// Algorithm 1 (paper §4.1). `inner_size` is clamped to `>= 2` (Line 3:
/// a double-qubit gate may target two global indices at once) and to the
/// number of global bits available.
pub fn partition_circuit(
    circuit: &Circuit,
    block_qubits: usize,
    inner_size: usize,
) -> Result<PartitionPlan> {
    if block_qubits > circuit.n_qubits {
        return Err(Error::Config(format!(
            "block_qubits {} exceeds circuit qubits {}",
            block_qubits, circuit.n_qubits
        )));
    }
    let global_bits = circuit.n_qubits - block_qubits;
    // Line 3: threshold = max(inner_size, 2), further clamped to the number
    // of global bits that actually exist (a stage can never target more).
    let threshold = inner_size.max(2).min(global_bits.max(2));

    let mut stages: Vec<Stage> = Vec::new();
    let mut cur_gates: Vec<Gate> = Vec::new();
    let mut cur_inner: Vec<usize> = Vec::new(); // sorted distinct globals

    for gate in &circuit.gates {
        // Query the global indices of [current stage + current gate].
        let mut merged = cur_inner.clone();
        for &q in gate.targets() {
            if q >= block_qubits {
                if let Err(pos) = merged.binary_search(&q) {
                    merged.insert(pos, q);
                }
            }
        }
        if merged.len() > threshold && !cur_gates.is_empty() {
            // Seal the current stage and start fresh with this gate.
            stages.push(Stage { gates: std::mem::take(&mut cur_gates), inner: std::mem::take(&mut cur_inner) });
            let mut fresh: Vec<usize> = Vec::new();
            for &q in gate.targets() {
                if q >= block_qubits {
                    if let Err(pos) = fresh.binary_search(&q) {
                        fresh.insert(pos, q);
                    }
                }
            }
            debug_assert!(fresh.len() <= threshold, "single gate exceeds threshold");
            cur_inner = fresh;
        } else {
            cur_inner = merged;
        }
        cur_gates.push(*gate);
    }
    if !cur_gates.is_empty() {
        stages.push(Stage { gates: cur_gates, inner: cur_inner });
    }

    Ok(PartitionPlan { stages, block_qubits, inner_size: threshold, n_qubits: circuit.n_qubits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators;

    fn check_invariants(c: &Circuit, plan: &PartitionPlan) {
        // 1. Every gate appears exactly once, in order.
        let flat: Vec<Gate> = plan.stages.iter().flat_map(|s| s.gates.clone()).collect();
        assert_eq!(flat.len(), c.gates.len());
        for (a, b) in flat.iter().zip(c.gates.iter()) {
            assert_eq!(a, b);
        }
        // 2. Per-stage inner sets are sorted, distinct, within threshold,
        //    and exactly the globals the stage's gates target.
        for s in &plan.stages {
            assert!(s.inner.windows(2).all(|w| w[0] < w[1]), "inner not sorted/distinct");
            assert!(
                s.inner.len() <= plan.inner_size,
                "stage inner {} > threshold {}",
                s.inner.len(),
                plan.inner_size
            );
            let mut want: Vec<usize> = s
                .gates
                .iter()
                .flat_map(|g| g.targets().iter().copied())
                .filter(|&q| q >= plan.block_qubits)
                .collect();
            want.sort_unstable();
            want.dedup();
            assert_eq!(s.inner, want);
        }
    }

    #[test]
    fn all_local_gates_make_one_stage() {
        let mut c = Circuit::new(8, "local");
        for q in 0..4 {
            c.h(q).rz(0.1, q);
        }
        c.cx(0, 1).cx(2, 3);
        let plan = partition_circuit(&c, 4, 2).unwrap();
        assert_eq!(plan.stages.len(), 1);
        assert!(plan.stages[0].inner.is_empty());
        check_invariants(&c, &plan);
    }

    #[test]
    fn global_gates_split_when_exceeding_threshold() {
        let mut c = Circuit::new(8, "global");
        // 4 global bits (4..8); threshold 2 → H on 4,5 in stage 1, 6,7 in stage 2.
        c.h(4).h(5).h(6).h(7);
        let plan = partition_circuit(&c, 4, 2).unwrap();
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].inner, vec![4, 5]);
        assert_eq!(plan.stages[1].inner, vec![6, 7]);
        check_invariants(&c, &plan);
    }

    #[test]
    fn threshold_minimum_is_two() {
        // inner_size=0 must still admit a 2-global double-qubit gate.
        let mut c = Circuit::new(6, "dq");
        c.cx(4, 5);
        let plan = partition_circuit(&c, 2, 0).unwrap();
        assert_eq!(plan.stages.len(), 1);
        assert_eq!(plan.stages[0].inner, vec![4, 5]);
    }

    #[test]
    fn repeated_global_target_does_not_grow_inner() {
        let mut c = Circuit::new(6, "rep");
        c.h(5).rz(0.3, 5).h(5).h(4);
        let plan = partition_circuit(&c, 2, 2).unwrap();
        assert_eq!(plan.stages.len(), 1);
        assert_eq!(plan.stages[0].inner, vec![4, 5]);
        check_invariants(&c, &plan);
    }

    #[test]
    fn qft_compression_round_reduction() {
        // Paper: 33-qubit QFT drops 2673 gate-wise rounds to 28 stages. The
        // reduction factor grows with block size (fewer global bits) and
        // inner size; reproduce the shape at laptop scale.
        let c = generators::qft(20);
        // c = 6 global bits, inner 4: strong reduction.
        let plan = partition_circuit(&c, 14, 4).unwrap();
        assert!(
            plan.compression_rounds() * 5 < c.len(),
            "stages {} not << gates {}",
            plan.compression_rounds(),
            c.len()
        );
        check_invariants(&c, &plan);
        // c = 4 global bits, inner 4: every global fits => exactly 1 stage.
        let plan = partition_circuit(&c, 16, 4).unwrap();
        assert_eq!(plan.compression_rounds(), 1);
        // Monotonicity: larger inner size never yields more stages.
        let s2 = partition_circuit(&c, 14, 2).unwrap().compression_rounds();
        let s3 = partition_circuit(&c, 14, 3).unwrap().compression_rounds();
        let s4 = partition_circuit(&c, 14, 4).unwrap().compression_rounds();
        assert!(s2 >= s3 && s3 >= s4, "{s2} {s3} {s4}");
    }

    #[test]
    fn group_geometry() {
        let mut c = Circuit::new(8, "geom");
        c.h(5).h(6);
        let plan = partition_circuit(&c, 4, 2).unwrap();
        let s = &plan.stages[0];
        assert_eq!(s.group_blocks(), 4); // 2^2 blocks per group
        assert_eq!(plan.total_blocks(), 16); // 2^4
        assert_eq!(plan.groups_in_stage(s), 4); // 16 / 4
    }

    #[test]
    fn stage_fusion_reduces_ops_on_qft() {
        let c = generators::qft(16);
        let plan = partition_circuit(&c, 12, 3).unwrap();
        let total: usize = plan.stages.iter().map(|s| s.gates.len()).sum();
        let (ops, merges) = plan.fusion_summary(3);
        assert_eq!(ops + merges, total);
        assert!(ops < total, "fusion merged nothing: {ops} ops over {total} gates");
        for s in &plan.stages {
            let fused = s.fused_ops(3);
            assert!(fused.len() <= s.gates.len());
            let sources: usize = fused.iter().map(|o| o.source_gates()).sum();
            assert_eq!(sources, s.gates.len());
        }
    }

    #[test]
    fn block_qubits_larger_than_n_rejected() {
        let c = Circuit::new(4, "bad");
        assert!(partition_circuit(&c, 5, 2).is_err());
    }

    #[test]
    fn all_benchmarks_partition_cleanly() {
        for name in generators::ALL {
            let c = generators::build(name, 12, 0xBEEF).unwrap();
            for (b, inner) in [(6, 2), (8, 3), (10, 2), (12, 2)] {
                let plan = partition_circuit(&c, b, inner).unwrap();
                check_invariants(&c, &plan);
            }
        }
    }
}
