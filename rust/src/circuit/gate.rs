//! The quantum gate set: kinds, parameters, and unitary matrices.
//!
//! Covers the gates emitted by the NWQBench-style circuit generators and
//! the OpenQASM-2 subset parser: 14 single-qubit and 10 double-qubit kinds.
//! Matrices are produced on demand as row-major [`Complex`] arrays; the
//! engines consume them via [`Gate::matrix1q`] / [`Gate::matrix2q`] or the
//! diagonal fast path ([`Gate::diagonal`]).

use crate::types::{Complex, Error, Result};

/// Gate kinds. One- and two-qubit; measurement is handled separately by the
/// engines (terminal sampling), as in the paper's simulators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GateKind {
    // --- single-qubit, parameter-free ---
    X,
    Y,
    Z,
    H,
    S,
    Sdg,
    T,
    Tdg,
    Sx,
    // --- single-qubit, parameterized ---
    Rx(f64),
    Ry(f64),
    Rz(f64),
    P(f64),
    U3(f64, f64, f64),
    // --- double-qubit ---
    Cx,
    Cy,
    Cz,
    Swap,
    Cp(f64),
    Crx(f64),
    Cry(f64),
    Crz(f64),
    Rxx(f64),
    Rzz(f64),
}

impl GateKind {
    /// Number of qubits the gate acts on (1 or 2).
    pub fn arity(self) -> usize {
        use GateKind::*;
        match self {
            X | Y | Z | H | S | Sdg | T | Tdg | Sx | Rx(_) | Ry(_) | Rz(_) | P(_)
            | U3(..) => 1,
            Cx | Cy | Cz | Swap | Cp(_) | Crx(_) | Cry(_) | Crz(_) | Rxx(_) | Rzz(_) => 2,
        }
    }

    /// True when the unitary is diagonal — these gates never mix
    /// amplitudes, enabling the element-wise fast path (no pair gather).
    pub fn is_diagonal(self) -> bool {
        use GateKind::*;
        matches!(self, Z | S | Sdg | T | Tdg | Rz(_) | P(_) | Cz | Cp(_) | Crz(_) | Rzz(_))
    }

    /// Canonical lowercase name (QASM style).
    pub fn name(self) -> &'static str {
        use GateKind::*;
        match self {
            X => "x",
            Y => "y",
            Z => "z",
            H => "h",
            S => "s",
            Sdg => "sdg",
            T => "t",
            Tdg => "tdg",
            Sx => "sx",
            Rx(_) => "rx",
            Ry(_) => "ry",
            Rz(_) => "rz",
            P(_) => "p",
            U3(..) => "u3",
            Cx => "cx",
            Cy => "cy",
            Cz => "cz",
            Swap => "swap",
            Cp(_) => "cp",
            Crx(_) => "crx",
            Cry(_) => "cry",
            Crz(_) => "crz",
            Rxx(_) => "rxx",
            Rzz(_) => "rzz",
        }
    }
}

/// A gate applied to specific qubit indices.
///
/// For two-qubit gates, `qubits[0]` is the control (where meaningful) and
/// `qubits[1]` the target; for symmetric gates (SWAP, RXX, RZZ, CZ) the
/// order is irrelevant physically but preserved for layout purposes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Gate {
    pub kind: GateKind,
    pub qubits: [usize; 2],
}

impl Gate {
    /// Single-qubit gate constructor.
    pub fn q1(kind: GateKind, q: usize) -> Result<Self> {
        if kind.arity() != 1 {
            return Err(Error::Circuit(format!("{} is not single-qubit", kind.name())));
        }
        Ok(Gate { kind, qubits: [q, usize::MAX] })
    }

    /// Double-qubit gate constructor (`a` control / first, `b` target / second).
    pub fn q2(kind: GateKind, a: usize, b: usize) -> Result<Self> {
        if kind.arity() != 2 {
            return Err(Error::Circuit(format!("{} is not double-qubit", kind.name())));
        }
        if a == b {
            return Err(Error::Circuit(format!(
                "{} control and target must differ (got {a})",
                kind.name()
            )));
        }
        Ok(Gate { kind, qubits: [a, b] })
    }

    pub fn arity(&self) -> usize {
        self.kind.arity()
    }

    /// The qubits this gate touches, in declaration order.
    pub fn targets(&self) -> &[usize] {
        &self.qubits[..self.arity()]
    }

    /// 2x2 unitary (row-major) for single-qubit kinds.
    pub fn matrix1q(&self) -> [Complex; 4] {
        use GateKind::*;
        let c = Complex::new;
        let z = Complex::ZERO;
        let one = Complex::ONE;
        let i = Complex::I;
        let frac = std::f64::consts::FRAC_1_SQRT_2;
        match self.kind {
            X => [z, one, one, z],
            Y => [z, -i, i, z],
            Z => [one, z, z, -one],
            H => [c(frac, 0.0), c(frac, 0.0), c(frac, 0.0), c(-frac, 0.0)],
            S => [one, z, z, i],
            Sdg => [one, z, z, -i],
            T => [one, z, z, Complex::cis(std::f64::consts::FRAC_PI_4)],
            Tdg => [one, z, z, Complex::cis(-std::f64::consts::FRAC_PI_4)],
            Sx => [
                c(0.5, 0.5),
                c(0.5, -0.5),
                c(0.5, -0.5),
                c(0.5, 0.5),
            ],
            Rx(t) => {
                let (ch, sh) = ((t / 2.0).cos(), (t / 2.0).sin());
                [c(ch, 0.0), c(0.0, -sh), c(0.0, -sh), c(ch, 0.0)]
            }
            Ry(t) => {
                let (ch, sh) = ((t / 2.0).cos(), (t / 2.0).sin());
                [c(ch, 0.0), c(-sh, 0.0), c(sh, 0.0), c(ch, 0.0)]
            }
            Rz(t) => [Complex::cis(-t / 2.0), z, z, Complex::cis(t / 2.0)],
            P(t) => [one, z, z, Complex::cis(t)],
            U3(theta, phi, lam) => {
                let (ch, sh) = ((theta / 2.0).cos(), (theta / 2.0).sin());
                [
                    c(ch, 0.0),
                    Complex::cis(lam).scale(-sh),
                    Complex::cis(phi).scale(sh),
                    Complex::cis(phi + lam).scale(ch),
                ]
            }
            _ => unreachable!("matrix1q on two-qubit gate {:?}", self.kind),
        }
    }

    /// 4x4 unitary (row-major) for double-qubit kinds, in the basis
    /// `|q_a q_b>` = `|00>, |01>, |10>, |11>` with `q_a = qubits[0]` the
    /// high bit.
    pub fn matrix2q(&self) -> [Complex; 16] {
        use GateKind::*;
        let z = Complex::ZERO;
        let one = Complex::ONE;
        let i = Complex::I;
        let mut m = [z; 16];
        let set = |m: &mut [Complex; 16], r: usize, cidx: usize, v: Complex| {
            m[r * 4 + cidx] = v;
        };
        match self.kind {
            Cx => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 3, one);
                set(&mut m, 3, 2, one);
            }
            Cy => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 3, -i);
                set(&mut m, 3, 2, i);
            }
            Cz => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 2, one);
                set(&mut m, 3, 3, -one);
            }
            Swap => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 2, one);
                set(&mut m, 2, 1, one);
                set(&mut m, 3, 3, one);
            }
            Cp(t) => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 2, one);
                set(&mut m, 3, 3, Complex::cis(t));
            }
            Crx(t) => {
                let (ch, sh) = ((t / 2.0).cos(), (t / 2.0).sin());
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 2, Complex::new(ch, 0.0));
                set(&mut m, 2, 3, Complex::new(0.0, -sh));
                set(&mut m, 3, 2, Complex::new(0.0, -sh));
                set(&mut m, 3, 3, Complex::new(ch, 0.0));
            }
            Cry(t) => {
                let (ch, sh) = ((t / 2.0).cos(), (t / 2.0).sin());
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 2, Complex::new(ch, 0.0));
                set(&mut m, 2, 3, Complex::new(-sh, 0.0));
                set(&mut m, 3, 2, Complex::new(sh, 0.0));
                set(&mut m, 3, 3, Complex::new(ch, 0.0));
            }
            Crz(t) => {
                set(&mut m, 0, 0, one);
                set(&mut m, 1, 1, one);
                set(&mut m, 2, 2, Complex::cis(-t / 2.0));
                set(&mut m, 3, 3, Complex::cis(t / 2.0));
            }
            Rxx(t) => {
                let (ch, sh) = ((t / 2.0).cos(), (t / 2.0).sin());
                let d = Complex::new(ch, 0.0);
                let o = Complex::new(0.0, -sh);
                set(&mut m, 0, 0, d);
                set(&mut m, 0, 3, o);
                set(&mut m, 1, 1, d);
                set(&mut m, 1, 2, o);
                set(&mut m, 2, 1, o);
                set(&mut m, 2, 2, d);
                set(&mut m, 3, 0, o);
                set(&mut m, 3, 3, d);
            }
            Rzz(t) => {
                let neg = Complex::cis(-t / 2.0);
                let pos = Complex::cis(t / 2.0);
                set(&mut m, 0, 0, neg);
                set(&mut m, 1, 1, pos);
                set(&mut m, 2, 2, pos);
                set(&mut m, 3, 3, neg);
            }
            _ => unreachable!("matrix2q on single-qubit gate {:?}", self.kind),
        }
        m
    }

    /// Diagonal entries when [`GateKind::is_diagonal`]; length 2 or 4.
    pub fn diagonal(&self) -> Vec<Complex> {
        debug_assert!(self.kind.is_diagonal());
        match self.arity() {
            1 => {
                let m = self.matrix1q();
                vec![m[0], m[3]]
            }
            _ => {
                let m = self.matrix2q();
                vec![m[0], m[5], m[10], m[15]]
            }
        }
    }
}

impl std::fmt::Display for Gate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        use GateKind::*;
        match self.kind {
            Rx(t) | Ry(t) | Rz(t) | P(t) | Cp(t) | Crx(t) | Cry(t) | Crz(t) | Rxx(t)
            | Rzz(t) => write!(f, "{}({:.4})", self.kind.name(), t)?,
            U3(a, b, c) => write!(f, "u3({a:.4},{b:.4},{c:.4})")?,
            _ => write!(f, "{}", self.kind.name())?,
        }
        write!(f, " {:?}", self.targets())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_unitary1q(m: &[Complex; 4]) -> bool {
        // m * m^dagger == I
        let dot = |r1: [Complex; 2], r2: [Complex; 2]| r1[0] * r2[0].conj() + r1[1] * r2[1].conj();
        let r0 = [m[0], m[1]];
        let r1 = [m[2], m[3]];
        dot(r0, r0).approx_eq(Complex::ONE, 1e-12)
            && dot(r1, r1).approx_eq(Complex::ONE, 1e-12)
            && dot(r0, r1).approx_eq(Complex::ZERO, 1e-12)
    }

    fn is_unitary2q(m: &[Complex; 16]) -> bool {
        for r1 in 0..4 {
            for r2 in 0..4 {
                let mut acc = Complex::ZERO;
                for k in 0..4 {
                    acc += m[r1 * 4 + k] * m[r2 * 4 + k].conj();
                }
                let want = if r1 == r2 { Complex::ONE } else { Complex::ZERO };
                if !acc.approx_eq(want, 1e-12) {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn all_1q_matrices_unitary() {
        use GateKind::*;
        for kind in [
            X, Y, Z, H, S, Sdg, T, Tdg, Sx, Rx(0.37), Ry(1.1), Rz(-2.2), P(0.9),
            U3(0.5, 1.5, -0.4),
        ] {
            let g = Gate::q1(kind, 0).unwrap();
            assert!(is_unitary1q(&g.matrix1q()), "{kind:?} not unitary");
        }
    }

    #[test]
    fn all_2q_matrices_unitary() {
        use GateKind::*;
        for kind in [
            Cx, Cy, Cz, Swap, Cp(0.7), Crx(1.3), Cry(-0.2), Crz(2.5), Rxx(0.8), Rzz(-1.6),
        ] {
            let g = Gate::q2(kind, 0, 1).unwrap();
            assert!(is_unitary2q(&g.matrix2q()), "{kind:?} not unitary");
        }
    }

    #[test]
    fn diagonal_flag_consistent_with_matrix() {
        use GateKind::*;
        for kind in [Z, S, Sdg, T, Tdg, Rz(0.3), P(1.2)] {
            let g = Gate::q1(kind, 0).unwrap();
            assert!(kind.is_diagonal());
            let m = g.matrix1q();
            assert!(m[1].approx_eq(Complex::ZERO, 0.0) && m[2].approx_eq(Complex::ZERO, 0.0));
            let d = g.diagonal();
            assert_eq!(d, vec![m[0], m[3]]);
        }
        for kind in [Cz, Cp(0.4), Crz(0.8), Rzz(1.0)] {
            let g = Gate::q2(kind, 0, 1).unwrap();
            assert!(kind.is_diagonal());
            let m = g.matrix2q();
            for r in 0..4 {
                for c in 0..4 {
                    if r != c {
                        assert!(m[r * 4 + c].approx_eq(Complex::ZERO, 0.0));
                    }
                }
            }
        }
    }

    #[test]
    fn sdg_is_s_inverse() {
        let s = Gate::q1(GateKind::S, 0).unwrap().matrix1q();
        let sdg = Gate::q1(GateKind::Sdg, 0).unwrap().matrix1q();
        // (s * sdg) == identity on diagonal entries
        assert!((s[3] * sdg[3]).approx_eq(Complex::ONE, 1e-15));
    }

    #[test]
    fn arity_validation() {
        assert!(Gate::q1(GateKind::Cx, 0).is_err());
        assert!(Gate::q2(GateKind::H, 0, 1).is_err());
        assert!(Gate::q2(GateKind::Cx, 3, 3).is_err());
    }

    #[test]
    fn rz_equals_p_up_to_global_phase() {
        let t = 0.83;
        let rz = Gate::q1(GateKind::Rz(t), 0).unwrap().matrix1q();
        let p = Gate::q1(GateKind::P(t), 0).unwrap().matrix1q();
        // rz = e^{-i t/2} * p
        let phase = Complex::cis(-t / 2.0);
        assert!(rz[0].approx_eq(phase * p[0], 1e-12));
        assert!(rz[3].approx_eq(phase * p[3], 1e-12));
    }
}
