//! OpenQASM 2.0 subset parser — enough to load NWQBench/QASMBench circuit
//! files: a single quantum register, the standard gate vocabulary, constant
//! arithmetic angle expressions (`pi/4`, `-3*pi/8`, `1.5707`), comments,
//! `barrier` (ignored) and `measure` (recorded count, not simulated
//! mid-circuit — the engines sample terminally, like the paper's
//! simulators).

use super::{Circuit, Gate, GateKind};
use crate::types::{Error, Result};

/// Parse OpenQASM-2 source text into a [`Circuit`].
pub fn parse(src: &str, name: impl Into<String>) -> Result<Circuit> {
    Parser::new(src).parse(name.into())
}

/// Parse a `.qasm` file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Circuit> {
    let src = std::fs::read_to_string(path)?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "qasm".to_string());
    parse(&src, name)
}

struct Parser<'a> {
    src: &'a str,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        Parser { src }
    }

    fn parse(&self, name: String) -> Result<Circuit> {
        let mut circuit: Option<Circuit> = None;
        let mut qreg_name = String::new();
        let mut measures = 0usize;

        for (lineno, raw) in self.src.lines().enumerate() {
            let line = lineno + 1;
            // Strip comments and whitespace; statements end with ';'.
            let code = raw.split("//").next().unwrap_or("").trim();
            if code.is_empty() {
                continue;
            }
            for stmt in code.split(';') {
                let stmt = stmt.trim();
                if stmt.is_empty() {
                    continue;
                }
                self.parse_stmt(stmt, line, &mut circuit, &mut qreg_name, &mut measures, &name)?;
            }
        }
        circuit.ok_or_else(|| Error::Qasm { line: 0, msg: "no qreg declaration found".into() })
    }

    #[allow(clippy::too_many_arguments)]
    fn parse_stmt(
        &self,
        stmt: &str,
        line: usize,
        circuit: &mut Option<Circuit>,
        qreg_name: &mut String,
        measures: &mut usize,
        name: &str,
    ) -> Result<()> {
        let err = |msg: String| Error::Qasm { line, msg };

        // Header / declarations / ignorables.
        if stmt.starts_with("OPENQASM") || stmt.starts_with("include") {
            return Ok(());
        }
        if let Some(rest) = stmt.strip_prefix("qreg") {
            let (rname, size) = parse_reg_decl(rest).map_err(|m| err(m))?;
            if circuit.is_some() {
                return Err(err("multiple qreg declarations unsupported".into()));
            }
            *qreg_name = rname;
            *circuit = Some(Circuit::new(size, name.to_string()));
            return Ok(());
        }
        if stmt.starts_with("creg") || stmt.starts_with("barrier") {
            return Ok(());
        }
        if stmt.starts_with("measure") {
            *measures += 1;
            return Ok(());
        }

        // Gate application: `name(params)? q[i] (, q[j])?`
        let c = circuit
            .as_mut()
            .ok_or_else(|| err("gate before qreg declaration".into()))?;
        // The head is `name` or `name(exprs...)`; parameter expressions may
        // contain spaces, so when a '(' opens before the first whitespace we
        // split after its matching ')'.
        let ws = stmt.find(char::is_whitespace).unwrap_or(stmt.len());
        let (head, args_str) = match stmt.find('(') {
            Some(open) if open < ws => {
                let close = stmt
                    .find(')')
                    .ok_or_else(|| err(format!("missing ) in {stmt:?}")))?;
                (&stmt[..=close], stmt[close + 1..].trim())
            }
            _ => {
                if ws == stmt.len() {
                    return Err(err(format!("malformed statement {stmt:?}")));
                }
                (&stmt[..ws], stmt[ws..].trim())
            }
        };
        let (gname, params) = parse_head(head, line)?;
        let qubits = parse_qubit_args(args_str, qreg_name, line)?;
        let gate = build_gate(&gname, &params, &qubits, line)?;
        c.push(gate)
            .map_err(|e| err(e.to_string()))?;
        Ok(())
    }
}

fn parse_reg_decl(rest: &str) -> std::result::Result<(String, usize), String> {
    // e.g. ` q[24]`
    let rest = rest.trim();
    let open = rest.find('[').ok_or("missing [ in qreg")?;
    let close = rest.find(']').ok_or("missing ] in qreg")?;
    let name = rest[..open].trim().to_string();
    let size: usize = rest[open + 1..close]
        .trim()
        .parse()
        .map_err(|_| "bad qreg size")?;
    if name.is_empty() || size == 0 {
        return Err("empty qreg name or zero size".into());
    }
    Ok((name, size))
}

/// Split `cp(pi/4)` into ("cp", [pi/4]).
fn parse_head(head: &str, line: usize) -> Result<(String, Vec<f64>)> {
    if let Some(open) = head.find('(') {
        let close = head
            .rfind(')')
            .ok_or(Error::Qasm { line, msg: format!("missing ) in {head:?}") })?;
        let gname = head[..open].to_string();
        let mut params = Vec::new();
        for expr in head[open + 1..close].split(',') {
            params.push(eval_expr(expr).map_err(|m| Error::Qasm { line, msg: m })?);
        }
        Ok((gname, params))
    } else {
        Ok((head.to_string(), Vec::new()))
    }
}

fn parse_qubit_args(args: &str, qreg: &str, line: usize) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in args.split(',') {
        let part = part.trim();
        let open = part
            .find('[')
            .ok_or(Error::Qasm { line, msg: format!("expected reg[idx], got {part:?}") })?;
        let close = part
            .find(']')
            .ok_or(Error::Qasm { line, msg: format!("missing ] in {part:?}") })?;
        let rname = part[..open].trim();
        if rname != qreg {
            return Err(Error::Qasm { line, msg: format!("unknown register {rname:?}") });
        }
        let idx: usize = part[open + 1..close]
            .trim()
            .parse()
            .map_err(|_| Error::Qasm { line, msg: format!("bad index in {part:?}") })?;
        out.push(idx);
    }
    Ok(out)
}

fn build_gate(gname: &str, params: &[f64], qubits: &[usize], line: usize) -> Result<Gate> {
    use GateKind::*;
    let err = |msg: String| Error::Qasm { line, msg };
    let p = |i: usize| -> Result<f64> {
        params
            .get(i)
            .copied()
            .ok_or_else(|| err(format!("{gname} missing parameter {i}")))
    };
    let q = |i: usize| -> Result<usize> {
        qubits
            .get(i)
            .copied()
            .ok_or_else(|| err(format!("{gname} missing qubit operand {i}")))
    };
    let kind = match gname {
        "x" => X,
        "y" => Y,
        "z" => Z,
        "h" => H,
        "s" => S,
        "sdg" => Sdg,
        "t" => T,
        "tdg" => Tdg,
        "sx" => Sx,
        "id" | "u0" => return Ok(Gate::q1(Rz(0.0), q(0)?)?), // identity as rz(0)
        "rx" => Rx(p(0)?),
        "ry" => Ry(p(0)?),
        "rz" => Rz(p(0)?),
        "p" | "u1" => P(p(0)?),
        "u2" => U3(std::f64::consts::FRAC_PI_2, p(0)?, p(1)?),
        "u3" | "u" => U3(p(0)?, p(1)?, p(2)?),
        "cx" | "CX" => Cx,
        "cy" => Cy,
        "cz" => Cz,
        "swap" => Swap,
        "cp" | "cu1" => Cp(p(0)?),
        "crx" => Crx(p(0)?),
        "cry" => Cry(p(0)?),
        "crz" => Crz(p(0)?),
        "rxx" => Rxx(p(0)?),
        "rzz" => Rzz(p(0)?),
        other => return Err(err(format!("unsupported gate {other:?}"))),
    };
    let g = match kind.arity() {
        1 => Gate::q1(kind, q(0)?)?,
        _ => Gate::q2(kind, q(0)?, q(1)?)?,
    };
    Ok(g)
}

/// Evaluate a constant angle expression: numbers, `pi`, unary minus, and
/// the binary operators `* / + -` with usual precedence, plus parentheses.
fn eval_expr(s: &str) -> std::result::Result<f64, String> {
    let tokens = tokenize(s)?;
    let (v, rest) = parse_add(&tokens)?;
    if !rest.is_empty() {
        return Err(format!("trailing tokens in expression {s:?}"));
    }
    Ok(v)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(f64),
    Op(char),
}

fn tokenize(s: &str) -> std::result::Result<Vec<Tok>, String> {
    let mut out = Vec::new();
    let b = s.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i] as char;
        if c.is_whitespace() {
            i += 1;
        } else if "+-*/()".contains(c) {
            out.push(Tok::Op(c));
            i += 1;
        } else if c.is_ascii_digit() || c == '.' {
            let start = i;
            while i < b.len() && ((b[i] as char).is_ascii_digit() || b[i] == b'.' || b[i] == b'e' || b[i] == b'E' || (i > start && (b[i] == b'+' || b[i] == b'-') && (b[i-1] == b'e' || b[i-1] == b'E'))) {
                i += 1;
            }
            let num: f64 = s[start..i].parse().map_err(|_| format!("bad number in {s:?}"))?;
            out.push(Tok::Num(num));
        } else if c.is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && (b[i] as char).is_ascii_alphanumeric() {
                i += 1;
            }
            match &s[start..i] {
                "pi" | "PI" => out.push(Tok::Num(std::f64::consts::PI)),
                other => return Err(format!("unknown identifier {other:?}")),
            }
        } else {
            return Err(format!("unexpected char {c:?} in {s:?}"));
        }
    }
    Ok(out)
}

fn parse_add(t: &[Tok]) -> std::result::Result<(f64, &[Tok]), String> {
    let (mut v, mut rest) = parse_mul(t)?;
    while let Some(Tok::Op(op @ ('+' | '-'))) = rest.first() {
        let (rhs, r) = parse_mul(&rest[1..])?;
        v = if *op == '+' { v + rhs } else { v - rhs };
        rest = r;
    }
    Ok((v, rest))
}

fn parse_mul(t: &[Tok]) -> std::result::Result<(f64, &[Tok]), String> {
    let (mut v, mut rest) = parse_atom(t)?;
    while let Some(Tok::Op(op @ ('*' | '/'))) = rest.first() {
        let (rhs, r) = parse_atom(&rest[1..])?;
        v = if *op == '*' { v * rhs } else { v / rhs };
        rest = r;
    }
    Ok((v, rest))
}

fn parse_atom(t: &[Tok]) -> std::result::Result<(f64, &[Tok]), String> {
    match t.first() {
        Some(Tok::Num(n)) => Ok((*n, &t[1..])),
        Some(Tok::Op('-')) => {
            let (v, rest) = parse_atom(&t[1..])?;
            Ok((-v, rest))
        }
        Some(Tok::Op('+')) => parse_atom(&t[1..]),
        Some(Tok::Op('(')) => {
            let (v, rest) = parse_add(&t[1..])?;
            match rest.first() {
                Some(Tok::Op(')')) => Ok((v, &rest[1..])),
                _ => Err("missing )".into()),
            }
        }
        other => Err(format!("unexpected token {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn parses_minimal_program() {
        let src = r#"
            OPENQASM 2.0;
            include "qelib1.inc";
            qreg q[3];
            creg c[3];
            h q[0];
            cx q[0], q[1];
            cx q[1], q[2];
            measure q[0] -> c[0];
        "#;
        let c = parse(src, "ghz").unwrap();
        assert_eq!(c.n_qubits, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.gates[0].kind, GateKind::H);
        assert_eq!(c.gates[1].kind, GateKind::Cx);
    }

    #[test]
    fn parses_parameterized_gates_and_pi_exprs() {
        let src = "qreg q[2]; rz(pi/4) q[0]; cp(-3*pi/8) q[1], q[0]; u3(0.1, pi, -pi/2) q[1];";
        let c = parse(src, "t").unwrap();
        match c.gates[0].kind {
            GateKind::Rz(t) => assert!((t - PI / 4.0).abs() < 1e-15),
            other => panic!("{other:?}"),
        }
        match c.gates[1].kind {
            GateKind::Cp(t) => assert!((t + 3.0 * PI / 8.0).abs() < 1e-15),
            other => panic!("{other:?}"),
        }
        match c.gates[2].kind {
            GateKind::U3(a, b, g) => {
                assert!((a - 0.1).abs() < 1e-15);
                assert!((b - PI).abs() < 1e-15);
                assert!((g + PI / 2.0).abs() < 1e-15);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comments_and_barriers_ignored() {
        let src = "// header\nqreg q[1]; // reg\nbarrier q; h q[0]; // gate";
        let c = parse(src, "t").unwrap();
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let src = "qreg q[2];\nfoo q[0];";
        match parse(src, "t") {
            Err(Error::Qasm { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected qasm error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_out_of_range_qubit() {
        let src = "qreg q[2]; x q[5];";
        assert!(parse(src, "t").is_err());
    }

    #[test]
    fn rejects_unknown_register() {
        let src = "qreg q[2]; x r[0];";
        assert!(parse(src, "t").is_err());
    }

    #[test]
    fn expr_evaluator_precedence() {
        assert!((eval_expr("1+2*3").unwrap() - 7.0).abs() < 1e-15);
        assert!((eval_expr("(1+2)*3").unwrap() - 9.0).absolute_diff_ok());
        assert!((eval_expr("pi/2/2").unwrap() - PI / 4.0).abs() < 1e-15);
        assert!((eval_expr("-pi").unwrap() + PI).abs() < 1e-15);
        assert!((eval_expr("2e-3").unwrap() - 0.002).abs() < 1e-18);
        assert!(eval_expr("foo").is_err());
        assert!(eval_expr("(1+2").is_err());
    }

    trait AbsDiffOk {
        fn absolute_diff_ok(&self) -> bool;
    }
    impl AbsDiffOk for f64 {
        fn absolute_diff_ok(&self) -> bool {
            self.abs() < 1e-15
        }
    }

    #[test]
    fn roundtrip_generated_circuit_via_qasm_text() {
        // Emit a tiny qasm program for qft(4) by hand and compare counts.
        let qft4 = crate::circuit::generators::qft(4);
        let mut src = String::from("qreg q[4];\n");
        for g in &qft4.gates {
            use GateKind::*;
            match g.kind {
                H => src.push_str(&format!("h q[{}];\n", g.qubits[0])),
                Cp(t) => src.push_str(&format!("cp({t}) q[{}], q[{}];\n", g.qubits[0], g.qubits[1])),
                Swap => src.push_str(&format!("swap q[{}], q[{}];\n", g.qubits[0], g.qubits[1])),
                other => panic!("unexpected {other:?}"),
            }
        }
        let parsed = parse(&src, "qft4").unwrap();
        assert_eq!(parsed.len(), qft4.len());
        for (a, b) in parsed.gates.iter().zip(qft4.gates.iter()) {
            assert_eq!(a.kind.name(), b.kind.name());
            assert_eq!(a.targets(), b.targets());
        }
    }
}
