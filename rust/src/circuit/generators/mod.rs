//! NWQBench-style benchmark circuit generators (paper §5.1).
//!
//! The paper evaluates eight algorithms from NWQBench: `cat_state`, `cc`,
//! `ising`, `qft`, `bv`, `qsvm`, `ghz_state`, and `qaoa`, with 23-33 qubits
//! and 24-3010 gates. These generators produce the same circuit families at
//! arbitrary qubit counts; parameterized circuits (ising/qaoa/qsvm/bv/cc)
//! draw their angles / hidden strings / graphs from a seeded [`SplitMix64`]
//! so every run is reproducible.
//!
//! The families span the compressibility spectrum the paper leans on:
//! sparse, clustered states (cat/ghz/bv: 400-700x ratios in Fig. 9) through
//! dense, featureless ones (qft/qaoa: ~10x).

use super::Circuit;
use crate::types::{Error, Result, SplitMix64};
use std::f64::consts::PI;

/// All benchmark names, in the paper's table order.
pub const ALL: [&str; 8] =
    ["cat_state", "cc", "ising", "qft", "bv", "qsvm", "ghz_state", "qaoa"];

/// Build a benchmark circuit by name.
pub fn build(name: &str, n_qubits: usize, seed: u64) -> Result<Circuit> {
    match name {
        "cat_state" => Ok(cat_state(n_qubits)),
        "cc" => Ok(cc(n_qubits, seed)),
        "ising" => Ok(ising(n_qubits, seed)),
        "qft" => Ok(qft_prepped(n_qubits, seed)),
        "bv" => Ok(bv(n_qubits, seed)),
        "qsvm" => Ok(qsvm(n_qubits, seed)),
        "ghz_state" => Ok(ghz_state(n_qubits)),
        "qaoa" => Ok(qaoa(n_qubits, seed)),
        // Not in `ALL` (it is not one of the paper's eight NWQBench
        // families): the deep-random workload used by the error-control
        // frontier bench and available for ad-hoc runs.
        "random" => Ok(random(n_qubits, seed)),
        other => Err(Error::Circuit(format!("unknown benchmark {other:?}"))),
    }
}

/// Cat state: `H` on qubit 0 then a fan-out of CNOTs from qubit 0.
/// Final state `(|0...0> + |1...1>)/sqrt(2)` — extremely compressible.
pub fn cat_state(n: usize) -> Circuit {
    let mut c = Circuit::new(n, "cat_state");
    c.h(0);
    for q in 1..n {
        c.cx(0, q);
    }
    c
}

/// GHZ state via a CNOT *chain* (same final state as `cat_state`, different
/// circuit structure: nearest-neighbour entangling pattern).
pub fn ghz_state(n: usize) -> Circuit {
    let mut c = Circuit::new(n, "ghz_state");
    c.h(0);
    for q in 1..n {
        c.cx(q - 1, q);
    }
    c
}

/// Bernstein-Vazirani with a seeded hidden bit-string. Qubit `n-1` is the
/// phase ancilla. The output state is a computational-basis state (plus
/// ancilla phase) — near-perfectly compressible, matching Fig. 9's `bv`.
pub fn bv(n: usize, seed: u64) -> Circuit {
    assert!(n >= 2, "bv needs >= 2 qubits");
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "bv");
    let anc = n - 1;
    c.x(anc).h(anc);
    for q in 0..anc {
        c.h(q);
    }
    for q in 0..anc {
        if rng.next_f64() < 0.5 {
            c.cx(q, anc);
        }
    }
    for q in 0..anc {
        c.h(q);
    }
    c
}

/// Counterfeit-coin problem (NWQBench `cc`): a one-query Deutsch-style
/// protocol. Query register `0..n-1` in superposition, balance-oracle marks
/// the counterfeit coin (seeded index) on the ancilla, then uncompute.
pub fn cc(n: usize, seed: u64) -> Circuit {
    assert!(n >= 2, "cc needs >= 2 qubits");
    let mut rng = SplitMix64::new(seed);
    let anc = n - 1;
    let fake = rng.next_below(anc as u64) as usize;
    let mut c = Circuit::new(n, "cc");
    for q in 0..anc {
        c.h(q);
    }
    // Oracle: the counterfeit coin flips the ancilla when weighed.
    c.cx(fake, anc);
    // Phase kickback setup + second weighing round.
    c.h(anc);
    c.cx(fake, anc);
    c.h(anc);
    for q in 0..anc {
        c.h(q);
    }
    c
}

/// Trotterized 1-D transverse-field Ising model: alternating `RZZ` layers
/// on nearest-neighbour bonds and `RX` field layers. Seeded couplings.
pub fn ising(n: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "ising");
    let steps = 3; // trotter steps; gate count ~ 3 * (2n)
    // random-ish but bounded angles, as in NWQBench's generated circuits
    let j: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64()).collect();
    let h_field: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64()).collect();
    let dt = 0.1;
    for _ in 0..steps {
        for q in 0..n.saturating_sub(1) {
            c.rzz(2.0 * j[q] * dt, q, q + 1);
        }
        for q in 0..n {
            c.rx(2.0 * h_field[q] * dt, q);
        }
    }
    c
}

/// QFT benchmark as evaluated: a seeded X-prep layer encoding a nonzero
/// basis state, then the exact QFT. Without the prep, every
/// controlled-phase is an identity on `|0...0>` and the circuit
/// degenerates to a trivially compressible uniform state — NWQBench's qft
/// programs likewise prepare an input pattern first.
pub fn qft_prepped(n: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "qft");
    let mut any = false;
    for q in 0..n {
        if rng.next_f64() < 0.5 {
            c.x(q);
            any = true;
        }
    }
    if !any {
        c.x(0);
    }
    let body = qft(n);
    for g in &body.gates {
        c.push(*g).unwrap();
    }
    c
}

/// Exact quantum Fourier transform: `H` + controlled-phase ladder + final
/// qubit-reversal SWAPs. Gate count `n(n+1)/2 + floor(n/2)`.
pub fn qft(n: usize) -> Circuit {
    let mut c = Circuit::new(n, "qft");
    for i in 0..n {
        c.h(i);
        for j in (i + 1)..n {
            let theta = PI / (1u64 << (j - i)) as f64;
            c.cp(theta, j, i);
        }
    }
    for i in 0..n / 2 {
        c.swap(i, n - 1 - i);
    }
    c
}

/// QSVM / ZZ-feature-map circuit (2 repetitions): `H` wall, per-qubit
/// phase encodings, and entangling `CX - P - CX` blocks on a line, with
/// seeded data angles. Highly entangling, low compressibility.
pub fn qsvm(n: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let x: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0 * PI).collect();
    let mut c = Circuit::new(n, "qsvm");
    for _rep in 0..2 {
        for q in 0..n {
            c.h(q);
            c.p(2.0 * x[q], q);
        }
        for q in 0..n.saturating_sub(1) {
            let phi = 2.0 * (PI - x[q]) * (PI - x[q + 1]);
            c.cx(q, q + 1);
            c.p(phi, q + 1);
            c.cx(q, q + 1);
        }
    }
    c
}

/// QAOA MaxCut ansatz on a seeded 3-regular-ish random graph, `p = 2`
/// layers: `H` wall, then per-layer `RZZ(gamma)` on edges + `RX(2 beta)`.
pub fn qaoa(n: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    // Random graph: ring + n/2 extra chords => ~1.5n edges, connected.
    let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let mut extra = 0;
    while extra < n / 2 {
        let a = rng.next_below(n as u64) as usize;
        let b = rng.next_below(n as u64) as usize;
        if a != b && !edges.contains(&(a.min(b), a.max(b))) && !edges.contains(&(a, b)) {
            edges.push((a.min(b), a.max(b)));
            extra += 1;
        }
    }
    let p_layers = 2;
    let mut c = Circuit::new(n, "qaoa");
    for q in 0..n {
        c.h(q);
    }
    for _layer in 0..p_layers {
        let gamma = rng.next_f64() * PI;
        let beta = rng.next_f64() * PI;
        for &(a, b) in &edges {
            if a != b {
                c.rzz(gamma, a, b);
            }
        }
        for q in 0..n {
            c.rx(2.0 * beta, q);
        }
    }
    c
}

/// Deep random circuit (the error-control stress workload): `n` brickwork
/// layers, each a seeded single-qubit rotation per qubit (`RX`/`P`/`H`)
/// followed by alternating-offset nearest-neighbour entanglers
/// (`CX`/`CP`). Gate count is `Θ(n²)`, so the staged partitioner yields a
/// genuinely deep stage sequence.
///
/// Deliberately no initial `H` wall: support spreads gradually and the
/// per-block amplitude mass stays nonuniform for the whole run, which is
/// the regime where amplitude-aware budget control pays off — early
/// near-empty blocks earn refunds that loosen every later stage's bounds.
pub fn random(n: usize, seed: u64) -> Circuit {
    let mut rng = SplitMix64::new(seed);
    let mut c = Circuit::new(n, "random");
    let layers = n.max(4);
    for layer in 0..layers {
        for q in 0..n {
            match rng.next_below(3) {
                0 => {
                    c.rx(rng.next_f64() * PI, q);
                }
                1 => {
                    c.p(rng.next_f64() * 2.0 * PI, q);
                }
                _ => {
                    c.h(q);
                }
            }
        }
        let mut q = layer % 2;
        while q + 1 < n {
            if rng.next_f64() < 0.5 {
                c.cx(q, q + 1);
            } else {
                c.cp(rng.next_f64() * PI, q + 1, q);
            }
            q += 2;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_build_and_validate() {
        for name in ALL {
            let c = build(name, 10, 42).unwrap();
            assert_eq!(c.n_qubits, 10, "{name}");
            assert!(!c.is_empty(), "{name} empty");
            assert_eq!(c.name, name);
            for g in &c.gates {
                for &q in g.targets() {
                    assert!(q < 10, "{name}: gate {g} out of range");
                }
            }
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(build("shor", 8, 0).is_err());
    }

    #[test]
    fn qft_gate_count_formula() {
        for n in [2usize, 5, 10, 16] {
            let c = qft(n);
            assert_eq!(c.len(), n * (n + 1) / 2 + n / 2, "n={n}");
        }
    }

    #[test]
    fn cat_and_ghz_have_linear_gate_count() {
        assert_eq!(cat_state(20).len(), 20);
        assert_eq!(ghz_state(20).len(), 20);
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        for name in ALL {
            let a = build(name, 12, 7).unwrap();
            let b = build(name, 12, 7).unwrap();
            assert_eq!(a.gates, b.gates, "{name} not deterministic");
        }
    }

    #[test]
    fn seed_changes_parameterized_circuits() {
        // A single seed pair may collide (e.g. cc's fake-coin index), so
        // require that a spread of seeds produces >1 distinct circuit.
        for name in ["bv", "qaoa", "qsvm", "ising", "cc"] {
            let base = build(name, 12, 0).unwrap();
            let distinct = (1u64..10)
                .map(|s| build(name, 12, s).unwrap())
                .filter(|c| c.gates != base.gates)
                .count();
            assert!(distinct > 0, "{name} ignored seed");
        }
    }

    #[test]
    fn random_is_deep_deterministic_and_buildable_by_name() {
        let a = random(10, 5);
        let b = build("random", 10, 5).unwrap();
        assert_eq!(a.gates, b.gates);
        // Θ(n²): n rotation layers of n gates plus ~n/2 entanglers each.
        assert!(a.len() >= 10 * 10, "only {} gates", a.len());
        assert!(random(10, 6).gates != a.gates, "seed ignored");
        assert!(!ALL.contains(&"random"), "random must stay out of the paper's table order");
        for g in &a.gates {
            for &q in g.targets() {
                assert!(q < 10);
            }
        }
    }

    #[test]
    fn qaoa_edges_are_valid() {
        let c = qaoa(14, 99);
        for g in &c.gates {
            if g.arity() == 2 {
                assert_ne!(g.qubits[0], g.qubits[1]);
            }
        }
    }

    #[test]
    fn paper_scale_gate_counts() {
        // Paper: 23-33 qubits, 24-3010 gates. Check our families land in
        // comparable ranges at n=28.
        for name in ALL {
            let c = build(name, 28, 3).unwrap();
            assert!(
                c.len() >= 24 && c.len() <= 3200,
                "{name}: {} gates out of paper range",
                c.len()
            );
        }
    }
}
