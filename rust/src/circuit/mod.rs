//! Circuit IR: an ordered gate list over `n` qubits, with builder helpers,
//! an OpenQASM-2 subset parser, the 8 NWQBench-style benchmark generators,
//! and the paper's Algorithm-1 circuit partitioner.

pub mod fusion;
pub mod gate;
pub mod generators;
pub mod partition;
pub mod qasm;

pub use fusion::{fuse_gates, fuse_remapped, FusedGate, MAX_FUSED_QUBITS};
pub use gate::{Gate, GateKind};
pub use partition::{partition_circuit, PartitionPlan, Stage};

use crate::types::{Error, Result};

/// A quantum circuit: `n_qubits` and an ordered list of gates.
#[derive(Debug, Clone)]
pub struct Circuit {
    pub n_qubits: usize,
    pub gates: Vec<Gate>,
    /// Human-readable tag (algorithm name), used in reports.
    pub name: String,
}

impl Circuit {
    pub fn new(n_qubits: usize, name: impl Into<String>) -> Self {
        Circuit { n_qubits, gates: Vec::new(), name: name.into() }
    }

    /// Validate and append a gate.
    pub fn push(&mut self, gate: Gate) -> Result<()> {
        for &q in gate.targets() {
            if q >= self.n_qubits {
                return Err(Error::Circuit(format!(
                    "gate {gate} targets qubit {q} but circuit has {} qubits",
                    self.n_qubits
                )));
            }
        }
        self.gates.push(gate);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.gates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }

    /// Count of two-qubit gates (entangling depth proxy).
    pub fn two_qubit_count(&self) -> usize {
        self.gates.iter().filter(|g| g.arity() == 2).count()
    }

    // ----- builder sugar (panics on invalid indices; use push() to handle) -----

    pub fn h(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::H, q).unwrap()).unwrap();
        self
    }
    pub fn x(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::X, q).unwrap()).unwrap();
        self
    }
    pub fn y(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::Y, q).unwrap()).unwrap();
        self
    }
    pub fn z(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::Z, q).unwrap()).unwrap();
        self
    }
    pub fn s(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::S, q).unwrap()).unwrap();
        self
    }
    pub fn t(&mut self, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::T, q).unwrap()).unwrap();
        self
    }
    pub fn rx(&mut self, theta: f64, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::Rx(theta), q).unwrap()).unwrap();
        self
    }
    pub fn ry(&mut self, theta: f64, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::Ry(theta), q).unwrap()).unwrap();
        self
    }
    pub fn rz(&mut self, theta: f64, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::Rz(theta), q).unwrap()).unwrap();
        self
    }
    pub fn p(&mut self, theta: f64, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::P(theta), q).unwrap()).unwrap();
        self
    }
    pub fn u3(&mut self, theta: f64, phi: f64, lam: f64, q: usize) -> &mut Self {
        self.push(Gate::q1(GateKind::U3(theta, phi, lam), q).unwrap()).unwrap();
        self
    }
    pub fn cx(&mut self, c: usize, t: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Cx, c, t).unwrap()).unwrap();
        self
    }
    pub fn cz(&mut self, c: usize, t: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Cz, c, t).unwrap()).unwrap();
        self
    }
    pub fn cp(&mut self, theta: f64, c: usize, t: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Cp(theta), c, t).unwrap()).unwrap();
        self
    }
    pub fn swap(&mut self, a: usize, b: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Swap, a, b).unwrap()).unwrap();
        self
    }
    pub fn rzz(&mut self, theta: f64, a: usize, b: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Rzz(theta), a, b).unwrap()).unwrap();
        self
    }
    pub fn rxx(&mut self, theta: f64, a: usize, b: usize) -> &mut Self {
        self.push(Gate::q2(GateKind::Rxx(theta), a, b).unwrap()).unwrap();
        self
    }

    /// Gate-kind histogram, for circuit stats in reports.
    pub fn kind_histogram(&self) -> Vec<(String, usize)> {
        let mut counts: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        for g in &self.gates {
            *counts.entry(g.kind.name()).or_insert(0) += 1;
        }
        counts.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
    }
}

impl std::fmt::Display for Circuit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "circuit {} — {} qubits, {} gates ({} two-qubit)",
            self.name,
            self.n_qubits,
            self.len(),
            self.two_qubit_count()
        )?;
        for g in &self.gates {
            writeln!(f, "  {g}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let mut c = Circuit::new(3, "test");
        c.h(0).cx(0, 1).cx(1, 2).rz(0.5, 2);
        assert_eq!(c.len(), 4);
        assert_eq!(c.two_qubit_count(), 2);
        assert_eq!(c.gates[0].kind, GateKind::H);
    }

    #[test]
    fn push_rejects_out_of_range() {
        let mut c = Circuit::new(2, "t");
        assert!(c.push(Gate::q1(GateKind::X, 2).unwrap()).is_err());
        assert!(c.push(Gate::q2(GateKind::Cx, 0, 5).unwrap()).is_err());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn histogram_counts() {
        let mut c = Circuit::new(2, "t");
        c.h(0).h(1).cx(0, 1);
        let h = c.kind_histogram();
        assert!(h.contains(&("h".to_string(), 2)));
        assert!(h.contains(&("cx".to_string(), 1)));
    }
}
