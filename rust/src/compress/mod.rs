//! The compression stack (paper §4.3): error-bounded lossy codecs with the
//! point-wise relative mode BMQSIM contributes, plus the lossless
//! substrate they are built on.
//!
//! Public surface: [`Codec`] (configured compressor) applied to *planes*
//! (flat `&[f64]` slices — one re or im plane of an SV block). The engines
//! never touch the wire formats directly.
//!
//! Three modes:
//! * [`CodecKind::PointwiseRel`] — Algorithm 2: sign bitmap (+ pre-scan) +
//!   zero bitmap + log2-domain absolute-error quantization, guaranteeing
//!   `|x̂-x|/|x| <= b_r` point-wise and exact zeros. The paper's default
//!   (`b_r = 1e-3`).
//! * [`CodecKind::Absolute`] — plain absolute-error quantization
//!   (`|x̂-x| <= eb`), the mode prior GPU compressors offer; used by the
//!   SC19-Sim baseline and the A2 ablation.
//! * [`CodecKind::Raw`] — bit-exact passthrough (compression disabled),
//!   used for the Fig. 11 no-compression comparison.

pub mod lossless;
pub mod lossy;
pub mod pointwise;
pub mod residual;

use crate::types::{Error, Result};

/// Which compression algorithm a [`Codec`] runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecKind {
    /// Point-wise relative bound (Algorithm 2) — BMQSIM's mode.
    PointwiseRel,
    /// Absolute bound — SC19-Sim / generic GPU-compressor mode.
    Absolute,
    /// No compression; exact bytes.
    Raw,
}

/// A configured plane compressor. Cheap to clone/share.
#[derive(Debug, Clone, Copy)]
pub struct Codec {
    pub kind: CodecKind,
    /// `b_r` for `PointwiseRel`, `eb` for `Absolute`; ignored for `Raw`.
    pub error_bound: f64,
    /// Enable the bitmap pre-scan stage (§4.3; ablation A1).
    pub prescan: bool,
}

impl Codec {
    /// The paper's default configuration: point-wise relative `1e-3`.
    pub fn paper_default() -> Self {
        Codec { kind: CodecKind::PointwiseRel, error_bound: 1e-3, prescan: true }
    }

    pub fn raw() -> Self {
        Codec { kind: CodecKind::Raw, error_bound: 0.0, prescan: false }
    }

    pub fn absolute(eb: f64) -> Self {
        Codec { kind: CodecKind::Absolute, error_bound: eb, prescan: false }
    }

    pub fn pointwise(b_r: f64) -> Self {
        Codec { kind: CodecKind::PointwiseRel, error_bound: b_r, prescan: true }
    }

    /// Compress one plane.
    pub fn compress(&self, data: &[f64]) -> Result<Vec<u8>> {
        match self.kind {
            CodecKind::PointwiseRel => pointwise::compress(data, self.error_bound, self.prescan),
            CodecKind::Absolute => lossy::compress(data, self.error_bound),
            CodecKind::Raw => Ok(raw_compress(data)),
        }
    }

    /// Decompress one plane (appends to a fresh Vec).
    pub fn decompress(&self, bytes: &[u8]) -> Result<Vec<f64>> {
        // The wire format is self-describing (mode byte), so decompression
        // does not depend on the configured kind — a codec can read blocks
        // written by another configuration (needed when an engine mixes
        // raw init blocks with compressed updates).
        decompress_any(bytes)
    }

    pub fn name(&self) -> &'static str {
        match self.kind {
            CodecKind::PointwiseRel => "bmz-pointwise",
            CodecKind::Absolute => "bmz-abs",
            CodecKind::Raw => "raw",
        }
    }
}

/// Wire-format mode tags (first byte of every compressed plane).
pub(crate) const MODE_RAW: u8 = 0x10;
pub(crate) const MODE_ABS: u8 = 0x11;
pub(crate) const MODE_POINTWISE: u8 = 0x12;

fn raw_compress(data: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 8 + data.len() * 8);
    out.push(MODE_RAW);
    lossless::varint::write_u64(&mut out, data.len() as u64);
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn raw_decompress(bytes: &[u8]) -> Result<Vec<f64>> {
    let mut pos = 1usize;
    let n = lossless::varint::read_u64(bytes, &mut pos)? as usize;
    if bytes.len() < pos + n * 8 {
        return Err(Error::Codec("raw: truncated".into()));
    }
    Ok(bytes[pos..pos + n * 8]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Dispatch on the self-describing mode byte.
pub fn decompress_any(bytes: &[u8]) -> Result<Vec<f64>> {
    match bytes.first() {
        Some(&MODE_RAW) => raw_decompress(bytes),
        Some(&MODE_ABS) => lossy::decompress(bytes),
        Some(&MODE_POINTWISE) => pointwise::decompress(bytes),
        Some(&m) => Err(Error::Codec(format!("unknown mode byte {m:#x}"))),
        None => Err(Error::Codec("empty payload".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    #[test]
    fn raw_roundtrip_bit_exact() {
        let mut rng = SplitMix64::new(1);
        let data: Vec<f64> = (0..5000).map(|_| rng.next_gaussian()).collect();
        let c = Codec::raw();
        let enc = c.compress(&data).unwrap();
        let dec = c.decompress(&enc).unwrap();
        assert_eq!(data, dec);
    }

    #[test]
    fn decompress_is_mode_agnostic() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let enc = Codec::pointwise(1e-3).compress(&data).unwrap();
        // A raw-configured codec can still read it.
        let dec = Codec::raw().decompress(&enc).unwrap();
        assert_eq!(dec.len(), data.len());
    }

    #[test]
    fn unknown_mode_rejected() {
        assert!(decompress_any(&[0xAB, 1, 2]).is_err());
        assert!(decompress_any(&[]).is_err());
    }

    #[test]
    fn paper_default_is_pointwise_1e3() {
        let c = Codec::paper_default();
        assert_eq!(c.kind, CodecKind::PointwiseRel);
        assert_eq!(c.error_bound, 1e-3);
        assert!(c.prescan);
    }
}
