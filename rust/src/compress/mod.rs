//! The compression stack (paper §4.3): error-bounded lossy codecs with the
//! point-wise relative mode BMQSIM contributes, plus the lossless
//! substrate they are built on.
//!
//! Public surface: [`Codec`] (configured compressor) applied to *planes*
//! (flat `&[f64]` slices — one re or im plane of an SV block). The engines
//! never touch the wire formats directly.
//!
//! Three modes:
//! * [`CodecKind::PointwiseRel`] — Algorithm 2: sign bitmap (+ pre-scan) +
//!   zero bitmap + log2-domain absolute-error quantization, guaranteeing
//!   `|x̂-x|/|x| <= b_r` point-wise and exact zeros. The paper's default
//!   (`b_r = 1e-3`).
//! * [`CodecKind::Absolute`] — plain absolute-error quantization
//!   (`|x̂-x| <= eb`), the mode prior GPU compressors offer; used by the
//!   SC19-Sim baseline and the A2 ablation.
//! * [`CodecKind::Raw`] — bit-exact passthrough (compression disabled),
//!   used for the Fig. 11 no-compression comparison.
//!
//! ## Zero-allocation hot path (§Perf, DESIGN.md)
//!
//! Every codec comes in three flavors:
//! * allocating ([`Codec::compress`], [`Codec::decompress`]) — one-shot
//!   convenience; returns fresh buffers;
//! * `*_into` ([`Codec::compress_into`], [`Codec::decompress_into`]) —
//!   writes into a caller buffer, deleting the temp-Vec-plus-copy on the
//!   engine hot path;
//! * `*_into_with` — additionally reuses a [`CodecScratch`] arena for all
//!   intermediate buffers (quantized codes, bitmap words, entropy-stage
//!   bytes), making steady-state (de)compression allocation-free.
//!
//! All three are byte-for-byte (encode) and bit-for-bit (decode)
//! equivalent; the property tests in `tests/codec_into.rs` pin this.
//! `decompress_into` requires `out.len()` to equal the encoded element
//! count exactly and fully overwrites `out` (dirty buffers are fine).

pub mod budget;
pub mod lossless;
pub mod lossy;
pub mod pointwise;
pub mod residual;

use crate::types::{Error, Result};

/// Which compression algorithm a [`Codec`] runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecKind {
    /// Point-wise relative bound (Algorithm 2) — BMQSIM's mode.
    PointwiseRel,
    /// Absolute bound — SC19-Sim / generic GPU-compressor mode.
    Absolute,
    /// No compression; exact bytes.
    Raw,
}

/// Reusable intermediate buffers for the codec hot path. One per pipeline
/// worker (owned by `pipeline::Scratch`); creation is allocation-free, the
/// buffers grow on first use and are recycled afterwards.
#[derive(Debug)]
pub struct CodecScratch {
    /// Quantized integer codes (sized from the zero-bitmap popcount).
    codes: Vec<i64>,
    /// Outlier side table (index, exact bits).
    outliers: Vec<(usize, f64)>,
    /// Packed sign-bitmap words.
    sign_words: Vec<u64>,
    /// Packed zero-bitmap words.
    zero_words: Vec<u64>,
    /// Entropy-stage byte scratch (bitmap/residual bodies, Huffman pass).
    buf_a: Vec<u8>,
    buf_b: Vec<u8>,
    buf_c: Vec<u8>,
    /// Zigzag-delta scratch for the residual coder's SIMD stage 1.
    delta: Vec<u64>,
    /// SIMD dispatch table captured at construction; every codec kernel
    /// invocation routes through it (the kill switch therefore applies to
    /// scratches built after it was thrown).
    simd: &'static crate::simd::SimdOps,
}

impl Default for CodecScratch {
    fn default() -> Self {
        Self::with_ops(crate::simd::dispatch())
    }
}

impl CodecScratch {
    /// Scratch bound to the runtime-dispatched SIMD table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch pinned to an explicit dispatch table — differential tests
    /// pass `simd::scalar_ops()` to force the oracle path regardless of
    /// what the host CPU supports.
    pub fn with_ops(ops: &'static crate::simd::SimdOps) -> Self {
        CodecScratch {
            codes: Vec::new(),
            outliers: Vec::new(),
            sign_words: Vec::new(),
            zero_words: Vec::new(),
            buf_a: Vec::new(),
            buf_b: Vec::new(),
            buf_c: Vec::new(),
            delta: Vec::new(),
            simd: ops,
        }
    }
}

/// A configured plane compressor. Cheap to clone/share.
#[derive(Debug, Clone, Copy)]
pub struct Codec {
    /// Which wire format / quantizer to use.
    pub kind: CodecKind,
    /// `b_r` for `PointwiseRel`, `eb` for `Absolute`; ignored for `Raw`.
    pub error_bound: f64,
    /// Enable the bitmap pre-scan stage (§4.3; ablation A1).
    pub prescan: bool,
}

impl Codec {
    /// The paper's default configuration: point-wise relative `1e-3`.
    pub fn paper_default() -> Self {
        Codec { kind: CodecKind::PointwiseRel, error_bound: 1e-3, prescan: true }
    }

    /// Lossless pass-through (no quantization).
    pub fn raw() -> Self {
        Codec { kind: CodecKind::Raw, error_bound: 0.0, prescan: false }
    }

    /// Absolute error bound `eb` (uniform quantizer).
    pub fn absolute(eb: f64) -> Self {
        Codec { kind: CodecKind::Absolute, error_bound: eb, prescan: false }
    }

    /// Point-wise relative bound `b_r` (log-magnitude quantizer).
    pub fn pointwise(b_r: f64) -> Self {
        Codec { kind: CodecKind::PointwiseRel, error_bound: b_r, prescan: true }
    }

    /// This codec with a different error bound — the per-encode form the
    /// [`budget::BudgetController`] hands to the engines (`Codec` is
    /// `Copy`; the wire format embeds the bound, so per-block bounds need
    /// no decode-side plumbing).
    pub fn with_bound(&self, bound: f64) -> Self {
        Codec { error_bound: bound, ..*self }
    }

    /// Compress one plane into a fresh buffer.
    pub fn compress(&self, data: &[f64]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.compress_into(data, &mut out)?;
        Ok(out)
    }

    /// Compress one plane into a reused buffer (`out` is cleared, its
    /// capacity retained). Byte-for-byte identical to [`Codec::compress`].
    pub fn compress_into(&self, data: &[f64], out: &mut Vec<u8>) -> Result<()> {
        self.compress_into_with(data, out, &mut CodecScratch::new())
    }

    /// [`Codec::compress_into`] with an explicit scratch arena — the
    /// steady-state zero-allocation form the pipeline workers use.
    pub fn compress_into_with(
        &self,
        data: &[f64],
        out: &mut Vec<u8>,
        scratch: &mut CodecScratch,
    ) -> Result<()> {
        match self.kind {
            CodecKind::PointwiseRel => {
                pointwise::compress_into_with(data, self.error_bound, self.prescan, out, scratch)
            }
            CodecKind::Absolute => lossy::compress_into_with(data, self.error_bound, out, scratch),
            CodecKind::Raw => {
                raw_compress_into(data, out);
                Ok(())
            }
        }
    }

    /// Decompress one plane into a fresh Vec.
    pub fn decompress(&self, bytes: &[u8]) -> Result<Vec<f64>> {
        // The wire format is self-describing (mode byte), so decompression
        // does not depend on the configured kind — a codec can read blocks
        // written by another configuration (needed when an engine mixes
        // raw init blocks with compressed updates).
        decompress_any(bytes)
    }

    /// Decompress one plane directly into `out`, which must have exactly
    /// the encoded length ([`decoded_len`]). Fully overwrites `out`.
    pub fn decompress_into(&self, bytes: &[u8], out: &mut [f64]) -> Result<()> {
        decompress_any_into(bytes, out)
    }

    /// [`Codec::decompress_into`] with an explicit scratch arena.
    pub fn decompress_into_with(
        &self,
        bytes: &[u8],
        out: &mut [f64],
        scratch: &mut CodecScratch,
    ) -> Result<()> {
        decompress_any_into_with(bytes, out, scratch)
    }

    /// Short human-readable codec name for reports.
    pub fn name(&self) -> &'static str {
        match self.kind {
            CodecKind::PointwiseRel => "bmz-pointwise",
            CodecKind::Absolute => "bmz-abs",
            CodecKind::Raw => "raw",
        }
    }
}

/// Wire-format mode tags (first byte of every compressed plane).
pub(crate) const MODE_RAW: u8 = 0x10;
pub(crate) const MODE_ABS: u8 = 0x11;
pub(crate) const MODE_POINTWISE: u8 = 0x12;

fn raw_compress_into(data: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(1 + 9 + data.len() * 8);
    out.push(MODE_RAW);
    lossless::varint::write_u64(out, data.len() as u64);
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Parsed wire-format prefix — the one shared header walk behind every
/// `decoded_len` peek and decode entry point (previously each mode
/// re-implemented its own, drifting in validation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum PlanePrefix {
    /// Raw passthrough: `n` elements follow (length already validated).
    Raw { n: usize },
    /// Absolute mode: bound + scan position of the residual body (the
    /// outlier table has been walked past, not collected).
    Abs { eb: f64, residual_pos: usize },
    /// Pointwise mode: bound, element count, and the scan position right
    /// after the count (bitmaps/outliers/residual follow).
    Pointwise { b_r: f64, n: usize, after_n: usize },
}

/// Parse the fixed `[mode][param: f64 LE]` prefix shared by the lossy
/// modes. The caller has already matched the mode byte; returns the
/// parameter and the scan position after it.
pub(crate) fn parse_mode_param(bytes: &[u8], what: &str) -> Result<(f64, usize)> {
    if bytes.len() < 9 {
        return Err(Error::Codec(format!("{what}: truncated header")));
    }
    let param = f64::from_le_bytes(bytes[1..9].try_into().unwrap());
    Ok((param, 9))
}

/// Walk the outlier side table (`count` varint, then delta-varint index +
/// 8 exact bytes per entry), advancing `pos` past it. When `outliers` is
/// given it receives the decoded `(index, bits)` pairs; `None` just
/// validates and skips (the `decoded_len` peeks).
pub(crate) fn parse_outliers(
    bytes: &[u8],
    pos: &mut usize,
    mut outliers: Option<&mut Vec<(usize, f64)>>,
    what: &str,
) -> Result<()> {
    let n_out = lossless::varint::read_u64(bytes, pos)? as usize;
    if let Some(o) = outliers.as_mut() {
        o.clear();
        o.reserve(n_out);
    }
    let mut prev = 0usize;
    for _ in 0..n_out {
        let d = lossless::varint::read_u64(bytes, pos)? as usize;
        if bytes.len() < *pos + 8 {
            return Err(Error::Codec(format!("{what}: truncated outlier")));
        }
        let x = f64::from_le_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
        *pos += 8;
        prev += d;
        if let Some(o) = outliers.as_mut() {
            o.push((prev, x));
        }
    }
    Ok(())
}

/// Parse and validate the self-describing plane prefix for any mode.
pub(crate) fn parse_prefix(bytes: &[u8]) -> Result<PlanePrefix> {
    match bytes.first() {
        Some(&MODE_RAW) => {
            let mut pos = 1usize;
            let n = lossless::varint::read_u64(bytes, &mut pos)? as usize;
            // Validate before anyone allocates n elements from a corrupt
            // header (division avoids overflow on absurd n).
            if n > (bytes.len() - pos) / 8 {
                return Err(Error::Codec("raw: truncated".into()));
            }
            Ok(PlanePrefix::Raw { n })
        }
        Some(&MODE_ABS) => {
            let (eb, mut pos) = parse_mode_param(bytes, "abs")?;
            parse_outliers(bytes, &mut pos, None, "abs")?;
            Ok(PlanePrefix::Abs { eb, residual_pos: pos })
        }
        Some(&MODE_POINTWISE) => {
            let (b_r, mut pos) = parse_mode_param(bytes, "pointwise")?;
            let n = lossless::varint::read_u64(bytes, &mut pos)? as usize;
            Ok(PlanePrefix::Pointwise { b_r, n, after_n: pos })
        }
        Some(&m) => Err(Error::Codec(format!("unknown mode byte {m:#x}"))),
        None => Err(Error::Codec("empty payload".into())),
    }
}

fn raw_decompress_into(bytes: &[u8], out: &mut [f64]) -> Result<()> {
    let mut pos = 1usize;
    let n = lossless::varint::read_u64(bytes, &mut pos)? as usize;
    if out.len() != n {
        return Err(Error::Codec(format!(
            "raw: output buffer holds {} elements, payload has {n}",
            out.len()
        )));
    }
    if bytes.len() < pos + n * 8 {
        return Err(Error::Codec("raw: truncated".into()));
    }
    for (slot, c) in out.iter_mut().zip(bytes[pos..pos + n * 8].chunks_exact(8)) {
        *slot = f64::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

/// The error bound embedded in a compressed plane's header — a cheap peek
/// used by the memory tier's recompression hook to judge whether a looser
/// controller-approved bound is worth a re-encode. `None` for raw planes
/// (no bound to compare).
pub fn plane_bound(bytes: &[u8]) -> Result<Option<f64>> {
    match parse_prefix(bytes)? {
        PlanePrefix::Raw { .. } => Ok(None),
        PlanePrefix::Abs { eb, .. } => Ok(Some(eb)),
        PlanePrefix::Pointwise { b_r, .. } => Ok(Some(b_r)),
    }
}

/// Number of `f64` elements a compressed plane decodes to — a cheap header
/// peek (no payload decode) used to size destination buffers.
pub fn decoded_len(bytes: &[u8]) -> Result<usize> {
    match parse_prefix(bytes)? {
        PlanePrefix::Raw { n } => Ok(n),
        PlanePrefix::Abs { residual_pos, .. } => residual::encoded_count(&bytes[residual_pos..]),
        PlanePrefix::Pointwise { n, .. } => Ok(n),
    }
}

/// Dispatch on the self-describing mode byte.
pub fn decompress_any(bytes: &[u8]) -> Result<Vec<f64>> {
    let n = decoded_len(bytes)?;
    let mut out = vec![0.0f64; n];
    decompress_any_into(bytes, &mut out)?;
    Ok(out)
}

/// [`decompress_any`] into a caller buffer of exactly [`decoded_len`]
/// elements. Fully overwrites `out` (dirty buffers are fine).
pub fn decompress_any_into(bytes: &[u8], out: &mut [f64]) -> Result<()> {
    decompress_any_into_with(bytes, out, &mut CodecScratch::new())
}

/// [`decompress_any_into`] with an explicit scratch arena — the
/// steady-state zero-allocation form the pipeline workers use.
pub fn decompress_any_into_with(
    bytes: &[u8],
    out: &mut [f64],
    scratch: &mut CodecScratch,
) -> Result<()> {
    match bytes.first() {
        Some(&MODE_RAW) => raw_decompress_into(bytes, out),
        Some(&MODE_ABS) => lossy::decompress_into_with(bytes, out, scratch),
        Some(&MODE_POINTWISE) => pointwise::decompress_into_with(bytes, out, scratch),
        Some(&m) => Err(Error::Codec(format!("unknown mode byte {m:#x}"))),
        None => Err(Error::Codec("empty payload".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    #[test]
    fn raw_roundtrip_bit_exact() {
        let mut rng = SplitMix64::new(1);
        let data: Vec<f64> = (0..5000).map(|_| rng.next_gaussian()).collect();
        let c = Codec::raw();
        let enc = c.compress(&data).unwrap();
        let dec = c.decompress(&enc).unwrap();
        assert_eq!(data, dec);
    }

    #[test]
    fn decompress_is_mode_agnostic() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let enc = Codec::pointwise(1e-3).compress(&data).unwrap();
        // A raw-configured codec can still read it.
        let dec = Codec::raw().decompress(&enc).unwrap();
        assert_eq!(dec.len(), data.len());
    }

    #[test]
    fn unknown_mode_rejected() {
        assert!(decompress_any(&[0xAB, 1, 2]).is_err());
        assert!(decompress_any(&[]).is_err());
    }

    #[test]
    fn paper_default_is_pointwise_1e3() {
        let c = Codec::paper_default();
        assert_eq!(c.kind, CodecKind::PointwiseRel);
        assert_eq!(c.error_bound, 1e-3);
        assert!(c.prescan);
    }

    #[test]
    fn decoded_len_matches_all_modes() {
        let mut rng = SplitMix64::new(2);
        let data: Vec<f64> = (0..777).map(|_| rng.next_gaussian()).collect();
        for codec in [Codec::raw(), Codec::absolute(1e-4), Codec::pointwise(1e-3)] {
            let enc = codec.compress(&data).unwrap();
            assert_eq!(decoded_len(&enc).unwrap(), data.len(), "{}", codec.name());
        }
    }

    #[test]
    fn raw_into_requires_exact_length() {
        let data = vec![1.0f64, 2.0, 3.0];
        let enc = Codec::raw().compress(&data).unwrap();
        let mut small = vec![0.0f64; 2];
        assert!(decompress_any_into(&enc, &mut small).is_err());
        let mut big = vec![0.0f64; 4];
        assert!(decompress_any_into(&enc, &mut big).is_err());
        let mut exact = vec![f64::NAN; 3];
        decompress_any_into(&enc, &mut exact).unwrap();
        assert_eq!(exact, data);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let mut rng = SplitMix64::new(3);
        let mut scratch = CodecScratch::new();
        let mut out = Vec::new();
        for codec in [Codec::pointwise(1e-3), Codec::absolute(1e-3), Codec::raw()] {
            for round in 0..3 {
                let data: Vec<f64> =
                    (0..2048).map(|_| rng.next_gaussian() * 10f64.powi(round - 1)).collect();
                codec.compress_into_with(&data, &mut out, &mut scratch).unwrap();
                assert_eq!(out, codec.compress(&data).unwrap(), "{} round {round}", codec.name());
                let mut dec = vec![f64::NAN; data.len()];
                decompress_any_into_with(&out, &mut dec, &mut scratch).unwrap();
                assert_eq!(dec, decompress_any(&out).unwrap());
            }
        }
    }
}
