//! Canonical Huffman coding over the byte alphabet — the final entropy
//! stage for residual and bitmap streams (the `bitcomp` lossless analogue).
//!
//! Encoded layout: `[n_symbols:varint][(<symbol><len>)*][payload_bits:varint][bits...]`.
//! Code lengths are canonical, so only lengths ship; codes are rebuilt on
//! both sides with the same assignment rule.

use super::varint;
use crate::types::{Error, Result};

const MAX_CODE_LEN: u32 = 48;

/// Compress `data` with a one-shot canonical Huffman code. Streams that are
/// incompressible come out slightly larger (header overhead); callers that
/// care (the codec framing) compare against raw and keep the smaller.
pub fn encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    encode_into(data, &mut out);
    out
}

/// [`encode`] writing into a reused buffer: clears `out` (capacity is
/// retained) and appends the identical byte stream.
pub fn encode_into(data: &[u8], out: &mut Vec<u8>) {
    let mut freq = [0u64; 256];
    for &b in data {
        freq[b as usize] += 1;
    }
    let lens = code_lengths(&freq);
    let codes = canonical_codes(&lens);

    out.clear();
    // Symbol table: count + (symbol, len) pairs.
    let used: Vec<u8> = (0..256u16).filter(|&s| lens[s as usize] > 0).map(|s| s as u8).collect();
    varint::write_u64(out, used.len() as u64);
    for &s in &used {
        out.push(s);
        out.push(lens[s as usize] as u8);
    }
    varint::write_u64(out, data.len() as u64);
    // Dedicated bit accumulator (perf §Perf): codes are <= 48 bits, so an
    // u64 window + whole-byte flushes beats the general BitWriter loop.
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for &b in data {
        let (code, len) = codes[b as usize];
        // Invariant: nbits < 8 here, so nbits + len <= 7 + 48 < 64.
        acc |= code << nbits;
        nbits += len;
        while nbits >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push(acc as u8);
    }
}

/// Inverse of [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decode_into(bytes, &mut out)?;
    Ok(out)
}

/// [`decode`] writing into a reused buffer: clears `out` (capacity is
/// retained) and appends the decoded bytes.
pub fn decode_into(bytes: &[u8], out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    let mut pos = 0usize;
    let n_sym = varint::read_u64(bytes, &mut pos)? as usize;
    if n_sym > 256 {
        return Err(Error::Codec(format!("huffman: {n_sym} symbols")));
    }
    let mut lens = [0u32; 256];
    for _ in 0..n_sym {
        if pos + 2 > bytes.len() {
            return Err(Error::Codec("huffman: truncated table".into()));
        }
        let s = bytes[pos] as usize;
        let l = bytes[pos + 1] as u32;
        if l == 0 || l > MAX_CODE_LEN {
            return Err(Error::Codec(format!("huffman: bad code length {l}")));
        }
        lens[s] = l;
        pos += 2;
    }
    let n_out = varint::read_u64(bytes, &mut pos)? as usize;
    if n_out == 0 {
        return Ok(());
    }
    if n_sym == 0 {
        return Err(Error::Codec("huffman: no symbols but nonzero output".into()));
    }

    // Canonical decode tables: for each length, first code + symbol range.
    let mut by_len: Vec<Vec<u8>> = vec![Vec::new(); (MAX_CODE_LEN + 1) as usize];
    let mut order: Vec<u16> = (0..256u16).filter(|&s| lens[s as usize] > 0).collect();
    order.sort_by_key(|&s| (lens[s as usize], s));
    for &s in &order {
        by_len[lens[s as usize] as usize].push(s as u8);
    }
    // first_code[l]: canonical first code value at length l (MSB-first).
    let mut first_code = vec![0u64; (MAX_CODE_LEN + 2) as usize];
    {
        let mut code = 0u64;
        for l in 1..=MAX_CODE_LEN as usize {
            first_code[l] = code;
            code = (code + by_len[l].len() as u64) << 1;
        }
    }

    // Fast path: a LUT_BITS-wide lookup table resolving any code of length
    // <= LUT_BITS in one probe (perf: replaces the bit-by-bit walk, ~10x
    // decode throughput; see EXPERIMENTS.md §Perf). Codes on the wire are
    // MSB-first; the table is indexed by the next LUT_BITS bits LSB-first
    // as read, i.e. by the *reversed* code padded with every suffix.
    const LUT_BITS: u32 = 11;
    let mut lut = vec![(0u8, 0u8); 1usize << LUT_BITS]; // (symbol, len); len 0 = slow path
    {
        let codes = canonical_codes(&lens);
        for s in 0..256usize {
            let l = lens[s];
            if l == 0 || l > LUT_BITS {
                continue;
            }
            // codes[s].0 is already bit-reversed into LSB-first wire order.
            let base = codes[s].0;
            let step = 1u64 << l;
            let mut idx = base;
            while idx < (1u64 << LUT_BITS) {
                lut[idx as usize] = (s as u8, l as u8);
                idx += step;
            }
        }
    }

    // Multi-symbol fast path (vectorized-decode analogue): a second LUT
    // mapping each 11-bit window to up to 4 already-decoded symbols, built
    // by simulating consecutive single-LUT probes inside the window. Valid
    // because a single-LUT entry is a function of its low `len` bits only
    // (canonical codes stride the table at `1 << len`), so the zero-padded
    // simulation agrees with the real bit stream whenever the cumulative
    // code lengths fit in the window. Short payloads skip the table build
    // (it costs 2048 probes); the `huffman_multi` dispatch flag keeps the
    // scalar oracle path reachable for differential tests.
    #[derive(Clone, Copy)]
    struct MEntry {
        syms: [u8; 4],
        count: u8,
        bits: u8,
    }
    let multi = crate::simd::dispatch().huffman_multi() && n_out >= 1024;
    let mut mlut: Vec<MEntry> = Vec::new();
    if multi {
        mlut = vec![MEntry { syms: [0; 4], count: 0, bits: 0 }; 1usize << LUT_BITS];
        for (w, entry) in mlut.iter_mut().enumerate() {
            let mut syms = [0u8; 4];
            let mut count = 0u8;
            let mut used = 0usize;
            while count < 4 {
                let (s, l) = lut[(w >> used) & ((1usize << LUT_BITS) - 1)];
                if l == 0 || used + l as usize > LUT_BITS as usize {
                    break;
                }
                syms[count as usize] = s;
                count += 1;
                used += l as usize;
            }
            if count >= 2 {
                *entry = MEntry { syms, count, bits: used as u8 };
            }
        }
        crate::simd::note_kernels(1);
    }

    let payload = &bytes[pos..];
    let total_bits = payload.len() * 8;
    out.reserve(n_out);
    let mut bitpos = 0usize;

    // Branch-light bit peek: one unaligned 8-byte load for the common case
    // (perf §Perf: the per-byte loop here dominated decode time).
    let peek = |bitpos: usize| -> u64 {
        let byte = bitpos / 8;
        let shift = (bitpos % 8) as u32;
        if byte + 8 <= payload.len() {
            let w = u64::from_le_bytes(payload[byte..byte + 8].try_into().unwrap());
            // 64 - shift >= 56 valid bits: enough for LUT (11) + slow (48).
            w >> shift
        } else {
            let mut buf = [0u8; 8];
            let take = payload.len() - byte.min(payload.len());
            buf[..take].copy_from_slice(&payload[byte..]);
            u64::from_le_bytes(buf) >> shift
        }
    };

    while out.len() < n_out {
        let window = peek(bitpos);
        if multi {
            let e = mlut[(window & ((1 << LUT_BITS) - 1)) as usize];
            // Every guard that fails here drops to the single-symbol steps
            // below, which decode the identical prefix — output equality
            // does not depend on when the multi entry applies.
            if e.count > 0
                && out.len() + e.count as usize <= n_out
                && bitpos + e.bits as usize <= total_bits
            {
                out.extend_from_slice(&e.syms[..e.count as usize]);
                bitpos += e.bits as usize;
                continue;
            }
        }
        let (sym, l) = lut[(window & ((1 << LUT_BITS) - 1)) as usize];
        if l != 0 && bitpos + l as usize <= total_bits {
            out.push(sym);
            bitpos += l as usize;
            continue;
        }
        // Slow path: codes longer than LUT_BITS (rare, skewed tables only).
        let mut code = 0u64;
        let mut len = 0usize;
        loop {
            if bitpos + len >= total_bits + 64 {
                return Err(Error::Codec("huffman: bit stream exhausted".into()));
            }
            if bitpos + len >= total_bits {
                return Err(Error::Codec("huffman: bit stream exhausted".into()));
            }
            let bit = (window >> len) & 1;
            code = (code << 1) | bit;
            len += 1;
            if len > MAX_CODE_LEN as usize {
                return Err(Error::Codec("huffman: code overrun".into()));
            }
            let k = by_len[len].len() as u64;
            if k > 0 && code >= first_code[len] && code < first_code[len] + k {
                out.push(by_len[len][(code - first_code[len]) as usize]);
                bitpos += len;
                break;
            }
        }
    }
    Ok(())
}

/// Code lengths via a simple heap-free Huffman build (256-symbol alphabet,
/// O(n log n) with sorting). Single-symbol inputs get length 1.
fn code_lengths(freq: &[u64; 256]) -> [u32; 256] {
    #[derive(Clone)]
    struct Node {
        weight: u64,
        kids: Option<(usize, usize)>,
        symbol: u16,
    }
    let mut nodes: Vec<Node> = Vec::new();
    let mut live: Vec<usize> = Vec::new();
    for s in 0..256 {
        if freq[s] > 0 {
            nodes.push(Node { weight: freq[s], kids: None, symbol: s as u16 });
            live.push(nodes.len() - 1);
        }
    }
    let mut lens = [0u32; 256];
    match live.len() {
        0 => return lens,
        1 => {
            lens[nodes[live[0]].symbol as usize] = 1;
            return lens;
        }
        _ => {}
    }
    while live.len() > 1 {
        // Pick two smallest (selection over <=256 entries; fine at this scale).
        live.sort_by_key(|&i| std::cmp::Reverse(nodes[i].weight));
        let a = live.pop().unwrap();
        let b = live.pop().unwrap();
        nodes.push(Node { weight: nodes[a].weight + nodes[b].weight, kids: Some((a, b)), symbol: 0 });
        live.push(nodes.len() - 1);
    }
    // Depth-first depth assignment.
    let mut stack = vec![(live[0], 0u32)];
    while let Some((i, d)) = stack.pop() {
        match nodes[i].kids {
            Some((a, b)) => {
                stack.push((a, d + 1));
                stack.push((b, d + 1));
            }
            None => lens[nodes[i].symbol as usize] = d.max(1).min(MAX_CODE_LEN),
        }
    }
    lens
}

/// Canonical code assignment; returns per-symbol `(bits, len)` where `bits`
/// holds the code MSB-first *reversed into LSB-first write order* so that
/// `BitWriter::write_bits` emits the MSB first on the wire.
fn canonical_codes(lens: &[u32; 256]) -> [(u64, u32); 256] {
    let mut order: Vec<u16> = (0..256u16).filter(|&s| lens[s as usize] > 0).collect();
    order.sort_by_key(|&s| (lens[s as usize], s));
    let mut codes = [(0u64, 0u32); 256];
    let mut code = 0u64;
    let mut prev_len = 0u32;
    for &s in &order {
        let l = lens[s as usize];
        code <<= l - prev_len;
        prev_len = l;
        // Reverse the l-bit code so LSB-first emission yields MSB-first wire order.
        let mut rev = 0u64;
        for b in 0..l {
            if code & (1 << b) != 0 {
                rev |= 1 << (l - 1 - b);
            }
        }
        codes[s as usize] = (rev, l);
        code += 1;
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    #[test]
    fn roundtrip_text() {
        let data = b"the quick brown fox jumps over the lazy dog, repeatedly the the the";
        let enc = encode(data);
        assert_eq!(decode(&enc).unwrap(), data);
        // Tiny inputs pay the symbol-table overhead; just bound the blowup.
        assert!(enc.len() < data.len() * 2);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        assert_eq!(decode(&encode(&[])).unwrap(), Vec::<u8>::new());
        assert_eq!(decode(&encode(&[42])).unwrap(), vec![42]);
        assert_eq!(decode(&encode(&[7; 1000])).unwrap(), vec![7; 1000]);
    }

    #[test]
    fn skewed_distribution_compresses_well() {
        let mut rng = SplitMix64::new(1);
        let data: Vec<u8> = (0..100_000)
            .map(|_| if rng.next_f64() < 0.95 { 0u8 } else { rng.next_u64() as u8 })
            .collect();
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
        // ~0.37 bits/symbol entropy => expect large reduction.
        assert!(enc.len() * 2 < data.len(), "enc {} vs raw {}", enc.len(), data.len());
    }

    #[test]
    fn uniform_random_roundtrips() {
        let mut rng = SplitMix64::new(2);
        let data: Vec<u8> = (0..50_000).map(|_| rng.next_u64() as u8).collect();
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn all_bytes_roundtrip() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn corrupt_header_rejected() {
        let enc = encode(b"hello world");
        // Break the symbol count.
        let mut bad = enc.clone();
        bad[0] = 0xFF;
        assert!(decode(&bad).is_err() || decode(&bad).unwrap() != b"hello world");
    }
}
