//! Bit-granular writer/reader over byte buffers (LSB-first within bytes).
//! Foundation for the Huffman coder and the bitmap machinery.

use crate::types::{Error, Result};

/// Append-only bit sink.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the final byte (0..8; 0 means byte-aligned).
    used: u32,
}

impl BitWriter {
    /// Fresh, empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 64), LSB first.
    pub fn write_bits(&mut self, mut v: u64, mut n: u32) {
        debug_assert!(n <= 64);
        if n < 64 {
            v &= (1u64 << n) - 1;
        }
        while n > 0 {
            if self.used == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.used;
            let take = free.min(n);
            let byte = self.buf.last_mut().unwrap();
            *byte |= ((v & ((1u64 << take) - 1)) as u8) << self.used;
            v >>= take;
            n -= take;
            self.used = (self.used + take) % 8;
        }
    }

    /// Write a single bit.
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.used == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.used as usize
        }
    }

    /// Finish and return the byte buffer (final byte zero-padded).
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential bit source.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit cursor
}

impl<'a> BitReader<'a> {
    /// Reader over `buf`, cursor at bit 0.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits (n <= 64), LSB-first, matching [`BitWriter`].
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 64);
        if self.pos + n as usize > self.buf.len() * 8 {
            return Err(Error::Codec("bit stream exhausted".into()));
        }
        let mut v = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte = self.buf[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(n - got);
            let bits = ((byte >> off) as u64) & ((1u64 << take) - 1);
            v |= bits << got;
            got += take;
            self.pos += take as usize;
        }
        Ok(v)
    }

    /// Read a single bit.
    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? != 0)
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFF, 8);
        w.write_bits(0, 1);
        w.write_bits(u64::MAX, 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(8).unwrap(), 0xFF);
        assert_eq!(r.read_bits(1).unwrap(), 0);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = SplitMix64::new(3);
        let items: Vec<(u64, u32)> = (0..2000)
            .map(|_| {
                let n = 1 + (rng.next_u64() % 64) as u32;
                let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let total = w.bit_len();
        let bytes = w.into_bytes();
        assert_eq!(total, items.iter().map(|&(_, n)| n as usize).sum::<usize>());
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn exhaustion_errors() {
        let bytes = [0u8; 1];
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bits(8).is_ok());
        assert!(r.read_bits(1).is_err());
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bit(true);
        assert_eq!(w.bit_len(), 1);
        w.write_bits(0, 10);
        assert_eq!(w.bit_len(), 11);
    }
}
