//! Lossless building blocks: bit I/O, varints, canonical Huffman, and the
//! sign-bitmap pre-scan coder. These compose into the lossy codecs'
//! residual/entropy stages and ship the Algorithm-2 sign bitmap.

pub mod bitio;
pub mod bitmap;
pub mod huffman;
pub mod varint;
