//! LEB128 varints + zig-zag mapping — the residual coder's integer layer.

use crate::types::{Error, Result};

/// Append `v` as LEB128 (7 bits per byte, MSB = continuation).
#[inline]
pub fn write_u64(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 u64 from `buf[*pos..]`, advancing `pos`.
#[inline]
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::Codec("varint: buffer exhausted".into()))?;
        *pos += 1;
        if shift >= 64 {
            return Err(Error::Codec("varint: overflow".into()));
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zig-zag: map signed to unsigned so small-magnitude values stay small.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    #[test]
    fn varint_roundtrip_edges() {
        let vals = [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX];
        let mut buf = Vec::new();
        for &v in &vals {
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_roundtrip_random() {
        let mut rng = SplitMix64::new(8);
        let vals: Vec<u64> = (0..5000)
            .map(|_| rng.next_u64() >> (rng.next_u64() % 64))
            .collect();
        let mut buf = Vec::new();
        for &v in &vals {
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
        }
    }

    #[test]
    fn zigzag_bijective() {
        for v in [0i64, 1, -1, 2, -2, i64::MAX, i64::MIN, 12345, -98765] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // Small magnitudes map to small codes.
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }

    #[test]
    fn truncated_varint_errors() {
        let buf = [0x80u8, 0x80];
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }
}
