//! Sign-bitmap coding with the paper's pre-scan optimization (§4.3).
//!
//! State-vector sign bits repeat over long stretches, so the bitmap is
//! chunked into 64-bit words and each word classified ALL-0 / ALL-1 /
//! MIXED — the CPU analogue of the paper's `__ballot_any/_all` warp scans.
//! Runs of same-class words are run-length coded; only MIXED words ship
//! their payload. A final Huffman pass (the "additional lossless encoding"
//! of Algorithm 2 line 17) is applied when it wins, and the whole prescan
//! result is dropped for the raw bitmap when *that* wins (adversarial
//! inputs), so the output is never pathologically larger.

use super::{huffman, varint};
use crate::types::{Error, Result};

const CLASS_ZERO: u64 = 0;
const CLASS_ONES: u64 = 1;
const CLASS_MIXED: u64 = 2;

const MODE_RAW: u8 = 0;
const MODE_PRESCAN: u8 = 1;
const MODE_PRESCAN_HUFF: u8 = 2;

/// Pack a bool-per-element sign slice into bitmap words (LSB-first).
pub fn pack_bits(bits: impl ExactSizeIterator<Item = bool>) -> (Vec<u64>, usize) {
    let mut words = Vec::new();
    let nbits = pack_bits_into(bits, &mut words);
    (words, nbits)
}

/// [`pack_bits`] into a reused word buffer: clears `words` (capacity is
/// retained) and returns the bit count.
pub fn pack_bits_into(bits: impl ExactSizeIterator<Item = bool>, words: &mut Vec<u64>) -> usize {
    let nbits = bits.len();
    words.clear();
    words.reserve(nbits.div_ceil(64));
    // Word-at-a-time accumulation (perf §Perf: the indexed per-bit loop was
    // ~12% of codec time; this form keeps the word in a register).
    let mut acc = 0u64;
    let mut fill = 0u32;
    for b in bits {
        acc |= (b as u64) << fill;
        fill += 1;
        if fill == 64 {
            words.push(acc);
            acc = 0;
            fill = 0;
        }
    }
    if fill > 0 {
        words.push(acc);
    }
    nbits
}

/// Read bit `i` of a packed bitmap.
#[inline]
pub fn get_bit(words: &[u64], i: usize) -> bool {
    words[i / 64] & (1 << (i % 64)) != 0
}

/// Compress a bitmap. `prescan=false` disables the word-classification
/// stage (the A1 ablation knob) and stores raw words.
pub fn compress_bitmap(words: &[u64], nbits: usize, prescan: bool) -> Vec<u8> {
    let mut out = Vec::new();
    let (mut sa, mut sb) = (Vec::new(), Vec::new());
    compress_bitmap_into(words, nbits, prescan, &mut out, &mut sa, &mut sb);
    out
}

/// [`compress_bitmap`] into a reused output buffer (`out` is cleared, its
/// capacity retained) with two reusable scratch buffers for the prescan
/// body and its Huffman pass. Byte-for-byte identical to the allocating
/// path: candidate encodings differ only in payload (headers are equal
/// length), so the winner is selected by comparing payload sizes.
pub fn compress_bitmap_into(
    words: &[u64],
    nbits: usize,
    prescan: bool,
    out: &mut Vec<u8>,
    sa: &mut Vec<u8>,
    sb: &mut Vec<u8>,
) {
    debug_assert!(words.len() == nbits.div_ceil(64));
    out.clear();
    let raw_payload = words.len() * 8;

    let mut mode = MODE_RAW;
    if prescan {
        // Pre-scan: classify words, RLE same-class runs -> `sa`.
        sa.clear();
        let mut i = 0usize;
        while i < words.len() {
            let class = classify(words[i], tail_mask(nbits, i, words.len()));
            let mut j = i + 1;
            while j < words.len() && classify(words[j], tail_mask(nbits, j, words.len())) == class {
                j += 1;
            }
            let run = (j - i) as u64;
            varint::write_u64(sa, class | (run << 2));
            if class == CLASS_MIXED {
                for &w in &words[i..j] {
                    sa.extend_from_slice(&w.to_le_bytes());
                }
            }
            i = j;
        }
        // Algorithm 2 line 17: lossless-encode the prescan result when it
        // wins; fall back to the raw words when even the prescan loses.
        huffman::encode_into(sa, sb);
        if sb.len() < sa.len() && sb.len() < raw_payload {
            mode = MODE_PRESCAN_HUFF;
        } else if sa.len() < raw_payload {
            mode = MODE_PRESCAN;
        }
    }

    varint::write_u64(out, nbits as u64);
    out.push(mode);
    match mode {
        MODE_PRESCAN_HUFF => out.extend_from_slice(sb),
        MODE_PRESCAN => out.extend_from_slice(sa),
        _ => {
            out.reserve(raw_payload);
            for &w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
    }
}

/// Inverse of [`compress_bitmap`]: returns `(words, nbits)`.
pub fn decompress_bitmap(bytes: &[u8]) -> Result<(Vec<u64>, usize)> {
    let mut words = Vec::new();
    let mut hbuf = Vec::new();
    let nbits = decompress_bitmap_into(bytes, &mut words, &mut hbuf)?;
    Ok((words, nbits))
}

/// [`decompress_bitmap`] into a reused word buffer (`words` is cleared,
/// capacity retained); `hbuf` is a reusable scratch for the Huffman pass.
/// Returns the bit count.
pub fn decompress_bitmap_into(
    bytes: &[u8],
    words: &mut Vec<u64>,
    hbuf: &mut Vec<u8>,
) -> Result<usize> {
    let mut pos = 0usize;
    let nbits = varint::read_u64(bytes, &mut pos)? as usize;
    let mode = *bytes
        .get(pos)
        .ok_or_else(|| Error::Codec("bitmap: missing mode".into()))?;
    pos += 1;
    let n_words = nbits.div_ceil(64);
    words.clear();
    match mode {
        MODE_RAW => {
            let need = n_words * 8;
            if bytes.len() < pos + need {
                return Err(Error::Codec("bitmap: truncated raw words".into()));
            }
            words.reserve(n_words);
            words.extend(
                bytes[pos..pos + need]
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap())),
            );
            Ok(nbits)
        }
        MODE_PRESCAN => {
            decode_prescan(&bytes[pos..], nbits, n_words, words)?;
            Ok(nbits)
        }
        MODE_PRESCAN_HUFF => {
            huffman::decode_into(&bytes[pos..], hbuf)?;
            decode_prescan(hbuf, nbits, n_words, words)?;
            Ok(nbits)
        }
        other => Err(Error::Codec(format!("bitmap: unknown mode {other}"))),
    }
}

fn decode_prescan(body: &[u8], nbits: usize, n_words: usize, words: &mut Vec<u64>) -> Result<()> {
    words.reserve(n_words);
    let mut pos = 0usize;
    while words.len() < n_words {
        let tag = varint::read_u64(body, &mut pos)?;
        let class = tag & 0b11;
        let run = (tag >> 2) as usize;
        if run == 0 || words.len() + run > n_words {
            return Err(Error::Codec("bitmap: bad run".into()));
        }
        match class {
            CLASS_ZERO => words.extend(std::iter::repeat(0u64).take(run)),
            CLASS_ONES => words.extend(std::iter::repeat(u64::MAX).take(run)),
            CLASS_MIXED => {
                if body.len() < pos + run * 8 {
                    return Err(Error::Codec("bitmap: truncated mixed words".into()));
                }
                for c in body[pos..pos + run * 8].chunks_exact(8) {
                    words.push(u64::from_le_bytes(c.try_into().unwrap()));
                }
                pos += run * 8;
            }
            _ => return Err(Error::Codec("bitmap: bad class".into())),
        }
    }
    // Mask padding bits of the tail word so ALL-1 runs reconstruct exactly.
    if nbits % 64 != 0 {
        if let Some(last) = words.last_mut() {
            *last &= (1u64 << (nbits % 64)) - 1;
        }
    }
    Ok(())
}

/// Class of one word; the tail word is classified with padding masked out.
#[inline]
fn classify(word: u64, mask: u64) -> u64 {
    let w = word & mask;
    if w == 0 {
        CLASS_ZERO
    } else if w == mask {
        CLASS_ONES
    } else {
        CLASS_MIXED
    }
}

#[inline]
fn tail_mask(nbits: usize, word_idx: usize, n_words: usize) -> u64 {
    if word_idx + 1 == n_words && nbits % 64 != 0 {
        (1u64 << (nbits % 64)) - 1
    } else {
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    fn roundtrip(bits: &[bool], prescan: bool) {
        let (words, nbits) = pack_bits(bits.iter().copied());
        let enc = compress_bitmap(&words, nbits, prescan);
        let (got_words, got_nbits) = decompress_bitmap(&enc).unwrap();
        assert_eq!(got_nbits, nbits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(get_bit(&got_words, i), b, "bit {i}");
        }
    }

    #[test]
    fn roundtrip_patterns() {
        for prescan in [false, true] {
            roundtrip(&[], prescan);
            roundtrip(&[true], prescan);
            roundtrip(&vec![false; 1000], prescan);
            roundtrip(&vec![true; 1000], prescan);
            roundtrip(&(0..1000).map(|i| i % 3 == 0).collect::<Vec<_>>(), prescan);
            roundtrip(&(0..63).map(|i| i % 2 == 0).collect::<Vec<_>>(), prescan);
            roundtrip(&(0..65).map(|i| i == 64).collect::<Vec<_>>(), prescan);
        }
    }

    #[test]
    fn long_constant_runs_compress_massively() {
        // The paper's observation: sign repeats over extensive distances.
        let mut bits = vec![false; 100_000];
        for b in bits.iter_mut().skip(60_000).take(30_000) {
            *b = true;
        }
        let (words, nbits) = pack_bits(bits.iter().copied());
        let enc = compress_bitmap(&words, nbits, true);
        assert!(enc.len() < 100, "prescan output {} bytes", enc.len());
        roundtrip(&bits, true);
    }

    #[test]
    fn random_bitmap_never_blows_up() {
        let mut rng = SplitMix64::new(4);
        let bits: Vec<bool> = (0..50_000).map(|_| rng.next_f64() < 0.5).collect();
        let (words, nbits) = pack_bits(bits.iter().copied());
        let enc = compress_bitmap(&words, nbits, true);
        // Must fall back to <= raw + small header.
        assert!(enc.len() <= words.len() * 8 + 16);
        roundtrip(&bits, true);
    }

    #[test]
    fn prescan_beats_raw_on_sparse_signs() {
        let mut rng = SplitMix64::new(5);
        let bits: Vec<bool> = (0..50_000).map(|_| rng.next_f64() < 0.001).collect();
        let (words, nbits) = pack_bits(bits.iter().copied());
        let pre = compress_bitmap(&words, nbits, true);
        let raw = compress_bitmap(&words, nbits, false);
        assert!(pre.len() * 4 < raw.len(), "pre {} raw {}", pre.len(), raw.len());
    }

    #[test]
    fn corrupt_input_errors() {
        assert!(decompress_bitmap(&[]).is_err());
        let (words, nbits) = pack_bits([true, false, true].into_iter());
        let enc = compress_bitmap(&words, nbits, true);
        assert!(decompress_bitmap(&enc[..enc.len() - 1]).is_err() || enc.len() == 1);
    }

    #[test]
    fn tail_word_all_ones_classified_correctly() {
        // 70 bits all ones: tail word has 6 live bits; prescan must treat
        // it as ALL-1 despite zero padding.
        let bits = vec![true; 70];
        let (words, nbits) = pack_bits(bits.iter().copied());
        let enc = compress_bitmap(&words, nbits, true);
        let (got, _) = decompress_bitmap(&enc).unwrap();
        for i in 0..70 {
            assert!(get_bit(&got, i));
        }
    }
}
