//! Absolute-error-bounded lossy codec (`|x̂ - x| <= eb`).
//!
//! Linear-scaling quantization (`code = round(x / (2 eb))`, reconstruction
//! `x̂ = code * 2 eb`) + the shared Lorenzo/zig-zag/Huffman residual coder.
//! This is the mode existing GPU compressors ship (§2.2) and the core the
//! SC19-Sim baseline prototype uses (SZ-style prediction + quantization).
//!
//! Values whose quantized magnitude would overflow the code range, and
//! non-finite values, take the *outlier escape*: their exact bits ship in a
//! side table and their slot holds code 0 — so the bound holds for every
//! element, not just typical ones.

use super::lossless::varint;
use super::{residual, CodecScratch, MODE_ABS};
use crate::types::{Error, Result};

/// Quantized codes above this magnitude go to the outlier table (guards
/// both i64 overflow and precision loss in `code * 2eb`). Shared with
/// the SIMD quantizer, whose exact-conversion trick also relies on
/// codes staying below 2^52.
pub(crate) const MAX_CODE: f64 = 4.0e15;

/// Compress `data` under absolute error bound `eb` into a fresh buffer.
pub fn compress(data: &[f64], eb: f64) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    compress_into_with(data, eb, &mut out, &mut CodecScratch::new())?;
    Ok(out)
}

/// [`compress`] into a reused output buffer (`out` is cleared, capacity
/// retained) with all intermediates drawn from `scratch`. Byte-for-byte
/// identical to the allocating path.
pub fn compress_into_with(
    data: &[f64],
    eb: f64,
    out: &mut Vec<u8>,
    s: &mut CodecScratch,
) -> Result<()> {
    if !(eb > 0.0) || !eb.is_finite() {
        return Err(Error::Codec(format!("absolute codec needs eb > 0, got {eb}")));
    }
    let twoeb = 2.0 * eb;
    let simd = s.simd;
    simd.quant_abs(data, twoeb, &mut s.codes, &mut s.outliers);

    out.clear();
    out.push(MODE_ABS);
    out.extend_from_slice(&eb.to_le_bytes());
    varint::write_u64(out, s.outliers.len() as u64);
    let mut prev = 0usize;
    for &(idx, x) in s.outliers.iter() {
        varint::write_u64(out, (idx - prev) as u64);
        out.extend_from_slice(&x.to_le_bytes());
        prev = idx;
    }
    residual::encode_into(&s.codes, out, &mut s.buf_a, &mut s.buf_b, &mut s.delta, simd);
    Ok(())
}

/// Decoded element count — header peek only (no residual decode).
pub fn decoded_len(bytes: &[u8]) -> Result<usize> {
    if bytes.first() != Some(&MODE_ABS) {
        return Err(Error::Codec("not an absolute-mode payload".into()));
    }
    let (_, mut pos) = super::parse_mode_param(bytes, "abs")?;
    super::parse_outliers(bytes, &mut pos, None, "abs")?;
    residual::encoded_count(&bytes[pos..])
}

/// Decompress an absolute-bound stream into a fresh vector.
pub fn decompress(bytes: &[u8]) -> Result<Vec<f64>> {
    let mut data = vec![0.0f64; decoded_len(bytes)?];
    decompress_into_with(bytes, &mut data, &mut CodecScratch::new())?;
    Ok(data)
}

/// [`decompress`] directly into `out`, which must hold exactly
/// [`decoded_len`] elements; every slot is overwritten.
pub fn decompress_into_with(bytes: &[u8], out: &mut [f64], s: &mut CodecScratch) -> Result<()> {
    if bytes.first() != Some(&MODE_ABS) {
        return Err(Error::Codec("not an absolute-mode payload".into()));
    }
    let (eb, mut pos) = super::parse_mode_param(bytes, "abs")?;
    super::parse_outliers(bytes, &mut pos, Some(&mut s.outliers), "abs")?;
    residual::decode_into(&bytes[pos..], &mut s.codes, &mut s.buf_a)?;
    if out.len() != s.codes.len() {
        return Err(Error::Codec(format!(
            "abs: output buffer holds {} elements, payload has {}",
            out.len(),
            s.codes.len()
        )));
    }
    let twoeb = 2.0 * eb;
    s.simd.dequant_abs(&s.codes, twoeb, out);
    for &(idx, x) in &s.outliers {
        *out.get_mut(idx)
            .ok_or_else(|| Error::Codec("abs: outlier index out of range".into()))? = x;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    fn max_abs_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn bound_holds_on_gaussian_data() {
        let mut rng = SplitMix64::new(1);
        let data: Vec<f64> = (0..50_000).map(|_| rng.next_gaussian()).collect();
        for eb in [1e-1, 1e-3, 1e-6] {
            let enc = compress(&data, eb).unwrap();
            let dec = decompress(&enc).unwrap();
            assert_eq!(dec.len(), data.len());
            assert!(max_abs_err(&data, &dec) <= eb * (1.0 + 1e-12), "eb={eb}");
        }
    }

    #[test]
    fn zeros_reconstruct_exactly() {
        let data = vec![0.0f64; 10_000];
        let enc = compress(&data, 1e-3).unwrap();
        assert!(enc.len() < 64, "all-zero plane took {} bytes", enc.len());
        assert!(decompress(&enc).unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn outliers_roundtrip_exactly() {
        let mut data = vec![1.0f64; 100];
        data[3] = f64::INFINITY;
        data[50] = f64::NEG_INFINITY;
        data[70] = 1e300; // overflows code range at eb=1e-9
        let enc = compress(&data, 1e-9).unwrap();
        let dec = decompress(&enc).unwrap();
        assert_eq!(dec[3], f64::INFINITY);
        assert_eq!(dec[50], f64::NEG_INFINITY);
        assert_eq!(dec[70], 1e300);
        assert!((dec[0] - 1.0).abs() <= 1e-9);
    }

    #[test]
    fn nan_roundtrips_via_outlier_table() {
        let mut data = vec![0.5f64; 10];
        data[7] = f64::NAN;
        let dec = decompress(&compress(&data, 1e-3).unwrap()).unwrap();
        assert!(dec[7].is_nan());
    }

    #[test]
    fn smooth_data_compresses_hard() {
        let data: Vec<f64> = (0..100_000).map(|i| (i as f64 * 1e-4).sin()).collect();
        let enc = compress(&data, 1e-4).unwrap();
        let ratio = (data.len() * 8) as f64 / enc.len() as f64;
        assert!(ratio > 8.0, "ratio {ratio}");
    }

    #[test]
    fn invalid_eb_rejected() {
        assert!(compress(&[1.0], 0.0).is_err());
        assert!(compress(&[1.0], -1.0).is_err());
        assert!(compress(&[1.0], f64::NAN).is_err());
    }

    #[test]
    fn empty_plane() {
        let enc = compress(&[], 1e-3).unwrap();
        assert_eq!(decompress(&enc).unwrap(), Vec::<f64>::new());
    }
}
