//! Amplitude-aware adaptive error control (ROADMAP "fidelity as an
//! input"): a per-block error-budget controller that turns a whole-run
//! fidelity target into per-encode point-wise bounds.
//!
//! ## Budget ledger math
//!
//! For a normalized state and the point-wise relative codec, an encode of
//! block `k` at bound `b_k` perturbs each amplitude by at most `b_k·|x|`,
//! so the stage-wide L2 error is bounded by
//!
//! ```text
//! err_s <= sqrt( Σ_k m_k · b_k² )      m_k = Σ_{i in block k} |x_i|²
//! ```
//!
//! (`m_k` is the block's *amplitude mass* — its share of the state's L2
//! norm). Stage errors compose additively under unitary evolution (gates
//! never amplify an error vector's norm), so a run of `S` encode stages
//! satisfies `‖ψ̂-ψ‖ <= Σ_s err_s`, and a terminal L2 error of `ε` keeps
//! fidelity `|⟨ψ|ψ̂⟩|² >= 1 - 2ε` (first order). The controller therefore
//! works in linear ε units with total budget
//!
//! ```text
//! ε_total = (1 - fidelity_target) / 2
//! ```
//!
//! and runs a headroom ledger: each stage draws
//! `ε_s = headroom / stages_remaining`; each encode of block `k` charges
//! `m_k·b_k²` against `ε_s²`; when the stage's last encode lands, the
//! *unspent* remainder `ε_s - sqrt(Σ m_k b_k²)` flows back into the
//! headroom for later stages. Bounds are allocated so the stage charge
//! can never exceed its draw:
//!
//! * [`ErrorPolicy::Amplitude`] — `b_k = ε_s / sqrt(K·max(m_k, tiny))`
//!   (K = block count): heavy blocks get tight bounds, near-zero blocks
//!   loose ones, and `Σ_k m_k·b_k² <= ε_s²` by construction.
//! * [`ErrorPolicy::Global`] — one uniform `b = ε_s` per stage (the mass
//!   fractions sum to 1, so the stage charge is again `<= ε_s²`); still
//!   target-driven and still refunding, just not amplitude-shaped.
//!
//! Every allocated bound is clamped to [`B_CAP`], which only lowers the
//! applied bound — the ledger is *conservative by construction*: at every
//! instant `spent + headroom <= ε_total` (pinned by the unit tests below
//! and by `tests/error_control.rs`).
//!
//! ## Interaction with the compressed-primary tier
//!
//! The memory layer may ask permission to *recompress* a cold
//! primary-resident block at a looser bound instead of spilling it
//! ([`BudgetController::approve_recompress`]). The controller treats that
//! as an extra encode: it draws a small fraction of the current headroom,
//! converts it to a bound via the block's recorded mass, and declines when
//! the headroom is exhausted, when the block was already recompressed
//! since its last encode (loop safety), or when the achievable bound is
//! not meaningfully looser than the payload's current one.

use std::collections::HashMap;
use std::sync::Mutex;

use super::{Codec, CodecKind};
use crate::types::{Error, Result};

/// Hard cap on any allocated point-wise bound. Beyond ~0.1 the codec's
/// log2-domain quantization has little left to gain and relative error
/// stops being "small"; the cap only ever tightens an allocation, so it
/// cannot break the budget invariant.
pub const B_CAP: f64 = 0.1;

/// Mass floor used when converting budget to a bound for a (near-)zero
/// mass block, so the division stays finite.
const TINY_MASS: f64 = 1e-12;

/// Fraction of the current headroom a single recompression may draw.
const RECOMPRESS_DRAW: f64 = 0.125;

/// A recompression must loosen the bound by at least this factor to be
/// worth re-encoding the block.
const RECOMPRESS_MIN_GAIN: f64 = 2.0;

/// How the error budget is distributed across blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorPolicy {
    /// One uniform bound per stage, derived from the fidelity target.
    Global,
    /// Per-block bounds shaped by amplitude mass (tight where the
    /// amplitudes live, loose where they don't).
    Amplitude,
}

impl Default for ErrorPolicy {
    fn default() -> Self {
        ErrorPolicy::Global
    }
}

impl std::str::FromStr for ErrorPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "global" => Ok(ErrorPolicy::Global),
            "amplitude" => Ok(ErrorPolicy::Amplitude),
            other => Err(Error::Config(format!(
                "unknown error policy '{other}' (expected global|amplitude)"
            ))),
        }
    }
}

impl std::fmt::Display for ErrorPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ErrorPolicy::Global => write!(f, "global"),
            ErrorPolicy::Amplitude => write!(f, "amplitude"),
        }
    }
}

/// Point-in-time controller accounting, absorbed into `Metrics` by the
/// engines at the end of a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct BudgetStats {
    /// Committed L2 error (linear ε units) across finalized stages and
    /// approved recompressions.
    pub spent: f64,
    /// The whole-run budget `(1 - target) / 2` (after any resume scaling).
    pub eps_total: f64,
    /// Tightest bound handed out (`0.0` when none were issued).
    pub bound_min: f64,
    /// Loosest bound handed out.
    pub bound_max: f64,
    /// Recompressions the controller approved.
    pub recompressions: u64,
}

#[derive(Debug)]
struct StageLedger {
    /// This stage's ε draw.
    eps: f64,
    /// Σ m_k·b_k² charged so far (squared-ε units).
    spent_sq: f64,
    /// Encodes still outstanding; the stage finalizes (refunds) at zero.
    pending: usize,
}

#[derive(Debug)]
struct Ledger {
    /// Unallocated ε.
    headroom: f64,
    /// Stages that have not yet drawn from the headroom.
    stages_remaining: usize,
    /// In-flight stage ledgers by stage key (cross-stage overlap keeps up
    /// to two alive at once).
    stages: HashMap<usize, StageLedger>,
    /// Last observed amplitude mass per block (refreshed at every encode).
    mass: Vec<f64>,
    /// Loop-safety latch: set by an approved recompression, cleared by the
    /// block's next regular encode.
    recompressed: Vec<bool>,
    /// Committed ε across finalized stages + recompressions.
    spent: f64,
    bound_min: f64,
    bound_max: f64,
    recompressions: u64,
}

/// The fidelity-target controller. One per engine run, shared (behind an
/// `Arc`) between the encode phases and the memory tier's recompression
/// hook; all state sits behind one short-critical-section mutex.
///
/// ```
/// use bmqsim::compress::budget::{BudgetController, ErrorPolicy};
/// use bmqsim::compress::Codec;
///
/// // 4 blocks, 3 encode stages, fidelity target 0.999.
/// let ctl = BudgetController::new(
///     ErrorPolicy::Amplitude, Codec::paper_default(), 0.999, 4, 3);
/// ctl.begin_stage(0, 4);
/// // A block holding all the mass gets a tight bound…
/// let tight = ctl.bound_for(0, 0, 1.0);
/// // …an empty block gets a loose one.
/// let loose = ctl.bound_for(0, 1, 0.0);
/// assert!(tight < loose);
/// ```
#[derive(Debug)]
pub struct BudgetController {
    policy: ErrorPolicy,
    base: Codec,
    eps_total: f64,
    num_blocks: usize,
    inner: Mutex<Ledger>,
}

impl BudgetController {
    /// Build a controller for `num_blocks` blocks and `total_stages`
    /// encode stages (count the initial state compression as a stage).
    ///
    /// `fidelity_target` must be in `(0, 1)` and `base.kind` must be
    /// [`CodecKind::PointwiseRel`] — the ledger math is written for the
    /// point-wise relative bound (`SimConfig::validate` enforces both
    /// before an engine ever constructs one).
    pub fn new(
        policy: ErrorPolicy,
        base: Codec,
        fidelity_target: f64,
        num_blocks: usize,
        total_stages: usize,
    ) -> Self {
        debug_assert!(fidelity_target > 0.0 && fidelity_target < 1.0);
        debug_assert_eq!(base.kind, CodecKind::PointwiseRel);
        let eps_total = (1.0 - fidelity_target) / 2.0;
        BudgetController {
            policy,
            base,
            eps_total,
            num_blocks: num_blocks.max(1),
            inner: Mutex::new(Ledger {
                headroom: eps_total,
                stages_remaining: total_stages.max(1),
                stages: HashMap::new(),
                mass: vec![0.0; num_blocks.max(1)],
                recompressed: vec![false; num_blocks.max(1)],
                spent: 0.0,
                bound_min: f64::INFINITY,
                bound_max: 0.0,
                recompressions: 0,
            }),
        }
    }

    /// Scale the remaining budget by `frac` (a resumed run grants itself
    /// only the fraction of ε proportional to the stages it still has to
    /// run — conservative, since the pre-crash lineage spent at most the
    /// complementary share; see DESIGN.md "Adaptive error control").
    pub fn scale_budget(&self, frac: f64) {
        let mut g = self.lock();
        let frac = frac.clamp(0.0, 1.0);
        g.headroom *= frac;
    }

    /// The codec bounds are derived from, with the stock global bound.
    pub fn base_codec(&self) -> Codec {
        self.base
    }

    /// The configured distribution policy.
    pub fn policy(&self) -> ErrorPolicy {
        self.policy
    }

    /// The whole-run linear error budget.
    pub fn eps_total(&self) -> f64 {
        self.eps_total
    }

    /// Currently unallocated budget (test/report hook).
    pub fn headroom(&self) -> f64 {
        self.lock().headroom
    }

    /// Committed error so far (test/report hook).
    pub fn spent(&self) -> f64 {
        self.lock().spent
    }

    /// Open stage `key`'s ledger: draw `headroom / stages_remaining` and
    /// expect exactly `expected_encodes` calls to
    /// [`BudgetController::bound_for`] with this key. Called from the
    /// engine's (sequential) submission thread, so two overlapped stages
    /// draw in order.
    pub fn begin_stage(&self, key: usize, expected_encodes: usize) {
        let mut g = self.lock();
        let remaining = g.stages_remaining.max(1);
        let eps = (g.headroom / remaining as f64).max(0.0);
        g.headroom -= eps;
        g.stages_remaining = g.stages_remaining.saturating_sub(1);
        g.stages.insert(
            key,
            StageLedger { eps, spent_sq: 0.0, pending: expected_encodes.max(1) },
        );
    }

    /// Allocate the point-wise bound for encoding `block` (with fresh
    /// amplitude mass `mass`) in stage `key`, charge the ledger, and
    /// finalize the stage (refunding unspent ε) when this was its last
    /// outstanding encode.
    pub fn bound_for(&self, key: usize, block: usize, mass: f64) -> f64 {
        let mut g = self.lock();
        if block < g.mass.len() {
            g.mass[block] = mass;
            g.recompressed[block] = false;
        }
        let k = self.num_blocks as f64;
        let stage = match g.stages.get_mut(&key) {
            Some(s) => s,
            // Defensive: an encode for a never-opened stage gets the base
            // bound and charges nothing (cannot happen via the engines).
            None => return self.base.error_bound,
        };
        let bound = match self.policy {
            ErrorPolicy::Global => stage.eps.min(B_CAP),
            ErrorPolicy::Amplitude => {
                (stage.eps / (k * mass.max(TINY_MASS)).sqrt()).min(B_CAP)
            }
        };
        stage.spent_sq += mass * bound * bound;
        stage.pending -= 1;
        if stage.pending == 0 {
            let used = stage.spent_sq.max(0.0).sqrt().min(stage.eps);
            let eps = stage.eps;
            g.stages.remove(&key);
            g.headroom += eps - used;
            g.spent += used;
        }
        g.bound_min = g.bound_min.min(bound);
        g.bound_max = g.bound_max.max(bound);
        bound
    }

    /// Ask permission to recompress primary-resident `block` at a looser
    /// bound instead of spilling it. `current_bound` is the bound embedded
    /// in the block's present payload. Returns the approved bound, or
    /// `None` when the controller declines (exhausted headroom, a repeat
    /// request since the block's last encode, or too little to gain).
    pub fn approve_recompress(&self, block: usize, current_bound: f64) -> Option<f64> {
        let mut g = self.lock();
        if block >= g.mass.len() || g.recompressed[block] {
            return None;
        }
        let draw = g.headroom * RECOMPRESS_DRAW;
        if draw <= 0.0 {
            return None;
        }
        let m_eff = g.mass[block].max(TINY_MASS);
        let bound = (draw / m_eff.sqrt()).min(B_CAP);
        if bound < current_bound * RECOMPRESS_MIN_GAIN {
            return None;
        }
        let cost = m_eff.sqrt() * bound; // <= draw <= headroom by construction
        g.headroom -= cost;
        g.spent += cost;
        g.recompressed[block] = true;
        g.recompressions += 1;
        g.bound_min = g.bound_min.min(bound);
        g.bound_max = g.bound_max.max(bound);
        Some(bound)
    }

    /// Snapshot the accounting for the metrics report.
    pub fn stats(&self) -> BudgetStats {
        let g = self.lock();
        BudgetStats {
            spent: g.spent,
            eps_total: self.eps_total,
            bound_min: if g.bound_min.is_finite() { g.bound_min } else { 0.0 },
            bound_max: g.bound_max,
            recompressions: g.recompressions,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ledger> {
        // Same poison policy as the store: a panicking encode thread must
        // not wedge its siblings; the ledger is valid at every step.
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    fn ctl(policy: ErrorPolicy, blocks: usize, stages: usize) -> BudgetController {
        BudgetController::new(policy, Codec::paper_default(), 0.999, blocks, stages)
    }

    /// The required conservativeness invariant: at every instant the
    /// committed error plus the unallocated headroom never exceeds the
    /// whole-run budget — i.e. the sum of per-block allocations can never
    /// outgrow what the fidelity target permits.
    #[test]
    fn ledger_is_conservative_at_every_stage() {
        for policy in [ErrorPolicy::Global, ErrorPolicy::Amplitude] {
            let blocks = 8;
            let stages = 12;
            let c = ctl(policy, blocks, stages);
            let eps = c.eps_total();
            let mut rng = SplitMix64::new(42);
            for s in 0..stages {
                c.begin_stage(s, blocks);
                let mut masses: Vec<f64> =
                    (0..blocks).map(|_| rng.next_f64()).collect();
                let tot: f64 = masses.iter().sum();
                for m in &mut masses {
                    *m /= tot; // normalized state
                }
                for (b, &m) in masses.iter().enumerate() {
                    let bound = c.bound_for(s, b, m);
                    assert!(bound > 0.0 && bound <= B_CAP, "{policy:?}");
                    // Mid-stage: spent tracks finalized work only, but
                    // spent + headroom can never exceed the total.
                    assert!(
                        c.spent() + c.headroom() <= eps + 1e-15,
                        "{policy:?} stage {s} block {b}"
                    );
                }
                assert!(c.spent() <= eps + 1e-15, "{policy:?} stage {s}");
            }
            assert!(c.spent() <= eps + 1e-15, "{policy:?} terminal");
        }
    }

    /// Unspent stage budget flows back: a stage of zero-mass blocks
    /// refunds (almost) its whole draw, so later stages draw more than a
    /// naive equal split would give them.
    #[test]
    fn unspent_budget_is_redistributed() {
        let c = ctl(ErrorPolicy::Amplitude, 4, 2);
        let eps = c.eps_total();
        c.begin_stage(0, 4);
        let naive_second_draw = eps / 2.0;
        for b in 0..4 {
            c.bound_for(0, b, 0.0); // near-zero mass: tiny charge
        }
        // After the refund nearly the whole budget is available again.
        assert!(c.headroom() > naive_second_draw * 1.9);
        c.begin_stage(1, 4);
        for b in 0..4 {
            c.bound_for(1, b, 0.25);
        }
        assert!(c.spent() + c.headroom() <= eps + 1e-15);
    }

    #[test]
    fn amplitude_policy_shapes_bounds_by_mass() {
        let c = ctl(ErrorPolicy::Amplitude, 4, 1);
        c.begin_stage(0, 4);
        let heavy = c.bound_for(0, 0, 0.97);
        let light = c.bound_for(0, 1, 0.01);
        let zero = c.bound_for(0, 2, 0.0);
        assert!(heavy < light, "heavy {heavy} light {light}");
        assert!(light <= zero, "light {light} zero {zero}");
        let s = c.stats();
        assert_eq!(s.bound_min, heavy);
        assert_eq!(s.bound_max, zero.min(B_CAP));
    }

    #[test]
    fn global_policy_is_uniform_within_a_stage() {
        let c = ctl(ErrorPolicy::Global, 4, 2);
        c.begin_stage(0, 4);
        let bounds: Vec<f64> =
            (0..4).map(|b| c.bound_for(0, b, 0.25)).collect();
        assert!(bounds.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn recompress_is_latched_and_budgeted() {
        let c = ctl(ErrorPolicy::Amplitude, 4, 4);
        c.begin_stage(0, 4);
        for b in 0..4 {
            // Near-zero mass: the stage charges ~nothing and refunds its
            // whole draw, leaving ample headroom for recompressions.
            c.bound_for(0, b, 0.0);
        }
        let before = c.headroom();
        assert!(before > 0.0);
        let approved = c.approve_recompress(1, 1e-3).expect("first request approved");
        assert!(approved > 2e-3 && approved <= B_CAP);
        assert!(c.headroom() < before);
        // Loop safety: a second request before the block is re-encoded is
        // refused…
        assert!(c.approve_recompress(1, approved).is_none());
        // …and the latch clears at the next regular encode.
        c.begin_stage(1, 4);
        c.bound_for(1, 1, 0.0);
        assert!(c.approve_recompress(1, 1e-3).is_some());
        assert_eq!(c.stats().recompressions, 2);
        assert!(c.spent() + c.headroom() <= c.eps_total() + 1e-15);
    }

    #[test]
    fn recompress_declines_marginal_gains() {
        let c = ctl(ErrorPolicy::Amplitude, 2, 2);
        c.begin_stage(0, 2);
        c.bound_for(0, 0, 1.0);
        // Headroom remains (stage 1's share), but the achievable bound
        // for a full-mass block is ~eps-scale: asking to "loosen" a
        // payload already at the cap is declined on the gain check.
        assert!(c.headroom() > 0.0);
        assert!(c.approve_recompress(0, B_CAP).is_none());
    }

    #[test]
    fn resume_scaling_shrinks_the_budget() {
        let c = ctl(ErrorPolicy::Global, 4, 10);
        let full = c.headroom();
        c.scale_budget(0.25);
        assert!((c.headroom() - full * 0.25).abs() < 1e-18);
    }

    #[test]
    fn policy_parses_and_prints() {
        assert_eq!("global".parse::<ErrorPolicy>().unwrap(), ErrorPolicy::Global);
        assert_eq!("amplitude".parse::<ErrorPolicy>().unwrap(), ErrorPolicy::Amplitude);
        assert!("belady".parse::<ErrorPolicy>().is_err());
        assert_eq!(ErrorPolicy::Amplitude.to_string(), "amplitude");
        assert_eq!(ErrorPolicy::default(), ErrorPolicy::Global);
    }
}
