//! Algorithm 2 (paper §4.3): point-wise **relative** error-bounded
//! compression — the first such mode for a GPU-era lossy codec, and the
//! piece that makes compression safe for state-vector fidelity.
//!
//! Per element: sign bit → bitmap (pre-scanned, §4.3's `__ballot` analogue);
//! magnitude → `log2` domain, where an absolute bound `b_a = log2(1 + b_r)`
//! realizes the relative bound `b_r` (Liang et al. transformation, Eq. 1-2);
//! log-domain values → linear-scaling quantization + shared residual coder.
//!
//! Deviation from the paper's literal pseudo-code, documented in DESIGN.md:
//! exact zeros get their own (pre-scanned) bitmap instead of a reserved
//! quantizer code. State vectors are typically zero-dominated, so this (a)
//! reconstructs zeros exactly, (b) removes the giant sentinel jumps from
//! the code stream, and (c) lets the all-zero-block case collapse to a few
//! bytes — the mechanism behind cat/ghz/bv's 400-700x ratios (Fig. 9).
//! Non-finite magnitudes use an exact-bits outlier table like the absolute
//! codec.
//!
//! Guarantee (tested property): for every finite nonzero `x`,
//! `|decompress(compress(x)) - x| / |x| <= b_r`; zeros and non-finite
//! values round-trip exactly; signs are always preserved.

use super::lossless::{bitmap, varint};
use super::{residual, CodecScratch, MODE_POINTWISE};
use crate::types::{Error, Result};

/// Guard for the quantized log-magnitude (|log2(x)| <= 1100 for f64, so
/// codes stay well inside i64 for any sane `b_r`).
const MAX_CODE: f64 = 4.0e15;

/// Compress `data` under point-wise relative bound `b_r` into a fresh buffer.
pub fn compress(data: &[f64], b_r: f64, prescan: bool) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    compress_into_with(data, b_r, prescan, &mut out, &mut CodecScratch::new())?;
    Ok(out)
}

/// [`compress`] into a reused output buffer (`out` is cleared, capacity
/// retained) with all intermediates — bitmap words, quantized codes,
/// entropy-stage bytes — drawn from `scratch`. Byte-for-byte identical to
/// the allocating path.
pub fn compress_into_with(
    data: &[f64],
    b_r: f64,
    prescan: bool,
    out: &mut Vec<u8>,
    s: &mut CodecScratch,
) -> Result<()> {
    if !(b_r > 0.0) || !b_r.is_finite() {
        return Err(Error::Codec(format!("pointwise codec needs b_r > 0, got {b_r}")));
    }
    // b_a = log2(1 + b_r): the absolute bound in log2 space (Eq. 2).
    let b_a = (1.0 + b_r).log2();
    let inv_twoba = 1.0 / (2.0 * b_a);

    let n = data.len();
    let CodecScratch { codes, outliers, sign_words, zero_words, buf_a, buf_b, buf_c, delta, simd } =
        s;
    let simd: &'static crate::simd::SimdOps = *simd;
    simd.pack_sign_bits(data, sign_words);
    simd.pack_zero_bits(data, zero_words);

    // Quantize nonzero magnitudes in log2 space. The code stream is sized
    // from the zero-bitmap popcount, not `n`: zeros carry no code, and
    // state vectors are typically zero-dominated. (The log2/exp2 transform
    // itself stays scalar — it is the oracle-policy libm boundary.)
    let zeros = simd.popcount_words(zero_words);
    codes.clear();
    codes.reserve(n - zeros);
    outliers.clear();
    for (i, &x) in data.iter().enumerate() {
        if x == 0.0 {
            continue; // carried by the zero bitmap
        }
        if !x.is_finite() {
            outliers.push((i, x));
            codes.push(0);
            continue;
        }
        let q = x.abs().log2() * inv_twoba;
        if q.abs() > MAX_CODE {
            outliers.push((i, x));
            codes.push(0);
        } else {
            // round-half-away-from-zero without the libm round() call
            // (perf §Perf): as-cast truncates toward zero, so adding a
            // signed 0.5 first reproduces f64::round exactly for |q| within
            // MAX_CODE.
            codes.push((q + 0.5f64.copysign(q)) as i64);
        }
    }

    out.clear();
    out.push(MODE_POINTWISE);
    out.extend_from_slice(&b_r.to_le_bytes());
    varint::write_u64(out, n as u64);
    bitmap::compress_bitmap_into(sign_words, n, prescan, buf_c, buf_a, buf_b);
    varint::write_u64(out, buf_c.len() as u64);
    out.extend_from_slice(buf_c);
    bitmap::compress_bitmap_into(zero_words, n, prescan, buf_c, buf_a, buf_b);
    varint::write_u64(out, buf_c.len() as u64);
    out.extend_from_slice(buf_c);
    varint::write_u64(out, outliers.len() as u64);
    let mut prev = 0usize;
    for &(idx, x) in outliers.iter() {
        varint::write_u64(out, (idx - prev) as u64);
        out.extend_from_slice(&x.to_le_bytes());
        prev = idx;
    }
    residual::encode_into(codes, out, buf_a, buf_b, delta, simd);
    Ok(())
}

/// Decoded element count — header peek only (mode byte + `b_r` + `n`).
pub fn decoded_len(bytes: &[u8]) -> Result<usize> {
    if bytes.first() != Some(&MODE_POINTWISE) {
        return Err(Error::Codec("not a pointwise-mode payload".into()));
    }
    let (_, mut pos) = super::parse_mode_param(bytes, "pointwise")?;
    Ok(varint::read_u64(bytes, &mut pos)? as usize)
}

/// Decompress a point-wise-relative stream into a fresh vector.
pub fn decompress(bytes: &[u8]) -> Result<Vec<f64>> {
    let mut data = vec![0.0f64; decoded_len(bytes)?];
    decompress_into_with(bytes, &mut data, &mut CodecScratch::new())?;
    Ok(data)
}

/// [`decompress`] directly into `out`, which must hold exactly
/// [`decoded_len`] elements; every slot (including exact zeros) is
/// overwritten, so a dirty buffer is fine.
pub fn decompress_into_with(bytes: &[u8], out: &mut [f64], s: &mut CodecScratch) -> Result<()> {
    if bytes.first() != Some(&MODE_POINTWISE) {
        return Err(Error::Codec("not a pointwise-mode payload".into()));
    }
    let (b_r, mut pos) = super::parse_mode_param(bytes, "pointwise")?;
    let n = varint::read_u64(bytes, &mut pos)? as usize;
    if out.len() != n {
        return Err(Error::Codec(format!(
            "pointwise: output buffer holds {} elements, payload has {n}",
            out.len()
        )));
    }

    let CodecScratch { codes, outliers, sign_words, zero_words, buf_a, .. } = s;

    let sign_len = varint::read_u64(bytes, &mut pos)? as usize;
    let sign_bits = bitmap::decompress_bitmap_into(
        bytes
            .get(pos..pos + sign_len)
            .ok_or_else(|| Error::Codec("pointwise: truncated sign bitmap".into()))?,
        sign_words,
        buf_a,
    )?;
    pos += sign_len;
    let zero_len = varint::read_u64(bytes, &mut pos)? as usize;
    let zero_bits = bitmap::decompress_bitmap_into(
        bytes
            .get(pos..pos + zero_len)
            .ok_or_else(|| Error::Codec("pointwise: truncated zero bitmap".into()))?,
        zero_words,
        buf_a,
    )?;
    pos += zero_len;
    if sign_bits != n || zero_bits != n {
        return Err(Error::Codec("pointwise: bitmap length mismatch".into()));
    }

    super::parse_outliers(bytes, &mut pos, Some(&mut *outliers), "pointwise")?;

    residual::decode_into(&bytes[pos..], codes, buf_a)?;
    let b_a = (1.0 + b_r).log2();
    let twoba = 2.0 * b_a;

    let mut ci = 0usize;
    // Perf (§Perf): word-level bitmap walk + last-code memo. Quantum
    // amplitudes repeat magnitudes heavily (uniform superpositions,
    // symmetric states), so consecutive equal codes skip the exp2 call;
    // all-zero bitmap words skip the per-bit test entirely.
    let mut last_code = i64::MIN;
    let mut last_mag = 0.0f64;
    for (w, &zword) in zero_words.iter().enumerate() {
        let sword = sign_words[w];
        let base = w * 64;
        let end = (base + 64).min(n);
        if zword == 0 {
            for (i, slot) in out[base..end].iter_mut().enumerate() {
                let code = *codes
                    .get(ci)
                    .ok_or_else(|| Error::Codec("pointwise: code stream short".into()))?;
                ci += 1;
                if code != last_code {
                    last_code = code;
                    last_mag = (code as f64 * twoba).exp2();
                }
                *slot = if sword & (1 << i) != 0 { -last_mag } else { last_mag };
            }
        } else {
            for (i, slot) in out[base..end].iter_mut().enumerate() {
                if zword & (1 << i) != 0 {
                    *slot = 0.0; // exact zero (written: the buffer may be dirty)
                    continue;
                }
                let code = *codes
                    .get(ci)
                    .ok_or_else(|| Error::Codec("pointwise: code stream short".into()))?;
                ci += 1;
                if code != last_code {
                    last_code = code;
                    last_mag = (code as f64 * twoba).exp2();
                }
                *slot = if sword & (1 << i) != 0 { -last_mag } else { last_mag };
            }
        }
    }
    if ci != codes.len() {
        return Err(Error::Codec("pointwise: code stream long".into()));
    }
    for &(idx, x) in outliers.iter() {
        // Outlier slots were quantized as code 0; restore exact bits (the
        // sign bitmap already matches x's sign, but exact bits win).
        *out.get_mut(idx)
            .ok_or_else(|| Error::Codec("pointwise: outlier index out of range".into()))? = x;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    fn check_bound(data: &[f64], b_r: f64, prescan: bool) -> usize {
        let enc = compress(data, b_r, prescan).unwrap();
        let dec = decompress(&enc).unwrap();
        assert_eq!(dec.len(), data.len());
        for (i, (&x, &y)) in data.iter().zip(&dec).enumerate() {
            if x == 0.0 {
                assert_eq!(y, 0.0, "zero at {i} not exact");
            } else if !x.is_finite() {
                assert!(x.to_bits() == y.to_bits(), "non-finite at {i}");
            } else {
                let rel = (y - x).abs() / x.abs();
                assert!(rel <= b_r * (1.0 + 1e-9), "idx {i}: rel {rel} > {b_r}");
                assert_eq!(x < 0.0, y < 0.0, "sign flip at {i}");
            }
        }
        enc.len()
    }

    #[test]
    fn bound_holds_across_magnitudes() {
        let mut rng = SplitMix64::new(1);
        // Amplitude-like data spanning 60 decades + salted zeros.
        let data: Vec<f64> = (0..30_000)
            .map(|i| {
                if i % 11 == 0 {
                    0.0
                } else {
                    let mag = 10f64.powf(rng.next_f64() * 60.0 - 45.0);
                    if rng.next_f64() < 0.5 {
                        -mag
                    } else {
                        mag
                    }
                }
            })
            .collect();
        for b_r in [1e-2, 1e-3, 1e-4] {
            check_bound(&data, b_r, true);
            check_bound(&data, b_r, false);
        }
    }

    #[test]
    fn all_zero_plane_is_tiny() {
        let data = vec![0.0f64; 1 << 16];
        let len = check_bound(&data, 1e-3, true);
        assert!(len < 64, "all-zero plane took {len} bytes");
    }

    #[test]
    fn sparse_plane_compresses_like_paper_sparse_circuits() {
        // cat/ghz/bv-like: two nonzeros in a sea of zeros -> huge ratio.
        let mut data = vec![0.0f64; 1 << 16];
        data[0] = std::f64::consts::FRAC_1_SQRT_2;
        data[(1 << 16) - 1] = -std::f64::consts::FRAC_1_SQRT_2;
        let len = check_bound(&data, 1e-3, true);
        let ratio = (data.len() * 8) as f64 / len as f64;
        assert!(ratio > 400.0, "sparse ratio {ratio}");
    }

    #[test]
    fn uniform_superposition_plane() {
        // qft-like: all amplitudes equal magnitude -> constant codes,
        // should compress extremely well too.
        let n = 1 << 14;
        let v = (1.0 / n as f64).sqrt();
        let data = vec![v; n];
        let len = check_bound(&data, 1e-3, true);
        assert!(len < 200, "uniform plane took {len} bytes");
    }

    #[test]
    fn dense_random_plane_bound_and_ratio() {
        let mut rng = SplitMix64::new(2);
        let data: Vec<f64> = (0..1 << 14).map(|_| rng.next_gaussian() * 1e-2).collect();
        let len = check_bound(&data, 1e-3, true);
        let ratio = (data.len() * 8) as f64 / len as f64;
        // Random data in log domain still beats raw f64 (≈2.4-4x typical).
        assert!(ratio > 1.8, "dense ratio {ratio}");
    }

    #[test]
    fn negative_zero_treated_as_zero() {
        let data = vec![-0.0f64, 0.0, 1.0];
        let dec = decompress(&compress(&data, 1e-3, true).unwrap()).unwrap();
        assert_eq!(dec[0], 0.0);
        assert_eq!(dec[1], 0.0);
    }

    #[test]
    fn subnormals_respect_bound() {
        let data = vec![f64::MIN_POSITIVE / 8.0, -f64::MIN_POSITIVE / 1024.0, 1e-300];
        check_bound(&data, 1e-3, true);
    }

    #[test]
    fn nonfinite_values_roundtrip() {
        let data = vec![1.0, f64::INFINITY, -1.0, f64::NEG_INFINITY, 0.5];
        check_bound(&data, 1e-3, true);
    }

    #[test]
    fn invalid_bound_rejected() {
        assert!(compress(&[1.0], 0.0, true).is_err());
        assert!(compress(&[1.0], -0.5, true).is_err());
    }

    #[test]
    fn truncation_detected() {
        let data: Vec<f64> = (0..100).map(|i| i as f64 - 50.0).collect();
        let enc = compress(&data, 1e-3, true).unwrap();
        for cut in [1usize, 5, 20] {
            if cut < enc.len() {
                assert!(decompress(&enc[..enc.len() - cut]).is_err());
            }
        }
    }

    #[test]
    fn idempotent_after_first_roundtrip() {
        // Re-compressing a reconstruction must be lossless from then on —
        // the property that stops stage-to-stage error accumulation once a
        // block stops being updated.
        let mut rng = SplitMix64::new(3);
        let data: Vec<f64> = (0..5000).map(|_| rng.next_gaussian()).collect();
        let r1 = decompress(&compress(&data, 1e-3, true).unwrap()).unwrap();
        let r2 = decompress(&compress(&r1, 1e-3, true).unwrap()).unwrap();
        for (a, b) in r1.iter().zip(&r2) {
            let rel = ((a - b) / a.max(1e-300)).abs();
            assert!(rel < 1e-12, "{a} vs {b}");
        }
    }
}
