//! Shared residual coder for quantized integer code streams.
//!
//! Pipeline: 1-D Lorenzo prediction (`delta_i = code_i - code_{i-1}`) →
//! zig-zag → LEB128 varints with a zero-run escape (token 0 + run length;
//! long constant stretches — e.g. the all-zero tails of sparse state
//! vectors — collapse to a few bytes) → optional canonical-Huffman pass,
//! kept only when it shrinks the stream.

use super::lossless::{huffman, varint};
use crate::types::{Error, Result};

const FLAG_HUFFMAN: u8 = 1;

/// Encode a code stream. Deterministic; `decode` is its exact inverse.
pub fn encode(codes: &[i64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len() + 10);
    let (mut sa, mut sb) = (Vec::new(), Vec::new());
    let mut zz = Vec::new();
    encode_into(codes, &mut out, &mut sa, &mut sb, &mut zz, crate::simd::dispatch());
    out
}

/// [`encode`] *appending* to `out` (callers frame the stream themselves),
/// with reusable scratch buffers for the delta body, its Huffman pass and
/// the zigzag-delta stage. Emits the identical byte stream as [`encode`].
///
/// Split into two stages so the data-parallel part vectorizes: stage 1
/// computes `zigzag(code_i - code_{i-1})` for the whole stream (SIMD);
/// stage 2 is the inherently serial run/varint emitter. `zz[i] == 0` iff
/// `delta_i == 0` (zigzag is a bijection fixing 0), so the zero-run scan
/// reads the transformed stream directly.
pub fn encode_into(
    codes: &[i64],
    out: &mut Vec<u8>,
    sa: &mut Vec<u8>,
    sb: &mut Vec<u8>,
    zz: &mut Vec<u64>,
    simd: &crate::simd::SimdOps,
) {
    sa.clear();
    simd.zigzag_deltas(codes, zz);
    let mut i = 0usize;
    while i < codes.len() {
        if zz[i] == 0 {
            // Count the zero-delta run (constant stretch).
            let mut run = 1usize;
            while i + run < codes.len() && zz[i + run] == 0 {
                run += 1;
            }
            varint::write_u64(sa, 0);
            varint::write_u64(sa, run as u64);
            i += run;
        } else {
            // zigzag(delta) == 0 iff delta == 0, which the run branch owns,
            // so nonzero deltas never collide with the run marker 0.
            varint::write_u64(sa, zz[i]);
            i += 1;
        }
    }

    huffman::encode_into(sa, sb);
    varint::write_u64(out, codes.len() as u64);
    if sb.len() < sa.len() {
        out.push(FLAG_HUFFMAN);
        out.extend_from_slice(sb);
    } else {
        out.push(0);
        out.extend_from_slice(sa);
    }
}

/// Decode a stream produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Vec<i64>> {
    let mut codes = Vec::new();
    let mut hbuf = Vec::new();
    decode_into(bytes, &mut codes, &mut hbuf)?;
    Ok(codes)
}

/// [`decode`] into a reused code buffer (`codes` is cleared, capacity
/// retained); `hbuf` is a reusable scratch for the Huffman pass.
pub fn decode_into(bytes: &[u8], codes: &mut Vec<i64>, hbuf: &mut Vec<u8>) -> Result<()> {
    let mut pos = 0usize;
    let n = varint::read_u64(bytes, &mut pos)? as usize;
    let flags = *bytes
        .get(pos)
        .ok_or_else(|| Error::Codec("residual: missing flags".into()))?;
    pos += 1;
    let body: &[u8] = if flags & FLAG_HUFFMAN != 0 {
        huffman::decode_into(&bytes[pos..], hbuf)?;
        hbuf.as_slice()
    } else {
        &bytes[pos..]
    };

    codes.clear();
    codes.reserve(n);
    let mut prev = 0i64;
    let mut bpos = 0usize;
    while codes.len() < n {
        let tok = varint::read_u64(body, &mut bpos)?;
        if tok == 0 {
            let run = varint::read_u64(body, &mut bpos)? as usize;
            if run == 0 || codes.len() + run > n {
                return Err(Error::Codec("residual: bad zero run".into()));
            }
            codes.extend(std::iter::repeat(prev).take(run));
        } else {
            prev = prev.wrapping_add(varint::unzigzag(tok));
            codes.push(prev);
        }
    }
    Ok(())
}

/// Number of codes in an encoded stream (the leading varint) — a cheap
/// peek used by allocating decompress wrappers to size their output.
pub fn encoded_count(bytes: &[u8]) -> Result<usize> {
    let mut pos = 0usize;
    Ok(varint::read_u64(bytes, &mut pos)? as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SplitMix64;

    fn roundtrip(codes: &[i64]) -> usize {
        let enc = encode(codes);
        assert_eq!(decode(&enc).unwrap(), codes);
        enc.len()
    }

    #[test]
    fn roundtrip_edges() {
        roundtrip(&[]);
        roundtrip(&[0]);
        roundtrip(&[i64::MAX, i64::MIN, 0, -1, 1]);
        roundtrip(&vec![42; 10_000]);
    }

    #[test]
    fn constant_stream_is_tiny() {
        let len = roundtrip(&vec![7i64; 100_000]);
        assert!(len < 32, "constant stream took {len} bytes");
    }

    #[test]
    fn smooth_stream_compresses() {
        // Slowly varying codes (what Lorenzo is for).
        let mut rng = SplitMix64::new(1);
        let mut codes = Vec::with_capacity(50_000);
        let mut v = 1000i64;
        for _ in 0..50_000 {
            v += (rng.next_u64() % 5) as i64 - 2;
            codes.push(v);
        }
        let len = roundtrip(&codes);
        assert!(len < 50_000, "smooth stream {len} bytes for 400KB raw");
    }

    #[test]
    fn random_stream_roundtrips() {
        let mut rng = SplitMix64::new(2);
        let codes: Vec<i64> = (0..20_000).map(|_| rng.next_u64() as i64).collect();
        roundtrip(&codes);
    }

    #[test]
    fn alternating_runs() {
        let mut codes = Vec::new();
        for block in 0..100 {
            codes.extend(std::iter::repeat(block as i64 * 3).take(97));
        }
        let len = roundtrip(&codes);
        assert!(len < 1200, "run-structured stream {len} bytes");
    }

    #[test]
    fn truncated_stream_errors() {
        let enc = encode(&[1, 2, 3, 4, 5]);
        for cut in 1..enc.len().min(4) {
            let r = decode(&enc[..enc.len() - cut]);
            assert!(r.is_err() || r.unwrap() != vec![1, 2, 3, 4, 5]);
        }
    }
}
