//! Crate-wide error type. Every fallible public API returns [`Result`].
//!
//! Hand-rolled `Display`/`Error` impls (the build environment vendors no
//! `thiserror`; see DESIGN.md substitutions).

/// Unified error for the simulator stack.
#[derive(Debug)]
pub enum Error {
    /// Invalid user/sim configuration (qubit counts, block sizes, ...).
    Config(String),

    /// Circuit construction or parsing problems.
    Circuit(String),

    /// OpenQASM parse failure with line information.
    Qasm { line: usize, msg: String },

    /// Compressed payload is corrupt or version-mismatched.
    Codec(String),

    /// The two-level memory manager ran out of both tiers.
    OutOfMemory(String),

    /// Secondary-tier (disk spill) I/O failure.
    Io(std::io::Error),

    /// PJRT/XLA runtime failure (artifact load, compile, execute).
    Xla(String),

    /// AOT artifact set is missing or inconsistent with the manifest.
    Artifact(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Circuit(m) => write!(f, "circuit error: {m}"),
            Error::Qasm { line, msg } => write!(f, "qasm parse error at line {line}: {msg}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::OutOfMemory(m) => write!(f, "out of memory: {m}"),
            Error::Io(e) => write!(f, "spill i/o error: {e}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad block size".into());
        assert_eq!(e.to_string(), "config error: bad block size");
        let e = Error::Qasm { line: 7, msg: "unknown gate foo".into() };
        assert!(e.to_string().contains("line 7"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
