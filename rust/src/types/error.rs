//! Crate-wide error type. Every fallible public API returns [`Result`].
//!
//! Hand-rolled `Display`/`Error` impls (the build environment vendors no
//! `thiserror`; see DESIGN.md substitutions).

/// Unified error for the simulator stack.
#[derive(Debug)]
pub enum Error {
    /// Invalid user/sim configuration (qubit counts, block sizes, ...).
    Config(String),

    /// Circuit construction or parsing problems.
    Circuit(String),

    /// OpenQASM parse failure with line information.
    Qasm { line: usize, msg: String },

    /// Compressed payload is corrupt or version-mismatched.
    Codec(String),

    /// The two-level memory manager ran out of both tiers.
    OutOfMemory(String),

    /// Spill / write-back machinery failure: secondary-tier I/O that
    /// exhausted its retries, a dead or wedged spill writer, a write-back
    /// queue that never drained. Distinct from [`Error::OutOfMemory`]
    /// (genuine budget exhaustion) — a disk hiccup is not an OOM. The
    /// originating `io::Error`, when one exists, is preserved as
    /// [`std::error::Error::source`].
    Spill { msg: String, source: Option<std::io::Error> },

    /// A spilled frame failed its integrity check on read (xxh64 /
    /// magic / length mismatch) and could not be recovered from the
    /// write-back retention ring — the on-disk bytes are corrupt.
    Corruption(String),

    /// Secondary-tier (disk spill) I/O failure.
    Io(std::io::Error),

    /// PJRT/XLA runtime failure (artifact load, compile, execute).
    Xla(String),

    /// AOT artifact set is missing or inconsistent with the manifest.
    Artifact(String),

    /// Checkpoint/restore failure: an unreadable or schema-mismatched
    /// manifest, a config fingerprint that does not match the resuming
    /// run, or a checkpoint directory with no intact snapshot. Distinct
    /// from [`Error::Corruption`] (torn frame *bytes*) so orchestrators
    /// can tell "this checkpoint cannot drive this run" apart from
    /// "the data on disk rotted".
    Checkpoint(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Circuit(m) => write!(f, "circuit error: {m}"),
            Error::Qasm { line, msg } => write!(f, "qasm parse error at line {line}: {msg}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::OutOfMemory(m) => write!(f, "out of memory: {m}"),
            Error::Spill { msg, source } => match source {
                Some(e) => write!(f, "spill error: {msg} ({e})"),
                None => write!(f, "spill error: {msg}"),
            },
            Error::Corruption(m) => write!(f, "corruption: {m}"),
            Error::Io(e) => write!(f, "spill i/o error: {e}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
        }
    }
}

impl Error {
    /// Spill failure without an underlying `io::Error` (wedged queue,
    /// dead writer, missing spill file).
    pub fn spill(msg: impl Into<String>) -> Self {
        Error::Spill { msg: msg.into(), source: None }
    }

    /// Spill failure caused by a concrete `io::Error` (kept as
    /// [`std::error::Error::source`]).
    pub fn spill_io(msg: impl Into<String>, source: std::io::Error) -> Self {
        Error::Spill { msg: msg.into(), source: Some(source) }
    }

    /// Checkpoint/restore failure.
    pub fn checkpoint(msg: impl Into<String>) -> Self {
        Error::Checkpoint(msg.into())
    }

    /// Process exit class for this error, so CI chaos jobs and
    /// orchestrators can dispatch on the failure *kind* without parsing
    /// stderr:
    ///
    /// - `2` — configuration / usage (bad flags, invalid circuit/QASM):
    ///   retrying will not help; fix the invocation.
    /// - `3` — storage-tier failure (spill I/O, corruption, OOM): the
    ///   host or disk is unhealthy; retry elsewhere.
    /// - `4` — checkpoint/restore: the snapshot cannot drive this run
    ///   (fingerprint mismatch, torn manifest with no fallback);
    ///   restart from scratch or point at a different checkpoint.
    /// - `1` — everything else.
    pub fn exit_class(&self) -> u8 {
        match self {
            Error::Config(_) | Error::Circuit(_) | Error::Qasm { .. } => 2,
            Error::OutOfMemory(_)
            | Error::Spill { .. }
            | Error::Corruption(_)
            | Error::Io(_) => 3,
            Error::Checkpoint(_) => 4,
            Error::Codec(_) | Error::Xla(_) | Error::Artifact(_) => 1,
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Spill { source: Some(e), .. } => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad block size".into());
        assert_eq!(e.to_string(), "config error: bad block size");
        let e = Error::Qasm { line: 7, msg: "unknown gate foo".into() };
        assert!(e.to_string().contains("line 7"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn spill_preserves_io_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::Interrupted, "EIO");
        let e = Error::spill_io("write of block 7 failed", io);
        assert!(e.to_string().starts_with("spill error: write of block 7 failed"));
        let src = e.source().expect("source must be preserved");
        assert!(src.to_string().contains("EIO"));
        let bare = Error::spill("write-back queue wedged");
        assert!(bare.source().is_none());
        assert_eq!(bare.to_string(), "spill error: write-back queue wedged");
    }

    #[test]
    fn corruption_displays() {
        let e = Error::Corruption("frame at 128: xxh64 mismatch".into());
        assert!(e.to_string().contains("corruption"));
        assert!(e.to_string().contains("xxh64"));
    }

    #[test]
    fn checkpoint_displays() {
        let e = Error::checkpoint("manifest schema 99 unsupported");
        assert_eq!(e.to_string(), "checkpoint error: manifest schema 99 unsupported");
        assert!(matches!(e, Error::Checkpoint(_)));
    }

    #[test]
    fn exit_classes_partition_the_taxonomy() {
        assert_eq!(Error::Config("x".into()).exit_class(), 2);
        assert_eq!(Error::Circuit("x".into()).exit_class(), 2);
        assert_eq!(Error::Qasm { line: 1, msg: "x".into() }.exit_class(), 2);
        assert_eq!(Error::OutOfMemory("x".into()).exit_class(), 3);
        assert_eq!(Error::spill("x").exit_class(), 3);
        assert_eq!(Error::Corruption("x".into()).exit_class(), 3);
        let io = std::io::Error::new(std::io::ErrorKind::Other, "x");
        assert_eq!(Error::Io(io).exit_class(), 3);
        assert_eq!(Error::checkpoint("x").exit_class(), 4);
        assert_eq!(Error::Codec("x".into()).exit_class(), 1);
        assert_eq!(Error::Xla("x".into()).exit_class(), 1);
        assert_eq!(Error::Artifact("x".into()).exit_class(), 1);
    }
}
