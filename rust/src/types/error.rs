//! Crate-wide error type. Every fallible public API returns [`Result`].

/// Unified error for the simulator stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Invalid user/sim configuration (qubit counts, block sizes, ...).
    #[error("config error: {0}")]
    Config(String),

    /// Circuit construction or parsing problems.
    #[error("circuit error: {0}")]
    Circuit(String),

    /// OpenQASM parse failure with line information.
    #[error("qasm parse error at line {line}: {msg}")]
    Qasm { line: usize, msg: String },

    /// Compressed payload is corrupt or version-mismatched.
    #[error("codec error: {0}")]
    Codec(String),

    /// The two-level memory manager ran out of both tiers.
    #[error("out of memory: {0}")]
    OutOfMemory(String),

    /// Secondary-tier (disk spill) I/O failure.
    #[error("spill i/o error: {0}")]
    Io(#[from] std::io::Error),

    /// PJRT/XLA runtime failure (artifact load, compile, execute).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// AOT artifact set is missing or inconsistent with the manifest.
    #[error("artifact error: {0}")]
    Artifact(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad block size".into());
        assert_eq!(e.to_string(), "config error: bad block size");
        let e = Error::Qasm { line: 7, msg: "unknown gate foo".into() };
        assert!(e.to_string().contains("line 7"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
