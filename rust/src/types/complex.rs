//! Minimal complex-number type for gate matrices and amplitude accounting.
//!
//! The state vector itself is stored as split re/im planes; `Complex` is the
//! boundary type for unitary matrices, fidelity inner products, and tests.

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// A double-precision complex number.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };
    pub const I: Complex = Complex { re: 0.0, im: 1.0 };

    pub const fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    /// `e^{i theta}` — the workhorse for phase/rotation gate matrices.
    pub fn cis(theta: f64) -> Self {
        Complex::new(theta.cos(), theta.sin())
    }

    pub fn conj(self) -> Self {
        Complex::new(self.re, -self.im)
    }

    /// Squared magnitude `|z|^2` (a probability when `z` is an amplitude).
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Scale by a real factor.
    pub fn scale(self, s: f64) -> Self {
        Complex::new(self.re * s, self.im * s)
    }

    /// True when both components are within `tol` of `other`'s.
    pub fn approx_eq(self, other: Complex, tol: f64) -> bool {
        (self.re - other.re).abs() <= tol && (self.im - other.im).abs() <= tol
    }
}

impl Add for Complex {
    type Output = Complex;
    fn add(self, rhs: Complex) -> Complex {
        Complex::new(self.re + rhs.re, self.im + rhs.im)
    }
}

impl AddAssign for Complex {
    fn add_assign(&mut self, rhs: Complex) {
        self.re += rhs.re;
        self.im += rhs.im;
    }
}

impl Sub for Complex {
    type Output = Complex;
    fn sub(self, rhs: Complex) -> Complex {
        Complex::new(self.re - rhs.re, self.im - rhs.im)
    }
}

impl Mul for Complex {
    type Output = Complex;
    fn mul(self, rhs: Complex) -> Complex {
        Complex::new(
            self.re * rhs.re - self.im * rhs.im,
            self.re * rhs.im + self.im * rhs.re,
        )
    }
}

impl Neg for Complex {
    type Output = Complex;
    fn neg(self) -> Complex {
        Complex::new(-self.re, -self.im)
    }
}

impl From<f64> for Complex {
    fn from(re: f64) -> Self {
        Complex::new(re, 0.0)
    }
}

impl std::fmt::Display for Complex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{:.6}+{:.6}i", self.re, self.im)
        } else {
            write!(f, "{:.6}-{:.6}i", self.re, -self.im)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let z = Complex::new(3.0, -4.0);
        assert_eq!(z + Complex::ZERO, z);
        assert_eq!(z * Complex::ONE, z);
        assert_eq!(z * Complex::I, Complex::new(4.0, 3.0));
        assert_eq!((z - z), Complex::ZERO);
        assert_eq!(-z, Complex::new(-3.0, 4.0));
    }

    #[test]
    fn norm_and_conj() {
        let z = Complex::new(3.0, 4.0);
        assert_eq!(z.norm_sq(), 25.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z * z.conj(), Complex::new(25.0, 0.0));
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..16 {
            let z = Complex::cis(k as f64 * 0.41);
            assert!((z.norm_sq() - 1.0).abs() < 1e-15);
        }
    }

    #[test]
    fn mul_matches_manual_expansion() {
        let a = Complex::new(1.5, -2.5);
        let b = Complex::new(-0.5, 4.0);
        let c = a * b;
        assert!((c.re - (1.5 * -0.5 - -2.5 * 4.0)).abs() < 1e-15);
        assert!((c.im - (1.5 * 4.0 + -2.5 * -0.5)).abs() < 1e-15);
    }
}
