//! Core value types shared across the simulator: complex amplitudes,
//! precision selection, deterministic RNG, and the crate-wide error type.
//!
//! Amplitude storage convention: the simulator keeps state vectors as
//! *split planes* (structure-of-arrays `re: Vec<f64>`, `im: Vec<f64>`)
//! rather than `Vec<Complex>`. This matches both the compressor (which
//! consumes plain float planes) and the AOT'd XLA kernels (whose operands
//! are separate re/im literals), so [`Complex`] appears mostly at API
//! boundaries (gate matrices, fidelity results).

mod complex;
mod error;
mod rng;

pub use complex::Complex;
pub use error::{Error, Result};
pub use rng::SplitMix64;

/// Floating-point precision of the state vector and artifacts.
///
/// The paper evaluates in float64 (noting cuQuantum's float32 gives it an
/// inherent speed edge, §5.5); both are supported end-to-end here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    /// Bytes per real scalar.
    pub fn scalar_bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }

    /// Bytes per complex amplitude (two scalars).
    pub fn amp_bytes(self) -> usize {
        2 * self.scalar_bytes()
    }

    /// The dtype tag used in `artifacts/manifest.json` module names.
    pub fn dtype_tag(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" | "float32" => Ok(Precision::F32),
            "f64" | "float64" => Ok(Precision::F64),
            other => Err(Error::Config(format!("unknown precision {other:?}"))),
        }
    }
}

/// Standard (uncompressed) state-vector memory requirement in bytes:
/// `2^(n+4)` for f64 (the paper's Fig. 9 baseline), `2^(n+3)` for f32.
pub fn standard_memory_bytes(n_qubits: usize, precision: Precision) -> u128 {
    (1u128 << n_qubits) * precision.amp_bytes() as u128
}

/// Human-readable byte size, used by the report tables.
pub fn fmt_bytes(b: u128) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_memory_matches_paper_formula() {
        // Paper §5.4: standard consumption is 2^(n+4) bytes (f64 amplitudes).
        assert_eq!(standard_memory_bytes(10, Precision::F64), 1 << 14);
        assert_eq!(standard_memory_bytes(33, Precision::F64), 1u128 << 37);
        assert_eq!(standard_memory_bytes(10, Precision::F32), 1 << 13);
    }

    #[test]
    fn precision_parsing() {
        assert_eq!("f64".parse::<Precision>().unwrap(), Precision::F64);
        assert_eq!("float32".parse::<Precision>().unwrap(), Precision::F32);
        assert!("f16".parse::<Precision>().is_err());
    }

    #[test]
    fn fmt_bytes_scales() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1 << 20), "1.00 MiB");
        assert_eq!(fmt_bytes(3 * (1 << 30) / 2), "1.50 GiB");
    }
}
