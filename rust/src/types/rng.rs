//! Deterministic, dependency-free PRNG.
//!
//! The build environment vendors no `rand` crate, so we carry a SplitMix64
//! (Steele et al., the JDK `SplittableRandom` mixer): tiny, fast, passes
//! BigCrush when used as a 64-bit stream, and — crucially for tests and
//! benchmarks — fully reproducible from a seed. Used for measurement
//! sampling, random-circuit/test-data generation, and the property-test
//! harness in `testutil`.

/// SplitMix64 PRNG. `Clone` yields an identical stream copy.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection-free multiply).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (used for synthetic codec inputs).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // Reference values from the canonical SplitMix64 with seed 0.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = SplitMix64::new(9);
        for bound in [1u64, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn split_streams_diverge() {
        let mut a = SplitMix64::new(1);
        let mut c = a.split();
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let cv: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(av, cv);
    }
}
