//! AVX2 4-lane f64 kernels, bit-identical to [`super::scalar`].
//!
//! Every kernel reproduces the scalar operation order exactly — no FMA
//! (contraction changes rounding), identical add/sub/mul association
//! trees, identical truncation semantics. The integer↔double conversion
//! recipes avoid `vcvttpd2qq` (AVX-512 only):
//!
//! - f64→i64 (`quant_abs`): round toward zero with `vroundpd`, then the
//!   2^52 magic-bias trick on the absolute value (exact for |v| < 2^52 —
//!   guaranteed because quantized codes are clamped to `MAX_CODE` = 4e15),
//!   then two's-complement negate the negative lanes.
//! - i64→f64 (`dequant_abs`): the split lo32/hi32 magic-constant method
//!   (exact over the full i64 range; the single rounding happens in the
//!   final add, matching the scalar `c as f64` round-to-nearest-even).
//!
//! Safety: every `#[target_feature]` function here is only reachable
//! through the AVX2 dispatch table, which `detect()` selects after
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("popcnt")`.

#![allow(unsafe_code)]

use super::scalar;
use crate::compress::lossless::varint;
use crate::compress::lossy::MAX_CODE;
use std::arch::x86_64::*;

const SIGN_BIT: f64 = -0.0;
/// Bit pattern of 2^52: the magic bias for exact f64↔i64 in [0, 2^52).
const MAGIC_LO: i64 = 0x4330000000000000;
/// High-half magic for the full-range i64→f64 conversion.
const MAGIC_HI32: i64 = 0x4530000080000000u64 as i64;
/// Combined magic (2^84 + 2^63 + 2^52) subtracted once from the hi part.
const MAGIC_ALL: i64 = 0x4530000080100000u64 as i64;

pub(super) fn quant_abs(
    data: &[f64],
    twoeb: f64,
    codes: &mut Vec<i64>,
    outliers: &mut Vec<(usize, f64)>,
) {
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { quant_abs_impl(data, twoeb, codes, outliers) }
}

#[target_feature(enable = "avx2")]
unsafe fn quant_abs_impl(
    data: &[f64],
    twoeb: f64,
    codes: &mut Vec<i64>,
    outliers: &mut Vec<(usize, f64)>,
) {
    let n = data.len();
    codes.clear();
    codes.resize(n, 0);
    outliers.clear();
    let sign = _mm256_set1_pd(SIGN_BIT);
    let half = _mm256_set1_pd(0.5);
    let vtwoeb = _mm256_set1_pd(twoeb);
    let inf = _mm256_set1_pd(f64::INFINITY);
    let vmax = _mm256_set1_pd(MAX_CODE);
    let magic = _mm256_set1_epi64x(MAGIC_LO);
    let cp = codes.as_mut_ptr();
    let dp = data.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let x = _mm256_loadu_pd(dp.add(i));
        let q = _mm256_div_pd(x, vtwoeb);
        let abs_x = _mm256_andnot_pd(sign, x);
        let abs_q = _mm256_andnot_pd(sign, q);
        // Escape lanes: !x.is_finite() (|x| >= inf or NaN, via NLT_UQ)
        // or |q| > MAX_CODE. Any escape sends the whole chunk through the
        // scalar path so the outlier push order matches the oracle.
        let nonfinite = _mm256_cmp_pd::<_CMP_NLT_UQ>(abs_x, inf);
        let overrange = _mm256_cmp_pd::<_CMP_GT_OQ>(abs_q, vmax);
        if _mm256_movemask_pd(_mm256_or_pd(nonfinite, overrange)) != 0 {
            for lane in 0..4 {
                let xv = *data.get_unchecked(i + lane);
                let qv = xv / twoeb;
                if !xv.is_finite() || qv.abs() > MAX_CODE {
                    outliers.push((i + lane, xv));
                } else {
                    *cp.add(i + lane) = (qv + 0.5f64.copysign(qv)) as i64;
                }
            }
        } else {
            // Scalar: (q + copysign(0.5, q)) as i64  — add then truncate.
            let signed_half = _mm256_or_pd(half, _mm256_and_pd(q, sign));
            let t = _mm256_round_pd::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(
                _mm256_add_pd(q, signed_half),
            );
            let abs_t = _mm256_andnot_pd(sign, t);
            // |t| <= MAX_CODE + 1 < 2^52, so abs_t + 2^52 is exact and its
            // mantissa bits are the integer value.
            let k = _mm256_sub_epi64(
                _mm256_castpd_si256(_mm256_add_pd(abs_t, _mm256_castsi256_pd(magic))),
                magic,
            );
            // Negate lanes where t < 0 (t = -0.0 has k = 0, so the mask
            // being false there is fine).
            let neg = _mm256_castpd_si256(_mm256_cmp_pd::<_CMP_LT_OQ>(t, _mm256_setzero_pd()));
            let v = _mm256_sub_epi64(_mm256_xor_si256(k, neg), neg);
            _mm256_storeu_si256(cp.add(i) as *mut __m256i, v);
        }
        i += 4;
    }
    while i < n {
        let xv = *data.get_unchecked(i);
        let qv = xv / twoeb;
        if !xv.is_finite() || qv.abs() > MAX_CODE {
            outliers.push((i, xv));
        } else {
            *cp.add(i) = (qv + 0.5f64.copysign(qv)) as i64;
        }
        i += 1;
    }
}

pub(super) fn dequant_abs(codes: &[i64], twoeb: f64, out: &mut [f64]) {
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { dequant_abs_impl(codes, twoeb, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn dequant_abs_impl(codes: &[i64], twoeb: f64, out: &mut [f64]) {
    let n = out.len().min(codes.len());
    let magic_lo = _mm256_set1_epi64x(MAGIC_LO);
    let magic_hi = _mm256_set1_epi64x(MAGIC_HI32);
    let magic_all = _mm256_castsi256_pd(_mm256_set1_epi64x(MAGIC_ALL));
    let lo_mask = _mm256_set1_epi64x(0xFFFF_FFFFi64);
    let vtwoeb = _mm256_set1_pd(twoeb);
    let cp = codes.as_ptr();
    let op = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let v = _mm256_loadu_si256(cp.add(i) as *const __m256i);
        // Full-range exact i64→f64: low 32 bits biased by 2^52, high 32
        // bits biased by 2^84+2^63; the final add performs the single
        // round-to-nearest step, matching scalar `c as f64`.
        let v_lo = _mm256_or_si256(_mm256_and_si256(v, lo_mask), magic_lo);
        let v_hi = _mm256_xor_si256(_mm256_srli_epi64::<32>(v), magic_hi);
        let f = _mm256_add_pd(
            _mm256_sub_pd(_mm256_castsi256_pd(v_hi), magic_all),
            _mm256_castsi256_pd(v_lo),
        );
        _mm256_storeu_pd(op.add(i), _mm256_mul_pd(f, vtwoeb));
        i += 4;
    }
    while i < n {
        *op.add(i) = *cp.add(i) as f64 * twoeb;
        i += 1;
    }
}

pub(super) fn pack_sign_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { pack_bits_impl::<true>(data, words) }
}

pub(super) fn pack_zero_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { pack_bits_impl::<false>(data, words) }
}

/// Shared bitmap builder: `SIGN` packs `is_sign_negative() && x != 0.0`,
/// otherwise `x == 0.0`. 16 four-lane groups fill one u64 word.
#[target_feature(enable = "avx2")]
unsafe fn pack_bits_impl<const SIGN: bool>(data: &[f64], words: &mut Vec<u64>) -> usize {
    let n = data.len();
    words.clear();
    words.reserve(n.div_ceil(64));
    let zero = _mm256_setzero_pd();
    let dp = data.as_ptr();
    let mut i = 0usize;
    while i + 64 <= n {
        let mut w = 0u64;
        for g in 0..16 {
            let x = _mm256_loadu_pd(dp.add(i + g * 4));
            let bits = if SIGN {
                // Sign bit set AND x != 0.0 (NEQ_UQ: true for NaN, false
                // for -0.0) — matches the scalar predicate exactly.
                (_mm256_movemask_pd(x) & _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_NEQ_UQ>(x, zero)))
                    as u64
            } else {
                _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_EQ_OQ>(x, zero)) as u64
            };
            w |= (bits & 0xF) << (g * 4);
        }
        words.push(w);
        i += 64;
    }
    if i < n {
        let mut w = 0u64;
        for (fill, &x) in data[i..].iter().enumerate() {
            let bit = if SIGN { x.is_sign_negative() && x != 0.0 } else { x == 0.0 };
            w |= (bit as u64) << fill;
        }
        words.push(w);
    }
    n
}

pub(super) fn popcount_words(words: &[u64]) -> usize {
    // SAFETY: table only selected after AVX2+POPCNT detection (module doc).
    unsafe { popcount_words_impl(words) }
}

#[target_feature(enable = "popcnt")]
unsafe fn popcount_words_impl(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

pub(super) fn zigzag_deltas(codes: &[i64], out: &mut Vec<u64>) {
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { zigzag_deltas_impl(codes, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn zigzag_deltas_impl(codes: &[i64], out: &mut Vec<u64>) {
    let n = codes.len();
    out.clear();
    out.resize(n, 0);
    if n == 0 {
        return;
    }
    out[0] = varint::zigzag(codes[0]);
    let cp = codes.as_ptr();
    let op = out.as_mut_ptr();
    let zero = _mm256_setzero_si256();
    let mut j = 1usize;
    while j + 4 <= n {
        let cur = _mm256_loadu_si256(cp.add(j) as *const __m256i);
        let prev = _mm256_loadu_si256(cp.add(j - 1) as *const __m256i);
        let d = _mm256_sub_epi64(cur, prev);
        // zigzag(d) = (d << 1) ^ (d >> 63); arithmetic 63-shift emulated
        // by the signed compare against zero (all-ones iff d < 0).
        let m = _mm256_cmpgt_epi64(zero, d);
        let zz = _mm256_xor_si256(_mm256_slli_epi64::<1>(d), m);
        _mm256_storeu_si256(op.add(j) as *mut __m256i, zz);
        j += 4;
    }
    while j < n {
        *op.add(j) = varint::zigzag((*cp.add(j)).wrapping_sub(*cp.add(j - 1)));
        j += 1;
    }
}

pub(super) fn dense_1q(m: &[f64; 8], re: &mut [f64], im: &mut [f64], bit: usize) {
    if bit < 4 {
        // 4-lane loads would straddle the (i, i|bit) pair boundary.
        return scalar::dense_1q(m, re, im, bit);
    }
    // SAFETY: table only selected after AVX2 detection (module doc).
    unsafe { dense_1q_impl(m, re, im, bit) }
}

#[target_feature(enable = "avx2")]
unsafe fn dense_1q_impl(m: &[f64; 8], re: &mut [f64], im: &mut [f64], bit: usize) {
    let m00r = _mm256_set1_pd(m[0]);
    let m00i = _mm256_set1_pd(m[1]);
    let m01r = _mm256_set1_pd(m[2]);
    let m01i = _mm256_set1_pd(m[3]);
    let m10r = _mm256_set1_pd(m[4]);
    let m10i = _mm256_set1_pd(m[5]);
    let m11r = _mm256_set1_pd(m[6]);
    let m11i = _mm256_set1_pd(m[7]);
    let len = re.len();
    let rp = re.as_mut_ptr();
    let ip = im.as_mut_ptr();
    let mut base = 0usize;
    while base < len {
        let mut i0 = base;
        while i0 < base + bit {
            let i1 = i0 | bit;
            let r0 = _mm256_loadu_pd(rp.add(i0));
            let v0 = _mm256_loadu_pd(ip.add(i0));
            let r1 = _mm256_loadu_pd(rp.add(i1));
            let v1 = _mm256_loadu_pd(ip.add(i1));
            // Scalar association tree: ((a*x - b*y) + c*z) - d*w, etc.
            let nr0 = _mm256_sub_pd(
                _mm256_add_pd(
                    _mm256_sub_pd(_mm256_mul_pd(m00r, r0), _mm256_mul_pd(m00i, v0)),
                    _mm256_mul_pd(m01r, r1),
                ),
                _mm256_mul_pd(m01i, v1),
            );
            let ni0 = _mm256_add_pd(
                _mm256_add_pd(
                    _mm256_add_pd(_mm256_mul_pd(m00r, v0), _mm256_mul_pd(m00i, r0)),
                    _mm256_mul_pd(m01r, v1),
                ),
                _mm256_mul_pd(m01i, r1),
            );
            let nr1 = _mm256_sub_pd(
                _mm256_add_pd(
                    _mm256_sub_pd(_mm256_mul_pd(m10r, r0), _mm256_mul_pd(m10i, v0)),
                    _mm256_mul_pd(m11r, r1),
                ),
                _mm256_mul_pd(m11i, v1),
            );
            let ni1 = _mm256_add_pd(
                _mm256_add_pd(
                    _mm256_add_pd(_mm256_mul_pd(m10r, v0), _mm256_mul_pd(m10i, r0)),
                    _mm256_mul_pd(m11r, v1),
                ),
                _mm256_mul_pd(m11i, r1),
            );
            _mm256_storeu_pd(rp.add(i0), nr0);
            _mm256_storeu_pd(ip.add(i0), ni0);
            _mm256_storeu_pd(rp.add(i1), nr1);
            _mm256_storeu_pd(ip.add(i1), ni1);
            i0 += 4;
        }
        base += bit << 1;
    }
}

pub(super) fn fused_kq_quad(
    re: &mut [f64],
    im: &mut [f64],
    base: usize,
    offs: &[usize; 8],
    mr: &[[f64; 8]; 8],
    mi: &[[f64; 8]; 8],
    dim: usize,
) {
    // SAFETY: table only selected after AVX2 detection (module doc);
    // caller guarantees the quad contract (see `FusedKqQuadFn`).
    unsafe { fused_kq_quad_impl(re, im, base, offs, mr, mi, dim) }
}

#[target_feature(enable = "avx2")]
unsafe fn fused_kq_quad_impl(
    re: &mut [f64],
    im: &mut [f64],
    base: usize,
    offs: &[usize; 8],
    mr: &[[f64; 8]; 8],
    mi: &[[f64; 8]; 8],
    dim: usize,
) {
    let rp = re.as_mut_ptr();
    let ip = im.as_mut_ptr();
    let mut vr = [_mm256_setzero_pd(); 8];
    let mut vi = [_mm256_setzero_pd(); 8];
    for s in 0..dim {
        let ix = base | offs[s];
        vr[s] = _mm256_loadu_pd(rp.add(ix));
        vi[s] = _mm256_loadu_pd(ip.add(ix));
    }
    for r in 0..dim {
        let mut ar = _mm256_setzero_pd();
        let mut ai = _mm256_setzero_pd();
        for s in 0..dim {
            let mre = _mm256_set1_pd(mr[r][s]);
            let mim = _mm256_set1_pd(mi[r][s]);
            // Scalar order: ar += m*vr - i*vi; ai += m*vi + i*vr.
            ar = _mm256_add_pd(ar, _mm256_sub_pd(_mm256_mul_pd(mre, vr[s]), _mm256_mul_pd(mim, vi[s])));
            ai = _mm256_add_pd(ai, _mm256_add_pd(_mm256_mul_pd(mre, vi[s]), _mm256_mul_pd(mim, vr[s])));
        }
        let ix = base | offs[r];
        _mm256_storeu_pd(rp.add(ix), ar);
        _mm256_storeu_pd(ip.add(ix), ai);
    }
}
