//! Runtime-dispatched SIMD kernels for the codec and gate hot loops.
//!
//! The paper's performance claim is that (de)compression runs at
//! memory-bandwidth speed so it can hide inside the decode/apply/encode
//! pipeline; on the CPU backend the scalar inner loops (quantization,
//! zero-bitmap build, residual zigzag packing, Huffman byte decode, and
//! the k≤3 fused unitary kernels) are the throughput floor. This module
//! lifts them with `std::arch` intrinsics behind a **one-time runtime
//! dispatch**:
//!
//! - [`detect`]-time feature probing (`is_x86_feature_detected!`) picks a
//!   [`SimdLevel`] once per process and caches it; the choice
//!   materializes as a `&'static` [`SimdOps`] function-pointer table.
//! - The scalar implementations in [`scalar`] are the **parity oracle**:
//!   always compiled, always reachable (non-x86 targets, the
//!   `BMQSIM_NO_SIMD` env kill switch, the `--no-simd` CLI flag), and the
//!   reference every vector kernel must match **bit-for-bit**. The
//!   byte-identical suites (`codec_into`, `fusion_parity`,
//!   `pipeline_parity`, `simd_parity`) enforce this.
//! - Vector kernels therefore never use FMA and reproduce the scalar
//!   operation order exactly (same rounding at every step); kernels whose
//!   scalar form is not bit-reproducible lane-wise (the `log2`/`exp2`
//!   pointwise transform) intentionally stay scalar.
//!
//! Tables are threaded through `CodecScratch` (captured at construction)
//! and consulted via [`dispatch`] in the gate kernels. Every plane-level
//! kernel invocation that routes through a non-scalar table bumps a
//! process-wide counter surfaced as `Metrics::simd_kernels_used`.

pub mod aligned;
mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod sse2;

pub use aligned::AlignedF64;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;

/// Instruction-set tier selected by [`detect`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar oracle (also the `BMQSIM_NO_SIMD` fallback).
    Scalar,
    /// x86-64 baseline 2-lane f64 kernels (always available on x86-64).
    Sse2,
    /// 4-lane f64 kernels; requires `avx2` **and** `popcnt`.
    Avx2,
}

impl SimdLevel {
    /// Human-readable tier name (used by `--no-simd` reporting and tests).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// Signature of the absolute-mode quantizer kernel: fills `codes` (one
/// i64 per input) and pushes `(index, value)` outliers in input order.
pub type QuantAbsFn = fn(&[f64], f64, &mut Vec<i64>, &mut Vec<(usize, f64)>);
/// Signature of the absolute-mode dequantizer: `out[i] = codes[i] as f64 * twoeb`.
pub type DequantAbsFn = fn(&[i64], f64, &mut [f64]);
/// Signature of the bitmap builders: packs one predicate bit per f64 into
/// LSB-first u64 words and returns the number of bits produced.
pub type PackBitsFn = fn(&[f64], &mut Vec<u64>) -> usize;
/// Signature of the bitmap popcount.
pub type PopcountFn = fn(&[u64]) -> usize;
/// Signature of the residual stage-1 kernel: `out[i] = zigzag(c[i] - c[i-1])`
/// with `c[-1] == 0`.
pub type ZigzagDeltasFn = fn(&[i64], &mut Vec<u64>);
/// Signature of the single-qubit dense kernel over split re/im planes.
/// The matrix is flattened `[m00r, m00i, m01r, m01i, m10r, m10i, m11r, m11i]`.
pub type Dense1qFn = fn(&[f64; 8], &mut [f64], &mut [f64], usize);
/// Signature of the fused k≤3 quad kernel: applies the dense `dim × dim`
/// unitary to the 4 consecutive subspace bases starting at `base`.
/// Caller guarantees `base % 4 == 0`, `bits[0] >= 2` (so the 4 bases are
/// memory-contiguous at every site offset) and in-bounds indices.
pub type FusedKqQuadFn =
    fn(&mut [f64], &mut [f64], usize, &[usize; 8], &[[f64; 8]; 8], &[[f64; 8]; 8], usize);

/// One dispatch table: the kernel set for a [`SimdLevel`], selected once
/// by [`dispatch`]. Fields are private so every call routes through the
/// counting methods; the raw quad pointer is exposed separately for
/// per-quad inner loops (counted once per plane by [`SimdOps::mark_used`]).
pub struct SimdOps {
    level: SimdLevel,
    quant_abs: QuantAbsFn,
    dequant_abs: DequantAbsFn,
    pack_sign_bits: PackBitsFn,
    pack_zero_bits: PackBitsFn,
    popcount_words: PopcountFn,
    zigzag_deltas: ZigzagDeltasFn,
    dense_1q: Dense1qFn,
    fused_kq_quad: FusedKqQuadFn,
    huffman_multi: bool,
}

impl std::fmt::Debug for SimdOps {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimdOps").field("level", &self.level).finish()
    }
}

impl SimdOps {
    /// Tier this table implements.
    pub fn level(&self) -> SimdLevel {
        self.level
    }

    /// True when this table is a vector tier (kernel invocations through
    /// it are counted in `Metrics::simd_kernels_used`).
    pub fn vectorized(&self) -> bool {
        self.level != SimdLevel::Scalar
    }

    fn note(&self) {
        if self.level != SimdLevel::Scalar {
            KERNELS_USED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one plane-level kernel invocation that bypassed the counting
    /// methods (per-quad inner loops using [`SimdOps::fused_kq_quad_fn`]).
    pub fn mark_used(&self) {
        self.note();
    }

    /// Absolute-mode quantization with error-bound clamp + outlier escape.
    pub fn quant_abs(
        &self,
        data: &[f64],
        twoeb: f64,
        codes: &mut Vec<i64>,
        outliers: &mut Vec<(usize, f64)>,
    ) {
        self.note();
        (self.quant_abs)(data, twoeb, codes, outliers)
    }

    /// Absolute-mode dequantization (`codes.len()` must equal `out.len()`).
    pub fn dequant_abs(&self, codes: &[i64], twoeb: f64, out: &mut [f64]) {
        self.note();
        (self.dequant_abs)(codes, twoeb, out)
    }

    /// Build the strict-negative sign bitmap directly from an f64 plane.
    pub fn pack_sign_bits(&self, data: &[f64], words: &mut Vec<u64>) -> usize {
        self.note();
        (self.pack_sign_bits)(data, words)
    }

    /// Build the exact-zero bitmap directly from an f64 plane.
    pub fn pack_zero_bits(&self, data: &[f64], words: &mut Vec<u64>) -> usize {
        self.note();
        (self.pack_zero_bits)(data, words)
    }

    /// Population count over bitmap words.
    pub fn popcount_words(&self, words: &[u64]) -> usize {
        self.note();
        (self.popcount_words)(words)
    }

    /// Residual stage 1: zigzag-encoded adjacent deltas of the code plane.
    pub fn zigzag_deltas(&self, codes: &[i64], out: &mut Vec<u64>) {
        self.note();
        (self.zigzag_deltas)(codes, out)
    }

    /// Dense single-qubit sweep over split planes.
    pub fn dense_1q(&self, m: &[f64; 8], re: &mut [f64], im: &mut [f64], bit: usize) {
        self.note();
        (self.dense_1q)(m, re, im, bit)
    }

    /// Raw fused-quad kernel pointer for per-quad inner loops; call
    /// [`SimdOps::mark_used`] once per plane-level sweep instead of per quad.
    pub fn fused_kq_quad_fn(&self) -> FusedKqQuadFn {
        self.fused_kq_quad
    }

    /// Whether the Huffman decoder should build the multi-symbol LUT.
    pub fn huffman_multi(&self) -> bool {
        self.huffman_multi
    }
}

static SCALAR_OPS: SimdOps = SimdOps {
    level: SimdLevel::Scalar,
    quant_abs: scalar::quant_abs,
    dequant_abs: scalar::dequant_abs,
    pack_sign_bits: scalar::pack_sign_bits,
    pack_zero_bits: scalar::pack_zero_bits,
    popcount_words: scalar::popcount_words,
    zigzag_deltas: scalar::zigzag_deltas,
    dense_1q: scalar::dense_1q,
    fused_kq_quad: scalar::fused_kq_quad,
    huffman_multi: false,
};

// SSE2 is part of the x86-64 baseline, so this tier needs no runtime
// probe — it is the floor on any x86-64 host. Kernels whose bit-exact
// recipe needs later ISAs keep the scalar oracle (quantize needs
// SSE4.1 `roundpd`; popcount needs the POPCNT flag).
#[cfg(target_arch = "x86_64")]
static SSE2_OPS: SimdOps = SimdOps {
    level: SimdLevel::Sse2,
    quant_abs: scalar::quant_abs,
    dequant_abs: sse2::dequant_abs,
    pack_sign_bits: sse2::pack_sign_bits,
    pack_zero_bits: sse2::pack_zero_bits,
    popcount_words: scalar::popcount_words,
    zigzag_deltas: sse2::zigzag_deltas,
    dense_1q: sse2::dense_1q,
    fused_kq_quad: sse2::fused_kq_quad,
    huffman_multi: true,
};

#[cfg(target_arch = "x86_64")]
static AVX2_OPS: SimdOps = SimdOps {
    level: SimdLevel::Avx2,
    quant_abs: avx2::quant_abs,
    dequant_abs: avx2::dequant_abs,
    pack_sign_bits: avx2::pack_sign_bits,
    pack_zero_bits: avx2::pack_zero_bits,
    popcount_words: avx2::popcount_words,
    zigzag_deltas: avx2::zigzag_deltas,
    dense_1q: avx2::dense_1q,
    fused_kq_quad: avx2::fused_kq_quad,
    huffman_multi: true,
};

static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
/// Runtime kill switch (`--no-simd`, [`disable_scope`]); independent of
/// the cached detection result so it can be toggled per engine run.
static ENABLED: AtomicBool = AtomicBool::new(true);
/// Process-wide count of kernel invocations routed through a vector table.
static KERNELS_USED: AtomicU64 = AtomicU64::new(0);

fn no_simd_env() -> bool {
    matches!(std::env::var("BMQSIM_NO_SIMD"), Ok(v) if !v.is_empty() && v != "0")
}

/// Probe CPU features once and cache the result for the process lifetime.
/// `BMQSIM_NO_SIMD=1` pins the scalar oracle regardless of hardware.
pub fn detect() -> SimdLevel {
    *DETECTED.get_or_init(|| {
        if no_simd_env() {
            return SimdLevel::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("popcnt")
            {
                SimdLevel::Avx2
            } else {
                SimdLevel::Sse2
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            SimdLevel::Scalar
        }
    })
}

/// Current runtime-enable state of the vector tiers.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle the vector tiers at runtime. Prefer [`disable_scope`], which
/// restores the previous state on drop.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// RAII kill switch used by the engines for `SimConfig::no_simd`: when
/// `disable` is true the vector tiers are switched off until the guard
/// drops (process-wide — concurrent runs in the same process fall back
/// to the scalar oracle for the duration, which is always byte-safe).
#[must_use = "the guard re-enables SIMD on drop"]
pub struct SimdGuard {
    restore: Option<bool>,
}

pub fn disable_scope(disable: bool) -> SimdGuard {
    if disable {
        let prev = ENABLED.swap(false, Ordering::Relaxed);
        SimdGuard { restore: Some(prev) }
    } else {
        SimdGuard { restore: None }
    }
}

impl Drop for SimdGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.restore {
            ENABLED.store(prev, Ordering::Relaxed);
        }
    }
}

/// The active dispatch table: the detected tier, unless the runtime kill
/// switch is engaged (then the scalar oracle).
pub fn dispatch() -> &'static SimdOps {
    if !enabled() {
        return &SCALAR_OPS;
    }
    match detect() {
        SimdLevel::Scalar => &SCALAR_OPS,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => &SSE2_OPS,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => &AVX2_OPS,
        #[cfg(not(target_arch = "x86_64"))]
        _ => &SCALAR_OPS,
    }
}

/// The scalar oracle table, regardless of detection (differential tests
/// compare [`dispatch`] against this).
pub fn scalar_ops() -> &'static SimdOps {
    &SCALAR_OPS
}

/// Tier the next [`dispatch`] call would route to.
pub fn active_level() -> SimdLevel {
    dispatch().level
}

/// Monotonic count of vector-kernel invocations since process start.
/// Engines snapshot this around a run to fill `Metrics::simd_kernels_used`
/// (best-effort: the counter is process-wide, so concurrent runs share it).
pub fn kernels_used() -> u64 {
    KERNELS_USED.load(Ordering::Relaxed)
}

/// Credit `n` kernel invocations from call sites that cannot route
/// through a table method (the Huffman multi-symbol decode).
pub(crate) fn note_kernels(n: u64) {
    KERNELS_USED.fetch_add(n, Ordering::Relaxed);
}

/// Alignment probe backing the scratch-arena debug_asserts: cache-line
/// (64-byte) aligned pointers keep every vector load/store on the fast
/// aligned path even though the kernels use unaligned load instructions.
pub fn is_aligned_64<T>(p: *const T) -> bool {
    (p as usize) % 64 == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_is_cached_and_coherent() {
        let first = detect();
        assert_eq!(detect(), first, "detection must be stable");
        if no_simd_env() {
            assert_eq!(first, SimdLevel::Scalar);
        }
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(first, SimdLevel::Scalar);
    }

    #[test]
    fn dispatch_honors_kill_switch() {
        // Serialize against other toggling tests via the guard API itself.
        let guard = disable_scope(true);
        assert_eq!(dispatch().level(), SimdLevel::Scalar);
        assert_eq!(active_level(), SimdLevel::Scalar);
        drop(guard);
        assert_eq!(dispatch().level(), detect());
    }

    #[test]
    fn guard_restores_previous_state() {
        let outer = disable_scope(false);
        let was = enabled();
        {
            let _inner = disable_scope(true);
            assert!(!enabled());
        }
        assert_eq!(enabled(), was);
        drop(outer);
    }

    #[test]
    fn counter_counts_only_vector_tables() {
        let before = kernels_used();
        let mut words = Vec::new();
        scalar_ops().pack_zero_bits(&[0.0; 128], &mut words);
        assert_eq!(kernels_used(), before, "scalar table must not count");
        let ops = dispatch();
        ops.popcount_words(&words);
        let after = kernels_used();
        if ops.vectorized() {
            assert!(after > before);
        }
        assert!(kernels_used() >= after, "counter is monotonic");
    }

    #[test]
    fn level_names_are_stable() {
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Sse2.name(), "sse2");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
    }

    #[test]
    fn alignment_probe() {
        #[repr(align(64))]
        struct A([u8; 64]);
        let a = A([0; 64]);
        assert!(is_aligned_64(a.0.as_ptr()));
        assert!(!is_aligned_64(unsafe { a.0.as_ptr().add(8) }));
    }
}
