//! SSE2 2-lane f64 kernels, bit-identical to [`super::scalar`].
//!
//! SSE2 is part of the x86-64 baseline, so these functions need no
//! runtime probe and no `#[target_feature]` — they are safe wrappers over
//! always-available intrinsics. Kernels without a bit-exact SSE2 recipe
//! stay on the scalar oracle in the SSE2 table: quantization needs the
//! SSE4.1 `roundpd` truncation, and popcount the POPCNT flag (see the
//! table construction in `super`).

#![allow(unsafe_code)]

use crate::compress::lossless::varint;
use std::arch::x86_64::*;

const MAGIC_LO: i64 = 0x4330000000000000;
const MAGIC_HI32: i64 = 0x4530000080000000u64 as i64;
const MAGIC_ALL: i64 = 0x4530000080100000u64 as i64;

pub(super) fn dequant_abs(codes: &[i64], twoeb: f64, out: &mut [f64]) {
    let n = out.len().min(codes.len());
    // SAFETY: SSE2 is unconditionally available on x86-64; pointer
    // arithmetic stays within the two slices.
    unsafe {
        let magic_lo = _mm_set1_epi64x(MAGIC_LO);
        let magic_hi = _mm_set1_epi64x(MAGIC_HI32);
        let magic_all = _mm_castsi128_pd(_mm_set1_epi64x(MAGIC_ALL));
        let lo_mask = _mm_set1_epi64x(0xFFFF_FFFFi64);
        let vtwoeb = _mm_set1_pd(twoeb);
        let cp = codes.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0usize;
        while i + 2 <= n {
            let v = _mm_loadu_si128(cp.add(i) as *const __m128i);
            let v_lo = _mm_or_si128(_mm_and_si128(v, lo_mask), magic_lo);
            let v_hi = _mm_xor_si128(_mm_srli_epi64::<32>(v), magic_hi);
            let f = _mm_add_pd(
                _mm_sub_pd(_mm_castsi128_pd(v_hi), magic_all),
                _mm_castsi128_pd(v_lo),
            );
            _mm_storeu_pd(op.add(i), _mm_mul_pd(f, vtwoeb));
            i += 2;
        }
        while i < n {
            *op.add(i) = *cp.add(i) as f64 * twoeb;
            i += 1;
        }
    }
}

pub(super) fn pack_sign_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    pack_bits_impl::<true>(data, words)
}

pub(super) fn pack_zero_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    pack_bits_impl::<false>(data, words)
}

fn pack_bits_impl<const SIGN: bool>(data: &[f64], words: &mut Vec<u64>) -> usize {
    let n = data.len();
    words.clear();
    words.reserve(n.div_ceil(64));
    // SAFETY: SSE2 baseline; loads stay within `data`.
    unsafe {
        let zero = _mm_setzero_pd();
        let dp = data.as_ptr();
        let mut i = 0usize;
        while i + 64 <= n {
            let mut w = 0u64;
            for g in 0..32 {
                let x = _mm_loadu_pd(dp.add(i + g * 2));
                let bits = if SIGN {
                    (_mm_movemask_pd(x) & _mm_movemask_pd(_mm_cmpneq_pd(x, zero))) as u64
                } else {
                    _mm_movemask_pd(_mm_cmpeq_pd(x, zero)) as u64
                };
                w |= (bits & 0x3) << (g * 2);
            }
            words.push(w);
            i += 64;
        }
        if i < n {
            let mut w = 0u64;
            for (fill, &x) in data[i..].iter().enumerate() {
                let bit = if SIGN { x.is_sign_negative() && x != 0.0 } else { x == 0.0 };
                w |= (bit as u64) << fill;
            }
            words.push(w);
        }
    }
    n
}

pub(super) fn zigzag_deltas(codes: &[i64], out: &mut Vec<u64>) {
    let n = codes.len();
    out.clear();
    out.resize(n, 0);
    if n == 0 {
        return;
    }
    out[0] = varint::zigzag(codes[0]);
    // SAFETY: SSE2 baseline; overlapping unaligned loads stay in-bounds
    // (`j - 1 >= 0`, `j + 1 < n`).
    unsafe {
        let cp = codes.as_ptr();
        let op = out.as_mut_ptr();
        let mut j = 1usize;
        while j + 2 <= n {
            let cur = _mm_loadu_si128(cp.add(j) as *const __m128i);
            let prev = _mm_loadu_si128(cp.add(j - 1) as *const __m128i);
            let d = _mm_sub_epi64(cur, prev);
            // Arithmetic 63-shift per 64-bit lane: srai on the high dwords,
            // then duplicate them across each lane.
            let m = _mm_shuffle_epi32::<0b1111_0101>(_mm_srai_epi32::<31>(d));
            let zz = _mm_xor_si128(_mm_slli_epi64::<1>(d), m);
            _mm_storeu_si128(op.add(j) as *mut __m128i, zz);
            j += 2;
        }
        while j < n {
            *op.add(j) = varint::zigzag((*cp.add(j)).wrapping_sub(*cp.add(j - 1)));
            j += 1;
        }
    }
}

pub(super) fn dense_1q(m: &[f64; 8], re: &mut [f64], im: &mut [f64], bit: usize) {
    if bit < 2 {
        return super::scalar::dense_1q(m, re, im, bit);
    }
    // SAFETY: SSE2 baseline; `(i, i|bit)` pair indexing matches the
    // scalar sweep, all indices < len.
    unsafe {
        let m00r = _mm_set1_pd(m[0]);
        let m00i = _mm_set1_pd(m[1]);
        let m01r = _mm_set1_pd(m[2]);
        let m01i = _mm_set1_pd(m[3]);
        let m10r = _mm_set1_pd(m[4]);
        let m10i = _mm_set1_pd(m[5]);
        let m11r = _mm_set1_pd(m[6]);
        let m11i = _mm_set1_pd(m[7]);
        let len = re.len();
        let rp = re.as_mut_ptr();
        let ip = im.as_mut_ptr();
        let mut base = 0usize;
        while base < len {
            let mut i0 = base;
            while i0 < base + bit {
                let i1 = i0 | bit;
                let r0 = _mm_loadu_pd(rp.add(i0));
                let v0 = _mm_loadu_pd(ip.add(i0));
                let r1 = _mm_loadu_pd(rp.add(i1));
                let v1 = _mm_loadu_pd(ip.add(i1));
                let nr0 = _mm_sub_pd(
                    _mm_add_pd(
                        _mm_sub_pd(_mm_mul_pd(m00r, r0), _mm_mul_pd(m00i, v0)),
                        _mm_mul_pd(m01r, r1),
                    ),
                    _mm_mul_pd(m01i, v1),
                );
                let ni0 = _mm_add_pd(
                    _mm_add_pd(
                        _mm_add_pd(_mm_mul_pd(m00r, v0), _mm_mul_pd(m00i, r0)),
                        _mm_mul_pd(m01r, v1),
                    ),
                    _mm_mul_pd(m01i, r1),
                );
                let nr1 = _mm_sub_pd(
                    _mm_add_pd(
                        _mm_sub_pd(_mm_mul_pd(m10r, r0), _mm_mul_pd(m10i, v0)),
                        _mm_mul_pd(m11r, r1),
                    ),
                    _mm_mul_pd(m11i, v1),
                );
                let ni1 = _mm_add_pd(
                    _mm_add_pd(
                        _mm_add_pd(_mm_mul_pd(m10r, v0), _mm_mul_pd(m10i, r0)),
                        _mm_mul_pd(m11r, v1),
                    ),
                    _mm_mul_pd(m11i, r1),
                );
                _mm_storeu_pd(rp.add(i0), nr0);
                _mm_storeu_pd(ip.add(i0), ni0);
                _mm_storeu_pd(rp.add(i1), nr1);
                _mm_storeu_pd(ip.add(i1), ni1);
                i0 += 2;
            }
            base += bit << 1;
        }
    }
}

pub(super) fn fused_kq_quad(
    re: &mut [f64],
    im: &mut [f64],
    base: usize,
    offs: &[usize; 8],
    mr: &[[f64; 8]; 8],
    mi: &[[f64; 8]; 8],
    dim: usize,
) {
    // The quad contract guarantees 4 consecutive bases; run them as two
    // 2-lane halves.
    // SAFETY: SSE2 baseline; caller guarantees in-bounds indices.
    unsafe {
        for half in 0..2 {
            let b = base + half * 2;
            let rp = re.as_mut_ptr();
            let ip = im.as_mut_ptr();
            let mut vr = [_mm_setzero_pd(); 8];
            let mut vi = [_mm_setzero_pd(); 8];
            for s in 0..dim {
                let ix = b | offs[s];
                vr[s] = _mm_loadu_pd(rp.add(ix));
                vi[s] = _mm_loadu_pd(ip.add(ix));
            }
            for r in 0..dim {
                let mut ar = _mm_setzero_pd();
                let mut ai = _mm_setzero_pd();
                for s in 0..dim {
                    let mre = _mm_set1_pd(mr[r][s]);
                    let mim = _mm_set1_pd(mi[r][s]);
                    ar = _mm_add_pd(ar, _mm_sub_pd(_mm_mul_pd(mre, vr[s]), _mm_mul_pd(mim, vi[s])));
                    ai = _mm_add_pd(ai, _mm_add_pd(_mm_mul_pd(mre, vi[s]), _mm_mul_pd(mim, vr[s])));
                }
                let ix = b | offs[r];
                _mm_storeu_pd(rp.add(ix), ar);
                _mm_storeu_pd(ip.add(ix), ai);
            }
        }
    }
}
