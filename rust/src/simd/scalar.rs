//! Scalar reference kernels — the **parity oracle**.
//!
//! These are the exact loops the codec and gate paths ran before the
//! vector tiers existed; every vector kernel must reproduce them
//! bit-for-bit (same rounding at every intermediate step, same outlier
//! push order, same bit layout). They are always compiled: they back the
//! `Scalar` table, the non-x86 build, the `BMQSIM_NO_SIMD` kill switch,
//! and the slots of the SSE2 table that have no bit-exact SSE2 recipe.

use crate::compress::lossless::{bitmap, varint};
use crate::compress::lossy::MAX_CODE;

/// Absolute-mode quantizer: `code = round_half_away(x / twoeb)`, with
/// non-finite or over-range values escaping to the outlier table (code 0).
pub(super) fn quant_abs(
    data: &[f64],
    twoeb: f64,
    codes: &mut Vec<i64>,
    outliers: &mut Vec<(usize, f64)>,
) {
    codes.clear();
    codes.reserve(data.len());
    outliers.clear();
    for (i, &x) in data.iter().enumerate() {
        let q = x / twoeb;
        if !x.is_finite() || q.abs() > MAX_CODE {
            outliers.push((i, x));
            codes.push(0);
        } else {
            // Round-half-away via signed-0.5 + as-cast (truncation).
            codes.push((q + 0.5f64.copysign(q)) as i64);
        }
    }
}

/// Absolute-mode dequantizer. Caller guarantees equal lengths.
pub(super) fn dequant_abs(codes: &[i64], twoeb: f64, out: &mut [f64]) {
    for (slot, &c) in out.iter_mut().zip(codes.iter()) {
        *slot = c as f64 * twoeb;
    }
}

/// Strict-negative sign bitmap (−0.0 and NaN-with-clear-sign excluded,
/// negative NaN included — matches `is_sign_negative() && x != 0.0`).
pub(super) fn pack_sign_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    bitmap::pack_bits_into(data.iter().map(|&x| x.is_sign_negative() && x != 0.0), words)
}

/// Exact-zero bitmap (`x == 0.0`, so both zero signs; NaN excluded).
pub(super) fn pack_zero_bits(data: &[f64], words: &mut Vec<u64>) -> usize {
    bitmap::pack_bits_into(data.iter().map(|&x| x == 0.0), words)
}

pub(super) fn popcount_words(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// Residual stage 1: `out[i] = zigzag(codes[i] - codes[i-1])`, `codes[-1] = 0`.
pub(super) fn zigzag_deltas(codes: &[i64], out: &mut Vec<u64>) {
    out.clear();
    out.reserve(codes.len());
    let mut prev = 0i64;
    for &c in codes {
        out.push(varint::zigzag(c.wrapping_sub(prev)));
        prev = c;
    }
}

/// Dense 1-qubit sweep over split planes; `bit` is the target-qubit
/// stride (`1 << qubit`), planes are block-contiguous pairs `(i, i|bit)`.
pub(super) fn dense_1q(m: &[f64; 8], re: &mut [f64], im: &mut [f64], bit: usize) {
    let [m00r, m00i, m01r, m01i, m10r, m10i, m11r, m11i] = *m;
    let len = re.len();
    let mut base = 0usize;
    while base < len {
        for i0 in base..base + bit {
            let i1 = i0 | bit;
            let (r0, v0) = (re[i0], im[i0]);
            let (r1, v1) = (re[i1], im[i1]);
            re[i0] = m00r * r0 - m00i * v0 + m01r * r1 - m01i * v1;
            im[i0] = m00r * v0 + m00i * r0 + m01r * v1 + m01i * r1;
            re[i1] = m10r * r0 - m10i * v0 + m11r * r1 - m11i * v1;
            im[i1] = m10r * v0 + m10i * r0 + m11r * v1 + m11i * r1;
        }
        base += bit << 1;
    }
}

/// Fused k≤3 kernel over 4 consecutive subspace bases (the scalar quad:
/// same contract as the vector tiers, one base at a time).
pub(super) fn fused_kq_quad(
    re: &mut [f64],
    im: &mut [f64],
    base: usize,
    offs: &[usize; 8],
    mr: &[[f64; 8]; 8],
    mi: &[[f64; 8]; 8],
    dim: usize,
) {
    for b in base..base + 4 {
        let mut vr = [0.0f64; 8];
        let mut vi = [0.0f64; 8];
        for s in 0..dim {
            let ix = b | offs[s];
            vr[s] = re[ix];
            vi[s] = im[ix];
        }
        for r in 0..dim {
            let (mrow, irow) = (&mr[r], &mi[r]);
            let mut ar = 0.0f64;
            let mut ai = 0.0f64;
            for s in 0..dim {
                ar += mrow[s] * vr[s] - irow[s] * vi[s];
                ai += mrow[s] * vi[s] + irow[s] * vr[s];
            }
            let ix = b | offs[r];
            re[ix] = ar;
            im[ix] = ai;
        }
    }
}
