//! Cache-line-aligned f64 plane storage for the pipeline scratch arenas.
//!
//! `Vec<f64>` only guarantees 8-byte alignment, so a 256-bit (or future
//! 512-bit) vector load over a plane may straddle cache lines at the
//! very first element. [`AlignedF64`] stores the plane as 64-byte
//! chunks, guaranteeing every SIMD load that starts at a multiple of 8
//! elements is cache-line aligned, while `Deref`-ing to `[f64]` so all
//! existing slice-based call sites (gate kernels, codec entry points,
//! range indexing) work unchanged.

/// One cache line of plane data; the alignment carrier.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Chunk([f64; 8]);

const CHUNK: usize = 8;

/// A growable f64 buffer with 64-byte-aligned backing storage and
/// `Vec`-like `resize`/`capacity` semantics (shrinking keeps capacity;
/// `resize` zero-fills or value-fills exactly like `Vec::resize`).
#[derive(Default)]
pub struct AlignedF64 {
    chunks: Vec<Chunk>,
    len: usize,
}

impl AlignedF64 {
    pub fn new() -> Self {
        AlignedF64 { chunks: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Element capacity (whole chunks, like `Vec::capacity` in spirit:
    /// how many elements fit without reallocating).
    pub fn capacity(&self) -> usize {
        self.chunks.capacity() * CHUNK
    }

    /// `Vec::resize` semantics: grow with `value`, shrink by truncating.
    pub fn resize(&mut self, new_len: usize, value: f64) {
        let old_len = self.len;
        let need = new_len.div_ceil(CHUNK);
        if need > self.chunks.len() {
            self.chunks.resize(need, Chunk([0.0; CHUNK]));
        }
        self.len = new_len;
        if new_len > old_len {
            // Overwrite the grown range explicitly: recycled chunk slots
            // may hold stale data from a previous larger resize.
            self.as_mut_slice()[old_len..].fill(value);
        }
    }

    pub fn as_slice(&self) -> &[f64] {
        // SAFETY: `chunks` owns `chunks.len() * CHUNK >= len` contiguous,
        // initialized f64s (Chunk is repr(C) over [f64; 8]).
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr() as *const f64, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        // SAFETY: as above, and `&mut self` gives unique access.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr() as *mut f64, self.len) }
    }
}

impl std::ops::Deref for AlignedF64 {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for AlignedF64 {
    fn deref_mut(&mut self) -> &mut [f64] {
        self.as_mut_slice()
    }
}

impl std::fmt::Debug for AlignedF64 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice().iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::is_aligned_64;

    #[test]
    fn backing_storage_is_cache_line_aligned() {
        let mut a = AlignedF64::new();
        assert!(is_aligned_64(a.as_slice().as_ptr()), "empty buffer dangling ptr is aligned");
        for len in [1usize, 7, 8, 9, 1024, 4097] {
            a.resize(len, 0.0);
            assert!(is_aligned_64(a.as_slice().as_ptr()), "len={len}");
        }
    }

    #[test]
    fn resize_matches_vec_semantics() {
        let mut a = AlignedF64::new();
        let mut v: Vec<f64> = Vec::new();
        for &(len, fill) in
            &[(10usize, 1.0f64), (3, 2.0), (17, 3.0), (17, 4.0), (0, 5.0), (100, 6.0)]
        {
            a.resize(len, fill);
            v.resize(len, fill);
            assert_eq!(&a[..], &v[..], "len={len}");
        }
    }

    #[test]
    fn shrink_keeps_capacity_grow_within_does_not_realloc() {
        let mut a = AlignedF64::new();
        a.resize(1024, 0.0);
        let cap = a.capacity();
        assert!(cap >= 1024);
        a.resize(512, 0.0);
        assert_eq!(a.capacity(), cap, "shrink keeps storage");
        a.resize(1024, 0.0);
        assert_eq!(a.capacity(), cap, "regrow within capacity");
        assert_eq!(a.len(), 1024);
    }

    #[test]
    fn stale_chunk_tail_is_refilled_on_regrow() {
        let mut a = AlignedF64::new();
        a.resize(16, 9.0);
        a.resize(4, 0.0);
        a.resize(16, 0.0);
        assert!(a[4..].iter().all(|&x| x == 0.0), "stale 9.0s must be overwritten");
        assert!(a[..4].iter().all(|&x| x == 9.0), "surviving prefix untouched");
    }

    #[test]
    fn deref_supports_slice_ops() {
        let mut a = AlignedF64::new();
        a.resize(8, 0.0);
        a[3] = 42.0;
        assert_eq!(a[3], 42.0);
        assert_eq!(a.iter().sum::<f64>(), 42.0);
        let sub: &mut [f64] = &mut a[2..6];
        sub[0] = 7.0;
        assert_eq!(a[2], 7.0);
    }
}
