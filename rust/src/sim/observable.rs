//! Memory-constrained observables: measurement sampling and Pauli-Z
//! expectations computed **directly from the compressed block store**,
//! without ever materializing the dense state vector.
//!
//! This is the missing half of the paper's memory story: simulating 40+
//! qubits compressed is pointless if readout requires the `2^(n+4)`-byte
//! dense state back. Both routines stream one block at a time (peak extra
//! memory = one decompressed block), so end-to-end memory stays at the
//! compressed footprint + O(block).

use crate::compress::{decompress_any_into_with, CodecScratch};
use crate::memory::BlockStore;
use crate::state::BlockLayout;
use crate::types::{Result, SplitMix64};
use std::collections::BTreeMap;

/// Streamed view over a compressed state: the terminal block store plus
/// its layout (produced by a BMQSIM run; see [`super::BmqSim`]).
pub struct CompressedState<'a> {
    /// Block partition of the state vector.
    pub layout: BlockLayout,
    /// The terminal compressed block store.
    pub store: &'a BlockStore,
}

impl<'a> CompressedState<'a> {
    /// View over `store` partitioned by `layout`.
    pub fn new(layout: BlockLayout, store: &'a BlockStore) -> Self {
        CompressedState { layout, store }
    }

    fn for_each_block(
        &self,
        mut f: impl FnMut(usize, &[f64], &[f64]) -> Result<()>,
    ) -> Result<()> {
        // One block-sized pair of buffers + codec scratch for the whole
        // stream: peak extra memory stays O(block), with no per-block
        // allocation (§Perf).
        let bl = self.layout.block_len();
        let mut re = vec![0.0f64; bl];
        let mut im = vec![0.0f64; bl];
        let mut cs = CodecScratch::new();
        for id in 0..self.layout.num_blocks() {
            let p = self.store.get(id)?;
            decompress_any_into_with(&p.re, &mut re, &mut cs)?;
            decompress_any_into_with(&p.im, &mut im, &mut cs)?;
            f(id, &re, &im)?;
        }
        Ok(())
    }

    /// Total probability mass (≈1; drifts by ≤ 2·b_r under lossy codecs).
    pub fn norm_sq(&self) -> Result<f64> {
        let mut acc = 0.0f64;
        self.for_each_block(|_, re, im| {
            acc += re.iter().zip(im).map(|(r, i)| r * r + i * i).sum::<f64>();
            Ok(())
        })?;
        Ok(acc)
    }

    /// Draw `shots` basis-state samples by streaming blocks twice: pass 1
    /// accumulates per-block probability mass; pass 2 resolves each block's
    /// share of sorted uniform draws inside that block. Never holds more
    /// than one decompressed block.
    pub fn sample(&self, shots: usize, rng: &mut SplitMix64) -> Result<BTreeMap<usize, usize>> {
        // Pass 1: block mass prefix sums.
        let mut mass = Vec::with_capacity(self.layout.num_blocks());
        self.for_each_block(|_, re, im| {
            mass.push(re.iter().zip(im).map(|(r, i)| r * r + i * i).sum::<f64>());
            Ok(())
        })?;
        let total: f64 = mass.iter().sum();
        let mut draws: Vec<f64> = (0..shots).map(|_| rng.next_f64() * total).collect();
        draws.sort_by(|a, b| a.partial_cmp(b).unwrap());

        // Pass 2: walk blocks and resolve the draws that land inside each.
        let mut counts = BTreeMap::new();
        let mut d = 0usize;
        let mut block_start = 0.0f64;
        let bl = self.layout.block_len();
        let mut re = vec![0.0f64; bl];
        let mut im = vec![0.0f64; bl];
        let mut cs = CodecScratch::new();
        for id in 0..self.layout.num_blocks() {
            let block_end = block_start + mass[id];
            if d < draws.len() && draws[d] < block_end {
                let p = self.store.get(id)?;
                decompress_any_into_with(&p.re, &mut re, &mut cs)?;
                decompress_any_into_with(&p.im, &mut im, &mut cs)?;
                // `upto` = cumulative mass through element k inclusive;
                // multiple draws landing in one element must not advance it.
                let mut k = 0usize;
                let mut upto = block_start + re[0] * re[0] + im[0] * im[0];
                while d < draws.len() && draws[d] < block_end {
                    while upto <= draws[d] && k + 1 < bl {
                        k += 1;
                        upto += re[k] * re[k] + im[k] * im[k];
                    }
                    *counts.entry(id * bl + k).or_insert(0) += 1;
                    d += 1;
                }
            }
            block_start = block_end;
        }
        // FP tail: residual draws hit the last basis state.
        if d < draws.len() {
            let last = (self.layout.num_blocks() * bl) - 1;
            *counts.entry(last).or_insert(0) += draws.len() - d;
        }
        Ok(counts)
    }

    /// `<Z_q>` for every qubit, in one streaming pass.
    pub fn expect_z_all(&self) -> Result<Vec<f64>> {
        let n = self.layout.n_qubits;
        let b = self.layout.block_qubits;
        let mut p_one = vec![0.0f64; n];
        let mut total = 0.0f64;
        self.for_each_block(|id, re, im| {
            for (local, (r, i)) in re.iter().zip(im).enumerate() {
                let prob = r * r + i * i;
                if prob == 0.0 {
                    continue;
                }
                total += prob;
                let full = (id << b) | local;
                let mut bits = full;
                while bits != 0 {
                    p_one[bits.trailing_zeros() as usize] += prob;
                    bits &= bits - 1;
                }
            }
            Ok(())
        })?;
        // Normalize: lossy codecs drift the norm slightly.
        Ok(p_one.iter().map(|&p| 1.0 - 2.0 * p / total).collect())
    }

    /// Expectation of a Pauli-Z string `Z_{q1} Z_{q2} ...` (the observable
    /// class QAOA/Ising energies need), streamed.
    pub fn expect_z_string(&self, qubits: &[usize]) -> Result<f64> {
        let b = self.layout.block_qubits;
        let mut acc = 0.0f64;
        let mut total = 0.0f64;
        let mask: usize = qubits.iter().map(|&q| 1usize << q).sum();
        self.for_each_block(|id, re, im| {
            for (local, (r, i)) in re.iter().zip(im).enumerate() {
                let prob = r * r + i * i;
                if prob == 0.0 {
                    continue;
                }
                total += prob;
                let full = (id << b) | local;
                let parity = (full & mask).count_ones() & 1;
                acc += if parity == 0 { prob } else { -prob };
            }
            Ok(())
        })?;
        Ok(acc / total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators;
    use crate::gates::measure;
    use crate::sim::{BmqSim, SimConfig};

    /// Helper: run bmqsim and get both the compressed view and the dense
    /// state for cross-checking. We re-run with materialize to keep the
    /// engine API unchanged; the streamed path uses only the store.
    fn run_with_view(
        name: &str,
        n: usize,
        f: impl FnOnce(&CompressedState<'_>, &crate::state::StateVector),
    ) {
        let c = generators::build(name, n, 42).unwrap();
        let config = SimConfig { block_qubits: n - 3, ..SimConfig::default() };
        let engine = BmqSim::new(config);
        let (store, layout) = engine.run_keeping_store(&c).unwrap();
        let dense = {
            let config = SimConfig { block_qubits: n - 3, ..SimConfig::default() };
            BmqSim::new(config).run(&c, true).unwrap().state.unwrap()
        };
        let view = CompressedState::new(layout, &store);
        f(&view, &dense);
    }

    #[test]
    fn norm_matches_dense() {
        run_with_view("qaoa", 10, |view, dense| {
            let a = view.norm_sq().unwrap();
            let b = dense.norm_sq();
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        });
    }

    #[test]
    fn expect_z_matches_dense() {
        run_with_view("ising", 9, |view, dense| {
            let streamed = view.expect_z_all().unwrap();
            let norm = dense.norm_sq();
            for (q, &z) in streamed.iter().enumerate() {
                let want = (1.0 - 2.0 * dense.prob_qubit_one(q) / norm).clamp(-1.0, 1.0);
                assert!((z - want).abs() < 1e-9, "qubit {q}: {z} vs {want}");
            }
        });
    }

    #[test]
    fn zz_string_on_ghz_is_one() {
        run_with_view("ghz_state", 10, |view, _| {
            // GHZ: perfectly correlated -> <Z_i Z_j> = 1 for all pairs.
            for (a, b) in [(0usize, 1usize), (0, 9), (4, 7)] {
                let zz = view.expect_z_string(&[a, b]).unwrap();
                assert!((zz - 1.0).abs() < 1e-6, "<Z{a}Z{b}> = {zz}");
            }
            // Single-qubit <Z> = 0 (equal superposition of all-0/all-1).
            let z = view.expect_z_string(&[3]).unwrap();
            assert!(z.abs() < 1e-6, "<Z3> = {z}");
        });
    }

    #[test]
    fn streamed_sampling_matches_dense_distribution() {
        run_with_view("bv", 10, |view, dense| {
            let mut rng = SplitMix64::new(9);
            let shots = 20_000;
            let streamed = view.sample(shots, &mut rng).unwrap();
            let mut rng2 = SplitMix64::new(9);
            let densed = measure::sample_counts(dense, shots, &mut rng2);
            // BV's state is concentrated on <=2 basis states; both samplers
            // must find the same support with matching frequencies.
            for (idx, count) in &streamed {
                let dcount = densed.get(idx).copied().unwrap_or(0);
                let diff = (*count as f64 - dcount as f64).abs() / shots as f64;
                assert!(diff < 0.02, "idx {idx}: streamed {count} vs dense {dcount}");
            }
            let total: usize = streamed.values().sum();
            assert_eq!(total, shots);
        });
    }

    #[test]
    fn sampling_uniform_state_is_flat() {
        run_with_view("qft", 8, |view, _| {
            let mut rng = SplitMix64::new(3);
            let counts = view.sample(50_000, &mut rng).unwrap();
            // qft output spreads mass widely; no single state should own
            // more than a few percent.
            let max = counts.values().max().copied().unwrap_or(0);
            assert!(max < 5_000, "max bucket {max}");
        });
    }
}
