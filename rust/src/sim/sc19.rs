//! SC19-Sim baseline prototype (paper §3, §5.3).
//!
//! The prior work's "basic solution": the state vector lives compressed in
//! blocks, and **every gate** triggers a full decompress → update →
//! recompress sweep over the blocks it touches. No staging, so the
//! (de)compression count scales with the gate count — the frequency problem
//! (Challenge ①) BMQSIM's partitioner removes — and lossy error is
//! re-injected per gate, which is why SC19's fidelity decays on deep
//! circuits (Fig. 8).
//!
//! Like the paper's prototype we offer two variants: `workers = 1`
//! reproduces SC19-Sim (CPU); `workers > 1` is the SC19-Sim (GPU) analogue
//! (parallel block updates, still per-gate compression, no pipelining —
//! the paper notes its GPU version doesn't overlap transfers either).
//!
//! **Cross-stage overlap is deliberately not wired in** (the engine
//! ignores `SimConfig::cross_stage` and drives `PoolDriver::run_stage`'s
//! per-stage barrier): the schedule horizon here is ONE gate, and every
//! gate's groups tile the entire block set — so no next-"stage" group is
//! ever disjoint from the previous one, every decode would wait on the
//! full previous gate anyway, and the barrier is already optimal. See
//! `barrier_only_even_with_cross_stage_pinned_on` for the pinned proof.

use super::{
    budget_recompressor, checkpoint_fingerprint, l2_mass, plan_group_order, GateApplier,
    NativeApplier, PoolDriver, SimConfig, SimResult,
};
use crate::circuit::Circuit;
use crate::compress::budget::BudgetController;
use crate::compress::CodecScratch;
use crate::memory::{checkpoint, BlockPayload, BlockStore};
use crate::metrics::{Metrics, Phase};
use crate::pipeline::{PipelineConfig, Scratch, WorkerCtx};
use crate::state::{BlockLayout, StateVector};
use crate::types::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// The per-gate compressed engine.
pub struct Sc19Sim<'a> {
    /// Run configuration (validated at `run` time).
    pub config: SimConfig,
    /// Parallel block-update width (1 = CPU variant, >1 = GPU variant).
    pub workers: usize,
    applier: &'a dyn GateApplier,
}

impl<'a> Sc19Sim<'a> {
    /// Engine with the native (CPU reference) gate applier.
    pub fn new(config: SimConfig, workers: usize) -> Sc19Sim<'static> {
        Sc19Sim { config, workers: workers.max(1), applier: &NativeApplier }
    }

    /// Engine with a caller-supplied gate applier (e.g. an accelerator).
    pub fn with_applier(config: SimConfig, workers: usize, applier: &'a dyn GateApplier) -> Self {
        Sc19Sim { config, workers: workers.max(1), applier }
    }

    /// Run the circuit gate-by-gate; `materialize` requests the dense
    /// terminal state in the result.
    pub fn run(&self, circuit: &Circuit, materialize: bool) -> Result<SimResult> {
        self.config.validate(circuit.n_qubits)?;
        let _simd_guard = crate::simd::disable_scope(self.config.no_simd);
        let simd_kernels_at_start = crate::simd::kernels_used();
        let metrics = Metrics::new();
        let t0 = Instant::now();

        let b = self.config.effective_block_qubits(circuit.n_qubits);
        let layout = BlockLayout::new(circuit.n_qubits, b)?;
        let codec = self.config.codec;
        // Adaptive error control: SC19's encode round is one *gate*, so a
        // run over G gates pays for G + 1 rounds (init is round 0). The
        // per-gate frequency problem is exactly why the budget matters
        // here — each round's slice is small, and the amplitude policy's
        // refunds are what keeps deep circuits above the target.
        let controller: Option<Arc<BudgetController>> = self.config.fidelity_target.map(|t| {
            Arc::new(BudgetController::new(
                self.config.error_policy,
                codec,
                t,
                layout.num_blocks(),
                circuit.len() + 1,
            ))
        });
        let mut store_opts = self.config.store_options();
        if let Some(c) = &controller {
            store_opts.recompressor = Some(budget_recompressor(c.clone(), codec));
        }
        let store = BlockStore::with_options(
            self.config.memory_budget,
            self.config.spill_dir.clone(),
            store_opts,
        )?;

        let engine = if self.workers == 1 { "sc19-cpu" } else { "sc19-gpu" };
        let fingerprint = checkpoint_fingerprint(engine, &self.config, circuit);
        let checkpoint_every = self.config.checkpoint_every.max(1);
        let mut start_gate = 0usize;

        // Initial compression of every block (SC19 compresses the whole
        // initial state; we reuse the zero-clone trick for fairness) — or,
        // on `--resume`, rehydration of a checkpoint taken at some gate
        // cursor (SC19's stage horizon is one gate). Either path also
        // calibrates the codec cost (ns/amp) for the per-gate overlap
        // auto-enable heuristic.
        let codec_ns_per_amp = match &self.config.resume_from {
            None => {
                let len = layout.block_len();
                let zero = vec![0.0f64; len];
                let mut first = vec![0.0f64; len];
                first[0] = 1.0;
                // Budget round 0: block 0 carries all the mass, the rest
                // are exact zeros (bitmap-encoded whatever the bound).
                let first_codec = match controller.as_deref() {
                    Some(c) => {
                        c.begin_stage(0, layout.num_blocks());
                        codec.with_bound(c.bound_for(0, 0, 1.0))
                    }
                    None => codec,
                };
                let t0 = Instant::now();
                let z = metrics.time(Phase::Compress, || codec.compress(&zero))?;
                let f = metrics.time(Phase::Compress, || first_codec.compress(&first))?;
                let per_amp = t0.elapsed().as_nanos() as f64 / (2.0 * len as f64);
                metrics.compressions.fetch_add(2, Ordering::Relaxed);
                store.put(0, BlockPayload { re: f, im: z.clone() })?;
                for id in 1..layout.num_blocks() {
                    if let Some(c) = controller.as_deref() {
                        c.bound_for(0, id, 0.0);
                    }
                    store.put(id, BlockPayload { re: z.clone(), im: z.clone() })?;
                }
                per_amp
            }
            Some(root) => {
                let loaded = checkpoint::load_latest(root, engine, fingerprint)?;
                if loaded.blocks.len() != layout.num_blocks() {
                    return Err(Error::checkpoint(format!(
                        "{}: {} blocks in checkpoint, layout expects {}",
                        loaded.dir.display(),
                        loaded.blocks.len(),
                        layout.num_blocks()
                    )));
                }
                for (name, v) in &loaded.manifest.counters {
                    metrics.restore_counter(name, *v);
                }
                metrics.resumes.fetch_add(1, Ordering::Relaxed);
                start_gate = loaded.manifest.stage_cursor;
                store.rehydrate(loaded.blocks)?;
                let len = layout.block_len();
                let zero = vec![0.0f64; len];
                let t0 = Instant::now();
                codec.compress(&zero)?;
                t0.elapsed().as_nanos() as f64 / len as f64
            }
        };
        if start_gate > 0 {
            if let Some(c) = &controller {
                // Same rule as BMQSIM: a resumed run only funds the gates
                // it still has to run, out of the G + 1 rounds total.
                let remaining = circuit.len().saturating_sub(start_gate);
                c.scale_budget(remaining as f64 / (circuit.len() + 1) as f64);
            }
        }

        // Per-gate sweep: the defining behaviour of the basic solution.
        // (The scratch arenas persist across gates, so even this engine's
        // far more frequent chains stay allocation-free in steady state.)
        // No fusion here — per-gate (de)compression is what SC19 *is* —
        // but the plane sweep itself may run worker-parallel
        // (`apply_workers`), and when overlap engages the per-gate chain
        // runs on the same persistent decode/apply/encode phase pool as
        // BMQSIM (the per-gate frequency problem remains; only
        // codec/transfer time is concealed). The pool pays off even more
        // here: the schedule horizon is one gate, so the scoped driver
        // would churn 3×workers threads per *gate*.
        let pipe = PipelineConfig::new(1, self.workers);
        let mut pools = PoolDriver::new(&self.config, pipe, codec_ns_per_amp);
        let sweep_workers =
            if self.applier.supports_fusion() { self.config.apply_workers.max(1) } else { 1 };
        let mut ids: Vec<usize> = Vec::new();
        for (gate_idx, gate) in circuit.gates.iter().enumerate() {
            // Resume: gates up to the checkpoint cursor are already
            // reflected in the rehydrated blocks.
            if gate_idx < start_gate {
                continue;
            }
            let mut globals: Vec<usize> =
                gate.targets().iter().copied().filter(|&q| q >= b).collect();
            globals.sort_unstable();
            globals.dedup();
            let schedule = layout.group_schedule(&globals)?;
            let bits: Vec<usize> =
                gate.targets().iter().map(|&q| schedule.buffer_bit(q)).collect();
            let block_len = layout.block_len();

            // Spill-aware scheduling, then publish this gate's group
            // schedule in processing order (per-gate sweeps are what SC19
            // *is*, so the schedule horizon is one gate).
            let (group_order, moved) =
                plan_group_order(&schedule, &store, self.config.spill_aware, &mut ids);
            metrics.groups_reordered.fetch_add(moved, Ordering::Relaxed);
            {
                let mut order: Vec<usize> =
                    Vec::with_capacity(schedule.num_groups() * schedule.blocks_per_group());
                for &g in &group_order {
                    schedule.group_blocks_into(g, &mut ids);
                    order.extend_from_slice(&ids);
                }
                store.publish_schedule(&order, schedule.blocks_per_group());
            }

            // The chain's three phases, shared verbatim by the sequential
            // and overlapped drivers (byte-identical output by structure).
            let decode = |ctx: &mut WorkerCtx<'_>, i: usize| -> Result<()> {
                let gidx = group_order[i];
                let glen = schedule.group_len();
                ctx.scratch.ensure_planes(glen);
                schedule.group_blocks_into(gidx, &mut ctx.scratch.block_ids);
                let Scratch { re, im, block_ids, payloads, codec: cs, .. } =
                    &mut *ctx.scratch;
                metrics.time(Phase::Fetch, || -> Result<()> {
                    payloads.clear();
                    for &id in block_ids.iter() {
                        payloads.push(store.take(id)?);
                    }
                    Ok(())
                })?;
                store.group_fetched();
                metrics.time(Phase::Decompress, || -> Result<()> {
                    for (slot, p) in payloads.iter().enumerate() {
                        let dst = slot * block_len..(slot + 1) * block_len;
                        codec.decompress_into_with(&p.re, &mut re[dst.clone()], cs)?;
                        codec.decompress_into_with(&p.im, &mut im[dst], cs)?;
                        metrics.decompressions.fetch_add(2, Ordering::Relaxed);
                    }
                    Ok(())
                })
            };
            let apply = |ctx: &mut WorkerCtx<'_>, _i: usize| -> Result<()> {
                let Scratch { re, im, .. } = &mut *ctx.scratch;
                metrics.time(Phase::Apply, || -> Result<()> {
                    if sweep_workers > 1 {
                        crate::gates::fused::apply_gate_parallel(
                            re,
                            im,
                            gate,
                            &bits,
                            sweep_workers,
                        );
                        Ok(())
                    } else {
                        self.applier.apply(re, im, gate, &bits)
                    }
                })
            };
            let encode = |ctx: &mut WorkerCtx<'_>, _i: usize| -> Result<()> {
                let Scratch { re, im, block_ids, payloads, codec: cs, .. } =
                    &mut *ctx.scratch;
                metrics.time(Phase::Compress, || -> Result<()> {
                    for (slot, p) in payloads.iter_mut().enumerate() {
                        let src = slot * block_len..(slot + 1) * block_len;
                        // Per-block bound under a fidelity target (round
                        // key is 1-based; 0 is init).
                        let codec = match controller.as_deref() {
                            Some(c) => {
                                let mass = l2_mass(&re[src.clone()], &im[src.clone()]);
                                codec.with_bound(c.bound_for(
                                    gate_idx + 1,
                                    block_ids[slot],
                                    mass,
                                ))
                            }
                            None => codec,
                        };
                        codec.compress_into_with(&re[src.clone()], &mut p.re, cs)?;
                        codec.compress_into_with(&im[src], &mut p.im, cs)?;
                        metrics.compressions.fetch_add(2, Ordering::Relaxed);
                        metrics
                            .bytes_compressed_in
                            .fetch_add((block_len * 16) as u64, Ordering::Relaxed);
                        metrics
                            .bytes_compressed_out
                            .fetch_add((p.re.len() + p.im.len()) as u64, Ordering::Relaxed);
                    }
                    Ok(())
                })?;
                metrics.time(Phase::Store, || -> Result<()> {
                    for (p, &id) in payloads.drain(..).zip(block_ids.iter()) {
                        store.put(id, p)?;
                    }
                    Ok(())
                })?;
                store.group_completed();
                Ok(())
            };

            // Open this gate's budget round before its encoders can run
            // (`run_stage` is a barrier, so rounds never interleave here).
            if let Some(c) = controller.as_deref() {
                c.begin_stage(gate_idx + 1, schedule.num_groups() * schedule.blocks_per_group());
            }
            // The driver decides per gate (the SC19 "stage" horizon)
            // whether the chain overlaps on the persistent pool or runs
            // sequentially — same heuristic as the staged engine.
            pools.run_stage(
                schedule.group_len(),
                schedule.num_groups(),
                &metrics,
                &decode,
                &apply,
                &encode,
            )?;
            metrics.gates_applied.fetch_add(1, Ordering::Relaxed);
            // One full state sweep per gate — the frequency problem.
            metrics.plane_sweeps.fetch_add(1, Ordering::Relaxed);
            // ---- Gate-boundary checkpoint ----
            // `run_stage` is a full barrier, so after flushing the
            // write-back queue every block holds its post-gate value.
            if let Some(ckpt_root) = &self.config.checkpoint_dir {
                if (gate_idx + 1 - start_gate) % checkpoint_every == 0 {
                    store.flush()?;
                    let t_ck = Instant::now();
                    let blocks = store.export_blocks()?;
                    let counters = metrics.checkpoint_counters();
                    let meta = checkpoint::CheckpointMeta {
                        engine,
                        stage_cursor: gate_idx + 1,
                        total_stages: circuit.len(),
                        fingerprint,
                        counters: &counters,
                    };
                    let bytes = checkpoint::write_checkpoint_with(
                        ckpt_root,
                        &meta,
                        &blocks,
                        store.injector(),
                        self.config.checkpoint_keep,
                    )?;
                    metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                    metrics.checkpoint_bytes.fetch_add(bytes, Ordering::Relaxed);
                    metrics
                        .checkpoint_ns
                        .fetch_add(t_ck.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            }
        }
        pools.finish(&metrics);
        store.flush()?;

        let wall = t0.elapsed().as_secs_f64();
        let state = if materialize {
            let len = 1usize << layout.n_qubits;
            let mut re = vec![0.0f64; len];
            let mut im = vec![0.0f64; len];
            let bl = layout.block_len();
            let mut cs = CodecScratch::new();
            for id in 0..layout.num_blocks() {
                let p = store.get(id)?;
                crate::compress::decompress_any_into_with(
                    &p.re,
                    &mut re[id * bl..(id + 1) * bl],
                    &mut cs,
                )?;
                crate::compress::decompress_any_into_with(
                    &p.im,
                    &mut im[id * bl..(id + 1) * bl],
                    &mut cs,
                )?;
            }
            Some(StateVector::from_planes(layout.n_qubits, re, im)?)
        } else {
            None
        };
        let mem = store.stats();
        metrics.absorb_mem(&mem);
        if let Some(c) = &controller {
            metrics.absorb_budget(&c.stats());
        }
        metrics.simd_kernels_used.store(
            crate::simd::kernels_used().saturating_sub(simd_kernels_at_start),
            Ordering::Relaxed,
        );
        Ok(SimResult {
            engine: if self.workers == 1 { "sc19-cpu" } else { "sc19-gpu" },
            circuit_name: circuit.name.clone(),
            n_qubits: circuit.n_qubits,
            wall_secs: wall,
            metrics: metrics.snapshot(wall),
            mem,
            peak_bytes: store.peak_total_bytes(),
            stages: circuit.len(),
            state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators;
    use crate::compress::Codec;
    use crate::sim::{BmqSim, DenseSim};

    #[test]
    fn correct_with_raw_codec() {
        let c = generators::qft(8);
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let mut config = SimConfig { block_qubits: 4, ..SimConfig::default() };
        config.codec = Codec::raw();
        for workers in [1usize, 4] {
            let r = Sc19Sim::new(config.clone(), workers).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(&ideal);
            assert!(f > 1.0 - 1e-12, "workers={workers}: {f}");
        }
    }

    #[test]
    fn compression_count_scales_with_gates() {
        let c = generators::qft(8);
        let config = SimConfig { block_qubits: 4, ..SimConfig::default() };
        let sc = Sc19Sim::new(config.clone(), 1).run(&c, false).unwrap();
        let bm = BmqSim::new(config).run(&c, false).unwrap();
        // SC19 must (de)compress far more often than BMQSIM — Challenge ①.
        // (2.5-4x at this tiny scale; the gap widens with circuit depth.)
        assert!(
            sc.metrics.decompressions > 2 * bm.metrics.decompressions,
            "sc19 {} vs bmqsim {}",
            sc.metrics.decompressions,
            bm.metrics.decompressions
        );
    }

    #[test]
    fn fidelity_worse_or_equal_to_bmqsim_on_deep_circuits() {
        // Fig. 8 shape: per-gate lossy cycles accumulate more error.
        let c = generators::qft(10);
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let config = SimConfig { block_qubits: 5, ..SimConfig::default() };
        let sc = Sc19Sim::new(config.clone(), 1).run(&c, true).unwrap();
        let bm = BmqSim::new(config).run(&c, true).unwrap();
        // Normalized fidelity: bounded by 1, so the ordering is meaningful
        // even though lossy compression perturbs the norms.
        let f_sc = sc.state.as_ref().unwrap().fidelity_normalized(&ideal);
        let f_bm = bm.state.as_ref().unwrap().fidelity_normalized(&ideal);
        assert!(f_bm >= f_sc - 1e-9, "bmqsim {f_bm} < sc19 {f_sc}");
        assert!(f_bm > 0.99);
    }

    #[test]
    fn parallel_plane_sweeps_match_serial() {
        // b = 14 on a 15-qubit QFT: gates targeting qubit 14 gather
        // 2-block groups of 2^15 amplitudes — ABOVE apply_gate_parallel's
        // 2^14-amplitude chunk floor — so the threaded multi-chunk sweep
        // path genuinely runs through the engine (smaller planes collapse
        // to one inline chunk and would leave it untested).
        let c = generators::qft(15);
        let mut config = SimConfig { block_qubits: 14, ..SimConfig::default() };
        config.codec = Codec::raw();
        let base = Sc19Sim::new(config.clone(), 1).run(&c, true).unwrap();
        // One sweep per gate — the SC19 frequency signature.
        assert_eq!(base.metrics.plane_sweeps, c.len() as u64);
        for apply_workers in [2usize, 4] {
            let mut par = config.clone();
            par.apply_workers = apply_workers;
            let r = Sc19Sim::new(par, 2).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
            assert!(f > 1.0 - 1e-12, "apply_workers={apply_workers}: {f}");
            assert_eq!(r.metrics.plane_sweeps, c.len() as u64);
        }
    }

    #[test]
    fn overlapped_per_gate_chain_matches_sequential() {
        let c = generators::qft(8);
        let mut config = SimConfig { block_qubits: 4, ..SimConfig::default() };
        config.codec = Codec::raw();
        config.overlap = crate::sim::OverlapMode::Off;
        let base = Sc19Sim::new(config.clone(), 1).run(&c, true).unwrap();
        assert_eq!(base.metrics.decode_ahead_hits, 0);
        assert_eq!(base.metrics.phase_threads_spawned, 0, "no pool without overlap");
        for (depth, workers) in [(1usize, 1usize), (2, 1), (2, 4)] {
            let mut oc = config.clone();
            oc.overlap = crate::sim::OverlapMode::On;
            oc.pipeline_depth = depth;
            oc.pipeline_depth_auto = false;
            let r = Sc19Sim::new(oc, workers).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
            assert!(f > 1.0 - 1e-12, "depth={depth} workers={workers}: {f}");
            // Same per-gate frequency signature, overlapped or not.
            assert_eq!(r.metrics.plane_sweeps, c.len() as u64);
            assert_eq!(r.metrics.decompressions, base.metrics.decompressions);
            assert!(r.metrics.decode_ahead_hits > 0 || r.metrics.overlap_stall_ns > 0);
            // Persistent pool: one handoff per gate, threads spawned once.
            assert_eq!(r.metrics.pool_stage_handoffs, c.len() as u64);
            assert_eq!(r.metrics.phase_threads_spawned, 3 * workers as u64);
        }
    }

    #[test]
    fn barrier_only_even_with_cross_stage_pinned_on() {
        // SC19 documents itself as barrier-only: per-gate "stages" tile
        // every block, so cross-stage gating could never release a decode
        // early. Pinning cross_stage On must change nothing — and the
        // boundary instrumentation must stay silent.
        let c = generators::qft(8);
        let mut config = SimConfig { block_qubits: 4, ..SimConfig::default() };
        config.codec = Codec::raw();
        config.overlap = crate::sim::OverlapMode::On;
        config.cross_stage = crate::sim::OverlapMode::On;
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        let r = Sc19Sim::new(config.clone(), 2).run(&c, true).unwrap();
        assert_eq!(r.metrics.cross_stage_decodes, 0, "sc19 must never cross a boundary");
        assert_eq!(r.metrics.boundary_stall_ns, 0);
        config.cross_stage = crate::sim::OverlapMode::Off;
        let base = Sc19Sim::new(config, 2).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "cross_stage knob leaked into sc19: {f}");
    }

    #[test]
    fn engine_name_reflects_variant() {
        let c = generators::ghz_state(6);
        let config = SimConfig { block_qubits: 3, ..SimConfig::default() };
        assert_eq!(Sc19Sim::new(config.clone(), 1).run(&c, false).unwrap().engine, "sc19-cpu");
        assert_eq!(Sc19Sim::new(config, 2).run(&c, false).unwrap().engine, "sc19-gpu");
    }
}
