//! The BMQSIM engine: staged, compressed, pipelined state-vector
//! simulation — the paper's system (§4).
//!
//! Per run:
//! 1. **Partition** the circuit into stages (Algorithm 1) so each stage
//!    needs ONE decompression + ONE compression per SV group.
//! 2. **Initialize** compressed blocks: only block 0 (holding amplitude
//!    `|0...0> = 1`) and one all-zero block are actually compressed; every
//!    other block *clones the zero payload* (§4.2's init optimization).
//! 3. For each stage, process its SV groups on the pipeline (§4.2):
//!    fetch (transfer section) → decompress → apply all stage gates with
//!    targets remapped into the gathered buffer → compress per block →
//!    store (transfer section). Groups are disjoint, so devices/streams
//!    need no cross-talk — the paper's multi-GPU property.
//! 4. Blocks live in the two-level [`BlockStore`] (§4.4): primary budget +
//!    disk spill.

use super::{
    budget_recompressor, checkpoint_fingerprint, l2_mass, noting_failure, plan_group_order,
    BoundaryGate, BoxedPhase, GateApplier, NativeApplier, OverlapMode, PoolDriver, SimConfig,
    SimResult, StageBatch,
};
use crate::circuit::fusion::{fuse_remapped, FusedGate};
use crate::circuit::{partition_circuit, Circuit};
use crate::compress::budget::BudgetController;
use crate::compress::{Codec, CodecScratch};
use crate::gates::fused;
use crate::memory::{checkpoint, BlockPayload, BlockStore};
use crate::metrics::{Metrics, Phase};
use crate::pipeline::{Scratch, WorkerCtx};
use crate::state::{BlockLayout, GroupSchedule, StateVector};
use crate::types::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The compressed, staged engine.
pub struct BmqSim<'a> {
    /// Run configuration (validated at `run` time).
    pub config: SimConfig,
    applier: &'a dyn GateApplier,
}

/// Prefix a codec failure with the block it hit, without double-wrapping
/// the "codec error:" Display prefix.
fn block_err(e: Error, block: usize, plane: &str) -> Error {
    let msg = match e {
        Error::Codec(m) => m,
        other => other.to_string(),
    };
    Error::Codec(format!("block {block} ({plane}): {msg}"))
}

/// Everything a stage's three phase closures need, owned and shared
/// behind one `Arc` so the closures can be boxed into the cross-stage
/// epoch window (two stages' contexts coexist while epochs overlap).
struct StageCtx {
    schedule: GroupSchedule,
    /// Group indices in processing order (spill-aware plan); item `i` of
    /// the stage runs group `group_order[i]`.
    group_order: Vec<usize>,
    /// Stage gates with targets remapped into the gathered group buffer.
    remapped: Vec<(crate::circuit::Gate, Vec<usize>)>,
    fused_plan: Option<(Vec<FusedGate>, Vec<fused::Segment>)>,
    /// This stage's encode-completion gate: item `i` is marked once its
    /// group's blocks are back in the store.
    gate: Arc<BoundaryGate>,
    /// The previous stage's gate (cross-stage runs only): decode of item
    /// `i` first waits for `deps[i]` on it.
    prev_gate: Option<Arc<BoundaryGate>>,
    /// Shared-block dependencies: previous-stage item indices whose
    /// groups own any of item `i`'s blocks. Empty when no gating applies.
    deps: Vec<Vec<u32>>,
}

impl StageCtx {
    fn fused(&self) -> Option<(&[FusedGate], &[fused::Segment])> {
        self.fused_plan.as_ref().map(|(ops, segs)| (ops.as_slice(), segs.as_slice()))
    }
}

/// What the next stage needs to stitch onto a still-draining stage:
/// its published order, geometry, block→item ownership, and gate.
struct PrevStage {
    /// Block ids in processing order (what `publish_schedule` saw).
    flat: Vec<usize>,
    bpg: usize,
    num_groups: usize,
    /// block id → the item index whose chain encodes it.
    owner: HashMap<usize, u32>,
    gate: Arc<BoundaryGate>,
}

/// Raises the run-abort flag on every scope exit. Declared *after* the
/// `PoolDriver` so it drops first: any unwind or early return sets the
/// flag before the driver's `Drop` aborts the pool, so decode threads
/// blocked in [`BoundaryGate::wait_for`] on marks that will never come
/// (their producers were skimmed by the abort) observe it and escape.
struct AbortOnDrop<'x>(&'x AtomicBool);

impl Drop for AbortOnDrop<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Release);
    }
}

impl<'a> BmqSim<'a> {
    /// Engine with the native (CPU reference) gate applier.
    pub fn new(config: SimConfig) -> BmqSim<'static> {
        BmqSim { config, applier: &NativeApplier }
    }

    /// Engine with a caller-supplied gate applier (e.g. an accelerator).
    pub fn with_applier(config: SimConfig, applier: &'a dyn GateApplier) -> Self {
        BmqSim { config, applier }
    }

    /// Run the circuit and hand back the terminal compressed block store +
    /// layout for streamed readout (see [`super::observable`]): sampling
    /// and expectations without ever materializing the dense state.
    pub fn run_keeping_store(
        &self,
        circuit: &Circuit,
    ) -> Result<(crate::memory::BlockStore, BlockLayout)> {
        let (result, store, layout) = self.run_inner(circuit, false)?;
        drop(result);
        Ok((store, layout))
    }

    /// Run the circuit and hand back the result *and* the terminal
    /// compressed store + layout — what the CLI uses to print a terminal
    /// state digest (xxh64 over the compressed payloads in block order)
    /// without materializing the dense state.
    pub fn run_with_store(
        &self,
        circuit: &Circuit,
        materialize: bool,
    ) -> Result<(SimResult, crate::memory::BlockStore, BlockLayout)> {
        self.run_inner(circuit, materialize)
    }

    /// Run the circuit. `materialize` controls whether the final dense
    /// state is assembled (needed for fidelity; skip it at large `n`).
    pub fn run(&self, circuit: &Circuit, materialize: bool) -> Result<SimResult> {
        let (result, _store, _layout) = self.run_inner(circuit, materialize)?;
        Ok(result)
    }

    fn run_inner(
        &self,
        circuit: &Circuit,
        materialize: bool,
    ) -> Result<(SimResult, crate::memory::BlockStore, BlockLayout)> {
        self.config.validate(circuit.n_qubits)?;
        let _simd_guard = crate::simd::disable_scope(self.config.no_simd);
        let simd_kernels_at_start = crate::simd::kernels_used();
        let metrics = Metrics::new();
        let t0 = Instant::now();

        let b = self.config.effective_block_qubits(circuit.n_qubits);
        let layout = BlockLayout::new(circuit.n_qubits, b)?;
        let codec = self.config.codec;

        // ---- Algorithm 1 (offline; timed for Fig. 14) ----
        let plan = metrics.time(Phase::Partition, || {
            partition_circuit(circuit, b, self.config.inner_size)
        })?;

        // ---- Adaptive error control (DESIGN.md §Adaptive error control) ----
        // One ledger for the whole run: the init compression counts as
        // stage 0, so a run with S circuit stages pays for S + 1 encode
        // rounds. Without a fidelity target the engine encodes at the
        // fixed global bound exactly as before.
        let controller: Option<Arc<BudgetController>> = self.config.fidelity_target.map(|t| {
            Arc::new(BudgetController::new(
                self.config.error_policy,
                codec,
                t,
                layout.num_blocks(),
                plan.stages.len() + 1,
            ))
        });
        let mut store_opts = self.config.store_options();
        if let Some(c) = &controller {
            // Compressed-primary third tier: under budget pressure the
            // store may recompress a cold resident block harder (at a
            // controller-approved looser bound) instead of spilling it.
            store_opts.recompressor = Some(budget_recompressor(c.clone(), codec));
        }

        // ---- Initial compressed state (§4.2 init optimization) ----
        let store = BlockStore::with_options(
            self.config.memory_budget,
            self.config.spill_dir.clone(),
            store_opts,
        )?;
        // The semantic compatibility key every checkpoint embeds; a
        // resume from a run with different stage-plan or state-affecting
        // parameters fails typed instead of silently diverging.
        let fingerprint = checkpoint_fingerprint("bmqsim", &self.config, circuit);
        // Either initialize |0...0> fresh, or rehydrate a checkpoint and
        // continue from its stage cursor. Both paths also calibrate the
        // codec (ns per amplitude) for the overlap auto-enable heuristic.
        let mut start_stage = 0usize;
        let codec_ns_per_amp = match &self.config.resume_from {
            None => self.init_blocks(&layout, &codec, controller.as_deref(), &store, &metrics)?,
            Some(root) => {
                let loaded = checkpoint::load_latest(root, "bmqsim", fingerprint)?;
                if loaded.blocks.len() != layout.num_blocks() {
                    return Err(Error::checkpoint(format!(
                        "{}: {} blocks in checkpoint, layout expects {}",
                        loaded.dir.display(),
                        loaded.blocks.len(),
                        layout.num_blocks()
                    )));
                }
                for (name, v) in &loaded.manifest.counters {
                    metrics.restore_counter(name, *v);
                }
                metrics.resumes.fetch_add(1, Ordering::Relaxed);
                start_stage = loaded.manifest.stage_cursor;
                store.rehydrate(loaded.blocks)?;
                // Calibrate on one zero plane (uncounted: the restored
                // manifest counters already cover all prior work).
                let len = layout.block_len();
                let zero_plane = vec![0.0f64; len];
                let t0 = Instant::now();
                codec.compress(&zero_plane)?;
                t0.elapsed().as_nanos() as f64 / len as f64
            }
        };
        if start_stage > 0 {
            if let Some(c) = &controller {
                // A resumed run grants itself only the share of ε
                // proportional to the stages it still has to pay for (out
                // of the S + 1 rounds a fresh run funds) — the pre-crash
                // lineage spent at most the complement, so the combined
                // history stays under ε_total.
                let remaining = plan.stages.len().saturating_sub(start_stage);
                c.scale_budget(remaining as f64 / (plan.stages.len() + 1) as f64);
            }
        }

        // ---- Staged, pipelined execution ----
        // Scratch arenas persist per worker for the WHOLE run: plane
        // buffers, codec intermediates, and recycled payload bytes carry
        // over from stage to stage, so steady-state group chains allocate
        // nothing. Overlapped stages run on the persistent `PhasePool` —
        // 3×workers decode/apply/encode threads spawned once for the run
        // and fed per-stage work descriptors, each worker holding up to
        // ring-depth group chains in flight; `PoolDriver` owns both chain
        // drivers and the per-stage overlap/ring-depth decisions.
        //
        // Cross-stage overlap (on by default whenever overlap itself is
        // not pinned off): stages are *submitted* to the pool's two-epoch
        // window instead of run to a barrier, so stage k+1's decode
        // threads start while stage k's encoders drain. Correctness at
        // the boundary is per-block: decode of a group that shares blocks
        // with the previous stage's unfinished tail waits on that stage's
        // `BoundaryGate` for exactly the items owning those blocks;
        // disjoint groups flow immediately.
        let cross = match self.config.cross_stage {
            OverlapMode::On => true,
            OverlapMode::Off => false,
            OverlapMode::Auto => !matches!(self.config.overlap, OverlapMode::Off),
        };
        let run_abort = AtomicBool::new(false);
        let mut pools = PoolDriver::new(&self.config, self.config.pipeline, codec_ns_per_amp);
        let _abort_guard = AbortOnDrop(&run_abort);
        let use_fusion = self.config.fusion && self.applier.supports_fusion();
        let mut group_ids: Vec<usize> = Vec::new();
        let mut prev: Option<PrevStage> = None;
        // Groups rebased away at the *next* stitched publish: the head
        // segment of the previous publish, fully retired by then (the
        // pre-publish `drain_to_one` guarantees it).
        let mut next_rebase = 0usize;
        let block_len = layout.block_len();
        let stall_timeout = self.config.stall_timeout_ms.map(Duration::from_millis);
        let checkpoint_every = self.config.checkpoint_every.max(1);
        let ctrl_ref: Option<&BudgetController> = controller.as_deref();
        for (stage_idx, stage) in plan.stages.iter().enumerate() {
            // Resume: stages up to the checkpoint cursor are already
            // reflected in the rehydrated blocks.
            if stage_idx < start_stage {
                continue;
            }
            let schedule = layout.group_schedule(&stage.inner)?;
            // Spill-aware scheduling: ask the store which groups are
            // already resident and run those first (the prefetcher then
            // has the cold groups' chains as warm-up time).
            let (group_order, moved) =
                plan_group_order(&schedule, &store, self.config.spill_aware, &mut group_ids);
            metrics.groups_reordered.fetch_add(moved, Ordering::Relaxed);
            // The stage's block ids in *processing* order (what the store
            // schedule sees), plus — for cross-stage gating — which item
            // of this stage owns each block.
            let mut flat: Vec<usize> = Vec::with_capacity(layout.num_blocks());
            let mut owner: HashMap<usize, u32> = HashMap::new();
            for (i, &g) in group_order.iter().enumerate() {
                schedule.group_blocks_into(g, &mut group_ids);
                flat.extend_from_slice(&group_ids);
                if cross {
                    for &id in &group_ids {
                        owner.insert(id, i as u32);
                    }
                }
            }
            // Publish the schedule so Belady eviction ranks and the
            // prefetch window track what the workers actually do. With a
            // draining previous stage the publish is *stitched*: its tail
            // plus this stage's head form one ranked order, so eviction
            // ranks and the prefetch window span the boundary instead of
            // resetting. The stage before it must be fully retired first
            // (its `group_completed` calls back the cursor rebase).
            let bpg = schedule.blocks_per_group();
            match prev.as_ref().filter(|_| cross) {
                Some(p) => {
                    pools.drain_to_one(&metrics)?;
                    store.publish_schedule_stitched(&p.flat, p.bpg, &flat, bpg, next_rebase);
                    next_rebase = p.num_groups;
                }
                None => store.publish_schedule(&flat, bpg),
            }
            // Precompute buffer-bit remaps for every gate of the stage.
            let remapped: Vec<(crate::circuit::Gate, Vec<usize>)> = stage
                .gates
                .iter()
                .map(|g| {
                    let bits = g.targets().iter().map(|&q| schedule.buffer_bit(q)).collect();
                    (*g, bits)
                })
                .collect();

            // Fuse the remapped gate list and plan its sweep segmentation
            // ONCE per stage; every SV group replays the same plan (all
            // groups share the plane geometry), keeping the group chain
            // allocation-free. Sweep count is per *state* pass (groups
            // tile the state), so it too is recorded once per stage.
            let fused_plan: Option<(Vec<FusedGate>, Vec<fused::Segment>)> = if use_fusion {
                let ops = fuse_remapped(&remapped, self.config.max_fuse_qubits);
                metrics
                    .gates_fused
                    .fetch_add((remapped.len() - ops.len()) as u64, Ordering::Relaxed);
                let segs =
                    fused::plan_segments(&ops, schedule.buffer_qubits(), self.config.tile_bits);
                Some((ops, segs))
            } else {
                None
            };
            let stage_sweeps = match &fused_plan {
                Some((_, segs)) => segs.len() as u64,
                None => stage.gates.len() as u64,
            };
            metrics.plane_sweeps.fetch_add(stage_sweeps, Ordering::Relaxed);

            // Shared-block decode gating: item i of this stage may decode
            // once the previous-stage items owning its blocks have
            // encoded. Groups tile the block set, so ownership is total
            // and each dep list is the (sorted, deduped) set of previous
            // items its blocks map to — usually a small fraction of the
            // stage.
            let prev_gate = prev.as_ref().filter(|_| cross).map(|p| p.gate.clone());
            let deps: Vec<Vec<u32>> = match prev.as_ref().filter(|_| cross) {
                Some(p) => group_order
                    .iter()
                    .map(|&g| {
                        schedule.group_blocks_into(g, &mut group_ids);
                        let mut d: Vec<u32> = group_ids
                            .iter()
                            .filter_map(|id| p.owner.get(id).copied())
                            .collect();
                        d.sort_unstable();
                        d.dedup();
                        d
                    })
                    .collect(),
                None => Vec::new(),
            };
            let ctx = Arc::new(StageCtx {
                schedule,
                group_order,
                remapped,
                fused_plan,
                gate: Arc::new(BoundaryGate::new(flat.len() / bpg.max(1))),
                prev_gate,
                deps,
            });

            // The chain's three phases, boxed so the driver can keep them
            // alive across the epoch window; the driver decides per stage
            // (overlap auto-enable + adaptive ring depth) whether they
            // run on the persistent phase pool — while a worker applies
            // gates to group g, its decode thread is already
            // fetching/decompressing g+1 and its encode thread
            // compressing/storing g−1 — or composed sequentially per
            // worker. `noting_failure` raises the run-abort flag on any
            // Err or panic so boundary-gate waiters in the *next* stage's
            // epoch never wedge on marks that will no longer come.
            let metrics_ref = &metrics;
            let store_ref = &store;
            let abort_ref = &run_abort;
            let decode: BoxedPhase<'_> = {
                let ctx = ctx.clone();
                Box::new(move |w, i| {
                    noting_failure(abort_ref, || {
                        if let Some(pg) = &ctx.prev_gate {
                            if !pg.complete() {
                                // The previous stage is still encoding:
                                // this is a cross-stage decode. Wait only
                                // for the items owning this group's
                                // blocks; a tripped stall watchdog
                                // surfaces here as a typed error (and
                                // `noting_failure` raises the run-abort
                                // flag for the other waiters).
                                metrics_ref.cross_stage_decodes.fetch_add(1, Ordering::Relaxed);
                                let stall = pg.wait_for(&ctx.deps[i], abort_ref, stall_timeout)?;
                                if stall > 0 {
                                    metrics_ref
                                        .boundary_stall_ns
                                        .fetch_add(stall, Ordering::Relaxed);
                                }
                            }
                        }
                        self.decode_group(
                            w,
                            &ctx.schedule,
                            ctx.group_order[i],
                            block_len,
                            &codec,
                            store_ref,
                            metrics_ref,
                        )
                    })
                })
            };
            let apply: BoxedPhase<'_> = {
                let ctx = ctx.clone();
                Box::new(move |w, _i| {
                    noting_failure(abort_ref, || {
                        self.apply_group(w, &ctx.remapped, ctx.fused(), metrics_ref)
                    })
                })
            };
            let encode: BoxedPhase<'_> = {
                let ctx = ctx.clone();
                Box::new(move |w, i| {
                    // Mark the item done on every exit of a *started*
                    // encode — after `store.put` on success, and on
                    // Err/panic too (the run-abort flag is raised first,
                    // so waiters discard whatever they read). Items
                    // skimmed by an abort never run this closure; their
                    // waiters escape via the run-abort poll instead.
                    struct MarkOnDrop<'g> {
                        gate: &'g BoundaryGate,
                        item: usize,
                    }
                    impl Drop for MarkOnDrop<'_> {
                        fn drop(&mut self) {
                            self.gate.mark_done(self.item);
                        }
                    }
                    let _mark = MarkOnDrop { gate: &ctx.gate, item: i };
                    noting_failure(abort_ref, || {
                        self.encode_group(
                            w,
                            block_len,
                            &codec,
                            ctrl_ref,
                            stage_idx + 1,
                            store_ref,
                            metrics_ref,
                        )
                    })
                })
            };
            // Open this stage's error-budget ledger *before* its encoders
            // can run; on this (sequential) submission thread, so two
            // overlapped stages draw headroom in order. Stage keys are
            // 1-based — key 0 is the init compression.
            if let Some(c) = ctrl_ref {
                c.begin_stage(stage_idx + 1, flat.len());
            }
            pools.submit_stage(
                ctx.schedule.group_len(),
                ctx.schedule.num_groups(),
                &metrics,
                StageBatch { decode, apply, encode },
            )?;
            if !cross {
                // Per-stage barrier semantics: close the epoch before the
                // next stage publishes its schedule.
                pools.drain_all(&metrics)?;
            }
            metrics
                .groups_processed
                .fetch_add(ctx.schedule.num_groups() as u64, Ordering::Relaxed);
            prev = cross.then(|| PrevStage {
                flat,
                bpg,
                num_groups: ctx.schedule.num_groups(),
                owner,
                gate: ctx.gate.clone(),
            });
            // ---- Stage-boundary checkpoint ----
            // Quiesce (drain the epoch window, flush the write-back
            // queue) so every live block is at its post-stage value, then
            // persist blocks + manifest atomically. The epoch window is
            // empty afterwards, so the next stage publishes plain, not
            // stitched.
            if let Some(ckpt_root) = &self.config.checkpoint_dir {
                if (stage_idx + 1 - start_stage) % checkpoint_every == 0 {
                    pools.drain_all(&metrics)?;
                    store.flush()?;
                    let t_ck = Instant::now();
                    let blocks = store.export_blocks()?;
                    let counters = metrics.checkpoint_counters();
                    let meta = checkpoint::CheckpointMeta {
                        engine: "bmqsim",
                        stage_cursor: stage_idx + 1,
                        total_stages: plan.stages.len(),
                        fingerprint,
                        counters: &counters,
                    };
                    let bytes = checkpoint::write_checkpoint_with(
                        ckpt_root,
                        &meta,
                        &blocks,
                        store.injector(),
                        self.config.checkpoint_keep,
                    )?;
                    metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                    metrics.checkpoint_bytes.fetch_add(bytes, Ordering::Relaxed);
                    metrics
                        .checkpoint_ns
                        .fetch_add(t_ck.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    prev = None;
                    next_rebase = 0;
                }
            }
        }
        pools.drain_all(&metrics)?;
        pools.finish(&metrics);

        // ---- Wrap up ----
        // Drain the write-back queue (and surface any background spill
        // failure) before stats/readout; counted in wall time.
        store.flush()?;
        let wall = t0.elapsed().as_secs_f64();
        let state = if materialize {
            Some(self.materialize(&layout, &store)?)
        } else {
            None
        };
        let mem = store.stats();
        metrics.absorb_mem(&mem);
        if let Some(c) = &controller {
            metrics.absorb_budget(&c.stats());
        }
        metrics.simd_kernels_used.store(
            crate::simd::kernels_used().saturating_sub(simd_kernels_at_start),
            Ordering::Relaxed,
        );
        let result = SimResult {
            engine: "bmqsim",
            circuit_name: circuit.name.clone(),
            n_qubits: circuit.n_qubits,
            wall_secs: wall,
            metrics: metrics.snapshot(wall),
            mem,
            peak_bytes: store.peak_total_bytes(),
            stages: plan.stages.len(),
            state,
        };
        Ok((result, store, layout))
    }

    /// Compress block 0 (`amp[0] = 1`) and one all-zero block; clone the
    /// zero payload into every other slot.
    ///
    /// Returns the measured codec cost in **ns per amplitude** (the two
    /// initial plane compressions, timed), which the overlap auto-enable
    /// heuristic multiplies by group size at stage-plan time. The init
    /// planes are sparse, so the estimate is a *floor* on real codec cost —
    /// biasing auto-overlap toward the safe sequential side.
    fn init_blocks(
        &self,
        layout: &BlockLayout,
        codec: &Codec,
        controller: Option<&BudgetController>,
        store: &BlockStore,
        metrics: &Metrics,
    ) -> Result<f64> {
        let len = layout.block_len();
        let zero_plane = vec![0.0f64; len];
        let mut first_re = vec![0.0f64; len];
        first_re[0] = 1.0;

        let compress_plane = |codec: &Codec, plane: &[f64]| -> Result<Vec<u8>> {
            let out = metrics.time(Phase::Compress, || codec.compress(plane))?;
            metrics.compressions.fetch_add(1, Ordering::Relaxed);
            metrics
                .bytes_compressed_in
                .fetch_add((plane.len() * 8) as u64, Ordering::Relaxed);
            metrics.bytes_compressed_out.fetch_add(out.len() as u64, Ordering::Relaxed);
            Ok(out)
        };

        // Budget stage 0 is the init itself: block 0 carries the whole
        // amplitude mass; every other block is exactly zero (zero planes
        // encode as a bitmap regardless of bound, but their zero-mass
        // ledger entries release stage 0's refund).
        if let Some(c) = controller {
            c.begin_stage(0, layout.num_blocks());
        }
        let first_codec = match controller {
            Some(c) => codec.with_bound(c.bound_for(0, 0, 1.0)),
            None => *codec,
        };
        let t0 = Instant::now();
        let zero_bytes = compress_plane(codec, &zero_plane)?;
        let first =
            BlockPayload { re: compress_plane(&first_codec, &first_re)?, im: zero_bytes.clone() };
        let codec_ns_per_amp = t0.elapsed().as_nanos() as f64 / (2.0 * len as f64);
        store.put(0, first)?;
        // §4.2: "copy the compressed SV block with all zeros multiple times".
        for id in 1..layout.num_blocks() {
            if let Some(c) = controller {
                c.bound_for(0, id, 0.0);
            }
            store.put(id, BlockPayload { re: zero_bytes.clone(), im: zero_bytes.clone() })?;
        }
        Ok(codec_ns_per_amp)
    }

    /// Pipeline phase 1 of the SV-group chain
    /// (fetch → decompress → update → compress → store): fetch the group's
    /// payloads (transfer section) and decompress them into the slot's
    /// gathered group buffer.
    ///
    /// The chain is split into the three pipeline phases so the overlapped
    /// driver can run them on separate threads; the sequential path simply
    /// composes them in order on one thread (`PoolDriver::run_stage`) —
    /// both paths execute the exact same code per group, which is what
    /// makes byte-identical output a structural property rather than a
    /// test-enforced one.
    ///
    /// Zero-copy / zero-allocation (§Perf): decompression writes directly
    /// into the worker's scratch planes (no temp Vec + copy), compression
    /// reuses the fetched payloads' byte buffers, and the planes themselves
    /// are reused across groups and stages via the scratch arena.
    #[allow(clippy::too_many_arguments)]
    fn decode_group(
        &self,
        ctx: &mut WorkerCtx<'_>,
        schedule: &crate::state::GroupSchedule,
        gidx: usize,
        block_len: usize,
        codec: &Codec,
        store: &BlockStore,
        metrics: &Metrics,
    ) -> Result<()> {
        let link = ctx.link;
        let glen = schedule.group_len();
        ctx.scratch.ensure_planes(glen);
        schedule.group_blocks_into(gidx, &mut ctx.scratch.block_ids);
        let Scratch { re, im, block_ids, payloads, codec: cs, .. } = &mut *ctx.scratch;

        // Fetch (H2D analogue; holds a transfer permit).
        link.section(|| {
            metrics.time(Phase::Fetch, || -> Result<()> {
                payloads.clear();
                for &id in block_ids.iter() {
                    payloads.push(store.take(id)?);
                }
                Ok(())
            })
        })?;
        // Advance the *decode-phase* cursor: the prefetch window follows
        // the fetch frontier, which in an overlapped pipeline runs ahead
        // of group completion.
        store.group_fetched();

        // Decompress straight into the gathered group buffer.
        metrics.time(Phase::Decompress, || -> Result<()> {
            for (slot, p) in payloads.iter().enumerate() {
                let dst = slot * block_len..(slot + 1) * block_len;
                if let Err(e) = codec.decompress_into_with(&p.re, &mut re[dst.clone()], cs) {
                    return Err(block_err(e, block_ids[slot], "re"));
                }
                if let Err(e) = codec.decompress_into_with(&p.im, &mut im[dst], cs) {
                    return Err(block_err(e, block_ids[slot], "im"));
                }
                metrics.decompressions.fetch_add(2, Ordering::Relaxed);
            }
            Ok(())
        })
    }

    /// Pipeline phase 2 — apply every gate of the stage to the decoded
    /// group buffer: ONE (de)compression for all. Fused-batched path: the
    /// whole stage runs in tiled, worker-parallel sweeps; per-gate path
    /// serves non-native appliers.
    fn apply_group(
        &self,
        ctx: &mut WorkerCtx<'_>,
        gates: &[(crate::circuit::Gate, Vec<usize>)],
        fused_plan: Option<(&[FusedGate], &[fused::Segment])>,
        metrics: &Metrics,
    ) -> Result<()> {
        let Scratch { re, im, .. } = &mut *ctx.scratch;
        metrics.time(Phase::Apply, || -> Result<()> {
            match fused_plan {
                Some((ops, segs)) => {
                    let stats =
                        fused::apply_segments(re, im, ops, segs, self.config.apply_workers);
                    metrics
                        .fused_ops_applied
                        .fetch_add(stats.fused_ops_applied, Ordering::Relaxed);
                    Ok(())
                }
                None => {
                    for (gate, bits) in gates {
                        self.applier.apply(re, im, gate, bits)?;
                    }
                    Ok(())
                }
            }
        })?;
        metrics.gates_applied.fetch_add(gates.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Pipeline phase 3 — recompress the group per block and hand the
    /// payloads back to the store (transfer section). Under a budget, any
    /// eviction this triggers lands in the store's *asynchronous*
    /// write-back queue, so spill-file I/O overlaps the chain too.
    #[allow(clippy::too_many_arguments)]
    fn encode_group(
        &self,
        ctx: &mut WorkerCtx<'_>,
        block_len: usize,
        codec: &Codec,
        controller: Option<&BudgetController>,
        stage_key: usize,
        store: &BlockStore,
        metrics: &Metrics,
    ) -> Result<()> {
        let link = ctx.link;
        let Scratch { re, im, block_ids, payloads, codec: cs, .. } = &mut *ctx.scratch;

        // Compress per block, recycling the fetched payloads' byte buffers
        // as outputs (store → worker → store, no fresh allocations).
        metrics.time(Phase::Compress, || -> Result<()> {
            for (slot, p) in payloads.iter_mut().enumerate() {
                let src = slot * block_len..(slot + 1) * block_len;
                // Under a fidelity target the bound is per-block: charge
                // the stage ledger with this block's fresh amplitude mass
                // and encode at whatever the controller hands back. The
                // wire format embeds the bound, so decode needs nothing.
                let codec = match controller {
                    Some(c) => {
                        let mass = l2_mass(&re[src.clone()], &im[src.clone()]);
                        codec.with_bound(c.bound_for(stage_key, block_ids[slot], mass))
                    }
                    None => *codec,
                };
                codec.compress_into_with(&re[src.clone()], &mut p.re, cs)?;
                codec.compress_into_with(&im[src], &mut p.im, cs)?;
                metrics.compressions.fetch_add(2, Ordering::Relaxed);
                metrics
                    .bytes_compressed_in
                    .fetch_add((block_len * 16) as u64, Ordering::Relaxed);
                metrics
                    .bytes_compressed_out
                    .fetch_add((p.re.len() + p.im.len()) as u64, Ordering::Relaxed);
            }
            Ok(())
        })?;

        // Store (D2H analogue; holds a transfer permit).
        link.section(|| {
            metrics.time(Phase::Store, || -> Result<()> {
                for (p, &id) in payloads.drain(..).zip(block_ids.iter()) {
                    store.put(id, p)?;
                }
                Ok(())
            })
        })?;
        // Advance the schedule cursor: the prefetcher works
        // `prefetch_depth` groups ahead of this point.
        store.group_completed();
        Ok(())
    }

    /// Assemble the dense state from compressed blocks (streamed: each
    /// block decompresses directly into its slice of the dense planes).
    fn materialize(&self, layout: &BlockLayout, store: &BlockStore) -> Result<StateVector> {
        let len = 1usize << layout.n_qubits;
        let mut re = vec![0.0f64; len];
        let mut im = vec![0.0f64; len];
        let bl = layout.block_len();
        let mut cs = CodecScratch::new();
        for id in 0..layout.num_blocks() {
            let p = store.get(id)?;
            crate::compress::decompress_any_into_with(&p.re, &mut re[id * bl..(id + 1) * bl], &mut cs)?;
            crate::compress::decompress_any_into_with(&p.im, &mut im[id * bl..(id + 1) * bl], &mut cs)?;
        }
        StateVector::from_planes(layout.n_qubits, re, im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators;
    use crate::compress::Codec;
    use crate::pipeline::PipelineConfig;
    use crate::sim::{DenseSim, OverlapMode};

    fn cfg(block_qubits: usize, inner: usize) -> SimConfig {
        SimConfig { block_qubits, inner_size: inner, ..SimConfig::default() }
    }

    fn fidelity_check(name: &str, n: usize, config: SimConfig, min_f: f64) {
        let c = generators::build(name, n, 42).unwrap();
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(&ideal);
        assert!(f > min_f, "{name} n={n}: fidelity {f} < {min_f}");
    }

    #[test]
    fn all_benchmarks_high_fidelity_at_default_bound() {
        // Paper §5.3: fidelity > 0.99 across all configurations.
        for name in generators::ALL {
            fidelity_check(name, 10, cfg(6, 2), 0.99);
        }
    }

    #[test]
    fn raw_codec_is_exact() {
        for name in ["qft", "qaoa", "ghz_state"] {
            let mut config = cfg(5, 2);
            config.codec = Codec::raw();
            fidelity_check(name, 9, config, 1.0 - 1e-12);
        }
    }

    #[test]
    fn various_geometries_agree() {
        let c = generators::qft(9);
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        for (b, inner) in [(3usize, 2usize), (4, 3), (6, 2), (9, 2), (5, 4)] {
            let mut config = cfg(b, inner);
            config.codec = Codec::raw(); // isolate staging correctness
            let r = BmqSim::new(config).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(&ideal);
            assert!(f > 1.0 - 1e-12, "b={b} inner={inner}: {f}");
        }
    }

    #[test]
    fn pipeline_shapes_are_deterministic_in_state() {
        let c = generators::build("qaoa", 9, 7).unwrap();
        let base = {
            let mut config = cfg(4, 2);
            config.pipeline = PipelineConfig::sequential();
            BmqSim::new(config).run(&c, true).unwrap().state.unwrap()
        };
        for (d, s) in [(1usize, 4usize), (2, 2), (4, 2)] {
            let mut config = cfg(4, 2);
            config.pipeline = PipelineConfig::new(d, s);
            let r = BmqSim::new(config).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(&base);
            assert!(f > 1.0 - 1e-12, "devices={d} streams={s}: {f}");
        }
    }

    #[test]
    fn overlapped_pipeline_is_deterministic_in_state() {
        // The three-phase overlapped chain must be state-identical to the
        // sequential chain at every depth/worker shape (groups are
        // disjoint and each runs the exact same phase code).
        let c = generators::build("qaoa", 9, 7).unwrap();
        let base = {
            let mut config = cfg(4, 2);
            config.pipeline = PipelineConfig::sequential();
            config.overlap = OverlapMode::Off;
            BmqSim::new(config).run(&c, true).unwrap()
        };
        for (depth, workers) in [(1usize, 1usize), (2, 1), (3, 2), (2, 4)] {
            let mut config = cfg(4, 2);
            config.pipeline = PipelineConfig::new(1, workers);
            config.overlap = OverlapMode::On;
            config.pipeline_depth = depth;
            config.pipeline_depth_auto = false;
            let r = BmqSim::new(config).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
            assert!(f > 1.0 - 1e-12, "depth={depth} workers={workers}: {f}");
            assert_eq!(r.metrics.groups_processed, base.metrics.groups_processed);
            assert_eq!(r.metrics.decompressions, base.metrics.decompressions);
            // Overlap instrumentation is live: the apply phase either
            // found groups pre-decoded or waited for them.
            assert!(
                r.metrics.decode_ahead_hits > 0 || r.metrics.overlap_stall_ns > 0,
                "depth={depth} workers={workers}: no overlap metrics recorded"
            );
        }
        // The sequential run records no overlap activity at all.
        assert_eq!(base.metrics.decode_ahead_hits, 0);
        assert_eq!(base.metrics.overlap_stall_ns, 0);
    }

    #[test]
    fn overlapped_ring_scratch_is_reused_across_stages() {
        // Ring arenas must survive stage boundaries like the sequential
        // pool: growth is bounded by stages x depth, not by group count.
        let c = generators::qft(12);
        let mut config = cfg(6, 2);
        config.pipeline = PipelineConfig::sequential();
        config.overlap = OverlapMode::On;
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        let r = BmqSim::new(config).run(&c, false).unwrap();
        assert!(r.metrics.scratch_grows >= 1);
        // Persistent pool: phase threads spawned once for the run, one
        // handoff per stage.
        assert_eq!(r.metrics.phase_threads_spawned, 3);
        assert_eq!(r.metrics.pool_stage_handoffs, r.stages as u64);
        // Two epoch banks under cross-stage overlap → up to twice the
        // ring arenas of the old single-bank pool, each warming once.
        assert!(
            r.metrics.scratch_grows <= 4 * r.stages as u64,
            "ring scratch grew {} times over {} stages",
            r.metrics.scratch_grows,
            r.stages
        );
        assert!(r.metrics.groups_processed >= r.metrics.scratch_grows);
    }

    #[test]
    fn cross_stage_overlap_is_deterministic_and_instrumented() {
        // Cross-stage epochs move *when* chains run, never what they
        // compute: the state must match the barrier run exactly, and on a
        // multi-stage pinned-overlap run the boundary instrumentation
        // must actually engage (decodes accepted while the previous
        // stage drains).
        let c = generators::build("qaoa", 10, 7).unwrap();
        let barrier = {
            let mut config = cfg(5, 2);
            config.overlap = OverlapMode::On;
            config.cross_stage = OverlapMode::Off;
            config.pipeline = PipelineConfig::new(1, 2);
            config.pipeline_depth = 2;
            config.pipeline_depth_auto = false;
            BmqSim::new(config).run(&c, true).unwrap()
        };
        assert_eq!(
            barrier.metrics.cross_stage_decodes, 0,
            "barrier runs must never record cross-stage decodes"
        );
        assert_eq!(barrier.metrics.boundary_stall_ns, 0);
        let mut config = cfg(5, 2);
        config.overlap = OverlapMode::On;
        config.cross_stage = OverlapMode::On;
        config.pipeline = PipelineConfig::new(1, 2);
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(barrier.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "cross-stage changed the state: {f}");
        assert_eq!(r.metrics.groups_processed, barrier.metrics.groups_processed);
        assert_eq!(r.metrics.decompressions, barrier.metrics.decompressions);
        assert!(r.stages > 1, "need a stage boundary to cross");
        // The epoch window engaged: either decode crossed a boundary or
        // the engine timed an end-of-run epoch drain (whether a decode
        // beats the previous stage's encoders is a scheduling race, so
        // the two counters are asserted jointly).
        assert!(
            r.metrics.cross_stage_decodes > 0 || r.metrics.epoch_drain_ns > 0,
            "cross-stage run recorded no boundary activity at all"
        );
    }

    #[test]
    fn cross_stage_with_spill_and_faults_matches_barrier() {
        // The full stack at once: tight budget, async spill, recoverable
        // injected faults, spill-aware reordering, and cross-stage
        // epochs. State must stay byte-identical to the fault-free
        // barrier run — and nothing may hang or panic mid-drain.
        let dir = std::env::temp_dir().join("bmqsim-engine-cross-fault");
        let c = generators::build("qaoa", 12, 5).unwrap();
        let base = {
            let mut config = cfg(6, 2);
            config.codec = Codec::raw();
            config.memory_budget = Some(10 * 1024);
            config.spill_dir = Some(dir.clone());
            config.cross_stage = OverlapMode::Off;
            config.pipeline = PipelineConfig::sequential();
            BmqSim::new(config).run(&c, true).unwrap()
        };
        assert!(base.mem.spill_events > 0, "budget never engaged");
        let mut config = cfg(6, 2);
        config.codec = Codec::raw();
        config.memory_budget = Some(10 * 1024);
        config.spill_dir = Some(dir);
        config.overlap = OverlapMode::On;
        config.cross_stage = OverlapMode::On;
        config.pipeline = PipelineConfig::new(1, 4);
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        config.fault_plan = Some(
            crate::memory::FaultPlan::parse("seed=3,eio@write:1,eio=0.02").unwrap(),
        );
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "cross-stage + faults changed the state: {f}");
        assert!(r.mem.io_retries > 0, "fault plan never engaged");
    }

    #[test]
    fn fatal_fault_under_cross_stage_fails_without_hanging() {
        // A persistent spill failure mid-run with two epochs in flight:
        // the run must surface a typed error — decode waiters at the
        // boundary gate have to escape via the run-abort flag, not wedge.
        let dir = std::env::temp_dir().join("bmqsim-engine-cross-fatal");
        let c = generators::build("ising", 10, 3).unwrap();
        let mut config = cfg(6, 2);
        config.memory_budget = Some(2048);
        config.spill_dir = Some(dir);
        config.sync_spill = true; // fail on the evicting put, deterministically
        config.overlap = OverlapMode::On;
        config.cross_stage = OverlapMode::On;
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        config.fault_plan =
            Some(crate::memory::FaultPlan::parse("seed=4,eio=1.0").unwrap());
        let err = BmqSim::new(config).run(&c, false);
        assert!(err.is_err(), "total-EIO plan must fail, got {err:?}");
    }

    #[test]
    fn auto_overlap_decides_every_stage_and_stays_correct() {
        let c = generators::build("qaoa", 10, 3).unwrap();
        let pinned_off = {
            let mut config = cfg(5, 2);
            config.overlap = OverlapMode::Off;
            BmqSim::new(config).run(&c, true).unwrap()
        };
        let mut config = cfg(5, 2);
        config.overlap = OverlapMode::Auto;
        let r = BmqSim::new(config).run(&c, true).unwrap();
        assert_eq!(
            r.metrics.auto_overlap_on + r.metrics.auto_overlap_off,
            r.stages as u64,
            "auto mode must decide every stage"
        );
        // Whatever auto decided, the state is identical to the pinned
        // sequential run (overlap moves when work happens, never what).
        let f = r
            .state
            .as_ref()
            .unwrap()
            .fidelity(pinned_off.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "auto overlap changed the state: {f}");
        // Pinned modes never touch the auto counters.
        assert_eq!(
            pinned_off.metrics.auto_overlap_on + pinned_off.metrics.auto_overlap_off,
            0
        );
    }

    #[test]
    fn overlapped_spill_run_matches_sequential_and_reorders() {
        // Overlap + budget + spill-aware scheduling together: state must
        // stay identical to the plain sequential engine, and under a tight
        // budget later stages find a mixed-residency block set, so the
        // spill-aware planner actually moves groups forward.
        let dir = std::env::temp_dir().join("bmqsim-engine-overlap-spill");
        let c = generators::build("qaoa", 12, 5).unwrap();
        let ideal = {
            let mut config = cfg(6, 2);
            config.codec = Codec::raw();
            config.pipeline = PipelineConfig::sequential();
            BmqSim::new(config).run(&c, true).unwrap()
        };
        let mut config = cfg(6, 2);
        config.codec = Codec::raw();
        config.memory_budget = Some(10 * 1024);
        config.spill_dir = Some(dir);
        config.pipeline = PipelineConfig::new(1, 2);
        config.overlap = OverlapMode::On;
        config.pipeline_depth = 2;
        config.pipeline_depth_auto = false;
        let r = BmqSim::new(config).run(&c, true).unwrap();
        assert!(r.mem.spill_events > 0, "budget never engaged");
        assert!(r.mem.peak_primary_bytes <= 10 * 1024);
        let f = r.state.as_ref().unwrap().fidelity(ideal.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "overlap+spill changed the state: {f}");
        assert!(
            r.metrics.groups_reordered > 0,
            "spill-aware scheduling never reordered a group"
        );
    }

    #[test]
    fn spill_aware_off_keeps_natural_order() {
        let dir = std::env::temp_dir().join("bmqsim-engine-no-spill-aware");
        let c = generators::build("qaoa", 11, 5).unwrap();
        let mut config = cfg(6, 2);
        config.codec = Codec::raw(); // incompressible: the budget must bite
        config.memory_budget = Some(8 * 1024);
        config.spill_dir = Some(dir);
        config.spill_aware = false;
        let r = BmqSim::new(config).run(&c, false).unwrap();
        assert!(r.mem.spill_events > 0);
        assert_eq!(r.metrics.groups_reordered, 0);
    }

    #[test]
    fn fused_path_matches_unfused_and_cuts_sweeps() {
        // Acceptance: on the QFT generator, plane sweeps are STRICTLY
        // fewer than gates, and the fused state matches the per-gate
        // state to raw-codec precision.
        let c = generators::qft(10);
        let mut fused_cfg = cfg(5, 3);
        fused_cfg.codec = Codec::raw();
        let mut unfused_cfg = fused_cfg.clone();
        unfused_cfg.fusion = false;
        let rf = BmqSim::new(fused_cfg).run(&c, true).unwrap();
        let ru = BmqSim::new(unfused_cfg).run(&c, true).unwrap();
        let f = rf.state.as_ref().unwrap().fidelity(ru.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "fused vs unfused fidelity {f}");
        assert!(rf.metrics.gates_fused > 0, "fusion merged nothing");
        assert!(
            rf.metrics.plane_sweeps < c.len() as u64,
            "sweeps {} not below gate count {}",
            rf.metrics.plane_sweeps,
            c.len()
        );
        assert!(rf.metrics.fused_ops_applied > 0);
        // Per-gate path: exactly one sweep per gate, no fused ops.
        assert_eq!(ru.metrics.plane_sweeps, c.len() as u64);
        assert_eq!(ru.metrics.gates_fused, 0);
        assert_eq!(ru.metrics.fused_ops_applied, 0);
    }

    #[test]
    fn fused_tile_and_worker_knobs_are_deterministic_in_state() {
        let c = generators::build("qaoa", 9, 11).unwrap();
        let base_state = {
            let mut config = cfg(4, 2);
            config.codec = Codec::raw();
            config.pipeline = PipelineConfig::sequential();
            BmqSim::new(config).run(&c, true).unwrap().state.unwrap()
        };
        for (tile_bits, apply_workers) in [(2usize, 1usize), (4, 2), (20, 4), (6, 3)] {
            let mut config = cfg(4, 2);
            config.codec = Codec::raw();
            config.pipeline = PipelineConfig::sequential();
            config.tile_bits = tile_bits;
            config.apply_workers = apply_workers;
            let r = BmqSim::new(config).run(&c, true).unwrap();
            let f = r.state.as_ref().unwrap().fidelity(&base_state);
            assert!(f > 1.0 - 1e-12, "tile={tile_bits} workers={apply_workers}: {f}");
        }
    }

    #[test]
    fn fusion_respects_default_fidelity_bound() {
        // Lossy default codec + fusion across every benchmark family.
        for name in generators::ALL {
            fidelity_check(name, 10, cfg(6, 3), 0.99);
        }
    }

    #[test]
    fn compression_counts_are_stagewise_not_gatewise() {
        let c = generators::qft(12);
        let config = cfg(8, 3);
        let r = BmqSim::new(config).run(&c, false).unwrap();
        // Per stage per group: 2 planes per block both ways; plus init.
        // The key claim: decompressions << 2 * gates * blocks. (The factor
        // grows with scale — the paper's 33-qubit QFT sees 95x — but at
        // n=12/c=4 a 3-4x gap is the expected shape.)
        let blocks = 1u64 << 4;
        let gatewise = 2 * c.len() as u64 * blocks;
        assert!(
            r.metrics.decompressions < gatewise / 3,
            "decompressions {} vs gate-wise {gatewise}",
            r.metrics.decompressions
        );
        assert!(r.stages < c.len());
    }

    #[test]
    fn memory_budget_with_spill_still_correct() {
        let dir = std::env::temp_dir().join("bmqsim-engine-spill");
        let c = generators::build("ising", 10, 3).unwrap();
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let mut config = cfg(6, 2);
        config.memory_budget = Some(2048); // absurdly tight -> heavy spill
        config.spill_dir = Some(dir);
        let r = BmqSim::new(config).run(&c, true).unwrap();
        assert!(r.mem.spill_events > 0, "expected spilling");
        let f = r.state.as_ref().unwrap().fidelity(&ideal);
        assert!(f > 0.99, "fidelity with spill {f}");
    }

    #[test]
    fn sharded_async_store_matches_sync_baseline_and_prefetches() {
        // Acceptance shape: the sharded + async-spill + prefetching store
        // must be state-identical to the single-shard synchronous-spill
        // baseline, respect the primary budget, and actually convert
        // spilled fetches into prefetch hits.
        let dir = std::env::temp_dir().join("bmqsim-engine-shard-spill");
        let c = generators::build("qaoa", 12, 5).unwrap();
        let budget = 10 * 1024;
        let base = {
            let mut config = cfg(6, 2);
            config.codec = Codec::raw();
            config.memory_budget = Some(budget);
            config.spill_dir = Some(dir.clone());
            config.store_shards = 1;
            config.sync_spill = true;
            config.prefetch_depth = 0;
            config.pipeline = PipelineConfig::sequential();
            BmqSim::new(config).run(&c, true).unwrap()
        };
        assert!(base.mem.spill_events > 0, "baseline never spilled");
        assert!(base.mem.peak_primary_bytes <= budget);
        assert_eq!(base.metrics.prefetch_hits, 0, "baseline must not prefetch");
        // The hit assertion races a background thread; correctness must
        // hold on EVERY attempt, hits on at least one of a few.
        let mut total_hits = 0u64;
        for attempt in 0..3 {
            let sharded = {
                let mut config = cfg(6, 2);
                config.codec = Codec::raw();
                config.memory_budget = Some(budget);
                config.spill_dir = Some(dir.clone());
                config.store_shards = 8;
                config.prefetch_depth = 4;
                config.sync_spill = false;
                config.pipeline = PipelineConfig::sequential();
                BmqSim::new(config).run(&c, true).unwrap()
            };
            let f = sharded
                .state
                .as_ref()
                .unwrap()
                .fidelity(base.state.as_ref().unwrap());
            assert!(
                f > 1.0 - 1e-12,
                "attempt {attempt}: sharded/async store changed the state: {f}"
            );
            assert!(sharded.mem.spill_events > 0);
            assert!(sharded.mem.peak_primary_bytes <= budget);
            total_hits += sharded.metrics.prefetch_hits;
            if total_hits > 0 {
                break;
            }
        }
        assert!(total_hits > 0, "prefetcher never hit across 3 runs");
    }

    #[test]
    fn budget_without_spill_dir_fails_cleanly() {
        let c = generators::qft(10);
        let mut config = cfg(6, 2);
        config.memory_budget = Some(64);
        let err = BmqSim::new(config).run(&c, false);
        assert!(matches!(err, Err(Error::OutOfMemory(_))));
    }

    #[test]
    fn fatal_fault_plan_surfaces_typed_spill_error() {
        // Every spill write fails on every attempt: retries exhaust and
        // the run must carry the typed `Error::Spill` out through the
        // engine — not a panic, not a hang, not `OutOfMemory`.
        let dir = std::env::temp_dir().join("bmqsim-engine-fatal-fault");
        let c = generators::build("ising", 10, 3).unwrap();
        let mut config = cfg(6, 2);
        config.memory_budget = Some(2048);
        config.spill_dir = Some(dir.clone());
        config.sync_spill = true; // fail on the evicting put, deterministically
        config.fault_plan =
            Some(crate::memory::FaultPlan::parse("seed=1,eio=1.0").unwrap());
        let err = BmqSim::new(config).run(&c, false);
        assert!(
            matches!(&err, Err(Error::Spill { .. })),
            "total-EIO plan must fail with Error::Spill, got {err:?}",
        );
        // A fresh fault-free engine over the same spill dir runs clean:
        // the failure left nothing poisoned behind.
        let mut clean = cfg(6, 2);
        clean.memory_budget = Some(2048);
        clean.spill_dir = Some(dir);
        let r = BmqSim::new(clean).run(&c, false).unwrap();
        assert!(r.mem.spill_events > 0);
    }

    #[test]
    fn recoverable_fault_plan_is_invisible_in_the_state() {
        // Low-rate transient EIO + bit flips on the spill tier: the retry +
        // checksum machinery must absorb every fault, leaving the terminal
        // state byte-identical to the fault-free run while the recovery
        // counters prove the plan actually engaged.
        let dir = std::env::temp_dir().join("bmqsim-engine-recoverable-fault");
        let c = generators::build("ising", 10, 3).unwrap();
        let base = {
            let mut config = cfg(6, 2);
            config.memory_budget = Some(2048);
            config.spill_dir = Some(dir.clone());
            BmqSim::new(config).run(&c, true).unwrap()
        };
        assert!(base.mem.spill_events > 0, "baseline never spilled");
        assert_eq!(base.mem.io_retries + base.mem.checksum_failures, 0);
        let mut config = cfg(6, 2);
        config.memory_budget = Some(2048);
        config.spill_dir = Some(dir);
        // The scripted first-write fault makes counter engagement
        // deterministic even if the probabilistic draws all miss at this
        // small scale.
        config.fault_plan = Some(
            crate::memory::FaultPlan::parse("seed=2,eio@write:1,eio=0.03,bitflip=0.03").unwrap(),
        );
        let r = BmqSim::new(config).run(&c, true).unwrap();
        let f = r.state.as_ref().unwrap().fidelity(base.state.as_ref().unwrap());
        assert!(f > 1.0 - 1e-12, "recovered run diverged from fault-free: {f}");
        let engaged = r.mem.io_retries + r.mem.checksum_failures + r.mem.frames_recovered;
        assert!(engaged > 0, "fault plan never engaged the recovery machinery");
        // The engine report carries the counters (absorb_mem plumbing).
        assert_eq!(r.metrics.io_retries, r.mem.io_retries);
        assert_eq!(r.metrics.checksum_failures, r.mem.checksum_failures);
    }

    #[test]
    fn sparse_circuits_have_huge_ratios() {
        // Fig. 9 shape: sparse states (cat/ghz/bv) compress far harder
        // than dense, phase-rich ones (qaoa). (QFT of |0..0> ends uniform,
        // so it also compresses extremely well at this scale — the paper's
        // 10.5x qft number comes from intermediate-stage states at n>=23.)
        let ratio = |name: &str| {
            let c = generators::build(name, 12, 1).unwrap();
            let r = BmqSim::new(cfg(8, 2)).run(&c, false).unwrap();
            let standard = (1u128 << (12 + 4)) as f64;
            standard / r.peak_bytes as f64
        };
        let cat = ratio("cat_state");
        let qaoa = ratio("qaoa");
        assert!(cat > 40.0, "cat ratio {cat}");
        assert!(cat > 3.0 * qaoa, "cat {cat} vs qaoa {qaoa}");
    }

    #[test]
    fn scratch_arena_is_reused_across_groups_and_stages() {
        // Zero-allocation steady state: group planes are allocated at most
        // once per worker per distinct (growing) group size — NOT once per
        // group chain. With a sequential pipeline the growth count is
        // bounded by the stage count while the chain count is far larger.
        let c = generators::qft(12);
        let mut config = cfg(6, 2);
        config.pipeline = PipelineConfig::sequential();
        config.overlap = OverlapMode::Off; // the bound below is arena-per-worker
        let r = BmqSim::new(config).run(&c, false).unwrap();
        assert!(r.metrics.scratch_grows >= 1, "arena never warmed");
        assert!(
            r.metrics.scratch_grows <= r.stages as u64,
            "scratch grew {} times over {} stages — planes are being reallocated",
            r.metrics.scratch_grows,
            r.stages
        );
        assert!(
            r.metrics.groups_processed >= 4 * r.metrics.scratch_grows,
            "groups {} vs grows {}",
            r.metrics.groups_processed,
            r.metrics.scratch_grows
        );
    }

    #[test]
    fn single_block_degenerate_case() {
        // block_qubits >= n: one block, every stage fully local.
        let c = generators::qft(6);
        let ideal = DenseSim::new(SimConfig::default()).run(&c).unwrap().state.unwrap();
        let r = BmqSim::new(cfg(14, 2)).run(&c, true).unwrap();
        assert_eq!(r.stages, 1);
        assert!(r.state.as_ref().unwrap().fidelity(&ideal) > 0.999);
    }
}
