//! Simulation engines.
//!
//! * [`dense`] — uncompressed full-memory reference (the SV-Sim-class
//!   baseline and the ψ_ideal source for fidelity).
//! * [`bmqsim`] — the paper's system: staged (Algorithm 1), compressed
//!   (Algorithm 2), pipelined (§4.2), two-level memory (§4.4).
//! * [`sc19`] — the SC19-Sim baseline prototype: per-gate block
//!   (de)compression (§3's "basic solution").

pub mod bmqsim;
pub mod config;
pub mod dense;
pub mod observable;
pub mod sc19;

pub use bmqsim::BmqSim;
pub use config::{auto_overlap, Backend, OverlapMode, SimConfig, OVERLAP_AUTO_MIN_CONCEAL_NS};
pub use dense::DenseSim;
pub use sc19::Sc19Sim;

use crate::circuit::Gate;
use crate::gates::apply_gate_remapped;
use crate::memory::{BlockStore, MemStats};
use crate::metrics::{Metrics, MetricsReport};
use crate::pipeline::{
    run_items, PhasePool, PipelineConfig, RingDepthController, ScratchPool, WorkerCtx,
    RING_DEPTH_MAX,
};
use crate::state::{GroupSchedule, StateVector};
use crate::types::{Error, Result};
use std::sync::atomic::Ordering;

/// A borrowed phase closure as the engines hand it to [`PoolDriver`]:
/// one third of a group chain (decode / apply / encode), callable on any
/// worker.
pub(crate) type PhaseFn<'a> = &'a (dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<()> + Sync);

/// Shared chain-driver plumbing for both engines: the lazily-built
/// sequential [`ScratchPool`] and persistent [`PhasePool`], the adaptive
/// ring-depth controller, and the per-stage overlap auto-enable decision.
/// One instance lives per engine run; `run_stage` is called once per
/// stage (per gate in SC19), `finish` once before the metrics snapshot.
pub(crate) struct PoolDriver {
    pipe: PipelineConfig,
    overlap: OverlapMode,
    depth_cap: usize,
    codec_ns_per_amp: f64,
    seq_pool: Option<ScratchPool>,
    phase_pool: Option<PhasePool>,
    depth_ctl: RingDepthController,
}

impl PoolDriver {
    /// `codec_ns_per_amp` is the engine's init-time codec calibration (see
    /// [`auto_overlap`]); `pipe` is the worker shape the engine actually
    /// drives (BMQSIM: `config.pipeline`; SC19: one device × its workers).
    pub(crate) fn new(config: &SimConfig, pipe: PipelineConfig, codec_ns_per_amp: f64) -> Self {
        let depth_cap = if config.pipeline_depth_auto {
            RING_DEPTH_MAX
        } else {
            config.pipeline_depth.max(1)
        };
        PoolDriver {
            pipe,
            overlap: config.overlap,
            depth_cap,
            codec_ns_per_amp,
            seq_pool: None,
            phase_pool: None,
            depth_ctl: RingDepthController::new(
                config.pipeline_depth,
                config.pipeline_depth_auto,
                depth_cap,
            ),
        }
    }

    /// Run one stage of `num_groups` disjoint group chains, deciding per
    /// stage (unless pinned) whether to overlap: engaged stages go to the
    /// persistent phase pool at the controller's ring depth, declined
    /// stages run the same three closures composed sequentially per
    /// worker. Both pools are built on first use, so a run whose stages
    /// all resolve one way never pays for the other.
    pub(crate) fn run_stage(
        &mut self,
        group_len: usize,
        num_groups: usize,
        metrics: &Metrics,
        decode: PhaseFn<'_>,
        apply: PhaseFn<'_>,
        encode: PhaseFn<'_>,
    ) -> Result<()> {
        let heuristic = auto_overlap(group_len, num_groups, self.codec_ns_per_amp);
        let use_overlap = self.overlap.engaged(heuristic);
        if self.overlap.is_auto() {
            if use_overlap {
                metrics.auto_overlap_on.fetch_add(1, Ordering::Relaxed);
            } else {
                metrics.auto_overlap_off.fetch_add(1, Ordering::Relaxed);
            }
        }
        let pipe = self.pipe;
        if use_overlap {
            let depth_cap = self.depth_cap;
            let pool =
                self.phase_pool.get_or_insert_with(|| PhasePool::new(pipe, depth_cap));
            let depth = self.depth_ctl.stage_depth(pool.stats().total_stall_ns());
            pool.run_stage(num_groups, depth, decode, apply, encode)
        } else {
            let pool =
                self.seq_pool.get_or_insert_with(|| ScratchPool::new(pipe.workers()));
            run_items::<Error, _>(pipe, num_groups, pool, |ctx, i| {
                decode(&mut *ctx, i)?;
                apply(&mut *ctx, i)?;
                encode(&mut *ctx, i)
            })
        }
    }

    /// End-of-run accounting: arena growth across both pools, the
    /// overlap/pool counters, and the ring-depth trajectory.
    pub(crate) fn finish(&self, metrics: &Metrics) {
        let grows = self.seq_pool.as_ref().map_or(0, |p| p.total_plane_grows())
            + self.phase_pool.as_ref().map_or(0, |p| p.total_plane_grows());
        metrics.scratch_grows.store(grows, Ordering::Relaxed);
        if let Some(pool) = &self.phase_pool {
            metrics.absorb_overlap(pool.stats());
            metrics
                .phase_threads_spawned
                .store(pool.threads_spawned(), Ordering::Relaxed);
            metrics
                .ring_depth_final
                .store(self.depth_ctl.current() as u64, Ordering::Relaxed);
            metrics.ring_depth_peak.store(self.depth_ctl.peak() as u64, Ordering::Relaxed);
            metrics
                .ring_depth_adjustments
                .store(self.depth_ctl.adjustments(), Ordering::Relaxed);
        }
    }
}

/// Spill-aware scheduling (ROADMAP): order a stage's groups so the ones
/// whose blocks are already primary-resident run first, deferring groups
/// that would pay synchronous disk reads until the prefetcher has had
/// time to stage them. Returns `(group processing order, groups promoted
/// ahead of their natural position)`.
///
/// The query runs *before* `publish_schedule`, and the published block
/// order follows the returned group order — so Belady ranks and the
/// prefetch window stay consistent with what the workers actually do.
/// Groups are disjoint, so any processing order yields byte-identical
/// terminal blocks; the sort is stable, keeping natural order within each
/// residency class. No-op (natural order) when `spill_aware` is off or
/// the store has no secondary tier.
pub(crate) fn plan_group_order(
    schedule: &GroupSchedule,
    store: &BlockStore,
    spill_aware: bool,
    scratch_ids: &mut Vec<usize>,
) -> (Vec<usize>, u64) {
    let n = schedule.num_groups();
    let mut order: Vec<usize> = (0..n).collect();
    if !spill_aware || n <= 1 || !store.may_spill() {
        return (order, 0);
    }
    let mut ranks: Vec<usize> = Vec::with_capacity(n);
    for g in 0..n {
        schedule.group_blocks_into(g, scratch_ids);
        ranks.push(store.residency_rank(scratch_ids));
    }
    order.sort_by_key(|&g| ranks[g]);
    // A group is *promoted* when it lands earlier than its natural
    // position `g` — the resident groups pulled forward. (Demoted cold
    // groups are the mirror image; counting both would double-report.)
    let moved = order.iter().enumerate().filter(|&(i, &g)| g > i).count() as u64;
    (order, moved)
}

/// Pluggable gate-application backend: native rust kernels or the AOT'd
/// JAX/Pallas executables (implemented in `runtime::XlaApplier`).
pub trait GateApplier: Sync {
    /// Apply `gate` to the buffer with targets remapped to `bits`
    /// (buffer bit positions).
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()>;

    fn name(&self) -> &'static str {
        "native"
    }

    /// True when this backend runs on the native fused/batched kernels,
    /// letting engines replace per-gate `apply` loops with fused stage
    /// ops (`gates::fused::apply_stage`) and parallel plane sweeps.
    /// Backends that ship gates elsewhere (XLA) keep the per-gate path.
    fn supports_fusion(&self) -> bool {
        false
    }
}

/// The tuned rust kernel path.
pub struct NativeApplier;

impl GateApplier for NativeApplier {
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()> {
        apply_gate_remapped(re, im, gate, bits);
        Ok(())
    }

    fn supports_fusion(&self) -> bool {
        true
    }
}

/// Outcome of a simulation run: final state (when materialized), metrics,
/// and memory statistics.
#[derive(Debug)]
pub struct SimResult {
    pub engine: &'static str,
    pub circuit_name: String,
    pub n_qubits: usize,
    pub wall_secs: f64,
    pub metrics: MetricsReport,
    pub mem: MemStats,
    /// Peak compressed footprint in bytes (Fig. 9's "practical memory");
    /// for the dense engine this is the full state size.
    pub peak_bytes: usize,
    /// Number of Algorithm-1 stages (1 per gate for sc19, 1 for dense).
    pub stages: usize,
    pub state: Option<StateVector>,
}

impl SimResult {
    /// Fidelity against an ideal state (panics if state not materialized).
    pub fn fidelity_vs(&self, ideal: &StateVector) -> f64 {
        self.state
            .as_ref()
            .expect("state not materialized; run with materialize=true")
            .fidelity(ideal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{BlockPayload, BlockStore, StoreOptions};
    use crate::state::BlockLayout;

    fn payload(tag: u8) -> BlockPayload {
        BlockPayload { re: vec![tag; 32], im: vec![tag; 32] }
    }

    #[test]
    fn spill_aware_order_runs_resident_groups_first() {
        // 8 single-block groups (empty inner set → group g holds block g).
        let layout = BlockLayout::new(5, 2).unwrap();
        let schedule = layout.group_schedule(&[]).unwrap();
        assert_eq!(schedule.num_groups(), 8);
        let dir =
            std::env::temp_dir().join(format!("bmqsim-order-{}", std::process::id()));
        let opts =
            StoreOptions { async_spill: false, prefetch_depth: 0, ..Default::default() };
        // Budget fits exactly 4 of the 64-byte payloads.
        let store = BlockStore::with_options(Some(4 * 64), Some(dir), opts).unwrap();
        store.publish_schedule(&[0, 1, 2, 3, 4, 5, 6, 7], 1);
        for id in 0..8 {
            store.put(id, payload(id as u8)).unwrap();
        }
        // Belady under schedule 0..8: each overflow evicts the farthest
        // resident, leaving {0, 1, 2, 7} in primary and {3, 4, 5, 6} on
        // disk (7 stays: it was the incoming block of the final put).
        let mut ids = Vec::new();
        let (order, moved) = plan_group_order(&schedule, &store, true, &mut ids);
        assert_eq!(order, vec![0, 1, 2, 7, 3, 4, 5, 6]);
        // Exactly one group (7) was PROMOTED ahead of its natural slot;
        // the four cold groups sliding back are not counted.
        assert_eq!(moved, 1);
        // Belady ranks must follow the REORDERED block order: republish
        // and check the store schedules eviction consistently (taking the
        // now-first groups touches no disk).
        let reordered: Vec<usize> = order.clone();
        store.publish_schedule(&reordered, 1);
        let before = store.stats().fetch_from_secondary;
        for &g in &[0usize, 1, 2, 7] {
            store.take(g).unwrap();
            store.group_completed();
        }
        assert_eq!(
            store.stats().fetch_from_secondary,
            before,
            "resident-first order still paid disk reads"
        );
        // Spill-aware off, or a store with no secondary tier: natural order.
        let (nat, m0) = plan_group_order(&schedule, &store, false, &mut ids);
        assert_eq!(nat, (0..8).collect::<Vec<_>>());
        assert_eq!(m0, 0);
        let un = BlockStore::unbounded();
        let (nat, m0) = plan_group_order(&schedule, &un, true, &mut ids);
        assert_eq!(nat, (0..8).collect::<Vec<_>>());
        assert_eq!(m0, 0);
    }
}
