//! Simulation engines.
//!
//! * [`dense`] — uncompressed full-memory reference (the SV-Sim-class
//!   baseline and the ψ_ideal source for fidelity).
//! * [`bmqsim`] — the paper's system: staged (Algorithm 1), compressed
//!   (Algorithm 2), pipelined (§4.2), two-level memory (§4.4).
//! * [`sc19`] — the SC19-Sim baseline prototype: per-gate block
//!   (de)compression (§3's "basic solution").

pub mod bmqsim;
pub mod config;
pub mod dense;
pub mod observable;
pub mod sc19;

pub use bmqsim::BmqSim;
pub use config::{Backend, SimConfig};
pub use dense::DenseSim;
pub use sc19::Sc19Sim;

use crate::circuit::Gate;
use crate::gates::apply_gate_remapped;
use crate::memory::MemStats;
use crate::metrics::MetricsReport;
use crate::state::StateVector;
use crate::types::Result;

/// Pluggable gate-application backend: native rust kernels or the AOT'd
/// JAX/Pallas executables (implemented in `runtime::XlaApplier`).
pub trait GateApplier: Sync {
    /// Apply `gate` to the buffer with targets remapped to `bits`
    /// (buffer bit positions).
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()>;

    fn name(&self) -> &'static str {
        "native"
    }

    /// True when this backend runs on the native fused/batched kernels,
    /// letting engines replace per-gate `apply` loops with fused stage
    /// ops (`gates::fused::apply_stage`) and parallel plane sweeps.
    /// Backends that ship gates elsewhere (XLA) keep the per-gate path.
    fn supports_fusion(&self) -> bool {
        false
    }
}

/// The tuned rust kernel path.
pub struct NativeApplier;

impl GateApplier for NativeApplier {
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()> {
        apply_gate_remapped(re, im, gate, bits);
        Ok(())
    }

    fn supports_fusion(&self) -> bool {
        true
    }
}

/// Outcome of a simulation run: final state (when materialized), metrics,
/// and memory statistics.
#[derive(Debug)]
pub struct SimResult {
    pub engine: &'static str,
    pub circuit_name: String,
    pub n_qubits: usize,
    pub wall_secs: f64,
    pub metrics: MetricsReport,
    pub mem: MemStats,
    /// Peak compressed footprint in bytes (Fig. 9's "practical memory");
    /// for the dense engine this is the full state size.
    pub peak_bytes: usize,
    /// Number of Algorithm-1 stages (1 per gate for sc19, 1 for dense).
    pub stages: usize,
    pub state: Option<StateVector>,
}

impl SimResult {
    /// Fidelity against an ideal state (panics if state not materialized).
    pub fn fidelity_vs(&self, ideal: &StateVector) -> f64 {
        self.state
            .as_ref()
            .expect("state not materialized; run with materialize=true")
            .fidelity(ideal)
    }
}
