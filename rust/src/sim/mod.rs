//! Simulation engines.
//!
//! * [`dense`] — uncompressed full-memory reference (the SV-Sim-class
//!   baseline and the ψ_ideal source for fidelity).
//! * [`bmqsim`] — the paper's system: staged (Algorithm 1), compressed
//!   (Algorithm 2), pipelined (§4.2), two-level memory (§4.4).
//! * [`sc19`] — the SC19-Sim baseline prototype: per-gate block
//!   (de)compression (§3's "basic solution").

pub mod bmqsim;
pub mod config;
pub mod dense;
pub mod observable;
pub mod sc19;

pub use bmqsim::BmqSim;
pub use config::{auto_overlap, Backend, OverlapMode, SimConfig, OVERLAP_AUTO_MIN_CONCEAL_NS};
pub use dense::DenseSim;
pub use sc19::Sc19Sim;

use crate::circuit::Gate;
use crate::compress::budget::BudgetController;
use crate::gates::apply_gate_remapped;
use crate::memory::{BlockPayload, BlockStore, MemStats, Recompressor};
use crate::metrics::{Metrics, MetricsReport};
use crate::pipeline::{
    run_items, PhasePool, PipelineConfig, RingDepthController, ScratchPool, WorkerCtx,
    MAX_EPOCHS_IN_FLIGHT, RING_DEPTH_MAX,
};
use crate::state::{GroupSchedule, StateVector};
use crate::types::{Error, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A borrowed phase closure as the engines hand it to [`PoolDriver`]:
/// one third of a group chain (decode / apply / encode), callable on any
/// worker.
pub(crate) type PhaseFn<'a> = &'a (dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<()> + Sync);

/// An owned phase closure for cross-stage submission. The driver keeps
/// the box alive (its pointee is heap-stable) until the epoch running it
/// is drained — that ownership is what discharges
/// [`PhasePool::submit_stage`]'s safety contract.
pub(crate) type BoxedPhase<'a> = Box<dyn Fn(&mut WorkerCtx<'_>, usize) -> Result<()> + Sync + 'a>;

/// One stage's three owned phase closures, submitted as a unit to
/// [`PoolDriver::submit_stage`].
pub(crate) struct StageBatch<'a> {
    pub decode: BoxedPhase<'a>,
    pub apply: BoxedPhase<'a>,
    pub encode: BoxedPhase<'a>,
}

/// Shared chain-driver plumbing for both engines: the lazily-built
/// sequential [`ScratchPool`] and persistent [`PhasePool`], the adaptive
/// ring-depth controller, and the per-stage overlap auto-enable decision.
/// One instance lives per engine run; `run_stage` (barrier) or
/// `submit_stage` (cross-stage window) is called once per stage (per gate
/// in SC19), `finish` once before the metrics snapshot.
pub(crate) struct PoolDriver<'a> {
    pipe: PipelineConfig,
    overlap: OverlapMode,
    depth_cap: usize,
    codec_ns_per_amp: f64,
    /// Epoch-drain watchdog deadline (`SimConfig::stall_timeout_ms`);
    /// armed on the phase pool at construction.
    stall_timeout: Option<Duration>,
    seq_pool: Option<ScratchPool>,
    phase_pool: Option<PhasePool>,
    depth_ctl: RingDepthController,
    /// Batches whose epochs are still in flight on the phase pool, oldest
    /// first. The pool's lifetime-erased pointers point into these boxes;
    /// [`Self::sync_inflight`] pops a batch only after the pool retired
    /// its epoch, and `Drop` drains the pool before the boxes free.
    inflight: VecDeque<StageBatch<'a>>,
}

impl<'a> PoolDriver<'a> {
    /// `codec_ns_per_amp` is the engine's init-time codec calibration (see
    /// [`auto_overlap`]); `pipe` is the worker shape the engine actually
    /// drives (BMQSIM: `config.pipeline`; SC19: one device × its workers).
    pub(crate) fn new(config: &SimConfig, pipe: PipelineConfig, codec_ns_per_amp: f64) -> Self {
        let depth_cap = if config.pipeline_depth_auto {
            RING_DEPTH_MAX
        } else {
            config.pipeline_depth.max(1)
        };
        PoolDriver {
            pipe,
            overlap: config.overlap,
            depth_cap,
            codec_ns_per_amp,
            stall_timeout: config.stall_timeout_ms.map(Duration::from_millis),
            seq_pool: None,
            phase_pool: None,
            depth_ctl: RingDepthController::new(
                config.pipeline_depth,
                config.pipeline_depth_auto,
                depth_cap,
            ),
            inflight: VecDeque::new(),
        }
    }

    /// The phase pool, built on first use with the watchdog deadline
    /// armed (both overlap paths construct through here so no pool can
    /// exist without its configured stall timeout).
    fn pool(&mut self) -> &mut PhasePool {
        let pipe = self.pipe;
        let depth_cap = self.depth_cap;
        let stall_timeout = self.stall_timeout;
        self.phase_pool.get_or_insert_with(|| {
            let mut p = PhasePool::new(pipe, depth_cap);
            p.set_stall_timeout(stall_timeout);
            p
        })
    }

    /// The per-stage overlap decision (auto-enable heuristic unless
    /// pinned), with the auto counters recorded.
    fn decide_overlap(&self, group_len: usize, num_groups: usize, metrics: &Metrics) -> bool {
        let heuristic = auto_overlap(group_len, num_groups, self.codec_ns_per_amp);
        let use_overlap = self.overlap.engaged(heuristic);
        if self.overlap.is_auto() {
            if use_overlap {
                metrics.auto_overlap_on.fetch_add(1, Ordering::Relaxed);
            } else {
                metrics.auto_overlap_off.fetch_add(1, Ordering::Relaxed);
            }
        }
        use_overlap
    }

    /// Retire batches whose epochs the pool has drained. The pool's
    /// window length is authoritative: a batch is popped only once its
    /// epoch is gone, so no erased pointer ever outlives its closures.
    fn sync_inflight(&mut self) {
        let live = self.phase_pool.as_ref().map_or(0, |p| p.in_flight());
        while self.inflight.len() > live {
            self.inflight.pop_front();
        }
    }

    /// Drain pool epochs until at most `window` remain, timing the wait
    /// as `Metrics::epoch_drain_ns` — the boundary cost the cross-stage
    /// overlap exists to shrink. On `Err` the pool has already drained
    /// its whole window (errors only surface once it is empty).
    fn drain_to_window(&mut self, window: usize, metrics: &Metrics) -> Result<()> {
        let r = match self.phase_pool.as_mut() {
            Some(pool) if pool.in_flight() > window => {
                let t0 = Instant::now();
                let r = if window == 0 { pool.drain_all() } else { pool.drain_oldest() };
                metrics
                    .epoch_drain_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                r
            }
            _ => Ok(()),
        };
        self.sync_inflight();
        r
    }

    /// Drain every in-flight epoch and surface any recorded failure. The
    /// engine calls this once after submitting its last stage, before the
    /// metrics snapshot / readout.
    pub(crate) fn drain_all(&mut self, metrics: &Metrics) -> Result<()> {
        self.drain_to_window(0, metrics)
    }

    /// Drain until at most one epoch is in flight — the engine's
    /// pre-publish step. Before stitching stage k+1's schedule onto stage
    /// k's tail, stage k-1 must have fully retired: its `group_completed`
    /// calls advanced the store's progress cursor past every group the
    /// stitched publish is about to rebase away.
    pub(crate) fn drain_to_one(&mut self, metrics: &Metrics) -> Result<()> {
        self.drain_to_window(1, metrics)
    }

    /// Submit one stage of `num_groups` disjoint group chains without
    /// waiting for it to finish. Overlap-engaged stages join the phase
    /// pool's epoch window (up to [`MAX_EPOCHS_IN_FLIGHT`] coexist, so
    /// the previous stage's encode tail runs under this stage's decode
    /// head); declined stages drain the window first and run the batch
    /// sequentially. The driver owns the batch's boxed closures until the
    /// epoch retires — including on unwind (see `Drop`) — which is what
    /// makes the pool's lifetime-erased submission sound.
    pub(crate) fn submit_stage(
        &mut self,
        group_len: usize,
        num_groups: usize,
        metrics: &Metrics,
        batch: StageBatch<'a>,
    ) -> Result<()> {
        let use_overlap = self.decide_overlap(group_len, num_groups, metrics);
        let pipe = self.pipe;
        if use_overlap {
            self.drain_to_window(MAX_EPOCHS_IN_FLIGHT - 1, metrics)?;
            let stall = self.pool().stats().total_stall_ns();
            let depth = self.depth_ctl.stage_depth(stall);
            self.inflight.push_back(batch);
            let r = {
                let b = self.inflight.back().expect("batch just pushed");
                let pool = self.phase_pool.as_mut().expect("phase pool built above");
                // SAFETY: the boxed closures live in `self.inflight`
                // (heap-stable behind their boxes) until `sync_inflight`
                // pops the batch, which happens only after the pool
                // retired the epoch — via `drain_to_window` on every
                // normal path and `Drop` on unwind. The pre-drain above
                // freed an epoch slot, so this submit does not drain
                // (and therefore cannot fail) internally.
                unsafe { pool.submit_stage(num_groups, depth, &*b.decode, &*b.apply, &*b.encode) }
            };
            self.sync_inflight();
            r
        } else {
            self.drain_to_window(0, metrics)?;
            let pool =
                self.seq_pool.get_or_insert_with(|| ScratchPool::new(pipe.workers()));
            run_items::<Error, _>(pipe, num_groups, pool, |ctx, i| {
                (batch.decode)(&mut *ctx, i)?;
                (batch.apply)(&mut *ctx, i)?;
                (batch.encode)(&mut *ctx, i)
            })
        }
    }

    /// Run one stage of `num_groups` disjoint group chains to a full
    /// barrier, deciding per stage (unless pinned) whether to overlap:
    /// engaged stages go to the persistent phase pool at the controller's
    /// ring depth, declined stages run the same three closures composed
    /// sequentially per worker. Both pools are built on first use, so a
    /// run whose stages all resolve one way never pays for the other.
    pub(crate) fn run_stage(
        &mut self,
        group_len: usize,
        num_groups: usize,
        metrics: &Metrics,
        decode: PhaseFn<'_>,
        apply: PhaseFn<'_>,
        encode: PhaseFn<'_>,
    ) -> Result<()> {
        // Barrier semantics: any cross-stage window still open must close
        // before these borrowed (non-boxed) closures may run.
        self.drain_all(metrics)?;
        let use_overlap = self.decide_overlap(group_len, num_groups, metrics);
        let pipe = self.pipe;
        if use_overlap {
            let stall = self.pool().stats().total_stall_ns();
            let depth = self.depth_ctl.stage_depth(stall);
            self.pool().run_stage(num_groups, depth, decode, apply, encode)
        } else {
            let pool =
                self.seq_pool.get_or_insert_with(|| ScratchPool::new(pipe.workers()));
            run_items::<Error, _>(pipe, num_groups, pool, |ctx, i| {
                decode(&mut *ctx, i)?;
                apply(&mut *ctx, i)?;
                encode(&mut *ctx, i)
            })
        }
    }

    /// End-of-run accounting: arena growth across both pools, the
    /// overlap/pool counters, and the ring-depth trajectory.
    pub(crate) fn finish(&self, metrics: &Metrics) {
        let grows = self.seq_pool.as_ref().map_or(0, |p| p.total_plane_grows())
            + self.phase_pool.as_ref().map_or(0, |p| p.total_plane_grows());
        metrics.scratch_grows.store(grows, Ordering::Relaxed);
        if let Some(pool) = &self.phase_pool {
            metrics.absorb_overlap(pool.stats());
            metrics
                .phase_threads_spawned
                .store(pool.threads_spawned(), Ordering::Relaxed);
            metrics
                .ring_depth_final
                .store(self.depth_ctl.current() as u64, Ordering::Relaxed);
            metrics.ring_depth_peak.store(self.depth_ctl.peak() as u64, Ordering::Relaxed);
            metrics
                .ring_depth_adjustments
                .store(self.depth_ctl.adjustments(), Ordering::Relaxed);
        }
    }
}

impl Drop for PoolDriver<'_> {
    fn drop(&mut self) {
        // Unwind / early-return guard for `submit_stage`'s safety
        // contract: the boxed closures in `inflight` must outlive their
        // epochs, so abort and drain the pool BEFORE the batches free.
        // A panic payload re-raised by the drain is swallowed here — if
        // the driver is dropping on a panic path the caller already
        // carries the original payload, and a second unwind out of `drop`
        // would abort the process.
        let wedged = match self.phase_pool.as_mut() {
            Some(pool) if pool.in_flight() > 0 => {
                pool.abort();
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let _ = pool.drain_all();
                }));
                pool.in_flight() > 0
            }
            _ => false,
        };
        if wedged {
            // The stall watchdog gave up mid-drain: phase threads may
            // still hold erased pointers into the inflight boxes, and the
            // pool's Drop would join those wedged threads forever. Leak
            // both — soundness over cleanliness on this failure path (the
            // run is already surfacing a typed stall error).
            std::mem::forget(std::mem::take(&mut self.inflight));
            if let Some(pool) = self.phase_pool.take() {
                std::mem::forget(pool);
            }
            return;
        }
        self.inflight.clear();
    }
}

/// Spill-aware scheduling (ROADMAP): order a stage's groups so the ones
/// whose blocks are already primary-resident run first, deferring groups
/// that would pay synchronous disk reads until the prefetcher has had
/// time to stage them. Returns `(group processing order, groups promoted
/// ahead of their natural position)`.
///
/// The query runs *before* `publish_schedule`, and the published block
/// order follows the returned group order — so Belady ranks and the
/// prefetch window stay consistent with what the workers actually do.
/// Groups are disjoint, so any processing order yields byte-identical
/// terminal blocks; the sort is stable, keeping natural order within each
/// residency class. No-op (natural order) when `spill_aware` is off or
/// the store has no secondary tier.
pub(crate) fn plan_group_order(
    schedule: &GroupSchedule,
    store: &BlockStore,
    spill_aware: bool,
    scratch_ids: &mut Vec<usize>,
) -> (Vec<usize>, u64) {
    let n = schedule.num_groups();
    let mut order: Vec<usize> = (0..n).collect();
    if !spill_aware || n <= 1 || !store.may_spill() {
        return (order, 0);
    }
    let mut ranks: Vec<usize> = Vec::with_capacity(n);
    for g in 0..n {
        schedule.group_blocks_into(g, scratch_ids);
        ranks.push(store.residency_rank(scratch_ids));
    }
    order.sort_by_key(|&g| ranks[g]);
    // A group is *promoted* when it lands earlier than its natural
    // position `g` — the resident groups pulled forward. (Demoted cold
    // groups are the mirror image; counting both would double-report.)
    let moved = order.iter().enumerate().filter(|&(i, &g)| g > i).count() as u64;
    (order, moved)
}

/// Cross-stage decode gating (shared-block barriers): one gate per stage,
/// one slot per *item* (group chain) in that stage's processing order.
/// The stage's encode marks items done; the NEXT stage's decode waits
/// only for the specific previous-stage items that own its input blocks —
/// disjoint groups flow into the new epoch immediately, shared-block
/// groups hold until their producers have re-encoded.
///
/// Determinism: a stage-`s+1` group reads exactly the blocks written by
/// its owner groups in stage `s`, and every block has exactly one owner
/// per stage (groups tile the block set) — so waiting for those owners is
/// sufficient. The gate is a correctness mechanism, not a heuristic.
pub(crate) struct BoundaryGate {
    done: Vec<AtomicBool>,
    remaining: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl BoundaryGate {
    pub(crate) fn new(items: usize) -> Self {
        BoundaryGate {
            done: (0..items).map(|_| AtomicBool::new(false)).collect(),
            remaining: AtomicUsize::new(items),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Mark one item's blocks re-encoded and stored. Idempotent (the
    /// engine's unwind guard and happy path may both call it).
    pub(crate) fn mark_done(&self, item: usize) {
        if !self.done[item].swap(true, Ordering::AcqRel) {
            self.remaining.fetch_sub(1, Ordering::AcqRel);
            // Serialize against a waiter between its readiness check and
            // its wait — classic lost-wakeup fence.
            drop(self.lock.lock());
            self.cv.notify_all();
        }
    }

    /// True once every item of the stage has encoded.
    pub(crate) fn complete(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    fn ready(&self, deps: &[u32]) -> bool {
        self.complete() || deps.iter().all(|&d| self.done[d as usize].load(Ordering::Acquire))
    }

    /// Block until every dep item is done, or `abort` rises (the run is
    /// failing; its results are discarded). Returns the stall in ns
    /// (`Metrics::boundary_stall_ns`). The wait re-polls the abort flag
    /// every millisecond, so a producer that died without marking
    /// (items skimmed on an aborted epoch) cannot wedge a waiter.
    ///
    /// With `stall_timeout` armed (CLI `--stall-timeout-ms`), a wait
    /// that observes no producer progress for that long gives up with a
    /// typed error carrying a progress dump (which dep items never
    /// encoded, how far the previous stage got) instead of polling
    /// forever — the watchdog the chaos harness leans on when a fault
    /// plan wedges an encoder.
    pub(crate) fn wait_for(
        &self,
        deps: &[u32],
        abort: &AtomicBool,
        stall_timeout: Option<Duration>,
    ) -> Result<u64> {
        if self.ready(deps) {
            return Ok(0);
        }
        let t0 = Instant::now();
        let mut last_remaining = self.remaining.load(Ordering::Acquire);
        let mut idle_since = Instant::now();
        let mut guard = self.lock.lock().unwrap();
        while !self.ready(deps) && !abort.load(Ordering::Acquire) {
            if let Some(limit) = stall_timeout {
                let remaining = self.remaining.load(Ordering::Acquire);
                if remaining != last_remaining {
                    last_remaining = remaining;
                    idle_since = Instant::now();
                } else if idle_since.elapsed() >= limit {
                    drop(guard);
                    let total = self.done.len();
                    let missing: Vec<u32> = deps
                        .iter()
                        .copied()
                        .filter(|&d| !self.done[d as usize].load(Ordering::Acquire))
                        .collect();
                    return Err(Error::spill(format!(
                        "boundary-gate watchdog: no producer progress for {} ms waiting \
                         on previous-stage items {missing:?} ({}/{total} items encoded)",
                        limit.as_millis(),
                        total - remaining,
                    )));
                }
            }
            let (g, _) = self.cv.wait_timeout(guard, Duration::from_millis(1)).unwrap();
            guard = g;
        }
        drop(guard);
        Ok(t0.elapsed().as_nanos() as u64)
    }
}

/// Phase-closure wrapper for the engines under cross-stage overlap: an
/// `Err` OR a panic raises the run-level fail flag, so gate waiters in
/// the next epoch stop waiting for producers that will never mark
/// ([`BoundaryGate::wait_for`] polls the flag).
pub(crate) fn noting_failure<R>(flag: &AtomicBool, f: impl FnOnce() -> Result<R>) -> Result<R> {
    struct RaiseOnUnwind<'a>(&'a AtomicBool);
    impl Drop for RaiseOnUnwind<'_> {
        fn drop(&mut self) {
            if std::thread::panicking() {
                self.0.store(true, Ordering::Release);
            }
        }
    }
    let guard = RaiseOnUnwind(flag);
    let r = f();
    drop(guard);
    if r.is_err() {
        flag.store(true, Ordering::Release);
    }
    r
}

/// L2 mass of one block's planes. The engines keep the state normalized,
/// so this is the block's fraction of the whole state's probability —
/// the `m_k` weight the [`BudgetController`] ledger charges per encode.
pub(crate) fn l2_mass(re: &[f64], im: &[f64]) -> f64 {
    let mut s = 0.0f64;
    for &x in re {
        s += x * x;
    }
    for &x in im {
        s += x * x;
    }
    s
}

/// The compressed-primary third tier, as a store callback: when the
/// store is about to evict `block`, ask the controller for a looser
/// bound and — if approved AND the re-encode shrinks the payload by at
/// least 25% — hand back the harder-compressed payload so the block
/// stays primary-resident instead of spilling. Any `None` (declined,
/// undecodable, or not enough shrink) falls back to the normal spill
/// path. Shared by both engines.
///
/// The closure runs on whichever thread drives the eviction (an encode
/// worker inside `store.put`, or the write-back thread), so it only
/// touches the controller's own lock and fresh allocations — never a
/// store shard lock.
pub(crate) fn budget_recompressor(ctrl: Arc<BudgetController>, codec: crate::compress::Codec) -> Recompressor {
    Recompressor(Arc::new(move |block, payload: &BlockPayload| {
        // How lossy the resident payload already is: the wire format
        // embeds the bound each plane was encoded with (raw planes are
        // lossless, i.e. bound 0).
        let b_re = crate::compress::plane_bound(&payload.re).ok()?.unwrap_or(0.0);
        let b_im = crate::compress::plane_bound(&payload.im).ok()?.unwrap_or(0.0);
        let approved = ctrl.approve_recompress(block, b_re.max(b_im))?;
        let re = crate::compress::decompress_any(&payload.re).ok()?;
        let im = crate::compress::decompress_any(&payload.im).ok()?;
        let loose = codec.with_bound(approved);
        let nre = loose.compress(&re).ok()?;
        let nim = loose.compress(&im).ok()?;
        // Only worth the decode/encode CPU when the shrink is
        // substantial; the budget drawn by the approval stays spent
        // either way (the per-block latch keeps that waste bounded).
        if (nre.len() + nim.len()) * 4 <= (payload.re.len() + payload.im.len()) * 3 {
            Some(BlockPayload { re: nre, im: nim })
        } else {
            None
        }
    }))
}

/// xxh64 fingerprint of the *semantic* run configuration + circuit: the
/// compatibility key a checkpoint embeds and a resume must match. It
/// covers everything that determines the terminal state and the stage
/// plan (engine, qubit count, gate list, block geometry, partition inner
/// size, codec, precision, fusion knobs, error-control policy/target) and
/// deliberately *excludes* the
/// execution-shape knobs (workers, pipeline depth, overlap, spill budget,
/// shards) — byte-identity across those is pinned by the engine parity
/// tests, so a checkpoint taken under async spill may resume under sync
/// spill and still land on the same terminal state.
pub(crate) fn checkpoint_fingerprint(
    engine: &str,
    config: &SimConfig,
    circuit: &crate::circuit::Circuit,
) -> u64 {
    // The error-control policy shapes every encoded payload (per-block
    // bounds, recompression approvals), so a resume that changed
    // `--fidelity-target`/`--error-policy` would silently mix bounds —
    // it must mismatch here (pinned by `fingerprint_covers_error_policy`).
    let canon = format!(
        "{engine}|n={}|b={}|inner={}|codec={:?}|precision={:?}|fusion={}|max_fuse={}|tile={}|epolicy={:?}|ftarget={:?}|gates={:?}",
        circuit.n_qubits,
        config.effective_block_qubits(circuit.n_qubits),
        config.inner_size,
        config.codec,
        config.precision,
        config.fusion,
        config.max_fuse_qubits,
        config.tile_bits,
        config.error_policy,
        config.fidelity_target,
        circuit.gates,
    );
    crate::memory::xxh64(canon.as_bytes(), 0)
}

/// Pluggable gate-application backend: native rust kernels or the AOT'd
/// JAX/Pallas executables (implemented in `runtime::XlaApplier`).
pub trait GateApplier: Sync {
    /// Apply `gate` to the buffer with targets remapped to `bits`
    /// (buffer bit positions).
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()>;

    fn name(&self) -> &'static str {
        "native"
    }

    /// True when this backend runs on the native fused/batched kernels,
    /// letting engines replace per-gate `apply` loops with fused stage
    /// ops (`gates::fused::apply_stage`) and parallel plane sweeps.
    /// Backends that ship gates elsewhere (XLA) keep the per-gate path.
    fn supports_fusion(&self) -> bool {
        false
    }
}

/// The tuned rust kernel path.
pub struct NativeApplier;

impl GateApplier for NativeApplier {
    fn apply(&self, re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) -> Result<()> {
        apply_gate_remapped(re, im, gate, bits);
        Ok(())
    }

    fn supports_fusion(&self) -> bool {
        true
    }
}

/// Outcome of a simulation run: final state (when materialized), metrics,
/// and memory statistics.
#[derive(Debug)]
pub struct SimResult {
    /// Engine identifier (`"bmqsim"`, `"dense"`, `"sc19-cpu"`, ...).
    pub engine: &'static str,
    /// Circuit name the run executed.
    pub circuit_name: String,
    /// Number of qubits simulated.
    pub n_qubits: usize,
    /// End-to-end wall time in seconds.
    pub wall_secs: f64,
    /// Aggregated pipeline/codec/error-control metrics.
    pub metrics: MetricsReport,
    /// Terminal memory-tier statistics.
    pub mem: MemStats,
    /// Peak compressed footprint in bytes (Fig. 9's "practical memory");
    /// for the dense engine this is the full state size.
    pub peak_bytes: usize,
    /// Number of Algorithm-1 stages (1 per gate for sc19, 1 for dense).
    pub stages: usize,
    /// Final dense state, when materialization was requested.
    pub state: Option<StateVector>,
}

impl SimResult {
    /// Fidelity against an ideal state (panics if state not materialized).
    pub fn fidelity_vs(&self, ideal: &StateVector) -> f64 {
        self.state
            .as_ref()
            .expect("state not materialized; run with materialize=true")
            .fidelity(ideal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{BlockPayload, BlockStore, StoreOptions};
    use crate::state::BlockLayout;

    fn payload(tag: u8) -> BlockPayload {
        BlockPayload { re: vec![tag; 32], im: vec![tag; 32] }
    }

    #[test]
    fn spill_aware_order_runs_resident_groups_first() {
        // 8 single-block groups (empty inner set → group g holds block g).
        let layout = BlockLayout::new(5, 2).unwrap();
        let schedule = layout.group_schedule(&[]).unwrap();
        assert_eq!(schedule.num_groups(), 8);
        let dir =
            std::env::temp_dir().join(format!("bmqsim-order-{}", std::process::id()));
        let opts =
            StoreOptions { async_spill: false, prefetch_depth: 0, ..Default::default() };
        // Budget fits exactly 4 of the 64-byte payloads.
        let store = BlockStore::with_options(Some(4 * 64), Some(dir), opts).unwrap();
        store.publish_schedule(&[0, 1, 2, 3, 4, 5, 6, 7], 1);
        for id in 0..8 {
            store.put(id, payload(id as u8)).unwrap();
        }
        // Belady under schedule 0..8: each overflow evicts the farthest
        // resident, leaving {0, 1, 2, 7} in primary and {3, 4, 5, 6} on
        // disk (7 stays: it was the incoming block of the final put).
        let mut ids = Vec::new();
        let (order, moved) = plan_group_order(&schedule, &store, true, &mut ids);
        assert_eq!(order, vec![0, 1, 2, 7, 3, 4, 5, 6]);
        // Exactly one group (7) was PROMOTED ahead of its natural slot;
        // the four cold groups sliding back are not counted.
        assert_eq!(moved, 1);
        // Belady ranks must follow the REORDERED block order: republish
        // and check the store schedules eviction consistently (taking the
        // now-first groups touches no disk).
        let reordered: Vec<usize> = order.clone();
        store.publish_schedule(&reordered, 1);
        let before = store.stats().fetch_from_secondary;
        for &g in &[0usize, 1, 2, 7] {
            store.take(g).unwrap();
            store.group_completed();
        }
        assert_eq!(
            store.stats().fetch_from_secondary,
            before,
            "resident-first order still paid disk reads"
        );
        // Spill-aware off, or a store with no secondary tier: natural order.
        let (nat, m0) = plan_group_order(&schedule, &store, false, &mut ids);
        assert_eq!(nat, (0..8).collect::<Vec<_>>());
        assert_eq!(m0, 0);
        let un = BlockStore::unbounded();
        let (nat, m0) = plan_group_order(&schedule, &un, true, &mut ids);
        assert_eq!(nat, (0..8).collect::<Vec<_>>());
        assert_eq!(m0, 0);
    }

    #[test]
    fn boundary_gate_releases_on_deps_and_escapes_on_abort() {
        let gate = BoundaryGate::new(4);
        let abort = AtomicBool::new(false);
        assert!(!gate.complete());
        gate.mark_done(1);
        gate.mark_done(1); // idempotent: must not double-count remaining
        assert_eq!(gate.wait_for(&[1], &abort, None).unwrap(), 0, "satisfied deps must not wait");
        // A dep marked from another thread releases the waiter.
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(5));
                gate.mark_done(0);
            });
            assert!(gate.wait_for(&[0, 1], &abort, None).unwrap() > 0, "waiter never stalled");
        });
        // An unmarked dep + abort: the waiter escapes instead of wedging.
        abort.store(true, Ordering::Release);
        gate.wait_for(&[3], &abort, None).unwrap();
        assert!(!gate.complete());
        gate.mark_done(2);
        gate.mark_done(3);
        assert!(gate.complete(), "all items marked but gate not complete");
        // A complete gate satisfies any dep list with zero stall.
        assert_eq!(gate.wait_for(&[0, 1, 2, 3], &AtomicBool::new(false), None).unwrap(), 0);
    }

    #[test]
    fn boundary_gate_watchdog_converts_a_hang_into_a_typed_error() {
        let gate = BoundaryGate::new(3);
        let abort = AtomicBool::new(false);
        gate.mark_done(0);
        // Item 2's producer never marks: without a timeout this wait
        // would poll until abort; with one it must surface a typed error
        // naming the missing item and the progress so far.
        let err = gate
            .wait_for(&[2], &abort, Some(Duration::from_millis(20)))
            .expect_err("watchdog must fire on a dead producer");
        let msg = err.to_string();
        assert!(msg.contains("watchdog"), "{msg}");
        assert!(msg.contains("[2]"), "dump must name the missing item: {msg}");
        assert!(msg.contains("1/3"), "dump must show progress: {msg}");
        // Progress re-arms the timer: a producer marking while another
        // waits keeps the watchdog quiet until the deps resolve.
        let gate = BoundaryGate::new(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(5));
                gate.mark_done(0);
                std::thread::sleep(Duration::from_millis(5));
                gate.mark_done(1);
            });
            let stalled = gate
                .wait_for(&[0, 1], &abort, Some(Duration::from_millis(1000)))
                .expect("live producers must not trip the watchdog");
            assert!(stalled > 0);
        });
    }

    #[test]
    fn noting_failure_raises_on_err_and_panic() {
        let flag = AtomicBool::new(false);
        assert!(noting_failure(&flag, || Ok(7usize)).is_ok());
        assert!(!flag.load(Ordering::Acquire), "clean call must not raise");
        let r = noting_failure(&flag, || Err::<(), _>(Error::Codec("x".into())));
        assert!(r.is_err());
        assert!(flag.load(Ordering::Acquire), "Err must raise the flag");
        let flag = AtomicBool::new(false);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = noting_failure(&flag, || -> Result<()> { panic!("boom") });
        }));
        assert!(caught.is_err());
        assert!(flag.load(Ordering::Acquire), "panic must raise the flag");
    }
}
